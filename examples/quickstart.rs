//! Quickstart: the 60-second tour of the framework.
//!
//! Loads the AOT artifacts, trains a small Llama-style model with
//! Adam-mini and AdamW side by side, and prints the paper's headline
//! facts live: same loss curve, half the optimizer state.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use adam_mini::config::TrainConfig;
use adam_mini::coordinator::Trainer;
use adam_mini::partition::{total_blocks, Strategy};
use adam_mini::runtime::{manifest, Engine};

fn main() -> anyhow::Result<()> {
    let engine = Engine::new(manifest::default_dir())?;

    // 1. The partition (paper Algorithm 3) and what it saves.
    let mm = engine.manifest.model("t48k")?;
    let spec: Vec<_> = mm
        .params
        .iter()
        .map(|p| p.block_view(Strategy::Hessian).unwrap())
        .collect();
    println!("model t48k: {} params -> {} Hessian blocks \
              ({:.2}% of Adam's v removed)\n",
             mm.n_params, total_blocks(&spec), mm.v_reduction * 100.0);

    // 2. Train with both optimizers on identical data.
    let mut results = Vec::new();
    for optimizer in ["adamw", "adam_mini"] {
        let cfg = TrainConfig {
            model: "t48k".into(),
            optimizer: optimizer.into(),
            steps: 200,
            peak_lr: 6e-3,
            eval_every: 100,
            log_every: 50,
            ..Default::default()
        };
        println!("--- {optimizer} ---");
        let mut trainer = Trainer::from_config(&engine, &cfg)?;
        let hist = trainer.train(false)?;
        println!();
        results.push((optimizer, hist));
    }

    // 3. The punchline.
    println!("=== summary ===");
    for (name, h) in &results {
        println!("{name:<10} val loss {:.4}   optimizer state {:>8.1} KB",
                 h.final_val_loss(), h.opt_state_bytes as f64 / 1e3);
    }
    let (aw, am) = (&results[0].1, &results[1].1);
    println!("\nAdam-mini used {:.1}% of AdamW's optimizer memory with a \
              loss gap of {:+.4}.",
             100.0 * am.opt_state_bytes as f64 / aw.opt_state_bytes as f64,
             am.final_val_loss() - aw.final_val_loss());
    Ok(())
}
