//! End-to-end driver (DESIGN.md §End-to-end validation): pre-train the
//! multi-million-parameter `m11` transformer through the FUSED AOT train
//! step — gradients + L1 Pallas Adam-mini kernel in one XLA executable —
//! on the embedded English byte corpus, for a few hundred steps, and
//! log the loss curve + throughput.
//!
//! Proves the whole stack composes: Rust coordinator → PJRT runtime →
//! L2 JAX transformer → L1 Pallas optimizer kernel.
//!
//! Run: `cargo run --release --example pretrain_e2e [steps]`
//! (defaults to 300 steps; the run is recorded in EXPERIMENTS.md)

use adam_mini::config::TrainConfig;
use adam_mini::coordinator::Trainer;
use adam_mini::eval::perplexity;
use adam_mini::runtime::{manifest, Engine};

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let engine = Engine::new(manifest::default_dir())?;
    let mm = engine.manifest.model("m11")?;
    println!("end-to-end pre-train: m11 ({} params, {} layers, d={}), \
              fused Adam-mini Pallas train step, {} steps on the \
              embedded text corpus\n",
             mm.n_params, mm.n_layers, mm.d_model, steps);

    let cfg = TrainConfig {
        model: "m11".into(),
        optimizer: "adam_mini".into(),
        fused: true,
        data: "text".into(),
        steps,
        peak_lr: 3e-3,
        schedule: "cosine".into(),
        eval_every: (steps / 5).max(1),
        log_every: (steps / 30).max(1),
        ..Default::default()
    };
    let mut trainer = Trainer::from_config(&engine, &cfg)?;
    let hist = trainer.train(false)?;
    let path = hist.write_csv("results/e2e")?;

    let first = hist.steps.first().map(|s| s.loss).unwrap_or(f32::NAN);
    println!("\n=== end-to-end summary ===");
    println!("loss: {:.4} -> {:.4} (ppl {:.2} -> {:.2})", first,
             hist.final_train_loss(), perplexity(first as f64),
             perplexity(hist.final_train_loss() as f64));
    println!("val loss: {:.4}", hist.final_val_loss());
    println!("wall: {:.1}s, {:.0} tokens/s", hist.wall_secs,
             hist.tokens_per_sec);
    println!("optimizer state: {:.2} MB (AdamW would be {:.2} MB)",
             hist.opt_state_bytes as f64 / 1e6,
             2.0 * 4.0 * mm.n_params as f64 / 1e6);
    println!("curve: {}", path.display());
    anyhow::ensure!(hist.final_train_loss() < 0.8 * first,
                    "loss did not improve enough — stack is broken");
    println!("E2E OK: all three layers compose.");
    Ok(())
}
