//! Cluster-throughput simulation (paper Table 2 / Fig 1a / Fig 13c):
//! sweep per-GPU batch size and optimizer on the simulated 2× A800-80GB
//! cluster; show where each optimizer OOMs and what that costs.
//!
//! Run: `cargo run --release --example throughput_sim`

use adam_mini::cluster::{Job, ADAFACTOR_PROFILE, ADAM_MINI_PROFILE,
                         ADAMW_PROFILE};
use adam_mini::memmodel::table1_models;

fn main() {
    println!("=== Llama 2-7B on 2x A800-80GB (simulated) ===\n");
    println!("{:<11} {:>4} {:>11} {:>8} {:>14}", "optimizer", "bs",
             "mem/GPU", "MFU", "tokens/s");
    for opt in [ADAMW_PROFILE, ADAM_MINI_PROFILE] {
        let job = Job::llama7b(opt);
        for bs in 1..=6 {
            let mem = job.mem_per_gpu(bs);
            let fits = mem <= job.gpu.mem_bytes;
            println!("{:<11} {:>4} {:>9.1}GB {:>7.1}% {:>14}", opt.name,
                     bs, mem / 1e9, job.mfu(bs) * 100.0,
                     if fits { format!("{:.0}", job.throughput(bs)) }
                     else { "OOM".into() });
        }
        println!();
    }

    println!("=== GPU-hours to a token budget (Table 2 bottom) ===\n");
    println!("{:<22} {:>12} {:>12} {:>8}", "tokens", "AdamW (h)",
             "Adam-mini (h)", "saved");
    let aw = Job::llama7b(ADAMW_PROFILE);
    let am = Job::llama7b(ADAM_MINI_PROFILE);
    for tokens in [1e9, 70e9, 140e9] {
        let (h_aw, h_am) = (aw.gpu_hours(tokens).unwrap(),
                            am.gpu_hours(tokens).unwrap());
        println!("{:<22} {:>12.1} {:>12.1} {:>7.1}%",
                 format!("{:.0}B", tokens / 1e9), h_aw, h_am,
                 100.0 * (1.0 - h_am / h_aw));
    }

    println!("\n=== Fig 13c: optimizer-step latency at Llama 2-1B ===\n");
    let arch = &table1_models()[1];
    for opt in [ADAM_MINI_PROFILE, ADAMW_PROFILE, ADAFACTOR_PROFILE] {
        let job = Job::from_arch(arch, 2, opt);
        let (bs, thr) = job.best_throughput().unwrap();
        println!("{:<11} opt-step {:>6.1} ms   best bs {:>3}   \
                  {:>8.0} tok/s", opt.name,
                 job.opt_step_time() * 1e3, bs, thr);
    }
}
