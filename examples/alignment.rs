//! Alignment pipeline demo (paper §3.3): pre-train → SFT (prompt-masked)
//! → RLHF (ReMax) on a small model, with Adam-mini end to end.
//!
//! Run: `cargo run --release --example alignment`

use adam_mini::config::TrainConfig;
use adam_mini::coordinator::Trainer;
use adam_mini::optim;
use adam_mini::rlhf::{remax_train, sft_train, RemaxConfig, SftConfig};
use adam_mini::runtime::{manifest, Engine, ModelRuntime};

fn main() -> anyhow::Result<()> {
    let engine = Engine::new(manifest::default_dir())?;
    let model = "t48k";

    // Stage 1: pre-train the base model.
    println!("=== stage 1: pre-train ({model}, Adam-mini) ===");
    let cfg = TrainConfig {
        model: model.into(),
        optimizer: "adam_mini".into(),
        steps: 150,
        peak_lr: 6e-3,
        eval_every: 75,
        log_every: 50,
        ..Default::default()
    };
    let mut trainer = Trainer::from_config(&engine, &cfg)?;
    let pre = trainer.train(false)?;
    let mut params = trainer.params.clone();

    // Stage 2: SFT on an instruction-style distribution, loss masked to
    // response tokens.
    println!("\n=== stage 2: SFT (prompt-masked) ===");
    let rt = ModelRuntime::new(&engine, model)?;
    let hp = engine.manifest.hyper();
    let mut opt = optim::by_name("adam_mini", hp, &params, &rt.mm.meta())?;
    let sft_losses = sft_train(&engine, &rt, &mut params, opt.as_mut(),
                               &SftConfig { steps: 60,
                                            ..Default::default() })?;
    println!("SFT masked loss: {:.4} -> {:.4}", sft_losses[0],
             sft_losses.last().unwrap());

    // Stage 3: ReMax reward ascent against the preference reward.
    println!("\n=== stage 3: RLHF (ReMax) ===");
    let hp_rl = optim::Hyper { weight_decay: 0.0, ..hp };
    let mut opt = optim::by_name("adam_mini", hp_rl, &params,
                                 &rt.mm.meta())?;
    let logs = remax_train(&engine, &rt, &mut params, opt.as_mut(),
                           &RemaxConfig { steps: 12, lr: 2e-4,
                                          ..Default::default() })?;
    for l in logs.iter().step_by(3) {
        println!("step {:>3}  reward {:+.3}  (greedy baseline {:+.3})",
                 l.step, l.mean_reward, l.baseline_reward);
    }
    let first = logs.first().unwrap().mean_reward;
    let last = logs.last().unwrap().mean_reward;
    println!("\n=== pipeline summary ===");
    println!("pre-train val loss: {:.4}", pre.final_val_loss());
    println!("SFT loss delta:     {:+.4}",
             sft_losses.last().unwrap() - sft_losses[0]);
    println!("ReMax reward:       {first:+.3} -> {last:+.3}");
    Ok(())
}
