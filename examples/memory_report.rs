//! Memory report (paper Table 1 + Fig 1a): exact optimizer-state
//! accounting for the published GPT-2/Llama shape inventories, plus the
//! partition breakdown per tensor class.
//!
//! Run: `cargo run --release --example memory_report`
//! (no artifacts needed — pure arithmetic over shape inventories)

use adam_mini::memmodel::{gib, memory_report, table1_models};
use adam_mini::partition::{Category, Strategy};

fn main() {
    println!("=== Table 1: optimizer-state memory (float32) ===\n");
    println!("{:<12} {:>13} {:>12} {:>14} {:>10} {:>10}", "model",
             "params", "lr scalars", "AdamW (GB)", "mini (GB)", "saved");
    for arch in table1_models() {
        let r = memory_report(&arch);
        println!("{:<12} {:>13} {:>12} {:>14.2} {:>10.2} {:>9.1}%",
                 r.model, r.n_params, r.n_blocks, gib(r.adamw_bytes),
                 gib(r.adam_mini_bytes), r.saving_pct());
    }

    println!("\n=== Partition breakdown: Llama 2-7B ===\n");
    let arch = &table1_models()[2];
    let spec = arch.spec(Strategy::Hessian);
    println!("{:<12} {:>14} {:>10} {:>12}  {}", "tensor", "params",
             "blocks", "block size", "category");
    for b in &spec {
        println!("{:<12} {:>14} {:>10} {:>12}  {}", b.name,
                 b.num_blocks * b.block_size, b.num_blocks, b.block_size,
                 match b.category {
                     Category::TokenRow => "per token row",
                     Category::Head => "per head",
                     Category::OutNeuron => "per output neuron",
                     Category::Whole => "whole tensor",
                 });
    }
    let total: usize = spec.iter().map(|b| b.num_blocks).sum();
    let params: usize =
        spec.iter().map(|b| b.num_blocks * b.block_size).sum();
    println!("\ntotal: {params} params -> {total} learning-rate scalars \
              ({:.4}% of v removed)",
             100.0 * (1.0 - total as f64 / params as f64));
}
