"""L2 model + optimizer-graph contracts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import optim as O
from compile.partition import partition_spec, v_reduction_ratio
from compile.zoo import model_zoo

ZOO = model_zoo()
CFG = ZOO["h1t"]
RNG = np.random.default_rng(1)


def tiny_batch(cfg):
    tok = jnp.asarray(RNG.integers(0, cfg.vocab,
                                   (cfg.batch_size, cfg.seq_len)),
                      jnp.int32)
    tgt = jnp.asarray(RNG.integers(0, cfg.vocab,
                                   (cfg.batch_size, cfg.seq_len)),
                      jnp.int32)
    return tok, tgt


class TestModel:
    def test_param_shapes_cover_all(self):
        for name, cfg in ZOO.items():
            shapes = cfg.param_shapes()
            total = sum(int(np.prod(s)) for s in shapes.values())
            assert total == cfg.n_params, name
            assert "embed" in shapes and "output" in shapes

    def test_forward_shape_and_loss_level(self):
        params = M.init_params(CFG, 0)
        tok, _ = tiny_batch(CFG)
        logits = M.forward(CFG, params, tok)
        assert logits.shape == (CFG.batch_size, CFG.seq_len, CFG.vocab)
        loss = M.loss_fn(CFG, params, tok, tok)
        # At init the model is near-uniform: loss ≈ ln(vocab).
        assert abs(float(loss) - np.log(CFG.vocab)) < 0.5

    def test_pallas_and_ref_paths_agree(self):
        params = M.init_params(CFG, 0)
        tok, tgt = tiny_batch(CFG)
        l_ref = M.loss_fn(CFG, params, tok, tgt, kernels="ref")
        l_pal = M.loss_fn(CFG, params, tok, tgt, kernels="pallas")
        np.testing.assert_allclose(float(l_ref), float(l_pal), rtol=1e-5)

    def test_grads_match_finite_difference(self):
        params = M.init_params(CFG, 0)
        tok, tgt = tiny_batch(CFG)
        loss, grads = M.grad_fn(CFG)(params, tok, tgt)
        eps = 1e-3
        f = lambda p0: M.loss_fn(CFG, [p0] + params[1:], tok, tgt)
        for idx in [(0, 1), (3, 5)]:
            e = np.zeros(params[0].shape, np.float32)
            e[idx] = 1.0
            fd = (f(params[0] + eps * e) - f(params[0] - eps * e)) / (
                2 * eps)
            assert abs(float(fd) - float(grads[0][idx])) < 5e-3

    def test_grads_pallas_match_ref(self):
        params = M.init_params(CFG, 0)
        tok, tgt = tiny_batch(CFG)
        _, g_ref = M.grad_fn(CFG, kernels="ref")(params, tok, tgt)
        _, g_pal = M.grad_fn(CFG, kernels="pallas")(params, tok, tgt)
        for a, b in zip(g_ref, g_pal):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-4, rtol=2e-3)

    def test_causality_of_lm(self):
        # Changing a later input token must not change earlier logits.
        params = M.init_params(CFG, 0)
        tok, _ = tiny_batch(CFG)
        logits1 = M.forward(CFG, params, tok)
        tok2 = tok.at[:, -1].set((tok[:, -1] + 1) % CFG.vocab)
        logits2 = M.forward(CFG, params, tok2)
        np.testing.assert_allclose(np.asarray(logits1[:, :-1]),
                                   np.asarray(logits2[:, :-1]),
                                   atol=1e-5, rtol=1e-5)

    def test_gpt2_family_builds(self):
        cfg = ZOO["gpt2s"]
        params = M.init_params(cfg, 0)
        tok = jnp.zeros((cfg.batch_size, cfg.seq_len), jnp.int32)
        logits = M.forward(cfg, params, tok)
        assert logits.shape[-1] == cfg.vocab


class TestPartition:
    def test_reduction_over_999_permille_at_scale(self):
        cfg = ZOO["m11"]
        spec = partition_spec(cfg.param_shapes(), cfg.n_heads,
                              cfg.stacked_names())
        assert v_reduction_ratio(spec) > 0.99

    def test_block_elements_cover_params(self):
        for name, cfg in ZOO.items():
            for strat in ("hessian", "default", "value_whole"):
                spec = partition_spec(cfg.param_shapes(), cfg.n_heads,
                                      cfg.stacked_names(), strat)
                assert sum(b.n_elements for b in spec) == cfg.n_params, (
                    name, strat)

    def test_head_partition(self):
        from compile.partition import block_view
        bv = block_view("wq", (4, 64, 64), 4, stacked=True)
        assert (bv.num_blocks, bv.block_size) == (16, 1024)
        bv = block_view("wv", (4, 64, 64), 4, stacked=True,
                        strategy="value_whole")
        assert bv.num_blocks == 4


class TestTrainSteps:
    def test_adamw_step_matches_manual(self):
        hp = O.OptHyper()
        step = O.make_train_step_adamw(CFG, hp, kernels="ref")
        params = M.init_params(CFG, 0)
        m, v = O.adamw_init(params)
        tok, tgt = tiny_batch(CFG)
        out = step(tok, tgt, jnp.float32(1e-3), jnp.float32(1.0),
                   *params, *m, *v)
        n = len(params)
        loss, new_p = out[0], out[1:1 + n]
        # Recompute manually: grads then ref update.
        _, grads = M.grad_fn(CFG)(params, tok, tgt)
        for p, g, mi, vi, np_ in zip(params, grads, m, v, new_p):
            want, _, _ = __import__(
                "compile.kernels.ref", fromlist=["x"]
            ).adamw_update_ref(p, g, mi, vi, 1e-3, 1.0,
                               beta1=hp.beta1, beta2=hp.beta2,
                               eps=hp.eps, weight_decay=hp.weight_decay)
            np.testing.assert_allclose(np.asarray(np_), np.asarray(want),
                                       atol=1e-6, rtol=1e-5)
        assert float(loss) > 0

    def test_adam_mini_pallas_matches_ref_step(self):
        hp = O.OptHyper()
        step_p, spec = O.make_train_step_adam_mini(CFG, hp,
                                                   kernels="pallas")
        step_r, _ = O.make_train_step_adam_mini(CFG, hp, kernels="ref")
        params = M.init_params(CFG, 0)
        m, vb = O.adam_mini_init(params, spec)
        tok, tgt = tiny_batch(CFG)
        args = (tok, tgt, jnp.float32(2e-3), jnp.float32(1.0),
                *params, *m, *vb)
        out_p = step_p(*args)
        out_r = step_r(*args)
        for a, b in zip(out_p, out_r):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-4, rtol=2e-3)

    def test_mini_state_is_small(self):
        _, spec = O.make_train_step_adam_mini(CFG, O.OptHyper())
        n_blocks = sum(b.num_blocks for b in spec)
        assert n_blocks < CFG.n_params / 5

    def test_training_reduces_loss(self):
        # 30 jitted fused steps on structured data must cut the loss.
        cfg = CFG
        hp = O.OptHyper(weight_decay=0.0)
        step, spec = O.make_train_step_adam_mini(cfg, hp, kernels="ref")
        jstep = jax.jit(step)
        params = M.init_params(cfg, 0)
        m, vb = O.adam_mini_init(params, spec)
        n = len(params)
        rng = np.random.default_rng(0)
        # Highly-structured data: alternate tokens.
        base = np.tile(np.arange(cfg.vocab, dtype=np.int32),
                       cfg.seq_len)[:cfg.seq_len]
        tok = jnp.asarray(np.tile(base, (cfg.batch_size, 1)))
        tgt = jnp.roll(tok, -1, axis=1)
        first = None
        state = list(params) + list(m) + list(vb)
        for t in range(1, 31):
            out = jstep(tok, tgt, jnp.float32(5e-3), jnp.float32(t),
                        *state)
            loss, state = out[0], list(out[1:])
            if first is None:
                first = float(loss)
        assert float(loss) < 0.5 * first, (first, float(loss))
        del rng

    def test_weighted_grad_zero_weights_zero_grads(self):
        step = O.make_weighted_grad_step(CFG)
        params = M.init_params(CFG, 0)
        tok, tgt = tiny_batch(CFG)
        w = jnp.zeros((CFG.batch_size, CFG.seq_len))
        out = step(tok, tgt, w, *params)
        assert float(out[0]) == 0.0
        for g in out[1:]:
            assert float(jnp.max(jnp.abs(g))) == 0.0

    def test_weighted_grad_uniform_equals_plain(self):
        wstep = O.make_weighted_grad_step(CFG)
        gstep = O.make_grad_step(CFG)
        params = M.init_params(CFG, 0)
        tok, tgt = tiny_batch(CFG)
        w = jnp.ones((CFG.batch_size, CFG.seq_len))
        out_w = wstep(tok, tgt, w, *params)
        out_g = gstep(tok, tgt, *params)
        for a, b in zip(out_w, out_g):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6, rtol=1e-5)
