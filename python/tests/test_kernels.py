"""Pallas kernels vs pure-jnp oracles — the L1 correctness signal.

Hypothesis sweeps shapes/dtypes/hyperparameters; every kernel must match
its oracle in ``compile.kernels.ref`` to float32 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - environment-dependent
    HAVE_HYPOTHESIS = False

from compile.kernels import optim as pk
from compile.kernels import ref as R
from compile.kernels.attention import attention, attention_fwd_kernel
from compile.kernels.cross_entropy import cross_entropy
from compile.kernels.rmsnorm import rmsnorm

RNG = np.random.default_rng(0)


def randf(*shape):
    return jnp.asarray(RNG.standard_normal(shape), jnp.float32)


def assert_close(a, b, atol=2e-5, rtol=2e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol,
                               rtol=rtol)


# ---------------------------------------------------------------------------
# Optimizer kernels
# ---------------------------------------------------------------------------

class TestAdamMiniKernel:
    def test_matches_ref_basic(self):
        p, g, m = randf(12, 20), randf(12, 20), randf(12, 20)
        vb = jnp.abs(randf(12))
        out_k = pk.adam_mini_update(p, g, m, vb, 1e-3, 3.0)
        out_r = R.adam_mini_update_ref(p, g, m, vb, 1e-3, 3.0)
        for a, b in zip(out_k, out_r):
            assert_close(a, b)

    def test_single_block(self):
        p, g, m = randf(1, 64), randf(1, 64), randf(1, 64)
        vb = jnp.zeros(1)
        out_k = pk.adam_mini_update(p, g, m, vb, 1e-2, 1.0)
        out_r = R.adam_mini_update_ref(p, g, m, vb, 1e-2, 1.0)
        for a, b in zip(out_k, out_r):
            assert_close(a, b)

    def test_vb_is_mean_of_gsq_at_t1(self):
        g = randf(4, 8)
        p = jnp.zeros((4, 8))
        m = jnp.zeros((4, 8))
        vb = jnp.zeros(4)
        _, _, vb1 = pk.adam_mini_update(p, g, m, vb, 1e-3, 1.0,
                                        beta2=0.95)
        expect = 0.05 * jnp.mean(g * g, axis=1)
        assert_close(vb1, expect)

    def test_under_jit(self):
        p, g, m = randf(8, 16), randf(8, 16), randf(8, 16)
        vb = jnp.abs(randf(8))
        f = jax.jit(lambda *a: pk.adam_mini_update(*a, 1e-3, 2.0))
        out_k = f(p, g, m, vb)
        out_r = R.adam_mini_update_ref(p, g, m, vb, 1e-3, 2.0)
        for a, b in zip(out_k, out_r):
            assert_close(a, b)

    if HAVE_HYPOTHESIS:
        @settings(max_examples=25, deadline=None)
        @given(nb=st.integers(1, 33), bs=st.integers(1, 65),
               t=st.integers(1, 1000),
               lr=st.floats(1e-5, 1e-1),
               seed=st.integers(0, 2**31))
        def test_shapes_hypothesis(self, nb, bs, t, lr, seed):
            rng = np.random.default_rng(seed)
            p = jnp.asarray(rng.standard_normal((nb, bs)), jnp.float32)
            g = jnp.asarray(rng.standard_normal((nb, bs)), jnp.float32)
            m = jnp.asarray(rng.standard_normal((nb, bs)), jnp.float32)
            vb = jnp.asarray(rng.random(nb), jnp.float32)
            out_k = pk.adam_mini_update(p, g, m, vb, lr, float(t))
            out_r = R.adam_mini_update_ref(p, g, m, vb, lr, float(t))
            for a, b in zip(out_k, out_r):
                assert_close(a, b, atol=1e-4, rtol=1e-4)


class TestAdamWKernel:
    def test_matches_ref(self):
        p, g, m = randf(12, 20), randf(12, 20), randf(12, 20)
        v = jnp.abs(randf(12, 20))
        out_k = pk.adamw_update(p, g, m, v, 1e-3, 5.0)
        out_r = R.adamw_update_ref(p, g, m, v, 1e-3, 5.0)
        for a, b in zip(out_k, out_r):
            assert_close(a, b)

    def test_weight_decay_decoupled(self):
        p = jnp.ones((2, 4))
        z = jnp.zeros((2, 4))
        po, _, _ = pk.adamw_update(p, z, z, z, 0.1, 1.0,
                                   weight_decay=0.5)
        assert_close(po, jnp.full((2, 4), 0.95))

    if HAVE_HYPOTHESIS:
        @settings(max_examples=20, deadline=None)
        @given(nb=st.integers(1, 17), bs=st.integers(1, 50),
               seed=st.integers(0, 2**31))
        def test_shapes_hypothesis(self, nb, bs, seed):
            rng = np.random.default_rng(seed)
            p, g, m = (jnp.asarray(rng.standard_normal((nb, bs)),
                                   jnp.float32) for _ in range(3))
            v = jnp.asarray(rng.random((nb, bs)), jnp.float32)
            out_k = pk.adamw_update(p, g, m, v, 3e-4, 7.0)
            out_r = R.adamw_update_ref(p, g, m, v, 3e-4, 7.0)
            for a, b in zip(out_k, out_r):
                assert_close(a, b, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# Model kernels
# ---------------------------------------------------------------------------

class TestRmsnorm:
    def test_matches_ref(self):
        x, w = randf(4, 6, 16), randf(16)
        assert_close(rmsnorm(x, w), R.rmsnorm_ref(x, w))

    def test_grad_matches_ref(self):
        x, w = randf(3, 8), randf(8)
        f_k = lambda x, w: jnp.sum(jnp.sin(rmsnorm(x, w)))
        f_r = lambda x, w: jnp.sum(jnp.sin(R.rmsnorm_ref(x, w)))
        gx_k, gw_k = jax.grad(f_k, argnums=(0, 1))(x, w)
        gx_r, gw_r = jax.grad(f_r, argnums=(0, 1))(x, w)
        assert_close(gx_k, gx_r, atol=1e-4, rtol=1e-4)
        assert_close(gw_k, gw_r, atol=1e-4, rtol=1e-4)

    def test_scale_equivariance(self):
        # rmsnorm(a*x, w) == rmsnorm(x, w) for a > 0 (eps-small regime).
        x, w = 10 * randf(4, 32), randf(32)
        assert_close(rmsnorm(3.0 * x, w), rmsnorm(x, w), atol=1e-4,
                     rtol=1e-4)

    if HAVE_HYPOTHESIS:
        @settings(max_examples=20, deadline=None)
        @given(n=st.integers(1, 40), d=st.integers(1, 96),
               seed=st.integers(0, 2**31))
        def test_shapes_hypothesis(self, n, d, seed):
            rng = np.random.default_rng(seed)
            x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
            w = jnp.asarray(rng.standard_normal(d), jnp.float32)
            assert_close(rmsnorm(x, w), R.rmsnorm_ref(x, w), atol=1e-4,
                         rtol=1e-4)


class TestAttention:
    def test_matches_ref(self):
        q, k, v = randf(2, 2, 16, 8), randf(2, 2, 16, 8), randf(2, 2, 16, 8)
        assert_close(attention(q, k, v), R.attention_ref(q, k, v),
                     atol=1e-4, rtol=1e-4)

    def test_causality(self):
        # Changing future K/V must not change earlier outputs.
        q, k, v = randf(1, 1, 8, 4), randf(1, 1, 8, 4), randf(1, 1, 8, 4)
        o1 = attention(q, k, v)
        k2 = k.at[:, :, 6:, :].set(99.0)
        v2 = v.at[:, :, 6:, :].set(-99.0)
        o2 = attention(q, k2, v2)
        assert_close(o1[:, :, :6], o2[:, :, :6], atol=1e-5, rtol=1e-5)

    def test_grad_matches_ref(self):
        q, k, v = randf(1, 2, 8, 4), randf(1, 2, 8, 4), randf(1, 2, 8, 4)
        f_k = lambda q, k, v: jnp.sum(attention(q, k, v) ** 2)
        f_r = lambda q, k, v: jnp.sum(R.attention_ref(q, k, v) ** 2)
        gk = jax.grad(f_k, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(f_r, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gk, gr):
            assert_close(a, b, atol=1e-4, rtol=1e-4)

    if HAVE_HYPOTHESIS:
        @settings(max_examples=15, deadline=None)
        @given(bh=st.integers(1, 6), s=st.sampled_from([4, 8, 16, 32]),
               dh=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2**31))
        def test_shapes_hypothesis(self, bh, s, dh, seed):
            rng = np.random.default_rng(seed)
            mk = lambda: jnp.asarray(rng.standard_normal((bh, s, dh)),
                                     jnp.float32)
            q, k, v = mk(), mk(), mk()
            got = attention_fwd_kernel(q, k, v)
            want = R.attention_ref(q[:, None], k[:, None],
                                   v[:, None])[:, 0]
            assert_close(got, want, atol=1e-4, rtol=1e-4)


class TestCrossEntropy:
    def test_matches_ref(self):
        logits = randf(8, 32)
        tgt = jnp.asarray(RNG.integers(0, 32, 8), jnp.int32)
        assert_close(cross_entropy(logits, tgt),
                     R.cross_entropy_ref(logits, tgt))

    def test_uniform_logits_give_log_v(self):
        logits = jnp.zeros((4, 100))
        tgt = jnp.asarray([0, 1, 50, 99], jnp.int32)
        assert_close(cross_entropy(logits, tgt),
                     jnp.full(4, np.log(100.0)), atol=1e-5, rtol=1e-5)

    def test_grad_is_softmax_minus_onehot(self):
        logits = randf(4, 16)
        tgt = jnp.asarray([3, 1, 0, 15], jnp.int32)
        g = jax.grad(lambda l: jnp.sum(cross_entropy(l, tgt)))(logits)
        want = jax.nn.softmax(logits, -1) - jax.nn.one_hot(tgt, 16)
        assert_close(g, want, atol=1e-5, rtol=1e-5)

    def test_numerical_stability_large_logits(self):
        logits = 1e4 * randf(4, 16)
        tgt = jnp.asarray([0, 5, 9, 2], jnp.int32)
        out = cross_entropy(logits, tgt)
        assert np.isfinite(np.asarray(out)).all()

    if HAVE_HYPOTHESIS:
        @settings(max_examples=20, deadline=None)
        @given(n=st.integers(1, 30), v=st.integers(2, 80),
               seed=st.integers(0, 2**31))
        def test_shapes_hypothesis(self, n, v, seed):
            rng = np.random.default_rng(seed)
            logits = jnp.asarray(rng.standard_normal((n, v)), jnp.float32)
            tgt = jnp.asarray(rng.integers(0, v, n), jnp.int32)
            assert_close(cross_entropy(logits, tgt),
                         R.cross_entropy_ref(logits, tgt), atol=1e-4,
                         rtol=1e-4)
