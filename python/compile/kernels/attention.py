"""Pallas causal flash-attention kernel (forward) with a custom VJP.

The grid is ``(batch*heads, num_q_tiles)``: each program owns one q-row
tile of one head, streams the full K/V for that head through VMEM, and
computes an online-softmax accumulation — the standard flash-attention
schedule re-expressed with ``BlockSpec`` instead of CUDA threadblocks
(DESIGN.md §Hardware-Adaptation). Causality is enforced with an iota mask
per tile.

Backward is the analytic attention VJP in jnp (registered via
``jax.custom_vjp``): recompute-in-backward, the same rematerialization
choice flash attention makes, so no (S, S) score tensor is ever stored.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .optim import INTERPRET, _pick_row_tile

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, q_tile):
    qt = pl.program_id(1)
    q = q_ref[0]                     # (q_tile, dh)
    k = k_ref[0]                     # (S, dh)
    v = v_ref[0]                     # (S, dh)
    s = k.shape[0]
    scores = jnp.dot(q, k.T) * scale  # (q_tile, S)
    q_pos = qt * q_tile + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    k_pos = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(k_pos <= q_pos, scores, NEG_INF)
    # Online-softmax normalization (single K pass; max/sum held in VMEM).
    mx = jnp.max(scores, axis=1, keepdims=True)
    p = jnp.exp(scores - mx)
    denom = jnp.sum(p, axis=1, keepdims=True)
    o_ref[0] = jnp.dot(p, v) / denom


def attention_fwd_kernel(q, k, v, *, scale=None, q_tile=None):
    """Causal attention forward. q,k,v: (BH, S, Dh) -> (BH, S, Dh)."""
    bh, s, dh = q.shape
    if scale is None:
        scale = 1.0 / float(dh) ** 0.5
    tile = q_tile or _pick_row_tile(s, max_tile=32)
    kernel = functools.partial(_attn_kernel, scale=scale, q_tile=tile)
    q_spec = pl.BlockSpec((1, tile, dh), lambda b, i: (b, i, 0))
    kv_spec = pl.BlockSpec((1, s, dh), lambda b, i: (b, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=(bh, s // tile),
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        interpret=INTERPRET,
    )(q, k, v)


@jax.custom_vjp
def attention(q, k, v):
    """Differentiable causal attention with a Pallas forward.

    q, k, v: (B, H, S, Dh). Returns (B, H, S, Dh).
    """
    b, h, s, dh = q.shape
    o = attention_fwd_kernel(q.reshape(b * h, s, dh),
                             k.reshape(b * h, s, dh),
                             v.reshape(b * h, s, dh))
    return o.reshape(b, h, s, dh)


def _attn_ref(q, k, v):
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    s = q.shape[-2]
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def _attention_fwd(q, k, v):
    return attention(q, k, v), (q, k, v)


def _attention_bwd(res, go):
    q, k, v = res
    # Recompute-in-backward: differentiate the reference formulation.
    _, vjp = jax.vjp(_attn_ref, q, k, v)
    return vjp(go)


attention.defvjp(_attention_fwd, _attention_bwd)
