"""Pallas fused cross-entropy kernel with a custom VJP.

Each program owns a tile of token rows and computes max/exp/sum/log plus
the target-logit gather in one VMEM pass — the (N, V) logits are read from
HBM exactly once and no (N, V) probability tensor is materialized on the
forward path. Backward is the analytic softmax-minus-onehot VJP in jnp.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .optim import INTERPRET, _pick_row_tile


def _ce_kernel(logits_ref, tgt_ref, loss_ref):
    logits = logits_ref[...]                    # (tile, V)
    tgt = tgt_ref[...]                          # (tile, 1) int32
    mx = jnp.max(logits, axis=1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(logits - mx), axis=1, keepdims=True)) + mx
    vocab_ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    tgt_logit = jnp.sum(jnp.where(vocab_ids == tgt, logits, 0.0),
                        axis=1, keepdims=True)
    loss_ref[...] = lse - tgt_logit


def cross_entropy_fwd_kernel(logits, targets, *, row_tile=None):
    """Per-row CE loss. logits: (N, V), targets: (N,) int32 -> (N,)."""
    n, v = logits.shape
    tile = row_tile or _pick_row_tile(n, max_tile=32)
    loss = pl.pallas_call(
        _ce_kernel,
        grid=(n // tile,),
        in_specs=[pl.BlockSpec((tile, v), lambda i: (i, 0)),
                  pl.BlockSpec((tile, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), logits.dtype),
        interpret=INTERPRET,
    )(logits, targets.reshape(n, 1).astype(jnp.int32))
    return loss.reshape(n)


@jax.custom_vjp
def cross_entropy(logits, targets):
    """Differentiable (w.r.t. logits) per-row cross-entropy via Pallas."""
    return cross_entropy_fwd_kernel(logits, targets)


def _ce_fwd(logits, targets):
    return cross_entropy(logits, targets), (logits, targets)


def _ce_bwd(res, gloss):
    logits, targets = res
    probs = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    return ((probs - onehot) * gloss[:, None], None)


cross_entropy.defvjp(_ce_fwd, _ce_bwd)
