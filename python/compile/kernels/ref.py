"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the CORE correctness signal: each Pallas kernel in
``python/compile/kernels/`` must match its oracle here to tight tolerance
(pytest + hypothesis sweeps in ``python/tests/``).

All optimizer math follows the paper's Algorithms 1/2 (Adam-mini) and
Algorithm 6 (AdamW), with decoupled weight decay.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Optimizer updates
# ---------------------------------------------------------------------------

def adamw_update_ref(p, g, m, v, lr, t, *, beta1=0.9, beta2=0.95,
                     eps=1e-8, weight_decay=0.1):
    """AdamW (paper Algorithm 6), one step. ``t`` is 1-based step count.

    Returns (p_new, m_new, v_new).
    """
    t = jnp.asarray(t, jnp.float32)
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    bc1 = 1.0 / (1.0 - beta1 ** t)
    bc2 = 1.0 / (1.0 - beta2 ** t)
    p_new = p * (1.0 - lr * weight_decay)
    p_new = p_new - lr * (m_new * bc1) / (jnp.sqrt(v_new * bc2) + eps)
    return p_new, m_new, v_new


def adam_mini_update_ref(p, g, m, vb, lr, t, *, beta1=0.9, beta2=0.95,
                         eps=1e-8, weight_decay=0.1):
    """Adam-mini (paper Algorithm 1), one step over a 2-D block view.

    ``p, g, m``: (num_blocks, block_size) — each row is one Hessian block.
    ``vb``:      (num_blocks,) — one second-moment scalar per block.

    v_b <- beta2 * v_b + (1-beta2) * mean(g_b ** 2); update uses
    lr * m_hat / (sqrt(v_hat_b) + eps) broadcast across the block row.
    Returns (p_new, m_new, vb_new).
    """
    t = jnp.asarray(t, jnp.float32)
    m_new = beta1 * m + (1.0 - beta1) * g
    vb_new = beta2 * vb + (1.0 - beta2) * jnp.mean(g * g, axis=-1)
    bc1 = 1.0 / (1.0 - beta1 ** t)
    bc2 = 1.0 / (1.0 - beta2 ** t)
    denom = jnp.sqrt(vb_new * bc2)[:, None] + eps
    p_new = p * (1.0 - lr * weight_decay)
    p_new = p_new - lr * (m_new * bc1) / denom
    return p_new, m_new, vb_new


# ---------------------------------------------------------------------------
# Model kernels
# ---------------------------------------------------------------------------

def rmsnorm_ref(x, w, *, eps=1e-5):
    """RMSNorm over the last axis. x: (..., d), w: (d,)."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * w


def attention_ref(q, k, v, *, causal=True, scale=None):
    """Multi-head scaled-dot-product attention.

    q, k, v: (B, H, S, Dh). Returns (B, H, S, Dh).
    """
    dh = q.shape[-1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        s = q.shape[-2]
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def cross_entropy_ref(logits, targets):
    """Per-row token cross-entropy. logits: (N, V), targets: (N,) int32.

    Returns per-row loss (N,).
    """
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[:, None].astype(jnp.int32),
                              axis=-1)[:, 0]
    return lse - tgt


def softmax_ref(x):
    """Numerically-stable softmax over last axis (kernel-test helper)."""
    return jax.nn.softmax(x, axis=-1)
