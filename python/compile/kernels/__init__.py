"""L1: Pallas kernels for the paper's compute hot-spots.

Every kernel has a pure-jnp oracle in :mod:`.ref` and is validated against
it by ``python/tests/`` (pytest + hypothesis). All kernels run under
``interpret=True`` on this CPU-PJRT testbed — see DESIGN.md
§Hardware-Adaptation for the TPU mapping.
"""

from . import ref  # noqa: F401
from .attention import attention, attention_fwd_kernel  # noqa: F401
from .cross_entropy import cross_entropy, cross_entropy_fwd_kernel  # noqa: F401
from .optim import adam_mini_update, adamw_update  # noqa: F401
from .rmsnorm import rmsnorm, rmsnorm_fwd_kernel  # noqa: F401
