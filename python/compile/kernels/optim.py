"""Pallas kernels for the optimizer hot path (the paper's contribution).

Two fused update kernels, both operating on a 2-D *block view* of a
parameter tensor (see ``compile.partition``: every tensor is reshaped to
``(num_blocks, block_size)`` so that each row is exactly one dense Hessian
sub-block of paper Principle 1):

- ``adam_mini_update``: fused blockwise second-moment EMA + bias-corrected
  update. One pass over HBM: reads (p, g, m) + one scalar per row, computes
  the per-row ``mean(g*g)`` reduction in VMEM, and writes (p, m) plus the
  tiny per-row ``v_b``. This removes the full-size ``v`` stream entirely —
  the memory-traffic saving the paper's throughput numbers come from.
- ``adamw_update``: the coordinate-wise baseline (paper Algorithm 6) as an
  equally-fused kernel, for a like-for-like hot-path comparison.

TPU mapping (DESIGN.md §Hardware-Adaptation): the row tile is the unit of
VMEM residency; ``BlockSpec`` expresses the HBM→VMEM schedule that the
paper's CUDA implementation expressed with threadblocks. On this CPU
testbed all kernels run under ``interpret=True`` (Mosaic custom-calls are
TPU-only).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU-PJRT testbed; see module docstring.


def _pick_row_tile(n_rows: int, max_tile: int = 64) -> int:
    """Largest divisor of ``n_rows`` that is <= max_tile (VMEM budget)."""
    tile = 1
    for cand in range(1, min(n_rows, max_tile) + 1):
        if n_rows % cand == 0:
            tile = cand
    return tile


def _bias_corrections(t, beta1: float, beta2: float):
    """1/(1-beta^t) factors, computed in the surrounding jax graph."""
    t = jnp.asarray(t, jnp.float32)
    bc1 = 1.0 / (1.0 - beta1 ** t)
    bc2 = 1.0 / (1.0 - beta2 ** t)
    return bc1.reshape(1, 1), bc2.reshape(1, 1)


# ---------------------------------------------------------------------------
# Adam-mini fused blockwise kernel
# ---------------------------------------------------------------------------

def _adam_mini_kernel(p_ref, g_ref, m_ref, vb_ref, lr_ref, bc1_ref, bc2_ref,
                      po_ref, mo_ref, vbo_ref, *, beta1, beta2, eps,
                      weight_decay):
    g = g_ref[...]
    lr = lr_ref[0, 0]
    # First-moment EMA (same as Adam).
    m = beta1 * m_ref[...] + (1.0 - beta1) * g
    # Blockwise second moment: ONE scalar per row (paper Algorithm 1 line 8).
    gsq_mean = jnp.mean(g * g, axis=1, keepdims=True)
    vb = beta2 * vb_ref[...] + (1.0 - beta2) * gsq_mean
    # Bias-corrected update, v_b broadcast across its block row.
    mhat = m * bc1_ref[0, 0]
    denom = jnp.sqrt(vb * bc2_ref[0, 0]) + eps
    p = p_ref[...] * (1.0 - lr * weight_decay)
    po_ref[...] = p - lr * mhat / denom
    mo_ref[...] = m
    vbo_ref[...] = vb


def adam_mini_update(p2, g2, m2, vb, lr, t, *, beta1=0.9, beta2=0.95,
                     eps=1e-8, weight_decay=0.1, row_tile=None):
    """Fused Adam-mini step on a (num_blocks, block_size) view.

    Args:
      p2, g2, m2: (B, N) parameter / gradient / first-moment block views.
      vb: (B,) per-block second moments.
      lr: scalar learning rate (schedule lives in the Rust coordinator).
      t:  scalar 1-based step for bias correction.
    Returns (p2_new, m2_new, vb_new) with the same shapes.
    """
    nb, bs = p2.shape
    tile = row_tile or _pick_row_tile(nb)
    vb2 = vb.reshape(nb, 1)
    lr2 = jnp.asarray(lr, jnp.float32).reshape(1, 1)
    bc1, bc2 = _bias_corrections(t, beta1, beta2)

    row_spec = pl.BlockSpec((tile, bs), lambda i: (i, 0))
    col_spec = pl.BlockSpec((tile, 1), lambda i: (i, 0))
    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))

    kernel = functools.partial(_adam_mini_kernel, beta1=beta1, beta2=beta2,
                               eps=eps, weight_decay=weight_decay)
    po, mo, vbo = pl.pallas_call(
        kernel,
        grid=(nb // tile,),
        in_specs=[row_spec, row_spec, row_spec, col_spec,
                  scalar_spec, scalar_spec, scalar_spec],
        out_specs=[row_spec, row_spec, col_spec],
        out_shape=[
            jax.ShapeDtypeStruct((nb, bs), p2.dtype),
            jax.ShapeDtypeStruct((nb, bs), m2.dtype),
            jax.ShapeDtypeStruct((nb, 1), vb.dtype),
        ],
        interpret=INTERPRET,
    )(p2, g2, m2, vb2, lr2, bc1, bc2)
    return po, mo, vbo.reshape(nb)


# ---------------------------------------------------------------------------
# AdamW fused coordinate-wise kernel (baseline hot path)
# ---------------------------------------------------------------------------

def _adamw_kernel(p_ref, g_ref, m_ref, v_ref, lr_ref, bc1_ref, bc2_ref,
                  po_ref, mo_ref, vo_ref, *, beta1, beta2, eps,
                  weight_decay):
    g = g_ref[...]
    lr = lr_ref[0, 0]
    m = beta1 * m_ref[...] + (1.0 - beta1) * g
    v = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    mhat = m * bc1_ref[0, 0]
    denom = jnp.sqrt(v * bc2_ref[0, 0]) + eps
    p = p_ref[...] * (1.0 - lr * weight_decay)
    po_ref[...] = p - lr * mhat / denom
    mo_ref[...] = m
    vo_ref[...] = v


def adamw_update(p2, g2, m2, v2, lr, t, *, beta1=0.9, beta2=0.95,
                 eps=1e-8, weight_decay=0.1, row_tile=None):
    """Fused AdamW step on a (B, N) view; v2 is full-size (B, N).

    Returns (p2_new, m2_new, v2_new).
    """
    nb, bs = p2.shape
    tile = row_tile or _pick_row_tile(nb)
    lr2 = jnp.asarray(lr, jnp.float32).reshape(1, 1)
    bc1, bc2 = _bias_corrections(t, beta1, beta2)

    row_spec = pl.BlockSpec((tile, bs), lambda i: (i, 0))
    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))

    kernel = functools.partial(_adamw_kernel, beta1=beta1, beta2=beta2,
                               eps=eps, weight_decay=weight_decay)
    po, mo, vo = pl.pallas_call(
        kernel,
        grid=(nb // tile,),
        in_specs=[row_spec] * 4 + [scalar_spec] * 3,
        out_specs=[row_spec, row_spec, row_spec],
        out_shape=[
            jax.ShapeDtypeStruct((nb, bs), p2.dtype),
            jax.ShapeDtypeStruct((nb, bs), m2.dtype),
            jax.ShapeDtypeStruct((nb, bs), v2.dtype),
        ],
        interpret=INTERPRET,
    )(p2, g2, m2, v2, lr2, bc1, bc2)
    return po, mo, vo
