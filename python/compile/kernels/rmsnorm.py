"""Pallas RMSNorm kernel with a custom VJP.

Forward is a row-tiled fused kernel (one VMEM pass: square, mean, rsqrt,
scale). Backward is an analytic jnp expression registered via
``jax.custom_vjp`` so the kernel is usable inside differentiated train-step
graphs (Pallas kernels are not transparently differentiable).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .optim import INTERPRET, _pick_row_tile

EPS = 1e-5


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...]
    ms = jnp.mean(x * x, axis=1, keepdims=True)
    o_ref[...] = x * jax.lax.rsqrt(ms + eps) * w_ref[...]


def rmsnorm_fwd_kernel(x2, w, *, eps=EPS, row_tile=None):
    """RMSNorm over rows of x2: (N, d), w: (d,). Returns (N, d)."""
    n, d = x2.shape
    tile = row_tile or _pick_row_tile(n)
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(n // tile,),
        in_specs=[pl.BlockSpec((tile, d), lambda i: (i, 0)),
                  pl.BlockSpec((1, d), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((tile, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x2.dtype),
        interpret=INTERPRET,
    )(x2, w.reshape(1, d))


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm(x, w, eps=EPS):
    """Differentiable RMSNorm with a Pallas forward. x: (..., d), w: (d,)."""
    shp = x.shape
    y = rmsnorm_fwd_kernel(x.reshape(-1, shp[-1]), w, eps=eps)
    return y.reshape(shp)


def _rmsnorm_fwd(x, w, eps):
    return rmsnorm(x, w, eps), (x, w)


def _rmsnorm_bwd(eps, res, gy):
    x, w = res
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    r = jax.lax.rsqrt(ms + eps)
    xhat = x * r
    gxhat = gy * w
    # d/dx [x * rsqrt(mean(x^2)+eps)] = r*(g - xhat*mean(g*xhat)), xhat = x*r
    gx = r * (gxhat - xhat * jnp.mean(gxhat * xhat, axis=-1, keepdims=True))
    gw = jnp.sum(gy * xhat, axis=tuple(range(x.ndim - 1)))
    return gx, gw


rmsnorm.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)
