"""Model zoo: the named configurations every experiment runs on.

Scaled-down analogues of the paper's workloads (DESIGN.md §4 records the
substitutions). The Llama ladder mirrors paper Table 8's geometry
(d_model/n_layers/n_heads growth at fixed seq) for the scaling-law
experiments (Fig 11 / Table 4); the GPT-2 family mirrors the
nanoGPT-style runs of Fig 8; ``h1t`` is the exact 1-layer transformer of
Fig 7 (n_emb 16, 4 heads, mlp width 32, vocab 8); ``m11`` is the
multi-million-parameter end-to-end driver model.
"""

from __future__ import annotations

from typing import Dict

from .model import ModelConfig

# Llama-style scaling ladder (RoPE + RMSNorm + SwiGLU), vocab 256, seq 64.
_LADDER = [
    # name,   d,  L, H, ff
    ("t48k", 32, 2, 2, 128),
    ("t134k", 48, 3, 4, 192),
    ("t295k", 64, 4, 4, 256),
    ("t786k", 96, 5, 6, 384),
    ("t1m6", 128, 6, 8, 512),
]

# GPT-2-style family (learned positions + GELU MLP), vocab 256, seq 64.
_GPT2 = [
    ("gpt2s", 64, 4, 4, 256),
    ("gpt2m", 96, 6, 6, 384),
    ("gpt2l", 128, 8, 8, 512),
]


def model_zoo() -> Dict[str, ModelConfig]:
    zoo: Dict[str, ModelConfig] = {}
    for name, d, l, h, ff in _LADDER:
        zoo[name] = ModelConfig(name=name, family="llama", vocab=256,
                                d_model=d, n_layers=l, n_heads=h, d_ff=ff,
                                seq_len=64, batch_size=16)
    for name, d, l, h, ff in _GPT2:
        zoo[name] = ModelConfig(name=name, family="gpt2", vocab=256,
                                d_model=d, n_layers=l, n_heads=h, d_ff=ff,
                                seq_len=64, batch_size=16)
    # Fig 7 / Table 3 Hessian-analysis transformer (paper Appendix F.2).
    zoo["h1t"] = ModelConfig(name="h1t", family="llama", vocab=8,
                             d_model=16, n_layers=1, n_heads=4, d_ff=32,
                             seq_len=8, batch_size=8)
    # End-to-end driver: multi-M-param pre-train (examples/pretrain_e2e).
    zoo["m11"] = ModelConfig(name="m11", family="llama", vocab=512,
                             d_model=256, n_layers=10, n_heads=8, d_ff=1024,
                             seq_len=128, batch_size=4)
    return zoo
