"""L2: optimizer update graphs (AdamW / Adam-mini) and fused train steps.

These compose the model gradients with the L1 Pallas update kernels (or
their jnp oracles) into a single jitted ``train_step`` that the Rust
coordinator executes per step. The learning-rate *schedule* lives in Rust;
the graph takes the current scalar ``lr`` and 1-based step ``t`` as inputs.

State layout (the artifact ABI, recorded in the manifest):

- AdamW:     m_i, v_i mirror every parameter tensor.
- Adam-mini: m_i mirrors parameters; v is a list of tiny per-tensor
  vectors of shape ``(num_blocks_i,)`` from :mod:`compile.partition` —
  the >=99.9% reduction of Table 1.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

import jax
import jax.numpy as jnp

from . import model as M
from .kernels import optim as pk
from .kernels import ref as R
from .partition import BlockView, partition_spec


@dataclasses.dataclass(frozen=True)
class OptHyper:
    """Optimizer hyperparameters baked into the artifact as constants.

    Paper defaults for LLM pre-training: beta1=0.9, beta2=0.95, eps=1e-8,
    weight_decay=0.1. Adam-mini deliberately reuses AdamW's values
    (paper §3.4: "the same hyperparameters as AdamW").
    """

    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1


def adamw_init(params: Sequence[jax.Array]):
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    return m, v


def adam_mini_init(params: Sequence[jax.Array], spec: Sequence[BlockView]):
    m = [jnp.zeros_like(p) for p in params]
    vb = [jnp.zeros((b.num_blocks,), jnp.float32) for b in spec]
    return m, vb


def adamw_step(params, grads, m, v, lr, t, hp: OptHyper,
               use_pallas: bool = True):
    """One AdamW update over the whole parameter list."""
    new_p, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        shp = p.shape
        if use_pallas:
            n = p.size
            # 2-D view for the tiled kernel; elementwise so any view works.
            rows = _best_rows(n)
            p2, g2 = p.reshape(rows, n // rows), g.reshape(rows, n // rows)
            m2, v2 = mi.reshape(rows, n // rows), vi.reshape(rows, n // rows)
            po, mo, vo = pk.adamw_update(
                p2, g2, m2, v2, lr, t, beta1=hp.beta1, beta2=hp.beta2,
                eps=hp.eps, weight_decay=hp.weight_decay)
        else:
            po, mo, vo = R.adamw_update_ref(
                p, g, mi, vi, lr, t, beta1=hp.beta1, beta2=hp.beta2,
                eps=hp.eps, weight_decay=hp.weight_decay)
        new_p.append(po.reshape(shp))
        new_m.append(mo.reshape(shp))
        new_v.append(vo.reshape(shp))
    return new_p, new_m, new_v


def adam_mini_step(params, grads, m, vb, lr, t, spec: Sequence[BlockView],
                   hp: OptHyper, use_pallas: bool = True):
    """One Adam-mini update; each tensor reshaped to its block view."""
    new_p, new_m, new_vb = [], [], []
    for p, g, mi, vbi, bv in zip(params, grads, m, vb, spec):
        shp = p.shape
        p2 = p.reshape(bv.num_blocks, bv.block_size)
        g2 = g.reshape(bv.num_blocks, bv.block_size)
        m2 = mi.reshape(bv.num_blocks, bv.block_size)
        if use_pallas:
            po, mo, vbo = pk.adam_mini_update(
                p2, g2, m2, vbi, lr, t, beta1=hp.beta1, beta2=hp.beta2,
                eps=hp.eps, weight_decay=hp.weight_decay)
        else:
            po, mo, vbo = R.adam_mini_update_ref(
                p2, g2, m2, vbi, lr, t, beta1=hp.beta1, beta2=hp.beta2,
                eps=hp.eps, weight_decay=hp.weight_decay)
        new_p.append(po.reshape(shp))
        new_m.append(mo.reshape(shp))
        new_vb.append(vbo)
    return new_p, new_m, new_vb


def _best_rows(n: int, max_tile: int = 4096) -> int:
    """Factor n into (rows, cols) with cols <= max_tile for kernel tiling."""
    rows = 1
    while n // rows > max_tile and n % (rows * 2) == 0:
        rows *= 2
    return rows


# ---------------------------------------------------------------------------
# Fused train steps (the exported artifacts)
# ---------------------------------------------------------------------------

def make_train_step_adamw(cfg: M.ModelConfig, hp: OptHyper,
                          kernels: str = "ref"):
    """f(tokens, targets, lr, t, *params, *m, *v) -> (loss, params, m, v)."""
    vg = M.grad_fn(cfg, kernels=kernels)
    n = len(cfg.param_shapes())
    use_pallas = kernels == "pallas"

    def step(tokens, targets, lr, t, *state):
        params = list(state[:n])
        m = list(state[n:2 * n])
        v = list(state[2 * n:3 * n])
        loss, grads = vg(params, tokens, targets)
        new_p, new_m, new_v = adamw_step(params, grads, m, v, lr, t, hp,
                                         use_pallas=use_pallas)
        return tuple([loss] + new_p + new_m + new_v)

    return step


def make_train_step_adam_mini(cfg: M.ModelConfig, hp: OptHyper,
                              strategy: str = "hessian",
                              kernels: str = "ref"):
    """Same ABI as AdamW step, but v entries are (num_blocks_i,) vectors."""
    vg = M.grad_fn(cfg, kernels=kernels)
    spec = partition_spec(cfg.param_shapes(), cfg.n_heads,
                          cfg.stacked_names(), strategy=strategy)
    n = len(cfg.param_shapes())
    use_pallas = kernels == "pallas"

    def step(tokens, targets, lr, t, *state):
        params = list(state[:n])
        m = list(state[n:2 * n])
        vb = list(state[2 * n:3 * n])
        loss, grads = vg(params, tokens, targets)
        new_p, new_m, new_vb = adam_mini_step(
            params, grads, m, vb, lr, t, spec, hp, use_pallas=use_pallas)
        return tuple([loss] + new_p + new_m + new_vb)

    return step, spec


def make_grad_step(cfg: M.ModelConfig, kernels: str = "ref"):
    """f(tokens, targets, *params) -> (loss, *grads).

    Consumed by Rust-side optimizers (Adafactor/CAME/SM3/Lion/LAMB/
    blockwise-GD and all grid-search experiments) so one artifact serves
    every optimizer variant.
    """
    vg = M.grad_fn(cfg, kernels=kernels)

    def step(tokens, targets, *params):
        loss, grads = vg(list(params), tokens, targets)
        return tuple([loss] + list(grads))

    return step


def make_weighted_grad_step(cfg: M.ModelConfig, kernels: str = "ref"):
    """f(tokens, targets, weights, *params) -> (loss, *grads).

    loss = mean over (B, S) of weights ⊙ per-token CE. Used by the Rust
    coordinator for SFT prompt masking and for ReMax/REINFORCE advantage
    weighting (weights[b, s] = advantage_b on response tokens, 0 on the
    prompt).
    """
    def wloss(params, tokens, targets, weights):
        logits = M.forward(cfg, list(params), tokens, kernels=kernels)
        flat = logits.reshape(-1, cfg.vocab)
        tgt = targets.reshape(-1)
        from .kernels import ref as KR
        losses = KR.cross_entropy_ref(flat, tgt)
        return jnp.mean(losses * weights.reshape(-1))

    vg = jax.value_and_grad(wloss)

    def step(tokens, targets, weights, *params):
        loss, grads = vg(list(params), tokens, targets, weights)
        return tuple([loss] + list(grads))

    return step


def make_logits_step(cfg: M.ModelConfig, kernels: str = "ref"):
    """f(tokens, *params) -> (logits,) — used for sampling (RLHF
    rollouts) and analysis from the Rust side."""
    def step(tokens, *params):
        return (M.forward(cfg, list(params), tokens, kernels=kernels),)
    return step


LORA_TARGETS = ("wq", "wk", "wv", "wo")


def make_lora_grad_step(cfg: M.ModelConfig, rank: int = 4,
                        kernels: str = "ref"):
    """f(tokens, targets, *base, *A, *B) -> (loss, *gA, *gB).

    LoRA (Hu et al. 2021) on the attention matrices: effective weight
    W' = W + (2/r)·B·A with A: (L, r, d), B: (L, d, r). Gradients flow
    to the adapters only (base frozen) — the paper's Fig 22 / Table 5
    SFT-LoRA setting, where the Adam steps on the adapters are replaced
    by Adam-mini.
    """
    names = list(cfg.param_shapes().keys())
    scale = 2.0 / rank

    def loss(adapters, base, tokens, targets):
        a_list, b_list = adapters
        eff = list(base)
        for t, a, bmat in zip(LORA_TARGETS, a_list, b_list):
            i = names.index(t)
            # (L, d, r) @ (L, r, d) -> (L, d, d)
            delta = jnp.einsum("ldr,lre->lde", bmat, a)
            eff[i] = eff[i] + scale * delta.reshape(eff[i].shape)
        return M.loss_fn(cfg, eff, tokens, targets, kernels=kernels)

    vg = jax.value_and_grad(loss)
    k = len(LORA_TARGETS)

    def step(tokens, targets, *args):
        base = list(args[: len(names)])
        a_list = list(args[len(names): len(names) + k])
        b_list = list(args[len(names) + k:])
        val, (ga, gb) = vg((a_list, b_list), base, tokens, targets)
        return tuple([val] + list(ga) + list(gb))

    return step


def lora_shapes(cfg: M.ModelConfig, rank: int = 4):
    """(A shapes, B shapes) for the LoRA adapters."""
    l, d = cfg.n_layers, cfg.d_model
    return ([(l, rank, d)] * len(LORA_TARGETS),
            [(l, d, rank)] * len(LORA_TARGETS))


def make_eval_step(cfg: M.ModelConfig, kernels: str = "ref"):
    """f(tokens, targets, *params) -> (loss,)."""
    def step(tokens, targets, *params):
        return (M.loss_fn(cfg, list(params), tokens, targets,
                          kernels=kernels),)
    return step
