"""AOT exporter: lower every (model, graph) pair to HLO text + manifest.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly. See
/opt/xla-example/gen_hlo.py.

Run once via ``make artifacts``; the Rust binary is self-contained after.

Usage: python -m compile.aot --out-dir ../artifacts [--models a,b,...]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import optim as O
from .partition import STRATEGIES, partition_spec, v_reduction_ratio
from .zoo import model_zoo

HP = O.OptHyper()

# Which graphs to export per model. The `grad` artifact is the universal
# substrate (all Rust-side optimizers consume it); fused train steps are
# exported where the experiments A/B them (see DESIGN.md §5/§6).
_FULL_TRAIN_MODELS = ("t295k", "m11")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(tuple(shape),
                                jnp.int32 if dtype == "i32" else jnp.float32)


def _io_entry(name, shape, dtype="f32", role="param"):
    return {"name": name, "shape": list(shape), "dtype": dtype, "role": role}


def _param_entries(cfg: M.ModelConfig, role: str):
    return [_io_entry(n, s, role=role)
            for n, s in cfg.param_shapes().items()]


def _state_entries(cfg: M.ModelConfig, optimizer: str, strategy: str):
    """m then v entries for the train-step ABI."""
    entries = [_io_entry("m." + n, s, role="m")
               for n, s in cfg.param_shapes().items()]
    if optimizer == "adamw":
        entries += [_io_entry("v." + n, s, role="v")
                    for n, s in cfg.param_shapes().items()]
    else:
        spec = partition_spec(cfg.param_shapes(), cfg.n_heads,
                              cfg.stacked_names(), strategy=strategy)
        entries += [_io_entry("v." + b.name, (b.num_blocks,), role="v")
                    for b in spec]
    return entries


class Exporter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest: Dict = {
            "version": 1,
            "hyper": {"beta1": HP.beta1, "beta2": HP.beta2, "eps": HP.eps,
                      "weight_decay": HP.weight_decay},
            "models": {},
        }
        os.makedirs(out_dir, exist_ok=True)

    def _write_hlo(self, name: str, lowered) -> str:
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        print(f"  wrote {fname} ({len(text) // 1024} KiB)", flush=True)
        return fname

    def model_entry(self, cfg: M.ModelConfig) -> Dict:
        shapes = cfg.param_shapes()
        params = []
        for name, shape in shapes.items():
            entry = {"name": name, "shape": list(shape)}
            for strat in STRATEGIES:
                from .partition import block_view
                bv = block_view(name, shape, cfg.n_heads,
                                stacked=name in cfg.stacked_names(),
                                strategy=strat)
                entry[strat] = [bv.num_blocks, bv.block_size]
                if strat == "hessian":
                    entry["category"] = bv.category
            params.append(entry)
        spec = partition_spec(shapes, cfg.n_heads, cfg.stacked_names())
        return {
            "family": cfg.family, "vocab": cfg.vocab,
            "d_model": cfg.d_model, "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads, "d_ff": cfg.d_ff,
            "seq_len": cfg.seq_len, "batch_size": cfg.batch_size,
            "n_params": cfg.n_params,
            "v_reduction": v_reduction_ratio(spec),
            "params": params,
            "artifacts": {},
        }

    def export_model(self, cfg: M.ModelConfig):
        t0 = time.time()
        print(f"[aot] {cfg.name}: {cfg.n_params} params", flush=True)
        entry = self.model_entry(cfg)
        shapes = cfg.param_shapes()
        b, s = cfg.batch_size, cfg.seq_len
        tok = _spec((b, s), "i32")
        tgt = _spec((b, s), "i32")
        scal = _spec((), "f32")
        pspecs = [_spec(sh) for sh in shapes.values()]
        batch_io = [_io_entry("tokens", (b, s), "i32", "batch"),
                    _io_entry("targets", (b, s), "i32", "batch")]
        scal_io = [_io_entry("lr", (), "f32", "scalar"),
                   _io_entry("t", (), "f32", "scalar")]

        # --- grad: the universal substrate -------------------------------
        g = O.make_grad_step(cfg, kernels="ref")
        lowered = jax.jit(g).lower(tok, tgt, *pspecs)
        entry["artifacts"]["grad"] = {
            "file": self._write_hlo(f"{cfg.name}_grad", lowered),
            "inputs": batch_io + _param_entries(cfg, "param"),
            "outputs": [_io_entry("loss", (), "f32", "loss")]
            + _param_entries(cfg, "grad"),
        }

        # --- eval ---------------------------------------------------------
        e = O.make_eval_step(cfg, kernels="ref")
        lowered = jax.jit(e).lower(tok, tgt, *pspecs)
        entry["artifacts"]["eval"] = {
            "file": self._write_hlo(f"{cfg.name}_eval", lowered),
            "inputs": batch_io + _param_entries(cfg, "param"),
            "outputs": [_io_entry("loss", (), "f32", "loss")],
        }

        # --- weighted grad (SFT masking / ReMax advantages) ---------------
        wg = O.make_weighted_grad_step(cfg, kernels="ref")
        wspec = _spec((b, s), "f32")
        lowered = jax.jit(wg).lower(tok, tgt, wspec, *pspecs)
        entry["artifacts"]["grad_weighted"] = {
            "file": self._write_hlo(f"{cfg.name}_grad_weighted", lowered),
            "inputs": batch_io
            + [_io_entry("weights", (b, s), "f32", "batch")]
            + _param_entries(cfg, "param"),
            "outputs": [_io_entry("loss", (), "f32", "loss")]
            + _param_entries(cfg, "grad"),
        }

        # --- logits (sampling / analysis) ----------------------------------
        lg = O.make_logits_step(cfg, kernels="ref")
        lowered = jax.jit(lg).lower(tok, *pspecs)
        entry["artifacts"]["logits"] = {
            "file": self._write_hlo(f"{cfg.name}_logits", lowered),
            "inputs": [batch_io[0]] + _param_entries(cfg, "param"),
            "outputs": [_io_entry("logits", (b, s, cfg.vocab), "f32",
                                  "logits")],
        }

        # --- LoRA adapter grads (Fig 22 / Table 5 SFT-LoRA) ----------------
        if cfg.name in ("t48k", "t134k"):
            rank = 4
            lg = O.make_lora_grad_step(cfg, rank=rank, kernels="ref")
            a_shapes, b_shapes = O.lora_shapes(cfg, rank)
            a_specs = [_spec(s) for s in a_shapes]
            b_specs = [_spec(s) for s in b_shapes]
            lowered = jax.jit(lg).lower(tok, tgt, *pspecs, *a_specs,
                                        *b_specs)
            a_io = [_io_entry(f"lora_a.{t}", s, role="lora")
                    for t, s in zip(O.LORA_TARGETS, a_shapes)]
            b_io = [_io_entry(f"lora_b.{t}", s, role="lora")
                    for t, s in zip(O.LORA_TARGETS, b_shapes)]
            entry["artifacts"]["grad_lora"] = {
                "file": self._write_hlo(f"{cfg.name}_grad_lora", lowered),
                "inputs": batch_io + _param_entries(cfg, "param")
                + a_io + b_io,
                "outputs": [_io_entry("loss", (), "f32", "loss")]
                + [_io_entry("g." + e["name"], e["shape"], "f32", "grad")
                   for e in a_io + b_io],
            }

        # --- fused train steps ---------------------------------------------
        if cfg.name in _FULL_TRAIN_MODELS:
            variants = [("adamw", "hessian", "pallas"),
                        ("adam_mini", "hessian", "pallas"),
                        ("adamw", "hessian", "ref"),
                        ("adam_mini", "hessian", "ref"),
                        ("adam_mini", "default", "pallas")]
        else:
            variants = []
        for optimizer, strategy, kern in variants:
            key = f"train_{optimizer}"
            if strategy != "hessian":
                key += f"_{strategy}"
            if kern != "pallas":
                key += f"_{kern}"
            if optimizer == "adamw":
                step = O.make_train_step_adamw(cfg, HP, kernels=kern)
                mspecs = pspecs
                vspecs = pspecs
            else:
                step, spec = O.make_train_step_adam_mini(
                    cfg, HP, strategy=strategy, kernels=kern)
                mspecs = pspecs
                vspecs = [_spec((bv.num_blocks,)) for bv in spec]
            lowered = jax.jit(step).lower(tok, tgt, scal, scal,
                                          *pspecs, *mspecs, *vspecs)
            out_state = (_param_entries(cfg, "param")
                         + _state_entries(cfg, optimizer, strategy))
            entry["artifacts"][key] = {
                "file": self._write_hlo(f"{cfg.name}_{key}", lowered),
                "optimizer": optimizer, "strategy": strategy,
                "kernels": kern,
                "inputs": batch_io + scal_io
                + _param_entries(cfg, "param")
                + _state_entries(cfg, optimizer, strategy),
                "outputs": [_io_entry("loss", (), "f32", "loss")]
                + out_state,
            }

        self.manifest["models"][cfg.name] = entry
        print(f"[aot] {cfg.name} done in {time.time() - t0:.1f}s",
              flush=True)

    def finish(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)
        print(f"[aot] wrote manifest.json "
              f"({len(self.manifest['models'])} models)")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="",
                    help="comma-separated subset (default: all)")
    args = ap.parse_args(argv)
    zoo = model_zoo()
    names = [n for n in args.models.split(",") if n] or list(zoo)
    ex = Exporter(args.out_dir)
    for name in names:
        ex.export_model(zoo[name])
    ex.finish()


if __name__ == "__main__":
    main()
