"""L2: JAX transformer language models (GPT-2 and Llama families).

Build-time only — these functions are lowered once by :mod:`compile.aot`
to HLO text and executed from the Rust coordinator; Python never runs on
the training step path.

Design notes:

- Parameters are a *flat ordered list* of layer-stacked tensors (axis 0 =
  n_layers for per-layer weights) so the whole depth lowers as one
  ``lax.scan`` — small HLO, fast PJRT compile, and a stable positional
  ABI for the Rust runtime (the manifest records the order).
- Weights are stored (out, in) like ``torch.nn.Linear``, which makes the
  paper's partition classes (head rows / output-neuron rows / token rows)
  contiguous row ranges — the same layout the Pallas optimizer kernels
  tile over.
- ``kernels='pallas'`` routes rmsnorm / attention / cross-entropy through
  the Pallas kernels (with custom VJPs); ``kernels='ref'`` uses the jnp
  oracles. Both lower to the same interface and are exported for A/B
  benchmarking.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import kernels as K
from .kernels import ref as R


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Transformer hyperparameters (mirrors paper Table 8, scaled)."""

    name: str
    family: str  # 'llama' | 'gpt2'
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int
    batch_size: int

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    def param_shapes(self) -> Dict[str, Tuple[int, ...]]:
        """Ordered name -> shape map; THE positional ABI for artifacts."""
        l, d, ff, v = self.n_layers, self.d_model, self.d_ff, self.vocab
        shapes: Dict[str, Tuple[int, ...]] = {"embed": (v, d)}
        if self.family == "gpt2":
            shapes["pos_emb"] = (self.seq_len, d)
        shapes.update({
            "wq": (l, d, d), "wk": (l, d, d), "wv": (l, d, d),
            "wo": (l, d, d),
        })
        if self.family == "llama":
            shapes.update({"w1": (l, ff, d), "w3": (l, ff, d),
                           "w2": (l, d, ff)})
        else:
            shapes.update({"w_in": (l, ff, d), "w_out": (l, d, ff)})
        shapes.update({
            "attn_norm": (l, d), "mlp_norm": (l, d), "final_norm": (d,),
            "output": (v, d),
        })
        return shapes

    def stacked_names(self) -> List[str]:
        return [n for n, s in self.param_shapes().items()
                if len(s) >= 2 and s[0] == self.n_layers
                and n not in ("embed", "output", "pos_emb")]

    @property
    def n_params(self) -> int:
        return sum(math.prod(s) for s in self.param_shapes().values())


def init_params(cfg: ModelConfig, seed: int = 0) -> List[jax.Array]:
    """GPT-2-style init: N(0, 0.02), residual-out mats scaled by 1/sqrt(2L)."""
    key = jax.random.PRNGKey(seed)
    shapes = cfg.param_shapes()
    keys = jax.random.split(key, len(shapes))
    out = []
    resid_scaled = ("wo", "w2", "w_out")
    for (name, shape), k in zip(shapes.items(), keys):
        if "norm" in name:
            p = jnp.ones(shape, jnp.float32)
        else:
            std = 0.02
            if name in resid_scaled:
                std /= math.sqrt(2 * cfg.n_layers)
            p = std * jax.random.normal(k, shape, jnp.float32)
        out.append(p)
    return out


def _rope_tables(seq_len: int, d_head: int):
    """Rotary embedding cos/sin tables, (S, d_head/2)."""
    half = d_head // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = jnp.arange(seq_len, dtype=jnp.float32)[:, None] * freqs[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def _apply_rope(x, cos, sin):
    """x: (B, H, S, Dh); rotate pairs (x1, x2) = split-half convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[None, None, :, :]
    s = sin[None, None, :, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


def _norm(x, w, use_pallas: bool):
    if use_pallas:
        return K.rmsnorm(x, w)
    return R.rmsnorm_ref(x, w)


def _attn(q, k, v, use_pallas: bool):
    if use_pallas:
        return K.attention(q, k, v)
    return R.attention_ref(q, k, v)


def forward(cfg: ModelConfig, params: List[jax.Array], tokens: jax.Array,
            kernels: str = "ref") -> jax.Array:
    """Token ids (B, S) -> logits (B, S, V)."""
    use_pallas = kernels == "pallas"
    names = list(cfg.param_shapes().keys())
    p = dict(zip(names, params))
    b, s = tokens.shape
    h, dh = cfg.n_heads, cfg.d_head

    x = p["embed"][tokens]  # (B, S, d)
    if cfg.family == "gpt2":
        x = x + p["pos_emb"][None, :s, :]
        cos = sin = None
    else:
        cos, sin = _rope_tables(s, dh)

    stacked = [p[n] for n in cfg.stacked_names()]
    names_stacked = cfg.stacked_names()

    def layer(x, layer_params):
        lp = dict(zip(names_stacked, layer_params))
        hn = _norm(x, lp["attn_norm"], use_pallas)
        # (B, S, d) @ (d, d)^T; weights stored (out, in).
        q = (hn @ lp["wq"].T).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
        k = (hn @ lp["wk"].T).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
        v = (hn @ lp["wv"].T).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
        if cfg.family == "llama":
            q = _apply_rope(q, cos, sin)
            k = _apply_rope(k, cos, sin)
        a = _attn(q, k, v, use_pallas)
        a = a.transpose(0, 2, 1, 3).reshape(b, s, cfg.d_model)
        x = x + a @ lp["wo"].T
        hn2 = _norm(x, lp["mlp_norm"], use_pallas)
        if cfg.family == "llama":
            ff = jax.nn.silu(hn2 @ lp["w1"].T) * (hn2 @ lp["w3"].T)
            x = x + ff @ lp["w2"].T
        else:
            x = x + jax.nn.gelu(hn2 @ lp["w_in"].T) @ lp["w_out"].T
        return x, None

    x, _ = jax.lax.scan(layer, x, stacked)
    x = _norm(x, p["final_norm"], use_pallas)
    return x @ p["output"].T


def loss_fn(cfg: ModelConfig, params: List[jax.Array], tokens: jax.Array,
            targets: jax.Array, kernels: str = "ref") -> jax.Array:
    """Mean next-token cross-entropy."""
    logits = forward(cfg, params, tokens, kernels=kernels)
    flat = logits.reshape(-1, cfg.vocab)
    tgt = targets.reshape(-1)
    if kernels == "pallas":
        losses = K.cross_entropy(flat, tgt)
    else:
        losses = R.cross_entropy_ref(flat, tgt)
    return jnp.mean(losses)


def grad_fn(cfg: ModelConfig, kernels: str = "ref"):
    """Returns f(params, tokens, targets) -> (loss, grads-list)."""
    def f(params, tokens, targets):
        return loss_fn(cfg, params, tokens, targets, kernels=kernels)
    return jax.value_and_grad(f)
