"""Parameter partitioning (paper Algorithm 3 + Principle 1).

Every parameter tensor is mapped to a uniform 2-D *block view*
``(num_blocks, block_size)`` such that each row is one dense Hessian
sub-block of Principle 1:

- ``embed`` / ``output``      -> one block per token row
- ``wq`` / ``wk``             -> one block per attention head (per layer)
- ``wv`` / ``wo`` / MLP mats  -> one block per output neuron (per layer)
- norms / everything else     -> one block per parameter tensor (per layer)

Layer-stacked tensors (leading axis = n_layers, used by the scan-based
model) fold the layer axis into the block axis, which exactly matches
"per-layer, then per-head/neuron" granularity.

Three strategies are exported (all used by the paper's experiments):

- ``hessian``     : Algorithm 3 (the Adam-mini default).
- ``default``     : PyTorch-default partition — one block per parameter
                    tensor (per layer). The paper shows this destabilizes
                    >=1B training (Fig 7i, Fig 8a).
- ``value_whole`` : Algorithm 3 but `value` treated as a whole per layer
                    (Appendix D.6 strategy II, ``optimizer.wv_names={}``).

The same spec is mirrored in Rust (``rust/src/partition``) and golden-
tested against the manifest emitted here.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

STRATEGIES = ("hessian", "default", "value_whole")

# Name-category table (paper Algorithm 3's `if 'embed' in name` chain).
_TOKEN_ROW = ("embed", "output", "pos_emb")
_HEAD = ("wq", "wk")
_OUT_NEURON = ("wv", "wo", "w1", "w2", "w3", "w_in", "w_out")


@dataclasses.dataclass(frozen=True)
class BlockView:
    """2-D block view of one parameter tensor.

    ``view = param.reshape(num_blocks, block_size)``; row ``i`` is Hessian
    block ``i``. ``category`` records which Algorithm-3 branch applied.
    """

    name: str
    shape: Tuple[int, ...]
    num_blocks: int
    block_size: int
    category: str

    @property
    def n_elements(self) -> int:
        return self.num_blocks * self.block_size


def _category(name: str) -> str:
    base = name.split(".")[-1]
    if any(k in base for k in _TOKEN_ROW):
        return "token_row"
    if any(base == k for k in _HEAD):
        return "head"
    if any(base == k for k in _OUT_NEURON):
        return "out_neuron"
    return "whole"


def block_view(name: str, shape: Sequence[int], n_heads: int,
               stacked: bool, strategy: str = "hessian") -> BlockView:
    """Compute the (num_blocks, block_size) view for one tensor.

    ``stacked`` marks layer-stacked tensors whose axis 0 is n_layers.
    """
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}")
    shape = tuple(int(s) for s in shape)
    n = math.prod(shape)
    layers = shape[0] if stacked else 1
    cat = _category(name)
    base = name.split(".")[-1]

    if strategy == "default":
        blocks = layers
    elif strategy == "value_whole" and base == "wv":
        blocks = layers
        cat = "whole"
    elif cat == "token_row":
        # embed/output stored (V, d) (pos_emb: (S, d)): one block per row.
        blocks = shape[0]
    elif cat == "head":
        # (L, d, d) or (d, d), output dim split across heads.
        blocks = layers * n_heads
    elif cat == "out_neuron":
        # (L, out, in) or (out, in): one block per output-neuron row.
        out_dim = shape[1] if stacked else shape[0]
        blocks = layers * out_dim
    else:
        blocks = layers

    if n % blocks != 0:
        raise ValueError(
            f"{name}: {n} elements not divisible into {blocks} blocks")
    return BlockView(name=name, shape=shape, num_blocks=blocks,
                     block_size=n // blocks, category=cat)


def partition_spec(param_shapes: Dict[str, Sequence[int]], n_heads: int,
                   stacked_names: Sequence[str],
                   strategy: str = "hessian") -> List[BlockView]:
    """Partition a whole model. Returns one BlockView per tensor, in the
    iteration order of ``param_shapes`` (which must be deterministic)."""
    out = []
    for name, shape in param_shapes.items():
        out.append(block_view(name, shape, n_heads,
                              stacked=name in stacked_names,
                              strategy=strategy))
    return out


def total_blocks(spec: Sequence[BlockView]) -> int:
    return sum(b.num_blocks for b in spec)


def total_params(spec: Sequence[BlockView]) -> int:
    return sum(b.n_elements for b in spec)


def v_reduction_ratio(spec: Sequence[BlockView]) -> float:
    """Fraction of Adam's v removed: 1 - (#blocks / #params).

    The paper reports >= 99.9% for mainstream LLM shapes.
    """
    return 1.0 - total_blocks(spec) / total_params(spec)
