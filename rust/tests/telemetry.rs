//! Integration tests for the telemetry subsystem against the real
//! dist engine (artifact-free).
//!
//! The contract under test, end to end: attaching an [`EventBus`]
//! never changes training math (N-vs-1 bit-exactness holds in all
//! four overlap × zero2 combinations), event-derived byte totals
//! match the transport ledger to the byte, per-bucket events respect
//! causal order (BucketReady ≤ CollectiveLaunched ≤ CollectiveLanded
//! ≤ ShardStepped ≤ param-gather), a tiny bus reports drops without
//! deadlocking or perturbing the run, and a recorded trace survives
//! the validate → Chrome-export → `repro top` render pipeline.

use std::collections::HashMap;
use std::sync::Arc;

use adam_mini::dist::{record_probe_trace, DistOptions, DistTrainer,
                      TrafficClass};
use adam_mini::optim::{by_name, Hyper, ModelMeta, ReduceOp};
use adam_mini::partition::{BlockView, Strategy};
use adam_mini::telemetry::{top, trace, Event, EventBus,
                           MetricsRegistry};
use adam_mini::tensor::Tensor;
use adam_mini::util::prng::Rng;

const D: usize = 32;

fn toy_params(seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed);
    vec![Tensor::randn("embed", &[D, D], 0.1, &mut rng)]
}

fn toy_meta() -> ModelMeta {
    ModelMeta { n_heads: 1, stacked: vec![] }
}

fn toy_spec(params: &[Tensor]) -> Vec<BlockView> {
    toy_meta().spec_for(params, Strategy::Hessian).unwrap()
}

fn toy_options(optimizer: &str, workers: usize, zero2: bool,
               spec: Option<Vec<BlockView>>) -> DistOptions {
    DistOptions {
        workers,
        bucket_kb: 1,
        zero1: true,
        zero2,
        bucket_step: true,
        optimizer: optimizer.into(),
        reduce: ReduceOp::Mean,
        spec,
        ..Default::default()
    }
}

/// One deterministic synthetic gradient per step — the SAME stream
/// for every run shape, so parameter trajectories are comparable
/// bit-for-bit.
fn grad_stream(steps: usize) -> Vec<Tensor> {
    let mut rng = Rng::new(0x9E17);
    (0..steps)
        .map(|_| Tensor::randn("embed", &[D, D], 0.02, &mut rng))
        .collect()
}

/// Reference: single-replica host optimizer on the shared stream.
fn run_host(optimizer: &str, steps: usize) -> Vec<Tensor> {
    let mut params = toy_params(1);
    let mut opt = by_name(optimizer, Hyper::default(), &params,
                          &toy_meta()).unwrap();
    for g in grad_stream(steps) {
        opt.step(&mut params, std::slice::from_ref(&g), 2e-2);
    }
    params
}

/// N-worker run on the shared stream (one micro-batch per step, so
/// ranks 1.. are idle — the bit-exactness configuration), optionally
/// with a bus attached.
fn run_dist(optimizer: &str, workers: usize, zero2: bool,
            overlap: bool, steps: usize, bus: Option<Arc<EventBus>>)
    -> Vec<Tensor> {
    let mut params = toy_params(1);
    let spec = if optimizer.starts_with("adam_mini") {
        Some(toy_spec(&params))
    } else {
        None
    };
    let mut dist = DistTrainer::new(
        &params, toy_options(optimizer, workers, zero2, spec))
        .unwrap();
    if let Some(b) = bus {
        dist.attach_bus(b);
    }
    for g in grad_stream(steps) {
        if overlap {
            let mut stream = dist.begin_step(1, 2e-2);
            stream.push_grad(0, 0, &g).unwrap();
            stream.finish(&mut params).unwrap();
        } else {
            let mut local = dist.grad_buffers();
            dist.layout()
                .accumulate(&mut local[0], std::slice::from_ref(&g));
            dist.step(&mut params, local, 1, 2e-2).unwrap();
        }
    }
    params
}

#[test]
fn events_are_causally_ordered_per_bucket() {
    // workers=4, overlap, ZeRO-2, bucket-granular stepping, 1 KB
    // buckets: the busiest schedule the engine has. Every bucket's
    // event chain must respect causal order by bus sequence number.
    let bus = EventBus::new(1 << 16);
    run_dist("adamw", 4, true, true, 3, Some(Arc::clone(&bus)));
    let events = bus.drain();
    assert_eq!(bus.dropped(), 0);
    for w in events.windows(2) {
        assert!(w[0].seq < w[1].seq, "seq must be strictly increasing");
    }
    #[derive(Default, Clone, Copy)]
    struct Marks {
        scatter_launch: Option<u64>,
        scatter_land: Option<u64>,
        stepped: Option<u64>,
        gather_launch: Option<u64>,
        gather_land: Option<u64>,
    }
    let mut ready: HashMap<(u64, i64), u64> = HashMap::new();
    let mut marks: HashMap<(u64, usize, i64), Marks> = HashMap::new();
    for st in &events {
        match &st.event {
            Event::BucketReady { step, bucket, .. } => {
                ready.insert((*step, *bucket as i64), st.seq);
            }
            Event::CollectiveLaunched {
                step, rank, bucket, class, ..
            } => {
                let m = marks
                    .entry((*step, *rank, *bucket as i64))
                    .or_default();
                match *class {
                    "grad_scatter" => m.scatter_launch = Some(st.seq),
                    "param_gather" => m.gather_launch = Some(st.seq),
                    _ => {}
                }
            }
            Event::CollectiveLanded {
                step, rank, bucket, class, ..
            } => {
                let m = marks
                    .entry((*step, *rank, *bucket as i64))
                    .or_default();
                match *class {
                    "grad_scatter" => m.scatter_land = Some(st.seq),
                    "param_gather" => m.gather_land = Some(st.seq),
                    _ => {}
                }
            }
            Event::ShardStepped { step, rank, bucket, .. }
                if *bucket >= 0 =>
            {
                marks
                    .entry((*step, *rank, *bucket))
                    .or_default()
                    .stepped = Some(st.seq);
            }
            _ => {}
        }
    }
    let mut full_chains = 0;
    for ((step, rank, bucket), m) in &marks {
        let key = format!("step {step} rank {rank} bucket {bucket}");
        let r = ready.get(&(*step, *bucket)).copied();
        if let (Some(r), Some(sl), Some(sd)) =
            (r, m.scatter_launch, m.scatter_land)
        {
            assert!(r <= sl, "{key}: ready {r} > launch {sl}");
            assert!(sl < sd, "{key}: launch {sl} >= land {sd}");
            if let Some(stp) = m.stepped {
                assert!(sd < stp, "{key}: land {sd} >= stepped {stp}");
                if let (Some(gl), Some(gd)) =
                    (m.gather_launch, m.gather_land)
                {
                    assert!(stp < gl,
                            "{key}: stepped {stp} >= gather {gl}");
                    assert!(gl < gd, "{key}: gather launch >= land");
                    full_chains += 1;
                }
            }
        }
    }
    assert!(full_chains > 0,
            "no full ready->scatter->step->gather chains observed");
}

#[test]
fn event_bytes_match_ledger_exactly() {
    // Fold Message events into the registry; per-class totals must
    // equal the transport ledger to the byte — including the
    // state_sync gather.
    let bus = EventBus::new(1 << 16);
    let mut params = toy_params(1);
    let spec = Some(toy_spec(&params));
    let mut dist = DistTrainer::new(
        &params, toy_options("adam_mini", 3, true, spec)).unwrap();
    dist.attach_bus(Arc::clone(&bus));
    for g in grad_stream(4) {
        let mut stream = dist.begin_step(1, 2e-2);
        stream.push_grad(0, 0, &g).unwrap();
        stream.finish(&mut params).unwrap();
    }
    dist.sync_state().unwrap();
    assert_eq!(bus.dropped(), 0);
    let mut m = MetricsRegistry::new();
    for st in bus.drain() {
        m.observe(&st);
    }
    for c in TrafficClass::ALL {
        let from_events: u64 = m
            .workers
            .values()
            .map(|w| w.bytes.get(c.name()).copied().unwrap_or(0))
            .sum();
        assert_eq!(from_events, dist.stats().bytes(c),
                   "class {}", c.name());
        assert!(from_events > 0, "class {} saw no traffic", c.name());
    }
}

#[test]
fn bus_attachment_never_changes_the_math() {
    // The acceptance gate: with a bus attached, every (overlap x
    // zero2) combination stays bit-identical to the host run.
    for optimizer in ["adamw", "adam_mini"] {
        let reference = run_host(optimizer, 25);
        for zero2 in [false, true] {
            for overlap in [false, true] {
                let bus = EventBus::new(1 << 16);
                let got = run_dist(optimizer, 4, zero2, overlap, 25,
                                   Some(Arc::clone(&bus)));
                assert!(bus.published() > 0);
                assert_eq!(got, reference,
                           "{optimizer} zero2={zero2} \
                            overlap={overlap}");
            }
        }
    }
}

#[test]
fn tiny_bus_drops_without_deadlock_or_perturbation() {
    // Capacity 8 against a schedule that emits hundreds of events:
    // the run must complete (publish never blocks), report drops,
    // keep seq gaps bounded by the drop count, and leave parameters
    // bit-identical to the bus-free run.
    let clean = run_dist("adamw", 4, true, true, 10, None);
    let bus = EventBus::new(8);
    let noisy =
        run_dist("adamw", 4, true, true, 10, Some(Arc::clone(&bus)));
    assert_eq!(noisy, clean, "tiny bus perturbed the math");
    let drained = bus.drain();
    assert!(drained.len() <= 8);
    assert!(bus.dropped() > 0, "capacity-8 bus should have dropped");
    let mut gaps = drained.first().map(|s| s.seq).unwrap_or(0);
    for w in drained.windows(2) {
        gaps += w[1].seq - w[0].seq - 1;
    }
    assert!(gaps <= bus.dropped(),
            "{gaps} seq gaps > {} reported drops", bus.dropped());
}

#[test]
fn probe_trace_records_validates_and_renders() {
    // The CI smoke path as a test: record an artifact-free probe
    // trace, validate its schema (gap-free), export Chrome spans,
    // and render a `repro top` frame from it without a TTY.
    let dir = std::env::temp_dir().join("amck_telemetry");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("probe.jsonl");
    let (published, dropped) =
        record_probe_trace(&path, 2, 2, true).unwrap();
    assert!(published > 0);
    assert_eq!(dropped, 0);
    let (n, gaps, drops) = trace::validate(&path).unwrap();
    assert_eq!(n as u64, published);
    assert_eq!((gaps, drops), (0, 0));
    let (events, _) = trace::read_trace(&path).unwrap();
    assert_eq!(events.len() as u64, published);
    let text = trace::chrome_trace(&events).to_string();
    assert!(text.contains("traceEvents"));
    assert!(text.contains("\"ph\":\"X\""), "no complete spans: {text}");
    let m = top::registry_from_trace(&path).unwrap();
    let frame = top::render_frame(&m);
    assert!(frame.contains("repro top"));
    assert!(frame.contains("w0"), "worker rows missing:\n{frame}");
    assert!(frame.contains("w1"), "worker rows missing:\n{frame}");
    assert!(!frame.contains('\x1b'), "frame must be ANSI-free");
    std::fs::remove_dir_all(dir).ok();
}
