//! Integration tests for the serve subsystem against the real
//! scheduler, pool, and tenant runtimes (no mocks).
//!
//! The contract under test, end to end: tenant trajectories are
//! bit-identical whether a tenant runs alone or interleaved with
//! others under preemption (isolation); preempt → checkpoint →
//! resume through `StateDict` is equivalent to never stopping; a
//! worker fault fails ONE job while the service and every other
//! tenant finish normally; `sched=fair` keeps Jain's index ≥ 0.9 and
//! respects the starvation bound on the seeded storm; and the
//! shared-base closed-form memory model matches bytes measured from
//! live runtimes.

use std::sync::Arc;

use adam_mini::cluster::{lora_adapter_params, shared_base_bytes,
                         ADAMW_PROFILE, ADAM_MINI_PROFILE};
use adam_mini::coordinator::bigram::VOCAB;
use adam_mini::serve::tenant::{shared_base, TenantRuntime};
use adam_mini::serve::{run, run_jobs, JobKind, JobSpec, ServeConfig};

fn spec(id: u64, tenant: &str, seed: u64, kind: JobKind, steps: u64)
    -> JobSpec {
    JobSpec {
        id,
        tenant: tenant.to_string(),
        tenant_seed: seed,
        kind,
        prio: 0,
        steps,
        arrival_round: 0,
        fail_at: None,
    }
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Two tenants forced to interleave on a single-worker pool must each
/// produce the exact loss trajectory they produce running alone.
#[test]
fn tenant_isolation_is_bit_exact_under_preemption() {
    let cfg = ServeConfig {
        tenants: 2,
        pool: 1, // one lease: every round preempts somebody
        quantum: 2,
        ..Default::default()
    };
    let both = vec![
        spec(0, "a", 11, JobKind::Train, 7),
        spec(1, "a", 11, JobKind::Eval, 3),
        spec(2, "b", 22, JobKind::Train, 6),
        spec(3, "b", 22, JobKind::Sft, 5),
    ];
    let mixed = run_jobs(&cfg, both.clone()).unwrap();
    assert_eq!(mixed.done, 4);
    // Interleaving happened: at least one preemption occurred.
    assert!(mixed.jobs.iter().any(|j| j.preemptions > 0),
            "workload too small to interleave");
    let solo_cfg = ServeConfig { tenants: 1, ..cfg.clone() };
    let solo_a =
        run_jobs(&solo_cfg, both[..2].to_vec()).unwrap();
    let solo_b =
        run_jobs(&solo_cfg, both[2..].to_vec()).unwrap();
    assert_eq!(bits(&mixed.tenant_losses["a"]),
               bits(&solo_a.tenant_losses["a"]));
    assert_eq!(bits(&mixed.tenant_losses["b"]),
               bits(&solo_b.tenant_losses["b"]));
}

/// Preempt, checkpoint to a `StateDict` under the tenant key prefix,
/// resume in a fresh runtime: the continuation is bit-identical to a
/// run that never stopped.
#[test]
fn preempt_checkpoint_resume_is_equivalent() {
    let base = shared_base(0xBA5E);
    let mut uninterrupted =
        TenantRuntime::new("t0", 77, 4, "adam_mini",
                           Arc::clone(&base)).unwrap();
    let full = uninterrupted
        .run_quantum(JobKind::Train, 12, 0, None)
        .unwrap();
    let mut first = TenantRuntime::new("t0", 77, 4, "adam_mini",
                                       Arc::clone(&base)).unwrap();
    let head =
        first.run_quantum(JobKind::Train, 5, 0, None).unwrap();
    let sd = first.checkpoint();
    // Key-prefix schema: everything namespaced, params + opt + cursor.
    assert!(sd.keys().all(|k| k.starts_with("tenant/t0/")));
    assert!(sd.get("tenant/t0/param/lora_a").is_some());
    assert!(sd.get("tenant/t0/param/lora_b").is_some());
    assert!(sd.get("tenant/t0/meta").is_some());
    assert!(sd.keys().any(|k| k.starts_with("tenant/t0/opt::")));
    let mut resumed = TenantRuntime::resume("t0", 77, 4, "adam_mini",
                                            Arc::clone(&base), &sd)
        .unwrap();
    let tail =
        resumed.run_quantum(JobKind::Train, 7, 1, None).unwrap();
    let stitched: Vec<f32> =
        head.iter().chain(&tail).copied().collect();
    assert_eq!(bits(&stitched), bits(&full));
    assert_eq!(resumed.params[0].data, uninterrupted.params[0].data);
    assert_eq!(resumed.params[1].data, uninterrupted.params[1].data);
}

/// A worker dying mid-quantum fails that one job with a typed error;
/// every other job still reaches `done` and the run reports cleanly.
#[test]
fn worker_fault_fails_one_job_not_the_service() {
    let cfg = ServeConfig { tenants: 2, pool: 2, ..Default::default() };
    let mut doomed = spec(0, "a", 11, JobKind::Train, 8);
    doomed.fail_at = Some(4);
    let jobs = vec![
        doomed,
        spec(1, "a", 11, JobKind::Train, 4),
        spec(2, "b", 22, JobKind::Sft, 6),
    ];
    let report = run_jobs(&cfg, jobs).unwrap();
    assert_eq!(report.failed, 1);
    assert_eq!(report.done, 2);
    let failed = &report.jobs[0];
    assert_eq!(failed.state, "failed");
    assert!(failed.error.as_deref().unwrap().contains("panicked"),
            "error: {:?}", failed.error);
    // Terminal-everything still satisfies the CI contract.
    report.check().unwrap();
}

/// The seeded CI storm under `sched=fair`: all jobs terminal, no
/// tenant starves past the bound, and service is near-evenly split
/// (Jain's index ≥ 0.9 — the ISSUE acceptance threshold).
#[test]
fn fair_storm_is_fair_and_starvation_free() {
    let cfg = ServeConfig::default(); // tenants=4 pool=2 storm_seed=7
    let report = run(&cfg).unwrap();
    assert_eq!(report.done + report.failed, report.jobs.len());
    report.check().unwrap();
    assert!(report.fairness >= 0.9,
            "fairness {} under fair", report.fairness);
    assert!(report.max_tenant_wait <= report.starvation_bound);
    // Every tenant actually trained.
    assert_eq!(report.tenant_steps.len(), 4);
    assert!(report.tenant_steps.values().all(|&s| s > 0));
}

/// The other policies also drive the same storm to all-terminal —
/// they differ in ordering, not in liveness of this finite workload.
#[test]
fn fifo_and_priority_storms_terminate() {
    for sched in ["fifo", "priority"] {
        let cfg = ServeConfig { sched: sched.to_string(),
                                ..Default::default() };
        let report = run(&cfg).unwrap();
        assert!(report.all_terminal(), "{sched} left jobs queued");
        assert_eq!(report.done + report.failed, report.jobs.len());
    }
}

/// Serve runs are a pure function of the config: identical reports
/// (schedule, latencies, losses) on every replay.
#[test]
fn storm_replay_is_deterministic() {
    let cfg = ServeConfig::default();
    let a = run(&cfg).unwrap();
    let b = run(&cfg).unwrap();
    assert_eq!(a.rounds, b.rounds);
    assert_eq!(a.done, b.done);
    assert_eq!(a.failed, b.failed);
    for (j1, j2) in a.jobs.iter().zip(&b.jobs) {
        assert_eq!(j1.latency_rounds, j2.latency_rounds);
        assert_eq!(j1.state, j2.state);
    }
    for (t, losses) in &a.tenant_losses {
        assert_eq!(bits(losses), bits(&b.tenant_losses[t]));
    }
}

/// Closed-form shared-base memory model vs bytes measured from live
/// tenant runtimes: within 10% for both optimizers, and Adam-mini's
/// marginal tenant is cheaper than AdamW's (halved optimizer state).
#[test]
fn memory_model_matches_measured_runtimes() {
    let base = shared_base(0xBA5E);
    let tenants = 4usize;
    let adapter = lora_adapter_params(VOCAB, VOCAB, 4) as f64;
    let mut measured_mini = 0.0;
    for (opt, profile) in [("adam_mini", &ADAM_MINI_PROFILE),
                           ("adamw", &ADAMW_PROFILE)] {
        let mut measured = (base.numel() * 4) as f64;
        for t in 0..tenants {
            let rt = TenantRuntime::new(&format!("t{t}"),
                                        t as u64 + 1, 4, opt,
                                        Arc::clone(&base)).unwrap();
            measured += rt.state_bytes() as f64;
        }
        let modeled = shared_base_bytes(base.numel() as f64, adapter,
                                        profile, tenants);
        let delta = (measured - modeled).abs() / modeled;
        assert!(delta < 0.10,
                "{opt}: measured {measured} vs modeled {modeled}");
        if opt == "adam_mini" {
            measured_mini = measured;
        } else {
            assert!(measured_mini < measured,
                    "adam-mini tenants must be cheaper than adamw");
        }
    }
}
