//! Parity contracts for the SIMD kernel layer (`optim::kernels`):
//!
//! 1. **Scalar oracle** — the whole roster stepped under `simd=on`
//!    matches `simd=off` bit-for-bit for elementwise members (the
//!    vector path stages chunks through the same `#[inline(always)]`
//!    per-element functions), and within a stated ULP-scale tolerance
//!    for members whose block/row reductions reassociate under the
//!    lane tree fold (`adam_mini*`, `adafactor*` — see DESIGN.md
//!    "Kernel layer").
//! 2. **Folded gradient scale** — `step_scaled(…, gscale)` is
//!    bit-identical to pre-scaling the gradients and calling `step`:
//!    `g * gscale` is the same f32 multiply whether staged in a buffer
//!    or folded into the fused sweep.
//! 3. **Vector partition invariance** — under `simd=on`, a partitioned
//!    `step_segment_scaled` walk equals the whole-model `step_scaled`
//!    bitwise (the invariant ZeRO bucket-granular stepping rests on).
//! 4. **N-vs-1 dist bit-exactness at `simd=on`** — every shardable
//!    roster member, (zero2 × overlap) matrix, 4 workers vs 1, single
//!    micro-batch: identical parameters.

use std::sync::Arc;

use adam_mini::dist::{DistOptions, DistTrainer};
use adam_mini::optim::{self, by_name, kernels, GradView, Hyper,
                       ModelMeta, Optimizer, ParamView, SimdPolicy};
use adam_mini::partition::Strategy;
use adam_mini::tensor::Tensor;
use adam_mini::util::prng::Rng;

/// Mixed inventory (same shapes as the optim_core contract tests).
fn toy() -> (Vec<Tensor>, ModelMeta) {
    let mut rng = Rng::new(7);
    let params = vec![
        Tensor::randn("embed", &[16, 12], 0.5, &mut rng),
        Tensor::randn("wq", &[2, 4, 4], 0.5, &mut rng),
        Tensor::randn("attn_norm", &[2, 4], 0.5, &mut rng),
        Tensor::randn("final_norm", &[5], 0.5, &mut rng),
    ];
    let meta = ModelMeta {
        n_heads: 2,
        stacked: vec!["wq".into(), "attn_norm".into()],
    };
    (params, meta)
}

fn rand_grads(params: &[Tensor], rng: &mut Rng) -> Vec<Tensor> {
    params
        .iter()
        .map(|p| Tensor::randn(&*p.name, &p.shape, 0.5, rng))
        .collect()
}

/// Members whose update folds a reassociating reduction (block sums,
/// factored row/col sums) through the lane-tree kernels — vector and
/// scalar dispatch agree to tolerance, not bitwise.
fn reassociates(name: &str) -> bool {
    name.starts_with("adam_mini") || name.starts_with("adafactor")
}

#[test]
fn vector_roster_matches_scalar_oracle() {
    let (params0, meta) = toy();
    for name in optim::ROSTER {
        let run = |policy: SimdPolicy| {
            kernels::set_policy(policy);
            let mut p = params0.clone();
            let mut opt =
                by_name(name, Hyper::default(), &p, &meta).unwrap();
            let mut rng = Rng::new(0x51D);
            for _ in 0..5 {
                let g = rand_grads(&p, &mut rng);
                opt.step(&mut p, &g, 1e-2);
            }
            p
        };
        let on = run(SimdPolicy::On);
        let off = run(SimdPolicy::Off);
        kernels::set_policy(SimdPolicy::Auto);
        if reassociates(name) {
            for (a, b) in on.iter().zip(&off) {
                let d = a.max_abs_diff(b);
                assert!(d < 1e-5,
                        "{name} {}: vector-vs-scalar drift {d}",
                        a.name);
            }
        } else {
            assert_eq!(on, off,
                       "{name}: elementwise updates must be bitwise \
                        identical across dispatch");
        }
    }
}

#[test]
fn folded_gscale_matches_prescaled_gradients_bitwise() {
    let (params0, meta) = toy();
    const GS: f32 = 0.5;
    for name in optim::ROSTER {
        let mut rng = Rng::new(0xFADE);
        let gs: Vec<Vec<Tensor>> =
            (0..4).map(|_| rand_grads(&params0, &mut rng)).collect();
        // Fused: the scale rides into the update sweep.
        let mut pa = params0.clone();
        let mut a =
            by_name(name, Hyper::default(), &pa, &meta).unwrap();
        for g in &gs {
            a.step_scaled(&mut pa, g, 1e-2, GS);
        }
        // Oracle: materialize g * GS, then plain step.
        let mut pb = params0.clone();
        let mut b =
            by_name(name, Hyper::default(), &pb, &meta).unwrap();
        for g in &gs {
            let scaled: Vec<Tensor> = g
                .iter()
                .map(|t| {
                    let mut t2 = t.clone();
                    for x in t2.data.iter_mut() {
                        *x *= GS;
                    }
                    t2
                })
                .collect();
            b.step(&mut pb, &scaled, 1e-2);
        }
        assert_eq!(pa, pb,
                   "{name}: folded gscale diverged from pre-scaled \
                    gradients");
    }
}

/// A random disjoint partition of `[0, total)` honoring `cuts`
/// (`None` = any boundary), in shuffled application order.
fn random_partition(cuts: Option<Vec<usize>>, total: usize,
                    rng: &mut Rng) -> Vec<(usize, usize)> {
    let candidates: Vec<usize> = match cuts {
        None => (1..total).collect(),
        Some(c) => {
            c.into_iter().filter(|&x| x > 0 && x < total).collect()
        }
    };
    let mut chosen: Vec<usize> = candidates
        .into_iter()
        .filter(|_| rng.below(3) == 0)
        .collect();
    chosen.push(0);
    chosen.push(total);
    chosen.sort_unstable();
    chosen.dedup();
    let mut segs: Vec<(usize, usize)> =
        chosen.windows(2).map(|w| (w[0], w[1])).collect();
    rng.shuffle(&mut segs);
    segs
}

#[test]
fn vector_partition_with_folded_scale_is_bitwise() {
    kernels::set_policy(SimdPolicy::On);
    let (params0, meta) = toy();
    const GS: f32 = 0.75;
    for name in optim::ROSTER {
        let mut rng = Rng::new(0xBEEF);
        let mut pa = params0.clone();
        let mut a =
            by_name(name, Hyper::default(), &pa, &meta).unwrap();
        let mut b =
            by_name(name, Hyper::default(), &params0, &meta).unwrap();
        let arena = Arc::clone(b.arena());
        let mut flat = arena.flatten(&params0);
        for _ in 0..3 {
            let g = rand_grads(&pa, &mut rng);
            a.step_scaled(&mut pa, &g, 1e-2, GS);
            let gflat = arena.flatten(&g);
            let segs = random_partition(b.segment_cuts(), arena.total,
                                        &mut rng);
            b.begin_step();
            for (lo, hi) in segs {
                b.step_segment_scaled(
                    ParamView::new(lo, &mut flat[lo..hi]),
                    GradView::new(lo, &gflat[lo..hi]), 1e-2, GS);
            }
        }
        let mut pb = params0.clone();
        arena.unflatten(&flat, &mut pb);
        assert_eq!(pa, pb, "{name}: vector partition diverged");
    }
    kernels::set_policy(SimdPolicy::Auto);
}

/// Dist-shaped inventory (same shapes as the dist engine unit tests).
fn toy_dist() -> (Vec<Tensor>, ModelMeta) {
    let mut rng = Rng::new(20);
    let params = vec![
        Tensor::randn("embed", &[16, 8], 0.5, &mut rng),
        Tensor::randn("wq", &[2, 8, 8], 0.5, &mut rng),
        Tensor::randn("attn_norm", &[2, 8], 0.5, &mut rng),
    ];
    let meta = ModelMeta {
        n_heads: 2,
        stacked: vec!["wq".into(), "attn_norm".into()],
    };
    (params, meta)
}

/// Drive 5 single-micro-batch sharded steps and return the params.
fn run_world(optimizer: &str, workers: usize, zero2: bool,
             overlap: bool) -> Vec<Tensor> {
    let (mut params, meta) = toy_dist();
    let spec = if optimizer.starts_with("adam_mini") {
        Some(meta.spec_for(&params, Strategy::Hessian).unwrap())
    } else {
        None
    };
    let mut dist = DistTrainer::new(&params, DistOptions {
        workers,
        bucket_kb: 1,
        zero1: true,
        zero2,
        optimizer: optimizer.into(),
        spec,
        ..Default::default()
    })
    .unwrap();
    let mut rng = Rng::new(4242);
    for _ in 0..5 {
        let g = rand_grads(&params, &mut rng);
        if overlap {
            let mut stream = dist.begin_step(1, 1e-2);
            for j in (0..g.len()).rev() {
                stream.push_grad(0, j, &g[j]).unwrap();
            }
            stream.finish(&mut params).unwrap();
        } else {
            let mut local = dist.grad_buffers();
            dist.layout().accumulate(&mut local[0], &g);
            dist.step(&mut params, local, 1, 1e-2).unwrap();
        }
    }
    params
}

#[test]
fn n_vs_1_dist_is_bit_exact_with_simd_on() {
    // Dispatch must not depend on arena size: shard arenas are much
    // smaller than the host arena, so any size heuristic would give
    // N-worker and 1-worker runs different summation orders. This
    // matrix pins the invariant for every shardable roster member.
    kernels::set_policy(SimdPolicy::On);
    for optimizer in ["adamw", "adam_mini", "sgd", "lion", "adagrad"] {
        let reference = run_world(optimizer, 1, false, false);
        for zero2 in [false, true] {
            for overlap in [false, true] {
                let got = run_world(optimizer, 4, zero2, overlap);
                assert_eq!(reference, got,
                           "{optimizer} zero2={zero2} \
                            overlap={overlap}: 4-vs-1 drift");
            }
        }
    }
    kernels::set_policy(SimdPolicy::Auto);
}
