//! Fault-injection matrix for the socket transport.
//!
//! The contract under test: the framed/retried TCP wire is invisible
//! to the training math. A `workers=4` run over sockets — with or
//! without injected faults — produces the bit-identical loss
//! trajectory of the in-process channel run (and, at one micro-batch
//! per step, of the single-worker run), while every retransmission is
//! visible in the byte ledgers under the `retry` traffic class.

use adam_mini::data::{Batch, Batcher, Corpus, SyntheticSpec};
use adam_mini::dist::transport::socket_ring_world;
use adam_mini::dist::{DistOptions, DistTrainer, FaultSpec,
                      LinkModel, SocketOptions, TimeoutPolicy,
                      TrafficClass, TransportKind};
use adam_mini::optim::ModelMeta;
use adam_mini::partition::Strategy;
use adam_mini::tensor::Tensor;
use adam_mini::util::prng::Rng;

const VOCAB: usize = 32;

fn init_params(seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed);
    vec![Tensor::randn("embed", &[VOCAB, VOCAB], 0.1, &mut rng)]
}

/// (mean loss, analytic gradient) for the bigram LM over one batch.
fn loss_grad(params: &[Tensor], batch: &Batch) -> (f32, Vec<Tensor>) {
    let w = &params[0];
    let mut grad = Tensor::zeros("embed", &[VOCAB, VOCAB]);
    let n = batch.tokens.len();
    let inv = 1.0 / n as f32;
    let mut total = 0.0f64;
    for (&tok, &tgt) in batch.tokens.iter().zip(&batch.targets) {
        let (tok, tgt) = (tok as usize, tgt as usize);
        let row = &w.data[tok * VOCAB..(tok + 1) * VOCAB];
        let mx = row.iter().cloned().fold(f32::MIN, f32::max);
        let exps: Vec<f32> =
            row.iter().map(|x| (x - mx).exp()).collect();
        let z: f32 = exps.iter().sum();
        total += (z.ln() + mx - row[tgt]) as f64;
        let grow = &mut grad.data[tok * VOCAB..(tok + 1) * VOCAB];
        for (c, e) in grow.iter_mut().zip(&exps) {
            *c += e / z * inv;
        }
        grow[tgt] -= inv;
    }
    ((total * inv as f64) as f32, vec![grad])
}

fn corpus_batcher(seed: u64) -> Batcher {
    let corpus = Corpus::synthetic(&SyntheticSpec {
        vocab: VOCAB,
        n_tokens: 20_000,
        seed: seed ^ 0xDA7A,
        ..Default::default()
    });
    Batcher::new(corpus, 4, 16, seed)
}

struct RunOut {
    loss_bits: Vec<u32>,
    bytes: [u64; TrafficClass::ALL.len()],
    retry_msgs: u64,
    data_msgs: u64,
}

/// One short bigram training run through `DistTrainer` and the given
/// transport; returns the loss bits plus the full byte ledger.
fn run(transport: TransportKind, workers: usize, zero2: bool,
       overlap: bool, steps: usize) -> RunOut {
    let mut params = init_params(1);
    let meta = ModelMeta { n_heads: 1, stacked: vec![] };
    let spec = meta.spec_for(&params, Strategy::Hessian).unwrap();
    let mut dist = DistTrainer::new(&params, DistOptions {
        workers,
        bucket_kb: 1,
        zero1: true,
        zero2,
        optimizer: "adam_mini".into(),
        spec: Some(spec),
        transport,
        ..Default::default()
    })
    .unwrap();
    let mut batcher = corpus_batcher(9);
    let mut loss_bits = Vec::with_capacity(steps);
    // One micro-batch per step: every schedule is bit-identical to
    // the single-worker run (idle workers contribute exact zeros).
    for _ in 0..steps {
        let batch = batcher.next_batch();
        let (loss, g) = loss_grad(&params, &batch);
        if overlap {
            let mut stream = dist.begin_step(1, 2e-2);
            stream.push_grad(0, 0, &g[0]).unwrap();
            stream.finish(&mut params).unwrap();
        } else {
            let mut local = dist.grad_buffers();
            dist.layout().accumulate(&mut local[0], &g);
            dist.step(&mut params, local, 1, 2e-2).unwrap();
        }
        loss_bits.push(loss.to_bits());
    }
    let stats = dist.stats();
    let mut bytes = [0u64; TrafficClass::ALL.len()];
    let mut data_msgs = 0;
    for (i, c) in TrafficClass::ALL.iter().enumerate() {
        bytes[i] = stats.bytes(*c);
        if *c != TrafficClass::Retry {
            data_msgs += stats.messages(*c);
        }
    }
    RunOut {
        loss_bits,
        bytes,
        retry_msgs: stats.messages(TrafficClass::Retry),
        data_msgs,
    }
}

fn sock(fault: &str, seed: u64) -> TransportKind {
    TransportKind::Socket(SocketOptions {
        faults: FaultSpec::parse(fault).unwrap(),
        seed,
        policy: TimeoutPolicy::twitchy(),
    })
}

#[test]
fn fault_matrix_is_bit_exact_and_accounts_retries() {
    const STEPS: usize = 4;
    let faults = ["drop:0.2", "dup:0.15", "reorder:0.15",
                  "corrupt:0.2"];
    for zero2 in [false, true] {
        for overlap in [false, true] {
            let reference =
                run(TransportKind::Channel, 1, zero2, overlap, STEPS);
            let channel =
                run(TransportKind::Channel, 4, zero2, overlap, STEPS);
            // N-vs-1 bit-exactness holds before any socket enters.
            assert_eq!(channel.loss_bits, reference.loss_bits,
                       "channel 4-vs-1 zero2={zero2} overlap={overlap}");
            for fault in faults {
                let got = run(sock(fault, 42), 4, zero2, overlap,
                              STEPS);
                assert_eq!(
                    got.loss_bits, channel.loss_bits,
                    "{fault} zero2={zero2} overlap={overlap}");
                // Base traffic ledgers are byte-identical: faults
                // cost retries, never payload.
                for (i, c) in TrafficClass::ALL.iter().enumerate() {
                    if *c != TrafficClass::Retry {
                        assert_eq!(
                            got.bytes[i], channel.bytes[i],
                            "{} bytes under {fault}", c.name());
                    }
                }
                // Retries are bounded by the attempt budget.
                let budget = got.data_msgs
                    * (TimeoutPolicy::twitchy().max_attempts as u64
                       - 1);
                assert!(got.retry_msgs <= budget,
                        "{fault}: {} retries > budget {budget}",
                        got.retry_msgs);
            }
        }
    }
}

#[test]
fn lossy_links_actually_retry() {
    // High drop rate: the ledger must show retry traffic, proving the
    // bit-exact trajectories above survived real retransmissions.
    let got = run(sock("drop:0.3,corrupt:0.2", 7), 4, true, false, 3);
    assert!(got.retry_msgs > 0, "no retries recorded under 30% drop");
    assert!(got.bytes[TrafficClass::ALL
        .iter()
        .position(|c| *c == TrafficClass::Retry)
        .unwrap()] > 0);
}

#[test]
fn fault_free_sockets_never_retry() {
    let got = run(
        TransportKind::Socket(SocketOptions::default()), 3, false,
        true, 3);
    assert_eq!(got.retry_msgs, 0,
               "retry on a clean localhost link is a bug");
}

#[test]
fn killed_worker_is_a_typed_error_naming_the_rank() {
    // Build a 3-rank socket world and kill rank 1 outright; its
    // neighbours' sends/recvs must fail with typed errors that name
    // rank 1 — not a panic, not a hang.
    let opts = SocketOptions {
        faults: FaultSpec::default(),
        seed: 0,
        policy: TimeoutPolicy {
            base_ms: 20,
            factor: 2.0,
            cap_ms: 100,
            max_attempts: 4,
        },
    };
    let (mut nodes, _stats) =
        socket_ring_world(3, LinkModel::default(), &opts).unwrap();
    drop(nodes.remove(1));
    use adam_mini::dist::DistError;
    let names_dead_rank = |e: &DistError| {
        matches!(e,
                 DistError::PeerDisconnected { peer: 1, .. }
                 | DistError::Timeout { peer: 1, .. })
    };
    // Rank 0 sends right into the dead rank: the ack never comes.
    let send_err = nodes[0]
        .send_right(TrafficClass::GradReduce, vec![1.0; 8])
        .expect_err("send into a dead rank must fail");
    assert!(names_dead_rank(&send_err), "got {send_err}");
    // Rank 2 receives from its left — the dead rank's closed
    // connection — and gets a typed disconnect, not a hang.
    let recv_err = nodes[1]
        .recv_left()
        .expect_err("recv from a dead rank must fail");
    assert!(names_dead_rank(&recv_err), "got {recv_err}");
}
