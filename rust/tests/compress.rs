//! Integration tests for the gradient-compression codec layer.
//!
//! Contracts under test, end to end through `DistTrainer`:
//!
//! - **Loss parity** — f16 and top-k (with error feedback) track the
//!   uncompressed loss curve within a per-codec tolerance at
//!   `workers=4`, in every (gradient schedule × pipeline) combination,
//!   and the model still learns.
//! - **`compress=none` is invisible** — the dense pipeline keeps the
//!   historical N-vs-1 bit-exactness and moves zero codec-class bytes.
//! - **Byte accounting** — measured step bytes match the `cluster.rs`
//!   compressed closed forms within 10%.
//! - **Error-feedback state is durable** — a preempt → save → resume
//!   cycle under `compress=topk` continues bit-identically to the
//!   uninterrupted run, because the per-rank residuals ride the run
//!   checkpoint as `rank<r>/ef/residual` entries.
//! - **Transport invariance** — a codec over lossy sockets produces
//!   the bit-identical loss trajectory of the same codec over
//!   in-process channels: the codec sits above the wire, the ARQ
//!   below it, and neither leaks into the math.

use adam_mini::coordinator::checkpoint::{load_run, save_run};
use adam_mini::data::{Batch, Batcher, Corpus, SyntheticSpec};
use adam_mini::dist::{measure_compressed_traffic, CodecSpec,
                      DistOptions, DistTrainer, FaultSpec,
                      SocketOptions, TimeoutPolicy, TrafficClass,
                      TransportKind};
use adam_mini::optim::{ModelMeta, ReduceOp};
use adam_mini::partition::Strategy;
use adam_mini::tensor::Tensor;
use adam_mini::util::prng::Rng;

const VOCAB: usize = 32;

/// Bigram LM (mean CE over a `(vocab, vocab)` table, analytic
/// gradient) — the artifact-free model every dist integration suite
/// drives.
struct Bigram;

impl Bigram {
    fn init(seed: u64) -> Vec<Tensor> {
        let mut rng = Rng::new(seed);
        vec![Tensor::randn("embed", &[VOCAB, VOCAB], 0.1, &mut rng)]
    }

    fn meta() -> ModelMeta {
        ModelMeta { n_heads: 1, stacked: vec![] }
    }

    fn loss_grad(params: &[Tensor], batch: &Batch)
        -> (f32, Vec<Tensor>) {
        let w = &params[0];
        let mut grad = Tensor::zeros("embed", &[VOCAB, VOCAB]);
        let n = batch.tokens.len();
        let inv = 1.0 / n as f32;
        let mut total = 0.0f64;
        for (&tok, &tgt) in batch.tokens.iter().zip(&batch.targets) {
            let (tok, tgt) = (tok as usize, tgt as usize);
            let row = &w.data[tok * VOCAB..(tok + 1) * VOCAB];
            let mx = row.iter().cloned().fold(f32::MIN, f32::max);
            let exps: Vec<f32> =
                row.iter().map(|x| (x - mx).exp()).collect();
            let z: f32 = exps.iter().sum();
            total += (z.ln() + mx - row[tgt]) as f64;
            let grow = &mut grad.data[tok * VOCAB..(tok + 1) * VOCAB];
            for (c, e) in grow.iter_mut().zip(&exps) {
                *c += e / z * inv;
            }
            grow[tgt] -= inv;
        }
        ((total * inv as f64) as f32, vec![grad])
    }
}

fn corpus_batcher(seed: u64) -> Batcher {
    let corpus = Corpus::synthetic(&SyntheticSpec {
        vocab: VOCAB,
        n_tokens: 20_000,
        seed: seed ^ 0xDA7A,
        ..Default::default()
    });
    Batcher::new(corpus, 4, 16, seed)
}

fn mini_spec(params: &[Tensor])
    -> Vec<adam_mini::partition::BlockView> {
    Bigram::meta().spec_for(params, Strategy::Hessian).unwrap()
}

fn options(workers: usize, zero2: bool, compress: &str,
           transport: TransportKind) -> DistOptions {
    let params = Bigram::init(1);
    DistOptions {
        workers,
        bucket_kb: 1,
        zero1: true,
        zero2,
        optimizer: "adam_mini".into(),
        reduce: ReduceOp::Mean,
        spec: Some(mini_spec(&params)),
        transport,
        compress: CodecSpec::parse(compress).unwrap(),
        ..Default::default()
    }
}

/// One short training run; returns (per-step losses, final trainer).
fn run(workers: usize, zero2: bool, overlap: bool, compress: &str,
       transport: TransportKind, steps: usize, micro: usize)
    -> (Vec<f32>, DistTrainer) {
    let mut params = Bigram::init(1);
    let mut dist = DistTrainer::new(
        &params, options(workers, zero2, compress, transport))
        .unwrap();
    let mut batcher = corpus_batcher(9);
    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        let mut total = 0.0;
        if overlap {
            let mut stream = dist.begin_step(micro, 2e-2);
            for i in 0..micro {
                let batch = batcher.next_batch();
                let (loss, g) = Bigram::loss_grad(&params, &batch);
                total += loss;
                stream.push_grad(i, 0, &g[0]).unwrap();
            }
            stream.finish(&mut params).unwrap();
        } else {
            let mut local = dist.grad_buffers();
            for i in 0..micro {
                let batch = batcher.next_batch();
                let (loss, g) = Bigram::loss_grad(&params, &batch);
                total += loss;
                dist.layout().accumulate(&mut local[i % workers], &g);
            }
            dist.step(&mut params, local, micro, 2e-2).unwrap();
        }
        losses.push(total / micro as f32);
    }
    (losses, dist)
}

fn bits(losses: &[f32]) -> Vec<u32> {
    losses.iter().map(|l| l.to_bits()).collect()
}

fn sock(fault: &str) -> TransportKind {
    TransportKind::Socket(SocketOptions {
        faults: FaultSpec::parse(fault).unwrap(),
        seed: 42,
        policy: TimeoutPolicy::twitchy(),
    })
}

#[test]
fn compressed_runs_track_the_dense_run() {
    const STEPS: usize = 30;
    for (compress, tol) in [("f16", 0.05f32), ("topk:0.25", 0.4)] {
        for zero2 in [false, true] {
            for overlap in [false, true] {
                let (dense, _) = run(4, zero2, overlap, "none",
                                     TransportKind::Channel, STEPS, 2);
                let (got, dist) = run(4, zero2, overlap, compress,
                                      TransportKind::Channel, STEPS,
                                      2);
                for (step, (a, b)) in
                    dense.iter().zip(&got).enumerate()
                {
                    assert!((a - b).abs() < tol,
                            "{compress} zero2={zero2} \
                             overlap={overlap} step {step}: \
                             dense {a} vs coded {b}");
                }
                // The compressed run still learns...
                assert!(got[STEPS - 1] < got[0] - 0.05,
                        "{compress}: {} -> {}", got[0],
                        got[STEPS - 1]);
                // ...and its coded hops hit the codec's own ledger
                // class.
                let class = if compress == "f16" {
                    TrafficClass::CodecF16
                } else {
                    TrafficClass::CodecTopK
                };
                assert!(dist.stats().bytes(class) > 0,
                        "{compress}: no coded traffic recorded");
            }
        }
    }
}

#[test]
fn compress_none_keeps_the_n_vs_1_bit_exactness() {
    // The dense pipeline must be untouched by the codec layer:
    // `compress=none` still satisfies the historical invariant that a
    // 4-worker single-micro-batch run is bit-identical to the
    // 1-worker run, and records zero codec-class bytes.
    const STEPS: usize = 10;
    for zero2 in [false, true] {
        for overlap in [false, true] {
            let (solo, _) = run(1, zero2, overlap, "none",
                                TransportKind::Channel, STEPS, 1);
            let (wide, dist) = run(4, zero2, overlap, "none",
                                   TransportKind::Channel, STEPS, 1);
            assert_eq!(bits(&solo), bits(&wide),
                       "zero2={zero2} overlap={overlap}");
            assert_eq!(dist.stats().bytes(TrafficClass::CodecF16), 0);
            assert_eq!(dist.stats().bytes(TrafficClass::CodecTopK), 0);
        }
    }
}

#[test]
fn measured_step_bytes_match_the_model_within_10pct() {
    for spec in [CodecSpec::F16, CodecSpec::TopK { frac: 0.25 }] {
        for zero2 in [false, true] {
            let row = measure_compressed_traffic(spec, 4, 16, 1,
                                                 zero2)
                .unwrap();
            assert!(row.delta_pct().abs() < 10.0,
                    "zero2={zero2} {row:?}");
            // Realized ratios against the dense f32 baseline: f16
            // halves everything; topk:0.25 halves only the sum hops
            // (zero1 = 2.5/3, zero2 = 1.5/2 of dense).
            let want = match (spec, zero2) {
                (CodecSpec::F16, _) => 0.5,
                (_, false) => 2.5 / 3.0,
                (_, true) => 0.75,
            };
            assert!((row.ratio_vs_f32 - want).abs() < 0.05,
                    "zero2={zero2} ratio {} want {want}",
                    row.ratio_vs_f32);
        }
    }
}

#[test]
fn topk_residual_rides_the_run_checkpoint() {
    // Preempt → save → resume under compress=topk continues
    // bit-identically to the uninterrupted run: the error-feedback
    // residuals are part of the sharded optimizer state.
    let make = |params: &[Tensor]| {
        DistTrainer::new(params, options(3, true, "topk:0.25",
                                         TransportKind::Channel))
            .unwrap()
    };
    let mut params = Bigram::init(1);
    let mut a = make(&params);
    let mut batcher = corpus_batcher(11);
    let mut step = |d: &mut DistTrainer, p: &mut Vec<Tensor>,
                    b: &mut Batcher| {
        let mut stream = d.begin_step(2, 2e-2);
        for i in 0..2 {
            let batch = b.next_batch();
            let (_, g) = Bigram::loss_grad(p, &batch);
            stream.push_grad(i, 0, &g[0]).unwrap();
        }
        stream.finish(p).unwrap();
    };
    for _ in 0..3 {
        step(&mut a, &mut params, &mut batcher);
    }
    let state = a.sync_state().unwrap();
    for r in 0..3 {
        let key = format!("rank{r}/ef/residual");
        let t = state.get(&key).unwrap_or_else(
            || panic!("missing {key}"));
        assert_eq!(t.data.len(), VOCAB * VOCAB);
    }
    // At least one rank holds dropped mass after three sparse steps.
    assert!((0..3).any(|r| {
        state.get(&format!("rank{r}/ef/residual")).unwrap().data
            .iter().any(|&x| x != 0.0)
    }), "all residuals are exactly zero");
    let path = std::env::temp_dir().join("amck_compress/run.bin");
    save_run(&path, &params, &state).unwrap();

    // Uninterrupted continuation.
    let mut batcher_b = batcher.clone();
    for _ in 0..2 {
        step(&mut a, &mut params, &mut batcher);
    }
    // Resumed continuation from the file.
    let (mut params_b, state_b) = load_run(&path).unwrap();
    let mut b = make(&params_b);
    b.import_state(&state_b).unwrap();
    for _ in 0..2 {
        step(&mut b, &mut params_b, &mut batcher_b);
    }
    assert_eq!(params, params_b,
               "resumed run diverged from the uninterrupted run");
    std::fs::remove_dir_all(path.parent().unwrap()).ok();
}

#[test]
fn codec_fault_matrix_is_bit_exact() {
    // Each codec over a faulty socket wire reproduces the loss bits
    // of the same codec over in-process channels: drop, dup, reorder
    // and corrupt all land below the exactly-once ARQ, the codec
    // above it.
    const STEPS: usize = 3;
    let faults = ["drop:0.2", "dup:0.15", "reorder:0.15",
                  "corrupt:0.2"];
    for compress in ["f16", "topk:0.25"] {
        for (zero2, overlap) in [(false, false), (true, true)] {
            let (channel, _) = run(4, zero2, overlap, compress,
                                   TransportKind::Channel, STEPS, 2);
            for fault in faults {
                let (got, dist) = run(4, zero2, overlap, compress,
                                      sock(fault), STEPS, 2);
                assert_eq!(
                    bits(&channel), bits(&got),
                    "{compress} {fault} zero2={zero2} \
                     overlap={overlap}");
                // The lossy link shows up as retries, never as a
                // changed payload.
                let class = if compress == "f16" {
                    TrafficClass::CodecF16
                } else {
                    TrafficClass::CodecTopK
                };
                assert!(dist.stats().bytes(class) > 0, "{compress}");
            }
        }
    }
}
