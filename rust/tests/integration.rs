//! Integration tests over real AOT artifacts: runtime + coordinator +
//! optimizer equivalence across the host and fused (Pallas) paths.
//!
//! These need `make artifacts` to have run; they are skipped (with a
//! loud message) when the artifacts directory is absent so `cargo test`
//! stays usable on a fresh checkout.

use adam_mini::config::TrainConfig;
use adam_mini::coordinator::{load_checkpoint, save_checkpoint, Trainer};
use adam_mini::data::{Batcher, Corpus, SyntheticSpec};
use adam_mini::optim::{self, Optimizer};
use adam_mini::runtime::{manifest, Engine, ModelRuntime};

fn engine() -> Option<Engine> {
    match Engine::new(manifest::default_dir()) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("SKIPPING integration test (no artifacts): {e}");
            None
        }
    }
}

fn batch_for(rt: &ModelRuntime, seed: u64) -> adam_mini::data::Batch {
    let corpus = Corpus::synthetic(&SyntheticSpec {
        vocab: rt.mm.vocab,
        n_tokens: 4 * rt.mm.batch_size * rt.mm.seq_len + 64,
        seed,
        ..Default::default()
    });
    Batcher::new(corpus, rt.mm.batch_size, rt.mm.seq_len, seed)
        .next_batch()
}

#[test]
fn grad_artifact_loss_is_log_vocab_at_init() {
    let Some(engine) = engine() else { return };
    let rt = ModelRuntime::new(&engine, "t48k").unwrap();
    let params = rt.init_params(0);
    let batch = batch_for(&rt, 0);
    let (loss, grads) = rt.grad(&params, &batch).unwrap();
    let expect = (rt.mm.vocab as f32).ln();
    assert!((loss - expect).abs() < 0.3, "loss {loss} vs ln V {expect}");
    assert_eq!(grads.len(), params.len());
    for (g, p) in grads.iter().zip(&params) {
        assert_eq!(g.shape, p.shape);
    }
    let gn: f64 = grads.iter().map(|g| g.sq_norm()).sum();
    assert!(gn.is_finite() && gn > 0.0);
}

#[test]
fn eval_matches_grad_loss() {
    let Some(engine) = engine() else { return };
    let rt = ModelRuntime::new(&engine, "t48k").unwrap();
    let params = rt.init_params(1);
    let batch = batch_for(&rt, 1);
    let (loss_g, _) = rt.grad(&params, &batch).unwrap();
    let loss_e = rt.eval_loss(&params, &batch).unwrap();
    assert!((loss_g - loss_e).abs() < 1e-5, "{loss_g} vs {loss_e}");
}

/// HOST AdamW (pure Rust) must match the FUSED AdamW artifact (XLA
/// graph with the jnp-ref update) step for step.
#[test]
fn host_adamw_equals_fused_ref_artifact() {
    let Some(engine) = engine() else { return };
    let rt = ModelRuntime::new(&engine, "t295k").unwrap();
    let hp = engine.manifest.hyper();

    let mut p_host = rt.init_params(2);
    let mut host = optim::AdamW::new(hp, &p_host);
    let mut p_fused = p_host.clone();
    let mut fused = rt.fused("train_adamw_ref").unwrap();

    for step in 0..3 {
        let batch = batch_for(&rt, 100 + step);
        let lr = 1e-3;
        let (_, grads) = rt.grad(&p_host, &batch).unwrap();
        host.step(&mut p_host, &grads, lr);
        fused.step(&mut p_fused, &batch, lr).unwrap();
    }
    for (a, b) in p_host.iter().zip(&p_fused) {
        let d = a.max_abs_diff(b);
        assert!(d < 5e-4, "{}: host vs fused drift {d}", a.name);
    }
}

/// HOST Adam-mini must match the FUSED adam-mini artifact.
#[test]
fn host_adam_mini_equals_fused_ref_artifact() {
    let Some(engine) = engine() else { return };
    let rt = ModelRuntime::new(&engine, "t295k").unwrap();
    let hp = engine.manifest.hyper();

    let mut p_host = rt.init_params(3);
    let spec = rt
        .mm
        .meta()
        .spec_for(&p_host, adam_mini::partition::Strategy::Hessian)
        .unwrap();
    let mut host = optim::AdamMini::new(hp, spec, optim::ReduceOp::Mean);
    let mut p_fused = p_host.clone();
    let mut fused = rt.fused("train_adam_mini_ref").unwrap();

    for step in 0..3 {
        let batch = batch_for(&rt, 200 + step);
        let lr = 2e-3;
        let (_, grads) = rt.grad(&p_host, &batch).unwrap();
        host.step(&mut p_host, &grads, lr);
        fused.step(&mut p_fused, &batch, lr).unwrap();
    }
    for (a, b) in p_host.iter().zip(&p_fused) {
        let d = a.max_abs_diff(b);
        assert!(d < 5e-4, "{}: host vs fused drift {d}", a.name);
    }
}

/// The PALLAS-kernel fused step must match the jnp-ref fused step.
#[test]
fn pallas_fused_equals_ref_fused() {
    let Some(engine) = engine() else { return };
    let rt = ModelRuntime::new(&engine, "t295k").unwrap();
    let mut p_pal = rt.init_params(4);
    let mut p_ref = p_pal.clone();
    let mut pal = rt.fused("train_adam_mini").unwrap();
    let mut refe = rt.fused("train_adam_mini_ref").unwrap();
    for step in 0..2 {
        let batch = batch_for(&rt, 300 + step);
        let l1 = pal.step(&mut p_pal, &batch, 1e-3).unwrap();
        let l2 = refe.step(&mut p_ref, &batch, 1e-3).unwrap();
        assert!((l1 - l2).abs() < 1e-4, "loss {l1} vs {l2}");
    }
    for (a, b) in p_pal.iter().zip(&p_ref) {
        let d = a.max_abs_diff(b);
        assert!(d < 5e-4, "{}: pallas vs ref drift {d}", a.name);
    }
}

#[test]
fn training_reduces_loss_and_is_seed_deterministic() {
    let Some(engine) = engine() else { return };
    let cfg = TrainConfig {
        model: "t48k".into(),
        optimizer: "adam_mini".into(),
        steps: 60,
        peak_lr: 6e-3,
        eval_every: 30,
        log_every: 30,
        ..Default::default()
    };
    let run = |cfg: &TrainConfig| {
        let mut t = Trainer::from_config(&engine, cfg).unwrap();
        let h = t.train(true).unwrap();
        (h.steps[0].loss, h.final_train_loss())
    };
    let (first, last) = run(&cfg);
    assert!(last < 0.8 * first, "loss {first} -> {last}");
    // Same seed → identical trajectory.
    let (f2, l2) = run(&cfg);
    assert_eq!(first, f2);
    assert_eq!(last, l2);
    // Different seed → different numbers.
    let mut cfg2 = cfg.clone();
    cfg2.seed = 7;
    let (f3, _) = run(&cfg2);
    assert_ne!(first, f3);
}

#[test]
fn adam_mini_matches_adamw_loss_with_half_state() {
    let Some(engine) = engine() else { return };
    let mut finals = Vec::new();
    let mut states = Vec::new();
    for optimizer in ["adamw", "adam_mini"] {
        let cfg = TrainConfig {
            model: "t48k".into(),
            optimizer: optimizer.into(),
            steps: 120,
            peak_lr: 6e-3,
            eval_every: 60,
            log_every: 40,
            ..Default::default()
        };
        let mut t = Trainer::from_config(&engine, &cfg).unwrap();
        let h = t.train(true).unwrap();
        finals.push(h.final_val_loss());
        states.push(h.opt_state_bytes as f64);
    }
    // Paper headline at probe scale: on-par loss, ~half the state.
    assert!((finals[1] - finals[0]).abs() < 0.15,
            "val losses {finals:?}");
    assert!(states[1] < 0.6 * states[0], "state bytes {states:?}");
}

#[test]
fn checkpoint_roundtrip_through_trainer() {
    let Some(engine) = engine() else { return };
    let cfg = TrainConfig {
        model: "t48k".into(),
        optimizer: "adam_mini".into(),
        steps: 10,
        eval_every: 0,
        log_every: 5,
        ..Default::default()
    };
    let mut t = Trainer::from_config(&engine, &cfg).unwrap();
    t.train(true).unwrap();
    let path = std::env::temp_dir().join("amck_integ/params.bin");
    save_checkpoint(&path, &t.params).unwrap();
    let loaded = load_checkpoint(&path).unwrap();
    assert_eq!(loaded, t.params);
    std::fs::remove_dir_all(path.parent().unwrap()).ok();
}

#[test]
fn logits_artifact_consistent_with_eval_loss() {
    let Some(engine) = engine() else { return };
    let rt = ModelRuntime::new(&engine, "t48k").unwrap();
    let params = rt.init_params(5);
    let batch = batch_for(&rt, 5);
    let sampler =
        adam_mini::rlhf::Sampler::new(&engine, &rt).unwrap();
    let logits = sampler.logits(&params, &batch.tokens).unwrap();
    let (b, s, v) = (rt.mm.batch_size, rt.mm.seq_len, rt.mm.vocab);
    assert_eq!(logits.len(), b * s * v);
    // CE computed from logits must match the eval artifact.
    let mut total = 0.0f64;
    for row in 0..b {
        for pos in 0..s {
            let off = (row * s + pos) * v;
            let slice = &logits[off..off + v];
            let mx = slice.iter().cloned().fold(f32::MIN, f32::max);
            let lse = slice.iter().map(|x| (x - mx).exp()).sum::<f32>()
                .ln() + mx;
            let tgt = batch.targets[row * s + pos] as usize;
            total += (lse - slice[tgt]) as f64;
        }
    }
    let ce = total / (b * s) as f64;
    let eval = rt.eval_loss(&params, &batch).unwrap() as f64;
    assert!((ce - eval).abs() < 1e-4, "{ce} vs {eval}");
}

#[test]
fn greedy_sampling_is_deterministic() {
    let Some(engine) = engine() else { return };
    let rt = ModelRuntime::new(&engine, "t48k").unwrap();
    let params = rt.init_params(6);
    let sampler = adam_mini::rlhf::Sampler::new(&engine, &rt).unwrap();
    let batch = batch_for(&rt, 6);
    let mut rng = adam_mini::util::prng::Rng::new(0);
    let a = sampler
        .complete(&params, &batch.tokens, 32, 0.0, &mut rng)
        .unwrap();
    let b = sampler
        .complete(&params, &batch.tokens, 32, 0.0, &mut rng)
        .unwrap();
    assert_eq!(a, b);
    // Prompt region untouched.
    let s = rt.mm.seq_len;
    for row in 0..rt.mm.batch_size {
        assert_eq!(&a[row * s..row * s + 32],
                   &batch.tokens[row * s..row * s + 32]);
    }
}

#[test]
fn fused_grad_accum_host_path_works() {
    let Some(engine) = engine() else { return };
    let cfg = TrainConfig {
        model: "t48k".into(),
        optimizer: "adamw".into(),
        steps: 8,
        grad_accum: 2,
        eval_every: 0,
        log_every: 4,
        ..Default::default()
    };
    let mut t = Trainer::from_config(&engine, &cfg).unwrap();
    let h = t.train(true).unwrap();
    assert!(h.final_train_loss().is_finite());
}
