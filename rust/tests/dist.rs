//! Integration tests for the `dist` data-parallel engine.
//!
//! The core invariant: an N-worker run with the same global batch and
//! seed matches the 1-worker run's loss curve to float tolerance. The
//! artifact-free tests drive a self-contained bigram language model
//! over the synthetic corpus (analytic gradients, no XLA), so they run
//! on a fresh checkout; the final test exercises the full coordinator
//! wiring when AOT artifacts are present (skipped loudly otherwise).

use adam_mini::config::TrainConfig;
use adam_mini::coordinator::Trainer;
use adam_mini::data::{Batch, Batcher, Corpus, SyntheticSpec};
use adam_mini::dist::{DistOptions, DistTrainer, TrafficClass};
use adam_mini::optim::{by_name, Hyper, ModelMeta, ReduceOp};
use adam_mini::partition::Strategy;
use adam_mini::runtime::{manifest, Engine};
use adam_mini::tensor::Tensor;
use adam_mini::util::prng::Rng;

const VOCAB: usize = 32;

/// Bigram LM: logits for position t are row `tokens[t]` of a
/// (vocab, vocab) table. Mean CE loss, analytic gradient — the
/// smallest model with a real Adam-mini partition (one Hessian block
/// per token row).
struct Bigram;

impl Bigram {
    fn init(seed: u64) -> Vec<Tensor> {
        let mut rng = Rng::new(seed);
        vec![Tensor::randn("embed", &[VOCAB, VOCAB], 0.1, &mut rng)]
    }

    fn meta() -> ModelMeta {
        ModelMeta { n_heads: 1, stacked: vec![] }
    }

    /// (mean loss, grad) over one batch.
    fn loss_grad(params: &[Tensor], batch: &Batch) -> (f32, Vec<Tensor>) {
        let w = &params[0];
        let mut grad = Tensor::zeros("embed", &[VOCAB, VOCAB]);
        let n = batch.tokens.len();
        let inv = 1.0 / n as f32;
        let mut total = 0.0f64;
        for (&tok, &tgt) in batch.tokens.iter().zip(&batch.targets) {
            let (tok, tgt) = (tok as usize, tgt as usize);
            let row = &w.data[tok * VOCAB..(tok + 1) * VOCAB];
            let mx = row.iter().cloned().fold(f32::MIN, f32::max);
            let exps: Vec<f32> =
                row.iter().map(|x| (x - mx).exp()).collect();
            let z: f32 = exps.iter().sum();
            total += (z.ln() + mx - row[tgt]) as f64;
            let grow = &mut grad.data[tok * VOCAB..(tok + 1) * VOCAB];
            for (c, e) in grow.iter_mut().zip(&exps) {
                *c += e / z * inv;
            }
            grow[tgt] -= inv;
        }
        ((total * inv as f64) as f32, vec![grad])
    }
}

fn corpus_batcher(seed: u64) -> Batcher {
    let corpus = Corpus::synthetic(&SyntheticSpec {
        vocab: VOCAB,
        n_tokens: 20_000,
        seed: seed ^ 0xDA7A,
        ..Default::default()
    });
    Batcher::new(corpus, 4, 16, seed)
}

fn mini_spec(params: &[Tensor])
    -> Vec<adam_mini::partition::BlockView> {
    Bigram::meta().spec_for(params, Strategy::Hessian).unwrap()
}

/// Reference: single-replica host optimizer, `micro` micro-batches per
/// step summed then averaged (the coordinator's host-path semantics).
fn run_host(optimizer: &str, steps: usize, micro: usize) -> Vec<f32> {
    let mut params = Bigram::init(1);
    let mut opt = by_name(optimizer, Hyper::default(), &params,
                          &Bigram::meta()).unwrap();
    let mut batcher = corpus_batcher(9);
    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        let mut total = 0.0;
        let mut acc = Tensor::zeros("embed", &[VOCAB, VOCAB]);
        for _ in 0..micro {
            let batch = batcher.next_batch();
            let (loss, g) = Bigram::loss_grad(&params, &batch);
            total += loss;
            acc.axpy(1.0, &g[0]);
        }
        let inv = 1.0 / micro as f32;
        for x in acc.data.iter_mut() {
            *x *= inv;
        }
        opt.step(&mut params, std::slice::from_ref(&acc), 2e-2);
        losses.push(total / micro as f32);
    }
    losses
}

/// N-worker ZeRO-1 run over the SAME batch stream (micro-batch i of a
/// step goes to worker i % N).
fn run_dist(optimizer: &str, workers: usize, steps: usize, micro: usize)
    -> Vec<f32> {
    let mut params = Bigram::init(1);
    let spec = if optimizer.starts_with("adam_mini") {
        Some(mini_spec(&params))
    } else {
        None
    };
    let mut dist = DistTrainer::new(&params, DistOptions {
        workers,
        bucket_kb: 1,
        zero1: true,
        optimizer: optimizer.into(),
        reduce: ReduceOp::Mean,
        spec,
        ..Default::default()
    }).unwrap();
    let mut batcher = corpus_batcher(9);
    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        let mut total = 0.0;
        let mut local = dist.grad_buffers();
        for i in 0..micro {
            let batch = batcher.next_batch();
            let (loss, g) = Bigram::loss_grad(&params, &batch);
            total += loss;
            dist.layout().accumulate(&mut local[i % workers], &g);
        }
        dist.step(&mut params, local, micro, 2e-2).unwrap();
        losses.push(total / micro as f32);
    }
    losses
}

#[test]
fn bigram_model_learns() {
    let losses = run_host("adam_mini", 60, 1);
    assert!(losses[59] < 0.8 * losses[0],
            "loss {} -> {}", losses[0], losses[59]);
}

#[test]
fn n_worker_loss_curve_matches_single_worker() {
    for optimizer in ["adamw", "adam_mini"] {
        let reference = run_host(optimizer, 40, 6);
        for workers in [2usize, 3] {
            let got = run_dist(optimizer, workers, 40, 6);
            for (step, (a, b)) in
                reference.iter().zip(&got).enumerate()
            {
                assert!((a - b).abs() < 1e-4,
                        "{optimizer} x{workers} step {step}: {a} vs {b}");
            }
            let (la, lb) = (reference[39], got[39]);
            assert!((la - lb).abs() < 1e-4,
                    "{optimizer} x{workers}: final {la} vs {lb}");
        }
    }
}

#[test]
fn idle_workers_change_nothing_bitwise() {
    // One global micro-batch, four workers: three workers idle; the
    // run must be bit-identical to the single-worker run.
    for optimizer in ["adamw", "adam_mini"] {
        let reference = run_host(optimizer, 25, 1);
        let got = run_dist(optimizer, 4, 25, 1);
        assert_eq!(reference, got, "{optimizer}");
    }
}

#[test]
fn adam_mini_moves_fewer_state_sync_bytes_than_adamw() {
    let measure = |optimizer: &str| {
        let mut params = Bigram::init(2);
        let spec = if optimizer.starts_with("adam_mini") {
            Some(mini_spec(&params))
        } else {
            None
        };
        let mut dist = DistTrainer::new(&params, DistOptions {
            workers: 4,
            optimizer: optimizer.into(),
            spec,
            ..Default::default()
        }).unwrap();
        let mut batcher = corpus_batcher(3);
        let mut local = dist.grad_buffers();
        let batch = batcher.next_batch();
        let (_, g) = Bigram::loss_grad(&params, &batch);
        dist.layout().accumulate(&mut local[0], &g);
        dist.step(&mut params, local, 1, 1e-2).unwrap();
        dist.sync_state().unwrap();
        (dist.stats().bytes(TrafficClass::StateSync),
         dist.stats().bytes(TrafficClass::GradReduce))
    };
    let (aw_sync, aw_grad) = measure("adamw");
    let (am_sync, am_grad) = measure("adam_mini");
    // Same gradient traffic, strictly fewer state-sync bytes — the
    // paper's communication argument, measured.
    assert_eq!(aw_grad, am_grad);
    assert!(am_sync < aw_sync,
            "adam_mini {am_sync} vs adamw {aw_sync}");
    // And close to half: v_b is one scalar per token row.
    let ratio = am_sync as f64 / aw_sync as f64;
    assert!(ratio < 0.6, "state-sync ratio {ratio}");
}

/// Full coordinator wiring over real AOT artifacts (skipped without
/// them, same convention as tests/integration.rs).
#[test]
fn coordinator_dist_run_matches_host_run() {
    let engine = match Engine::new(manifest::default_dir()) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("SKIPPING dist coordinator test (no artifacts): \
                       {e}");
            return;
        }
    };
    let base = TrainConfig {
        model: "t48k".into(),
        optimizer: "adam_mini".into(),
        steps: 30,
        peak_lr: 6e-3,
        eval_every: 0,
        log_every: 10,
        ..Default::default()
    };
    let run = |workers: usize| {
        let mut cfg = base.clone();
        cfg.workers = workers;
        let mut t = Trainer::from_config(&engine, &cfg).unwrap();
        let h = t.train(true).unwrap();
        h.final_train_loss()
    };
    let solo = run(1);
    let quad = run(4);
    assert!((solo - quad).abs() < 1e-4,
            "workers=1 {solo} vs workers=4 {quad}");
}

/// Trainer-level checkpoint round-trip across the Host and Dist
/// (ZeRO-1 sharded) mode dispatch (skipped without artifacts).
#[test]
fn trainer_run_checkpoint_roundtrips_host_and_dist() {
    let engine = match Engine::new(manifest::default_dir()) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("SKIPPING trainer checkpoint test (no artifacts): \
                       {e}");
            return;
        }
    };
    for workers in [1usize, 3] {
        let cfg = TrainConfig {
            model: "t48k".into(),
            optimizer: "adam_mini".into(),
            steps: 8,
            eval_every: 0,
            log_every: 4,
            workers,
            ..Default::default()
        };
        let path = std::env::temp_dir()
            .join(format!("amck_dist/run_w{workers}.bin"));
        let mut a = Trainer::from_config(&engine, &cfg).unwrap();
        a.train(true).unwrap();
        a.save_run_checkpoint(&path).unwrap();
        // Two fresh trainers restored from the same checkpoint must
        // agree exactly — params and the next optimizer step.
        let mut b = Trainer::from_config(&engine, &cfg).unwrap();
        b.load_run_checkpoint(&path).unwrap();
        assert_eq!(b.params, a.params, "workers={workers}");
        let mut c = Trainer::from_config(&engine, &cfg).unwrap();
        c.load_run_checkpoint(&path).unwrap();
        let lb = b.step_once().unwrap();
        let lc = c.step_once().unwrap();
        assert_eq!(lb, lc, "workers={workers}");
        assert_eq!(b.params, c.params, "workers={workers}");
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }
}
