//! Integration tests for the `dist` data-parallel engine.
//!
//! The core invariant: an N-worker run with the same global batch and
//! seed matches the 1-worker run's loss curve to float tolerance — in
//! every (gradient schedule × pipeline) combination: ZeRO-1
//! all-reduce vs ZeRO-2 reduce-scatter, batch-synchronous vs
//! streaming overlap. The artifact-free tests drive a self-contained
//! bigram language model over the synthetic corpus (analytic
//! gradients, no XLA), so they run on a fresh checkout; the final
//! tests exercise the full coordinator wiring when AOT artifacts are
//! present (skipped loudly otherwise).

use adam_mini::config::TrainConfig;
use adam_mini::coordinator::checkpoint::{load_run, save_run};
use adam_mini::coordinator::Trainer;
use adam_mini::data::{Batch, Batcher, Corpus, SyntheticSpec};
use adam_mini::dist::{probe_params, DistOptions, DistTrainer,
                      TrafficClass};
use adam_mini::optim::{by_name, Hyper, ModelMeta, ReduceOp};
use adam_mini::partition::Strategy;
use adam_mini::runtime::{manifest, Engine};
use adam_mini::tensor::Tensor;
use adam_mini::util::prng::Rng;

const VOCAB: usize = 32;

/// Bigram LM: logits for position t are row `tokens[t]` of a
/// (vocab, vocab) table. Mean CE loss, analytic gradient — the
/// smallest model with a real Adam-mini partition (one Hessian block
/// per token row).
struct Bigram;

impl Bigram {
    fn init(seed: u64) -> Vec<Tensor> {
        let mut rng = Rng::new(seed);
        vec![Tensor::randn("embed", &[VOCAB, VOCAB], 0.1, &mut rng)]
    }

    fn meta() -> ModelMeta {
        ModelMeta { n_heads: 1, stacked: vec![] }
    }

    /// (mean loss, grad) over one batch.
    fn loss_grad(params: &[Tensor], batch: &Batch) -> (f32, Vec<Tensor>) {
        let w = &params[0];
        let mut grad = Tensor::zeros("embed", &[VOCAB, VOCAB]);
        let n = batch.tokens.len();
        let inv = 1.0 / n as f32;
        let mut total = 0.0f64;
        for (&tok, &tgt) in batch.tokens.iter().zip(&batch.targets) {
            let (tok, tgt) = (tok as usize, tgt as usize);
            let row = &w.data[tok * VOCAB..(tok + 1) * VOCAB];
            let mx = row.iter().cloned().fold(f32::MIN, f32::max);
            let exps: Vec<f32> =
                row.iter().map(|x| (x - mx).exp()).collect();
            let z: f32 = exps.iter().sum();
            total += (z.ln() + mx - row[tgt]) as f64;
            let grow = &mut grad.data[tok * VOCAB..(tok + 1) * VOCAB];
            for (c, e) in grow.iter_mut().zip(&exps) {
                *c += e / z * inv;
            }
            grow[tgt] -= inv;
        }
        ((total * inv as f64) as f32, vec![grad])
    }
}

fn corpus_batcher(seed: u64) -> Batcher {
    let corpus = Corpus::synthetic(&SyntheticSpec {
        vocab: VOCAB,
        n_tokens: 20_000,
        seed: seed ^ 0xDA7A,
        ..Default::default()
    });
    Batcher::new(corpus, 4, 16, seed)
}

fn mini_spec(params: &[Tensor])
    -> Vec<adam_mini::partition::BlockView> {
    Bigram::meta().spec_for(params, Strategy::Hessian).unwrap()
}

/// Reference: single-replica host optimizer, `micro` micro-batches per
/// step summed then averaged (the coordinator's host-path semantics).
fn run_host(optimizer: &str, steps: usize, micro: usize) -> Vec<f32> {
    let mut params = Bigram::init(1);
    let mut opt = by_name(optimizer, Hyper::default(), &params,
                          &Bigram::meta()).unwrap();
    let mut batcher = corpus_batcher(9);
    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        let mut total = 0.0;
        let mut acc = Tensor::zeros("embed", &[VOCAB, VOCAB]);
        for _ in 0..micro {
            let batch = batcher.next_batch();
            let (loss, g) = Bigram::loss_grad(&params, &batch);
            total += loss;
            acc.axpy(1.0, &g[0]);
        }
        let inv = 1.0 / micro as f32;
        for x in acc.data.iter_mut() {
            *x *= inv;
        }
        opt.step(&mut params, std::slice::from_ref(&acc), 2e-2);
        losses.push(total / micro as f32);
    }
    losses
}

fn bigram_options(optimizer: &str, workers: usize, zero2: bool,
                  spec: Option<Vec<adam_mini::partition::BlockView>>)
    -> DistOptions {
    DistOptions {
        workers,
        bucket_kb: 1,
        zero1: true,
        zero2,
        optimizer: optimizer.into(),
        reduce: ReduceOp::Mean,
        spec,
        ..Default::default()
    }
}

/// N-worker sharded run over the SAME batch stream (micro-batch i of
/// a step goes to worker i % N). `zero2` picks the gradient schedule;
/// `overlap` routes through the streaming bucket pipeline.
fn run_dist(optimizer: &str, workers: usize, zero2: bool, overlap: bool,
            steps: usize, micro: usize) -> Vec<f32> {
    let mut params = Bigram::init(1);
    let spec = if optimizer.starts_with("adam_mini") {
        Some(mini_spec(&params))
    } else {
        None
    };
    let mut dist = DistTrainer::new(
        &params, bigram_options(optimizer, workers, zero2, spec))
        .unwrap();
    let mut batcher = corpus_batcher(9);
    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        let mut total = 0.0;
        if overlap {
            let mut stream = dist.begin_step(micro, 2e-2);
            for i in 0..micro {
                let batch = batcher.next_batch();
                let (loss, g) = Bigram::loss_grad(&params, &batch);
                total += loss;
                stream.push_grad(i, 0, &g[0]).unwrap();
            }
            stream.finish(&mut params).unwrap();
        } else {
            let mut local = dist.grad_buffers();
            for i in 0..micro {
                let batch = batcher.next_batch();
                let (loss, g) = Bigram::loss_grad(&params, &batch);
                total += loss;
                dist.layout().accumulate(&mut local[i % workers], &g);
            }
            dist.step(&mut params, local, micro, 2e-2).unwrap();
        }
        losses.push(total / micro as f32);
    }
    losses
}

#[test]
fn bigram_model_learns() {
    let losses = run_host("adam_mini", 60, 1);
    assert!(losses[59] < 0.8 * losses[0],
            "loss {} -> {}", losses[0], losses[59]);
}

#[test]
fn n_worker_loss_curve_matches_single_worker() {
    // Every (schedule × pipeline) combination must track the host
    // run's loss curve: overlap and gradient sharding change the
    // communication schedule, never the math.
    for optimizer in ["adamw", "adam_mini"] {
        let reference = run_host(optimizer, 40, 6);
        for workers in [2usize, 3] {
            for zero2 in [false, true] {
                for overlap in [false, true] {
                    let got = run_dist(optimizer, workers, zero2,
                                       overlap, 40, 6);
                    for (step, (a, b)) in
                        reference.iter().zip(&got).enumerate()
                    {
                        assert!((a - b).abs() < 1e-4,
                                "{optimizer} x{workers} zero2={zero2} \
                                 overlap={overlap} step {step}: \
                                 {a} vs {b}");
                    }
                }
            }
        }
    }
}

#[test]
fn idle_workers_change_nothing_bitwise() {
    // One global micro-batch, four workers: three workers idle; the
    // run must be bit-identical to the single-worker run in all four
    // (overlap × zero2) mode combinations — idle ranks contribute
    // exact zeros through reduce-scatter just as through all-reduce.
    for optimizer in ["adamw", "adam_mini"] {
        let reference = run_host(optimizer, 25, 1);
        for zero2 in [false, true] {
            for overlap in [false, true] {
                let got = run_dist(optimizer, 4, zero2, overlap, 25, 1);
                assert_eq!(reference, got,
                           "{optimizer} zero2={zero2} \
                            overlap={overlap}");
            }
        }
    }
}

#[test]
fn adam_mini_moves_fewer_state_sync_bytes_than_adamw() {
    let measure = |optimizer: &str| {
        let mut params = Bigram::init(2);
        let spec = if optimizer.starts_with("adam_mini") {
            Some(mini_spec(&params))
        } else {
            None
        };
        let mut dist = DistTrainer::new(&params, DistOptions {
            workers: 4,
            optimizer: optimizer.into(),
            spec,
            ..Default::default()
        }).unwrap();
        let mut batcher = corpus_batcher(3);
        let mut local = dist.grad_buffers();
        let batch = batcher.next_batch();
        let (_, g) = Bigram::loss_grad(&params, &batch);
        dist.layout().accumulate(&mut local[0], &g);
        dist.step(&mut params, local, 1, 1e-2).unwrap();
        dist.sync_state().unwrap();
        (dist.stats().bytes(TrafficClass::StateSync),
         dist.stats().bytes(TrafficClass::GradReduce))
    };
    let (aw_sync, aw_grad) = measure("adamw");
    let (am_sync, am_grad) = measure("adam_mini");
    // Same gradient traffic, strictly fewer state-sync bytes — the
    // paper's communication argument, measured.
    assert_eq!(aw_grad, am_grad);
    assert!(am_sync < aw_sync,
            "adam_mini {am_sync} vs adamw {aw_sync}");
    // And close to half: v_b is one scalar per token row.
    let ratio = am_sync as f64 / aw_sync as f64;
    assert!(ratio < 0.6, "state-sync ratio {ratio}");
}

#[test]
fn overlapped_pipeline_is_faster_on_the_simulated_link() {
    // The PR-2 claim, still held: at workers >= 4 the streamed
    // bucket pipeline's modeled wall clock is strictly below the
    // batch-synchronous schedule derived from the SAME step's events —
    // for both gradient schedules.
    let (params, _) = probe_params(0xBEEF);
    for zero2 in [false, true] {
        let mut params = params.clone();
        let mut dist = DistTrainer::new(&params, DistOptions {
            workers: 4,
            bucket_kb: 64,
            zero1: true,
            zero2,
            optimizer: "adamw".into(),
            ..Default::default()
        }).unwrap();
        assert!(dist.plan().len() > 4,
                "probe inventory should carve many buckets");
        let mut rng = Rng::new(41);
        let grads: Vec<Tensor> = params
            .iter()
            .map(|p| Tensor::randn(&*p.name, &p.shape, 0.01, &mut rng))
            .collect();
        let mut stream = dist.begin_step(1, 1e-4);
        for j in (0..grads.len()).rev() {
            stream.push_grad(0, j, &grads[j]).unwrap();
        }
        stream.finish(&mut params).unwrap();
        let t = dist.last_step_timing().unwrap();
        assert!(t.overlapped_ns < t.sequential_ns,
                "zero2={zero2}: overlapped {:.0} !< sequential {:.0}",
                t.overlapped_ns, t.sequential_ns);
        assert!(t.speedup() > 1.0, "zero2={zero2}");
    }
}

#[test]
fn bucket_granular_stepping_shortens_the_critical_path() {
    // The tentpole claim, measured at workers = 4 on the probe
    // inventory: stepping each bucket's shard the moment its
    // reduce-scatter lands (and launching that bucket's all-gather
    // immediately) strictly beats stepping after the LAST
    // reduce-scatter — both against the same step's modeled deferred
    // schedule and against an actual bucket_step=false run. Adam-mini
    // exercises the block-aligned carve; AdamW the elementwise path.
    for optimizer in ["adamw", "adam_mini"] {
        let run = |bucket_step: bool| {
            let (mut params, _n) = probe_params(0xBEEF);
            let spec = if optimizer.starts_with("adam_mini") {
                let shapes: Vec<(String, Vec<usize>)> = params
                    .iter()
                    .map(|p| (p.name.clone(), p.shape.clone()))
                    .collect();
                let meta = adam_mini::dist::probe_meta();
                Some(adam_mini::partition::partition_spec(
                    &shapes, meta.n_heads, &meta.stacked,
                    Strategy::Hessian).unwrap())
            } else {
                None
            };
            let mut dist = DistTrainer::new(&params, DistOptions {
                workers: 4,
                bucket_kb: 64,
                zero1: true,
                zero2: true,
                bucket_step,
                optimizer: optimizer.into(),
                spec,
                ..Default::default()
            }).unwrap();
            assert_eq!(dist.granular(), bucket_step,
                       "{optimizer}: granular mode gate");
            let mut rng = Rng::new(41);
            let grads: Vec<Tensor> = params
                .iter()
                .map(|p| {
                    Tensor::randn(&*p.name, &p.shape, 0.01, &mut rng)
                })
                .collect();
            let mut stream = dist.begin_step(1, 1e-4);
            for j in (0..grads.len()).rev() {
                stream.push_grad(0, j, &grads[j]).unwrap();
            }
            stream.finish(&mut params).unwrap();
            (dist.last_step_timing().unwrap(), params)
        };
        let (granular, params_on) = run(true);
        let (deferred, params_off) = run(false);
        // Same math, bit-identical parameters.
        assert_eq!(params_on, params_off, "{optimizer}");
        // Within one run: live bucket-granular schedule strictly
        // beats its own deferred-step comparator.
        assert!(granular.overlapped_ns < granular.deferred_ns,
                "{optimizer}: granular {:.0} !< deferred {:.0}",
                granular.overlapped_ns, granular.deferred_ns);
        assert!(granular.granular_gain() > 1.0, "{optimizer}");
        // Across runs: the bucket_step=false pipeline's live clock IS
        // the deferred schedule — and the granular run beats it.
        assert!((deferred.overlapped_ns - deferred.deferred_ns).abs()
                    < 1e-6,
                "{optimizer}: deferred run should have no gain");
        assert!(granular.overlapped_ns < deferred.overlapped_ns,
                "{optimizer}: granular {:.0} !< bucket_step=false \
                 {:.0}", granular.overlapped_ns,
                deferred.overlapped_ns);
    }
}

#[test]
fn streamed_zero2_traffic_matches_closed_forms() {
    // One streamed ZeRO-2 step: reduce-scatter moves (N−1)·P bytes in
    // its own class, the param all-gather (N−1)·P in its class, and
    // the all-reduce class stays at exactly zero (the double-count
    // guard).
    let mut params = Bigram::init(7);
    let flat_bytes = (VOCAB * VOCAB * 4) as u64;
    let mut dist = DistTrainer::new(
        &params, bigram_options("adamw", 4, true, None)).unwrap();
    let mut batcher = corpus_batcher(5);
    let batch = batcher.next_batch();
    let (_, g) = Bigram::loss_grad(&params, &batch);
    let mut stream = dist.begin_step(1, 1e-2);
    stream.push_grad(0, 0, &g[0]).unwrap();
    stream.finish(&mut params).unwrap();
    let stats = dist.stats();
    assert_eq!(stats.bytes(TrafficClass::GradReduce), 0);
    assert_eq!(stats.bytes(TrafficClass::GradScatter), 3 * flat_bytes);
    assert_eq!(stats.bytes(TrafficClass::ParamGather), 3 * flat_bytes);
}

#[test]
fn zero2_sharded_state_resumes_through_run_checkpoint() {
    // save_run/load_run round-trips the per-worker shard optimizer
    // state of a ZeRO-2 run: a fresh engine restored from the file
    // continues bit-identically to the original.
    let spec = mini_spec(&Bigram::init(1));
    let make = |params: &[Tensor]| {
        DistTrainer::new(params, bigram_options(
            "adam_mini", 3, true, Some(spec.clone()))).unwrap()
    };
    let mut params = Bigram::init(1);
    let mut a = make(&params);
    let mut batcher = corpus_batcher(11);
    let mut step = |d: &mut DistTrainer, p: &mut Vec<Tensor>,
                    b: &mut Batcher| {
        let batch = b.next_batch();
        let (_, g) = Bigram::loss_grad(p, &batch);
        let mut stream = d.begin_step(1, 2e-2);
        stream.push_grad(0, 0, &g[0]).unwrap();
        stream.finish(p).unwrap();
    };
    for _ in 0..3 {
        step(&mut a, &mut params, &mut batcher);
    }
    let state = a.sync_state().unwrap();
    assert!(state.keys().all(|k| k.starts_with("rank")),
            "ZeRO state entries carry rank routing prefixes");
    let path = std::env::temp_dir().join("amck_zero2/run.bin");
    save_run(&path, &params, &state).unwrap();
    let (params_b, state_b) = load_run(&path).unwrap();
    assert_eq!(state_b, state, "named state survives the container");
    let mut params_b = params_b;
    assert_eq!(params_b, params);
    let mut b = make(&params_b);
    b.import_state(&state_b).unwrap();
    // Both engines consume the same continuation stream.
    let mut batcher_b = batcher.clone();
    step(&mut a, &mut params, &mut batcher);
    step(&mut b, &mut params_b, &mut batcher_b);
    assert_eq!(params, params_b);
    std::fs::remove_dir_all(path.parent().unwrap()).ok();
}

/// Full coordinator wiring over real AOT artifacts (skipped without
/// them, same convention as tests/integration.rs).
#[test]
fn coordinator_dist_run_matches_host_run() {
    let engine = match Engine::new(manifest::default_dir()) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("SKIPPING dist coordinator test (no artifacts): \
                       {e}");
            return;
        }
    };
    let base = TrainConfig {
        model: "t48k".into(),
        optimizer: "adam_mini".into(),
        steps: 30,
        peak_lr: 6e-3,
        eval_every: 0,
        log_every: 10,
        ..Default::default()
    };
    let run = |workers: usize, zero2: bool, overlap: bool| {
        let mut cfg = base.clone();
        cfg.workers = workers;
        cfg.zero2 = zero2;
        cfg.overlap = overlap;
        let mut t = Trainer::from_config(&engine, &cfg).unwrap();
        let h = t.train(true).unwrap();
        h.final_train_loss()
    };
    let solo = run(1, false, false);
    for (zero2, overlap) in
        [(false, false), (true, false), (false, true), (true, true)]
    {
        let quad = run(4, zero2, overlap);
        assert!((solo - quad).abs() < 1e-4,
                "workers=1 {solo} vs workers=4 {quad} \
                 (zero2={zero2} overlap={overlap})");
    }
}

/// Trainer-level checkpoint round-trip across the Host and Dist
/// (ZeRO-1 sharded) mode dispatch (skipped without artifacts).
#[test]
fn trainer_run_checkpoint_roundtrips_host_and_dist() {
    let engine = match Engine::new(manifest::default_dir()) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("SKIPPING trainer checkpoint test (no artifacts): \
                       {e}");
            return;
        }
    };
    for (workers, zero2) in [(1usize, false), (3, false), (3, true)] {
        let cfg = TrainConfig {
            model: "t48k".into(),
            optimizer: "adam_mini".into(),
            steps: 8,
            eval_every: 0,
            log_every: 4,
            workers,
            zero2,
            ..Default::default()
        };
        let path = std::env::temp_dir()
            .join(format!("amck_dist/run_w{workers}_z{zero2}.bin"));
        let mut a = Trainer::from_config(&engine, &cfg).unwrap();
        a.train(true).unwrap();
        a.save_run_checkpoint(&path).unwrap();
        // Two fresh trainers restored from the same checkpoint must
        // agree exactly — params and the next optimizer step.
        let mut b = Trainer::from_config(&engine, &cfg).unwrap();
        b.load_run_checkpoint(&path).unwrap();
        assert_eq!(b.params, a.params, "workers={workers}");
        let mut c = Trainer::from_config(&engine, &cfg).unwrap();
        c.load_run_checkpoint(&path).unwrap();
        let lb = b.step_once().unwrap();
        let lc = c.step_once().unwrap();
        assert_eq!(lb, lc, "workers={workers}");
        assert_eq!(b.params, c.params, "workers={workers}");
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }
}
