//! Contract tests for the block-granular optimizer core
//! (`optim::core`) across the FULL roster:
//!
//! 1. **Segment partitioning** — one model step executed as
//!    `begin_step` + `step_segment` over an arbitrary (shuffled)
//!    partition of the arena is bit-identical to the whole-model
//!    `step`, for every roster member, with partition boundaries drawn
//!    from the optimizer's own `segment_cuts` (any boundary for
//!    elementwise members). This is the invariant the ZeRO-2
//!    bucket-granular streaming pipeline rests on.
//! 2. **StateDict round trip** — export → import into a fresh
//!    instance → identical continued trajectory, for every member
//!    (not just AdamW/Adam-mini), plus arity/key checking (a truncated
//!    dict is a loud error, never a silent drop).

use std::sync::Arc;

use adam_mini::optim::{self, by_name, GradView, Granularity, Hyper,
                       ModelMeta, Optimizer, ParamView, StateDict};
use adam_mini::tensor::Tensor;
use adam_mini::util::prng::Rng;

/// Mixed inventory: a matrix big enough for GaLore's projected path
/// and Adafactor's factored path, a stacked 3-D attention tensor, a
/// stacked norm, and a bare vector.
fn toy() -> (Vec<Tensor>, ModelMeta) {
    let mut rng = Rng::new(7);
    let params = vec![
        Tensor::randn("embed", &[16, 12], 0.5, &mut rng),
        Tensor::randn("wq", &[2, 4, 4], 0.5, &mut rng),
        Tensor::randn("attn_norm", &[2, 4], 0.5, &mut rng),
        Tensor::randn("final_norm", &[5], 0.5, &mut rng),
    ];
    let meta = ModelMeta {
        n_heads: 2,
        stacked: vec!["wq".into(), "attn_norm".into()],
    };
    (params, meta)
}

fn rand_grads(params: &[Tensor], rng: &mut Rng) -> Vec<Tensor> {
    params
        .iter()
        .map(|p| Tensor::randn(&*p.name, &p.shape, 0.5, rng))
        .collect()
}

/// A random disjoint partition of `[0, total)` honoring `cuts`
/// (`None` = any boundary), in shuffled application order.
fn random_partition(cuts: Option<Vec<usize>>, total: usize,
                    rng: &mut Rng) -> Vec<(usize, usize)> {
    let candidates: Vec<usize> = match cuts {
        None => (1..total).collect(),
        Some(c) => {
            c.into_iter().filter(|&x| x > 0 && x < total).collect()
        }
    };
    let mut chosen: Vec<usize> = candidates
        .into_iter()
        .filter(|_| rng.below(3) == 0)
        .collect();
    chosen.push(0);
    chosen.push(total);
    chosen.sort_unstable();
    chosen.dedup();
    let mut segs: Vec<(usize, usize)> =
        chosen.windows(2).map(|w| (w[0], w[1])).collect();
    rng.shuffle(&mut segs);
    segs
}

#[test]
fn arbitrary_segment_partitions_match_whole_step_for_roster() {
    let (params0, meta) = toy();
    for name in optim::ROSTER {
        let mut rng = Rng::new(0xC0FFEE);
        let mut pa = params0.clone();
        let mut a =
            by_name(name, Hyper::default(), &pa, &meta).unwrap();
        let mut b =
            by_name(name, Hyper::default(), &params0, &meta).unwrap();
        let arena = Arc::clone(b.arena());
        let mut flat = arena.flatten(&params0);
        for _step in 0..5 {
            let grads = rand_grads(&pa, &mut rng);
            a.step(&mut pa, &grads, 1e-2);
            let gflat = arena.flatten(&grads);
            let segs = random_partition(b.segment_cuts(), arena.total,
                                        &mut rng);
            assert!(!segs.is_empty(), "{name}");
            b.begin_step();
            for (lo, hi) in segs {
                b.step_segment(
                    ParamView::new(lo, &mut flat[lo..hi]),
                    GradView::new(lo, &gflat[lo..hi]), 1e-2);
            }
        }
        let mut pb = params0.clone();
        arena.unflatten(&flat, &mut pb);
        assert_eq!(pa, pb, "{name}: segment partition diverged");
    }
}

#[test]
fn segment_cuts_are_consistent_with_granularity() {
    let (params, meta) = toy();
    for name in optim::ROSTER {
        let opt =
            by_name(name, Hyper::default(), &params, &meta).unwrap();
        let total = opt.arena().total;
        match opt.segment_cuts() {
            None => assert_eq!(opt.granularity(), Granularity::Element,
                               "{name}: only elementwise updates may \
                                accept arbitrary boundaries"),
            Some(cuts) => {
                assert!(cuts.windows(2).all(|w| w[0] < w[1]),
                        "{name}: cuts must be strictly sorted");
                assert_eq!(cuts.first(), Some(&0), "{name}");
                assert_eq!(cuts.last(), Some(&total), "{name}");
                // Every tensor boundary is a valid cut (a segment can
                // always stop at a span edge).
                for cut in opt.arena().span_cuts() {
                    assert!(cuts.binary_search(&cut).is_ok(),
                            "{name}: span boundary {cut} missing from \
                             cuts");
                }
            }
        }
    }
}

#[test]
fn state_dict_roundtrip_resumes_identically_for_roster() {
    let (params0, meta) = toy();
    for name in optim::ROSTER {
        let mut rng = Rng::new(0xABCD);
        let gs: Vec<Vec<Tensor>> =
            (0..6).map(|_| rand_grads(&params0, &mut rng)).collect();
        let mut pa = params0.clone();
        let mut a =
            by_name(name, Hyper::default(), &pa, &meta).unwrap();
        for g in &gs[..3] {
            a.step(&mut pa, g, 1e-2);
        }
        let sd = a.state_dict();
        assert_eq!(sd.len(), a.state_len(),
                   "{name}: state_len must not drift from the dict");
        assert!(!sd.is_empty(),
                "{name}: every roster member checkpoints real state");
        let mut pb = pa.clone();
        let mut b =
            by_name(name, Hyper::default(), &params0, &meta).unwrap();
        b.load_state_dict(&sd).unwrap();
        for g in &gs[3..] {
            a.step(&mut pa, g, 1e-2);
            b.step(&mut pb, g, 1e-2);
        }
        assert_eq!(pa, pb, "{name}: restored trajectory diverged");
        // A truncated dict is an error, never a silent drop.
        let mut short = StateDict::new();
        for t in sd.entries().iter().skip(1) {
            short.insert_tensor(t.clone());
        }
        assert!(b.load_state_dict(&short).is_err(),
                "{name}: truncated state must be rejected");
    }
}

#[test]
fn segment_stepping_in_shard_coordinates_matches_global() {
    // A shard optimizer built over a sub-inventory (shard-local
    // arena) must produce the same updates as the matching range of a
    // full-arena optimizer — the ZeRO worker contract.
    let mut rng = Rng::new(99);
    let full = vec![Tensor::randn("w", &[8, 4], 0.5, &mut rng)];
    let g = Tensor::randn("w", &[8, 4], 0.5, &mut rng);
    // Full-space AdamW.
    let mut pa = full.clone();
    let mut a = optim::AdamW::new(Hyper::default(), &pa);
    a.step(&mut pa, std::slice::from_ref(&g), 1e-2);
    // Two "shards" [0, 12) and [12, 32), each its own optimizer.
    let mut flat: Vec<f32> = full[0].data.clone();
    let gflat = &g.data;
    for (lo, hi) in [(0usize, 12usize), (12, 32)] {
        let shard = vec![Tensor::new("w_shard", &[hi - lo],
                                     flat[lo..hi].to_vec())];
        let mut opt = optim::AdamW::new(Hyper::default(), &shard);
        opt.begin_step();
        opt.step_segment(ParamView::new(0, &mut flat[lo..hi]),
                         GradView::new(0, &gflat[lo..hi]), 1e-2);
    }
    assert_eq!(flat, pa[0].data);
}
