//! Bench: multi-tenant serve throughput under each scheduling policy.
//!
//! Runs the seeded CI storm (`tenants=4 pool=2 storm_seed=7`) to
//! all-terminal under `fair`, `fifo`, and `priority`, measuring job
//! throughput, p50/p95 job latency (in scheduler rounds, scaled by
//! the measured round wall time), and Jain's fairness index.
//!
//! Emits `results/BENCH_serve.json` (provenance `"measured"`) for the
//! `repro report --bench-history --gate` regression check.

use adam_mini::serve::{run, ServeConfig};
use adam_mini::util::json::Json;

fn main() {
    println!("serve bench: seeded storm per scheduling policy\n");
    let mut records = Vec::new();
    let mut fairness_fair = 1.0;
    for sched in ["fair", "fifo", "priority"] {
        let cfg = ServeConfig { sched: sched.to_string(),
                                ..Default::default() };
        let r = run(&cfg).expect("serve run failed");
        assert!(r.all_terminal(), "{sched}: jobs left non-terminal");
        if sched == "fair" {
            fairness_fair = r.fairness;
        }
        let jobs = r.jobs.len();
        let wall_ns = r.wall_secs * 1e9;
        let ns_per_round = wall_ns / r.rounds.max(1) as f64;
        records.push(Json::obj(vec![
            ("name",
             Json::str(format!("serve/{sched}/t{}_p{}", r.tenants,
                               r.pool))),
            ("sched", Json::str(sched)),
            ("iters", Json::num(jobs as f64)),
            ("mean_ns", Json::num(wall_ns / jobs.max(1) as f64)),
            ("p50_ns",
             Json::num(r.p50_latency_rounds * ns_per_round)),
            ("p95_ns",
             Json::num(r.p95_latency_rounds * ns_per_round)),
            ("rounds", Json::num(r.rounds as f64)),
            ("done", Json::num(r.done as f64)),
            ("failed", Json::num(r.failed as f64)),
            ("throughput_jobs_per_s",
             Json::num(r.throughput_jobs_per_s)),
            ("p50_latency_rounds", Json::num(r.p50_latency_rounds)),
            ("p95_latency_rounds", Json::num(r.p95_latency_rounds)),
            ("fairness", Json::num(r.fairness)),
            ("max_tenant_wait", Json::num(r.max_tenant_wait as f64)),
        ]));
        println!(
            "  -> {sched}: {} jobs in {} rounds, {:.1} jobs/s, \
             latency p50 {:.0} / p95 {:.0} rounds, fairness {:.3}",
            jobs, r.rounds, r.throughput_jobs_per_s,
            r.p50_latency_rounds, r.p95_latency_rounds, r.fairness);
    }

    std::fs::create_dir_all("results").expect("mkdir results");
    let out = Json::obj(vec![
        ("bench", Json::str("serve")),
        ("provenance", Json::str("measured")),
        ("fairness_fair", Json::num(fairness_fair)),
        ("records", Json::Arr(records)),
    ]);
    std::fs::write("results/BENCH_serve.json", out.to_string())
        .expect("write BENCH_serve.json");
    println!("\nwrote results/BENCH_serve.json");
}
