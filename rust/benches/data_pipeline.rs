//! Bench: data-pipeline throughput — corpus generation and batching
//! must never be a bottleneck next to the XLA step (target: >100M
//! tokens/s batching, i.e. >1000× faster than the step loop needs).

use adam_mini::data::{Batcher, Corpus, SyntheticSpec};
use adam_mini::partition::{partition_spec, Strategy};
use adam_mini::util::timer::Bench;

fn main() {
    let bench = Bench::quick();

    let spec = SyntheticSpec { n_tokens: 1 << 18, ..Default::default() };
    let r = bench.run("data/synthetic_corpus_256k_tokens", || {
        std::hint::black_box(Corpus::synthetic(&spec));
    });
    println!("  -> {:.1} M tokens/s generation\n",
             (1 << 18) as f64 / (r.mean_ns / 1e9) / 1e6);

    let corpus = Corpus::synthetic(&spec);
    let mut batcher = Batcher::new(corpus, 16, 64, 0);
    let r = bench.run("data/next_batch_16x64", || {
        std::hint::black_box(batcher.next_batch());
    });
    println!("  -> {:.1} M tokens/s batching\n",
             (16 * 64) as f64 / (r.mean_ns / 1e9) / 1e6);

    // Partitioner on the Llama-2-7B inventory (runs once per training
    // job; benched to keep it trivially cheap).
    let arch = &adam_mini::memmodel::table1_models()[2];
    let shapes = arch.param_shapes();
    let stacked = arch.stacked_names();
    bench.run("partition/llama7b_inventory", || {
        std::hint::black_box(
            partition_spec(&shapes, 32, &stacked, Strategy::Hessian)
                .unwrap());
    });
}
