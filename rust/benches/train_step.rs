//! Bench: end-to-end training step latency (Table 2's "no extra
//! compute per step" claim, measured) — fused Pallas vs fused jnp-ref
//! vs host-optimizer paths, AdamW vs Adam-mini, on the t295k model.
//!
//! Needs `make artifacts`; exits 0 with a message otherwise.

use adam_mini::data::{Batcher, Corpus, SyntheticSpec};
use adam_mini::optim::{self, Optimizer};
use adam_mini::runtime::{manifest, Engine, ModelRuntime};
use adam_mini::util::timer::Bench;

fn main() {
    let Ok(engine) = Engine::new(manifest::default_dir()) else {
        println!("BENCH train_step SKIPPED (run `make artifacts`)");
        return;
    };
    let rt = ModelRuntime::new(&engine, "t295k").unwrap();
    let corpus = Corpus::synthetic(&SyntheticSpec {
        vocab: rt.mm.vocab,
        n_tokens: 64 * rt.mm.batch_size * rt.mm.seq_len,
        ..Default::default()
    });
    let mut batcher =
        Batcher::new(corpus, rt.mm.batch_size, rt.mm.seq_len, 0);
    let batch = batcher.next_batch();
    let tokens = (rt.mm.batch_size * rt.mm.seq_len) as f64;
    let bench = Bench { max_iters: 200, ..Bench::default() };

    // Fused variants (grad + optimizer inside one XLA executable).
    for key in ["train_adamw", "train_adam_mini", "train_adamw_ref",
                "train_adam_mini_ref"] {
        let mut params = rt.init_params(0);
        let mut fused = rt.fused(key).unwrap();
        // Warm the executable cache/compile before timing.
        fused.step(&mut params, &batch, 1e-4).unwrap();
        let r = bench.run(&format!("train_step/fused_hostsync/{key}"),
                          || {
            fused.step(&mut params, &batch, 1e-4).unwrap();
        });
        println!("  -> {:.0} tokens/s\n", tokens / (r.mean_ns / 1e9));
        // Perf-pass fast path: literal-resident state, no host sync.
        let r = bench.run(&format!("train_step/fused_device/{key}"), || {
            fused.step_device(&params, &batch, 1e-4).unwrap();
        });
        println!("  -> {:.0} tokens/s\n", tokens / (r.mean_ns / 1e9));
    }

    // Host path: grad artifact + Rust optimizer.
    for name in ["adamw", "adam_mini"] {
        let mut params = rt.init_params(0);
        let mut opt = optim::by_name(name, engine.manifest.hyper(),
                                     &params, &rt.mm.meta())
            .unwrap();
        rt.grad(&params, &batch).unwrap(); // warm
        let r = bench.run(&format!("train_step/host/{name}"), || {
            let (_, grads) = rt.grad(&params, &batch).unwrap();
            opt.step(&mut params, &grads, 1e-4);
        });
        println!("  -> {:.0} tokens/s\n", tokens / (r.mean_ns / 1e9));
    }
}
