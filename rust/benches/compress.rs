//! Bench: the gradient-compression codecs under the streamed bucket
//! pipeline on the probe inventory (~1.6M f32), 4 in-process workers.
//!
//! One cell per codec (`none`, `f16`, `topk:0.25`), each driving a
//! full ZeRO-2 overlapped step — reduce-scatter through the codec,
//! shard step, all-gather back. Latency tells us what the encode /
//! decode passes cost on top of the dense pipeline; next to it each
//! record carries the measured and closed-form modeled step bytes
//! from the traffic probe, so the history gate tracks both the time
//! and the wire. Emits `results/BENCH_compress.json`.

use adam_mini::dist::{measure_compressed_traffic, probe_params,
                      CodecSpec, DistOptions, DistTrainer};
use adam_mini::tensor::Tensor;
use adam_mini::util::json::Json;
use adam_mini::util::timer::Bench;

fn main() {
    let workers = 4usize;
    let (params, n) = probe_params(0xC0DE);
    println!("codec sweep payload: {n} f32 ({:.1} MB), {workers} \
              workers, zero2 overlap\n",
             n as f64 * 4.0 / 1e6);
    let grads: Vec<Tensor> = params
        .iter()
        .map(|p| {
            Tensor::new(&*p.name, &p.shape, vec![1e-3; p.numel()])
        })
        .collect();

    let bench = Bench::quick();
    let mut records = Vec::new();
    for codec in ["none", "f16", "topk:0.25"] {
        let spec = CodecSpec::parse(codec).unwrap();
        let name = format!("compress/w{workers}/{codec}");
        let mut run_params = params.to_vec();
        let mut dist = DistTrainer::new(&run_params, DistOptions {
            workers,
            bucket_kb: 64,
            zero1: true,
            zero2: true,
            optimizer: "adamw".into(),
            compress: spec,
            ..Default::default()
        })
        .expect("probe DistTrainer");
        let r = bench.run(&name, || {
            let mut stream = dist.begin_step(1, 1e-4);
            for j in (0..grads.len()).rev() {
                stream.push_grad(0, j, &grads[j]).unwrap();
            }
            stream.finish(&mut run_params).unwrap();
        });
        // Wire accounting from the traffic probe: measured per-step
        // bytes next to the closed-form model.
        let row = measure_compressed_traffic(spec, workers, 64, 2,
                                             true)
            .expect("traffic probe");
        println!("  -> {codec}: {:.2} ms/step, {:.1} KB/step on the \
                  wire ({:.3}x of f32, model off by {:+.2}%)\n",
                 r.mean_ms(), row.measured_bytes / 1e3,
                 row.ratio_vs_f32, row.delta_pct());
        records.push(Json::obj(vec![
            ("name", Json::str(&r.name)),
            ("workers", Json::num(workers as f64)),
            ("codec", Json::str(codec)),
            ("schedule", Json::str("zero2/overlap")),
            ("iters", Json::num(r.iters as f64)),
            ("mean_ns", Json::num(r.mean_ns)),
            ("p50_ns", Json::num(r.p50_ns)),
            ("p95_ns", Json::num(r.p95_ns)),
            ("measured_step_bytes", Json::num(row.measured_bytes)),
            ("modeled_step_bytes", Json::num(row.modeled_bytes)),
            ("ratio_vs_f32", Json::num(row.ratio_vs_f32)),
        ]));
    }

    std::fs::create_dir_all("results").expect("mkdir results");
    let out = Json::obj(vec![
        ("bench", Json::str("dist_compress")),
        ("provenance", Json::str("measured")),
        ("records", Json::Arr(records)),
    ]);
    std::fs::write("results/BENCH_compress.json", out.to_string())
        .expect("write BENCH_compress.json");
    println!("wrote results/BENCH_compress.json");
}
