//! Bench: bucketed ring all-reduce latency vs bucket size on the probe
//! inventory (~1.6M f32), 4 in-process workers.
//!
//! Small buckets bound staging memory but pay per-message latency and
//! thread-wakeup overhead; large buckets amortize it. Cluster-total
//! bytes are bucket-invariant (2·(N−1)·payload), so this sweep isolates
//! the latency term. Emits `results/BENCH_dist.json` so the perf
//! trajectory of the dist engine is recorded across PRs.

use adam_mini::dist::allreduce::ring_all_reduce;
use adam_mini::dist::comm::{ring_world, LinkModel, TrafficClass};
use adam_mini::dist::probe_params;
use adam_mini::tensor::Tensor;
use adam_mini::util::json::Json;
use adam_mini::util::timer::Bench;

fn main() {
    let workers = 4usize;
    let (params, n) = probe_params(0xBE7C);
    let flat: Vec<f32> = params
        .iter()
        .flat_map(|t: &Tensor| t.data.iter().copied())
        .collect();
    println!("all-reduce payload: {n} f32 ({:.1} MB), {workers} workers\n",
             n as f64 * 4.0 / 1e6);

    let bench = Bench::quick();
    let mut records = Vec::new();
    for bucket_kb in [4usize, 16, 64, 256, 1024, 8192] {
        let bucket_elems = bucket_kb * 1024 / 4;
        let name = format!("allreduce/w{workers}/bucket{bucket_kb}kb");
        let r = bench.run(&name, || {
            let (nodes, _) = ring_world(workers, LinkModel::default());
            std::thread::scope(|s| {
                for node in nodes {
                    let mut data = flat.clone();
                    s.spawn(move || {
                        ring_all_reduce(&node, &mut data, bucket_elems,
                                        TrafficClass::GradReduce);
                    });
                }
            });
        });
        // Effective per-worker reduction throughput.
        let gb_s = n as f64 * 4.0 / (r.mean_ns / 1e9) / 1e9;
        println!("  -> bucket {bucket_kb} KB: {:.2} ms/all-reduce, \
                  {gb_s:.2} GB/s\n", r.mean_ms());
        records.push(Json::obj(vec![
            ("name", Json::str(&r.name)),
            ("workers", Json::num(workers as f64)),
            ("bucket_kb", Json::num(bucket_kb as f64)),
            ("payload_elems", Json::num(n as f64)),
            ("iters", Json::num(r.iters as f64)),
            ("mean_ns", Json::num(r.mean_ns)),
            ("p50_ns", Json::num(r.p50_ns)),
            ("p95_ns", Json::num(r.p95_ns)),
            ("gb_per_s", Json::num(gb_s)),
        ]));
    }
    std::fs::create_dir_all("results").expect("mkdir results");
    let out = Json::obj(vec![
        ("bench", Json::str("dist_allreduce")),
        ("records", Json::Arr(records)),
    ]);
    std::fs::write("results/BENCH_dist.json", out.to_string())
        .expect("write BENCH_dist.json");
    println!("wrote results/BENCH_dist.json");
}
