//! Bench: the dist engine's collectives and step schedules on the
//! probe inventory (~1.6M f32), 4 in-process workers.
//!
//! Part 1 — bucketed ring all-reduce latency vs bucket size. Small
//! buckets bound staging memory but pay per-message latency and
//! thread-wakeup overhead; large buckets amortize it. Cluster-total
//! bytes are bucket-invariant (2·(N−1)·payload), so this sweep
//! isolates the latency term. Emits `results/BENCH_dist.json`.
//!
//! Part 2 — step-schedule sweep: overlap on/off × ZeRO-1/ZeRO-2.
//! Each cell drives a full DistTrainer step (grad reduce + shard step
//! + param all-gather) and records the real wall clock next to the
//! simulated-link-model timeline (overlapped vs sequential) so the
//! perf trajectory of the streaming pipeline is tracked across PRs.
//! Emits `results/BENCH_overlap.json`.

use adam_mini::dist::allreduce::ring_all_reduce;
use adam_mini::dist::comm::{ring_world, LinkModel, TrafficClass};
use adam_mini::dist::{probe_params, DistOptions, DistTrainer};
use adam_mini::tensor::Tensor;
use adam_mini::util::json::Json;
use adam_mini::util::timer::Bench;

fn bench_bucket_sweep(bench: &Bench, workers: usize, flat: &[f32],
                      n: usize) -> Vec<Json> {
    let mut records = Vec::new();
    for bucket_kb in [4usize, 16, 64, 256, 1024, 8192] {
        let bucket_elems = bucket_kb * 1024 / 4;
        let name = format!("allreduce/w{workers}/bucket{bucket_kb}kb");
        let r = bench.run(&name, || {
            let (nodes, _) = ring_world(workers, LinkModel::default());
            std::thread::scope(|s| {
                for node in nodes {
                    let mut data = flat.to_vec();
                    s.spawn(move || {
                        ring_all_reduce(&node, &mut data, bucket_elems,
                                        TrafficClass::GradReduce);
                    });
                }
            });
        });
        // Effective per-worker reduction throughput.
        let gb_s = n as f64 * 4.0 / (r.mean_ns / 1e9) / 1e9;
        println!("  -> bucket {bucket_kb} KB: {:.2} ms/all-reduce, \
                  {gb_s:.2} GB/s\n", r.mean_ms());
        records.push(Json::obj(vec![
            ("name", Json::str(&r.name)),
            ("workers", Json::num(workers as f64)),
            ("bucket_kb", Json::num(bucket_kb as f64)),
            ("payload_elems", Json::num(n as f64)),
            ("iters", Json::num(r.iters as f64)),
            ("mean_ns", Json::num(r.mean_ns)),
            ("p50_ns", Json::num(r.p50_ns)),
            ("p95_ns", Json::num(r.p95_ns)),
            ("gb_per_s", Json::num(gb_s)),
        ]));
    }
    records
}

fn bench_step_schedules(bench: &Bench, workers: usize,
                        params: &[Tensor]) -> Vec<Json> {
    let mut records = Vec::new();
    let grads: Vec<Tensor> = params
        .iter()
        .map(|p| {
            Tensor::new(&*p.name, &p.shape, vec![1e-3; p.numel()])
        })
        .collect();
    for zero2 in [false, true] {
        for overlap in [false, true] {
            let schedule = if zero2 { "zero2" } else { "zero1" };
            let pipeline = if overlap { "overlap" } else { "sync" };
            let name =
                format!("step/w{workers}/{schedule}/{pipeline}");
            let mut run_params = params.to_vec();
            let mut dist = DistTrainer::new(&run_params, DistOptions {
                workers,
                bucket_kb: 64,
                zero1: true,
                zero2,
                optimizer: "adamw".into(),
                ..Default::default()
            })
            .expect("probe DistTrainer");
            let r = bench.run(&name, || {
                if overlap {
                    let mut stream = dist.begin_step(1, 1e-4);
                    for j in (0..grads.len()).rev() {
                        stream.push_grad(0, j, &grads[j]).unwrap();
                    }
                    stream.finish(&mut run_params).unwrap();
                } else {
                    let mut local = dist.grad_buffers();
                    dist.layout().accumulate(&mut local[0], &grads);
                    dist.step(&mut run_params, local, 1, 1e-4)
                        .unwrap();
                }
            });
            let timing = dist.last_step_timing();
            let (model_ov, model_seq) = timing
                .map(|t| (t.overlapped_ns, t.sequential_ns))
                .unwrap_or((0.0, 0.0));
            println!(
                "  -> {schedule}/{pipeline}: {:.2} ms/step real{}",
                r.mean_ms(),
                if overlap {
                    format!(", modeled {:.2} ms overlapped vs {:.2} \
                             ms sequential ({:.2}x)",
                            model_ov / 1e6, model_seq / 1e6,
                            model_seq / model_ov.max(1.0))
                } else {
                    String::new()
                }
            );
            records.push(Json::obj(vec![
                ("name", Json::str(&r.name)),
                ("workers", Json::num(workers as f64)),
                ("schedule", Json::str(schedule)),
                ("pipeline", Json::str(pipeline)),
                ("iters", Json::num(r.iters as f64)),
                ("mean_ns", Json::num(r.mean_ns)),
                ("p50_ns", Json::num(r.p50_ns)),
                ("p95_ns", Json::num(r.p95_ns)),
                ("modeled_overlapped_ns", Json::num(model_ov)),
                ("modeled_sequential_ns", Json::num(model_seq)),
            ]));
        }
    }
    records
}

fn main() {
    let workers = 4usize;
    let (params, n) = probe_params(0xBE7C);
    let flat: Vec<f32> = params
        .iter()
        .flat_map(|t: &Tensor| t.data.iter().copied())
        .collect();
    println!("all-reduce payload: {n} f32 ({:.1} MB), {workers} workers\n",
             n as f64 * 4.0 / 1e6);

    let bench = Bench::quick();
    let bucket_records = bench_bucket_sweep(&bench, workers, &flat, n);
    println!("step schedules (overlap x zero2):");
    let step_records = bench_step_schedules(&bench, workers, &params);

    std::fs::create_dir_all("results").expect("mkdir results");
    let out = Json::obj(vec![
        ("bench", Json::str("dist_allreduce")),
        ("provenance", Json::str("measured")),
        ("records", Json::Arr(bucket_records)),
    ]);
    std::fs::write("results/BENCH_dist.json", out.to_string())
        .expect("write BENCH_dist.json");
    println!("wrote results/BENCH_dist.json");
    let out = Json::obj(vec![
        ("bench", Json::str("dist_overlap")),
        ("provenance", Json::str("measured")),
        ("records", Json::Arr(step_records)),
    ]);
    std::fs::write("results/BENCH_overlap.json", out.to_string())
        .expect("write BENCH_overlap.json");
    println!("wrote results/BENCH_overlap.json");
}
