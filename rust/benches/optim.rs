//! Bench: step throughput of the block-granular optimizer API —
//! roster × (whole-model `step` vs partitioned `step_segment`) on the
//! ~1.6M-param probe inventory.
//!
//! The whole/segment delta isolates the cost of segment dispatch
//! (binary searches, span lookups, per-segment loop setup) that the
//! ZeRO-2 bucket-granular pipeline pays per bucket — it should stay in
//! the noise next to the update arithmetic. Emits
//! `results/BENCH_optim.json` to seed the optimizer-step perf
//! trajectory across PRs.

use std::sync::Arc;

use adam_mini::dist::{probe_meta, probe_params};
use adam_mini::optim::{self, GradView, Hyper, Optimizer, ParamView};
use adam_mini::tensor::Tensor;
use adam_mini::util::json::Json;
use adam_mini::util::prng::Rng;
use adam_mini::util::timer::Bench;

/// Split `[0, total)` into ~`want` pieces honoring the cut grid
/// (`None` = any boundary), mimicking a bucket plan.
fn segments(cuts: Option<Vec<usize>>, total: usize, want: usize)
    -> Vec<(usize, usize)> {
    let mut bounds = vec![0usize];
    match cuts {
        None => {
            for k in 1..want {
                bounds.push(k * total / want);
            }
        }
        Some(cs) => {
            for k in 1..want {
                let target = k * total / want;
                let idx = cs.partition_point(|&c| c < target);
                let pick = cs.get(idx).copied().unwrap_or(total);
                if pick > *bounds.last().unwrap() && pick < total {
                    bounds.push(pick);
                }
            }
        }
    }
    bounds.push(total);
    bounds.dedup();
    bounds.windows(2).map(|w| (w[0], w[1])).collect()
}

fn main() {
    let (params, n) = probe_params(0xB0B);
    let meta = probe_meta();
    let mut rng = Rng::new(1);
    let grads: Vec<Tensor> = params
        .iter()
        .map(|p| Tensor::randn(&*p.name, &p.shape, 0.01, &mut rng))
        .collect();
    println!("optimizer step bench: {n} params, whole vs segmented\n");

    let bench = Bench::quick();
    let mut records = Vec::new();
    for name in optim::ROSTER {
        // Whole-model tensor-list step (the classic path).
        let mut p_whole = params.clone();
        let mut opt =
            optim::by_name(name, Hyper::default(), &p_whole, &meta)
                .unwrap();
        let r_whole = bench.run(&format!("optstep/{name}/whole"), || {
            opt.step(&mut p_whole, &grads, 1e-4);
        });

        // Segment-partitioned step over flat views (the dist path).
        let mut opt_seg =
            optim::by_name(name, Hyper::default(), &params, &meta)
                .unwrap();
        let arena = Arc::clone(opt_seg.arena());
        let mut flat = arena.flatten(&params);
        let gflat = arena.flatten(&grads);
        let segs = segments(opt_seg.segment_cuts(), arena.total, 16);
        let n_segs = segs.len();
        let r_seg = bench.run(&format!("optstep/{name}/segmented"),
                              || {
            opt_seg.begin_step();
            for &(lo, hi) in &segs {
                opt_seg.step_segment(
                    ParamView::new(lo, &mut flat[lo..hi]),
                    GradView::new(lo, &gflat[lo..hi]), 1e-4);
            }
        });

        let overhead =
            (r_seg.mean_ns - r_whole.mean_ns) / r_whole.mean_ns;
        println!(
            "  -> {name}: whole {:.2} ns/param, segmented ({n_segs} \
             segs) {:.2} ns/param ({:+.1}% vs whole), state {:.1} KB\n",
            r_whole.mean_ns / n as f64, r_seg.mean_ns / n as f64,
            overhead * 100.0, opt.state_bytes() as f64 / 1e3);
        for (mode, r) in [("whole", &r_whole), ("segmented", &r_seg)] {
            records.push(Json::obj(vec![
                ("name", Json::str(&r.name)),
                ("optimizer", Json::str(*name)),
                ("mode", Json::str(mode)),
                ("segments", Json::num(if mode == "whole" { 1.0 }
                                       else { n_segs as f64 })),
                ("payload_elems", Json::num(n as f64)),
                ("iters", Json::num(r.iters as f64)),
                ("mean_ns", Json::num(r.mean_ns)),
                ("p50_ns", Json::num(r.p50_ns)),
                ("p95_ns", Json::num(r.p95_ns)),
                ("ns_per_param", Json::num(r.mean_ns / n as f64)),
            ]));
        }
    }

    std::fs::create_dir_all("results").expect("mkdir results");
    let out = Json::obj(vec![
        ("bench", Json::str("optim_step")),
        ("records", Json::Arr(records)),
    ]);
    std::fs::write("results/BENCH_optim.json", out.to_string())
        .expect("write BENCH_optim.json");
    println!("wrote results/BENCH_optim.json");
}
