//! Bench: optimizer step throughput across the roster, three ways —
//! the §3.4 "throughput comparison" microbench on the ~1.6M-param
//! probe inventory.
//!
//! - `scalar`: the pre-kernel pipeline, faithfully emulated — a
//!   separate gradient scale pass, then flatten → whole-arena
//!   `step_segment` → unflatten, with the optimizer built under
//!   `simd=off` (the scalar parity oracle).
//! - `simd`: the identical pipeline with the optimizer built under
//!   `simd=on` — isolates the vector-kernel win alone.
//! - `fused`: `step_scaled` — the gradient scale folds into the
//!   update sweep and parameters step in place, span by span. No
//!   scale pass, no flatten/unflatten temporaries. This is the path
//!   the trainer and the ZeRO shard step actually run.
//!
//! Emits `results/BENCH_optim.json` (provenance `"measured"`) for the
//! `repro report --bench-history --gate` regression check.

use std::sync::Arc;

use adam_mini::dist::{probe_meta, probe_params};
use adam_mini::optim::{self, kernels, GradView, Hyper, Optimizer,
                       ParamView, SimdPolicy};
use adam_mini::tensor::Tensor;
use adam_mini::util::json::Json;
use adam_mini::util::prng::Rng;
use adam_mini::util::timer::Bench;

/// A scale factor close enough to 1 that repeated in-place application
/// cannot drift the payload, but not exactly 1.0 — the compiler must
/// not be able to fold the multiply away.
const GSCALE: f32 = 0.999_999_9;

fn main() {
    let (params, n) = probe_params(0xB0B);
    let meta = probe_meta();
    let mut rng = Rng::new(1);
    let grads: Vec<Tensor> = params
        .iter()
        .map(|p| Tensor::randn(&*p.name, &p.shape, 0.01, &mut rng))
        .collect();
    println!("optimizer step bench: {n} params, \
              scalar vs simd vs fused\n");

    let bench = Bench::quick();
    let mut records = Vec::new();
    for name in optim::ROSTER {
        let mut mean = [0.0f64; 3];
        for (mi, mode) in ["scalar", "simd", "fused"]
            .iter()
            .enumerate()
        {
            // Dispatch is cached at construction from the thread-local
            // policy, so set it before building each optimizer.
            kernels::set_policy(if *mode == "scalar" {
                SimdPolicy::Off
            } else {
                SimdPolicy::On
            });
            let mut p = params.clone();
            let mut opt =
                optim::by_name(name, Hyper::default(), &p, &meta)
                    .unwrap();
            let rec_name = format!("optstep/{name}/{mode}");
            let r = if *mode == "fused" {
                bench.run(&rec_name, || {
                    opt.step_scaled(&mut p, &grads, 1e-4, GSCALE);
                })
            } else {
                // The pre-kernel pipeline: scale pass + flatten +
                // whole-arena segment step + unflatten.
                let arena = Arc::clone(opt.arena());
                let mut gflat = arena.flatten(&grads);
                bench.run(&rec_name, || {
                    for x in gflat.iter_mut() {
                        *x *= GSCALE;
                    }
                    let mut flat = arena.flatten(&p);
                    opt.begin_step();
                    let total = arena.total;
                    opt.step_segment(
                        ParamView::new(0, &mut flat[..total]),
                        GradView::new(0, &gflat[..total]), 1e-4);
                    arena.unflatten(&flat, &mut p);
                })
            };
            mean[mi] = r.mean_ns;
            records.push(Json::obj(vec![
                ("name", Json::str(&r.name)),
                ("optimizer", Json::str(*name)),
                ("mode", Json::str(mode)),
                ("payload_elems", Json::num(n as f64)),
                ("iters", Json::num(r.iters as f64)),
                ("mean_ns", Json::num(r.mean_ns)),
                ("p50_ns", Json::num(r.p50_ns)),
                ("p95_ns", Json::num(r.p95_ns)),
                ("ns_per_param", Json::num(r.mean_ns / n as f64)),
                ("elems_per_sec",
                 Json::num(n as f64 / (r.mean_ns / 1e9))),
            ]));
        }
        println!(
            "  -> {name}: scalar {:.2} ns/param, simd {:.2} \
             ({:.2}x), fused {:.2} ({:.2}x vs scalar)\n",
            mean[0] / n as f64, mean[1] / n as f64, mean[0] / mean[1],
            mean[2] / n as f64, mean[0] / mean[2]);
    }
    kernels::set_policy(SimdPolicy::Auto);

    std::fs::create_dir_all("results").expect("mkdir results");
    let out = Json::obj(vec![
        ("bench", Json::str("optim_step")),
        ("provenance", Json::str("measured")),
        ("records", Json::Arr(records)),
    ]);
    std::fs::write("results/BENCH_optim.json", out.to_string())
        .expect("write BENCH_optim.json");
    println!("wrote results/BENCH_optim.json");
}
