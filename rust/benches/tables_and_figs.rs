//! Bench: the analytic regenerators — Table 1 memory accounting,
//! Table 2 cluster simulation, Fig 4 quadratic solvers, Fig 5
//! preconditioner sweep, Fig 3 MLP Hessian — so their costs are
//! tracked and regressions in the substrates show up in `cargo bench`.

use adam_mini::cluster::{Job, ADAM_MINI_PROFILE, ADAMW_PROFILE};
use adam_mini::hessian::mlp::{GaussianMixture, Mlp};
use adam_mini::linalg::eigh;
use adam_mini::memmodel::{memory_report, table1_models};
use adam_mini::quadratic::fig4::{blockwise_gd_quadratic,
                                 make_fig4_hessian};
use adam_mini::quadratic::precond::precond_sweep;
use adam_mini::util::prng::Rng;
use adam_mini::util::timer::Bench;

fn main() {
    let bench = Bench::quick();

    // Table 1: full memory accounting for all five published models.
    bench.run("table1/memory_reports", || {
        for arch in table1_models() {
            std::hint::black_box(memory_report(&arch));
        }
    });

    // Table 2: cluster sim operating-point search.
    bench.run("table2/cluster_sim", || {
        for opt in [ADAMW_PROFILE, ADAM_MINI_PROFILE] {
            let job = Job::llama7b(opt);
            std::hint::black_box(job.best_throughput());
        }
    });

    // Fig 4: blockwise-GD on the 90-dim three-block quadratic.
    let mut rng = Rng::new(0);
    let (h, ranges) = make_fig4_hessian(&mut rng);
    let w0: Vec<f64> = (0..h.rows).map(|_| rng.normal()).collect();
    bench.run("fig4/blockwise_gd_300_steps", || {
        std::hint::black_box(blockwise_gd_quadratic(&h, &ranges, &w0,
                                                    300));
    });

    // Jacobi eigensolver on a 90x90 symmetric matrix.
    bench.run("linalg/eigh_90x90", || {
        std::hint::black_box(eigh(&h));
    });

    // Fig 5: one sweep point set at d=20.
    bench.run("fig5/precond_sweep_d20", || {
        let mut rng = Rng::new(1);
        std::hint::black_box(precond_sweep(20, 500.0, &[0.0, 1.0], 2, 4,
                                           &mut rng));
    });

    // Fig 3: exact MLP Hessian (24x24 here).
    let data = GaussianMixture::generate(60, 6, 3, 0.4, 0);
    let mut mlp = Mlp::init(6, 4, 3, 0);
    bench.run("fig3/mlp_hessian_24x24", || {
        std::hint::black_box(mlp.hessian_w(&data, 1e-2));
    });
}
