//! Bench: host optimizer update latency per step (the Fig 13c /
//! §3.4 "throughput comparison" microbench) across the whole roster,
//! on a realistic tensor inventory.

use adam_mini::optim::{self, Hyper, ModelMeta};
use adam_mini::tensor::Tensor;
use adam_mini::util::prng::Rng;
use adam_mini::util::timer::Bench;

fn main() {
    let mut rng = Rng::new(0);
    // A ~1.6M-param inventory shaped like the t1m6 model.
    let (l, d, ff, v) = (6usize, 128usize, 512usize, 256usize);
    let params = vec![
        Tensor::randn("embed", &[v, d], 0.02, &mut rng),
        Tensor::randn("wq", &[l, d, d], 0.02, &mut rng),
        Tensor::randn("wk", &[l, d, d], 0.02, &mut rng),
        Tensor::randn("wv", &[l, d, d], 0.02, &mut rng),
        Tensor::randn("wo", &[l, d, d], 0.02, &mut rng),
        Tensor::randn("w1", &[l, ff, d], 0.02, &mut rng),
        Tensor::randn("w3", &[l, ff, d], 0.02, &mut rng),
        Tensor::randn("w2", &[l, d, ff], 0.02, &mut rng),
        Tensor::ones("attn_norm", &[l, d]),
        Tensor::ones("mlp_norm", &[l, d]),
        Tensor::ones("final_norm", &[d]),
        Tensor::randn("output", &[v, d], 0.02, &mut rng),
    ];
    let meta = ModelMeta {
        n_heads: 8,
        stacked: ["wq", "wk", "wv", "wo", "w1", "w3", "w2", "attn_norm",
                  "mlp_norm"].iter().map(|s| s.to_string()).collect(),
    };
    let grads: Vec<Tensor> = params
        .iter()
        .map(|p| Tensor::randn(&*p.name, &p.shape, 0.01, &mut rng))
        .collect();
    let n: usize = params.iter().map(Tensor::numel).sum();
    println!("inventory: {n} params across {} tensors\n", params.len());

    let bench = Bench::default();
    for name in optim::ROSTER {
        let mut p = params.clone();
        let mut opt =
            optim::by_name(name, Hyper::default(), &p, &meta).unwrap();
        let r = bench.run(&format!("optstep/{name}"), || {
            opt.step(&mut p, &grads, 1e-4);
        });
        println!("  -> {name}: {:.2} ns/param, state {:.1} KB\n",
                 r.mean_ns / n as f64,
                 opt.state_bytes() as f64 / 1e3);
    }
}
