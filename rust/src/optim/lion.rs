//! Lion (Chen et al. 2024) — sign-momentum optimizer, compared against
//! Adam-mini in the paper's Appendix D.8 (with the "lr 10× smaller than
//! AdamW" tuning rule).

use std::sync::Arc;

use anyhow::Result;

use super::core::{check_state_len, Arena, GradView, Granularity,
                  Optimizer, ParamView, StateDict};
use super::kernels::{self, Dispatch, LionCoef};
use super::Hyper;
use crate::tensor::Tensor;

pub struct Lion {
    hp: Hyper,
    arena: Arc<Arena>,
    dispatch: Dispatch,
    m: Vec<f32>,
}

impl Lion {
    pub fn new(hp: Hyper, params: &[Tensor]) -> Lion {
        let arena = Arc::new(Arena::of(params));
        let n = arena.total;
        Lion { hp, arena, dispatch: Dispatch::for_arena(n),
               m: vec![0.0; n] }
    }

    fn step_impl(&mut self, params: ParamView<'_>, grads: GradView<'_>,
                 lr: f32, gscale: f32) {
        assert_eq!(params.range(), (grads.lo(), grads.hi()));
        let (lo, hi) = params.range();
        let Hyper { beta1, beta2, weight_decay, .. } = self.hp;
        let k = LionCoef { beta1, beta2, wd: 1.0 - lr * weight_decay,
                           lr, gscale };
        kernels::lion_step(self.dispatch, params.data, grads.data,
                           &mut self.m[lo..hi], &k);
    }
}

impl Optimizer for Lion {
    fn name(&self) -> String {
        "lion".into()
    }

    fn arena(&self) -> &Arc<Arena> {
        &self.arena
    }

    fn granularity(&self) -> Granularity {
        Granularity::Element
    }

    fn step_segment(&mut self, params: ParamView<'_>, grads: GradView<'_>,
                    lr: f32) {
        self.step_impl(params, grads, lr, 1.0);
    }

    fn step_segment_scaled(&mut self, params: ParamView<'_>,
                           grads: GradView<'_>, lr: f32, gscale: f32) {
        self.step_impl(params, grads, lr, gscale);
    }

    fn state_bytes(&self) -> usize {
        self.m.len() * 4
    }

    /// Entries: `m` (the sign-momentum EMA).
    fn state_dict(&self) -> StateDict {
        let mut sd = StateDict::new();
        sd.insert("m", &[self.m.len()], self.m.clone());
        sd
    }

    fn state_len(&self) -> usize {
        1
    }

    fn load_state_dict(&mut self, state: &StateDict) -> Result<()> {
        check_state_len(state, 1, "lion")?;
        self.m.copy_from_slice(state.data("m", self.m.len())?);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn updates_are_sign_sized() {
        let hp = Hyper { weight_decay: 0.0, ..Hyper::default() };
        let mut params = vec![Tensor::zeros("w", &[3])];
        let grads = vec![Tensor::new("w", &[3], vec![5.0, -0.01, 2.0])];
        let mut opt = Lion::new(hp, &params);
        opt.step(&mut params, &grads, 0.1);
        assert_eq!(params[0].data, vec![-0.1, 0.1, -0.1]);
    }

    #[test]
    fn half_memory_of_adamw() {
        let params = vec![Tensor::zeros("w", &[10, 10])];
        let opt = Lion::new(Hyper::default(), &params);
        assert_eq!(opt.state_bytes(), 100 * 4);
    }

    #[test]
    fn state_roundtrips() {
        let mut params = vec![Tensor::new("w", &[2], vec![1.0, -1.0])];
        let g = vec![Tensor::new("w", &[2], vec![0.5, 0.25])];
        let mut a = Lion::new(Hyper::default(), &params);
        a.step(&mut params, &g, 0.1);
        let sd = a.state_dict();
        assert_eq!(sd.len(), a.state_len());
        let mut pb = params.clone();
        let mut b = Lion::new(Hyper::default(), &pb);
        b.load_state_dict(&sd).unwrap();
        a.step(&mut params, &g, 0.1);
        b.step(&mut pb, &g, 0.1);
        assert_eq!(params, pb);
    }
}
