//! Lion (Chen et al. 2024) — sign-momentum optimizer, compared against
//! Adam-mini in the paper's Appendix D.8 (with the "lr 10× smaller than
//! AdamW" tuning rule).

use super::{Hyper, Optimizer};
use crate::tensor::Tensor;

pub struct Lion {
    hp: Hyper,
    m: Vec<Tensor>,
}

impl Lion {
    pub fn new(hp: Hyper, params: &[Tensor]) -> Lion {
        Lion {
            hp,
            m: params
                .iter()
                .map(|p| Tensor::zeros(&*p.name, &p.shape))
                .collect(),
        }
    }
}

impl Optimizer for Lion {
    fn name(&self) -> String {
        "lion".into()
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        let Hyper { beta1, beta2, weight_decay, .. } = self.hp;
        let wd = 1.0 - lr * weight_decay;
        for ((p, g), m) in params.iter_mut().zip(grads).zip(&mut self.m) {
            for i in 0..p.data.len() {
                // Update direction: sign of the interpolated momentum.
                let c = beta1 * m.data[i] + (1.0 - beta1) * g.data[i];
                p.data[i] = p.data[i] * wd - lr * c.signum();
                // Momentum EMA uses β2 (Lion's defining asymmetry).
                m.data[i] = beta2 * m.data[i] + (1.0 - beta2) * g.data[i];
            }
        }
    }

    fn state_bytes(&self) -> usize {
        self.m.iter().map(Tensor::numel).sum::<usize>() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn updates_are_sign_sized() {
        let hp = Hyper { weight_decay: 0.0, ..Hyper::default() };
        let mut params = vec![Tensor::zeros("w", &[3])];
        let grads = vec![Tensor::new("w", &[3], vec![5.0, -0.01, 2.0])];
        let mut opt = Lion::new(hp, &params);
        opt.step(&mut params, &grads, 0.1);
        assert_eq!(params[0].data, vec![-0.1, 0.1, -0.1]);
    }

    #[test]
    fn half_memory_of_adamw() {
        let params = vec![Tensor::zeros("w", &[10, 10])];
        let opt = Lion::new(Hyper::default(), &params);
        assert_eq!(opt.state_bytes(), 100 * 4);
    }
}
