//! The block-granular optimizer core: flat parameter arena, segment
//! views, named state dicts, and the [`Optimizer`] trait every roster
//! member implements.
//!
//! Adam-mini's premise is that optimizer state is *block-structured*
//! (one `v_b` per dense Hessian block), and the distributed engine
//! wants to drive updates at *bucket* granularity (step a shard range
//! the moment its reduce-scatter lands). Both needs meet in one API:
//!
//! - [`Arena`] — the flattened parameter space (tensor order =
//!   parameter order), shared by optimizers and the ZeRO partitioner.
//!   Optimizer state is laid out against arena coordinates.
//! - [`ParamView`] / [`GradView`] — a contiguous arena segment of
//!   parameters (mutable) and gradients (shared), stepped in place:
//!   no tensor-list clone round-trips anywhere on the step path.
//! - [`Optimizer::step_segment`] — apply the current step's update to
//!   one segment. [`Optimizer::begin_step`] opens a step (advances the
//!   bias-correction counter once); any disjoint segment partition of
//!   the arena then produces the same parameters as one whole-model
//!   step, provided segment boundaries respect the optimizer's
//!   [`Granularity`] (its [`Optimizer::segment_cuts`]).
//! - [`Optimizer::step`] — the classic whole-model tensor-list step,
//!   provided as a blanket wrapper: flatten, `begin_step`, one
//!   full-range `step_segment`, write back.
//! - [`StateDict`] — string-keyed state export/import (`"m"`, `"vb"`,
//!   `"r/<tensor>"`, `"__step"`, ...) replacing the old fragile
//!   positional `Vec<Tensor>` convention. Used by checkpointing, the
//!   ZeRO state router (`rank<r>/...` prefixes) and `repro report`.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::tensor::Tensor;

/// One tensor's placement in the flattened parameter space.
#[derive(Debug, Clone)]
pub struct Span {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub len: usize,
}

/// The flat parameter arena: tensor order is parameter order. A shard
/// optimizer's arena covers only its shard (shard-local coordinates).
#[derive(Debug, Clone)]
pub struct Arena {
    pub spans: Vec<Span>,
    pub total: usize,
}

impl Arena {
    pub fn of(params: &[Tensor]) -> Arena {
        Arena::from_shapes(
            params.iter().map(|p| (p.name.clone(), p.shape.clone())))
    }

    pub fn from_shapes(
        shapes: impl IntoIterator<Item = (String, Vec<usize>)>) -> Arena {
        let mut spans = Vec::new();
        let mut offset = 0;
        for (name, shape) in shapes {
            let len: usize = shape.iter().product();
            spans.push(Span { name, shape, offset, len });
            offset += len;
        }
        Arena { spans, total: offset }
    }

    pub fn flatten(&self, params: &[Tensor]) -> Vec<f32> {
        assert_eq!(params.len(), self.spans.len());
        let mut flat = Vec::with_capacity(self.total);
        for (p, s) in params.iter().zip(&self.spans) {
            debug_assert_eq!(p.numel(), s.len, "{}: layout drift", s.name);
            flat.extend_from_slice(&p.data);
        }
        flat
    }

    /// Copy a flat vector back into the tensor list.
    pub fn unflatten(&self, flat: &[f32], params: &mut [Tensor]) {
        assert_eq!(flat.len(), self.total);
        assert_eq!(params.len(), self.spans.len());
        for (p, s) in params.iter_mut().zip(&self.spans) {
            p.data.copy_from_slice(&flat[s.offset..s.offset + s.len]);
        }
    }

    /// flat += tensors (gradient accumulation into a worker's buffer).
    pub fn accumulate(&self, flat: &mut [f32], grads: &[Tensor]) {
        assert_eq!(flat.len(), self.total);
        assert_eq!(grads.len(), self.spans.len());
        for (g, s) in grads.iter().zip(&self.spans) {
            for (x, y) in
                flat[s.offset..s.offset + s.len].iter_mut().zip(&g.data)
            {
                *x += y;
            }
        }
    }

    /// The spans fully covered by the flat range `[lo, hi)`, plus the
    /// index of the first. Panics if either boundary splits a tensor —
    /// tensor-granular optimizers use this to reject invalid segments.
    pub fn spans_in(&self, lo: usize, hi: usize) -> (usize, &[Span]) {
        assert!(lo <= hi && hi <= self.total,
                "segment [{lo}, {hi}) out of arena bounds {}", self.total);
        if lo == hi {
            return (0, &[]);
        }
        let start =
            self.spans.partition_point(|s| s.offset + s.len <= lo);
        let s0 = &self.spans[start];
        assert_eq!(s0.offset, lo,
                   "segment lo {lo} splits tensor {}", s0.name);
        let end = self.spans.partition_point(|s| s.offset < hi);
        let sl = &self.spans[end - 1];
        assert_eq!(sl.offset + sl.len, hi,
                   "segment hi {hi} splits tensor {}", sl.name);
        (start, &self.spans[start..end])
    }

    /// Tensor boundaries as flat cut points (0, span offsets, total).
    pub fn span_cuts(&self) -> Vec<usize> {
        let mut cuts: Vec<usize> =
            self.spans.iter().map(|s| s.offset).collect();
        cuts.push(self.total);
        cuts
    }
}

/// Mutable view of one contiguous arena segment of parameters.
pub struct ParamView<'a> {
    lo: usize,
    pub data: &'a mut [f32],
}

impl<'a> ParamView<'a> {
    /// `lo` is the arena offset of `data[0]`.
    pub fn new(lo: usize, data: &'a mut [f32]) -> ParamView<'a> {
        ParamView { lo, data }
    }

    pub fn lo(&self) -> usize {
        self.lo
    }

    pub fn hi(&self) -> usize {
        self.lo + self.data.len()
    }

    pub fn range(&self) -> (usize, usize) {
        (self.lo, self.hi())
    }

    /// Reborrow (for forwarding to an inner optimizer).
    pub fn reborrow(&mut self) -> ParamView<'_> {
        ParamView { lo: self.lo, data: &mut *self.data }
    }
}

/// Shared view of the matching gradient segment.
pub struct GradView<'a> {
    lo: usize,
    pub data: &'a [f32],
}

impl<'a> GradView<'a> {
    pub fn new(lo: usize, data: &'a [f32]) -> GradView<'a> {
        GradView { lo, data }
    }

    pub fn lo(&self) -> usize {
        self.lo
    }

    pub fn hi(&self) -> usize {
        self.lo + self.data.len()
    }

    pub fn reborrow(&self) -> GradView<'_> {
        GradView { lo: self.lo, data: self.data }
    }
}

/// Finest segmentation an optimizer's update decomposes over.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    /// Per-coordinate update (AdamW, SGD, Lion, AdaGrad, Adan): any
    /// segment boundary is valid.
    Element,
    /// Blockwise update (Adam-mini, blockwise GD): boundaries must
    /// fall on the optimizer's block grid.
    Block,
    /// Whole-tensor coupling (LAMB trust ratio, factored second
    /// moments, projections): boundaries must fall on tensor edges.
    Tensor,
}

/// Name of the step-counter entry in a [`StateDict`].
pub const STEP_TENSOR: &str = "__step";

/// Encode a step counter as a 2-element tensor. Split into 24-bit
/// halves so each is exactly representable in f32 (a single f32 would
/// silently round counters past 2^24).
pub fn step_tensor(t: u64) -> Tensor {
    let lo = (t & 0xFF_FFFF) as f32;
    let hi = (t >> 24) as f32;
    Tensor::new(STEP_TENSOR, &[2], vec![lo, hi])
}

/// Decode a [`step_tensor`].
pub fn decode_step(t: &Tensor) -> Result<u64> {
    if t.numel() != 2 {
        bail!("malformed {STEP_TENSOR} entry: {} elems", t.numel());
    }
    Ok(t.data[0] as u64 | ((t.data[1] as u64) << 24))
}

/// Named optimizer state: an ordered map of string keys to tensors.
/// Keys are flat identifiers (`"m"`, `"v"`, `"vb"`), per-tensor
/// entries (`"r/<tensor name>"`), the `"__step"` counter, and — in
/// ZeRO-gathered dicts — rank-routed entries (`"rank2/m"`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StateDict {
    /// Entries in insertion order; the tensor name IS the key.
    entries: Vec<Tensor>,
}

impl StateDict {
    pub fn new() -> StateDict {
        StateDict::default()
    }

    /// Insert an entry. Panics on a duplicate key (an export bug, not
    /// an input error).
    pub fn insert(&mut self, key: impl Into<String>, shape: &[usize],
                  data: Vec<f32>) {
        let key = key.into();
        assert!(self.get(&key).is_none(), "duplicate state key {key:?}");
        self.entries.push(Tensor::new(key, shape, data));
    }

    /// Insert a pre-built tensor entry (name = key).
    pub fn insert_tensor(&mut self, t: Tensor) {
        assert!(self.get(&t.name).is_none(),
                "duplicate state key {:?}", t.name);
        self.entries.push(t);
    }

    pub fn set_step(&mut self, t: u64) {
        self.insert_tensor(step_tensor(t));
    }

    /// The `__step` counter (error if absent or malformed).
    pub fn step(&self) -> Result<u64> {
        decode_step(self.require(STEP_TENSOR)?)
    }

    pub fn get(&self, key: &str) -> Option<&Tensor> {
        self.entries.iter().find(|t| t.name == key)
    }

    pub fn require(&self, key: &str) -> Result<&Tensor> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing state key {key:?}"))
    }

    /// Entry data with an exact length check.
    pub fn data(&self, key: &str, len: usize) -> Result<&[f32]> {
        let t = self.require(key)?;
        if t.numel() != len {
            bail!("state key {key:?}: {} elems, expected {len}",
                  t.numel());
        }
        Ok(&t.data)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn entries(&self) -> &[Tensor] {
        &self.entries
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.iter().map(|t| t.name.as_str())
    }

    pub fn total_elems(&self) -> usize {
        self.entries.iter().map(Tensor::numel).sum()
    }

    pub fn into_tensors(self) -> Vec<Tensor> {
        self.entries
    }

    /// Build from named tensors (checkpoint load). Duplicate names are
    /// an error, never a silent shadow.
    pub fn from_tensors(tensors: Vec<Tensor>) -> Result<StateDict> {
        let mut sd = StateDict::new();
        for t in tensors {
            if sd.get(&t.name).is_some() {
                bail!("duplicate state key {:?}", t.name);
            }
            sd.entries.push(t);
        }
        Ok(sd)
    }

    /// Remove and return an entry by key (`None` if absent). The ZeRO
    /// state router uses this to peel engine-owned entries — e.g. the
    /// `ef/residual` error-feedback residual — out of a rank's dict
    /// before the remainder reaches the shard optimizer.
    pub fn remove(&mut self, key: &str) -> Option<Tensor> {
        let i = self.entries.iter().position(|t| t.name == key)?;
        Some(self.entries.remove(i))
    }

    /// The sub-dict of entries whose key starts with `prefix`, with
    /// the prefix stripped (ZeRO rank routing).
    pub fn sub_dict(&self, prefix: &str) -> StateDict {
        let mut sd = StateDict::new();
        for t in &self.entries {
            if let Some(rest) = t.name.strip_prefix(prefix) {
                sd.entries.push(Tensor::new(rest, &t.shape,
                                            t.data.clone()));
            }
        }
        sd
    }
}

/// Check an imported dict has exactly the expected entry count.
pub fn check_state_len(sd: &StateDict, want: usize, who: &str)
    -> Result<()> {
    if sd.len() != want {
        bail!("{who}: expected {want} state entries, got {} ({:?})",
              sd.len(), sd.keys().collect::<Vec<_>>());
    }
    Ok(())
}

/// A host-side optimizer over a flat parameter [`Arena`].
///
/// Contract: one *model step* is `begin_step()` followed by
/// `step_segment` calls covering any disjoint partition of the arena
/// whose boundaries respect [`Optimizer::segment_cuts`]. The result is
/// identical (bitwise) to a single full-range `step_segment` — the
/// property the ZeRO-2 streaming pipeline relies on to step each
/// bucket's shard the moment its reduce-scatter lands.
pub trait Optimizer {
    fn name(&self) -> String;

    /// The arena this optimizer's state is laid out over.
    fn arena(&self) -> &Arc<Arena>;

    /// Finest valid segmentation of the update.
    fn granularity(&self) -> Granularity;

    /// Open the next optimizer step (advance bias-correction counters
    /// once). Call exactly once per model step, before that step's
    /// `step_segment` calls. Default: no step counter.
    fn begin_step(&mut self) {}

    /// Apply the current step's update to one contiguous arena
    /// segment, in place. `params` and `grads` must cover the same
    /// range, and the range must respect `segment_cuts`.
    fn step_segment(&mut self, params: ParamView<'_>, grads: GradView<'_>,
                    lr: f32);

    /// [`Optimizer::step_segment`] with every gradient read
    /// pre-multiplied by `gscale` — the hook the fused kernel layer
    /// (`optim::kernels`) implements so micro-batch averaging and
    /// global-norm clipping fold into the update sweep instead of
    /// costing their own pass over the gradient. `g * gscale` is the
    /// same float whether staged in a buffer or computed inline, so
    /// overriding this never changes the trajectory — only the pass
    /// count. The default materializes a scaled copy, which is
    /// correct for any optimizer; kernel-migrated members override.
    fn step_segment_scaled(&mut self, params: ParamView<'_>,
                           grads: GradView<'_>, lr: f32, gscale: f32) {
        if gscale == 1.0 {
            return self.step_segment(params, grads, lr);
        }
        let lo = grads.lo();
        let scaled: Vec<f32> =
            grads.data.iter().map(|x| x * gscale).collect();
        self.step_segment(params, GradView::new(lo, &scaled), lr);
    }

    /// Bytes of optimizer state currently held (memory accounting).
    fn state_bytes(&self) -> usize;

    /// Named state export. Default: empty (stateless optimizer).
    fn state_dict(&self) -> StateDict {
        StateDict::new()
    }

    /// Entry count of [`Optimizer::state_dict`] WITHOUT materializing
    /// it (the ZeRO state router sizes payloads with this). Must equal
    /// `state_dict().len()`; the default matches the default (empty)
    /// export.
    fn state_len(&self) -> usize {
        0
    }

    /// Restore state produced by [`Optimizer::state_dict`] on an
    /// identically-constructed instance. Importing a non-empty dict
    /// into a stateless optimizer is an error (never a silent drop).
    fn load_state_dict(&mut self, state: &StateDict) -> Result<()> {
        if state.is_empty() {
            return Ok(());
        }
        bail!("{}: optimizer state import not supported", self.name())
    }

    /// Valid segment boundaries: `None` means every element boundary
    /// (elementwise updates); `Some(cuts)` means boundaries must be
    /// drawn from `cuts` (sorted, includes 0 and total). Blockwise
    /// optimizers override this with their block grid.
    fn segment_cuts(&self) -> Option<Vec<usize>> {
        match self.granularity() {
            Granularity::Element => None,
            // Conservative default for Block: tensor edges are always
            // valid block boundaries; Adam-mini overrides with its
            // finer Hessian-block grid.
            Granularity::Block | Granularity::Tensor => {
                Some(self.arena().span_cuts())
            }
        }
    }

    /// Whole-model step over tensor lists (the classic API):
    /// [`Optimizer::step_scaled`] with unit gradient scale.
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        self.step_scaled(params, grads, lr, 1.0);
    }

    /// Whole-model fused step: one `begin_step`, then one in-place
    /// sweep per tensor span with `gscale` (micro-batch averaging ×
    /// clip factor) folded into each segment's gradient reads. No
    /// flatten/unflatten round trip: every tensor edge is a valid
    /// segment cut at every granularity (see
    /// [`Optimizer::segment_cuts`]), so stepping span-by-span in
    /// place is bitwise the whole-arena step minus two full-model
    /// copies each way.
    fn step_scaled(&mut self, params: &mut [Tensor], grads: &[Tensor],
                   lr: f32, gscale: f32) {
        let arena = Arc::clone(self.arena());
        assert_eq!(params.len(), arena.spans.len(), "params/arena drift");
        assert_eq!(grads.len(), arena.spans.len(), "grads/arena drift");
        self.begin_step();
        for (i, sp) in arena.spans.iter().enumerate() {
            debug_assert_eq!(params[i].data.len(), sp.len,
                             "{}: span length drift", sp.name);
            self.step_segment_scaled(
                ParamView::new(sp.offset, &mut params[i].data),
                GradView::new(sp.offset, &grads[i].data), lr, gscale);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn toy_arena() -> Arena {
        Arena::from_shapes(vec![
            ("a".to_string(), vec![4, 3]),
            ("b".to_string(), vec![6]),
            ("c".to_string(), vec![2, 2]),
        ])
    }

    #[test]
    fn arena_layout_and_roundtrip() {
        let mut rng = Rng::new(0);
        let params = vec![
            Tensor::randn("a", &[4, 3], 1.0, &mut rng),
            Tensor::randn("b", &[6], 1.0, &mut rng),
            Tensor::randn("c", &[2, 2], 1.0, &mut rng),
        ];
        let arena = Arena::of(&params);
        assert_eq!(arena.total, 22);
        assert_eq!(arena.span_cuts(), vec![0, 12, 18, 22]);
        let flat = arena.flatten(&params);
        let mut back: Vec<Tensor> = params
            .iter()
            .map(|p| Tensor::zeros(&*p.name, &p.shape))
            .collect();
        arena.unflatten(&flat, &mut back);
        assert_eq!(back, params);
    }

    #[test]
    fn spans_in_requires_tensor_alignment() {
        let arena = toy_arena();
        let (i0, spans) = arena.spans_in(12, 22);
        assert_eq!(i0, 1);
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].name, "b");
        let (_, all) = arena.spans_in(0, 22);
        assert_eq!(all.len(), 3);
        let (_, none) = arena.spans_in(5, 5);
        assert!(none.is_empty());
        assert!(std::panic::catch_unwind(|| arena.spans_in(3, 22).0)
            .is_err());
        assert!(std::panic::catch_unwind(|| arena.spans_in(0, 13).0)
            .is_err());
    }

    #[test]
    fn state_dict_basics() {
        let mut sd = StateDict::new();
        sd.insert("m", &[3], vec![1.0, 2.0, 3.0]);
        sd.set_step(5);
        assert_eq!(sd.len(), 2);
        assert_eq!(sd.step().unwrap(), 5);
        assert_eq!(sd.data("m", 3).unwrap(), &[1.0, 2.0, 3.0]);
        assert!(sd.data("m", 4).is_err());
        assert!(sd.require("v").is_err());
        // Round-trip through tensors.
        let back =
            StateDict::from_tensors(sd.clone().into_tensors()).unwrap();
        assert_eq!(back, sd);
        // Duplicate keys are loud.
        let dup = vec![Tensor::zeros("m", &[1]), Tensor::zeros("m", &[1])];
        assert!(StateDict::from_tensors(dup).is_err());
    }

    #[test]
    fn state_dict_rank_routing() {
        let mut sd = StateDict::new();
        sd.insert("rank0/m", &[2], vec![1.0, 2.0]);
        sd.insert("rank1/m", &[2], vec![3.0, 4.0]);
        sd.insert("rank1/v", &[1], vec![5.0]);
        let r1 = sd.sub_dict("rank1/");
        assert_eq!(r1.len(), 2);
        assert_eq!(r1.data("m", 2).unwrap(), &[3.0, 4.0]);
        assert_eq!(r1.data("v", 1).unwrap(), &[5.0]);
        assert_eq!(sd.sub_dict("rank9/").len(), 0);
    }

    #[test]
    fn state_dict_remove_peels_one_entry() {
        let mut sd = StateDict::new();
        sd.insert("m", &[2], vec![1.0, 2.0]);
        sd.insert("ef/residual", &[3], vec![0.5, 0.0, -0.5]);
        let t = sd.remove("ef/residual").unwrap();
        assert_eq!(t.data, vec![0.5, 0.0, -0.5]);
        assert_eq!(sd.len(), 1);
        assert!(sd.get("ef/residual").is_none());
        assert!(sd.remove("ef/residual").is_none());
        // The key can be re-inserted after removal.
        sd.insert("ef/residual", &[1], vec![9.0]);
        assert_eq!(sd.len(), 2);
    }

    #[test]
    fn step_tensor_roundtrips_beyond_f32_integer_range() {
        for t in [0u64, 1, 1 << 20, (1 << 24) + 1, (1 << 30) + 12345,
                  (1 << 40) + 7] {
            let enc = step_tensor(t);
            assert_eq!(decode_step(&enc).unwrap(), t, "t = {t}");
        }
        assert!(decode_step(&Tensor::zeros("w", &[3])).is_err());
    }
}
