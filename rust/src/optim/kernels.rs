//! SIMD kernel layer: fused one-pass update sweeps for the hot
//! roster members, behind a runtime scalar/vector dispatch.
//!
//! Stable Rust has no `portable_simd`, so the vector path is the
//! classic hand-unrolled form: fixed [`LANES`]-wide blocks staged
//! through arrays, which LLVM's loop/SLP vectorizers turn into wide
//! registers (including the sqrt/div chains in the Adam family).
//! Both dispatches share one `#[inline(always)]` per-element
//! function per kernel, so elementwise math is *bitwise identical*
//! across dispatch and across any chunking — the segment-partition
//! and N-vs-1 dist invariants survive vectorization untouched.
//!
//! Reductions are the exception: the vector path keeps [`LANES`]
//! independent accumulators and tree-folds them at the end, which
//! reassociates the sum relative to the scalar left fold. That is
//! inherent to vectorized reduction, so those kernels ([`sq_mean`],
//! [`sq_eps_sum`]) carry a documented ULP tolerance instead of a
//! bitwise contract (see DESIGN.md "Kernel layer"). The column fold
//! ([`col_sq_accumulate`]) is *not* a reassociating reduction — each
//! column's partial sums accumulate in row order under both
//! dispatches — so it stays bitwise.
//!
//! Dispatch is resolved from a thread-local policy (config key
//! `simd=auto|on|off`) exactly once per arena, at optimizer
//! construction; workers spawned afterwards inherit the decision
//! through the constructed optimizer, never re-consult the policy.

use std::cell::Cell;
use std::sync::OnceLock;

use anyhow::{bail, Result};

/// Lane width of the hand-unrolled vector path (f32 × 8 = one AVX2
/// register; narrower targets split it, wider ones fuse pairs).
pub const LANES: usize = 8;

/// The `simd` config key: `auto` (default) | `on` | `off`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdPolicy {
    Auto,
    On,
    Off,
}

impl SimdPolicy {
    pub fn parse(s: &str) -> Result<SimdPolicy> {
        Ok(match s {
            "auto" => SimdPolicy::Auto,
            "on" => SimdPolicy::On,
            "off" => SimdPolicy::Off,
            other => bail!("simd must be auto|on|off, got {other:?}"),
        })
    }
}

thread_local! {
    static POLICY: Cell<SimdPolicy> =
        const { Cell::new(SimdPolicy::Auto) };
}

/// Set the kernel dispatch policy for optimizers constructed on this
/// thread from here on. Thread-local so parallel tests pinning
/// `on`/`off` cannot race each other; trainers and the dist engine
/// construct every optimizer on the driver thread, so one call there
/// covers the whole run.
pub fn set_policy(p: SimdPolicy) {
    POLICY.with(|c| c.set(p));
}

/// The policy optimizers constructed on this thread will resolve.
pub fn policy() -> SimdPolicy {
    POLICY.with(|c| c.get())
}

/// A resolved kernel dispatch, cached per optimizer at construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dispatch {
    Scalar,
    Vector,
}

impl Dispatch {
    /// Resolve the thread-local policy once per arena (called from
    /// optimizer constructors). `auto` always takes the vector path:
    /// a size heuristic here would hand a model arena and its ZeRO
    /// shards different dispatches — and the vectorized block
    /// reductions different summation orders — silently breaking the
    /// N-vs-1 bit-exactness invariant. `_total` is the hook for a
    /// future heuristic that respects that constraint (it would have
    /// to key on per-block size, which shards preserve, never on
    /// arena size, which they do not).
    pub fn for_arena(_total: usize) -> Dispatch {
        match policy() {
            SimdPolicy::Off => Dispatch::Scalar,
            SimdPolicy::On | SimdPolicy::Auto => Dispatch::Vector,
        }
    }
}

// ---------------------------------------------------------------- AdamW

/// Per-step AdamW constants, precomputed once per `begin_step` so
/// the sweep does no per-element recomputation: bias corrections,
/// the decoupled-decay factor `wd = 1 - lr·λ`, and the gradient
/// scale (micro-batch averaging × clip factor) folded into every
/// gradient read.
#[derive(Debug, Clone, Copy)]
pub struct AdamCoef {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub bc1: f32,
    pub bc2: f32,
    pub wd: f32,
    pub lr: f32,
    pub gscale: f32,
}

#[inline(always)]
fn adamw_el(pi: f32, gi: f32, mi: &mut f32, vi: &mut f32,
            k: &AdamCoef) -> f32 {
    let gi = gi * k.gscale;
    let mn = k.beta1 * *mi + (1.0 - k.beta1) * gi;
    let vn = k.beta2 * *vi + (1.0 - k.beta2) * gi * gi;
    *mi = mn;
    *vi = vn;
    pi * k.wd - k.lr * (mn * k.bc1) / ((vn * k.bc2).sqrt() + k.eps)
}

/// Fused AdamW sweep: moments, bias correction, decay, and the
/// folded gradient scale in one read-modify-write pass.
pub fn adamw_step(d: Dispatch, p: &mut [f32], g: &[f32], m: &mut [f32],
                  v: &mut [f32], k: &AdamCoef) {
    debug_assert!(g.len() == p.len() && m.len() == p.len()
                  && v.len() == p.len());
    match d {
        Dispatch::Scalar => {
            for i in 0..p.len() {
                p[i] = adamw_el(p[i], g[i], &mut m[i], &mut v[i], k);
            }
        }
        Dispatch::Vector => {
            let n = p.len();
            let main = n - n % LANES;
            let mut i = 0;
            while i < main {
                let mut pl = [0.0f32; LANES];
                let mut gl = [0.0f32; LANES];
                let mut ml = [0.0f32; LANES];
                let mut vl = [0.0f32; LANES];
                pl.copy_from_slice(&p[i..i + LANES]);
                gl.copy_from_slice(&g[i..i + LANES]);
                ml.copy_from_slice(&m[i..i + LANES]);
                vl.copy_from_slice(&v[i..i + LANES]);
                for l in 0..LANES {
                    pl[l] = adamw_el(pl[l], gl[l], &mut ml[l],
                                     &mut vl[l], k);
                }
                p[i..i + LANES].copy_from_slice(&pl);
                m[i..i + LANES].copy_from_slice(&ml);
                v[i..i + LANES].copy_from_slice(&vl);
                i += LANES;
            }
            for j in main..n {
                p[j] = adamw_el(p[j], g[j], &mut m[j], &mut v[j], k);
            }
        }
    }
}

// ------------------------------------------------------------ Adam-mini

/// Per-step Adam-mini constants for the elementwise half of a block
/// update (the block's `denom` is computed from the reduction first).
#[derive(Debug, Clone, Copy)]
pub struct MiniCoef {
    pub beta1: f32,
    pub bc1: f32,
    pub wd: f32,
    pub lr: f32,
    pub gscale: f32,
}

#[inline(always)]
fn mini_el(pi: f32, gi: f32, mi: &mut f32, denom: f32,
           k: &MiniCoef) -> f32 {
    let gi = gi * k.gscale;
    let mn = k.beta1 * *mi + (1.0 - k.beta1) * gi;
    *mi = mn;
    pi * k.wd - k.lr * (mn * k.bc1) / denom
}

/// Elementwise half of one Adam-mini block: first-moment EMA and the
/// parameter update against the block-shared `denom`. Bitwise across
/// dispatch (no reduction here).
pub fn adam_mini_block(d: Dispatch, p: &mut [f32], g: &[f32],
                       m: &mut [f32], denom: f32, k: &MiniCoef) {
    debug_assert!(g.len() == p.len() && m.len() == p.len());
    match d {
        Dispatch::Scalar => {
            for i in 0..p.len() {
                p[i] = mini_el(p[i], g[i], &mut m[i], denom, k);
            }
        }
        Dispatch::Vector => {
            let n = p.len();
            let main = n - n % LANES;
            let mut i = 0;
            while i < main {
                let mut pl = [0.0f32; LANES];
                let mut gl = [0.0f32; LANES];
                let mut ml = [0.0f32; LANES];
                pl.copy_from_slice(&p[i..i + LANES]);
                gl.copy_from_slice(&g[i..i + LANES]);
                ml.copy_from_slice(&m[i..i + LANES]);
                for l in 0..LANES {
                    pl[l] = mini_el(pl[l], gl[l], &mut ml[l], denom, k);
                }
                p[i..i + LANES].copy_from_slice(&pl);
                m[i..i + LANES].copy_from_slice(&ml);
                i += LANES;
            }
            for j in main..n {
                p[j] = mini_el(p[j], g[j], &mut m[j], denom, k);
            }
        }
    }
}

// ------------------------------------------------------------ reductions

/// Deterministic tree fold of the lane accumulators. Fixed shape, so
/// the vector reduction is reproducible run-to-run — it differs from
/// the scalar left fold only by reassociation (ULP-level).
#[inline(always)]
fn fold_lanes(acc: [f32; LANES]) -> f32 {
    ((acc[0] + acc[1]) + (acc[2] + acc[3]))
        + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

#[inline(always)]
fn sq_sum(d: Dispatch, g: &[f32], gscale: f32) -> f32 {
    match d {
        Dispatch::Scalar => {
            let mut s = 0.0f32;
            for &x in g {
                let y = x * gscale;
                s += y * y;
            }
            s
        }
        Dispatch::Vector => {
            let n = g.len();
            let main = n - n % LANES;
            let mut acc = [0.0f32; LANES];
            let mut i = 0;
            while i < main {
                for l in 0..LANES {
                    let y = g[i + l] * gscale;
                    acc[l] += y * y;
                }
                i += LANES;
            }
            let mut rem = 0.0f32;
            for &x in &g[main..] {
                let y = x * gscale;
                rem += y * y;
            }
            // Blocks shorter than LANES fold all-zero lanes: the
            // result is exactly the scalar remainder sum, so small
            // blocks stay bitwise even under Vector dispatch.
            fold_lanes(acc) + rem
        }
    }
}

/// Mean of squared (scaled) gradients over a block — Adam-mini's
/// default `vb` statistic. Vector dispatch reassociates the sum
/// (ULP tolerance); empty blocks yield 0 like `ReduceOp::Mean`.
pub fn sq_mean(d: Dispatch, g: &[f32], gscale: f32) -> f32 {
    if g.is_empty() {
        return 0.0;
    }
    sq_sum(d, g, gscale) / g.len() as f32
}

/// Adafactor row-statistic inner fold: `Σ (g·gscale)² + eps1` over
/// one row. Vector dispatch reassociates (ULP tolerance).
pub fn sq_eps_sum(d: Dispatch, row: &[f32], gscale: f32,
                  eps1: f32) -> f32 {
    match d {
        Dispatch::Scalar => {
            let mut s = 0.0f32;
            for &x in row {
                let y = x * gscale;
                s += y * y + eps1;
            }
            s
        }
        Dispatch::Vector => {
            let n = row.len();
            let main = n - n % LANES;
            let mut acc = [0.0f32; LANES];
            let mut i = 0;
            while i < main {
                for l in 0..LANES {
                    let y = row[i + l] * gscale;
                    acc[l] += y * y + eps1;
                }
                i += LANES;
            }
            let mut rem = 0.0f32;
            for &x in &row[main..] {
                let y = x * gscale;
                rem += y * y + eps1;
            }
            fold_lanes(acc) + rem
        }
    }
}

/// One row's contribution to Adafactor's column statistics:
/// `acc[ci] += (row[ci]·gscale)² + eps1`, vectorized across columns.
/// Each column's partial sums land in row order under both
/// dispatches — this is a strided elementwise accumulate, not a
/// reassociating reduction, so it is bitwise.
pub fn col_sq_accumulate(d: Dispatch, row: &[f32], gscale: f32,
                         eps1: f32, acc: &mut [f32]) {
    debug_assert_eq!(row.len(), acc.len());
    match d {
        Dispatch::Scalar => {
            for ci in 0..row.len() {
                let y = row[ci] * gscale;
                acc[ci] += y * y + eps1;
            }
        }
        Dispatch::Vector => {
            let n = row.len();
            let main = n - n % LANES;
            let mut i = 0;
            while i < main {
                let mut rl = [0.0f32; LANES];
                let mut al = [0.0f32; LANES];
                rl.copy_from_slice(&row[i..i + LANES]);
                al.copy_from_slice(&acc[i..i + LANES]);
                for l in 0..LANES {
                    let y = rl[l] * gscale;
                    al[l] += y * y + eps1;
                }
                acc[i..i + LANES].copy_from_slice(&al);
                i += LANES;
            }
            for ci in main..n {
                let y = row[ci] * gscale;
                acc[ci] += y * y + eps1;
            }
        }
    }
}

// ----------------------------------------------------------- Lion / SGD

/// Lion per-step constants (`wd = 1 - lr·λ`).
#[derive(Debug, Clone, Copy)]
pub struct LionCoef {
    pub beta1: f32,
    pub beta2: f32,
    pub wd: f32,
    pub lr: f32,
    pub gscale: f32,
}

#[inline(always)]
fn lion_el(pi: f32, gi: f32, mi: &mut f32, k: &LionCoef) -> f32 {
    let gi = gi * k.gscale;
    let c = k.beta1 * *mi + (1.0 - k.beta1) * gi;
    let out = pi * k.wd - k.lr * c.signum();
    *mi = k.beta2 * *mi + (1.0 - k.beta2) * gi;
    out
}

/// Fused Lion sweep (sign update reads the pre-update momentum; the
/// β₂ EMA writes after, matching the reference asymmetry).
pub fn lion_step(d: Dispatch, p: &mut [f32], g: &[f32], m: &mut [f32],
                 k: &LionCoef) {
    debug_assert!(g.len() == p.len() && m.len() == p.len());
    match d {
        Dispatch::Scalar => {
            for i in 0..p.len() {
                p[i] = lion_el(p[i], g[i], &mut m[i], k);
            }
        }
        Dispatch::Vector => {
            let n = p.len();
            let main = n - n % LANES;
            let mut i = 0;
            while i < main {
                let mut pl = [0.0f32; LANES];
                let mut gl = [0.0f32; LANES];
                let mut ml = [0.0f32; LANES];
                pl.copy_from_slice(&p[i..i + LANES]);
                gl.copy_from_slice(&g[i..i + LANES]);
                ml.copy_from_slice(&m[i..i + LANES]);
                for l in 0..LANES {
                    pl[l] = lion_el(pl[l], gl[l], &mut ml[l], k);
                }
                p[i..i + LANES].copy_from_slice(&pl);
                m[i..i + LANES].copy_from_slice(&ml);
                i += LANES;
            }
            for j in main..n {
                p[j] = lion_el(p[j], g[j], &mut m[j], k);
            }
        }
    }
}

#[inline(always)]
fn sgd_el(pi: f32, gi: f32, bi: &mut f32, momentum: f32, lr: f32,
          gscale: f32) -> f32 {
    let v = momentum * *bi + gi * gscale;
    *bi = v;
    pi - lr * v
}

/// Fused SGD-with-momentum sweep.
pub fn sgd_step(d: Dispatch, p: &mut [f32], g: &[f32], buf: &mut [f32],
                momentum: f32, lr: f32, gscale: f32) {
    debug_assert!(g.len() == p.len() && buf.len() == p.len());
    match d {
        Dispatch::Scalar => {
            for i in 0..p.len() {
                p[i] = sgd_el(p[i], g[i], &mut buf[i], momentum, lr,
                              gscale);
            }
        }
        Dispatch::Vector => {
            let n = p.len();
            let main = n - n % LANES;
            let mut i = 0;
            while i < main {
                let mut pl = [0.0f32; LANES];
                let mut gl = [0.0f32; LANES];
                let mut bl = [0.0f32; LANES];
                pl.copy_from_slice(&p[i..i + LANES]);
                gl.copy_from_slice(&g[i..i + LANES]);
                bl.copy_from_slice(&buf[i..i + LANES]);
                for l in 0..LANES {
                    pl[l] = sgd_el(pl[l], gl[l], &mut bl[l], momentum,
                                   lr, gscale);
                }
                p[i..i + LANES].copy_from_slice(&pl);
                buf[i..i + LANES].copy_from_slice(&bl);
                i += LANES;
            }
            for j in main..n {
                p[j] = sgd_el(p[j], g[j], &mut buf[j], momentum, lr,
                              gscale);
            }
        }
    }
}

#[inline(always)]
fn adagrad_el(pi: f32, gi: f32, ai: &mut f32, bi: &mut f32,
              momentum: f32, eps: f32, lr: f32, gscale: f32) -> f32 {
    let gi = gi * gscale;
    *ai += gi * gi;
    let u = gi / (ai.sqrt() + eps);
    *bi = momentum * *bi + u;
    pi - lr * *bi
}

/// Fused AdaGrad-with-momentum sweep.
pub fn adagrad_step(d: Dispatch, p: &mut [f32], g: &[f32],
                    acc: &mut [f32], buf: &mut [f32], momentum: f32,
                    eps: f32, lr: f32, gscale: f32) {
    debug_assert!(g.len() == p.len() && acc.len() == p.len()
                  && buf.len() == p.len());
    match d {
        Dispatch::Scalar => {
            for i in 0..p.len() {
                p[i] = adagrad_el(p[i], g[i], &mut acc[i], &mut buf[i],
                                  momentum, eps, lr, gscale);
            }
        }
        Dispatch::Vector => {
            let n = p.len();
            let main = n - n % LANES;
            let mut i = 0;
            while i < main {
                let mut pl = [0.0f32; LANES];
                let mut gl = [0.0f32; LANES];
                let mut al = [0.0f32; LANES];
                let mut bl = [0.0f32; LANES];
                pl.copy_from_slice(&p[i..i + LANES]);
                gl.copy_from_slice(&g[i..i + LANES]);
                al.copy_from_slice(&acc[i..i + LANES]);
                bl.copy_from_slice(&buf[i..i + LANES]);
                for l in 0..LANES {
                    pl[l] = adagrad_el(pl[l], gl[l], &mut al[l],
                                       &mut bl[l], momentum, eps, lr,
                                       gscale);
                }
                p[i..i + LANES].copy_from_slice(&pl);
                acc[i..i + LANES].copy_from_slice(&al);
                buf[i..i + LANES].copy_from_slice(&bl);
                i += LANES;
            }
            for j in main..n {
                p[j] = adagrad_el(p[j], g[j], &mut acc[j], &mut buf[j],
                                  momentum, eps, lr, gscale);
            }
        }
    }
}

// ---------------------------------------------------------- calibration

/// Measured fused-kernel cost in ns per element, calibrated once per
/// process (best of 5 timed vector AdamW sweeps over a 64 K-element
/// arena, after one warm pass) and cached. The dist engine feeds
/// this into `ComputeModel::step_ns_per_elem` so the overlapped /
/// deferred / sequential clocks in `StepTiming` price optimizer
/// compute at the real post-SIMD rate instead of the 1 ns/elem
/// placeholder. Clamped to a sane range so a preempted probe cannot
/// poison the timeline model.
pub fn measured_step_ns_per_elem() -> f64 {
    static CAL: OnceLock<f64> = OnceLock::new();
    *CAL.get_or_init(|| {
        const N: usize = 1 << 16;
        let g: Vec<f32> = (0..N)
            .map(|i| ((i % 997) as f32 - 498.0) * 1e-5)
            .collect();
        let mut p = vec![0.1f32; N];
        let mut m = vec![0.0f32; N];
        let mut v = vec![0.0f32; N];
        let k = AdamCoef {
            beta1: 0.9,
            beta2: 0.95,
            eps: 1e-8,
            bc1: 1.0,
            bc2: 1.0,
            wd: 1.0,
            lr: 1e-3,
            gscale: 1.0,
        };
        adamw_step(Dispatch::Vector, &mut p, &g, &mut m, &mut v, &k);
        let mut best = f64::INFINITY;
        for _ in 0..5 {
            let t = std::time::Instant::now();
            adamw_step(Dispatch::Vector, &mut p, &g, &mut m, &mut v,
                       &k);
            best = best.min(t.elapsed().as_nanos() as f64 / N as f64);
        }
        best.clamp(0.02, 50.0)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn probe(n: usize, seed: u32) -> Vec<f32> {
        let mut x = seed;
        (0..n)
            .map(|_| {
                x = x.wrapping_mul(1664525).wrapping_add(1013904223);
                (x >> 8) as f32 / (1u32 << 24) as f32 - 0.5
            })
            .collect()
    }

    #[test]
    fn policy_parses_and_rejects() {
        assert_eq!(SimdPolicy::parse("auto").unwrap(), SimdPolicy::Auto);
        assert_eq!(SimdPolicy::parse("on").unwrap(), SimdPolicy::On);
        assert_eq!(SimdPolicy::parse("off").unwrap(), SimdPolicy::Off);
        assert!(SimdPolicy::parse("fast").is_err());
    }

    #[test]
    fn dispatch_resolves_thread_local_policy() {
        set_policy(SimdPolicy::Off);
        assert_eq!(Dispatch::for_arena(1 << 20), Dispatch::Scalar);
        set_policy(SimdPolicy::On);
        assert_eq!(Dispatch::for_arena(3), Dispatch::Vector);
        set_policy(SimdPolicy::Auto);
        // `auto` is size-independent by design (N-vs-1 invariant).
        assert_eq!(Dispatch::for_arena(1), Dispatch::Vector);
    }

    #[test]
    fn adamw_vector_is_bitwise_scalar_on_odd_lengths() {
        for n in [1usize, 7, 8, 9, 64, 103] {
            let g = probe(n, 1);
            let k = AdamCoef {
                beta1: 0.9, beta2: 0.95, eps: 1e-8,
                bc1: 1.0 / (1.0 - 0.9f32), bc2: 1.0 / (1.0 - 0.95f32),
                wd: 1.0 - 1e-3 * 0.1, lr: 1e-3, gscale: 0.25,
            };
            let (mut pa, mut ma, mut va) =
                (probe(n, 2), probe(n, 3), vec![0.5f32; n]);
            let (mut pb, mut mb, mut vb) =
                (pa.clone(), ma.clone(), va.clone());
            adamw_step(Dispatch::Scalar, &mut pa, &g, &mut ma,
                       &mut va, &k);
            adamw_step(Dispatch::Vector, &mut pb, &g, &mut mb,
                       &mut vb, &k);
            assert_eq!(pa, pb, "n={n}");
            assert_eq!(ma, mb, "n={n}");
            assert_eq!(va, vb, "n={n}");
        }
    }

    #[test]
    fn elementwise_kernels_are_bitwise_across_dispatch() {
        let n = 101;
        let g = probe(n, 11);
        // Lion.
        let lk = LionCoef { beta1: 0.9, beta2: 0.99, wd: 0.999,
                            lr: 1e-3, gscale: 0.5 };
        let (mut pa, mut ma) = (probe(n, 12), probe(n, 13));
        let (mut pb, mut mb) = (pa.clone(), ma.clone());
        lion_step(Dispatch::Scalar, &mut pa, &g, &mut ma, &lk);
        lion_step(Dispatch::Vector, &mut pb, &g, &mut mb, &lk);
        assert_eq!(pa, pb);
        assert_eq!(ma, mb);
        // SGD.
        let (mut pa, mut ba) = (probe(n, 14), probe(n, 15));
        let (mut pb, mut bb) = (pa.clone(), ba.clone());
        sgd_step(Dispatch::Scalar, &mut pa, &g, &mut ba, 0.9, 1e-2,
                 0.125);
        sgd_step(Dispatch::Vector, &mut pb, &g, &mut bb, 0.9, 1e-2,
                 0.125);
        assert_eq!(pa, pb);
        assert_eq!(ba, bb);
        // AdaGrad.
        let (mut pa, mut aa, mut ba) =
            (probe(n, 16), vec![0.1f32; n], probe(n, 17));
        let (mut pb, mut ab, mut bb) =
            (pa.clone(), aa.clone(), ba.clone());
        adagrad_step(Dispatch::Scalar, &mut pa, &g, &mut aa, &mut ba,
                     0.9, 1e-8, 1e-2, 2.0);
        adagrad_step(Dispatch::Vector, &mut pb, &g, &mut ab, &mut bb,
                     0.9, 1e-8, 1e-2, 2.0);
        assert_eq!(pa, pb);
        assert_eq!(aa, ab);
        assert_eq!(ba, bb);
        // Adam-mini elementwise half.
        let mk = MiniCoef { beta1: 0.9, bc1: 10.0, wd: 0.999,
                            lr: 1e-3, gscale: 0.5 };
        let (mut pa, mut ma) = (probe(n, 18), probe(n, 19));
        let (mut pb, mut mb) = (pa.clone(), ma.clone());
        adam_mini_block(Dispatch::Scalar, &mut pa, &g, &mut ma, 0.7,
                        &mk);
        adam_mini_block(Dispatch::Vector, &mut pb, &g, &mut mb, 0.7,
                        &mk);
        assert_eq!(pa, pb);
        assert_eq!(ma, mb);
        // Column accumulate (strided elementwise, bitwise by design).
        let rows: Vec<Vec<f32>> =
            (0..7).map(|r| probe(13, 30 + r)).collect();
        let mut ca = vec![0.0f32; 13];
        let mut cb = vec![0.0f32; 13];
        for row in &rows {
            col_sq_accumulate(Dispatch::Scalar, row, 0.5, 1e-30,
                              &mut ca);
            col_sq_accumulate(Dispatch::Vector, row, 0.5, 1e-30,
                              &mut cb);
        }
        assert_eq!(ca, cb);
    }

    #[test]
    fn reductions_match_scalar_within_ulp_tolerance() {
        for n in [1usize, 5, 8, 65, 1000] {
            let g = probe(n, 21);
            let a = sq_mean(Dispatch::Scalar, &g, 0.5);
            let b = sq_mean(Dispatch::Vector, &g, 0.5);
            let tol = 1e-6 * a.abs().max(1e-12);
            assert!((a - b).abs() <= tol, "sq_mean n={n}: {a} vs {b}");
            let a = sq_eps_sum(Dispatch::Scalar, &g, 0.5, 1e-30);
            let b = sq_eps_sum(Dispatch::Vector, &g, 0.5, 1e-30);
            let tol = 1e-6 * a.abs().max(1e-12);
            assert!((a - b).abs() <= tol,
                    "sq_eps_sum n={n}: {a} vs {b}");
        }
        // Sub-LANES blocks fold zero lanes: exactly the scalar sum.
        let g = probe(5, 22);
        assert_eq!(sq_mean(Dispatch::Scalar, &g, 1.0),
                   sq_mean(Dispatch::Vector, &g, 1.0));
        assert_eq!(sq_mean(Dispatch::Vector, &[], 1.0), 0.0);
    }

    #[test]
    fn calibration_is_cached_and_sane() {
        let a = measured_step_ns_per_elem();
        let b = measured_step_ns_per_elem();
        assert_eq!(a, b);
        assert!((0.02..=50.0).contains(&a));
    }
}
