//! SGD with momentum, and the "blockwise GD" method of the paper's
//! case studies (Fig 4 green line, Fig 14 / Appendix D.1 Exp 2): plain
//! gradient descent where each Hessian block gets its own fixed
//! learning-rate multiplier.

use super::Optimizer;
use crate::partition::BlockView;
use crate::tensor::Tensor;

/// Heavy-ball SGD.
pub struct Sgd {
    momentum: f32,
    buf: Vec<Tensor>,
    initialized: bool,
}

impl Sgd {
    pub fn new(momentum: f32, params: &[Tensor]) -> Sgd {
        Sgd {
            momentum,
            buf: params
                .iter()
                .map(|p| Tensor::zeros(&*p.name, &p.shape))
                .collect(),
            initialized: false,
        }
    }
}

impl Optimizer for Sgd {
    fn name(&self) -> String {
        "sgd".into()
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        for ((p, g), b) in params.iter_mut().zip(grads).zip(&mut self.buf) {
            for i in 0..p.data.len() {
                let v = if self.initialized {
                    self.momentum * b.data[i] + g.data[i]
                } else {
                    g.data[i]
                };
                b.data[i] = v;
                p.data[i] -= lr * v;
            }
        }
        self.initialized = true;
    }

    fn state_bytes(&self) -> usize {
        self.buf.iter().map(Tensor::numel).sum::<usize>() * 4
    }
}

/// Blockwise GD: update for block b is `lr * block_lr[b] * g` — the
/// "collect the optimal per-block learning rates" method the paper uses
/// to show a single good lr per dense Hessian block beats Adam.
pub struct BlockwiseGd {
    spec: Vec<BlockView>,
    /// Per-tensor, per-block lr multipliers (grid-searched by callers).
    pub block_lrs: Vec<Vec<f32>>,
}

impl BlockwiseGd {
    pub fn new(spec: Vec<BlockView>) -> BlockwiseGd {
        let block_lrs = spec.iter().map(|b| vec![1.0; b.num_blocks])
            .collect();
        BlockwiseGd { spec, block_lrs }
    }

    pub fn with_lrs(spec: Vec<BlockView>, block_lrs: Vec<Vec<f32>>)
        -> BlockwiseGd {
        assert_eq!(spec.len(), block_lrs.len());
        for (s, l) in spec.iter().zip(&block_lrs) {
            assert_eq!(s.num_blocks, l.len());
        }
        BlockwiseGd { spec, block_lrs }
    }
}

impl Optimizer for BlockwiseGd {
    fn name(&self) -> String {
        "blockwise_gd".into()
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        for (i, bv) in self.spec.iter().enumerate() {
            let p = &mut params[i];
            let g = &grads[i];
            let bs = bv.block_size;
            for b in 0..bv.num_blocks {
                let s = lr * self.block_lrs[i][b];
                for j in b * bs..(b + 1) * bs {
                    p.data[j] -= s * g.data[j];
                }
            }
        }
    }

    fn state_bytes(&self) -> usize {
        self.block_lrs.iter().map(Vec::len).sum::<usize>() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::Category;

    #[test]
    fn sgd_plain_step() {
        let mut params = vec![Tensor::new("w", &[2], vec![1.0, 1.0])];
        let grads = vec![Tensor::new("w", &[2], vec![0.5, -0.5])];
        let mut opt = Sgd::new(0.0, &params);
        opt.step(&mut params, &grads, 0.1);
        assert!((params[0].data[0] - 0.95).abs() < 1e-7);
        assert!((params[0].data[1] - 1.05).abs() < 1e-7);
    }

    #[test]
    fn sgd_momentum_accumulates() {
        let mut params = vec![Tensor::new("w", &[1], vec![0.0])];
        let g = vec![Tensor::new("w", &[1], vec![1.0])];
        let mut opt = Sgd::new(0.5, &params);
        opt.step(&mut params, &g, 1.0); // v=1, w=-1
        opt.step(&mut params, &g, 1.0); // v=1.5, w=-2.5
        assert!((params[0].data[0] + 2.5).abs() < 1e-6);
    }

    #[test]
    fn blockwise_gd_uses_per_block_lr() {
        let spec = vec![BlockView {
            name: "w".into(),
            shape: vec![4],
            num_blocks: 2,
            block_size: 2,
            category: Category::Whole,
        }];
        let mut opt =
            BlockwiseGd::with_lrs(spec, vec![vec![1.0, 10.0]]);
        let mut params = vec![Tensor::zeros("w", &[4])];
        let grads = vec![Tensor::ones("w", &[4])];
        opt.step(&mut params, &grads, 0.1);
        assert_eq!(params[0].data, vec![-0.1, -0.1, -1.0, -1.0]);
    }
}
