//! SGD with momentum, and the "blockwise GD" method of the paper's
//! case studies (Fig 4 green line, Fig 14 / Appendix D.1 Exp 2): plain
//! gradient descent where each Hessian block gets its own fixed
//! learning-rate multiplier.

use std::sync::Arc;

use anyhow::Result;

use super::core::{check_state_len, Arena, GradView, Granularity,
                  Optimizer, ParamView, StateDict};
use super::kernels::{self, Dispatch};
use crate::partition::BlockView;
use crate::tensor::Tensor;

/// Heavy-ball SGD. State: one arena-flat momentum buffer (zero-init,
/// so the first step's `momentum·0 + g = g` needs no special case).
pub struct Sgd {
    momentum: f32,
    arena: Arc<Arena>,
    dispatch: Dispatch,
    buf: Vec<f32>,
}

impl Sgd {
    pub fn new(momentum: f32, params: &[Tensor]) -> Sgd {
        let arena = Arc::new(Arena::of(params));
        let n = arena.total;
        Sgd { momentum, arena, dispatch: Dispatch::for_arena(n),
              buf: vec![0.0; n] }
    }

    fn step_impl(&mut self, params: ParamView<'_>, grads: GradView<'_>,
                 lr: f32, gscale: f32) {
        assert_eq!(params.range(), (grads.lo(), grads.hi()));
        let (lo, hi) = params.range();
        kernels::sgd_step(self.dispatch, params.data, grads.data,
                          &mut self.buf[lo..hi], self.momentum, lr,
                          gscale);
    }
}

impl Optimizer for Sgd {
    fn name(&self) -> String {
        "sgd".into()
    }

    fn arena(&self) -> &Arc<Arena> {
        &self.arena
    }

    fn granularity(&self) -> Granularity {
        Granularity::Element
    }

    fn step_segment(&mut self, params: ParamView<'_>, grads: GradView<'_>,
                    lr: f32) {
        self.step_impl(params, grads, lr, 1.0);
    }

    fn step_segment_scaled(&mut self, params: ParamView<'_>,
                           grads: GradView<'_>, lr: f32, gscale: f32) {
        self.step_impl(params, grads, lr, gscale);
    }

    fn state_bytes(&self) -> usize {
        self.buf.len() * 4
    }

    /// Entries: `buf` (the momentum buffer).
    fn state_dict(&self) -> StateDict {
        let mut sd = StateDict::new();
        sd.insert("buf", &[self.buf.len()], self.buf.clone());
        sd
    }

    fn state_len(&self) -> usize {
        1
    }

    fn load_state_dict(&mut self, state: &StateDict) -> Result<()> {
        check_state_len(state, 1, "sgd")?;
        self.buf.copy_from_slice(state.data("buf", self.buf.len())?);
        Ok(())
    }
}

/// Blockwise GD: update for block b is `lr * block_lr[b] * g` — the
/// "collect the optimal per-block learning rates" method the paper uses
/// to show a single good lr per dense Hessian block beats Adam.
/// Memoryless (no state to checkpoint); `block_lrs` is configuration
/// set by the grid-search drivers.
pub struct BlockwiseGd {
    arena: Arc<Arena>,
    /// Flat block grid: block `b` covers `[cuts[b], cuts[b+1])`.
    cuts: Vec<usize>,
    /// Per-tensor, per-block lr multipliers (grid-searched by callers).
    pub block_lrs: Vec<Vec<f32>>,
    /// First flat-block index of each tensor.
    block_offsets: Vec<usize>,
}

impl BlockwiseGd {
    pub fn new(spec: Vec<BlockView>) -> BlockwiseGd {
        let lrs = spec.iter().map(|b| vec![1.0; b.num_blocks]).collect();
        BlockwiseGd::with_lrs(spec, lrs)
    }

    pub fn with_lrs(spec: Vec<BlockView>, block_lrs: Vec<Vec<f32>>)
        -> BlockwiseGd {
        assert_eq!(spec.len(), block_lrs.len());
        for (s, l) in spec.iter().zip(&block_lrs) {
            assert_eq!(s.num_blocks, l.len());
        }
        let arena = Arc::new(Arena::from_shapes(
            spec.iter().map(|b| (b.name.clone(), b.shape.clone()))));
        let mut cuts = vec![0usize];
        let mut block_offsets = Vec::with_capacity(spec.len());
        let mut offset = 0;
        for bv in &spec {
            block_offsets.push(cuts.len() - 1);
            for b in 1..=bv.num_blocks {
                cuts.push(offset + b * bv.block_size);
            }
            offset += bv.num_blocks * bv.block_size;
        }
        BlockwiseGd { arena, cuts, block_lrs, block_offsets }
    }

    /// lr multiplier of flat block `b`.
    fn lr_of(&self, b: usize) -> f32 {
        let i = self.block_offsets.partition_point(|&o| o <= b) - 1;
        self.block_lrs[i][b - self.block_offsets[i]]
    }
}

impl Optimizer for BlockwiseGd {
    fn name(&self) -> String {
        "blockwise_gd".into()
    }

    fn arena(&self) -> &Arc<Arena> {
        &self.arena
    }

    fn granularity(&self) -> Granularity {
        Granularity::Block
    }

    fn segment_cuts(&self) -> Option<Vec<usize>> {
        Some(self.cuts.clone())
    }

    fn step_segment(&mut self, params: ParamView<'_>, grads: GradView<'_>,
                    lr: f32) {
        assert_eq!(params.range(), (grads.lo(), grads.hi()));
        let (lo, hi) = params.range();
        let b0 = self
            .cuts
            .binary_search(&lo)
            .unwrap_or_else(|_| {
                panic!("segment lo {lo} is not on a block boundary")
            });
        let mut b = b0;
        while self.cuts[b] < hi {
            let (blo, bhi) = (self.cuts[b], self.cuts[b + 1]);
            assert!(bhi <= hi,
                    "segment hi {hi} splits block [{blo}, {bhi})");
            let s = lr * self.lr_of(b);
            for j in blo..bhi {
                params.data[j - lo] -= s * grads.data[j - lo];
            }
            b += 1;
        }
    }

    fn state_bytes(&self) -> usize {
        self.block_lrs.iter().map(Vec::len).sum::<usize>() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::Category;

    #[test]
    fn sgd_plain_step() {
        let mut params = vec![Tensor::new("w", &[2], vec![1.0, 1.0])];
        let grads = vec![Tensor::new("w", &[2], vec![0.5, -0.5])];
        let mut opt = Sgd::new(0.0, &params);
        opt.step(&mut params, &grads, 0.1);
        assert!((params[0].data[0] - 0.95).abs() < 1e-7);
        assert!((params[0].data[1] - 1.05).abs() < 1e-7);
    }

    #[test]
    fn sgd_momentum_accumulates() {
        let mut params = vec![Tensor::new("w", &[1], vec![0.0])];
        let g = vec![Tensor::new("w", &[1], vec![1.0])];
        let mut opt = Sgd::new(0.5, &params);
        opt.step(&mut params, &g, 1.0); // v=1, w=-1
        opt.step(&mut params, &g, 1.0); // v=1.5, w=-2.5
        assert!((params[0].data[0] + 2.5).abs() < 1e-6);
    }

    #[test]
    fn sgd_state_roundtrips() {
        let mut params = vec![Tensor::new("w", &[2], vec![0.0, 0.0])];
        let g = vec![Tensor::new("w", &[2], vec![1.0, -2.0])];
        let mut a = Sgd::new(0.9, &params);
        a.step(&mut params, &g, 0.1);
        let sd = a.state_dict();
        assert_eq!(sd.len(), a.state_len());
        let mut pb = params.clone();
        let mut b = Sgd::new(0.9, &pb);
        b.load_state_dict(&sd).unwrap();
        a.step(&mut params, &g, 0.1);
        b.step(&mut pb, &g, 0.1);
        assert_eq!(params, pb);
    }

    #[test]
    fn blockwise_gd_uses_per_block_lr() {
        let spec = vec![BlockView {
            name: "w".into(),
            shape: vec![4],
            num_blocks: 2,
            block_size: 2,
            category: Category::Whole,
        }];
        let mut opt =
            BlockwiseGd::with_lrs(spec, vec![vec![1.0, 10.0]]);
        let mut params = vec![Tensor::zeros("w", &[4])];
        let grads = vec![Tensor::ones("w", &[4])];
        opt.step(&mut params, &grads, 0.1);
        assert_eq!(params[0].data, vec![-0.1, -0.1, -1.0, -1.0]);
    }

    #[test]
    fn blockwise_gd_flat_block_lookup_spans_tensors() {
        let spec = vec![
            BlockView { name: "a".into(), shape: vec![4], num_blocks: 2,
                        block_size: 2, category: Category::Whole },
            BlockView { name: "b".into(), shape: vec![3], num_blocks: 1,
                        block_size: 3, category: Category::Whole },
        ];
        let opt = BlockwiseGd::with_lrs(
            spec, vec![vec![2.0, 3.0], vec![5.0]]);
        assert_eq!(opt.lr_of(0), 2.0);
        assert_eq!(opt.lr_of(1), 3.0);
        assert_eq!(opt.lr_of(2), 5.0);
        assert_eq!(opt.segment_cuts().unwrap(), vec![0, 2, 4, 7]);
    }
}
