//! CAME (Luo et al. 2023): confidence-guided Adafactor variant — the
//! second memory-efficient baseline in the paper's Fig 8/10 comparison.
//!
//! On top of the Adafactor factored second moment it keeps a *second*
//! factored EMA of the instability (û − m)², whose inverse square root
//! scales the momentum update (high residual → low confidence → small
//! step). Tensor-granular: both factored EMAs couple a whole tensor.

use std::sync::Arc;

use anyhow::Result;

use super::core::{check_state_len, Arena, GradView, Granularity,
                  Optimizer, ParamView, StateDict};
use super::Hyper;
use crate::tensor::Tensor;

const EPS1: f32 = 1e-30;
const EPS2: f32 = 1e-16;
const CLIP_D: f32 = 1.0;
/// β3 of the confidence EMA (CAME paper default).
const BETA3: f32 = 0.9999;

struct FactoredPair {
    r: Vec<f32>,
    c: Vec<f32>,
}

enum State {
    Mat {
        v: FactoredPair,
        /// Confidence (instability) factored EMA.
        u: FactoredPair,
        rows: usize,
        cols: usize,
    },
    Vec {
        v: Vec<f32>,
        u: Vec<f32>,
    },
}

pub struct Came {
    hp: Hyper,
    arena: Arc<Arena>,
    /// Momentum, arena-flat.
    m: Vec<f32>,
    state: Vec<State>,
    t: u64,
}

impl Came {
    pub fn new(hp: Hyper, params: &[Tensor]) -> Came {
        let arena = Arc::new(Arena::of(params));
        let state = arena
            .spans
            .iter()
            .map(|s| {
                if s.shape.len() >= 2 {
                    let cols = *s.shape.last().unwrap();
                    let rows = s.len / cols;
                    State::Mat {
                        v: FactoredPair { r: vec![0.0; rows],
                                          c: vec![0.0; cols] },
                        u: FactoredPair { r: vec![0.0; rows],
                                          c: vec![0.0; cols] },
                        rows,
                        cols,
                    }
                } else {
                    State::Vec { v: vec![0.0; s.len],
                                 u: vec![0.0; s.len] }
                }
            })
            .collect();
        let n = arena.total;
        Came { hp, arena, m: vec![0.0; n], state, t: 0 }
    }
}

fn factored_update(f: &mut FactoredPair, sq: &[f32], rows: usize,
                   cols: usize, beta: f32) {
    for ri in 0..rows {
        let mut acc = 0.0;
        for ci in 0..cols {
            acc += sq[ri * cols + ci];
        }
        f.r[ri] = beta * f.r[ri] + (1.0 - beta) * (acc / cols as f32);
    }
    for ci in 0..cols {
        let mut acc = 0.0;
        for ri in 0..rows {
            acc += sq[ri * cols + ci];
        }
        f.c[ci] = beta * f.c[ci] + (1.0 - beta) * (acc / rows as f32);
    }
}

fn r_mean(f: &FactoredPair, rows: usize) -> f32 {
    f.r.iter().sum::<f32>() / rows as f32 + EPS1
}

#[inline]
fn factored_get_pre(f: &FactoredPair, ri: usize, ci: usize,
                    r_mean: f32) -> f32 {
    f.r[ri] * f.c[ci] / r_mean
}

impl Optimizer for Came {
    fn name(&self) -> String {
        "came".into()
    }

    fn arena(&self) -> &Arc<Arena> {
        &self.arena
    }

    fn granularity(&self) -> Granularity {
        Granularity::Tensor
    }

    fn begin_step(&mut self) {
        self.t += 1;
    }

    fn step_segment(&mut self, params: ParamView<'_>, grads: GradView<'_>,
                    lr: f32) {
        debug_assert!(self.t > 0, "step_segment before begin_step");
        assert_eq!(params.range(), (grads.lo(), grads.hi()));
        let (lo, hi) = params.range();
        let arena = Arc::clone(&self.arena);
        let (i0, spans) = arena.spans_in(lo, hi);
        let b1 = self.hp.beta1;
        let b2 = self.hp.beta2;
        let wd = 1.0 - lr * self.hp.weight_decay;

        for (k, sp) in spans.iter().enumerate() {
            let i = i0 + k;
            let a = sp.offset - lo;
            let n = sp.len;
            let g = &grads.data[a..a + n];
            let mut uhat = vec![0.0f32; n];
            match &mut self.state[i] {
                State::Mat { v, rows, cols, .. } => {
                    let (rows, cols) = (*rows, *cols);
                    let sq: Vec<f32> =
                        g.iter().map(|x| x * x + EPS1).collect();
                    factored_update(v, &sq, rows, cols, b2);
                    let rm = r_mean(v, rows);
                    for ri in 0..rows {
                        for ci in 0..cols {
                            let vh = factored_get_pre(v, ri, ci, rm);
                            uhat[ri * cols + ci] =
                                g[ri * cols + ci] / (vh.sqrt() + EPS1);
                        }
                    }
                }
                State::Vec { v, .. } => {
                    for j in 0..n {
                        let gv = g[j];
                        v[j] = b2 * v[j] + (1.0 - b2) * (gv * gv + EPS1);
                        uhat[j] = gv / (v[j].sqrt() + EPS1);
                    }
                }
            }
            // Clip like Adafactor.
            let rms = (uhat.iter().map(|x| x * x).sum::<f32>()
                / n as f32)
                .sqrt();
            let scale = 1.0 / (rms / CLIP_D).max(1.0);
            for x in uhat.iter_mut() {
                *x *= scale;
            }
            // Momentum.
            for j in 0..n {
                self.m[sp.offset + j] =
                    b1 * self.m[sp.offset + j] + (1.0 - b1) * uhat[j];
            }
            // Instability residual (û − m)², factored EMA → confidence.
            let res: Vec<f32> = (0..n)
                .map(|j| {
                    let d = uhat[j] - self.m[sp.offset + j];
                    d * d + EPS2
                })
                .collect();
            match &mut self.state[i] {
                State::Mat { u, rows, cols, .. } => {
                    let (rows, cols) = (*rows, *cols);
                    factored_update(u, &res, rows, cols, BETA3);
                    let rm = r_mean(u, rows);
                    for ri in 0..rows {
                        for ci in 0..cols {
                            let s = factored_get_pre(u, ri, ci, rm);
                            let j = ri * cols + ci;
                            params.data[a + j] = params.data[a + j] * wd
                                - lr * self.m[sp.offset + j]
                                    / (s.sqrt() + EPS1);
                        }
                    }
                }
                State::Vec { u, .. } => {
                    for j in 0..n {
                        u[j] = BETA3 * u[j] + (1.0 - BETA3) * res[j];
                        params.data[a + j] = params.data[a + j] * wd
                            - lr * self.m[sp.offset + j]
                                / (u[j].sqrt() + EPS1);
                    }
                }
            }
        }
    }

    fn state_bytes(&self) -> usize {
        let s: usize = self
            .state
            .iter()
            .map(|s| match s {
                State::Mat { v, u, .. } => {
                    v.r.len() + v.c.len() + u.r.len() + u.c.len()
                }
                State::Vec { v, u } => v.len() + u.len(),
            })
            .sum();
        (s + self.m.len()) * 4
    }

    /// Entries: `m` (arena-flat); per matrix tensor `vr/<name>`,
    /// `vc/<name>`, `ur/<name>`, `uc/<name>`; per vector tensor
    /// `v/<name>`, `u/<name>`; `__step`.
    fn state_dict(&self) -> StateDict {
        let mut sd = StateDict::new();
        sd.insert("m", &[self.m.len()], self.m.clone());
        for (sp, st) in self.arena.spans.iter().zip(&self.state) {
            match st {
                State::Mat { v, u, .. } => {
                    sd.insert(format!("vr/{}", sp.name), &[v.r.len()],
                              v.r.clone());
                    sd.insert(format!("vc/{}", sp.name), &[v.c.len()],
                              v.c.clone());
                    sd.insert(format!("ur/{}", sp.name), &[u.r.len()],
                              u.r.clone());
                    sd.insert(format!("uc/{}", sp.name), &[u.c.len()],
                              u.c.clone());
                }
                State::Vec { v, u } => {
                    sd.insert(format!("v/{}", sp.name), &[v.len()],
                              v.clone());
                    sd.insert(format!("u/{}", sp.name), &[u.len()],
                              u.clone());
                }
            }
        }
        sd.set_step(self.t);
        sd
    }

    fn state_len(&self) -> usize {
        2 + self
            .state
            .iter()
            .map(|s| match s {
                State::Mat { .. } => 4,
                State::Vec { .. } => 2,
            })
            .sum::<usize>()
    }

    fn load_state_dict(&mut self, state: &StateDict) -> Result<()> {
        check_state_len(state, self.state_len(), "came")?;
        self.m.copy_from_slice(state.data("m", self.m.len())?);
        for (sp, st) in self.arena.spans.iter().zip(&mut self.state) {
            match st {
                State::Mat { v, u, .. } => {
                    v.r.copy_from_slice(state.data(
                        &format!("vr/{}", sp.name), v.r.len())?);
                    v.c.copy_from_slice(state.data(
                        &format!("vc/{}", sp.name), v.c.len())?);
                    u.r.copy_from_slice(state.data(
                        &format!("ur/{}", sp.name), u.r.len())?);
                    u.c.copy_from_slice(state.data(
                        &format!("uc/{}", sp.name), u.c.len())?);
                }
                State::Vec { v, u } => {
                    v.copy_from_slice(state.data(
                        &format!("v/{}", sp.name), v.len())?);
                    u.copy_from_slice(state.data(
                        &format!("u/{}", sp.name), u.len())?);
                }
            }
        }
        self.t = state.step()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn descends_on_quadratic() {
        let mut rng = Rng::new(3);
        let hp = Hyper { weight_decay: 0.0, ..Hyper::default() };
        let mut params = vec![Tensor::randn("w", &[8, 8], 1.0, &mut rng)];
        let mut opt = Came::new(hp, &params);
        let start = params[0].sq_norm();
        for _ in 0..300 {
            let g = Tensor::new("w", &[8, 8], params[0].data.clone());
            opt.step(&mut params, &[g], 1e-2);
        }
        assert!(params[0].sq_norm() < 0.2 * start);
    }

    #[test]
    fn state_is_factored_for_matrices() {
        let params = vec![Tensor::zeros("w", &[64, 64])];
        let opt = Came::new(Hyper::default(), &params);
        // m full + two factored pairs (v and confidence).
        assert_eq!(opt.state_bytes(), (64 * 64 + 4 * 64) * 4);
    }

    #[test]
    fn state_roundtrips() {
        let mut rng = Rng::new(6);
        let mut pa = vec![Tensor::randn("w", &[3, 4], 1.0, &mut rng),
                          Tensor::randn("b", &[3], 1.0, &mut rng)];
        let gs: Vec<Vec<Tensor>> = (0..4)
            .map(|_| vec![Tensor::randn("w", &[3, 4], 1.0, &mut rng),
                          Tensor::randn("b", &[3], 1.0, &mut rng)])
            .collect();
        let mut a = Came::new(Hyper::default(), &pa);
        for g in &gs[..2] {
            a.step(&mut pa, g, 1e-2);
        }
        let sd = a.state_dict();
        // m + 4 factors for w + 2 vectors for b + __step.
        assert_eq!(sd.len(), 8);
        assert_eq!(sd.len(), a.state_len());
        let mut pb = pa.clone();
        let mut b = Came::new(Hyper::default(), &pb);
        b.load_state_dict(&sd).unwrap();
        for g in &gs[2..] {
            a.step(&mut pa, g, 1e-2);
            b.step(&mut pb, g, 1e-2);
        }
        assert_eq!(pa, pb);
    }
}
