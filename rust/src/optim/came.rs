//! CAME (Luo et al. 2023): confidence-guided Adafactor variant — the
//! second memory-efficient baseline in the paper's Fig 8/10 comparison.
//!
//! On top of the Adafactor factored second moment it keeps a *second*
//! factored EMA of the instability (û − m)², whose inverse square root
//! scales the momentum update (high residual → low confidence → small
//! step).

use super::{Hyper, Optimizer};
use crate::tensor::Tensor;

const EPS1: f32 = 1e-30;
const EPS2: f32 = 1e-16;
const CLIP_D: f32 = 1.0;
/// β3 of the confidence EMA (CAME paper default).
const BETA3: f32 = 0.9999;

struct FactoredPair {
    r: Vec<f32>,
    c: Vec<f32>,
}

enum State {
    Mat {
        v: FactoredPair,
        /// Confidence (instability) factored EMA.
        u: FactoredPair,
        rows: usize,
        cols: usize,
    },
    Vec {
        v: Vec<f32>,
        u: Vec<f32>,
    },
}

pub struct Came {
    hp: Hyper,
    m: Vec<Tensor>,
    state: Vec<State>,
    t: u64,
}

impl Came {
    pub fn new(hp: Hyper, params: &[Tensor]) -> Came {
        let state = params
            .iter()
            .map(|p| {
                if p.shape.len() >= 2 {
                    let cols = *p.shape.last().unwrap();
                    let rows = p.numel() / cols;
                    State::Mat {
                        v: FactoredPair { r: vec![0.0; rows],
                                          c: vec![0.0; cols] },
                        u: FactoredPair { r: vec![0.0; rows],
                                          c: vec![0.0; cols] },
                        rows,
                        cols,
                    }
                } else {
                    State::Vec { v: vec![0.0; p.numel()],
                                 u: vec![0.0; p.numel()] }
                }
            })
            .collect();
        Came {
            hp,
            m: params
                .iter()
                .map(|p| Tensor::zeros(&*p.name, &p.shape))
                .collect(),
            state,
            t: 0,
        }
    }
}

fn factored_update(f: &mut FactoredPair, sq: &[f32], rows: usize,
                   cols: usize, beta: f32) {
    for ri in 0..rows {
        let mut acc = 0.0;
        for ci in 0..cols {
            acc += sq[ri * cols + ci];
        }
        f.r[ri] = beta * f.r[ri] + (1.0 - beta) * (acc / cols as f32);
    }
    for ci in 0..cols {
        let mut acc = 0.0;
        for ri in 0..rows {
            acc += sq[ri * cols + ci];
        }
        f.c[ci] = beta * f.c[ci] + (1.0 - beta) * (acc / rows as f32);
    }
}

fn r_mean(f: &FactoredPair, rows: usize) -> f32 {
    f.r.iter().sum::<f32>() / rows as f32 + EPS1
}

#[inline]
fn factored_get_pre(f: &FactoredPair, ri: usize, ci: usize,
                    r_mean: f32) -> f32 {
    f.r[ri] * f.c[ci] / r_mean
}

impl Optimizer for Came {
    fn name(&self) -> String {
        "came".into()
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        self.t += 1;
        let b1 = self.hp.beta1;
        let b2 = self.hp.beta2;
        let wd = 1.0 - lr * self.hp.weight_decay;

        for (i, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            let n = p.numel();
            let mut uhat = vec![0.0f32; n];
            match &mut self.state[i] {
                State::Mat { v, rows, cols, .. } => {
                    let (rows, cols) = (*rows, *cols);
                    let sq: Vec<f32> =
                        g.data.iter().map(|x| x * x + EPS1).collect();
                    factored_update(v, &sq, rows, cols, b2);
                    let rm = r_mean(v, rows);
                    for ri in 0..rows {
                        for ci in 0..cols {
                            let vh = factored_get_pre(v, ri, ci, rm);
                            uhat[ri * cols + ci] = g.data[ri * cols + ci]
                                / (vh.sqrt() + EPS1);
                        }
                    }
                }
                State::Vec { v, .. } => {
                    for j in 0..n {
                        let gv = g.data[j];
                        v[j] = b2 * v[j] + (1.0 - b2) * (gv * gv + EPS1);
                        uhat[j] = gv / (v[j].sqrt() + EPS1);
                    }
                }
            }
            // Clip like Adafactor.
            let rms =
                (uhat.iter().map(|x| x * x).sum::<f32>() / n as f32).sqrt();
            let scale = 1.0 / (rms / CLIP_D).max(1.0);
            for x in uhat.iter_mut() {
                *x *= scale;
            }
            // Momentum.
            let m = &mut self.m[i];
            for j in 0..n {
                m.data[j] = b1 * m.data[j] + (1.0 - b1) * uhat[j];
            }
            // Instability residual (û − m)², factored EMA → confidence.
            let res: Vec<f32> = (0..n)
                .map(|j| {
                    let d = uhat[j] - m.data[j];
                    d * d + EPS2
                })
                .collect();
            match &mut self.state[i] {
                State::Mat { u, rows, cols, .. } => {
                    let (rows, cols) = (*rows, *cols);
                    factored_update(u, &res, rows, cols, BETA3);
                    let rm = r_mean(u, rows);
                    for ri in 0..rows {
                        for ci in 0..cols {
                            let s = factored_get_pre(u, ri, ci, rm);
                            let j = ri * cols + ci;
                            p.data[j] = p.data[j] * wd
                                - lr * m.data[j] / (s.sqrt() + EPS1);
                        }
                    }
                }
                State::Vec { u, .. } => {
                    for j in 0..n {
                        u[j] = BETA3 * u[j] + (1.0 - BETA3) * res[j];
                        p.data[j] = p.data[j] * wd
                            - lr * m.data[j] / (u[j].sqrt() + EPS1);
                    }
                }
            }
        }
    }

    fn state_bytes(&self) -> usize {
        let s: usize = self
            .state
            .iter()
            .map(|s| match s {
                State::Mat { v, u, .. } => {
                    v.r.len() + v.c.len() + u.r.len() + u.c.len()
                }
                State::Vec { v, u } => v.len() + u.len(),
            })
            .sum();
        (s + self.m.iter().map(Tensor::numel).sum::<usize>()) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn descends_on_quadratic() {
        let mut rng = Rng::new(3);
        let hp = Hyper { weight_decay: 0.0, ..Hyper::default() };
        let mut params = vec![Tensor::randn("w", &[8, 8], 1.0, &mut rng)];
        let mut opt = Came::new(hp, &params);
        let start = params[0].sq_norm();
        for _ in 0..300 {
            let g = Tensor::new("w", &[8, 8], params[0].data.clone());
            opt.step(&mut params, &[g], 1e-2);
        }
        assert!(params[0].sq_norm() < 0.2 * start);
    }

    #[test]
    fn state_is_factored_for_matrices() {
        let params = vec![Tensor::zeros("w", &[64, 64])];
        let opt = Came::new(Hyper::default(), &params);
        // m full + two factored pairs (v and confidence).
        assert_eq!(opt.state_bytes(), (64 * 64 + 4 * 64) * 4);
    }
}
