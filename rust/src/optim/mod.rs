//! The optimizer roster: every method the paper compares (§3, App. D).
//!
//! All optimizers implement the block-granular [`Optimizer`] trait from
//! [`core`]: state lives flat over an [`Arena`] (the flattened
//! parameter space), updates apply to contiguous [`ParamView`] /
//! [`GradView`] segments in place, and state exports as a named
//! [`StateDict`]. One model step is `begin_step()` plus `step_segment`
//! calls over any disjoint partition whose boundaries respect the
//! optimizer's [`Granularity`] — which is how the ZeRO-2 streaming
//! pipeline steps each bucket's shard the moment its reduce-scatter
//! lands. The classic whole-model `step(&mut [Tensor], &[Tensor], lr)`
//! survives as a blanket wrapper, so experiment drivers are unchanged.
//!
//! Gradients come from the AOT `grad` artifact — one compiled graph
//! serves the whole roster, which is how the paper's grid-search
//! experiments (leave-one-out, blockwise-GD, lr sweeps) stay cheap.
//! AdamW and Adam-mini additionally exist as *fused* L1 Pallas kernels
//! inside the `train_*` artifacts; `tests/` verifies the host and fused
//! paths agree to float tolerance.

pub mod adafactor;
pub mod adam;
pub mod adam_mini;
pub mod came;
pub mod core;
pub mod extra;
pub mod galore;
pub mod kernels;
pub mod lamb;
pub mod lion;
pub mod schedule;
pub mod sgd;
pub mod sm3;

pub use adafactor::{Adafactor, AdafactorVariant};
pub use adam::AdamW;
pub use adam_mini::{AdamMini, ReduceOp};
pub use came::Came;
pub use self::core::{check_state_len, decode_step, step_tensor, Arena,
                     GradView, Granularity, Optimizer, ParamView, Span,
                     StateDict, STEP_TENSOR};
pub use extra::{AdaGrad, Adan, NovoGrad};
pub use galore::{Galore, GaloreMode};
pub use kernels::{Dispatch, SimdPolicy};
pub use lamb::Lamb;
pub use lion::Lion;
pub use schedule::Schedule;
pub use sgd::{BlockwiseGd, Sgd};
pub use sm3::Sm3;

use anyhow::{bail, Result};

use crate::partition::{BlockView, Strategy};
use crate::tensor::Tensor;

/// Shared optimizer hyperparameters (paper defaults for LLM training).
#[derive(Debug, Clone, Copy)]
pub struct Hyper {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for Hyper {
    fn default() -> Self {
        Hyper { beta1: 0.9, beta2: 0.95, eps: 1e-8, weight_decay: 0.1 }
    }
}

/// Model metadata the partition-aware optimizers need.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub n_heads: usize,
    /// Names of layer-stacked tensors (axis 0 = n_layers).
    pub stacked: Vec<String>,
}

impl ModelMeta {
    pub fn spec_for(&self, params: &[Tensor], strategy: Strategy)
        -> Result<Vec<BlockView>> {
        params
            .iter()
            .map(|t| {
                crate::partition::block_view(
                    &t.name, &t.shape, self.n_heads,
                    self.stacked.iter().any(|s| s == &t.name), strategy)
            })
            .collect()
    }
}

/// Construct any roster optimizer by name (the config-file hook).
///
/// Every name in [`ROSTER`] is constructible here and vice versa —
/// `roster_matches_by_name` asserts the parity so a sweep driver can
/// never silently skip a member again.
pub fn by_name(name: &str, hp: Hyper, params: &[Tensor], meta: &ModelMeta)
    -> Result<Box<dyn Optimizer>> {
    Ok(match name {
        "adamw" => Box::new(AdamW::new(hp, params)),
        "adam_mini" => Box::new(AdamMini::new(
            hp, meta.spec_for(params, Strategy::Hessian)?, ReduceOp::Mean)),
        "adam_mini_default" => Box::new(AdamMini::new(
            hp, meta.spec_for(params, Strategy::Default)?, ReduceOp::Mean)),
        "adam_mini_value_whole" => Box::new(AdamMini::new(
            hp, meta.spec_for(params, Strategy::ValueWhole)?,
            ReduceOp::Mean)),
        "adafactor" => Box::new(Adafactor::new(
            hp, params, AdafactorVariant::Original)),
        "adafactor_zhai" => Box::new(Adafactor::new(
            hp, params, AdafactorVariant::Zhai)),
        "came" => Box::new(Came::new(hp, params)),
        "sm3" => Box::new(Sm3::new(hp, params)),
        "lion" => Box::new(Lion::new(hp, params)),
        "lamb" => Box::new(Lamb::new(hp, params)),
        "sgd" => Box::new(Sgd::new(0.9, params)),
        "adagrad" => Box::new(AdaGrad::new(params, 0.9, hp.eps)),
        "novograd" => Box::new(NovoGrad::new(hp, params)),
        "adan" => Box::new(Adan::new(hp, params)),
        "galore" => Box::new(Galore::new(hp, params, 8,
                                         GaloreMode::Adam)),
        "galore_mini" => Box::new(Galore::new(hp, params, 8,
                                              GaloreMode::Mini)),
        other => bail!("unknown optimizer {other:?}"),
    })
}

/// All roster names (for sweep drivers). Kept in parity with
/// [`by_name`] — including `adam_mini_value_whole` (App. D.6
/// strategy II), which used to be constructible but missing here.
pub const ROSTER: &[&str] = &[
    "adamw", "adam_mini", "adam_mini_default", "adam_mini_value_whole",
    "adafactor", "adafactor_zhai", "came", "sm3", "lion", "lamb", "sgd",
    "adagrad", "novograd", "adan", "galore", "galore_mini",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn toy_params() -> (Vec<Tensor>, ModelMeta) {
        let mut rng = Rng::new(0);
        let params = vec![
            Tensor::randn("embed", &[8, 4], 0.02, &mut rng),
            Tensor::randn("wq", &[2, 4, 4], 0.02, &mut rng),
            Tensor::randn("attn_norm", &[2, 4], 0.02, &mut rng),
        ];
        let meta = ModelMeta {
            n_heads: 2,
            stacked: vec!["wq".into(), "attn_norm".into()],
        };
        (params, meta)
    }

    #[test]
    fn factory_builds_whole_roster() {
        let (params, meta) = toy_params();
        for name in ROSTER {
            let opt = by_name(name, Hyper::default(), &params, &meta)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(!opt.name().is_empty());
        }
        assert!(by_name("bogus", Hyper::default(), &params, &meta).is_err());
    }

    #[test]
    fn roster_matches_by_name() {
        // Satellite invariant: every by_name-documented member is in
        // ROSTER exactly once (adam_mini_value_whole was silently
        // missing from every sweep driver before this).
        let (params, meta) = toy_params();
        assert!(ROSTER.contains(&"adam_mini_value_whole"));
        let mut seen = std::collections::BTreeSet::new();
        for name in ROSTER {
            assert!(seen.insert(*name), "duplicate roster entry {name}");
            by_name(name, Hyper::default(), &params, &meta)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
        }
        assert_eq!(ROSTER.len(), 16);
    }

    #[test]
    fn roster_state_len_never_materializes_wrong_count() {
        // state_len() must agree with the materialized dict for every
        // member (the old default silently cloned the whole export).
        let (params, meta) = toy_params();
        for name in ROSTER {
            let opt =
                by_name(name, Hyper::default(), &params, &meta).unwrap();
            assert_eq!(opt.state_len(), opt.state_dict().len(),
                       "{name}: state_len drift");
        }
    }

    #[test]
    fn every_roster_member_descends_on_quadratic() {
        // min 0.5*||w||² — every reasonable optimizer should reduce ||w||.
        let meta = ModelMeta { n_heads: 1, stacked: vec![] };
        for name in ROSTER {
            let mut rng = Rng::new(42);
            let mut params =
                vec![Tensor::randn("w1", &[16, 4], 1.0, &mut rng)];
            let hp = Hyper { weight_decay: 0.0, ..Hyper::default() };
            let mut opt = by_name(name, hp, &params, &meta).unwrap();
            let start: f64 = params[0].sq_norm();
            for _ in 0..600 {
                let grads = vec![Tensor::new("w1", &[16, 4],
                                             params[0].data.clone())];
                opt.step(&mut params, &grads, 1e-2);
            }
            let end: f64 = params[0].sq_norm();
            assert!(end < start * 0.5,
                    "{name}: ||w||² {start:.4} -> {end:.4}");
        }
    }
}
