//! The optimizer roster: every method the paper compares (§3, App. D).
//!
//! All optimizers implement [`Optimizer`] over host [`Tensor`] lists and
//! consume gradients produced by the AOT `grad` artifact — one compiled
//! graph serves the whole roster, which is how the paper's grid-search
//! experiments (leave-one-out, blockwise-GD, lr sweeps) stay cheap.
//!
//! AdamW and Adam-mini additionally exist as *fused* L1 Pallas kernels
//! inside the `train_*` artifacts; `tests/` verifies the host and fused
//! paths agree to float tolerance.

pub mod adafactor;
pub mod extra;
pub mod galore;
pub mod adam;
pub mod adam_mini;
pub mod came;
pub mod lamb;
pub mod lion;
pub mod schedule;
pub mod sgd;
pub mod sm3;

pub use adafactor::{Adafactor, AdafactorVariant};
pub use extra::{AdaGrad, Adan, NovoGrad};
pub use galore::{Galore, GaloreMode};
pub use adam::AdamW;
pub use adam_mini::{AdamMini, ReduceOp};
pub use came::Came;
pub use lamb::Lamb;
pub use lion::Lion;
pub use schedule::Schedule;
pub use sgd::{BlockwiseGd, Sgd};
pub use sm3::Sm3;

use anyhow::{bail, Result};

use crate::partition::{BlockView, Strategy};
use crate::tensor::Tensor;

/// Shared optimizer hyperparameters (paper defaults for LLM training).
#[derive(Debug, Clone, Copy)]
pub struct Hyper {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for Hyper {
    fn default() -> Self {
        Hyper { beta1: 0.9, beta2: 0.95, eps: 1e-8, weight_decay: 0.1 }
    }
}

/// A host-side optimizer stepping a list of parameter tensors.
pub trait Optimizer {
    fn name(&self) -> String;

    /// Apply one update. `lr` is the scheduled learning rate for this
    /// step; implementations track their own step counter for bias
    /// correction.
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32);

    /// Bytes of optimizer state currently held (memory accounting).
    fn state_bytes(&self) -> usize;

    /// Export optimizer state as named tensors (checkpointing and
    /// ZeRO-1 state-sync). The step counter travels as a `__step`
    /// scalar tensor. Default: empty — optimizers without an
    /// implementation checkpoint as "fresh state" (the pre-existing
    /// behavior, now explicit).
    fn state_export(&self) -> Vec<Tensor> {
        Vec::new()
    }

    /// Restore state produced by [`Optimizer::state_export`] on an
    /// identically-constructed instance. Importing a non-empty list
    /// into an optimizer without an implementation is an error (never
    /// a silent drop).
    fn state_import(&mut self, state: &[Tensor]) -> Result<()> {
        if state.is_empty() {
            return Ok(());
        }
        bail!("{}: optimizer state import not supported", self.name())
    }

    /// Number of tensors [`Optimizer::state_export`] returns, without
    /// materializing them (ZeRO-1 state routing). Implementations with
    /// a real export should override this to avoid the clone.
    fn state_len(&self) -> usize {
        self.state_export().len()
    }
}

/// Name used by the `__step` counter tensor in exported state.
pub const STEP_TENSOR: &str = "__step";

/// Helper: encode a step counter as a 2-element state tensor. Split
/// into 24-bit halves so each is exactly representable in f32 (a
/// single f32 would silently round counters past 2^24).
pub fn step_tensor(t: u64) -> Tensor {
    let lo = (t & 0xFF_FFFF) as f32;
    let hi = (t >> 24) as f32;
    Tensor::new(STEP_TENSOR, &[2], vec![lo, hi])
}

/// Helper: decode the `__step` tensor (must be the last list entry).
pub fn decode_step(state: &[Tensor]) -> Result<u64> {
    match state.last() {
        Some(t) if t.name == STEP_TENSOR && t.numel() == 2 => {
            Ok(t.data[0] as u64 | ((t.data[1] as u64) << 24))
        }
        _ => bail!("exported state must end with a {STEP_TENSOR} tensor"),
    }
}

/// Model metadata the partition-aware optimizers need.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub n_heads: usize,
    /// Names of layer-stacked tensors (axis 0 = n_layers).
    pub stacked: Vec<String>,
}

impl ModelMeta {
    pub fn spec_for(&self, params: &[Tensor], strategy: Strategy)
        -> Result<Vec<BlockView>> {
        params
            .iter()
            .map(|t| {
                crate::partition::block_view(
                    &t.name, &t.shape, self.n_heads,
                    self.stacked.iter().any(|s| s == &t.name), strategy)
            })
            .collect()
    }
}

/// Construct any roster optimizer by name (the config-file hook).
///
/// Recognized names: `adamw`, `adam_mini`, `adam_mini_default`,
/// `adam_mini_value_whole`, `adafactor`, `adafactor_zhai`, `came`,
/// `sm3`, `lion`, `lamb`, `sgd`.
pub fn by_name(name: &str, hp: Hyper, params: &[Tensor], meta: &ModelMeta)
    -> Result<Box<dyn Optimizer>> {
    Ok(match name {
        "adamw" => Box::new(AdamW::new(hp, params)),
        "adam_mini" => Box::new(AdamMini::new(
            hp, meta.spec_for(params, Strategy::Hessian)?, ReduceOp::Mean)),
        "adam_mini_default" => Box::new(AdamMini::new(
            hp, meta.spec_for(params, Strategy::Default)?, ReduceOp::Mean)),
        "adam_mini_value_whole" => Box::new(AdamMini::new(
            hp, meta.spec_for(params, Strategy::ValueWhole)?,
            ReduceOp::Mean)),
        "adafactor" => Box::new(Adafactor::new(
            hp, params, AdafactorVariant::Original)),
        "adafactor_zhai" => Box::new(Adafactor::new(
            hp, params, AdafactorVariant::Zhai)),
        "came" => Box::new(Came::new(hp, params)),
        "sm3" => Box::new(Sm3::new(hp, params)),
        "lion" => Box::new(Lion::new(hp, params)),
        "lamb" => Box::new(Lamb::new(hp, params)),
        "sgd" => Box::new(Sgd::new(0.9, params)),
        "adagrad" => Box::new(AdaGrad::new(params, 0.9, hp.eps)),
        "novograd" => Box::new(NovoGrad::new(hp, params)),
        "adan" => Box::new(Adan::new(hp, params)),
        "galore" => Box::new(Galore::new(hp, params, 8,
                                         GaloreMode::Adam)),
        "galore_mini" => Box::new(Galore::new(hp, params, 8,
                                              GaloreMode::Mini)),
        other => bail!("unknown optimizer {other:?}"),
    })
}

/// All roster names (for sweep drivers).
pub const ROSTER: &[&str] = &[
    "adamw", "adam_mini", "adam_mini_default", "adafactor",
    "adafactor_zhai", "came", "sm3", "lion", "lamb", "sgd",
    "adagrad", "novograd", "adan", "galore", "galore_mini",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn toy_params() -> (Vec<Tensor>, ModelMeta) {
        let mut rng = Rng::new(0);
        let params = vec![
            Tensor::randn("embed", &[8, 4], 0.02, &mut rng),
            Tensor::randn("wq", &[2, 4, 4], 0.02, &mut rng),
            Tensor::randn("attn_norm", &[2, 4], 0.02, &mut rng),
        ];
        let meta = ModelMeta {
            n_heads: 2,
            stacked: vec!["wq".into(), "attn_norm".into()],
        };
        (params, meta)
    }

    #[test]
    fn step_tensor_roundtrips_beyond_f32_integer_range() {
        for t in [0u64, 1, 1 << 20, (1 << 24) + 1, (1 << 30) + 12345,
                  (1 << 40) + 7] {
            let enc = step_tensor(t);
            assert_eq!(decode_step(&[enc]).unwrap(), t, "t = {t}");
        }
        assert!(decode_step(&[Tensor::zeros("w", &[2])]).is_err());
        assert!(decode_step(&[]).is_err());
    }

    #[test]
    fn factory_builds_whole_roster() {
        let (params, meta) = toy_params();
        for name in ROSTER {
            let opt = by_name(name, Hyper::default(), &params, &meta)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(!opt.name().is_empty());
        }
        assert!(by_name("bogus", Hyper::default(), &params, &meta).is_err());
    }

    #[test]
    fn every_roster_member_descends_on_quadratic() {
        // min 0.5*||w||² — every reasonable optimizer should reduce ||w||.
        let meta = ModelMeta { n_heads: 1, stacked: vec![] };
        for name in ROSTER {
            let mut rng = Rng::new(42);
            let mut params =
                vec![Tensor::randn("w1", &[16, 4], 1.0, &mut rng)];
            let hp = Hyper { weight_decay: 0.0, ..Hyper::default() };
            let mut opt = by_name(name, hp, &params, &meta).unwrap();
            let start: f64 = params[0].sq_norm();
            for _ in 0..600 {
                let grads = vec![Tensor::new("w1", &[16, 4],
                                             params[0].data.clone())];
                opt.step(&mut params, &grads, 1e-2);
            }
            let end: f64 = params[0].sq_norm();
            assert!(end < start * 0.5,
                    "{name}: ||w||² {start:.4} -> {end:.4}");
        }
    }
}
