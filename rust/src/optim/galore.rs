//! GaLore (Zhao et al. 2024) and **GaLore-mini** — the paper's
//! Appendix-A "orthogonal combination": project gradients of matrix
//! parameters onto a low-rank subspace, run Adam (or Adam-mini) in the
//! r-dimensional projected space, and project the update back.
//!
//! GaLore-mini replaces the projected-space per-coordinate `v` with one
//! scalar per projected row block — the paper's predicted "further ~40%
//! memory reduction on GaLore" (App. A), which `state_bytes()` makes
//! measurable here.
//!
//! The projector is the top-r eigenbasis of G·Gᵀ (equivalent to the
//! top-r left singular vectors of G), recomputed every
//! `update_proj_every` steps via the in-crate Jacobi eigensolver.
//! Tensor-granular: the projection couples a whole tensor.

use std::sync::Arc;

use anyhow::Result;

use super::core::{check_state_len, Arena, GradView, Granularity,
                  Optimizer, ParamView, StateDict};
use super::Hyper;
use crate::linalg::{eigh, Mat};
use crate::tensor::Tensor;

/// Second-moment mode for the projected space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GaloreMode {
    /// Full Adam in projected space (original GaLore).
    Adam,
    /// One v scalar per projected row (GaLore-mini).
    Mini,
}

struct MatState {
    /// (rows, r) projector P; update = P · Adam(Pᵀ g).
    proj: Vec<f32>,
    rows: usize,
    cols: usize,
    r: usize,
    m: Vec<f32>,
    /// Adam: r*cols entries; Mini: r entries (one per projected row).
    v: Vec<f32>,
}

enum State {
    /// Matrix tensors: projected optimizer.
    Mat(MatState),
    /// Small tensors: plain AdamW state.
    Vec { m: Vec<f32>, v: Vec<f32> },
}

pub struct Galore {
    hp: Hyper,
    mode: GaloreMode,
    rank: usize,
    update_proj_every: u64,
    arena: Arc<Arena>,
    states: Vec<State>,
    t: u64,
    /// Set by `begin_step` so every segment of one step agrees on
    /// whether this is a projector-refresh step.
    refresh_now: bool,
}

impl Galore {
    pub fn new(hp: Hyper, params: &[Tensor], rank: usize,
               mode: GaloreMode) -> Galore {
        let arena = Arc::new(Arena::of(params));
        let states = arena
            .spans
            .iter()
            .map(|s| {
                if s.shape.len() >= 2 {
                    let cols = *s.shape.last().unwrap();
                    let rows = s.len / cols;
                    // Projector cost is O(rows^3) (Jacobi eigh of GGᵀ);
                    // cap it — larger tensors fall back to plain Adam
                    // (GaLore implementations likewise restrict target
                    // modules).
                    if rows.min(cols) > rank && rows <= 384 {
                        let r = rank;
                        return State::Mat(MatState {
                            proj: vec![0.0; rows * r],
                            rows,
                            cols,
                            r,
                            m: vec![0.0; r * cols],
                            v: match mode {
                                GaloreMode::Adam => vec![0.0; r * cols],
                                GaloreMode::Mini => vec![0.0; r],
                            },
                        });
                    }
                }
                State::Vec { m: vec![0.0; s.len], v: vec![0.0; s.len] }
            })
            .collect();
        Galore {
            hp,
            mode,
            rank,
            update_proj_every: 200,
            arena,
            states,
            t: 0,
            refresh_now: false,
        }
    }

    /// Top-r eigenbasis of G·Gᵀ as the projector columns.
    fn refresh_projector(st: &mut MatState, g: &[f32]) {
        let (rows, cols, r) = (st.rows, st.cols, st.r);
        // GGᵀ (rows × rows) in f64.
        let mut ggt = Mat::zeros(rows, rows);
        for i in 0..rows {
            for j in i..rows {
                let mut acc = 0.0f64;
                for k in 0..cols {
                    acc += g[i * cols + k] as f64 * g[j * cols + k] as f64;
                }
                ggt.set(i, j, acc);
                ggt.set(j, i, acc);
            }
        }
        let e = eigh(&ggt);
        // Indices of the r largest eigenvalues.
        let mut idx: Vec<usize> = (0..rows).collect();
        idx.sort_by(|&a, &b| e.values[b].partial_cmp(&e.values[a])
            .unwrap());
        for (c, &col) in idx[..r].iter().enumerate() {
            for i in 0..rows {
                st.proj[i * r + c] = e.vectors.get(i, col) as f32;
            }
        }
    }
}

impl Optimizer for Galore {
    fn name(&self) -> String {
        match self.mode {
            GaloreMode::Adam => format!("galore[r={}]", self.rank),
            GaloreMode::Mini => format!("galore_mini[r={}]", self.rank),
        }
    }

    fn arena(&self) -> &Arc<Arena> {
        &self.arena
    }

    fn granularity(&self) -> Granularity {
        Granularity::Tensor
    }

    fn begin_step(&mut self) {
        self.t += 1;
        self.refresh_now = (self.t - 1) % self.update_proj_every == 0;
    }

    fn step_segment(&mut self, params: ParamView<'_>, grads: GradView<'_>,
                    lr: f32) {
        debug_assert!(self.t > 0, "step_segment before begin_step");
        assert_eq!(params.range(), (grads.lo(), grads.hi()));
        let (lo, hi) = params.range();
        let arena = Arc::clone(&self.arena);
        let (i0, spans) = arena.spans_in(lo, hi);
        let Hyper { beta1, beta2, eps, weight_decay } = self.hp;
        let bc1 = 1.0 / (1.0 - beta1.powi(self.t as i32));
        let bc2 = 1.0 / (1.0 - beta2.powi(self.t as i32));
        let wd = 1.0 - lr * weight_decay;
        let refresh = self.refresh_now;

        for (k, sp) in spans.iter().enumerate() {
            let i = i0 + k;
            let a = sp.offset - lo;
            let g = &grads.data[a..a + sp.len];
            let p = &mut params.data[a..a + sp.len];
            match &mut self.states[i] {
                State::Mat(st) => {
                    if refresh {
                        Self::refresh_projector(st, g);
                    }
                    let (rows, cols, r) = (st.rows, st.cols, st.r);
                    // Projected gradient ĝ = Pᵀ g  (r × cols).
                    let mut ghat = vec![0.0f32; r * cols];
                    for ri in 0..rows {
                        for c in 0..r {
                            let pic = st.proj[ri * r + c];
                            if pic == 0.0 {
                                continue;
                            }
                            for kk in 0..cols {
                                ghat[c * cols + kk] +=
                                    pic * g[ri * cols + kk];
                            }
                        }
                    }
                    // Adam / Adam-mini in projected space.
                    let mut upd = vec![0.0f32; r * cols];
                    match self.mode {
                        GaloreMode::Adam => {
                            for j in 0..r * cols {
                                let gi = ghat[j];
                                let mi = beta1 * st.m[j]
                                    + (1.0 - beta1) * gi;
                                let vi = beta2 * st.v[j]
                                    + (1.0 - beta2) * gi * gi;
                                st.m[j] = mi;
                                st.v[j] = vi;
                                upd[j] = (mi * bc1)
                                    / ((vi * bc2).sqrt() + eps);
                            }
                        }
                        GaloreMode::Mini => {
                            for row in 0..r {
                                let rlo = row * cols;
                                let gsq: f32 = ghat[rlo..rlo + cols]
                                    .iter()
                                    .map(|x| x * x)
                                    .sum::<f32>()
                                    / cols as f32;
                                let vb = beta2 * st.v[row]
                                    + (1.0 - beta2) * gsq;
                                st.v[row] = vb;
                                let denom = (vb * bc2).sqrt() + eps;
                                for j in rlo..rlo + cols {
                                    let mi = beta1 * st.m[j]
                                        + (1.0 - beta1) * ghat[j];
                                    st.m[j] = mi;
                                    upd[j] = (mi * bc1) / denom;
                                }
                            }
                        }
                    }
                    // Back-project: Δ = P · upd; decoupled decay.
                    for ri in 0..rows {
                        for kk in 0..cols {
                            let mut acc = 0.0f32;
                            for c in 0..r {
                                acc += st.proj[ri * r + c]
                                    * upd[c * cols + kk];
                            }
                            let j = ri * cols + kk;
                            p[j] = p[j] * wd - lr * acc;
                        }
                    }
                }
                State::Vec { m, v } => {
                    for j in 0..sp.len {
                        let gi = g[j];
                        let mi = beta1 * m[j] + (1.0 - beta1) * gi;
                        let vi = beta2 * v[j] + (1.0 - beta2) * gi * gi;
                        m[j] = mi;
                        v[j] = vi;
                        p[j] = p[j] * wd
                            - lr * (mi * bc1) / ((vi * bc2).sqrt() + eps);
                    }
                }
            }
        }
    }

    fn state_bytes(&self) -> usize {
        self.states
            .iter()
            .map(|s| match s {
                State::Mat(st) => st.proj.len() + st.m.len() + st.v.len(),
                State::Vec { m, v } => m.len() + v.len(),
            })
            .sum::<usize>()
            * 4
    }

    /// Entries per projected tensor: `proj/<name>`, `m/<name>`,
    /// `v/<name>` (projected-space shapes); per plain tensor:
    /// `m/<name>`, `v/<name>`; plus `__step`.
    fn state_dict(&self) -> StateDict {
        let mut sd = StateDict::new();
        for (sp, st) in self.arena.spans.iter().zip(&self.states) {
            match st {
                State::Mat(st) => {
                    sd.insert(format!("proj/{}", sp.name),
                              &[st.rows, st.r], st.proj.clone());
                    sd.insert(format!("m/{}", sp.name), &[st.m.len()],
                              st.m.clone());
                    sd.insert(format!("v/{}", sp.name), &[st.v.len()],
                              st.v.clone());
                }
                State::Vec { m, v } => {
                    sd.insert(format!("m/{}", sp.name), &[m.len()],
                              m.clone());
                    sd.insert(format!("v/{}", sp.name), &[v.len()],
                              v.clone());
                }
            }
        }
        sd.set_step(self.t);
        sd
    }

    fn state_len(&self) -> usize {
        1 + self
            .states
            .iter()
            .map(|s| match s {
                State::Mat(_) => 3,
                State::Vec { .. } => 2,
            })
            .sum::<usize>()
    }

    fn load_state_dict(&mut self, state: &StateDict) -> Result<()> {
        check_state_len(state, self.state_len(), "galore")?;
        for (sp, st) in self.arena.spans.iter().zip(&mut self.states) {
            match st {
                State::Mat(st) => {
                    st.proj.copy_from_slice(state.data(
                        &format!("proj/{}", sp.name), st.proj.len())?);
                    st.m.copy_from_slice(state.data(
                        &format!("m/{}", sp.name), st.m.len())?);
                    st.v.copy_from_slice(state.data(
                        &format!("v/{}", sp.name), st.v.len())?);
                }
                State::Vec { m, v } => {
                    m.copy_from_slice(state.data(
                        &format!("m/{}", sp.name), m.len())?);
                    v.copy_from_slice(state.data(
                        &format!("v/{}", sp.name), v.len())?);
                }
            }
        }
        self.t = state.step()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn quad_train(mode: GaloreMode) -> (f64, f64, usize) {
        let mut rng = Rng::new(11);
        let hp = Hyper { weight_decay: 0.0, ..Hyper::default() };
        let mut params = vec![Tensor::randn("w", &[16, 12], 1.0,
                                            &mut rng)];
        let mut opt = Galore::new(hp, &params, 4, mode);
        let start = params[0].sq_norm();
        for _ in 0..400 {
            let g = Tensor::new("w", &[16, 12], params[0].data.clone());
            opt.step(&mut params, &[g], 1e-2);
        }
        (start, params[0].sq_norm(), opt.state_bytes())
    }

    #[test]
    fn galore_descends_on_quadratic() {
        // For min ||w||², g = w: the top-r subspace tracks the largest
        // remaining components, so the norm must shrink substantially.
        for mode in [GaloreMode::Adam, GaloreMode::Mini] {
            let (start, end, _) = quad_train(mode);
            assert!(end < 0.3 * start, "{mode:?}: {start} -> {end}");
        }
    }

    #[test]
    fn galore_mini_state_is_smaller() {
        let (_, _, adam_bytes) = quad_train(GaloreMode::Adam);
        let (_, _, mini_bytes) = quad_train(GaloreMode::Mini);
        assert!(mini_bytes < adam_bytes);
        // Projected m (r·cols) + proj (rows·r) + v: Adam v = r·cols,
        // Mini v = r.
        assert_eq!(adam_bytes - mini_bytes, (4 * 12 - 4) * 4);
    }

    #[test]
    fn projector_is_orthonormal_after_refresh() {
        let mut rng = Rng::new(3);
        let g = Tensor::randn("w", &[10, 8], 1.0, &mut rng);
        let mut st = MatState {
            proj: vec![0.0; 10 * 3],
            rows: 10,
            cols: 8,
            r: 3,
            m: vec![],
            v: vec![],
        };
        Galore::refresh_projector(&mut st, &g.data);
        for a in 0..3 {
            for b in 0..3 {
                let dot: f32 = (0..10)
                    .map(|i| st.proj[i * 3 + a] * st.proj[i * 3 + b])
                    .sum();
                let want = if a == b { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-4,
                        "PᵀP[{a}{b}] = {dot}");
            }
        }
    }

    #[test]
    fn small_tensors_fall_back_to_adam() {
        let params = vec![Tensor::zeros("norm", &[8])];
        let opt = Galore::new(Hyper::default(), &params, 4,
                              GaloreMode::Adam);
        assert_eq!(opt.state_bytes(), 2 * 8 * 4);
    }

    #[test]
    fn state_roundtrips_including_projector() {
        let mut rng = Rng::new(13);
        let mut pa = vec![Tensor::randn("w", &[10, 8], 1.0, &mut rng),
                          Tensor::randn("norm", &[6], 1.0, &mut rng)];
        let gs: Vec<Vec<Tensor>> = (0..4)
            .map(|_| vec![Tensor::randn("w", &[10, 8], 1.0, &mut rng),
                          Tensor::randn("norm", &[6], 1.0, &mut rng)])
            .collect();
        let mut a = Galore::new(Hyper::default(), &pa, 3,
                                GaloreMode::Mini);
        for g in &gs[..2] {
            a.step(&mut pa, g, 1e-2);
        }
        let sd = a.state_dict();
        // proj/m/v for w + m/v for norm + __step.
        assert_eq!(sd.len(), 6);
        assert_eq!(sd.len(), a.state_len());
        let mut pb = pa.clone();
        let mut b = Galore::new(Hyper::default(), &pb, 3,
                                GaloreMode::Mini);
        b.load_state_dict(&sd).unwrap();
        for g in &gs[2..] {
            a.step(&mut pa, g, 1e-2);
            b.step(&mut pb, g, 1e-2);
        }
        assert_eq!(pa, pb);
    }
}
