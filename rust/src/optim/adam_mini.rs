//! Adam-mini (paper Algorithms 1–3): one second-moment scalar per dense
//! Hessian block instead of one per parameter.
//!
//! The partition comes from [`crate::partition`] (Algorithm 3). The
//! blockwise reduce defaults to `mean(g⊙g)` — the paper's choice — with
//! the Appendix D.2 ablation alternatives (max/min/ℓ1/ℓ2) selectable
//! for the Fig 15 experiment.

use anyhow::{bail, Result};

use super::{decode_step, step_tensor, Hyper, Optimizer};
use crate::partition::BlockView;
use crate::tensor::Tensor;

/// Blockwise statistic borrowed from Adam's v (paper Appendix D.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    Mean,
    Max,
    Min,
    L1Norm,
    L2Norm,
}

impl ReduceOp {
    pub fn name(&self) -> &'static str {
        match self {
            ReduceOp::Mean => "mean",
            ReduceOp::Max => "max",
            ReduceOp::Min => "min",
            ReduceOp::L1Norm => "l1norm",
            ReduceOp::L2Norm => "l2norm",
        }
    }

    fn apply(&self, gsq: impl Iterator<Item = f32>, n: usize) -> f32 {
        // A zero-element block has no statistic; folding Min from
        // f32::MAX (or Max from an arbitrary floor) would fabricate a
        // bogus v_b. Define the degenerate reduce as 0 — the same
        // "fresh state" value an untouched block carries.
        if n == 0 {
            return 0.0;
        }
        match self {
            ReduceOp::Mean => gsq.sum::<f32>() / n as f32,
            ReduceOp::Max => gsq.fold(0.0, f32::max),
            ReduceOp::Min => gsq.fold(f32::MAX, f32::min),
            // Norms of the g⊙g vector, as in the Fig 15 ablation.
            ReduceOp::L1Norm => gsq.sum::<f32>(),
            ReduceOp::L2Norm => gsq.map(|x| x * x).sum::<f32>().sqrt(),
        }
    }
}

/// The Adam-mini optimizer. State: full-size m + one f32 per block.
pub struct AdamMini {
    hp: Hyper,
    spec: Vec<BlockView>,
    reduce: ReduceOp,
    m: Vec<Tensor>,
    /// vb[i][b] = second-moment scalar for block b of tensor i.
    vb: Vec<Vec<f32>>,
    t: u64,
}

impl AdamMini {
    pub fn new(hp: Hyper, spec: Vec<BlockView>, reduce: ReduceOp)
        -> AdamMini {
        let m = spec
            .iter()
            .map(|b| Tensor::zeros(&*b.name, &b.shape))
            .collect();
        let vb = spec.iter().map(|b| vec![0.0; b.num_blocks]).collect();
        AdamMini { hp, spec, reduce, m, vb, t: 0 }
    }

    /// The per-block second moments (inspection / checkpointing).
    pub fn vb(&self) -> &[Vec<f32>] {
        &self.vb
    }

    /// Number of learning-rate scalars this instance maintains.
    pub fn total_blocks(&self) -> usize {
        self.vb.iter().map(Vec::len).sum()
    }
}

impl Optimizer for AdamMini {
    fn name(&self) -> String {
        format!("adam_mini[{}]", self.reduce.name())
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        assert_eq!(params.len(), self.spec.len());
        self.t += 1;
        let Hyper { beta1, beta2, eps, weight_decay } = self.hp;
        let bc1 = 1.0 / (1.0 - beta1.powi(self.t as i32));
        let bc2 = 1.0 / (1.0 - beta2.powi(self.t as i32));
        let wd = 1.0 - lr * weight_decay;

        for (i, bv) in self.spec.iter().enumerate() {
            let p = &mut params[i];
            let g = &grads[i];
            let m = &mut self.m[i];
            debug_assert_eq!(p.numel(), bv.num_blocks * bv.block_size,
                             "{}: partition mismatch", bv.name);
            let bs = bv.block_size;
            for b in 0..bv.num_blocks {
                let lo = b * bs;
                let hi = lo + bs;
                let gb = &g.data[lo..hi];
                // Blockwise second moment: ONE scalar per Hessian block.
                let stat = self
                    .reduce
                    .apply(gb.iter().map(|x| x * x), bs);
                let vb = beta2 * self.vb[i][b] + (1.0 - beta2) * stat;
                self.vb[i][b] = vb;
                let denom = (vb * bc2).sqrt() + eps;
                for j in lo..hi {
                    let mj = beta1 * m.data[j] + (1.0 - beta1) * g.data[j];
                    m.data[j] = mj;
                    p.data[j] = p.data[j] * wd - lr * (mj * bc1) / denom;
                }
            }
        }
    }

    fn state_bytes(&self) -> usize {
        (self.m.iter().map(Tensor::numel).sum::<usize>()
            + self.total_blocks())
            * 4
    }

    /// State layout: m tensors, then one `<name>__vb` vector per
    /// tensor (the per-block second moments), then `__step`. The v_b
    /// vectors are what makes Adam-mini's sharded state sync cheap:
    /// one scalar per Hessian block instead of one per parameter.
    fn state_export(&self) -> Vec<Tensor> {
        let mut out = self.m.clone();
        for (bv, vb) in self.spec.iter().zip(&self.vb) {
            out.push(Tensor::new(format!("{}__vb", bv.name),
                                 &[vb.len()], vb.clone()));
        }
        out.push(step_tensor(self.t));
        out
    }

    fn state_len(&self) -> usize {
        2 * self.m.len() + 1
    }

    fn state_import(&mut self, state: &[Tensor]) -> Result<()> {
        let n = self.m.len();
        if state.len() != 2 * n + 1 {
            bail!("adam_mini: expected {} state tensors, got {}",
                  2 * n + 1, state.len());
        }
        self.t = decode_step(state)?;
        for (dst, src) in self.m.iter_mut().zip(&state[..n]) {
            src.assert_shape(&dst.shape)?;
            dst.data.copy_from_slice(&src.data);
        }
        for (dst, src) in self.vb.iter_mut().zip(&state[n..2 * n]) {
            src.assert_shape(&[dst.len()])?;
            dst.copy_from_slice(&src.data);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::adam::AdamW;
    use crate::partition::{block_view, Strategy};
    use crate::util::prng::Rng;
    use crate::util::prop::{check, prop_close};

    fn spec_one(name: &str, shape: &[usize], blocks: usize) -> BlockView {
        let n: usize = shape.iter().product();
        BlockView {
            name: name.into(),
            shape: shape.to_vec(),
            num_blocks: blocks,
            block_size: n / blocks,
            category: crate::partition::Category::Whole,
        }
    }

    #[test]
    fn equals_adam_when_blocks_have_size_one() {
        // With block_size == 1, mean(g²) == g² → Adam-mini ≡ AdamW.
        check(16, |rng: &mut Rng| {
            let n = 1 + rng.below(12);
            let hp = Hyper::default();
            let p0 = Tensor::randn("w", &[n], 1.0, rng);
            let g1 = Tensor::randn("w", &[n], 1.0, rng);
            let g2 = Tensor::randn("w", &[n], 1.0, rng);

            let mut pa = vec![p0.clone()];
            let mut adam = AdamW::new(hp, &pa);
            let mut pb = vec![p0.clone()];
            let mut mini = AdamMini::new(
                hp, vec![spec_one("w", &[n], n)], ReduceOp::Mean);

            for g in [&g1, &g2] {
                adam.step(&mut pa, std::slice::from_ref(g), 1e-2);
                mini.step(&mut pb, std::slice::from_ref(g), 1e-2);
            }
            for i in 0..n {
                prop_close(pa[0].data[i] as f64, pb[0].data[i] as f64,
                           1e-7, 1e-6, "mini == adam at block size 1")?;
            }
            Ok(())
        });
    }

    #[test]
    fn single_block_uses_global_mean() {
        let hp = Hyper { beta1: 0.0, beta2: 0.0, eps: 0.0,
                         weight_decay: 0.0 };
        let mut params = vec![Tensor::new("w", &[2], vec![0.0, 0.0])];
        let grads = vec![Tensor::new("w", &[2], vec![3.0, 4.0])];
        let mut opt = AdamMini::new(
            hp, vec![spec_one("w", &[2], 1)], ReduceOp::Mean);
        opt.step(&mut params, &grads, 1.0);
        // v = mean(9,16) = 12.5 → denom = sqrt(12.5); update = g/denom.
        let denom = 12.5f32.sqrt();
        assert!((params[0].data[0] + 3.0 / denom).abs() < 1e-6);
        assert!((params[0].data[1] + 4.0 / denom).abs() < 1e-6);
    }

    #[test]
    fn state_is_tiny_versus_adamw() {
        let mut rng = Rng::new(0);
        let params = vec![Tensor::randn("wv", &[4, 64, 64], 0.02, &mut rng)];
        let spec = vec![block_view("wv", &[4, 64, 64], 4, true,
                                   Strategy::Hessian).unwrap()];
        let mini = AdamMini::new(Hyper::default(), spec, ReduceOp::Mean);
        let adam = AdamW::new(Hyper::default(), &params);
        // AdamW: 2N floats. Adam-mini: N + #blocks floats.
        assert_eq!(adam.state_bytes(), 2 * 4 * 16384);
        assert_eq!(mini.state_bytes(), 4 * (16384 + 256));
    }

    #[test]
    fn reduce_ops_all_finite_and_descend() {
        for op in [ReduceOp::Mean, ReduceOp::Max, ReduceOp::Min,
                   ReduceOp::L1Norm, ReduceOp::L2Norm] {
            let mut rng = Rng::new(1);
            let hp = Hyper { weight_decay: 0.0, ..Hyper::default() };
            let mut params =
                vec![Tensor::randn("w", &[8, 8], 1.0, &mut rng)];
            let mut opt = AdamMini::new(
                hp, vec![spec_one("w", &[8, 8], 8)], op);
            let start = params[0].sq_norm();
            for _ in 0..100 {
                let g = Tensor::new("w", &[8, 8], params[0].data.clone());
                opt.step(&mut params, &[g], 1e-2);
            }
            let end = params[0].sq_norm();
            assert!(end.is_finite() && end < start,
                    "{:?}: {start} -> {end}", op);
        }
    }

    #[test]
    fn reduce_ops_safe_on_empty_and_degenerate_blocks() {
        // A zero-element block must yield v_b = 0, not f32::MAX (Min)
        // or another fabricated value.
        for op in [ReduceOp::Mean, ReduceOp::Max, ReduceOp::Min,
                   ReduceOp::L1Norm, ReduceOp::L2Norm] {
            let stat = op.apply(std::iter::empty(), 0);
            assert_eq!(stat, 0.0, "{op:?} on empty block");
        }
        // A single-element block is its own mean/max/min/l1.
        for op in [ReduceOp::Mean, ReduceOp::Max, ReduceOp::Min,
                   ReduceOp::L1Norm, ReduceOp::L2Norm] {
            let stat = op.apply([4.0f32].iter().copied(), 1);
            assert_eq!(stat, 4.0, "{op:?} on singleton block");
        }
        // An all-zero gradient block stays finite and non-negative.
        for op in [ReduceOp::Mean, ReduceOp::Max, ReduceOp::Min,
                   ReduceOp::L1Norm, ReduceOp::L2Norm] {
            let stat = op.apply([0.0f32; 3].iter().copied(), 3);
            assert_eq!(stat, 0.0, "{op:?} on zero block");
        }
    }

    #[test]
    fn state_roundtrip_resumes_identically() {
        let mut rng = Rng::new(5);
        let p0 = vec![Tensor::randn("w", &[4, 4], 1.0, &mut rng)];
        let gs: Vec<Tensor> =
            (0..6).map(|_| Tensor::randn("w", &[4, 4], 1.0, &mut rng))
                  .collect();
        let spec = || vec![spec_one("w", &[4, 4], 4)];
        let mut pa = p0.clone();
        let mut a = AdamMini::new(Hyper::default(), spec(),
                                  ReduceOp::Mean);
        for g in &gs[..3] {
            a.step(&mut pa, std::slice::from_ref(g), 1e-2);
        }
        let state = a.state_export();
        // m + vb + __step.
        assert_eq!(state.len(), 3);
        assert_eq!(state[1].shape, vec![4]);
        let mut pb = pa.clone();
        let mut b = AdamMini::new(Hyper::default(), spec(),
                                  ReduceOp::Mean);
        b.state_import(&state).unwrap();
        for g in &gs[3..] {
            a.step(&mut pa, std::slice::from_ref(g), 1e-2);
            b.step(&mut pb, std::slice::from_ref(g), 1e-2);
        }
        assert_eq!(pa, pb);
        assert!(b.state_import(&state[..2]).is_err());
    }

    #[test]
    fn blockwise_lr_differs_across_blocks() {
        // Two blocks with very different gradient scales must receive
        // different effective learning rates.
        let hp = Hyper { beta1: 0.0, beta2: 0.0, eps: 0.0,
                         weight_decay: 0.0 };
        let mut params = vec![Tensor::zeros("w", &[4])];
        let grads = vec![Tensor::new("w", &[4],
                                     vec![100.0, 100.0, 0.01, 0.01])];
        let mut opt = AdamMini::new(
            hp, vec![spec_one("w", &[4], 2)], ReduceOp::Mean);
        opt.step(&mut params, &grads, 1.0);
        // Each block normalizes by its own RMS → both updates ≈ ±1.
        assert!((params[0].data[0] + 1.0).abs() < 1e-5);
        assert!((params[0].data[2] + 1.0).abs() < 1e-4);
    }
}
