//! Adam-mini (paper Algorithms 1–3): one second-moment scalar per dense
//! Hessian block instead of one per parameter.
//!
//! The partition comes from [`crate::partition`] (Algorithm 3). The
//! blockwise reduce defaults to `mean(g⊙g)` — the paper's choice — with
//! the Appendix D.2 ablation alternatives (max/min/ℓ1/ℓ2) selectable
//! for the Fig 15 experiment.
//!
//! State is arena-flat: `m` mirrors the parameters; `v_b` is one f32
//! per block of the flat block grid (`cuts`), which is also the
//! optimizer's [`Optimizer::segment_cuts`] grid — the ZeRO partitioner
//! and the bucket scheduler align to it so shard- and bucket-granular
//! stepping stays bit-identical to the whole-model step.

use std::sync::Arc;

use anyhow::Result;

use super::core::{check_state_len, Arena, GradView, Granularity,
                  Optimizer, ParamView, StateDict};
use super::kernels::{self, Dispatch, MiniCoef};
use super::Hyper;
use crate::partition::BlockView;

/// Blockwise statistic borrowed from Adam's v (paper Appendix D.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    Mean,
    Max,
    Min,
    L1Norm,
    L2Norm,
}

impl ReduceOp {
    pub fn name(&self) -> &'static str {
        match self {
            ReduceOp::Mean => "mean",
            ReduceOp::Max => "max",
            ReduceOp::Min => "min",
            ReduceOp::L1Norm => "l1norm",
            ReduceOp::L2Norm => "l2norm",
        }
    }

    pub fn apply(&self, gsq: impl Iterator<Item = f32>, n: usize) -> f32 {
        // A zero-element block has no statistic; folding Min from
        // f32::MAX (or Max from an arbitrary floor) would fabricate a
        // bogus v_b. Define the degenerate reduce as 0 — the same
        // "fresh state" value an untouched block carries.
        if n == 0 {
            return 0.0;
        }
        match self {
            ReduceOp::Mean => gsq.sum::<f32>() / n as f32,
            ReduceOp::Max => gsq.fold(0.0, f32::max),
            ReduceOp::Min => gsq.fold(f32::MAX, f32::min),
            // Norms of the g⊙g vector, as in the Fig 15 ablation.
            ReduceOp::L1Norm => gsq.sum::<f32>(),
            ReduceOp::L2Norm => gsq.map(|x| x * x).sum::<f32>().sqrt(),
        }
    }
}

/// The Adam-mini optimizer. State: full-size m + one f32 per block.
pub struct AdamMini {
    hp: Hyper,
    reduce: ReduceOp,
    arena: Arc<Arena>,
    dispatch: Dispatch,
    /// Flat block grid: block `b` covers `[cuts[b], cuts[b+1])`.
    cuts: Vec<usize>,
    m: Vec<f32>,
    /// vb[b] = second-moment scalar for flat block b.
    vb: Vec<f32>,
    t: u64,
}

impl AdamMini {
    pub fn new(hp: Hyper, spec: Vec<BlockView>, reduce: ReduceOp)
        -> AdamMini {
        let arena = Arc::new(Arena::from_shapes(
            spec.iter().map(|b| (b.name.clone(), b.shape.clone()))));
        let mut cuts = vec![0usize];
        let mut offset = 0;
        for bv in &spec {
            debug_assert_eq!(bv.shape.iter().product::<usize>(),
                             bv.num_blocks * bv.block_size,
                             "{}: partition mismatch", bv.name);
            for b in 1..=bv.num_blocks {
                cuts.push(offset + b * bv.block_size);
            }
            offset += bv.num_blocks * bv.block_size;
        }
        debug_assert_eq!(offset, arena.total);
        let n_blocks = cuts.len() - 1;
        let total = arena.total;
        AdamMini {
            hp,
            reduce,
            arena,
            dispatch: Dispatch::for_arena(total),
            cuts,
            m: vec![0.0; total],
            vb: vec![0.0; n_blocks],
            t: 0,
        }
    }

    fn step_impl(&mut self, params: ParamView<'_>, grads: GradView<'_>,
                 lr: f32, gscale: f32) {
        debug_assert!(self.t > 0, "step_segment before begin_step");
        assert_eq!(params.range(), (grads.lo(), grads.hi()));
        let (lo, hi) = params.range();
        let b0 = self
            .cuts
            .binary_search(&lo)
            .unwrap_or_else(|_| {
                panic!("segment lo {lo} is not on a block boundary")
            });
        let Hyper { beta1, beta2, eps, weight_decay } = self.hp;
        let bc2 = 1.0 / (1.0 - beta2.powi(self.t as i32));
        let k = MiniCoef {
            beta1,
            bc1: 1.0 / (1.0 - beta1.powi(self.t as i32)),
            wd: 1.0 - lr * weight_decay,
            lr,
            gscale,
        };
        let mut b = b0;
        while self.cuts[b] < hi {
            let (blo, bhi) = (self.cuts[b], self.cuts[b + 1]);
            assert!(bhi <= hi,
                    "segment hi {hi} splits block [{blo}, {bhi})");
            let gb = &grads.data[blo - lo..bhi - lo];
            // Blockwise second moment: ONE scalar per Hessian block.
            // The hot (paper-default) Mean statistic goes through the
            // vectorizable kernel; the Fig 15 ablation reduces stay
            // on the scalar fold (cold path).
            let stat = match self.reduce {
                ReduceOp::Mean => {
                    kernels::sq_mean(self.dispatch, gb, gscale)
                }
                _ => self.reduce.apply(
                    gb.iter().map(|x| {
                        let y = x * gscale;
                        y * y
                    }),
                    gb.len()),
            };
            let vb = beta2 * self.vb[b] + (1.0 - beta2) * stat;
            self.vb[b] = vb;
            let denom = (vb * bc2).sqrt() + eps;
            kernels::adam_mini_block(
                self.dispatch, &mut params.data[blo - lo..bhi - lo], gb,
                &mut self.m[blo..bhi], denom, &k);
            b += 1;
        }
    }

    /// The per-block second moments, flat over the block grid
    /// (inspection / checkpointing).
    pub fn vb(&self) -> &[f32] {
        &self.vb
    }

    /// Number of learning-rate scalars this instance maintains.
    pub fn total_blocks(&self) -> usize {
        self.vb.len()
    }
}

impl Optimizer for AdamMini {
    fn name(&self) -> String {
        format!("adam_mini[{}]", self.reduce.name())
    }

    fn arena(&self) -> &Arc<Arena> {
        &self.arena
    }

    fn granularity(&self) -> Granularity {
        Granularity::Block
    }

    fn segment_cuts(&self) -> Option<Vec<usize>> {
        Some(self.cuts.clone())
    }

    fn begin_step(&mut self) {
        self.t += 1;
    }

    fn step_segment(&mut self, params: ParamView<'_>, grads: GradView<'_>,
                    lr: f32) {
        self.step_impl(params, grads, lr, 1.0);
    }

    fn step_segment_scaled(&mut self, params: ParamView<'_>,
                           grads: GradView<'_>, lr: f32, gscale: f32) {
        self.step_impl(params, grads, lr, gscale);
    }

    fn state_bytes(&self) -> usize {
        (self.m.len() + self.vb.len()) * 4
    }

    /// Entries: `m` (arena-flat), `vb` (one f32 per flat block — what
    /// makes Adam-mini's sharded state sync cheap), `__step`.
    fn state_dict(&self) -> StateDict {
        let mut sd = StateDict::new();
        sd.insert("m", &[self.m.len()], self.m.clone());
        sd.insert("vb", &[self.vb.len()], self.vb.clone());
        sd.set_step(self.t);
        sd
    }

    fn state_len(&self) -> usize {
        3
    }

    fn load_state_dict(&mut self, state: &StateDict) -> Result<()> {
        check_state_len(state, 3, "adam_mini")?;
        self.m.copy_from_slice(state.data("m", self.m.len())?);
        self.vb.copy_from_slice(state.data("vb", self.vb.len())?);
        self.t = state.step()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::adam::AdamW;
    use crate::partition::{block_view, Strategy};
    use crate::tensor::Tensor;
    use crate::util::prng::Rng;
    use crate::util::prop::{check, prop_close};

    fn spec_one(name: &str, shape: &[usize], blocks: usize) -> BlockView {
        let n: usize = shape.iter().product();
        BlockView {
            name: name.into(),
            shape: shape.to_vec(),
            num_blocks: blocks,
            block_size: n / blocks,
            category: crate::partition::Category::Whole,
        }
    }

    #[test]
    fn equals_adam_when_blocks_have_size_one() {
        // With block_size == 1, mean(g²) == g² → Adam-mini ≡ AdamW.
        check(16, |rng: &mut Rng| {
            let n = 1 + rng.below(12);
            let hp = Hyper::default();
            let p0 = Tensor::randn("w", &[n], 1.0, rng);
            let g1 = Tensor::randn("w", &[n], 1.0, rng);
            let g2 = Tensor::randn("w", &[n], 1.0, rng);

            let mut pa = vec![p0.clone()];
            let mut adam = AdamW::new(hp, &pa);
            let mut pb = vec![p0.clone()];
            let mut mini = AdamMini::new(
                hp, vec![spec_one("w", &[n], n)], ReduceOp::Mean);

            for g in [&g1, &g2] {
                adam.step(&mut pa, std::slice::from_ref(g), 1e-2);
                mini.step(&mut pb, std::slice::from_ref(g), 1e-2);
            }
            for i in 0..n {
                prop_close(pa[0].data[i] as f64, pb[0].data[i] as f64,
                           1e-7, 1e-6, "mini == adam at block size 1")?;
            }
            Ok(())
        });
    }

    #[test]
    fn single_block_uses_global_mean() {
        let hp = Hyper { beta1: 0.0, beta2: 0.0, eps: 0.0,
                         weight_decay: 0.0 };
        let mut params = vec![Tensor::new("w", &[2], vec![0.0, 0.0])];
        let grads = vec![Tensor::new("w", &[2], vec![3.0, 4.0])];
        let mut opt = AdamMini::new(
            hp, vec![spec_one("w", &[2], 1)], ReduceOp::Mean);
        opt.step(&mut params, &grads, 1.0);
        // v = mean(9,16) = 12.5 → denom = sqrt(12.5); update = g/denom.
        let denom = 12.5f32.sqrt();
        assert!((params[0].data[0] + 3.0 / denom).abs() < 1e-6);
        assert!((params[0].data[1] + 4.0 / denom).abs() < 1e-6);
    }

    #[test]
    fn state_is_tiny_versus_adamw() {
        let mut rng = Rng::new(0);
        let params = vec![Tensor::randn("wv", &[4, 64, 64], 0.02, &mut rng)];
        let spec = vec![block_view("wv", &[4, 64, 64], 4, true,
                                   Strategy::Hessian).unwrap()];
        let mini = AdamMini::new(Hyper::default(), spec, ReduceOp::Mean);
        let adam = AdamW::new(Hyper::default(), &params);
        // AdamW: 2N floats. Adam-mini: N + #blocks floats.
        assert_eq!(adam.state_bytes(), 2 * 4 * 16384);
        assert_eq!(mini.state_bytes(), 4 * (16384 + 256));
    }

    #[test]
    fn reduce_ops_all_finite_and_descend() {
        for op in [ReduceOp::Mean, ReduceOp::Max, ReduceOp::Min,
                   ReduceOp::L1Norm, ReduceOp::L2Norm] {
            let mut rng = Rng::new(1);
            let hp = Hyper { weight_decay: 0.0, ..Hyper::default() };
            let mut params =
                vec![Tensor::randn("w", &[8, 8], 1.0, &mut rng)];
            let mut opt = AdamMini::new(
                hp, vec![spec_one("w", &[8, 8], 8)], op);
            let start = params[0].sq_norm();
            for _ in 0..100 {
                let g = Tensor::new("w", &[8, 8], params[0].data.clone());
                opt.step(&mut params, &[g], 1e-2);
            }
            let end = params[0].sq_norm();
            assert!(end.is_finite() && end < start,
                    "{:?}: {start} -> {end}", op);
        }
    }

    #[test]
    fn reduce_ops_safe_on_empty_and_degenerate_blocks() {
        // A zero-element block must yield v_b = 0, not f32::MAX (Min)
        // or another fabricated value.
        for op in [ReduceOp::Mean, ReduceOp::Max, ReduceOp::Min,
                   ReduceOp::L1Norm, ReduceOp::L2Norm] {
            let stat = op.apply(std::iter::empty(), 0);
            assert_eq!(stat, 0.0, "{op:?} on empty block");
        }
        // A single-element block is its own mean/max/min/l1.
        for op in [ReduceOp::Mean, ReduceOp::Max, ReduceOp::Min,
                   ReduceOp::L1Norm, ReduceOp::L2Norm] {
            let stat = op.apply([4.0f32].iter().copied(), 1);
            assert_eq!(stat, 4.0, "{op:?} on singleton block");
        }
        // An all-zero gradient block stays finite and non-negative.
        for op in [ReduceOp::Mean, ReduceOp::Max, ReduceOp::Min,
                   ReduceOp::L1Norm, ReduceOp::L2Norm] {
            let stat = op.apply([0.0f32; 3].iter().copied(), 3);
            assert_eq!(stat, 0.0, "{op:?} on zero block");
        }
    }

    #[test]
    fn state_roundtrip_resumes_identically() {
        let mut rng = Rng::new(5);
        let p0 = vec![Tensor::randn("w", &[4, 4], 1.0, &mut rng)];
        let gs: Vec<Tensor> =
            (0..6).map(|_| Tensor::randn("w", &[4, 4], 1.0, &mut rng))
                  .collect();
        let spec = || vec![spec_one("w", &[4, 4], 4)];
        let mut pa = p0.clone();
        let mut a = AdamMini::new(Hyper::default(), spec(),
                                  ReduceOp::Mean);
        for g in &gs[..3] {
            a.step(&mut pa, std::slice::from_ref(g), 1e-2);
        }
        let state = a.state_dict();
        // m + vb + __step.
        assert_eq!(state.len(), 3);
        assert_eq!(state.len(), a.state_len());
        assert_eq!(state.require("vb").unwrap().numel(), 4);
        let mut pb = pa.clone();
        let mut b = AdamMini::new(Hyper::default(), spec(),
                                  ReduceOp::Mean);
        b.load_state_dict(&state).unwrap();
        for g in &gs[3..] {
            a.step(&mut pa, std::slice::from_ref(g), 1e-2);
            b.step(&mut pb, std::slice::from_ref(g), 1e-2);
        }
        assert_eq!(pa, pb);
        let mut short = StateDict::new();
        short.insert_tensor(state.entries()[0].clone());
        assert!(b.load_state_dict(&short).is_err());
    }

    #[test]
    fn blockwise_lr_differs_across_blocks() {
        // Two blocks with very different gradient scales must receive
        // different effective learning rates.
        let hp = Hyper { beta1: 0.0, beta2: 0.0, eps: 0.0,
                         weight_decay: 0.0 };
        let mut params = vec![Tensor::zeros("w", &[4])];
        let grads = vec![Tensor::new("w", &[4],
                                     vec![100.0, 100.0, 0.01, 0.01])];
        let mut opt = AdamMini::new(
            hp, vec![spec_one("w", &[4], 2)], ReduceOp::Mean);
        opt.step(&mut params, &grads, 1.0);
        // Each block normalizes by its own RMS → both updates ≈ ±1.
        assert!((params[0].data[0] + 1.0).abs() < 1e-5);
        assert!((params[0].data[2] + 1.0).abs() < 1e-4);
    }

    #[test]
    fn block_partitioned_segments_match_whole_step() {
        // Stepping block-aligned segments one by one is bit-identical
        // to the whole-model step (the ZeRO-2 bucket-stepping
        // invariant).
        let mut rng = Rng::new(8);
        let params = vec![Tensor::randn("w", &[4, 4], 1.0, &mut rng)];
        let g = Tensor::randn("w", &[4, 4], 1.0, &mut rng);
        let spec = || vec![spec_one("w", &[4, 4], 4)];
        let mut pa = params.clone();
        let mut a = AdamMini::new(Hyper::default(), spec(),
                                  ReduceOp::Mean);
        a.step(&mut pa, std::slice::from_ref(&g), 1e-2);

        let mut b = AdamMini::new(Hyper::default(), spec(),
                                  ReduceOp::Mean);
        let cuts = b.segment_cuts().unwrap();
        assert_eq!(cuts, vec![0, 4, 8, 12, 16]);
        let arena = Arc::clone(b.arena());
        let mut flat = arena.flatten(&params);
        let gflat = arena.flatten(std::slice::from_ref(&g));
        b.begin_step();
        // Step blocks out of order: {2}, {0, 1}, {3}.
        for (lo, hi) in [(8usize, 12usize), (0, 8), (12, 16)] {
            b.step_segment(ParamView::new(lo, &mut flat[lo..hi]),
                           GradView::new(lo, &gflat[lo..hi]), 1e-2);
        }
        let mut pb = params.clone();
        arena.unflatten(&flat, &mut pb);
        assert_eq!(pa, pb);
    }
}
