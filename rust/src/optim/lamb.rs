//! LAMB (You et al. 2019, paper Algorithm 7): Adam with a layer-wise
//! trust ratio. Included because the paper explicitly contrasts it with
//! Adam-mini (Appendix A): LAMB keeps the full coordinate-wise 1/√v AND
//! adds layer-wise rescaling — it saves no memory.

use super::{Hyper, Optimizer};
use crate::tensor::Tensor;

pub struct Lamb {
    hp: Hyper,
    m: Vec<Tensor>,
    v: Vec<Tensor>,
    t: u64,
}

impl Lamb {
    pub fn new(hp: Hyper, params: &[Tensor]) -> Lamb {
        Lamb {
            hp,
            m: params.iter().map(|p| Tensor::zeros(&*p.name, &p.shape))
                .collect(),
            v: params.iter().map(|p| Tensor::zeros(&*p.name, &p.shape))
                .collect(),
            t: 0,
        }
    }
}

impl Optimizer for Lamb {
    fn name(&self) -> String {
        "lamb".into()
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        self.t += 1;
        let Hyper { beta1, beta2, eps, weight_decay } = self.hp;
        let bc1 = 1.0 / (1.0 - beta1.powi(self.t as i32));
        let bc2 = 1.0 / (1.0 - beta2.powi(self.t as i32));
        for ((p, g), (m, v)) in params
            .iter_mut()
            .zip(grads)
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            let n = p.data.len();
            // r = m̂ / (√v̂ + ε), then add decoupled decay into the
            // trust-ratio direction (Algorithm 7 line 10).
            let mut dir = vec![0.0f32; n];
            for i in 0..n {
                let gi = g.data[i];
                let mi = beta1 * m.data[i] + (1.0 - beta1) * gi;
                let vi = beta2 * v.data[i] + (1.0 - beta2) * gi * gi;
                m.data[i] = mi;
                v.data[i] = vi;
                dir[i] = (mi * bc1) / ((vi * bc2).sqrt() + eps)
                    + weight_decay * p.data[i];
            }
            let p_norm = p.norm() as f32;
            let d_norm =
                (dir.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>())
                    .sqrt() as f32;
            // φ(‖p‖)/‖r + λp‖ with φ = identity; 1.0 fallback at zero.
            let trust = if p_norm > 0.0 && d_norm > 0.0 {
                p_norm / d_norm
            } else {
                1.0
            };
            for i in 0..n {
                p.data[i] -= lr * trust * dir[i];
            }
        }
    }

    fn state_bytes(&self) -> usize {
        (self.m.iter().map(Tensor::numel).sum::<usize>()
            + self.v.iter().map(Tensor::numel).sum::<usize>())
            * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn no_memory_saving_vs_adamw() {
        let params = vec![Tensor::zeros("w", &[10, 10])];
        let opt = Lamb::new(Hyper::default(), &params);
        assert_eq!(opt.state_bytes(), 2 * 100 * 4);
    }

    #[test]
    fn trust_ratio_scales_update_by_param_norm() {
        // Same gradient, parameters 10× larger → update ~10× larger.
        let hp = Hyper { weight_decay: 0.0, ..Hyper::default() };
        let g = Tensor::new("w", &[2], vec![1.0, 1.0]);

        let mut small = vec![Tensor::new("w", &[2], vec![0.1, 0.1])];
        let mut o1 = Lamb::new(hp, &small);
        let before_s = small[0].data.clone();
        o1.step(&mut small, std::slice::from_ref(&g), 1e-2);
        let ds = (small[0].data[0] - before_s[0]).abs();

        let mut big = vec![Tensor::new("w", &[2], vec![1.0, 1.0])];
        let mut o2 = Lamb::new(hp, &big);
        let before_b = big[0].data.clone();
        o2.step(&mut big, std::slice::from_ref(&g), 1e-2);
        let db = (big[0].data[0] - before_b[0]).abs();

        assert!((db / ds - 10.0).abs() < 0.5, "ratio {}", db / ds);
    }

    #[test]
    fn descends_on_quadratic() {
        let mut rng = Rng::new(9);
        let hp = Hyper { weight_decay: 0.0, ..Hyper::default() };
        let mut params = vec![Tensor::randn("w", &[8, 8], 1.0, &mut rng)];
        let mut opt = Lamb::new(hp, &params);
        let start = params[0].sq_norm();
        for _ in 0..200 {
            let g = Tensor::new("w", &[8, 8], params[0].data.clone());
            opt.step(&mut params, &[g], 1e-2);
        }
        assert!(params[0].sq_norm() < 0.5 * start);
    }
}
