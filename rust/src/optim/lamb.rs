//! LAMB (You et al. 2019, paper Algorithm 7): Adam with a layer-wise
//! trust ratio. Included because the paper explicitly contrasts it with
//! Adam-mini (Appendix A): LAMB keeps the full coordinate-wise 1/√v AND
//! adds layer-wise rescaling — it saves no memory.
//!
//! Tensor-granular: the trust ratio couples every coordinate of a
//! tensor, so segments must cover whole tensors.

use std::sync::Arc;

use anyhow::Result;

use super::core::{check_state_len, Arena, GradView, Granularity,
                  Optimizer, ParamView, StateDict};
use super::Hyper;
use crate::tensor::Tensor;

pub struct Lamb {
    hp: Hyper,
    arena: Arc<Arena>,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl Lamb {
    pub fn new(hp: Hyper, params: &[Tensor]) -> Lamb {
        let arena = Arc::new(Arena::of(params));
        let n = arena.total;
        Lamb { hp, arena, m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }
}

impl Optimizer for Lamb {
    fn name(&self) -> String {
        "lamb".into()
    }

    fn arena(&self) -> &Arc<Arena> {
        &self.arena
    }

    fn granularity(&self) -> Granularity {
        Granularity::Tensor
    }

    fn begin_step(&mut self) {
        self.t += 1;
    }

    fn step_segment(&mut self, params: ParamView<'_>, grads: GradView<'_>,
                    lr: f32) {
        debug_assert!(self.t > 0, "step_segment before begin_step");
        assert_eq!(params.range(), (grads.lo(), grads.hi()));
        let (lo, hi) = params.range();
        let arena = Arc::clone(&self.arena);
        let (_, spans) = arena.spans_in(lo, hi);
        let Hyper { beta1, beta2, eps, weight_decay } = self.hp;
        let bc1 = 1.0 / (1.0 - beta1.powi(self.t as i32));
        let bc2 = 1.0 / (1.0 - beta2.powi(self.t as i32));
        for sp in spans {
            let (a, b) = (sp.offset - lo, sp.offset - lo + sp.len);
            // r = m̂ / (√v̂ + ε), then add decoupled decay into the
            // trust-ratio direction (Algorithm 7 line 10).
            let mut dir = vec![0.0f32; sp.len];
            let mut p_sq = 0.0f64;
            for j in a..b {
                let gi = grads.data[j];
                let pi = params.data[j];
                let mi = beta1 * self.m[lo + j] + (1.0 - beta1) * gi;
                let vi = beta2 * self.v[lo + j] + (1.0 - beta2) * gi * gi;
                self.m[lo + j] = mi;
                self.v[lo + j] = vi;
                dir[j - a] = (mi * bc1) / ((vi * bc2).sqrt() + eps)
                    + weight_decay * pi;
                p_sq += pi as f64 * pi as f64;
            }
            let p_norm = p_sq.sqrt() as f32;
            let d_norm = (dir
                .iter()
                .map(|x| (*x as f64) * (*x as f64))
                .sum::<f64>())
                .sqrt() as f32;
            // φ(‖p‖)/‖r + λp‖ with φ = identity; 1.0 fallback at zero.
            let trust = if p_norm > 0.0 && d_norm > 0.0 {
                p_norm / d_norm
            } else {
                1.0
            };
            for j in a..b {
                params.data[j] -= lr * trust * dir[j - a];
            }
        }
    }

    fn state_bytes(&self) -> usize {
        (self.m.len() + self.v.len()) * 4
    }

    /// Entries: `m`, `v` (arena-flat), `__step`.
    fn state_dict(&self) -> StateDict {
        let mut sd = StateDict::new();
        sd.insert("m", &[self.m.len()], self.m.clone());
        sd.insert("v", &[self.v.len()], self.v.clone());
        sd.set_step(self.t);
        sd
    }

    fn state_len(&self) -> usize {
        3
    }

    fn load_state_dict(&mut self, state: &StateDict) -> Result<()> {
        check_state_len(state, 3, "lamb")?;
        self.m.copy_from_slice(state.data("m", self.m.len())?);
        self.v.copy_from_slice(state.data("v", self.v.len())?);
        self.t = state.step()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn no_memory_saving_vs_adamw() {
        let params = vec![Tensor::zeros("w", &[10, 10])];
        let opt = Lamb::new(Hyper::default(), &params);
        assert_eq!(opt.state_bytes(), 2 * 100 * 4);
    }

    #[test]
    fn trust_ratio_scales_update_by_param_norm() {
        // Same gradient, parameters 10× larger → update ~10× larger.
        let hp = Hyper { weight_decay: 0.0, ..Hyper::default() };
        let g = Tensor::new("w", &[2], vec![1.0, 1.0]);

        let mut small = vec![Tensor::new("w", &[2], vec![0.1, 0.1])];
        let mut o1 = Lamb::new(hp, &small);
        let before_s = small[0].data.clone();
        o1.step(&mut small, std::slice::from_ref(&g), 1e-2);
        let ds = (small[0].data[0] - before_s[0]).abs();

        let mut big = vec![Tensor::new("w", &[2], vec![1.0, 1.0])];
        let mut o2 = Lamb::new(hp, &big);
        let before_b = big[0].data.clone();
        o2.step(&mut big, std::slice::from_ref(&g), 1e-2);
        let db = (big[0].data[0] - before_b[0]).abs();

        assert!((db / ds - 10.0).abs() < 0.5, "ratio {}", db / ds);
    }

    #[test]
    fn descends_on_quadratic() {
        let mut rng = Rng::new(9);
        let hp = Hyper { weight_decay: 0.0, ..Hyper::default() };
        let mut params = vec![Tensor::randn("w", &[8, 8], 1.0, &mut rng)];
        let mut opt = Lamb::new(hp, &params);
        let start = params[0].sq_norm();
        for _ in 0..200 {
            let g = Tensor::new("w", &[8, 8], params[0].data.clone());
            opt.step(&mut params, &[g], 1e-2);
        }
        assert!(params[0].sq_norm() < 0.5 * start);
    }

    #[test]
    fn state_roundtrips() {
        let mut rng = Rng::new(12);
        let mut pa = vec![Tensor::randn("w", &[3, 3], 1.0, &mut rng)];
        let g = Tensor::randn("w", &[3, 3], 1.0, &mut rng);
        let mut a = Lamb::new(Hyper::default(), &pa);
        a.step(&mut pa, std::slice::from_ref(&g), 1e-2);
        let sd = a.state_dict();
        assert_eq!(sd.len(), a.state_len());
        let mut pb = pa.clone();
        let mut b = Lamb::new(Hyper::default(), &pb);
        b.load_state_dict(&sd).unwrap();
        a.step(&mut pa, std::slice::from_ref(&g), 1e-2);
        b.step(&mut pb, std::slice::from_ref(&g), 1e-2);
        assert_eq!(pa, pb);
    }
}
