//! SM3 (Anil et al. 2019) — memory-efficient AdaGrad, the third
//! lightweight baseline in the paper's comparison (SM3-II update rule),
//! with β1 = 0.9 momentum added as in the paper's setup.
//!
//! For a matrix (r × c) it keeps one accumulator per row and one per
//! column; the per-coordinate second-moment estimate is
//! `min(row_acc[i], col_acc[j])`, monotonically grown by `g²`.
//! Tensor-granular: the row/column cover couples a whole tensor.

use std::sync::Arc;

use anyhow::Result;

use super::core::{check_state_len, Arena, GradView, Granularity,
                  Optimizer, ParamView, StateDict};
use super::Hyper;
use crate::tensor::Tensor;

enum Cover {
    Mat { row: Vec<f32>, col: Vec<f32>, rows: usize, cols: usize },
    Vec { acc: Vec<f32> },
}

pub struct Sm3 {
    hp: Hyper,
    arena: Arc<Arena>,
    /// Momentum, arena-flat.
    m: Vec<f32>,
    cover: Vec<Cover>,
}

impl Sm3 {
    pub fn new(hp: Hyper, params: &[Tensor]) -> Sm3 {
        let arena = Arc::new(Arena::of(params));
        let cover = arena
            .spans
            .iter()
            .map(|s| {
                if s.shape.len() >= 2 {
                    let cols = *s.shape.last().unwrap();
                    let rows = s.len / cols;
                    Cover::Mat {
                        row: vec![0.0; rows],
                        col: vec![0.0; cols],
                        rows,
                        cols,
                    }
                } else {
                    Cover::Vec { acc: vec![0.0; s.len] }
                }
            })
            .collect();
        let n = arena.total;
        Sm3 { hp, arena, m: vec![0.0; n], cover }
    }

    #[cfg(test)]
    fn cover(&self, i: usize) -> &Cover {
        &self.cover[i]
    }
}

impl Optimizer for Sm3 {
    fn name(&self) -> String {
        "sm3".into()
    }

    fn arena(&self) -> &Arc<Arena> {
        &self.arena
    }

    fn granularity(&self) -> Granularity {
        Granularity::Tensor
    }

    fn step_segment(&mut self, params: ParamView<'_>, grads: GradView<'_>,
                    lr: f32) {
        assert_eq!(params.range(), (grads.lo(), grads.hi()));
        let (lo, hi) = params.range();
        let arena = Arc::clone(&self.arena);
        let (i0, spans) = arena.spans_in(lo, hi);
        let b1 = self.hp.beta1;
        let eps = self.hp.eps;
        let wd = 1.0 - lr * self.hp.weight_decay;
        for (k, sp) in spans.iter().enumerate() {
            let i = i0 + k;
            let a = sp.offset - lo;
            match &mut self.cover[i] {
                Cover::Mat { row, col, rows, cols } => {
                    let (rows, cols) = (*rows, *cols);
                    // New row/col accumulators are maxes of ν over the
                    // slice (SM3-II), computed from the previous cover.
                    let mut new_row = vec![0.0f32; rows];
                    let mut new_col = vec![0.0f32; cols];
                    for ri in 0..rows {
                        for ci in 0..cols {
                            let j = ri * cols + ci;
                            let gv = grads.data[a + j];
                            let nu = row[ri].min(col[ci]) + gv * gv;
                            new_row[ri] = new_row[ri].max(nu);
                            new_col[ci] = new_col[ci].max(nu);
                            let u = gv / (nu.sqrt() + eps);
                            let mj = b1 * self.m[sp.offset + j]
                                + (1.0 - b1) * u;
                            self.m[sp.offset + j] = mj;
                            params.data[a + j] =
                                params.data[a + j] * wd - lr * mj;
                        }
                    }
                    *row = new_row;
                    *col = new_col;
                }
                Cover::Vec { acc } => {
                    for j in 0..sp.len {
                        let gv = grads.data[a + j];
                        acc[j] += gv * gv;
                        let u = gv / (acc[j].sqrt() + eps);
                        let mj = b1 * self.m[sp.offset + j]
                            + (1.0 - b1) * u;
                        self.m[sp.offset + j] = mj;
                        params.data[a + j] =
                            params.data[a + j] * wd - lr * mj;
                    }
                }
            }
        }
    }

    fn state_bytes(&self) -> usize {
        let c: usize = self
            .cover
            .iter()
            .map(|c| match c {
                Cover::Mat { row, col, .. } => row.len() + col.len(),
                Cover::Vec { acc } => acc.len(),
            })
            .sum();
        (c + self.m.len()) * 4
    }

    /// Entries: `m` (arena-flat); per matrix tensor `row/<name>` and
    /// `col/<name>`; per vector tensor `acc/<name>`.
    fn state_dict(&self) -> StateDict {
        let mut sd = StateDict::new();
        sd.insert("m", &[self.m.len()], self.m.clone());
        for (sp, cv) in self.arena.spans.iter().zip(&self.cover) {
            match cv {
                Cover::Mat { row, col, .. } => {
                    sd.insert(format!("row/{}", sp.name), &[row.len()],
                              row.clone());
                    sd.insert(format!("col/{}", sp.name), &[col.len()],
                              col.clone());
                }
                Cover::Vec { acc } => {
                    sd.insert(format!("acc/{}", sp.name), &[acc.len()],
                              acc.clone());
                }
            }
        }
        sd
    }

    fn state_len(&self) -> usize {
        1 + self
            .cover
            .iter()
            .map(|c| match c {
                Cover::Mat { .. } => 2,
                Cover::Vec { .. } => 1,
            })
            .sum::<usize>()
    }

    fn load_state_dict(&mut self, state: &StateDict) -> Result<()> {
        check_state_len(state, self.state_len(), "sm3")?;
        self.m.copy_from_slice(state.data("m", self.m.len())?);
        for (sp, cv) in self.arena.spans.iter().zip(&mut self.cover) {
            match cv {
                Cover::Mat { row, col, .. } => {
                    row.copy_from_slice(state.data(
                        &format!("row/{}", sp.name), row.len())?);
                    col.copy_from_slice(state.data(
                        &format!("col/{}", sp.name), col.len())?);
                }
                Cover::Vec { acc } => {
                    acc.copy_from_slice(state.data(
                        &format!("acc/{}", sp.name), acc.len())?);
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn cover_dominates_gradient_squares() {
        // SM3 invariant: row_acc[i] >= Σ_t g²_{t,ij} slicewise-max — in
        // particular after one step, min(row, col) >= g² of each entry.
        let hp = Hyper { beta1: 0.0, weight_decay: 0.0, ..Hyper::default() };
        let mut params = vec![Tensor::zeros("w", &[3, 3])];
        let g = Tensor::new("w", &[3, 3],
                            vec![1.0, 2.0, 3.0, 0.5, 0.1, 4.0,
                                 2.0, 2.0, 0.3]);
        let mut opt = Sm3::new(hp, &params);
        opt.step(&mut params, &[g.clone()], 0.1);
        if let Cover::Mat { row, col, .. } = opt.cover(0) {
            for ri in 0..3 {
                for ci in 0..3 {
                    let gsq = g.data[ri * 3 + ci].powi(2);
                    assert!(row[ri].min(col[ci]) >= gsq - 1e-6);
                }
            }
        } else {
            panic!("expected matrix cover");
        }
    }

    #[test]
    fn descends_on_quadratic() {
        let mut rng = Rng::new(5);
        let hp = Hyper { weight_decay: 0.0, ..Hyper::default() };
        let mut params = vec![Tensor::randn("w", &[8, 8], 1.0, &mut rng)];
        let mut opt = Sm3::new(hp, &params);
        let start = params[0].sq_norm();
        for _ in 0..300 {
            let g = Tensor::new("w", &[8, 8], params[0].data.clone());
            opt.step(&mut params, &[g], 5e-2);
        }
        assert!(params[0].sq_norm() < 0.2 * start);
    }

    #[test]
    fn memory_is_sublinear() {
        let params = vec![Tensor::zeros("w", &[100, 100])];
        let opt = Sm3::new(Hyper::default(), &params);
        assert_eq!(opt.state_bytes(), (100 * 100 + 200) * 4);
    }

    #[test]
    fn state_roundtrips() {
        let mut rng = Rng::new(7);
        let mut pa = vec![Tensor::randn("w", &[3, 3], 1.0, &mut rng),
                          Tensor::randn("b", &[4], 1.0, &mut rng)];
        let gs: Vec<Vec<Tensor>> = (0..4)
            .map(|_| vec![Tensor::randn("w", &[3, 3], 1.0, &mut rng),
                          Tensor::randn("b", &[4], 1.0, &mut rng)])
            .collect();
        let mut a = Sm3::new(Hyper::default(), &pa);
        for g in &gs[..2] {
            a.step(&mut pa, g, 1e-2);
        }
        let sd = a.state_dict();
        // m + row/w + col/w + acc/b.
        assert_eq!(sd.len(), 4);
        assert_eq!(sd.len(), a.state_len());
        let mut pb = pa.clone();
        let mut b = Sm3::new(Hyper::default(), &pb);
        b.load_state_dict(&sd).unwrap();
        for g in &gs[2..] {
            a.step(&mut pa, g, 1e-2);
            b.step(&mut pb, g, 1e-2);
        }
        assert_eq!(pa, pb);
    }
}
