//! SM3 (Anil et al. 2019) — memory-efficient AdaGrad, the third
//! lightweight baseline in the paper's comparison (SM3-II update rule),
//! with β1 = 0.9 momentum added as in the paper's setup.
//!
//! For a matrix (r × c) it keeps one accumulator per row and one per
//! column; the per-coordinate second-moment estimate is
//! `min(row_acc[i], col_acc[j])`, monotonically grown by `g²`.

use super::{Hyper, Optimizer};
use crate::tensor::Tensor;

enum Cover {
    Mat { row: Vec<f32>, col: Vec<f32>, rows: usize, cols: usize },
    Vec { acc: Vec<f32> },
}

pub struct Sm3 {
    hp: Hyper,
    m: Vec<Tensor>,
    cover: Vec<Cover>,
}

impl Sm3 {
    pub fn new(hp: Hyper, params: &[Tensor]) -> Sm3 {
        let cover = params
            .iter()
            .map(|p| {
                if p.shape.len() >= 2 {
                    let cols = *p.shape.last().unwrap();
                    let rows = p.numel() / cols;
                    Cover::Mat {
                        row: vec![0.0; rows],
                        col: vec![0.0; cols],
                        rows,
                        cols,
                    }
                } else {
                    Cover::Vec { acc: vec![0.0; p.numel()] }
                }
            })
            .collect();
        Sm3 {
            hp,
            m: params
                .iter()
                .map(|p| Tensor::zeros(&*p.name, &p.shape))
                .collect(),
            cover,
        }
    }
}

impl Optimizer for Sm3 {
    fn name(&self) -> String {
        "sm3".into()
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        let b1 = self.hp.beta1;
        let eps = self.hp.eps;
        let wd = 1.0 - lr * self.hp.weight_decay;
        for (i, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            let m = &mut self.m[i];
            match &mut self.cover[i] {
                Cover::Mat { row, col, rows, cols } => {
                    let (rows, cols) = (*rows, *cols);
                    // New row/col accumulators are maxes of ν over the
                    // slice (SM3-II), computed from the previous cover.
                    let mut new_row = vec![0.0f32; rows];
                    let mut new_col = vec![0.0f32; cols];
                    for ri in 0..rows {
                        for ci in 0..cols {
                            let j = ri * cols + ci;
                            let gv = g.data[j];
                            let nu = row[ri].min(col[ci]) + gv * gv;
                            new_row[ri] = new_row[ri].max(nu);
                            new_col[ci] = new_col[ci].max(nu);
                            let u = gv / (nu.sqrt() + eps);
                            let mj = b1 * m.data[j] + (1.0 - b1) * u;
                            m.data[j] = mj;
                            p.data[j] = p.data[j] * wd - lr * mj;
                        }
                    }
                    *row = new_row;
                    *col = new_col;
                }
                Cover::Vec { acc } => {
                    for j in 0..p.data.len() {
                        let gv = g.data[j];
                        acc[j] += gv * gv;
                        let u = gv / (acc[j].sqrt() + eps);
                        let mj = b1 * m.data[j] + (1.0 - b1) * u;
                        m.data[j] = mj;
                        p.data[j] = p.data[j] * wd - lr * mj;
                    }
                }
            }
        }
    }

    fn state_bytes(&self) -> usize {
        let c: usize = self
            .cover
            .iter()
            .map(|c| match c {
                Cover::Mat { row, col, .. } => row.len() + col.len(),
                Cover::Vec { acc } => acc.len(),
            })
            .sum();
        (c + self.m.iter().map(Tensor::numel).sum::<usize>()) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn cover_dominates_gradient_squares() {
        // SM3 invariant: row_acc[i] >= Σ_t g²_{t,ij} slicewise-max — in
        // particular after one step, min(row, col) >= g² of each entry.
        let hp = Hyper { beta1: 0.0, weight_decay: 0.0, ..Hyper::default() };
        let mut params = vec![Tensor::zeros("w", &[3, 3])];
        let g = Tensor::new("w", &[3, 3],
                            vec![1.0, 2.0, 3.0, 0.5, 0.1, 4.0,
                                 2.0, 2.0, 0.3]);
        let mut opt = Sm3::new(hp, &params);
        opt.step(&mut params, &[g.clone()], 0.1);
        if let Cover::Mat { row, col, .. } = &opt.cover[0] {
            for ri in 0..3 {
                for ci in 0..3 {
                    let gsq = g.data[ri * 3 + ci].powi(2);
                    assert!(row[ri].min(col[ci]) >= gsq - 1e-6);
                }
            }
        } else {
            panic!("expected matrix cover");
        }
    }

    #[test]
    fn descends_on_quadratic() {
        let mut rng = Rng::new(5);
        let hp = Hyper { weight_decay: 0.0, ..Hyper::default() };
        let mut params = vec![Tensor::randn("w", &[8, 8], 1.0, &mut rng)];
        let mut opt = Sm3::new(hp, &params);
        let start = params[0].sq_norm();
        for _ in 0..300 {
            let g = Tensor::new("w", &[8, 8], params[0].data.clone());
            opt.step(&mut params, &[g], 5e-2);
        }
        assert!(params[0].sq_norm() < 0.2 * start);
    }

    #[test]
    fn memory_is_sublinear() {
        let params = vec![Tensor::zeros("w", &[100, 100])];
        let opt = Sm3::new(Hyper::default(), &params);
        assert_eq!(opt.state_bytes(), (100 * 100 + 200) * 4);
    }
}
