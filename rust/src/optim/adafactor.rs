//! Adafactor (Shazeer & Stern 2018) — the paper's main memory-efficient
//! baseline — in two variants:
//!
//! - `Original`: factored second moment with the β̂2(t) = 1 − t^(−0.8)
//!   schedule and update clipping (d = 1.0); momentum β1 = 0.9 added as
//!   in the paper's §3 setup ("we incorporate momentum to ensure a fair
//!   comparison").
//! - `Zhai`: the Zhai et al. (2022) modification — fixed β2, same
//!   clipping, explicit learning rate (paper §3.4 / Appendix D.7).
//!
//! Matrices factor v into row statistics R and column statistics C
//! (O(r + c) memory); vectors fall back to full AdaGrad-style v.
//! Tensor-granular: the row/column factors couple a whole tensor.

use std::sync::Arc;

use anyhow::Result;

use super::core::{check_state_len, Arena, GradView, Granularity,
                  Optimizer, ParamView, StateDict};
use super::kernels::{self, Dispatch};
use super::Hyper;
use crate::tensor::Tensor;

const EPS1: f32 = 1e-30;
const CLIP_D: f32 = 1.0;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdafactorVariant {
    Original,
    Zhai,
}

enum Factored {
    /// Matrix tensors: row and column second-moment EMAs.
    Mat { r: Vec<f32>, c: Vec<f32>, rows: usize, cols: usize },
    /// Vector tensors: full second moment.
    Vec { v: Vec<f32> },
}

pub struct Adafactor {
    hp: Hyper,
    variant: AdafactorVariant,
    arena: Arc<Arena>,
    dispatch: Dispatch,
    /// Momentum, arena-flat.
    m: Vec<f32>,
    /// Per-span factored second moment.
    state: Vec<Factored>,
    t: u64,
}

/// Flatten an nd shape to (rows, cols) with cols = last dim.
fn mat_dims(shape: &[usize]) -> Option<(usize, usize)> {
    if shape.len() < 2 {
        return None;
    }
    let cols = *shape.last().unwrap();
    let rows: usize = shape[..shape.len() - 1].iter().product();
    Some((rows, cols))
}

impl Adafactor {
    pub fn new(hp: Hyper, params: &[Tensor], variant: AdafactorVariant)
        -> Adafactor {
        let arena = Arc::new(Arena::of(params));
        let state = arena
            .spans
            .iter()
            .map(|s| match mat_dims(&s.shape) {
                Some((rows, cols)) => Factored::Mat {
                    r: vec![0.0; rows],
                    c: vec![0.0; cols],
                    rows,
                    cols,
                },
                None => Factored::Vec { v: vec![0.0; s.len] },
            })
            .collect();
        let n = arena.total;
        Adafactor { hp, variant, arena,
                    dispatch: Dispatch::for_arena(n), m: vec![0.0; n],
                    state, t: 0 }
    }

    fn beta2_t(&self) -> f32 {
        match self.variant {
            // Shazeer & Stern eq. (Alg 4): β̂2(t) = 1 − t^(−0.8).
            AdafactorVariant::Original => {
                1.0 - (self.t as f32).powf(-0.8)
            }
            AdafactorVariant::Zhai => self.hp.beta2,
        }
    }

    fn step_impl(&mut self, params: ParamView<'_>, grads: GradView<'_>,
                 lr: f32, gscale: f32) {
        debug_assert!(self.t > 0, "step_segment before begin_step");
        assert_eq!(params.range(), (grads.lo(), grads.hi()));
        let (lo, hi) = params.range();
        let arena = Arc::clone(&self.arena);
        let (i0, spans) = arena.spans_in(lo, hi);
        let b2 = self.beta2_t();
        let b1 = self.hp.beta1;
        let wd = 1.0 - lr * self.hp.weight_decay;
        let d = self.dispatch;

        for (k, sp) in spans.iter().enumerate() {
            let i = i0 + k;
            let a = sp.offset - lo;
            let n = sp.len;
            let g = &grads.data[a..a + n];
            // u = g / sqrt(v̂), with v̂ from factored or full state.
            let mut u = vec![0.0f32; n];
            match &mut self.state[i] {
                Factored::Mat { r, c, rows, cols } => {
                    let (rows, cols) = (*rows, *cols);
                    // Row statistics: the inner Σ g² + ε1 per row runs
                    // through the vectorizable fold (reassociates
                    // under Vector dispatch — ULP tolerance).
                    for ri in 0..rows {
                        let acc = kernels::sq_eps_sum(
                            d, &g[ri * cols..(ri + 1) * cols], gscale,
                            EPS1);
                        r[ri] = b2 * r[ri]
                            + (1.0 - b2) * (acc / cols as f32);
                    }
                    // Column statistics: accumulate row by row across
                    // the column axis — strided elementwise, so this
                    // fold is bitwise identical under both dispatches
                    // (each column's partial sums stay in row order,
                    // exactly like the scalar column-major loop).
                    let mut cacc = vec![0.0f32; cols];
                    for ri in 0..rows {
                        kernels::col_sq_accumulate(
                            d, &g[ri * cols..(ri + 1) * cols], gscale,
                            EPS1, &mut cacc);
                    }
                    for ci in 0..cols {
                        c[ci] = b2 * c[ci]
                            + (1.0 - b2) * (cacc[ci] / rows as f32);
                    }
                    let r_mean: f32 =
                        r.iter().sum::<f32>() / rows as f32 + EPS1;
                    for ri in 0..rows {
                        for ci in 0..cols {
                            let vhat = r[ri] * c[ci] / r_mean;
                            u[ri * cols + ci] = g[ri * cols + ci]
                                * gscale
                                / (vhat.sqrt() + EPS1);
                        }
                    }
                }
                Factored::Vec { v } => {
                    for j in 0..n {
                        let gv = g[j] * gscale;
                        v[j] = b2 * v[j] + (1.0 - b2) * (gv * gv + EPS1);
                        u[j] = gv / (v[j].sqrt() + EPS1);
                    }
                }
            }
            // Update clipping: u /= max(1, RMS(u)/d).
            let rms = kernels::sq_mean(d, &u, 1.0).sqrt();
            let scale = 1.0 / (rms / CLIP_D).max(1.0);
            // Momentum on the clipped update, then apply.
            for j in 0..n {
                let mj = b1 * self.m[sp.offset + j]
                    + (1.0 - b1) * u[j] * scale;
                self.m[sp.offset + j] = mj;
                params.data[a + j] = params.data[a + j] * wd - lr * mj;
            }
        }
    }
}

impl Optimizer for Adafactor {
    fn name(&self) -> String {
        match self.variant {
            AdafactorVariant::Original => "adafactor".into(),
            AdafactorVariant::Zhai => "adafactor_zhai".into(),
        }
    }

    fn arena(&self) -> &Arc<Arena> {
        &self.arena
    }

    fn granularity(&self) -> Granularity {
        Granularity::Tensor
    }

    fn begin_step(&mut self) {
        self.t += 1;
    }

    fn step_segment(&mut self, params: ParamView<'_>, grads: GradView<'_>,
                    lr: f32) {
        self.step_impl(params, grads, lr, 1.0);
    }

    fn step_segment_scaled(&mut self, params: ParamView<'_>,
                           grads: GradView<'_>, lr: f32, gscale: f32) {
        self.step_impl(params, grads, lr, gscale);
    }

    fn state_bytes(&self) -> usize {
        let factored: usize = self
            .state
            .iter()
            .map(|s| match s {
                Factored::Mat { r, c, .. } => r.len() + c.len(),
                Factored::Vec { v } => v.len(),
            })
            .sum();
        (factored + self.m.len()) * 4
    }

    /// Entries: `m` (arena-flat), per matrix tensor `r/<name>` and
    /// `c/<name>`, per vector tensor `v/<name>`, `__step`.
    fn state_dict(&self) -> StateDict {
        let mut sd = StateDict::new();
        sd.insert("m", &[self.m.len()], self.m.clone());
        for (sp, st) in self.arena.spans.iter().zip(&self.state) {
            match st {
                Factored::Mat { r, c, .. } => {
                    sd.insert(format!("r/{}", sp.name), &[r.len()],
                              r.clone());
                    sd.insert(format!("c/{}", sp.name), &[c.len()],
                              c.clone());
                }
                Factored::Vec { v } => {
                    sd.insert(format!("v/{}", sp.name), &[v.len()],
                              v.clone());
                }
            }
        }
        sd.set_step(self.t);
        sd
    }

    fn state_len(&self) -> usize {
        2 + self
            .state
            .iter()
            .map(|s| match s {
                Factored::Mat { .. } => 2,
                Factored::Vec { .. } => 1,
            })
            .sum::<usize>()
    }

    fn load_state_dict(&mut self, state: &StateDict) -> Result<()> {
        check_state_len(state, self.state_len(), "adafactor")?;
        self.m.copy_from_slice(state.data("m", self.m.len())?);
        for (sp, st) in self.arena.spans.iter().zip(&mut self.state) {
            match st {
                Factored::Mat { r, c, .. } => {
                    r.copy_from_slice(state.data(
                        &format!("r/{}", sp.name), r.len())?);
                    c.copy_from_slice(state.data(
                        &format!("c/{}", sp.name), c.len())?);
                }
                Factored::Vec { v } => {
                    v.copy_from_slice(state.data(
                        &format!("v/{}", sp.name), v.len())?);
                }
            }
        }
        self.t = state.step()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn factored_state_is_sublinear_for_matrices() {
        let mut rng = Rng::new(0);
        let params = vec![Tensor::randn("w", &[64, 64], 0.02, &mut rng)];
        let opt = Adafactor::new(Hyper::default(), &params,
                                 AdafactorVariant::Original);
        // m is full (momentum), but v is 64 + 64 instead of 4096.
        assert_eq!(opt.state_bytes(), (64 * 64 + 128) * 4);
    }

    #[test]
    fn descends_on_quadratic_both_variants() {
        for variant in [AdafactorVariant::Original, AdafactorVariant::Zhai] {
            let mut rng = Rng::new(7);
            let hp = Hyper { weight_decay: 0.0, ..Hyper::default() };
            let mut params =
                vec![Tensor::randn("w", &[8, 8], 1.0, &mut rng)];
            let mut opt = Adafactor::new(hp, &params, variant);
            let start = params[0].sq_norm();
            for _ in 0..300 {
                let g = Tensor::new("w", &[8, 8], params[0].data.clone());
                opt.step(&mut params, &[g], 1e-2);
            }
            let end = params[0].sq_norm();
            assert!(end < 0.2 * start, "{variant:?}: {start} -> {end}");
        }
    }

    #[test]
    fn clipping_bounds_update_rms() {
        // A huge first-step gradient must not produce an update with
        // RMS(u) > 1 (the d=1.0 clip).
        let hp = Hyper { beta1: 0.0, weight_decay: 0.0, ..Hyper::default() };
        let mut params = vec![Tensor::zeros("w", &[4, 4])];
        let g = Tensor::new("w", &[4, 4], vec![1e6; 16]);
        let mut opt =
            Adafactor::new(hp, &params, AdafactorVariant::Zhai);
        opt.step(&mut params, &[g], 1.0);
        let rms = (params[0].sq_norm() / 16.0).sqrt();
        assert!(rms <= CLIP_D as f64 + 1e-5, "rms {rms}");
    }

    #[test]
    fn vector_params_use_full_v() {
        let params = vec![Tensor::zeros("b", &[32])];
        let opt = Adafactor::new(Hyper::default(), &params,
                                 AdafactorVariant::Original);
        assert_eq!(opt.state_bytes(), (32 + 32) * 4);
    }

    #[test]
    fn state_roundtrips_with_named_factors() {
        let mut rng = Rng::new(3);
        let mut pa = vec![Tensor::randn("w", &[4, 3], 1.0, &mut rng),
                          Tensor::randn("b", &[5], 1.0, &mut rng)];
        let gs: Vec<Vec<Tensor>> = (0..4)
            .map(|_| vec![Tensor::randn("w", &[4, 3], 1.0, &mut rng),
                          Tensor::randn("b", &[5], 1.0, &mut rng)])
            .collect();
        let mut a = Adafactor::new(Hyper::default(), &pa,
                                   AdafactorVariant::Zhai);
        for g in &gs[..2] {
            a.step(&mut pa, g, 1e-2);
        }
        let sd = a.state_dict();
        // m + (r/w, c/w) + v/b + __step.
        assert_eq!(sd.len(), 5);
        assert_eq!(sd.len(), a.state_len());
        assert!(sd.get("r/w").is_some());
        assert!(sd.get("c/w").is_some());
        assert!(sd.get("v/b").is_some());
        let mut pb = pa.clone();
        let mut b = Adafactor::new(Hyper::default(), &pb,
                                   AdafactorVariant::Zhai);
        b.load_state_dict(&sd).unwrap();
        for g in &gs[2..] {
            a.step(&mut pa, g, 1e-2);
            b.step(&mut pb, g, 1e-2);
        }
        assert_eq!(pa, pb);
    }
}
