//! Learning-rate schedules. The schedule lives in the Rust coordinator
//! (L3); AOT train-step artifacts take the current scalar lr as input.
//!
//! Paper setups: GPT-2 runs use warmup+cosine (2000-step warmup,
//! min_lr = peak/20 or /10); Llama/Torchtitan runs use 1%-warmup +
//! linear decay (Appendix F.1).

/// A learning-rate schedule over `total_steps`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Schedule {
    Constant { lr: f32 },
    /// Linear warmup to `peak`, cosine decay to `min_lr`.
    WarmupCosine { peak: f32, min_lr: f32, warmup: usize,
                   total: usize },
    /// Linear warmup to `peak`, linear decay to `min_lr` (Torchtitan).
    WarmupLinear { peak: f32, min_lr: f32, warmup: usize,
                   total: usize },
}

impl Schedule {
    /// Paper GPT-2 protocol: cosine with explicit warmup steps.
    pub fn gpt2(peak: f32, total: usize) -> Schedule {
        Schedule::WarmupCosine {
            peak,
            min_lr: peak / 20.0,
            warmup: (total / 20).max(1),
            total,
        }
    }

    /// Paper Llama/Torchtitan protocol: 1 % warmup + linear decay.
    pub fn llama(peak: f32, total: usize) -> Schedule {
        Schedule::WarmupLinear {
            peak,
            min_lr: 0.0,
            warmup: (total / 100).max(1),
            total,
        }
    }

    /// lr at 1-based step `t`.
    pub fn lr(&self, t: usize) -> f32 {
        match *self {
            Schedule::Constant { lr } => lr,
            Schedule::WarmupCosine { peak, min_lr, warmup, total } => {
                if t <= warmup {
                    peak * t as f32 / warmup as f32
                } else {
                    let frac = (t - warmup) as f32
                        / (total.saturating_sub(warmup)).max(1) as f32;
                    let frac = frac.min(1.0);
                    min_lr
                        + 0.5 * (peak - min_lr)
                            * (1.0 + (std::f32::consts::PI * frac).cos())
                }
            }
            Schedule::WarmupLinear { peak, min_lr, warmup, total } => {
                if t <= warmup {
                    peak * t as f32 / warmup as f32
                } else {
                    let frac = (t - warmup) as f32
                        / (total.saturating_sub(warmup)).max(1) as f32;
                    let frac = frac.min(1.0);
                    peak + (min_lr - peak) * frac
                }
            }
        }
    }

    pub fn peak(&self) -> f32 {
        match *self {
            Schedule::Constant { lr } => lr,
            Schedule::WarmupCosine { peak, .. } => peak,
            Schedule::WarmupLinear { peak, .. } => peak,
        }
    }

    /// Same shape with a different peak (and proportional min_lr) — for
    /// lr grid sweeps.
    pub fn with_peak(&self, new_peak: f32) -> Schedule {
        match *self {
            Schedule::Constant { .. } => Schedule::Constant { lr: new_peak },
            Schedule::WarmupCosine { peak, min_lr, warmup, total } => {
                Schedule::WarmupCosine {
                    peak: new_peak,
                    min_lr: min_lr / peak * new_peak,
                    warmup,
                    total,
                }
            }
            Schedule::WarmupLinear { peak, min_lr, warmup, total } => {
                Schedule::WarmupLinear {
                    peak: new_peak,
                    min_lr: min_lr / peak * new_peak,
                    warmup,
                    total,
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_reaches_peak_then_decays() {
        let s = Schedule::WarmupCosine {
            peak: 1.0, min_lr: 0.1, warmup: 10, total: 110,
        };
        assert!((s.lr(1) - 0.1).abs() < 1e-6);
        assert!((s.lr(10) - 1.0).abs() < 1e-6);
        assert!(s.lr(60) < 1.0 && s.lr(60) > 0.1);
        assert!((s.lr(110) - 0.1).abs() < 1e-5);
        // Never exceeds total.
        assert!((s.lr(200) - 0.1).abs() < 1e-5);
    }

    #[test]
    fn linear_decay_hits_min() {
        let s = Schedule::WarmupLinear {
            peak: 2.0, min_lr: 0.0, warmup: 5, total: 105,
        };
        assert!((s.lr(5) - 2.0).abs() < 1e-6);
        assert!((s.lr(55) - 1.0).abs() < 1e-6);
        assert!(s.lr(105) < 1e-6);
    }

    #[test]
    fn schedule_monotone_after_warmup() {
        let s = Schedule::gpt2(6e-4, 1000);
        let mut prev = f32::MAX;
        for t in 51..=1000 {
            let lr = s.lr(t);
            assert!(lr <= prev + 1e-9, "not monotone at {t}");
            prev = lr;
        }
    }

    #[test]
    fn with_peak_rescales() {
        let s = Schedule::gpt2(6e-4, 100).with_peak(3e-4);
        assert!((s.peak() - 3e-4).abs() < 1e-9);
    }
}
