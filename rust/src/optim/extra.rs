//! Related-work optimizers the paper discusses in Appendix A: AdaGrad
//! (Duchi et al. 2011 — the ancestor SM3 compresses), NovoGrad
//! (Ginsburg et al. 2019 — layer-wise second moments with the
//! normalized-gradient momentum the paper contrasts with Adam-mini),
//! and Adan (Xie et al. 2022 — Nesterov-momentum Adam, listed as a
//! combinable diagonal method).

use super::{Hyper, Optimizer};
use crate::tensor::Tensor;

/// AdaGrad with optional momentum.
pub struct AdaGrad {
    eps: f32,
    momentum: f32,
    acc: Vec<Tensor>,
    buf: Vec<Tensor>,
}

impl AdaGrad {
    pub fn new(params: &[Tensor], momentum: f32, eps: f32) -> AdaGrad {
        AdaGrad {
            eps,
            momentum,
            acc: params.iter().map(|p| Tensor::zeros(&*p.name, &p.shape))
                .collect(),
            buf: params.iter().map(|p| Tensor::zeros(&*p.name, &p.shape))
                .collect(),
        }
    }
}

impl Optimizer for AdaGrad {
    fn name(&self) -> String {
        "adagrad".into()
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        for ((p, g), (a, b)) in params
            .iter_mut()
            .zip(grads)
            .zip(self.acc.iter_mut().zip(self.buf.iter_mut()))
        {
            for i in 0..p.data.len() {
                let gi = g.data[i];
                a.data[i] += gi * gi;
                let u = gi / (a.data[i].sqrt() + self.eps);
                b.data[i] = self.momentum * b.data[i] + u;
                p.data[i] -= lr * b.data[i];
            }
        }
    }

    fn state_bytes(&self) -> usize {
        self.acc.iter().map(Tensor::numel).sum::<usize>() * 4 * 2
    }
}

/// NovoGrad: ONE second-moment scalar per layer (PyTorch-default
/// partition granularity), and momentum over *normalized* gradients —
/// m = β1·m + (g/√v_layer + λ·p). The paper (App. A) predicts the
/// layer-wise granularity inherits the default-partition instability;
/// `repro exp fig21` can be extended with it to check.
pub struct NovoGrad {
    hp: Hyper,
    m: Vec<Tensor>,
    /// One v per tensor (layer).
    v: Vec<f32>,
    t: u64,
}

impl NovoGrad {
    pub fn new(hp: Hyper, params: &[Tensor]) -> NovoGrad {
        NovoGrad {
            hp,
            m: params.iter().map(|p| Tensor::zeros(&*p.name, &p.shape))
                .collect(),
            v: vec![0.0; params.len()],
            t: 0,
        }
    }
}

impl Optimizer for NovoGrad {
    fn name(&self) -> String {
        "novograd".into()
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        self.t += 1;
        let Hyper { beta1, beta2, eps, weight_decay } = self.hp;
        for (i, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            let gsq: f32 =
                g.data.iter().map(|x| (x * x)).sum::<f32>();
            self.v[i] = if self.t == 1 {
                gsq
            } else {
                beta2 * self.v[i] + (1.0 - beta2) * gsq
            };
            let denom = self.v[i].sqrt() + eps;
            let m = &mut self.m[i];
            for j in 0..p.data.len() {
                let u = g.data[j] / denom + weight_decay * p.data[j];
                m.data[j] = beta1 * m.data[j] + u;
                p.data[j] -= lr * m.data[j];
            }
        }
    }

    fn state_bytes(&self) -> usize {
        (self.m.iter().map(Tensor::numel).sum::<usize>() + self.v.len())
            * 4
    }
}

/// Adan: Nesterov-style Adam with gradient-difference momentum.
pub struct Adan {
    hp: Hyper,
    /// β3 for the gradient-difference EMA.
    beta3: f32,
    m: Vec<Tensor>,
    d: Vec<Tensor>,
    v: Vec<Tensor>,
    prev_g: Vec<Tensor>,
    t: u64,
}

impl Adan {
    pub fn new(hp: Hyper, params: &[Tensor]) -> Adan {
        let z = |_: &Tensor| ();
        let mk = || {
            params
                .iter()
                .map(|p| Tensor::zeros(&*p.name, &p.shape))
                .collect::<Vec<_>>()
        };
        let _ = z;
        Adan { hp, beta3: 0.99, m: mk(), d: mk(), v: mk(), prev_g: mk(),
               t: 0 }
    }
}

impl Optimizer for Adan {
    fn name(&self) -> String {
        "adan".into()
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        self.t += 1;
        let Hyper { beta1, beta2, eps, weight_decay } = self.hp;
        let b3 = self.beta3;
        for (i, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            let (m, d, v, pg) = (&mut self.m[i], &mut self.d[i],
                                 &mut self.v[i], &mut self.prev_g[i]);
            for j in 0..p.data.len() {
                let gj = g.data[j];
                let diff = if self.t == 1 { 0.0 } else { gj - pg.data[j] };
                m.data[j] = beta1 * m.data[j] + (1.0 - beta1) * gj;
                d.data[j] = b3 * d.data[j] + (1.0 - b3) * diff;
                let nest = gj + b3 * diff;
                v.data[j] =
                    beta2 * v.data[j] + (1.0 - beta2) * nest * nest;
                let denom = v.data[j].sqrt() + eps;
                let upd = (m.data[j] + b3 * d.data[j]) / denom;
                p.data[j] = (p.data[j] - lr * upd)
                    / (1.0 + lr * weight_decay);
                pg.data[j] = gj;
            }
        }
    }

    fn state_bytes(&self) -> usize {
        4 * self.m.iter().map(Tensor::numel).sum::<usize>() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn descends(opt: &mut dyn Optimizer, lr: f32) -> (f64, f64) {
        let mut rng = Rng::new(21);
        let mut params = vec![Tensor::randn("w", &[10, 10], 1.0,
                                            &mut rng)];
        let start = params[0].sq_norm();
        for _ in 0..300 {
            let g = Tensor::new("w", &[10, 10], params[0].data.clone());
            opt.step(&mut params, &[g], lr);
        }
        (start, params[0].sq_norm())
    }

    #[test]
    fn all_extras_descend_on_quadratic() {
        let mut rng = Rng::new(21);
        let proto = vec![Tensor::randn("w", &[10, 10], 1.0, &mut rng)];
        let hp = Hyper { weight_decay: 0.0, ..Hyper::default() };
        let mut opts: Vec<Box<dyn Optimizer>> = vec![
            Box::new(AdaGrad::new(&proto, 0.9, 1e-8)),
            Box::new(NovoGrad::new(hp, &proto)),
            Box::new(Adan::new(hp, &proto)),
        ];
        for opt in opts.iter_mut() {
            let (s, e) = descends(opt.as_mut(), 1e-2);
            assert!(e < 0.5 * s, "{}: {s} -> {e}", opt.name());
        }
    }

    #[test]
    fn novograd_state_is_one_scalar_per_tensor_plus_m() {
        let params = vec![Tensor::zeros("a", &[50, 50]),
                          Tensor::zeros("b", &[10])];
        let opt = NovoGrad::new(Hyper::default(), &params);
        assert_eq!(opt.state_bytes(), (2500 + 10 + 2) * 4);
    }

    #[test]
    fn adagrad_monotone_accumulator() {
        let mut opt = AdaGrad::new(&[Tensor::zeros("w", &[3])], 0.0, 0.0);
        let mut params = vec![Tensor::zeros("w", &[3])];
        let g = Tensor::new("w", &[3], vec![1.0, 2.0, 0.0]);
        opt.step(&mut params, std::slice::from_ref(&g), 0.1);
        opt.step(&mut params, std::slice::from_ref(&g), 0.1);
        assert!((opt.acc[0].data[0] - 2.0).abs() < 1e-6);
        assert!((opt.acc[0].data[1] - 8.0).abs() < 1e-6);
        assert_eq!(opt.acc[0].data[2], 0.0);
    }
}
