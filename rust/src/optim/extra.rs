//! Related-work optimizers the paper discusses in Appendix A: AdaGrad
//! (Duchi et al. 2011 — the ancestor SM3 compresses), NovoGrad
//! (Ginsburg et al. 2019 — layer-wise second moments with the
//! normalized-gradient momentum the paper contrasts with Adam-mini),
//! and Adan (Xie et al. 2022 — Nesterov-momentum Adam, listed as a
//! combinable diagonal method).

use std::sync::Arc;

use anyhow::Result;

use super::core::{check_state_len, Arena, GradView, Granularity,
                  Optimizer, ParamView, StateDict};
use super::kernels::{self, Dispatch};
use super::Hyper;
use crate::tensor::Tensor;

/// AdaGrad with optional momentum. Elementwise.
pub struct AdaGrad {
    eps: f32,
    momentum: f32,
    arena: Arc<Arena>,
    dispatch: Dispatch,
    acc: Vec<f32>,
    buf: Vec<f32>,
}

impl AdaGrad {
    pub fn new(params: &[Tensor], momentum: f32, eps: f32) -> AdaGrad {
        let arena = Arc::new(Arena::of(params));
        let n = arena.total;
        AdaGrad { eps, momentum, arena,
                  dispatch: Dispatch::for_arena(n), acc: vec![0.0; n],
                  buf: vec![0.0; n] }
    }

    fn step_impl(&mut self, params: ParamView<'_>, grads: GradView<'_>,
                 lr: f32, gscale: f32) {
        assert_eq!(params.range(), (grads.lo(), grads.hi()));
        let (lo, hi) = params.range();
        kernels::adagrad_step(self.dispatch, params.data, grads.data,
                              &mut self.acc[lo..hi],
                              &mut self.buf[lo..hi], self.momentum,
                              self.eps, lr, gscale);
    }

    /// The monotone g² accumulator (inspection).
    pub fn acc(&self) -> &[f32] {
        &self.acc
    }
}

impl Optimizer for AdaGrad {
    fn name(&self) -> String {
        "adagrad".into()
    }

    fn arena(&self) -> &Arc<Arena> {
        &self.arena
    }

    fn granularity(&self) -> Granularity {
        Granularity::Element
    }

    fn step_segment(&mut self, params: ParamView<'_>, grads: GradView<'_>,
                    lr: f32) {
        self.step_impl(params, grads, lr, 1.0);
    }

    fn step_segment_scaled(&mut self, params: ParamView<'_>,
                           grads: GradView<'_>, lr: f32, gscale: f32) {
        self.step_impl(params, grads, lr, gscale);
    }

    fn state_bytes(&self) -> usize {
        (self.acc.len() + self.buf.len()) * 4
    }

    /// Entries: `acc` (monotone g²), `buf` (momentum).
    fn state_dict(&self) -> StateDict {
        let mut sd = StateDict::new();
        sd.insert("acc", &[self.acc.len()], self.acc.clone());
        sd.insert("buf", &[self.buf.len()], self.buf.clone());
        sd
    }

    fn state_len(&self) -> usize {
        2
    }

    fn load_state_dict(&mut self, state: &StateDict) -> Result<()> {
        check_state_len(state, 2, "adagrad")?;
        self.acc.copy_from_slice(state.data("acc", self.acc.len())?);
        self.buf.copy_from_slice(state.data("buf", self.buf.len())?);
        Ok(())
    }
}

/// NovoGrad: ONE second-moment scalar per layer (PyTorch-default
/// partition granularity), and momentum over *normalized* gradients —
/// m = β1·m + (g/√v_layer + λ·p). The paper (App. A) predicts the
/// layer-wise granularity inherits the default-partition instability;
/// `repro exp fig21` can be extended with it to check. Tensor-granular
/// (v couples a whole tensor).
pub struct NovoGrad {
    hp: Hyper,
    arena: Arc<Arena>,
    m: Vec<f32>,
    /// One v per tensor (layer).
    v: Vec<f32>,
    t: u64,
}

impl NovoGrad {
    pub fn new(hp: Hyper, params: &[Tensor]) -> NovoGrad {
        let arena = Arc::new(Arena::of(params));
        let n = arena.total;
        let spans = arena.spans.len();
        NovoGrad { hp, arena, m: vec![0.0; n], v: vec![0.0; spans], t: 0 }
    }
}

impl Optimizer for NovoGrad {
    fn name(&self) -> String {
        "novograd".into()
    }

    fn arena(&self) -> &Arc<Arena> {
        &self.arena
    }

    fn granularity(&self) -> Granularity {
        Granularity::Tensor
    }

    fn begin_step(&mut self) {
        self.t += 1;
    }

    fn step_segment(&mut self, params: ParamView<'_>, grads: GradView<'_>,
                    lr: f32) {
        debug_assert!(self.t > 0, "step_segment before begin_step");
        assert_eq!(params.range(), (grads.lo(), grads.hi()));
        let (lo, hi) = params.range();
        let arena = Arc::clone(&self.arena);
        let (i0, spans) = arena.spans_in(lo, hi);
        let Hyper { beta1, beta2, eps, weight_decay } = self.hp;
        for (k, sp) in spans.iter().enumerate() {
            let i = i0 + k;
            let (a, b) = (sp.offset - lo, sp.offset - lo + sp.len);
            let gsq: f32 = grads.data[a..b]
                .iter()
                .map(|x| x * x)
                .sum::<f32>();
            self.v[i] = if self.t == 1 {
                gsq
            } else {
                beta2 * self.v[i] + (1.0 - beta2) * gsq
            };
            let denom = self.v[i].sqrt() + eps;
            for j in a..b {
                let u = grads.data[j] / denom
                    + weight_decay * params.data[j];
                let mj = beta1 * self.m[lo + j] + u;
                self.m[lo + j] = mj;
                params.data[j] -= lr * mj;
            }
        }
    }

    fn state_bytes(&self) -> usize {
        (self.m.len() + self.v.len()) * 4
    }

    /// Entries: `m` (arena-flat), `v` (one per tensor), `__step`.
    fn state_dict(&self) -> StateDict {
        let mut sd = StateDict::new();
        sd.insert("m", &[self.m.len()], self.m.clone());
        sd.insert("v", &[self.v.len()], self.v.clone());
        sd.set_step(self.t);
        sd
    }

    fn state_len(&self) -> usize {
        3
    }

    fn load_state_dict(&mut self, state: &StateDict) -> Result<()> {
        check_state_len(state, 3, "novograd")?;
        self.m.copy_from_slice(state.data("m", self.m.len())?);
        self.v.copy_from_slice(state.data("v", self.v.len())?);
        self.t = state.step()?;
        Ok(())
    }
}

/// Adan: Nesterov-style Adam with gradient-difference momentum.
/// Elementwise (the g − g_prev difference is per-coordinate).
pub struct Adan {
    hp: Hyper,
    /// β3 for the gradient-difference EMA.
    beta3: f32,
    arena: Arc<Arena>,
    m: Vec<f32>,
    d: Vec<f32>,
    v: Vec<f32>,
    prev_g: Vec<f32>,
    t: u64,
}

impl Adan {
    pub fn new(hp: Hyper, params: &[Tensor]) -> Adan {
        let arena = Arc::new(Arena::of(params));
        let n = arena.total;
        Adan {
            hp,
            beta3: 0.99,
            arena,
            m: vec![0.0; n],
            d: vec![0.0; n],
            v: vec![0.0; n],
            prev_g: vec![0.0; n],
            t: 0,
        }
    }
}

impl Optimizer for Adan {
    fn name(&self) -> String {
        "adan".into()
    }

    fn arena(&self) -> &Arc<Arena> {
        &self.arena
    }

    fn granularity(&self) -> Granularity {
        Granularity::Element
    }

    fn begin_step(&mut self) {
        self.t += 1;
    }

    fn step_segment(&mut self, params: ParamView<'_>, grads: GradView<'_>,
                    lr: f32) {
        debug_assert!(self.t > 0, "step_segment before begin_step");
        assert_eq!(params.range(), (grads.lo(), grads.hi()));
        let (lo, hi) = params.range();
        let Hyper { beta1, beta2, eps, weight_decay } = self.hp;
        let b3 = self.beta3;
        let m = &mut self.m[lo..hi];
        let d = &mut self.d[lo..hi];
        let v = &mut self.v[lo..hi];
        let pg = &mut self.prev_g[lo..hi];
        for j in 0..params.data.len() {
            let gj = grads.data[j];
            let diff = if self.t == 1 { 0.0 } else { gj - pg[j] };
            m[j] = beta1 * m[j] + (1.0 - beta1) * gj;
            d[j] = b3 * d[j] + (1.0 - b3) * diff;
            let nest = gj + b3 * diff;
            v[j] = beta2 * v[j] + (1.0 - beta2) * nest * nest;
            let denom = v[j].sqrt() + eps;
            let upd = (m[j] + b3 * d[j]) / denom;
            params.data[j] =
                (params.data[j] - lr * upd) / (1.0 + lr * weight_decay);
            pg[j] = gj;
        }
    }

    fn state_bytes(&self) -> usize {
        (self.m.len() + self.d.len() + self.v.len() + self.prev_g.len())
            * 4
    }

    /// Entries: `m`, `d`, `v`, `prev_g` (arena-flat), `__step`.
    fn state_dict(&self) -> StateDict {
        let mut sd = StateDict::new();
        sd.insert("m", &[self.m.len()], self.m.clone());
        sd.insert("d", &[self.d.len()], self.d.clone());
        sd.insert("v", &[self.v.len()], self.v.clone());
        sd.insert("prev_g", &[self.prev_g.len()], self.prev_g.clone());
        sd.set_step(self.t);
        sd
    }

    fn state_len(&self) -> usize {
        5
    }

    fn load_state_dict(&mut self, state: &StateDict) -> Result<()> {
        check_state_len(state, 5, "adan")?;
        self.m.copy_from_slice(state.data("m", self.m.len())?);
        self.d.copy_from_slice(state.data("d", self.d.len())?);
        self.v.copy_from_slice(state.data("v", self.v.len())?);
        self.prev_g
            .copy_from_slice(state.data("prev_g", self.prev_g.len())?);
        self.t = state.step()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn descends(opt: &mut dyn Optimizer, lr: f32) -> (f64, f64) {
        let mut rng = Rng::new(21);
        let mut params = vec![Tensor::randn("w", &[10, 10], 1.0,
                                            &mut rng)];
        let start = params[0].sq_norm();
        for _ in 0..300 {
            let g = Tensor::new("w", &[10, 10], params[0].data.clone());
            opt.step(&mut params, &[g], lr);
        }
        (start, params[0].sq_norm())
    }

    #[test]
    fn all_extras_descend_on_quadratic() {
        let mut rng = Rng::new(21);
        let proto = vec![Tensor::randn("w", &[10, 10], 1.0, &mut rng)];
        let hp = Hyper { weight_decay: 0.0, ..Hyper::default() };
        let mut opts: Vec<Box<dyn Optimizer>> = vec![
            Box::new(AdaGrad::new(&proto, 0.9, 1e-8)),
            Box::new(NovoGrad::new(hp, &proto)),
            Box::new(Adan::new(hp, &proto)),
        ];
        for opt in opts.iter_mut() {
            let (s, e) = descends(opt.as_mut(), 1e-2);
            assert!(e < 0.5 * s, "{}: {s} -> {e}", opt.name());
        }
    }

    #[test]
    fn novograd_state_is_one_scalar_per_tensor_plus_m() {
        let params = vec![Tensor::zeros("a", &[50, 50]),
                          Tensor::zeros("b", &[10])];
        let opt = NovoGrad::new(Hyper::default(), &params);
        assert_eq!(opt.state_bytes(), (2500 + 10 + 2) * 4);
    }

    #[test]
    fn adagrad_monotone_accumulator() {
        let mut opt = AdaGrad::new(&[Tensor::zeros("w", &[3])], 0.0, 0.0);
        let mut params = vec![Tensor::zeros("w", &[3])];
        let g = Tensor::new("w", &[3], vec![1.0, 2.0, 0.0]);
        opt.step(&mut params, std::slice::from_ref(&g), 0.1);
        opt.step(&mut params, std::slice::from_ref(&g), 0.1);
        assert!((opt.acc[0] - 2.0).abs() < 1e-6);
        assert!((opt.acc[1] - 8.0).abs() < 1e-6);
        assert_eq!(opt.acc[2], 0.0);
    }

    #[test]
    fn extras_state_roundtrips() {
        let mut rng = Rng::new(2);
        let p0 = vec![Tensor::randn("w", &[4, 4], 1.0, &mut rng)];
        let gs: Vec<Tensor> =
            (0..4).map(|_| Tensor::randn("w", &[4, 4], 1.0, &mut rng))
                  .collect();
        let hp = Hyper { weight_decay: 0.0, ..Hyper::default() };
        let builders: Vec<Box<dyn Fn() -> Box<dyn Optimizer>>> = vec![
            Box::new(move || Box::new(AdaGrad::new(
                &[Tensor::zeros("w", &[4, 4])], 0.9, 1e-8))),
            Box::new(move || Box::new(NovoGrad::new(
                hp, &[Tensor::zeros("w", &[4, 4])]))),
            Box::new(move || Box::new(Adan::new(
                hp, &[Tensor::zeros("w", &[4, 4])]))),
        ];
        for make in &builders {
            let mut pa = p0.clone();
            let mut a = make();
            for g in &gs[..2] {
                a.step(&mut pa, std::slice::from_ref(g), 1e-2);
            }
            let sd = a.state_dict();
            assert_eq!(sd.len(), a.state_len(), "{}", a.name());
            let mut pb = pa.clone();
            let mut b = make();
            b.load_state_dict(&sd).unwrap();
            for g in &gs[2..] {
                a.step(&mut pa, std::slice::from_ref(g), 1e-2);
                b.step(&mut pb, std::slice::from_ref(g), 1e-2);
            }
            assert_eq!(pa, pb, "{}", a.name());
        }
    }
}
