//! AdamW (paper Algorithm 6) — the baseline everything is compared to.

use std::sync::Arc;

use anyhow::Result;

use super::core::{check_state_len, Arena, GradView, Granularity,
                  Optimizer, ParamView, StateDict};
use super::kernels::{self, AdamCoef, Dispatch};
use super::Hyper;
use crate::tensor::Tensor;

/// Decoupled-weight-decay Adam. State: full-size m and v, flat over
/// the arena. The update sweep runs through the fused kernel layer
/// (`optim::kernels::adamw_step`); the dispatch is resolved from the
/// thread-local simd policy once here, at construction.
pub struct AdamW {
    hp: Hyper,
    arena: Arc<Arena>,
    dispatch: Dispatch,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl AdamW {
    pub fn new(hp: Hyper, params: &[Tensor]) -> AdamW {
        let arena = Arc::new(Arena::of(params));
        let n = arena.total;
        let dispatch = Dispatch::for_arena(n);
        AdamW { hp, arena, dispatch, m: vec![0.0; n], v: vec![0.0; n],
                t: 0 }
    }

    fn step_impl(&mut self, params: ParamView<'_>, grads: GradView<'_>,
                 lr: f32, gscale: f32) {
        debug_assert!(self.t > 0, "step_segment before begin_step");
        assert_eq!(params.range(), (grads.lo(), grads.hi()));
        let (lo, hi) = params.range();
        let Hyper { beta1, beta2, eps, weight_decay } = self.hp;
        let k = AdamCoef {
            beta1,
            beta2,
            eps,
            bc1: 1.0 / (1.0 - beta1.powi(self.t as i32)),
            bc2: 1.0 / (1.0 - beta2.powi(self.t as i32)),
            wd: 1.0 - lr * weight_decay,
            lr,
            gscale,
        };
        kernels::adamw_step(self.dispatch, params.data, grads.data,
                            &mut self.m[lo..hi], &mut self.v[lo..hi],
                            &k);
    }

    /// Access v in arena-flat form (used by the leave-one-out
    /// experiment to seed blockwise learning rates from Adam's own
    /// statistics).
    pub fn v(&self) -> &[f32] {
        &self.v
    }
}

impl Optimizer for AdamW {
    fn name(&self) -> String {
        "adamw".into()
    }

    fn arena(&self) -> &Arc<Arena> {
        &self.arena
    }

    fn granularity(&self) -> Granularity {
        Granularity::Element
    }

    fn begin_step(&mut self) {
        self.t += 1;
    }

    fn step_segment(&mut self, params: ParamView<'_>, grads: GradView<'_>,
                    lr: f32) {
        self.step_impl(params, grads, lr, 1.0);
    }

    fn step_segment_scaled(&mut self, params: ParamView<'_>,
                           grads: GradView<'_>, lr: f32, gscale: f32) {
        self.step_impl(params, grads, lr, gscale);
    }

    fn state_bytes(&self) -> usize {
        (self.m.len() + self.v.len()) * 4
    }

    /// Entries: `m`, `v` (arena-flat), `__step`.
    fn state_dict(&self) -> StateDict {
        let mut sd = StateDict::new();
        sd.insert("m", &[self.m.len()], self.m.clone());
        sd.insert("v", &[self.v.len()], self.v.clone());
        sd.set_step(self.t);
        sd
    }

    fn state_len(&self) -> usize {
        3
    }

    fn load_state_dict(&mut self, state: &StateDict) -> Result<()> {
        check_state_len(state, 3, "adamw")?;
        self.m.copy_from_slice(state.data("m", self.m.len())?);
        self.v.copy_from_slice(state.data("v", self.v.len())?);
        self.t = state.step()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    /// Scalar hand-computed AdamW step.
    #[test]
    fn first_step_matches_hand_calc() {
        let hp = Hyper { beta1: 0.9, beta2: 0.95, eps: 0.0,
                         weight_decay: 0.0 };
        let mut params = vec![Tensor::new("w", &[1], vec![1.0])];
        let grads = vec![Tensor::new("w", &[1], vec![0.5])];
        let mut opt = AdamW::new(hp, &params);
        opt.step(&mut params, &grads, 0.1);
        // m̂ = g, v̂ = g² after bias correction → update = lr * sign-ish.
        // w = 1 - 0.1 * 0.5/|0.5| = 0.9
        assert!((params[0].data[0] - 0.9).abs() < 1e-6);
    }

    #[test]
    fn weight_decay_is_decoupled() {
        let hp = Hyper { weight_decay: 0.5, ..Hyper::default() };
        let mut params = vec![Tensor::new("w", &[1], vec![2.0])];
        let grads = vec![Tensor::new("w", &[1], vec![0.0])];
        let mut opt = AdamW::new(hp, &params);
        opt.step(&mut params, &grads, 0.1);
        // zero grad → only decay: w *= (1 - 0.1*0.5) = 0.95 → 1.9
        assert!((params[0].data[0] - 1.9).abs() < 1e-6);
    }

    #[test]
    fn update_is_scale_invariant_property() {
        // Adam's direction is invariant to gradient scaling (with eps→0).
        use crate::util::prop::{check, prop_close};
        check(32, |rng: &mut Rng| {
            let n = 1 + rng.below(20);
            let hp = Hyper { eps: 1e-30, weight_decay: 0.0,
                             ..Hyper::default() };
            let p0 = Tensor::randn("w", &[n], 1.0, rng);
            let g = Tensor::randn("w", &[n], 1.0, rng);
            let scale = 10f32.powi(rng.below(5) as i32 - 2);

            let mut pa = vec![p0.clone()];
            let mut oa = AdamW::new(hp, &pa);
            oa.step(&mut pa, &[g.clone()], 1e-2);

            let gs = Tensor::new("w", &[n],
                                 g.data.iter().map(|x| x * scale).collect());
            let mut pb = vec![p0.clone()];
            let mut ob = AdamW::new(hp, &pb);
            ob.step(&mut pb, &[gs], 1e-2);

            for i in 0..n {
                prop_close(pa[0].data[i] as f64, pb[0].data[i] as f64,
                           1e-5, 1e-4, "scale invariance")?;
            }
            Ok(())
        });
    }

    #[test]
    fn state_roundtrip_resumes_identically() {
        let mut rng = Rng::new(9);
        let p0 = vec![Tensor::randn("w", &[6, 3], 1.0, &mut rng)];
        let gs: Vec<Tensor> =
            (0..6).map(|_| Tensor::randn("w", &[6, 3], 1.0, &mut rng))
                  .collect();
        let mut pa = p0.clone();
        let mut a = AdamW::new(Hyper::default(), &pa);
        for g in &gs[..3] {
            a.step(&mut pa, std::slice::from_ref(g), 1e-2);
        }
        // Export, import into a fresh instance, continue both.
        let state = a.state_dict();
        assert_eq!(state.len(), 3);
        assert_eq!(state.len(), a.state_len());
        assert_eq!(state.step().unwrap(), 3);
        let mut pb = pa.clone();
        let mut b = AdamW::new(Hyper::default(), &pb);
        b.load_state_dict(&state).unwrap();
        for g in &gs[3..] {
            a.step(&mut pa, std::slice::from_ref(g), 1e-2);
            b.step(&mut pb, std::slice::from_ref(g), 1e-2);
        }
        assert_eq!(pa, pb);
        // Wrong arity is an error, not a silent drop.
        let mut short = StateDict::new();
        short.insert_tensor(state.entries()[0].clone());
        assert!(b.load_state_dict(&short).is_err());
    }

    #[test]
    fn state_bytes_counts_m_and_v() {
        let params = vec![Tensor::zeros("w", &[10, 10])];
        let opt = AdamW::new(Hyper::default(), &params);
        assert_eq!(opt.state_bytes(), 2 * 100 * 4);
    }

    #[test]
    fn segment_partition_matches_whole_step() {
        // Elementwise update: ANY segment partition is bit-identical
        // to the whole-model step.
        let mut rng = Rng::new(4);
        let params = vec![Tensor::randn("w", &[5, 4], 1.0, &mut rng)];
        let g = Tensor::randn("w", &[5, 4], 1.0, &mut rng);
        let mut pa = params.clone();
        let mut a = AdamW::new(Hyper::default(), &pa);
        a.step(&mut pa, std::slice::from_ref(&g), 1e-2);

        let mut b = AdamW::new(Hyper::default(), &params);
        let arena = Arc::clone(b.arena());
        let mut flat = arena.flatten(&params);
        let gflat = arena.flatten(std::slice::from_ref(&g));
        b.begin_step();
        for (lo, hi) in [(7usize, 20usize), (0, 3), (3, 7)] {
            b.step_segment(ParamView::new(lo, &mut flat[lo..hi]),
                           GradView::new(lo, &gflat[lo..hi]), 1e-2);
        }
        let mut pb = params.clone();
        arena.unflatten(&flat, &mut pb);
        assert_eq!(pa, pb);
    }
}
