//! AdamW (paper Algorithm 6) — the baseline everything is compared to.

use anyhow::{bail, Result};

use super::{decode_step, step_tensor, Hyper, Optimizer};
use crate::tensor::Tensor;

/// Decoupled-weight-decay Adam. State: full-size m and v per tensor.
pub struct AdamW {
    hp: Hyper,
    m: Vec<Tensor>,
    v: Vec<Tensor>,
    t: u64,
}

impl AdamW {
    pub fn new(hp: Hyper, params: &[Tensor]) -> AdamW {
        AdamW {
            hp,
            m: params.iter().map(|p| Tensor::zeros(&*p.name, &p.shape))
                .collect(),
            v: params.iter().map(|p| Tensor::zeros(&*p.name, &p.shape))
                .collect(),
            t: 0,
        }
    }

    /// Access v (used by the leave-one-out experiment to seed blockwise
    /// learning rates from Adam's own statistics).
    pub fn v(&self) -> &[Tensor] {
        &self.v
    }
}

impl Optimizer for AdamW {
    fn name(&self) -> String {
        "adamw".into()
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        self.t += 1;
        let Hyper { beta1, beta2, eps, weight_decay } = self.hp;
        let bc1 = 1.0 / (1.0 - beta1.powi(self.t as i32));
        let bc2 = 1.0 / (1.0 - beta2.powi(self.t as i32));
        for ((p, g), (m, v)) in params
            .iter_mut()
            .zip(grads)
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            debug_assert_eq!(p.shape, g.shape);
            let wd = 1.0 - lr * weight_decay;
            for i in 0..p.data.len() {
                let gi = g.data[i];
                let mi = beta1 * m.data[i] + (1.0 - beta1) * gi;
                let vi = beta2 * v.data[i] + (1.0 - beta2) * gi * gi;
                m.data[i] = mi;
                v.data[i] = vi;
                p.data[i] = p.data[i] * wd
                    - lr * (mi * bc1) / ((vi * bc2).sqrt() + eps);
            }
        }
    }

    fn state_bytes(&self) -> usize {
        (self.m.iter().map(Tensor::numel).sum::<usize>()
            + self.v.iter().map(Tensor::numel).sum::<usize>())
            * 4
    }

    /// State layout: m tensors, then v tensors, then `__step`.
    fn state_export(&self) -> Vec<Tensor> {
        let mut out = self.m.clone();
        out.extend(self.v.iter().cloned());
        out.push(step_tensor(self.t));
        out
    }

    fn state_len(&self) -> usize {
        2 * self.m.len() + 1
    }

    fn state_import(&mut self, state: &[Tensor]) -> Result<()> {
        let n = self.m.len();
        if state.len() != 2 * n + 1 {
            bail!("adamw: expected {} state tensors, got {}", 2 * n + 1,
                  state.len());
        }
        self.t = decode_step(state)?;
        for (dst, src) in self
            .m
            .iter_mut()
            .chain(self.v.iter_mut())
            .zip(&state[..2 * n])
        {
            src.assert_shape(&dst.shape)?;
            dst.data.copy_from_slice(&src.data);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    /// Scalar hand-computed AdamW step.
    #[test]
    fn first_step_matches_hand_calc() {
        let hp = Hyper { beta1: 0.9, beta2: 0.95, eps: 0.0,
                         weight_decay: 0.0 };
        let mut params = vec![Tensor::new("w", &[1], vec![1.0])];
        let grads = vec![Tensor::new("w", &[1], vec![0.5])];
        let mut opt = AdamW::new(hp, &params);
        opt.step(&mut params, &grads, 0.1);
        // m̂ = g, v̂ = g² after bias correction → update = lr * sign-ish.
        // w = 1 - 0.1 * 0.5/|0.5| = 0.9
        assert!((params[0].data[0] - 0.9).abs() < 1e-6);
    }

    #[test]
    fn weight_decay_is_decoupled() {
        let hp = Hyper { weight_decay: 0.5, ..Hyper::default() };
        let mut params = vec![Tensor::new("w", &[1], vec![2.0])];
        let grads = vec![Tensor::new("w", &[1], vec![0.0])];
        let mut opt = AdamW::new(hp, &params);
        opt.step(&mut params, &grads, 0.1);
        // zero grad → only decay: w *= (1 - 0.1*0.5) = 0.95 → 1.9
        assert!((params[0].data[0] - 1.9).abs() < 1e-6);
    }

    #[test]
    fn update_is_scale_invariant_property() {
        // Adam's direction is invariant to gradient scaling (with eps→0).
        use crate::util::prop::{check, prop_close};
        check(32, |rng: &mut Rng| {
            let n = 1 + rng.below(20);
            let hp = Hyper { eps: 1e-30, weight_decay: 0.0,
                             ..Hyper::default() };
            let p0 = Tensor::randn("w", &[n], 1.0, rng);
            let g = Tensor::randn("w", &[n], 1.0, rng);
            let scale = 10f32.powi(rng.below(5) as i32 - 2);

            let mut pa = vec![p0.clone()];
            let mut oa = AdamW::new(hp, &pa);
            oa.step(&mut pa, &[g.clone()], 1e-2);

            let gs = Tensor::new("w", &[n],
                                 g.data.iter().map(|x| x * scale).collect());
            let mut pb = vec![p0.clone()];
            let mut ob = AdamW::new(hp, &pb);
            ob.step(&mut pb, &[gs], 1e-2);

            for i in 0..n {
                prop_close(pa[0].data[i] as f64, pb[0].data[i] as f64,
                           1e-5, 1e-4, "scale invariance")?;
            }
            Ok(())
        });
    }

    #[test]
    fn state_roundtrip_resumes_identically() {
        let mut rng = Rng::new(9);
        let p0 = vec![Tensor::randn("w", &[6, 3], 1.0, &mut rng)];
        let gs: Vec<Tensor> =
            (0..6).map(|_| Tensor::randn("w", &[6, 3], 1.0, &mut rng))
                  .collect();
        let mut pa = p0.clone();
        let mut a = AdamW::new(Hyper::default(), &pa);
        for g in &gs[..3] {
            a.step(&mut pa, std::slice::from_ref(g), 1e-2);
        }
        // Export, import into a fresh instance, continue both.
        let state = a.state_export();
        assert_eq!(state.len(), 3);
        let mut pb = pa.clone();
        let mut b = AdamW::new(Hyper::default(), &pb);
        b.state_import(&state).unwrap();
        for g in &gs[3..] {
            a.step(&mut pa, std::slice::from_ref(g), 1e-2);
            b.step(&mut pb, std::slice::from_ref(g), 1e-2);
        }
        assert_eq!(pa, pb);
        // Wrong arity is an error, not a silent drop.
        assert!(b.state_import(&state[..1]).is_err());
    }

    #[test]
    fn state_bytes_counts_m_and_v() {
        let params = vec![Tensor::zeros("w", &[10, 10])];
        let opt = AdamW::new(Hyper::default(), &params);
        assert_eq!(opt.state_bytes(), 2 * 100 * 4);
    }
}
