//! Fig 12 + Table 5: SFT, RLHF (ReMax) and the sensitivity grid.

use anyhow::Result;

use super::pretrain::run_one;
use super::quad::verdict;
use super::RESULTS_DIR;
use crate::config::TrainConfig;
use crate::coordinator::Trainer;
use crate::eval::{mt_proxy_score, perplexity};
use crate::optim;
use crate::rlhf::{remax_train, sft_train, RemaxConfig, SftConfig};
use crate::runtime::{Engine, ModelRuntime};
use crate::tensor::Tensor;
use crate::util::csv::{ascii_table, Csv};

/// Shared: pre-train a base model briefly (the "pretrained checkpoint"
/// every alignment stage starts from).
fn pretrain_base(engine: &Engine, model: &str, steps: usize)
    -> Result<Vec<Tensor>> {
    let cfg = TrainConfig {
        model: model.into(),
        optimizer: "adamw".into(),
        steps,
        peak_lr: 6e-3,
        schedule: "linear".into(),
        seed: 9,
        eval_every: 0,
        log_every: steps,
        ..Default::default()
    };
    let mut tr = Trainer::from_config(engine, &cfg)?;
    tr.train(true)?;
    Ok(tr.params)
}

/// Fig 12a: SFT — AdamW vs Adam-mini from the same base checkpoint.
pub fn sft(engine: &Engine, quick: bool) -> Result<()> {
    let model = "t48k";
    let base_steps = if quick { 40 } else { 200 };
    let sft_steps = if quick { 30 } else { 120 };
    println!("Fig 12a: SFT on {model} (base {base_steps} steps, SFT \
              {sft_steps} steps, prompt-masked loss)");
    let base = pretrain_base(engine, model, base_steps)?;
    let rt = ModelRuntime::new(engine, model)?;
    let hp = engine.manifest.hyper();
    let meta = rt.mm.meta();
    let cfg = SftConfig { steps: sft_steps, ..Default::default() };

    let mut rows = Vec::new();
    let mut csv = Csv::create(format!("{RESULTS_DIR}/fig12a_sft.csv"),
                              &["optimizer", "step", "loss"])?;
    let mut finals = Vec::new();
    for name in ["adamw", "adam_mini"] {
        let mut params = base.clone();
        let mut opt = optim::by_name(name, hp, &params, &meta)?;
        let losses = sft_train(engine, &rt, &mut params, opt.as_mut(),
                               &cfg)?;
        for (i, l) in losses.iter().enumerate() {
            csv.row_str(&[name.into(), (i + 1).to_string(),
                          format!("{l:.5}")])?;
        }
        let tail = losses[losses.len().saturating_sub(5)..]
            .iter()
            .sum::<f32>()
            / 5.0_f32.min(losses.len() as f32);
        finals.push(tail);
        rows.push(vec![name.into(), format!("{:.4}", losses[0]),
                       format!("{tail:.4}"),
                       format!("{:.3}", perplexity(tail as f64))]);
    }
    csv.flush()?;
    println!("{}", ascii_table(
        &["optimizer", "first loss", "final loss", "final ppl"], &rows));
    println!("{}", verdict(finals[1] <= finals[0] + 0.03,
        "Adam-mini SFT matches/beats AdamW (Fig 12a shape)"));
    println!("results: {RESULTS_DIR}/fig12a_sft.csv");
    Ok(())
}

/// Fig 12b + Table 5: ReMax reward ascent, AdamW vs Adam-mini.
pub fn rlhf(engine: &Engine, quick: bool) -> Result<()> {
    let model = "t48k";
    let base_steps = if quick { 40 } else { 200 };
    let remax_steps = if quick { 8 } else { 40 };
    println!("Fig 12b: ReMax on {model} ({remax_steps} steps)");
    let base = pretrain_base(engine, model, base_steps)?;
    let rt = ModelRuntime::new(engine, model)?;
    let hp = optim::Hyper { weight_decay: 0.0,
                            ..engine.manifest.hyper() };
    let meta = rt.mm.meta();
    let cfg = RemaxConfig { steps: remax_steps, lr: 2e-4,
                            ..Default::default() };

    let mut rows = Vec::new();
    let mut csv = Csv::create(format!("{RESULTS_DIR}/fig12b_rlhf.csv"),
                              &["optimizer", "step", "reward",
                                "baseline"])?;
    let mut table5 = Vec::new();
    for name in ["adamw", "adam_mini"] {
        let mut params = base.clone();
        let mut opt = optim::by_name(name, hp, &params, &meta)?;
        let logs = remax_train(engine, &rt, &mut params, opt.as_mut(),
                               &cfg)?;
        for l in &logs {
            csv.row_str(&[name.into(), l.step.to_string(),
                          format!("{:.4}", l.mean_reward),
                          format!("{:.4}", l.baseline_reward)])?;
        }
        let first = logs.first().map(|l| l.mean_reward).unwrap_or(0.0);
        let last_k = &logs[logs.len().saturating_sub(5)..];
        let fin = last_k.iter().map(|l| l.mean_reward).sum::<f64>()
            / last_k.len() as f64;
        // Table 5 proxy: blend of reward and language quality.
        let base_batch_loss = 3.0; // reference anchor
        let score = mt_proxy_score(perplexity(base_batch_loss), fin,
                                   perplexity(base_batch_loss));
        table5.push((name, fin, score));
        rows.push(vec![name.into(), format!("{first:.3}"),
                       format!("{fin:.3}"), format!("{score:.2}")]);
    }
    csv.flush()?;
    println!("{}", ascii_table(
        &["optimizer", "first reward", "final reward",
          "MT-proxy score (0-10)"], &rows));
    println!("{}", verdict(table5[1].1 >= table5[0].1 - 0.05,
        "Adam-mini reaches equal-or-higher reward (Fig 12b shape)"));
    println!("results: {RESULTS_DIR}/fig12b_rlhf.csv");
    Ok(())
}

/// Fig 22 + Table 5 "SFT (LoRA)": LoRA fine-tuning with the adapter
/// Adam steps replaced by Adam-mini.
pub fn fig22(engine: &Engine, quick: bool) -> Result<()> {
    use crate::data::{Batcher, Corpus, SyntheticSpec};
    use crate::optim::Schedule;
    use crate::rlhf::LoraGrad;

    let model = "t48k";
    let base_steps = if quick { 40 } else { 200 };
    let steps = if quick { 30 } else { 150 };
    println!("Fig 22: SFT with LoRA adapters ({model}, rank 4, \
              {steps} steps)");
    let base = pretrain_base(engine, model, base_steps)?;
    let rt = ModelRuntime::new(engine, model)?;
    let lora = LoraGrad::new(engine, &rt)?;
    // Shifted-domain SFT corpus, shared by both optimizers.
    let corpus = Corpus::synthetic(&SyntheticSpec {
        vocab: rt.mm.vocab,
        n_tokens: (steps + 8) * rt.mm.batch_size * rt.mm.seq_len / 2
            + 4096,
        coherence: 0.92,
        branching: 2,
        seed: 0x10AA,
        ..Default::default()
    });
    let hp = engine.manifest.hyper();
    let schedule = Schedule::WarmupCosine {
        peak: 2e-3, min_lr: 2e-4, warmup: (steps / 20).max(1),
        total: steps,
    };
    let mut rows = Vec::new();
    let mut csv = Csv::create(format!("{RESULTS_DIR}/fig22.csv"),
                              &["optimizer", "step", "loss"])?;
    let mut finals = Vec::new();
    for name in ["adamw", "adam_mini"] {
        let mut adapters = lora.init_adapters(1);
        let meta = crate::optim::ModelMeta {
            n_heads: rt.mm.n_heads,
            stacked: adapters.iter().map(|t| t.name.clone()).collect(),
        };
        let mut opt = optim::by_name(name, hp, &adapters, &meta)?;
        let mut batcher = Batcher::new(corpus.clone(), rt.mm.batch_size,
                                       rt.mm.seq_len, 1);
        let mut first = 0.0;
        let mut tail = Vec::new();
        for t in 1..=steps {
            let b = batcher.next_batch();
            let (loss, grads) =
                lora.grad(&base, &adapters, &b.tokens, &b.targets)?;
            opt.step(&mut adapters, &grads, schedule.lr(t));
            if t == 1 {
                first = loss;
            }
            if t + 5 > steps {
                tail.push(loss);
            }
            csv.row_str(&[name.into(), t.to_string(),
                          format!("{loss:.5}")])?;
        }
        let fin = tail.iter().sum::<f32>() / tail.len() as f32;
        finals.push(fin);
        rows.push(vec![name.into(), format!("{first:.4}"),
                       format!("{fin:.4}")]);
    }
    csv.flush()?;
    println!("{}", ascii_table(
        &["optimizer (LoRA steps)", "first loss", "final loss"], &rows));
    println!("{}", verdict(finals[1] <= finals[0] + 0.03,
        "LoRA improves when Adam steps are replaced by Adam-mini"));
    println!("results: {RESULTS_DIR}/fig22.csv");
    Ok(())
}

/// Fig 12c: sensitivity of Adam-mini to (lr, beta2) around the default.
pub fn sensitivity(engine: &Engine, quick: bool) -> Result<()> {
    let steps = if quick { 40 } else { 150 };
    let lrs: &[f32] = if quick { &[3e-3, 6e-3] }
                      else { &[1e-3, 3e-3, 6e-3, 1e-2, 2e-2] };
    println!("Fig 12c: Adam-mini lr sensitivity (t48k, {steps} steps)");
    let mut csv = Csv::create(format!("{RESULTS_DIR}/fig12c.csv"),
                              &["lr", "val_loss"])?;
    let mut losses = Vec::new();
    let mut rows = Vec::new();
    for &lr in lrs {
        let h = run_one(engine, "t48k", "adam_mini", steps, lr, 0,
                        "cosine")?;
        let v = h.final_val_loss();
        csv.row(&[lr as f64, v as f64])?;
        losses.push(v as f64);
        rows.push(vec![format!("{lr:.0e}"), format!("{v:.4}")]);
    }
    csv.flush()?;
    println!("{}", ascii_table(&["peak lr", "val loss"], &rows));
    let spread = losses.iter().cloned().fold(f64::MIN, f64::max)
        - losses.iter().cloned().fold(f64::MAX, f64::min);
    println!("loss spread across the grid: {spread:.4}");
    println!("{}", verdict(losses.iter().all(|l| l.is_finite()),
        "no divergence across the hyperparameter grid"));
    println!("results: {RESULTS_DIR}/fig12c.csv");
    Ok(())
}
