//! Fig 4 + Fig 5 regenerators (random-quadratic case studies).

use anyhow::Result;

use super::RESULTS_DIR;
use crate::linalg::{cond_sym, Mat};
use crate::quadratic::fig4::{adam_quadratic_tuned, blockwise_gd_quadratic,
                             gd_quadratic, make_fig4_hessian};
use crate::quadratic::precond::precond_sweep;
use crate::util::csv::{ascii_table, Csv};
use crate::util::prng::Rng;

/// Fig 4: full-Hessian race (a, b) + single-dense-block race (c, d).
pub fn fig4(quick: bool) -> Result<()> {
    let steps = if quick { 120 } else { 1000 };
    let mut rng = Rng::new(0xF16_4);
    let (h, ranges) = make_fig4_hessian(&mut rng);
    let w0: Vec<f64> = (0..h.rows).map(|_| rng.normal()).collect();

    println!("Fig 4(b): three-block quadratic, kappa(H) = {:.1}",
             cond_sym(&h));
    let curves = vec![
        gd_quadratic(&h, &w0, steps),
        adam_quadratic_tuned(&h, &w0, steps),
        blockwise_gd_quadratic(&h, &ranges, &w0, steps),
    ];
    let mut csv = Csv::create(format!("{RESULTS_DIR}/fig4b.csv"),
                              &["step", "gd_optimal", "adam",
                                "blockwise_gd"])?;
    for t in 0..=steps {
        csv.row(&[t as f64, curves[0].losses[t], curves[1].losses[t],
                  curves[2].losses[t]])?;
    }
    csv.flush()?;
    let mut rows = Vec::new();
    for c in &curves {
        rows.push(vec![c.method.clone(),
                       format!("{:.3e}", c.losses[steps / 10]),
                       format!("{:.3e}", c.losses[steps])]);
    }
    println!("{}", ascii_table(
        &["method", &format!("loss@{}", steps / 10),
          &format!("loss@{steps}")], &rows));

    // (c, d): single dense middle block.
    let hb = Mat::from_fn(30, 30, |i, j| h.get(30 + i, 30 + j));
    let wb: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
    let gd_b = gd_quadratic(&hb, &wb, steps);
    let adam_b = adam_quadratic_tuned(&hb, &wb, steps);
    let mut csv = Csv::create(format!("{RESULTS_DIR}/fig4d.csv"),
                              &["step", "gd_optimal", "adam"])?;
    for t in 0..=steps {
        csv.row(&[t as f64, gd_b.losses[t], adam_b.losses[t]])?;
    }
    csv.flush()?;
    println!("Fig 4(d): single dense block — GD(optimal) {:.3e} vs \
              Adam {:.3e} at step {steps}  {}",
             gd_b.losses[steps], adam_b.losses[steps],
             verdict(gd_b.losses[steps] < adam_b.losses[steps],
                     "single good lr beats Adam on the dense block"));
    println!("results: {RESULTS_DIR}/fig4b.csv, {RESULTS_DIR}/fig4d.csv");
    Ok(())
}

/// Fig 5: preconditioner effectiveness sweep over (d, kappa, tau).
pub fn fig5(quick: bool) -> Result<()> {
    let (n_theta, n_init) = if quick { (4, 8) } else { (20, 40) };
    let scales = [0.0, 0.1, 0.2, 0.4, 0.6, 0.8, 1.0];
    let mut rng = Rng::new(0xF16_5);
    let mut csv = Csv::create(format!("{RESULTS_DIR}/fig5.csv"),
                              &["d", "kappa", "scale_r", "tau", "ratio"])?;

    println!("Fig 5(a): r vs tau at kappa = 500, varying d");
    let dims: &[usize] = if quick { &[10, 30] } else { &[10, 30, 50, 100] };
    let mut rows = Vec::new();
    for &d in dims {
        let pts = precond_sweep(d, 500.0, &scales, n_theta, n_init,
                                &mut rng);
        for p in &pts {
            csv.row(&[p.d as f64, p.kappa, p.scale_r, p.tau, p.ratio])?;
        }
        let diag = pts.iter().find(|p| p.scale_r == 0.0).unwrap();
        let dense = pts.iter().find(|p| p.scale_r == 1.0).unwrap();
        rows.push(vec![format!("d={d}"),
                       format!("{:.3}", diag.tau),
                       format!("{:.2}", diag.ratio),
                       format!("{:.3}", dense.tau),
                       format!("{:.2}", dense.ratio)]);
    }
    println!("{}", ascii_table(
        &["dim", "tau(diag)", "r(diag)", "tau(dense)", "r(dense)"],
        &rows));

    println!("Fig 5(b): r vs tau at d = 50, varying kappa");
    let kappas: &[f64] = if quick { &[10.0, 1000.0] }
                         else { &[10.0, 100.0, 1000.0, 10000.0] };
    let d = if quick { 20 } else { 50 };
    let mut rows = Vec::new();
    for &k in kappas {
        let pts = precond_sweep(d, k, &scales, n_theta, n_init, &mut rng);
        for p in &pts {
            csv.row(&[p.d as f64, p.kappa, p.scale_r, p.tau, p.ratio])?;
        }
        let diag = pts.iter().find(|p| p.scale_r == 0.0).unwrap();
        let dense = pts.iter().find(|p| p.scale_r == 1.0).unwrap();
        rows.push(vec![format!("kappa={k}"),
                       format!("{:.2}", diag.ratio),
                       format!("{:.2}", dense.ratio),
                       verdict(dense.ratio > diag.ratio,
                               "r grows as H densifies").into()]);
    }
    csv.flush()?;
    println!("{}", ascii_table(
        &["kappa", "r(diag)", "r(dense)", "paper shape"], &rows));
    println!("results: {RESULTS_DIR}/fig5.csv");
    Ok(())
}

pub(crate) fn verdict(ok: bool, what: &str) -> String {
    if ok {
        format!("[OK: {what}]")
    } else {
        format!("[MISMATCH: expected {what}]")
    }
}
