//! Fig 3, Fig 7 and Table 3: Hessian-structure experiments.

use anyhow::Result;

use super::quad::verdict;
use super::RESULTS_DIR;
use crate::data::{Batcher, Corpus, SyntheticSpec};
use crate::hessian::mlp::{GaussianMixture, Mlp};
use crate::hessian::transformer::{block_hessian, kappa_report, BlockSel};
use crate::linalg::block_energy_ratio;
use crate::optim::{AdamW, Hyper, Optimizer};
use crate::runtime::{Engine, ModelRuntime};
use crate::util::csv::{ascii_table, Csv};

/// Fig 3: MLP Hessian block-diagonal energy at 0 / 1 / 50% / 100% of
/// training (paper: structure appears after 1 step and persists).
pub fn fig3(quick: bool) -> Result<()> {
    let (d, hidden, classes, n) =
        if quick { (8, 4, 4, 120) } else { (16, 8, 8, 320) };
    let total_steps = if quick { 60 } else { 400 };
    let data = GaussianMixture::generate(n, d, classes, 0.5, 0);
    let mut mlp = Mlp::init(d, hidden, classes, 0);
    let blocks = mlp.neuron_blocks();
    let hp = Hyper { weight_decay: 0.0, ..Default::default() };
    let params = vec![mlp.w.clone(), mlp.v.clone()];
    let mut opt = AdamW::new(hp, &params);

    let mut csv = Csv::create(format!("{RESULTS_DIR}/fig3.csv"),
                              &["step", "block_energy_ratio", "loss"])?;
    let mut rows = Vec::new();
    let checkpoints = [0, 1, total_steps / 2, total_steps];
    let mut done = 0usize;
    for &ck in &checkpoints {
        let todo = ck - done;
        if todo > 0 {
            mlp.train(&data, &mut opt, 1e-3, todo);
            done = ck;
        }
        let h = mlp.hessian_w(&data, 1e-2);
        let ratio = block_energy_ratio(&h, &blocks);
        let loss = mlp.loss(&data);
        csv.row(&[ck as f64, ratio, loss])?;
        rows.push(vec![format!("step {ck}"), format!("{ratio:.4}"),
                       format!("{loss:.4}")]);
    }
    csv.flush()?;
    println!("Fig 3: fraction of |H_W|^2 inside per-neuron diagonal \
              blocks ({} blocks of {} params)", hidden, d);
    println!("{}", ascii_table(
        &["checkpoint", "block energy", "train loss"], &rows));
    // Paper claim: near-block-diagonal from step 1 onward. Random-chance
    // level is 1/hidden.
    let chance = 1.0 / hidden as f64;
    println!("chance level (dense H): {chance:.3}");
    println!("results: {RESULTS_DIR}/fig3.csv");
    Ok(())
}

fn h1t_setup<'e>(engine: &'e Engine)
    -> Result<(ModelRuntime<'e>, Vec<crate::tensor::Tensor>,
               Vec<crate::data::Batch>)> {
    let rt = ModelRuntime::new(engine, "h1t")?;
    let mut params = rt.init_params(7);
    // Take one short Adam phase so the Hessian is evaluated slightly
    // off-init ("1% training step" in the paper's Fig 7).
    let corpus = Corpus::synthetic(&SyntheticSpec {
        vocab: rt.mm.vocab,
        n_tokens: 1 << 14,
        seed: 7,
        ..Default::default()
    });
    let mut batcher = Batcher::new(corpus, rt.mm.batch_size,
                                   rt.mm.seq_len, 7);
    let hp = engine.manifest.hyper();
    let mut opt = AdamW::new(hp, &params);
    for _ in 0..3 {
        let b = batcher.next_batch();
        let (_, grads) = rt.grad(&params, &b)?;
        opt.step(&mut params, &grads, 1e-3);
    }
    let batches: Vec<_> = (0..8).map(|_| batcher.next_batch()).collect();
    Ok((rt, params, batches))
}

/// Fig 7(a–h): Hessian block structure per parameter class of the
/// 1-layer probe transformer.
pub fn fig7(engine: &Engine, quick: bool) -> Result<()> {
    let (rt, params, batches) = h1t_setup(engine)?;
    let batch = &batches[0];
    let names: Vec<String> =
        rt.mm.params.iter().map(|p| p.name.clone()).collect();
    let idx = |n: &str| names.iter().position(|x| x == n).unwrap();
    let d = rt.mm.d_model;
    let heads = rt.mm.n_heads;
    let dh = d / heads;
    let eps = 1e-3;

    // (tensor, label, rows to analyze, block length)
    // wq/wk/wv are (1, d, d): flatten = d rows of d. Head block = dh
    // rows = dh*d elements. attn.proj rows are output neurons (d
    // elements each). MLP w1 rows too. embed rows = token rows.
    let mut specs: Vec<(BlockSel, Vec<(usize, usize)>)> = Vec::new();
    let full = |t: &str| {
        let p = &rt.mm.params[idx(t)];
        p.shape.iter().product::<usize>()
    };
    // Query / Key / Value: full tensor, head blocks.
    for t in ["wq", "wk", "wv"] {
        let n = full(t);
        let blocks: Vec<(usize, usize)> =
            (0..heads).map(|h| (h * dh * d, dh * d)).collect();
        specs.push((BlockSel::new(format!("{t} (by head)"), idx(t), 0, n),
                    blocks));
    }
    // attn.proj + MLP fc1: per-output-neuron blocks. Restrict to the
    // first `k` neurons to bound finite-difference cost.
    let k_neurons = if quick { 4 } else { 8 };
    for t in ["wo", "w1"] {
        let cols = rt.mm.params[idx(t)].shape[2];
        let n = k_neurons * cols;
        let blocks: Vec<(usize, usize)> =
            (0..k_neurons).map(|i| (i * cols, cols)).collect();
        specs.push((BlockSel::new(format!("{t} (by neuron)"), idx(t), 0, n),
                    blocks));
    }
    // Embedding: token-row blocks.
    {
        let n = full("embed");
        let blocks: Vec<(usize, usize)> =
            (0..rt.mm.vocab).map(|v| (v * d, d)).collect();
        specs.push((BlockSel::new("embed (by token)", idx("embed"), 0, n),
                    blocks));
    }

    let mut rows = Vec::new();
    let mut csv = Csv::create(format!("{RESULTS_DIR}/fig7.csv"),
                              &["block", "n_params", "n_subblocks",
                                "block_energy", "chance"])?;
    for (sel, blocks) in &specs {
        let h = block_hessian(&rt, &params, batch, sel, eps)?;
        let ratio = block_energy_ratio(&h, blocks);
        let chance: f64 = blocks
            .iter()
            .map(|&(_, l)| (l * l) as f64)
            .sum::<f64>()
            / ((sel.len * sel.len) as f64);
        csv.row_str(&[sel.label.clone(), sel.len.to_string(),
                      blocks.len().to_string(), format!("{ratio:.4}"),
                      format!("{chance:.4}")])?;
        rows.push(vec![sel.label.clone(), blocks.len().to_string(),
                       format!("{ratio:.3}"), format!("{chance:.3}"),
                       verdict(ratio > 2.0 * chance,
                               "energy concentrates in diagonal blocks")]);
    }
    csv.flush()?;
    println!("Fig 7: Hessian near-block-diagonal structure per class \
              (1-layer transformer, d={d}, heads={heads})");
    println!("{}", ascii_table(
        &["parameter class", "#blocks", "in-block energy", "chance",
          "paper shape"], &rows));
    println!("results: {RESULTS_DIR}/fig7.csv");
    println!("(Fig 7i — default-partition loss spikes — is part of \
              `repro exp fig8`/`fig21`.)");
    Ok(())
}

/// Table 3: kappa(H) vs kappa(D_Adam H) on the dense sub-blocks.
pub fn table3(engine: &Engine, quick: bool) -> Result<()> {
    let (rt, params, batches) = h1t_setup(engine)?;
    let names: Vec<String> =
        rt.mm.params.iter().map(|p| p.name.clone()).collect();
    let idx = |n: &str| names.iter().position(|x| x == n).unwrap();
    let d = rt.mm.d_model;
    let dh = d / rt.mm.n_heads;
    let eps = 1e-3;

    let mut sels = vec![
        BlockSel::new("1st head in Query", idx("wq"), 0, dh * d),
        BlockSel::new("1st head in Key", idx("wk"), 0, dh * d),
        BlockSel::new("1st head in Value", idx("wv"), 0, dh * d),
        BlockSel::new("1st neuron in attn.proj", idx("wo"), 0, d),
        BlockSel::new("1st neuron in MLP_fc1", idx("w1"), 0, d),
    ];
    if !quick {
        sels.push(BlockSel::new("1st neuron in MLP_c_proj", idx("w2"), 0,
                                rt.mm.d_ff));
    }

    let mut rows = Vec::new();
    let mut csv = Csv::create(format!("{RESULTS_DIR}/table3.csv"),
                              &["block", "kappa_h", "kappa_dh"])?;
    let mut worse = 0usize;
    for sel in &sels {
        let (kh, kdh) = kappa_report(&rt, &params, &batches, sel, eps)?;
        csv.row_str(&[sel.label.clone(), format!("{kh:.2}"),
                      format!("{kdh:.2}")])?;
        if kdh > kh {
            worse += 1;
        }
        rows.push(vec![sel.label.clone(), format!("{kh:.2}"),
                       format!("{kdh:.2}")]);
    }
    csv.flush()?;
    println!("Table 3: Adam's preconditioner on dense Hessian blocks");
    println!("{}", ascii_table(
        &["Hessian block", "kappa(H)", "kappa(D_Adam H)"], &rows));
    println!("{}", verdict(worse * 2 >= sels.len(),
        "D_Adam fails to reduce (often increases) block condition \
         numbers"));
    println!("results: {RESULTS_DIR}/table3.csv");
    Ok(())
}
