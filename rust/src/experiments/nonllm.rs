//! Table 6: non-LLM tasks — an MLP classifier (vision stand-in) and a
//! 2-layer GCN on a synthetic graph (OGB stand-in), trained with AdamW
//! vs Adam-mini under the "Partition for non-Transformers" strategy
//! (one block per parameter tensor — paper Algorithm 3, non-Transformer
//! branch).

use anyhow::Result;

use super::quad::verdict;
use super::RESULTS_DIR;
use crate::hessian::mlp::{GaussianMixture, Mlp};
use crate::optim::{self, Hyper, Optimizer};
use crate::partition::{BlockView, Category};
use crate::tensor::Tensor;
use crate::util::csv::{ascii_table, Csv};
use crate::util::prng::Rng;

/// Per-tensor (non-Transformer) partition spec for arbitrary tensors.
fn default_spec(params: &[Tensor]) -> Vec<BlockView> {
    params
        .iter()
        .map(|p| BlockView {
            name: p.name.clone(),
            shape: p.shape.clone(),
            num_blocks: 1,
            block_size: p.numel(),
            category: Category::Whole,
        })
        .collect()
}

fn make_opt(name: &str, hp: Hyper, params: &[Tensor])
    -> Box<dyn Optimizer> {
    match name {
        "adamw" => Box::new(optim::AdamW::new(hp, params)),
        "adam_mini" => Box::new(optim::AdamMini::new(
            hp, default_spec(params), optim::ReduceOp::Mean)),
        _ => unreachable!(),
    }
}

// ---------------------------------------------------------------------------
// MLP classifier (vision stand-in)
// ---------------------------------------------------------------------------

fn mlp_accuracy(mlp: &Mlp, data: &GaussianMixture) -> f64 {
    let mut hit = 0usize;
    for (x, &y) in data.x.iter().zip(&data.y) {
        // argmax over logits
        let h = mlp.hidden;
        let mut a = vec![0.0f32; h];
        for i in 0..h {
            let mut z = 0.0;
            for j in 0..mlp.d {
                z += mlp.w.data[i * mlp.d + j] * x[j];
            }
            a[i] = z.tanh();
        }
        let mut best = 0;
        let mut best_v = f32::MIN;
        for c in 0..mlp.classes {
            let mut acc = 0.0;
            for i in 0..h {
                acc += mlp.v.data[c * h + i] * a[i];
            }
            if acc > best_v {
                best_v = acc;
                best = c;
            }
        }
        hit += (best == y) as usize;
    }
    hit as f64 / data.x.len() as f64
}

fn run_mlp(opt_name: &str, steps: usize, checkpoints: &[usize])
    -> Vec<f64> {
    // One mixture (shared class centers), split train/val.
    let all = GaussianMixture::generate(600, 12, 6, 0.7, 1);
    let (train, val) = all.split(400);
    let mut mlp = Mlp::init(12, 16, 6, 3);
    let hp = Hyper { weight_decay: 0.0, ..Default::default() };
    let params = vec![mlp.w.clone(), mlp.v.clone()];
    let mut opt = make_opt(opt_name, hp, &params);
    let mut accs = Vec::new();
    let mut done = 0;
    for &ck in checkpoints {
        mlp.train(&train, opt.as_mut(), 5e-3, ck - done);
        done = ck;
        accs.push(mlp_accuracy(&mlp, &val));
    }
    let _ = steps;
    accs
}

// ---------------------------------------------------------------------------
// GCN on a synthetic graph (OGB stand-in)
// ---------------------------------------------------------------------------

/// Synthetic node-classification graph: community structure (SBM-ish),
/// node features = noisy community indicator.
struct GraphData {
    n: usize,
    feat_dim: usize,
    classes: usize,
    /// Row-normalized adjacency (dense; probe scale).
    a_hat: Vec<f32>,
    x: Vec<f32>,
    y: Vec<usize>,
    train_mask: Vec<bool>,
}

impl GraphData {
    fn generate(n: usize, classes: usize, feat_dim: usize, seed: u64)
        -> GraphData {
        let mut rng = Rng::new(seed ^ 0x6C4);
        let y: Vec<usize> = (0..n).map(|i| i % classes).collect();
        // Adjacency: p_in = 0.2, p_out = 0.02, plus self loops.
        let mut adj = vec![0.0f32; n * n];
        for i in 0..n {
            adj[i * n + i] = 1.0;
            for j in (i + 1)..n {
                let p = if y[i] == y[j] { 0.2 } else { 0.02 };
                if rng.f64() < p {
                    adj[i * n + j] = 1.0;
                    adj[j * n + i] = 1.0;
                }
            }
        }
        // Row normalize.
        let mut a_hat = adj;
        for i in 0..n {
            let s: f32 = a_hat[i * n..(i + 1) * n].iter().sum();
            for j in 0..n {
                a_hat[i * n + j] /= s;
            }
        }
        // Features: community one-hot + noise.
        let mut x = vec![0.0f32; n * feat_dim];
        for i in 0..n {
            for f in 0..feat_dim {
                x[i * feat_dim + f] =
                    rng.normal_f32(0.6)
                    + if f % classes == y[i] { 1.0 } else { 0.0 };
            }
        }
        // Alternate train/val in label-complete groups (mask must not
        // correlate with y = i % classes).
        let train_mask: Vec<bool> =
            (0..n).map(|i| (i / classes) % 2 == 0).collect();
        GraphData { n, feat_dim, classes, a_hat, x, y, train_mask }
    }
}

/// Two-layer GCN: logits = Â·relu(Â·X·W1ᵀ)·W2ᵀ, analytic gradients.
struct Gcn {
    w1: Tensor, // (hidden, feat)
    w2: Tensor, // (classes, hidden)
    hidden: usize,
}

impl Gcn {
    fn init(feat: usize, hidden: usize, classes: usize, seed: u64) -> Gcn {
        let mut rng = Rng::new(seed ^ 0x6C42);
        Gcn {
            w1: Tensor::randn("w1", &[hidden, feat],
                              (1.0 / feat as f32).sqrt(), &mut rng),
            w2: Tensor::randn("w2", &[classes, hidden],
                              (1.0 / hidden as f32).sqrt(), &mut rng),
            hidden,
        }
    }

    /// Forward; returns (ax, h_pre, h, ah, logits).
    #[allow(clippy::type_complexity)]
    fn forward(&self, g: &GraphData)
        -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let (n, f, hid, c) = (g.n, g.feat_dim, self.hidden, g.classes);
        let ax = matmul(&g.a_hat, &g.x, n, n, f);
        let h_pre = matmul_t(&ax, &self.w1.data, n, f, hid);
        let h: Vec<f32> = h_pre.iter().map(|&v| v.max(0.0)).collect();
        let ah = matmul(&g.a_hat, &h, n, n, hid);
        let logits = matmul_t(&ah, &self.w2.data, n, hid, c);
        (ax, h_pre, h, ah, logits)
    }

    fn accuracy(&self, g: &GraphData, on_train: bool) -> f64 {
        let (_, _, _, _, logits) = self.forward(g);
        let c = g.classes;
        let mut hit = 0usize;
        let mut tot = 0usize;
        for i in 0..g.n {
            if g.train_mask[i] != on_train {
                continue;
            }
            let row = &logits[i * c..(i + 1) * c];
            let mut best = 0;
            for k in 1..c {
                if row[k] > row[best] {
                    best = k;
                }
            }
            hit += (best == g.y[i]) as usize;
            tot += 1;
        }
        hit as f64 / tot.max(1) as f64
    }

    /// Masked-CE loss + grads (w.r.t. W1, W2) over training nodes.
    fn loss_grad(&self, g: &GraphData) -> (f64, Tensor, Tensor) {
        let (n, f, hid, c) = (g.n, g.feat_dim, self.hidden, g.classes);
        let (ax, h_pre, h, ah, logits) = self.forward(g);
        let n_train = g.train_mask.iter().filter(|&&m| m).count() as f32;
        let mut dlogits = vec![0.0f32; n * c];
        let mut loss = 0.0f64;
        for i in 0..n {
            if !g.train_mask[i] {
                continue;
            }
            let row = &logits[i * c..(i + 1) * c];
            let mx = row.iter().cloned().fold(f32::MIN, f32::max);
            let lse = row.iter().map(|v| (v - mx).exp()).sum::<f32>().ln()
                + mx;
            loss += (lse - row[g.y[i]]) as f64;
            for k in 0..c {
                let p = (row[k] - lse).exp();
                dlogits[i * c + k] =
                    (p - if k == g.y[i] { 1.0 } else { 0.0 }) / n_train;
            }
        }
        loss /= n_train as f64;
        // gW2 = dlogitsᵀ · Âh ; dah = dlogits · W2
        let gw2 = matmul_tn(&dlogits, &ah, n, c, hid);
        let dah = matmul(&dlogits, &self.w2.data, n, c, hid);
        // dh = Âᵀ · dah (Â row-normalized, not symmetric)
        let dh = matmul_tn_left(&g.a_hat, &dah, n, n, hid);
        let dhpre: Vec<f32> = dh
            .iter()
            .zip(&h_pre)
            .map(|(&d, &z)| if z > 0.0 { d } else { 0.0 })
            .collect();
        let gw1 = matmul_tn(&dhpre, &ax, n, hid, f);
        let _ = h;
        (loss,
         Tensor::new("w1", &[hid, f], gw1),
         Tensor::new("w2", &[c, hid], gw2))
    }
}

/// C = A(m×k) · B(k×n)
fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            for j in 0..n {
                c[i * n + j] += av * b[p * n + j];
            }
        }
    }
    c
}

/// C = A(m×k) · B(n×k)ᵀ
fn matmul_t(a: &[f32], b: &[f32], m: usize, k: usize, n: usize)
    -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0;
            for p in 0..k {
                acc += a[i * k + p] * b[j * k + p];
            }
            c[i * n + j] = acc;
        }
    }
    c
}

/// C = A(m×k)ᵀ · B(m×n) -> (k×n)
fn matmul_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize)
    -> Vec<f32> {
    let mut c = vec![0.0f32; k * n];
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            for j in 0..n {
                c[p * n + j] += av * b[i * n + j];
            }
        }
    }
    c
}

/// C = A(m×m)ᵀ · B(m×n) — for the adjacency transpose product.
fn matmul_tn_left(a: &[f32], b: &[f32], m: usize, _: usize, n: usize)
    -> Vec<f32> {
    matmul_tn(a, b, m, m, n)
}

fn run_gcn(opt_name: &str, checkpoints: &[usize]) -> Vec<f64> {
    let g = GraphData::generate(160, 4, 12, 5);
    let mut gcn = Gcn::init(g.feat_dim, 16, g.classes, 6);
    let hp = Hyper { weight_decay: 0.0, ..Default::default() };
    let params = vec![gcn.w1.clone(), gcn.w2.clone()];
    let mut opt = make_opt(opt_name, hp, &params);
    let mut accs = Vec::new();
    let mut done = 0;
    for &ck in checkpoints {
        for _ in done..ck {
            let (_, g1, g2) = gcn.loss_grad(&g);
            let mut params = vec![gcn.w1.clone(), gcn.w2.clone()];
            opt.step(&mut params, &[g1, g2], 5e-3);
            gcn.w1 = params.remove(0);
            gcn.w2 = params.remove(0);
        }
        done = ck;
        accs.push(gcn.accuracy(&g, false));
    }
    accs
}

/// Table 6: val accuracy at 25/50/75/100% of training.
pub fn table6(quick: bool) -> Result<()> {
    let total = if quick { 80 } else { 400 };
    let checkpoints = [total / 4, total / 2, 3 * total / 4, total];
    println!("Table 6: non-LLM tasks, AdamW vs Adam-mini \
              (non-Transformer partition), val acc at 25/50/75/100% \
              of {total} steps");
    let mut rows = Vec::new();
    let mut csv = Csv::create(format!("{RESULTS_DIR}/table6.csv"),
                              &["task", "optimizer", "acc25", "acc50",
                                "acc75", "acc100"])?;
    let mut finals = Vec::new();
    for (task, runner) in [
        ("MLP (vision stand-in)",
         run_mlp as fn(&str, usize, &[usize]) -> Vec<f64>),
        ("GCN (graph)", |o: &str, _s: usize, c: &[usize]| run_gcn(o, c)),
    ] {
        for opt in ["adamw", "adam_mini"] {
            let accs = runner(opt, total, &checkpoints);
            csv.row_str(&[task.into(), opt.into(),
                          format!("{:.4}", accs[0]),
                          format!("{:.4}", accs[1]),
                          format!("{:.4}", accs[2]),
                          format!("{:.4}", accs[3])])?;
            finals.push(accs[3]);
            rows.push(vec![task.into(), opt.into(),
                           format!("{:.3}", accs[0]),
                           format!("{:.3}", accs[1]),
                           format!("{:.3}", accs[2]),
                           format!("{:.3}", accs[3])]);
        }
    }
    csv.flush()?;
    println!("{}", ascii_table(
        &["task", "optimizer", "25%", "50%", "75%", "100%"], &rows));
    let ok = finals
        .chunks(2)
        .all(|pair| pair[1] >= pair[0] - 0.03);
    println!("{}", verdict(ok,
        "Adam-mini on par with AdamW on non-LLM tasks"));
    println!("results: {RESULTS_DIR}/table6.csv");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gcn_grad_matches_finite_difference() {
        let g = GraphData::generate(24, 3, 6, 0);
        let mut gcn = Gcn::init(6, 5, 3, 0);
        let (_, g1, g2) = gcn.loss_grad(&g);
        let eps = 1e-3f32;
        for idx in [0, 7, 13] {
            let orig = gcn.w1.data[idx];
            gcn.w1.data[idx] = orig + eps;
            let lp = gcn.loss_grad(&g).0;
            gcn.w1.data[idx] = orig - eps;
            let lm = gcn.loss_grad(&g).0;
            gcn.w1.data[idx] = orig;
            let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
            assert!((fd - g1.data[idx]).abs() < 3e-3,
                    "w1[{idx}]: fd {fd} vs {}", g1.data[idx]);
        }
        for idx in [0, 4, 11] {
            let orig = gcn.w2.data[idx];
            gcn.w2.data[idx] = orig + eps;
            let lp = gcn.loss_grad(&g).0;
            gcn.w2.data[idx] = orig - eps;
            let lm = gcn.loss_grad(&g).0;
            gcn.w2.data[idx] = orig;
            let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
            assert!((fd - g2.data[idx]).abs() < 3e-3,
                    "w2[{idx}]: fd {fd} vs {}", g2.data[idx]);
        }
    }

    #[test]
    fn gcn_learns_communities() {
        let accs = run_gcn("adamw", &[50, 200]);
        assert!(accs[1] > 0.6, "val acc {accs:?}");
        assert!(accs[1] >= accs[0] - 0.05);
    }

    #[test]
    fn mlp_learns() {
        let accs = run_mlp("adam_mini", 100, &[25, 100]);
        assert!(accs[1] > 0.5, "val acc {accs:?}");
    }
}
