//! `repro report --bench-history`: diff the latest bench JSONs
//! (`results/BENCH_*.json`, written by `cargo bench`) against the
//! committed baselines in `results/baseline/`, joined per record
//! name on `mean_ns`.
//!
//! The baselines are a perf trajectory anchor: CI uploads each run's
//! fresh JSONs as artifacts, and this table makes a regression
//! visible as a `+NN%` delta without any external dashboard.
//!
//! Every bench JSON carries a `provenance` field: `"measured"` means a
//! bench binary timed it on real hardware, `"seeded"` means it was
//! hand-planted to bootstrap the trajectory. `--gate` turns the diff
//! into a CI check — any record more than [`GATE_THRESHOLD`] slower
//! than a MEASURED baseline fails the run. Seeded baselines never
//! gate: failing CI over a made-up number would teach everyone to
//! ignore the gate.

use anyhow::{bail, Result};

use crate::util::csv::ascii_table;
use crate::util::json::Json;

use super::RESULTS_DIR;

const BENCHES: [&str; 5] = ["BENCH_dist.json", "BENCH_overlap.json",
                            "BENCH_optim.json", "BENCH_serve.json",
                            "BENCH_compress.json"];

/// Relative slowdown vs a measured baseline that fails `--gate`.
pub const GATE_THRESHOLD: f64 = 0.15;

/// One loaded bench JSON: where its numbers came from + the records.
struct BenchFile {
    provenance: String,
    records: Vec<(String, f64)>,
}

impl BenchFile {
    fn measured(&self) -> bool {
        self.provenance == "measured"
    }
}

/// Parse a bench JSON, or `None` if the file is absent. A missing
/// `provenance` key reads as `"seeded"` (pre-provenance files were
/// all hand-planted).
fn load_records(path: &str) -> Result<Option<BenchFile>> {
    if !std::path::Path::new(path).exists() {
        return Ok(None);
    }
    let j = Json::parse(&std::fs::read_to_string(path)?)?;
    let provenance = j
        .get("provenance")
        .and_then(|p| Ok(p.as_str()?.to_string()))
        .unwrap_or_else(|_| "seeded".to_string());
    let mut records = Vec::new();
    for r in j.get("records")?.as_arr()? {
        records.push((
            r.get("name")?.as_str()?.to_string(),
            r.get("mean_ns")?.as_f64()?,
        ));
    }
    Ok(Some(BenchFile { provenance, records }))
}

/// Rows for one bench file's diff (exposed for the unit test).
fn diff_rows(cur: &[(String, f64)], base: &[(String, f64)])
    -> Vec<Vec<String>> {
    let mut rows = Vec::new();
    for (name, cur_ns) in cur {
        let (base_str, delta) = match base
            .iter()
            .find(|(n, _)| n == name)
        {
            Some((_, base_ns)) => (
                format!("{base_ns:.0}"),
                format!("{:+.1}%",
                        100.0 * (cur_ns - base_ns) / base_ns),
            ),
            None => ("-".to_string(), "new".to_string()),
        };
        rows.push(vec![name.clone(), base_str,
                       format!("{cur_ns:.0}"), delta]);
    }
    for (name, base_ns) in base {
        if !cur.iter().any(|(n, _)| n == name) {
            rows.push(vec![name.clone(), format!("{base_ns:.0}"),
                           "-".to_string(), "gone".to_string()]);
        }
    }
    rows
}

/// Records slower than `threshold` vs the baseline: `(name, frac)`.
/// New/gone records never regress (there is nothing to compare).
fn regressions(cur: &[(String, f64)], base: &[(String, f64)],
               threshold: f64) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for (name, cur_ns) in cur {
        if let Some((_, base_ns)) =
            base.iter().find(|(n, _)| n == name)
        {
            let frac = (cur_ns - base_ns) / base_ns;
            if frac > threshold {
                out.push((name.clone(), frac));
            }
        }
    }
    out
}

/// Print the bench diffs (graceful when either side is missing: a
/// fresh checkout has baselines but no current run yet). With
/// `gate=true`, error out when any record regresses more than
/// [`GATE_THRESHOLD`] against a MEASURED baseline.
pub fn report(gate: bool) -> Result<()> {
    println!("Bench history: latest {RESULTS_DIR}/BENCH_*.json vs \
              committed {RESULTS_DIR}/baseline/ (mean_ns)");
    let mut rows = Vec::new();
    let mut failures = Vec::new();
    for file in BENCHES {
        let cur = load_records(&format!("{RESULTS_DIR}/{file}"))?;
        let base =
            load_records(&format!("{RESULTS_DIR}/baseline/{file}"))?;
        match (cur, base) {
            (None, _) => println!(
                "  {file}: no current run (cargo bench writes it)"),
            (_, None) => println!("  {file}: no committed baseline"),
            (Some(cur), Some(base)) => {
                println!("  {file}: baseline provenance = {}{}",
                         base.provenance,
                         if base.measured() { " (gating)" }
                         else { " (informational only)" });
                rows.extend(diff_rows(&cur.records, &base.records));
                if gate && base.measured() {
                    for (name, frac) in regressions(
                        &cur.records, &base.records, GATE_THRESHOLD)
                    {
                        failures.push(format!(
                            "{name}: {:+.1}% vs measured baseline",
                            100.0 * frac));
                    }
                }
            }
        }
    }
    if rows.is_empty() {
        println!("(nothing to diff)");
    } else {
        println!("{}", ascii_table(
            &["Record", "Baseline ns", "Latest ns", "Delta"], &rows));
    }
    if !failures.is_empty() {
        bail!("bench gate: {} record(s) regressed more than {:.0}%:\n  \
               {}", failures.len(), GATE_THRESHOLD * 100.0,
              failures.join("\n  "));
    }
    if gate {
        println!("bench gate: no regression beyond {:.0}% vs any \
                  measured baseline", GATE_THRESHOLD * 100.0);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diff_joins_by_name() {
        let base = vec![("a".to_string(), 100.0),
                        ("b".to_string(), 200.0)];
        let cur = vec![("a".to_string(), 150.0),
                       ("c".to_string(), 50.0)];
        let rows = diff_rows(&cur, &base);
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0], vec!["a", "100", "150", "+50.0%"]);
        assert_eq!(rows[1], vec!["c", "-", "50", "new"]);
        assert_eq!(rows[2], vec!["b", "200", "-", "gone"]);
    }

    #[test]
    fn load_missing_is_none() {
        assert!(load_records("results/definitely_absent.json")
            .unwrap()
            .is_none());
    }

    #[test]
    fn regressions_respect_the_threshold() {
        let base = vec![("a".to_string(), 100.0),
                        ("b".to_string(), 100.0),
                        ("c".to_string(), 100.0)];
        let cur = vec![("a".to_string(), 114.0),   // +14%: under
                       ("b".to_string(), 120.0),   // +20%: over
                       ("d".to_string(), 900.0)];  // new: skipped
        let r = regressions(&cur, &base, GATE_THRESHOLD);
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].0, "b");
        assert!((r[0].1 - 0.20).abs() < 1e-9);
        // Faster records never trip the gate.
        let fast = vec![("a".to_string(), 10.0)];
        assert!(regressions(&fast, &base, GATE_THRESHOLD).is_empty());
    }

    #[test]
    fn provenance_parses_with_seeded_default() {
        let dir = std::env::temp_dir().join("bench_hist_prov_test");
        std::fs::create_dir_all(&dir).unwrap();
        let with = dir.join("with.json");
        std::fs::write(&with,
            r#"{"bench":"x","provenance":"measured",
                "records":[{"name":"a","mean_ns":1.0}]}"#).unwrap();
        let f = load_records(with.to_str().unwrap())
            .unwrap().unwrap();
        assert!(f.measured());
        assert_eq!(f.records, vec![("a".to_string(), 1.0)]);
        let without = dir.join("without.json");
        std::fs::write(&without,
            r#"{"bench":"x","records":[{"name":"a","mean_ns":1.0}]}"#)
            .unwrap();
        let f = load_records(without.to_str().unwrap())
            .unwrap().unwrap();
        assert_eq!(f.provenance, "seeded");
        assert!(!f.measured());
    }
}
