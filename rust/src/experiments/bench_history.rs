//! `repro report --bench-history`: diff the latest bench JSONs
//! (`results/BENCH_*.json`, written by `cargo bench`) against the
//! committed baselines in `results/baseline/`, joined per record
//! name on `mean_ns`.
//!
//! The baselines are a perf trajectory anchor: CI uploads each run's
//! fresh JSONs as artifacts, and this table makes a regression
//! visible as a `+NN%` delta without any external dashboard.

use anyhow::Result;

use crate::util::csv::ascii_table;
use crate::util::json::Json;

use super::RESULTS_DIR;

const BENCHES: [&str; 3] =
    ["BENCH_dist.json", "BENCH_overlap.json", "BENCH_optim.json"];

/// `(name, mean_ns)` per record, or `None` if the file is absent.
fn load_records(path: &str) -> Result<Option<Vec<(String, f64)>>> {
    if !std::path::Path::new(path).exists() {
        return Ok(None);
    }
    let j = Json::parse(&std::fs::read_to_string(path)?)?;
    let mut out = Vec::new();
    for r in j.get("records")?.as_arr()? {
        out.push((
            r.get("name")?.as_str()?.to_string(),
            r.get("mean_ns")?.as_f64()?,
        ));
    }
    Ok(Some(out))
}

/// Rows for one bench file's diff (exposed for the unit test).
fn diff_rows(cur: &[(String, f64)], base: &[(String, f64)])
    -> Vec<Vec<String>> {
    let mut rows = Vec::new();
    for (name, cur_ns) in cur {
        let (base_str, delta) = match base
            .iter()
            .find(|(n, _)| n == name)
        {
            Some((_, base_ns)) => (
                format!("{base_ns:.0}"),
                format!("{:+.1}%",
                        100.0 * (cur_ns - base_ns) / base_ns),
            ),
            None => ("-".to_string(), "new".to_string()),
        };
        rows.push(vec![name.clone(), base_str,
                       format!("{cur_ns:.0}"), delta]);
    }
    for (name, base_ns) in base {
        if !cur.iter().any(|(n, _)| n == name) {
            rows.push(vec![name.clone(), format!("{base_ns:.0}"),
                           "-".to_string(), "gone".to_string()]);
        }
    }
    rows
}

/// Print the three bench diffs (graceful when either side is missing:
/// a fresh checkout has baselines but no current run yet).
pub fn report() -> Result<()> {
    println!("Bench history: latest {RESULTS_DIR}/BENCH_*.json vs \
              committed {RESULTS_DIR}/baseline/ (mean_ns)");
    let mut rows = Vec::new();
    for file in BENCHES {
        let cur = load_records(&format!("{RESULTS_DIR}/{file}"))?;
        let base =
            load_records(&format!("{RESULTS_DIR}/baseline/{file}"))?;
        match (cur, base) {
            (None, _) => println!(
                "  {file}: no current run (cargo bench writes it)"),
            (_, None) => println!("  {file}: no committed baseline"),
            (Some(cur), Some(base)) => {
                rows.extend(diff_rows(&cur, &base));
            }
        }
    }
    if rows.is_empty() {
        println!("(nothing to diff)");
    } else {
        println!("{}", ascii_table(
            &["Record", "Baseline ns", "Latest ns", "Delta"], &rows));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diff_joins_by_name() {
        let base = vec![("a".to_string(), 100.0),
                        ("b".to_string(), 200.0)];
        let cur = vec![("a".to_string(), 150.0),
                       ("c".to_string(), 50.0)];
        let rows = diff_rows(&cur, &base);
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0], vec!["a", "100", "150", "+50.0%"]);
        assert_eq!(rows[1], vec!["c", "-", "50", "new"]);
        assert_eq!(rows[2], vec!["b", "200", "-", "gone"]);
    }

    #[test]
    fn load_missing_is_none() {
        assert!(load_records("results/definitely_absent.json")
            .unwrap()
            .is_none());
    }
}
