//! Fig 6 (Adam leave-x-out) and Fig 14 (blockwise GD beats AdamW on a
//! 1-layer transformer) — the grid-search motivation experiments.

use std::sync::Arc;

use anyhow::Result;

use super::quad::verdict;
use super::RESULTS_DIR;
use crate::data::{Batcher, Corpus, SyntheticSpec};
use crate::optim::{AdamW, Arena, BlockwiseGd, GradView, Granularity,
                   Hyper, Optimizer, ParamView, Schedule};
use crate::partition::Strategy;
use crate::runtime::{Engine, ModelRuntime};
use crate::tensor::Tensor;
use crate::util::csv::{ascii_table, Csv};

/// Adam everywhere except `left_out` tensors, which get a single
/// grid-searched learning-rate multiplier (the Fig 6 "Adam
/// (leave-one-out)" method). Tensor-granular (the left-out redo
/// applies per whole tensor).
struct LeaveOut {
    adam: AdamW,
    left_out: Vec<usize>,
    /// Per-left-out-tensor lr multipliers (relative to the base lr).
    lr_mults: Vec<f32>,
    /// Arena-flat momentum for the left-out single-lr updates.
    momentum: Vec<f32>,
    beta1: f32,
}

impl LeaveOut {
    fn new(hp: Hyper, params: &[Tensor], left_out: Vec<usize>,
           lr_mults: Vec<f32>) -> LeaveOut {
        assert_eq!(left_out.len(), lr_mults.len());
        let adam = AdamW::new(hp, params);
        let total = adam.arena().total;
        LeaveOut {
            adam,
            momentum: vec![0.0; total],
            left_out,
            lr_mults,
            beta1: hp.beta1,
        }
    }
}

impl Optimizer for LeaveOut {
    fn name(&self) -> String {
        format!("adam_leaveout_x{}", self.left_out.len())
    }

    fn arena(&self) -> &Arc<Arena> {
        self.adam.arena()
    }

    fn granularity(&self) -> Granularity {
        Granularity::Tensor
    }

    fn begin_step(&mut self) {
        self.adam.begin_step();
    }

    fn step_segment(&mut self, params: ParamView<'_>, grads: GradView<'_>,
                    lr: f32) {
        // Save left-out tensors in the segment, let Adam update
        // everything, then redo the left-out ones with single-lr
        // momentum-SGD.
        let mut params = params;
        let (lo, hi) = params.range();
        let arena = Arc::clone(self.adam.arena());
        let (i0, spans) = arena.spans_in(lo, hi);
        let saved: Vec<(usize, usize, Vec<f32>)> = spans
            .iter()
            .enumerate()
            .filter_map(|(k, sp)| {
                let i = i0 + k;
                self.left_out.iter().position(|&l| l == i).map(|slot| {
                    let a = sp.offset - lo;
                    (slot, i0 + k, params.data[a..a + sp.len].to_vec())
                })
            })
            .collect();
        self.adam.step_segment(params.reborrow(), grads.reborrow(), lr);
        for (slot, i, saved_p) in saved {
            let sp = &arena.spans[i];
            let a = sp.offset - lo;
            let mult = self.lr_mults[slot];
            params.data[a..a + sp.len].copy_from_slice(&saved_p);
            for j in 0..sp.len {
                let m = &mut self.momentum[sp.offset + j];
                *m = self.beta1 * *m
                    + (1.0 - self.beta1) * grads.data[a + j];
                params.data[a + j] -= lr * mult * *m;
            }
        }
    }

    fn state_bytes(&self) -> usize {
        self.adam.state_bytes()
    }
}

fn train_with(engine: &Engine, model: &str, steps: usize,
              mut opt: Box<dyn Optimizer>, peak_lr: f32, seed: u64)
    -> Result<f32> {
    let rt = ModelRuntime::new(engine, model)?;
    let mut params = rt.init_params(seed);
    let corpus = Corpus::synthetic(&SyntheticSpec {
        vocab: rt.mm.vocab,
        n_tokens: (steps + 8) * rt.mm.batch_size * rt.mm.seq_len / 2
            + 4096,
        seed: seed ^ 0xDA7A,
        ..Default::default()
    });
    let mut batcher = Batcher::new(corpus, rt.mm.batch_size,
                                   rt.mm.seq_len, seed);
    let schedule = Schedule::llama(peak_lr, steps);
    let mut tail = Vec::new();
    for t in 1..=steps {
        let b = batcher.next_batch();
        let (loss, grads) = rt.grad(&params, &b)?;
        opt.step(&mut params, &grads, schedule.lr(t));
        if t + 3 > steps {
            tail.push(loss);
        }
        if !loss.is_finite() {
            return Ok(f32::NAN);
        }
    }
    Ok(tail.iter().sum::<f32>() / tail.len() as f32)
}

/// Fig 6: leave-x-out for x = 1, 2, 3 on a 4-layer transformer.
pub fn fig6(engine: &Engine, quick: bool) -> Result<()> {
    let model = if quick { "t48k" } else { "t295k" };
    let steps = if quick { 40 } else { 200 };
    let grid: &[f32] = if quick { &[0.3, 1.0] }
                       else { &[0.1, 0.3, 1.0, 3.0, 10.0] };
    let hp = engine.manifest.hyper();
    let rt = ModelRuntime::new(engine, model)?;
    let params = rt.init_params(0);
    let n_tensors = params.len();
    drop(rt);

    println!("Fig 6: Adam (leave-x-out) on {model}, {steps} steps, \
              lr-mult grid {grid:?}");
    let base = train_with(engine, model, steps,
                          Box::new(AdamW::new(hp, &params)), 6e-3, 0)?;
    println!("  Adam baseline loss: {base:.4}");

    let mut csv = Csv::create(format!("{RESULTS_DIR}/fig6.csv"),
                              &["x", "left_out", "best_mult",
                                "best_loss", "adam_loss"])?;
    let mut rows = Vec::new();
    let mut all_close = true;
    // Deterministic "random" block choices: spread across tensor list.
    let choices: Vec<Vec<usize>> = vec![
        vec![1 % n_tensors],
        vec![1 % n_tensors, 5 % n_tensors],
        vec![1 % n_tensors, 5 % n_tensors, 7 % n_tensors],
    ];
    let xs = if quick { &choices[..1] } else { &choices[..] };
    for (x, left_out) in xs.iter().enumerate() {
        // Sequential coordinate search: each left-out tensor gets its
        // OWN lr multiplier (the paper searches one lr per block).
        let mut mults = vec![1.0f32; left_out.len()];
        let eval = |mults: &Vec<f32>| -> Result<f32> {
            let opt = Box::new(LeaveOut::new(
                hp, &params, left_out.clone(), mults.clone()));
            train_with(engine, model, steps, opt, 6e-3, 0)
        };
        let mut best = eval(&mults)?;
        for k in 0..left_out.len() {
            for &mult in grid {
                let mut cand = mults.clone();
                cand[k] = mult;
                let loss = eval(&cand)?;
                if loss.is_finite() && loss < best {
                    best = loss;
                    mults = cand;
                }
            }
        }
        csv.row_str(&[(x + 1).to_string(), format!("{left_out:?}"),
                      format!("{mults:?}"), format!("{best:.4}"),
                      format!("{base:.4}")])?;
        let close = best <= base + 0.05;
        all_close &= close;
        rows.push(vec![format!("leave-{}-out", x + 1),
                       format!("{left_out:?}"),
                       format!("{mults:?}"),
                       format!("{best:.4}"),
                       format!("{base:.4}")]);
    }
    csv.flush()?;
    println!("{}", ascii_table(
        &["method", "left-out tensors", "best lr-mult", "best loss",
          "Adam loss"], &rows));
    println!("{}", verdict(all_close,
        "a single searched lr per left-out block matches Adam"));
    println!("results: {RESULTS_DIR}/fig6.csv");
    Ok(())
}

/// Fig 14 (Appendix D.1 Exp 2): blockwise GD with per-block searched
/// lrs vs AdamW on the 1-layer transformer.
pub fn fig14(engine: &Engine, quick: bool) -> Result<()> {
    let model = "h1t";
    let steps = if quick { 80 } else { 400 };
    let hp = engine.manifest.hyper();
    let rt = ModelRuntime::new(engine, model)?;
    let params = rt.init_params(0);
    let spec = rt.mm.meta().spec_for(&params, Strategy::Default)?;
    drop(rt);

    println!("Fig 14: blockwise GD (per-tensor searched lrs) vs AdamW \
              on {model}");
    let adam = train_with(engine, model, steps,
                          Box::new(AdamW::new(hp, &params)), 6e-3, 0)?;

    // Coordinate-descent grid search over per-tensor lr multipliers.
    let grid: &[f32] = if quick { &[0.3, 1.0, 3.0] }
                       else { &[0.1, 0.3, 1.0, 3.0, 10.0] };
    let n = spec.len();
    let mut mults = vec![1.0f32; n];
    let base_lr = 0.5f32;
    let eval = |mults: &[f32]| -> Result<f32> {
        let lrs: Vec<Vec<f32>> = spec
            .iter()
            .zip(mults)
            .map(|(s, &m)| vec![m; s.num_blocks])
            .collect();
        train_with(engine, model, steps,
                   Box::new(BlockwiseGd::with_lrs(spec.clone(), lrs)),
                   base_lr, 0)
    };
    let mut best = eval(&mults)?;
    let rounds = if quick { 1 } else { 2 };
    for _ in 0..rounds {
        for i in 0..n {
            for &g in grid {
                let mut cand = mults.clone();
                cand[i] = g;
                let loss = eval(&cand)?;
                if loss.is_finite() && loss < best {
                    best = loss;
                    mults = cand;
                }
            }
        }
    }
    println!("  AdamW loss:        {adam:.4}");
    println!("  blockwise GD loss: {best:.4}  (mults {mults:?})");
    let mut csv = Csv::create(format!("{RESULTS_DIR}/fig14.csv"),
                              &["method", "loss"])?;
    csv.row_str(&["adamw".into(), format!("{adam:.4}")])?;
    csv.row_str(&["blockwise_gd".into(), format!("{best:.4}")])?;
    csv.flush()?;
    println!("{}", verdict(best <= adam + 0.02,
        "blockwise GD matches/beats AdamW with one lr per block"));
    println!("results: {RESULTS_DIR}/fig14.csv");
    Ok(())
}
