//! Pre-training comparison experiments (Fig 8, 9, 10, 13, 15, 19, 20,
//! 21) — all share one roster runner over the AOT `grad` artifact.

use anyhow::Result;

use super::quad::verdict;
use super::RESULTS_DIR;
use crate::config::TrainConfig;
use crate::coordinator::{RunHistory, Trainer};
use crate::runtime::Engine;
use crate::tensor::params_l2_dist;
use crate::util::csv::{ascii_table, Csv};

/// Run one configured training job; returns its history.
pub fn run_one(engine: &Engine, model: &str, optimizer: &str,
               steps: usize, peak_lr: f32, seed: u64, schedule: &str)
    -> Result<RunHistory> {
    let mut cfg = TrainConfig {
        model: model.into(),
        optimizer: optimizer.into(),
        steps,
        peak_lr,
        seed,
        schedule: schedule.into(),
        eval_every: (steps / 4).max(1),
        log_every: (steps / 20).max(1),
        ..Default::default()
    };
    if let Some(op) = optimizer.strip_prefix("adam_mini@") {
        cfg.optimizer = "adam_mini".into();
        cfg.reduce_op = op.into();
    }
    let mut tr = Trainer::from_config(engine, &cfg)?;
    let mut hist = tr.train(true)?;
    if optimizer.contains('@') {
        hist.name = format!("{model}_{}", optimizer.replace('@', "_"));
    }
    Ok(hist)
}

/// Roster comparison: same model/data/steps, per-optimizer peak lrs.
fn roster(engine: &Engine, model: &str, steps: usize,
          entries: &[(&str, f32)], schedule: &str, tag: &str)
    -> Result<Vec<RunHistory>> {
    let mut out = Vec::new();
    let mut rows = Vec::new();
    for &(opt, lr) in entries {
        let hist = run_one(engine, model, opt, steps, lr, 0, schedule)?;
        hist.write_csv(&format!("{RESULTS_DIR}/{tag}"))?;
        rows.push(vec![
            opt.to_string(),
            format!("{lr:.1e}"),
            format!("{:.4}", hist.tail_loss(3)),
            format!("{:.4}", hist.final_val_loss()),
            format!("{:.1}", hist.opt_state_bytes as f64 / 1e3),
            if hist.has_spike(1.5) { "SPIKE".into() }
            else { "stable".into() },
        ]);
        println!("  {opt:<22} done (tail loss {:.4})", hist.tail_loss(3));
        out.push(hist);
    }
    println!("{}", ascii_table(
        &["optimizer", "peak lr", "train loss", "val loss",
          "opt state (KB)", "stability"], &rows));
    Ok(out)
}

/// Fig 8 (+9a): GPT-2-style pre-training, full roster incl. the
/// default-partition failure case.
pub fn fig8(engine: &Engine, quick: bool) -> Result<()> {
    let steps = if quick { 60 } else { 400 };
    println!("Fig 8: GPT-2 pre-training roster (gpt2s, {steps} steps)");
    let entries: Vec<(&str, f32)> = if quick {
        vec![("adamw", 6e-3), ("adam_mini", 6e-3),
             ("adam_mini_default", 6e-3)]
    } else {
        vec![("adamw", 6e-3), ("adam_mini", 6e-3),
             ("adam_mini_default", 6e-3), ("adafactor", 6e-3),
             ("came", 6e-3), ("sm3", 6e-3), ("lamb", 6e-3),
             ("lion", 6e-4)]
    };
    let hists = roster(engine, "gpt2s", steps, &entries, "cosine",
                       "fig8")?;
    let adamw = hists[0].tail_loss(3);
    let mini = hists[1].tail_loss(3);
    println!("{}", verdict((mini - adamw).abs() < 0.05 || mini < adamw,
                           "Adam-mini on par with AdamW"));
    println!("results: {RESULTS_DIR}/fig8/");
    Ok(())
}

/// Fig 10: Llama-style pre-training roster.
pub fn fig10(engine: &Engine, quick: bool) -> Result<()> {
    let steps = if quick { 60 } else { 400 };
    println!("Fig 10: Llama pre-training roster (t134k, {steps} steps)");
    let entries: Vec<(&str, f32)> = if quick {
        vec![("adamw", 6e-3), ("adam_mini", 6e-3)]
    } else {
        vec![("adamw", 6e-3), ("adam_mini", 6e-3), ("adafactor", 6e-3),
             ("adafactor_zhai", 6e-3), ("came", 6e-3), ("sm3", 6e-3),
             ("lamb", 6e-3), ("lion", 6e-4)]
    };
    let hists = roster(engine, "t134k", steps, &entries, "linear",
                       "fig10")?;
    let adamw = hists[0].tail_loss(3);
    let mini = hists[1].tail_loss(3);
    println!("{}", verdict(mini < adamw + 0.05,
                           "Adam-mini on par or better than AdamW"));
    println!("results: {RESULTS_DIR}/fig10/");
    Ok(())
}

/// Fig 9b: trajectory l2-distance of each optimizer to AdamW's
/// trajectory under identical seed and lr.
pub fn fig9(engine: &Engine, quick: bool) -> Result<()> {
    let steps = if quick { 40 } else { 250 };
    let every = (steps / 10).max(1);
    let model = "t48k";
    println!("Fig 9b: trajectory distance to AdamW ({model}, lr 1e-5, \
              same seed — paper Appendix F.1 protocol)");
    let mk = |optimizer: &str| -> Result<Vec<Vec<crate::tensor::Tensor>>> {
        let cfg = TrainConfig {
            model: model.into(),
            optimizer: optimizer.into(),
            steps,
            peak_lr: 1e-5,
            schedule: "const".into(),
            seed: 3,
            eval_every: 0,
            log_every: steps,
            ..Default::default()
        };
        let mut tr = Trainer::from_config(engine, &cfg)?;
        tr.record_snapshots(every);
        tr.train(true)?;
        Ok(tr.snapshots.take().unwrap().1)
    };
    let reference = mk("adamw")?;
    let others = if quick {
        vec!["adam_mini"]
    } else {
        vec!["adam_mini", "adafactor", "sm3", "lion"]
    };
    let mut header = vec!["step".to_string()];
    header.extend(others.iter().map(|s| s.to_string()));
    let hdr_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut csv = Csv::create(format!("{RESULTS_DIR}/fig9b.csv"),
                              &hdr_refs)?;
    let mut table_rows = Vec::new();
    let mut dists: Vec<Vec<f64>> = Vec::new();
    for opt in &others {
        let snaps = mk(opt)?;
        let d: Vec<f64> = snaps
            .iter()
            .zip(&reference)
            .map(|(a, b)| params_l2_dist(a, b))
            .collect();
        dists.push(d);
    }
    for (i, snap_ref) in reference.iter().enumerate() {
        let mut row = vec![(i * every) as f64];
        for d in &dists {
            row.push(d[i]);
        }
        csv.row(&row)?;
        let _ = snap_ref;
    }
    csv.flush()?;
    for (opt, d) in others.iter().zip(&dists) {
        table_rows.push(vec![opt.to_string(),
                             format!("{:.4}", d[d.len() / 2]),
                             format!("{:.4}", d[d.len() - 1])]);
    }
    println!("{}", ascii_table(
        &["optimizer", "mid-run dist", "final dist"], &table_rows));
    if !quick {
        let mini_final = dists[0].last().copied().unwrap_or(f64::MAX);
        let others_min = dists[1..]
            .iter()
            .filter_map(|d| d.last().copied())
            .fold(f64::MAX, f64::min);
        println!("{}", verdict(mini_final < others_min,
            "Adam-mini stays closest to AdamW's trajectory"));
    }
    println!("results: {RESULTS_DIR}/fig9b.csv");
    Ok(())
}

/// Fig 13: Adafactor (orig + Zhai) vs Adam-mini (+ optimizer-step
/// latency comparison, the Fig 13c analogue — the cluster-sim version
/// lives in `repro exp table2`).
pub fn fig13(engine: &Engine, quick: bool) -> Result<()> {
    let steps = if quick { 60 } else { 300 };
    println!("Fig 13(a,b): Adafactor variants vs Adam-mini (t48k)");
    let hists = roster(engine, "t48k", steps,
                       &[("adam_mini", 6e-3), ("adafactor", 6e-3),
                         ("adafactor_zhai", 5e-3)],
                       "linear", "fig13")?;
    let mini = hists[0].tail_loss(3);
    let worst_af = hists[1..]
        .iter()
        .map(|h| h.tail_loss(3))
        .fold(f32::MIN, f32::max);
    println!("{}", verdict(mini <= worst_af + 0.02,
                           "Adafactor variants do not beat Adam-mini"));
    println!("(Fig 13c — throughput — regenerate with `repro exp table2` \
              and `cargo bench --bench optimizer_step`.)");
    Ok(())
}

/// Fig 15: blockwise reduce ablation — mean vs max/min/l1/l2.
pub fn fig15(engine: &Engine, quick: bool) -> Result<()> {
    let steps = if quick { 60 } else { 300 };
    println!("Fig 15: Adam-mini reduce-op ablation (t48k, {steps} steps)");
    let hists = roster(engine, "t48k", steps,
                       &[("adam_mini@mean", 6e-3), ("adam_mini@max", 6e-3),
                         ("adam_mini@min", 6e-3),
                         ("adam_mini@l1norm", 6e-3),
                         ("adam_mini@l2norm", 6e-3)],
                       "linear", "fig15")?;
    let mean_loss = hists[0].tail_loss(3);
    let best_other = hists[1..]
        .iter()
        .map(|h| {
            let l = h.tail_loss(3);
            if l.is_finite() { l } else { f32::MAX }
        })
        .fold(f32::MAX, f32::min);
    println!("{}", verdict(mean_loss <= best_other + 0.02,
                           "mean(v) is the best blockwise statistic"));
    Ok(())
}

/// Fig 19: Adafactor hyperparameter sweeps (Setups 1–3).
pub fn fig19(engine: &Engine, quick: bool) -> Result<()> {
    let steps = if quick { 50 } else { 200 };
    println!("Fig 19: Adafactor-Zhai hyperparameter sweeps (t48k)");
    // Setup 1: lr sweep (β2 fixed at manifest's 0.95 — the paper's
    // Setup 1 change — our Hyper already uses β2=0.95).
    let lrs = if quick { vec![5e-3f32] }
              else { vec![1e-3, 3e-3, 5e-3, 1e-2] };
    let mut best_af = f32::MAX;
    let mut rows = Vec::new();
    for lr in lrs {
        let h = run_one(engine, "t48k", "adafactor_zhai", steps, lr, 0,
                        "linear")?;
        h.write_csv(&format!("{RESULTS_DIR}/fig19"))?;
        let l = h.tail_loss(3);
        best_af = best_af.min(if l.is_finite() { l } else { f32::MAX });
        rows.push(vec![format!("lr={lr:.0e}"), format!("{l:.4}")]);
    }
    let mini = run_one(engine, "t48k", "adam_mini", steps, 6e-3, 0,
                       "linear")?;
    rows.push(vec!["adam_mini (untuned)".into(),
                   format!("{:.4}", mini.tail_loss(3))]);
    println!("{}", ascii_table(&["setting", "train loss"], &rows));
    println!("{}", verdict(mini.tail_loss(3) <= best_af + 0.02,
        "tuned Adafactor still does not beat untuned Adam-mini"));
    Ok(())
}

/// Fig 20: Lion tuning with the "10x smaller lr" rule.
pub fn fig20(engine: &Engine, quick: bool) -> Result<()> {
    let steps = if quick { 50 } else { 200 };
    println!("Fig 20: Lion lr sweep (t48k; standard AdamW lr is 6e-3)");
    let lrs: Vec<f32> = if quick { vec![6e-4] }
                        else { vec![3.16e-4, 6e-4, 1e-3, 2e-3, 6e-3] };
    let mut rows = Vec::new();
    let mut best = f32::MAX;
    for lr in lrs {
        let h = run_one(engine, "t48k", "lion", steps, lr, 0, "linear")?;
        h.write_csv(&format!("{RESULTS_DIR}/fig20"))?;
        let l = h.tail_loss(3);
        best = best.min(if l.is_finite() { l } else { f32::MAX });
        rows.push(vec![format!("lion lr={lr:.2e}"), format!("{l:.4}"),
                       if h.has_spike(1.5) { "SPIKE".into() }
                       else { "stable".into() }]);
    }
    let mini = run_one(engine, "t48k", "adam_mini", steps, 6e-3, 0,
                       "linear")?;
    rows.push(vec!["adam_mini lr=6e-3".into(),
                   format!("{:.4}", mini.tail_loss(3)), "stable".into()]);
    println!("{}", ascii_table(&["setting", "train loss", "stability"],
                               &rows));
    println!("{}", verdict(mini.tail_loss(3) <= best + 0.02,
                           "Lion underperforms Adam-mini"));
    Ok(())
}

/// Fig 21 (+ Fig 7i analogue): loss spikes — AdamW at aggressive lr/eps
/// vs Adam-mini; and Adam-mini(default partition) vs Algorithm 3.
pub fn fig21(engine: &Engine, quick: bool) -> Result<()> {
    let steps = if quick { 60 } else { 250 };
    // Spike-prone configuration: high lr, minimal warmup (const
    // schedule), low-coherence data.
    println!("Fig 21 / Fig 7i: stability under aggressive settings \
              (t48k, const lr 2e-2, {steps} steps)");
    let entries = [("adamw", "adamw"),
                   ("adam_mini", "adam_mini"),
                   ("adam_mini_default", "adam_mini (default part.)")];
    let mut rows = Vec::new();
    let mut spikes = std::collections::BTreeMap::new();
    for (opt, label) in entries {
        let cfg = TrainConfig {
            model: "t48k".into(),
            optimizer: opt.into(),
            steps,
            peak_lr: 2e-2,
            schedule: "const".into(),
            seed: 1,
            coherence: 0.4,
            eval_every: 0,
            log_every: (steps / 25).max(1),
            ..Default::default()
        };
        let mut tr = Trainer::from_config(engine, &cfg)?;
        let h = tr.train(true)?;
        h.write_csv(&format!("{RESULTS_DIR}/fig21"))?;
        let spiked = h.has_spike(1.3);
        spikes.insert(opt.to_string(), spiked);
        rows.push(vec![label.to_string(),
                       format!("{:.4}", h.tail_loss(3)),
                       if spiked { "SPIKE".into() }
                       else { "stable".into() }]);
    }
    println!("{}", ascii_table(&["optimizer", "final loss", "stability"],
                               &rows));
    println!("{}", verdict(!spikes["adam_mini"],
                           "Adam-mini (Algorithm 3) stays stable"));
    println!("results: {RESULTS_DIR}/fig21/");
    Ok(())
}
