//! Fig 11 + Table 4 + Fig 16: scaling-law experiments.
//!
//! Chinchilla-style protocol at probe scale: a Llama-architecture
//! ladder trained with tokens = ratio × params (paper ratio ≈ 26; the
//! CPU testbed uses a smaller ratio, held CONSTANT across the ladder —
//! which is what a scaling-law comparison needs). Fits
//! loss = c · N^k per optimizer and compares final validation
//! perplexity (Table 4's shape: Adam-mini ≤ AdamW at every size).

use anyhow::Result;

use super::quad::verdict;
use super::RESULTS_DIR;
use crate::config::TrainConfig;
use crate::coordinator::Trainer;
use crate::eval::perplexity;
use crate::runtime::Engine;
use crate::util::csv::{ascii_table, Csv};
use crate::util::stats::powerfit;

pub fn run(engine: &Engine, quick: bool) -> Result<()> {
    let (models, ratio): (&[&str], usize) = if quick {
        (&["t48k", "t134k"], 2)
    } else {
        (&["t48k", "t134k", "t295k"], 8)
    };
    println!("Fig 11 / Table 4: scaling law, tokens = {ratio} x params");
    let mut csv = Csv::create(format!("{RESULTS_DIR}/scaling.csv"),
                              &["model", "n_params", "tokens", "optimizer",
                                "val_loss", "val_ppl"])?;
    let mut sizes = Vec::new();
    let mut ppl: std::collections::BTreeMap<String, Vec<f64>> =
        Default::default();
    let mut rows = Vec::new();
    for model in models {
        let mm = engine.manifest.model(model)?;
        let n = mm.n_params;
        let tokens_per_step = mm.batch_size * mm.seq_len;
        let steps = (ratio * n / tokens_per_step).max(20);
        sizes.push(n as f64);
        let mut row = vec![model.to_string(), n.to_string(),
                           (ratio * n).to_string()];
        for opt in ["adamw", "adam_mini"] {
            let cfg = TrainConfig {
                model: model.to_string(),
                optimizer: opt.into(),
                steps,
                peak_lr: 6e-3,
                schedule: "linear".into(),
                seed: 0,
                eval_every: (steps / 4).max(1),
                log_every: (steps / 20).max(1),
                ..Default::default()
            };
            let mut tr = Trainer::from_config(engine, &cfg)?;
            let hist = tr.train(true)?;
            hist.write_csv(&format!("{RESULTS_DIR}/scaling"))?;
            let vl = hist.final_val_loss() as f64;
            let p = perplexity(vl);
            csv.row_str(&[model.to_string(), n.to_string(),
                          (ratio * n).to_string(), opt.into(),
                          format!("{vl:.4}"), format!("{p:.3}")])?;
            ppl.entry(opt.to_string()).or_default().push(p);
            row.push(format!("{p:.3}"));
            println!("  {model}/{opt}: {steps} steps, val ppl {p:.3}");
        }
        rows.push(row);
    }
    csv.flush()?;
    println!("{}", ascii_table(
        &["model", "params", "tokens", "AdamW ppl", "Adam-mini ppl"],
        &rows));

    // Fig 11b: fitted scaling lines (power law over params).
    for (opt, ps) in &ppl {
        if sizes.len() >= 2 {
            let (c, k, r2) = powerfit(&sizes, ps);
            println!("fit {opt}: ppl = {c:.2} * N^{k:.3} (r2 = {r2:.3})");
        }
    }
    let wins = ppl["adam_mini"]
        .iter()
        .zip(&ppl["adamw"])
        .filter(|(m, a)| m <= a)
        .count();
    println!("{}", verdict(wins * 2 >= sizes.len(),
        "Adam-mini reaches equal-or-lower perplexity across the ladder \
         (Table 4 shape)"));
    println!("(Fig 16 is the largest rung's full loss curve: \
              results/scaling/<largest>_adam*_s0.csv)");
    println!("results: {RESULTS_DIR}/scaling.csv");
    Ok(())
}
