//! Table 1 (+ Fig 1a) and Table 2 (+ Fig 13c): memory + simulated
//! cluster throughput.

use anyhow::Result;

use super::quad::verdict;
use super::RESULTS_DIR;
use crate::cluster::{Job, ADAFACTOR_PROFILE, ADAM_MINI_PROFILE,
                     ADAMW_PROFILE};
use crate::memmodel::{gib, memory_report, table1_models};
use crate::util::csv::{ascii_table, Csv};

/// Table 1: optimizer-state memory, AdamW vs Adam-mini.
pub fn table1() -> Result<()> {
    println!("Table 1: optimizer-state memory (float32), exact shape \
              inventories");
    let mut rows = Vec::new();
    let mut csv = Csv::create(format!("{RESULTS_DIR}/table1.csv"),
                              &["model", "params", "blocks", "adamw_gb",
                                "adam_mini_gb", "saving_pct",
                                "v_cut_pct"])?;
    let mut ok = true;
    for arch in table1_models() {
        let r = memory_report(&arch);
        let v_cut = 100.0
            * (1.0 - r.n_blocks as f64 / r.n_params as f64);
        csv.row_str(&[r.model.clone(), r.n_params.to_string(),
                      r.n_blocks.to_string(),
                      format!("{:.2}", gib(r.adamw_bytes)),
                      format!("{:.2}", gib(r.adam_mini_bytes)),
                      format!("{:.2}", r.saving_pct()),
                      format!("{v_cut:.3}")])?;
        ok &= r.saving_pct() > 49.9 && v_cut > 99.9;
        rows.push(vec![r.model.clone(),
                       format!("{:.2}", gib(r.adamw_bytes)),
                       format!("{:.2} ({:.1}% less)",
                               gib(r.adam_mini_bytes), r.saving_pct()),
                       format!("{v_cut:.3}%")]);
    }
    csv.flush()?;
    println!("{}", ascii_table(
        &["Model", "AdamW (GB)", "Adam-mini (GB)", "v removed"], &rows));
    println!("{}", verdict(ok,
        ">=99.9% of v removed; 50% of optimizer memory saved"));
    println!("results: {RESULTS_DIR}/table1.csv");
    Ok(())
}

/// Table 2 + Fig 1a + Fig 13c: simulated 2xA800 throughput.
pub fn table2() -> Result<()> {
    println!("Table 2: Llama 2-7B on simulated 2x A800-80GB (see \
              cluster.rs for the calibration contract)");
    let mut rows = Vec::new();
    let mut csv = Csv::create(format!("{RESULTS_DIR}/table2.csv"),
                              &["optimizer", "bs_per_gpu",
                                "throughput_tok_s"])?;
    let aw = Job::llama7b(ADAMW_PROFILE);
    let am = Job::llama7b(ADAM_MINI_PROFILE);
    // Paper's exact rows: Adam-mini bs=4; AdamW bs=2 (OOM); AdamW bs=1.
    let (am_bs, am_thr) = am.best_throughput().unwrap();
    csv.row_str(&["adam_mini".into(), am_bs.to_string(),
                  format!("{am_thr:.1}")])?;
    rows.push(vec!["Adam-mini".into(), am_bs.to_string(),
                   format!("{am_thr:.1}")]);
    let oom2 = aw.mem_per_gpu(2) > aw.gpu.mem_bytes;
    rows.push(vec!["AdamW".into(), "2".into(),
                   if oom2 { "OOM".into() }
                   else { format!("{:.1}", aw.throughput(2)) }]);
    let (aw_bs, aw_thr) = aw.best_throughput().unwrap();
    csv.row_str(&["adamw".into(), aw_bs.to_string(),
                  format!("{aw_thr:.1}")])?;
    rows.push(vec!["AdamW".into(), aw_bs.to_string(),
                   format!("{aw_thr:.1}")]);
    println!("{}", ascii_table(
        &["Optimizer", "bs/GPU", "Throughput (tok/s)"], &rows));
    let gain = am_thr / aw_thr - 1.0;
    println!("throughput gain: {:.1}% (paper: 49.6%)  {}", gain * 100.0,
             verdict((gain - 0.496).abs() < 0.08,
                     "~50% higher throughput"));

    // GPU-hours at the paper's token budgets.
    let mut rows = Vec::new();
    for (label, tokens) in [("7B (Chinchilla ~140B tokens)", 140e9),
                            ("70B tokens", 70e9), ("1B tokens", 1e9)] {
        let h_aw = aw.gpu_hours(tokens).unwrap();
        let h_am = am.gpu_hours(tokens).unwrap();
        rows.push(vec![label.to_string(), format!("{h_aw:.1}"),
                       format!("{h_am:.1} ({:.1}% less)",
                               100.0 * (1.0 - h_am / h_aw))]);
        csv.row_str(&[format!("gpu_hours_{tokens:.0}"),
                      format!("{h_aw:.1}"), format!("{h_am:.1}")])?;
    }
    csv.flush()?;
    println!("{}", ascii_table(
        &["Token budget", "AdamW GPU-h", "Adam-mini GPU-h"], &rows));

    // Fig 13c analogue: Adam-mini vs Adafactor update latency on
    // Llama-2-1B. We report the optimizer-STEP ratio (the paper's §3.4
    // mechanism: Adafactor reduces across rows AND columns and its v
    // has in×out dimension); the paper's 40% END-TO-END gap implies
    // additional implementation overheads our first-order model does
    // not carry — recorded as a known gap in EXPERIMENTS.md.
    let arch_1b = &table1_models()[1];
    let mini_1b = Job::from_arch(arch_1b, 2, ADAM_MINI_PROFILE);
    let af_1b = Job::from_arch(arch_1b, 2, ADAFACTOR_PROFILE);
    let (o_mini, o_af) =
        (mini_1b.opt_step_time() * 1e3, af_1b.opt_step_time() * 1e3);
    println!("Fig 13c: Llama 2-1B optimizer step — Adam-mini \
              {o_mini:.1} ms vs Adafactor {o_af:.1} ms \
              ({:.2}x)  {}",
             o_af / o_mini,
             verdict(o_af > 1.4 * o_mini,
                     "Adafactor's update is substantially slower \
                      (paper's latency mechanism)"));
    println!("results: {RESULTS_DIR}/table2.csv");
    Ok(())
}
