//! Experiment registry: one entry per paper table/figure (DESIGN.md §5).
//!
//! Every experiment prints the paper-shaped table/series to stdout AND
//! writes its raw data under `results/`. `quick` trades steps for
//! wall-clock (CI mode); EXPERIMENTS.md records full-mode runs.

pub mod align;
pub mod bench_history;
pub mod hessian_exp;
pub mod leaveout;
pub mod nonllm;
pub mod pretrain;
pub mod quad;
pub mod scaling;
pub mod throughput;

use anyhow::{bail, Result};

use crate::runtime::Engine;

/// Output directory for experiment CSVs.
pub const RESULTS_DIR: &str = "results";

/// (name, paper artifact, needs_engine)
pub const EXPERIMENTS: &[(&str, &str, bool)] = &[
    ("fig3", "Fig 3: MLP Hessian is near-block-diagonal through training",
     false),
    ("fig4", "Fig 4: quadratic — blockwise GD > Adam > single-lr GD",
     false),
    ("fig5", "Fig 5: r = kappa(D_Adam H)/kappa(H) vs tau, d, kappa",
     false),
    ("fig6", "Fig 6: Adam (leave-x-out) matches Adam on a Transformer",
     true),
    ("fig7", "Fig 7: Transformer Hessian block classes + partition fix",
     true),
    ("table3", "Table 3: kappa(H) vs kappa(D_Adam H) per Hessian block",
     true),
    ("fig8", "Fig 8/9a: GPT-2 pre-training, roster comparison", true),
    ("fig9", "Fig 9b: trajectory l2-distance to AdamW", true),
    ("fig10", "Fig 10: Llama pre-training, roster comparison", true),
    ("scaling", "Fig 11/16 + Table 4: scaling law (Chinchilla-style)",
     true),
    ("sft", "Fig 12a + Table 5: SFT (masked), AdamW vs Adam-mini", true),
    ("rlhf", "Fig 12b + Table 5: ReMax reward ascent", true),
    ("sensitivity", "Fig 12c: hyperparameter sensitivity grid", true),
    ("fig13", "Fig 13: Adafactor (orig/Zhai) vs Adam-mini + throughput",
     true),
    ("fig15", "Fig 15: mean vs max/min/l1/l2 blockwise reduce ablation",
     true),
    ("fig19", "Fig 19: Adafactor hyperparameter sweeps", true),
    ("fig20", "Fig 20: Lion tuning (incl. 10x-smaller-lr rule)", true),
    ("fig21", "Fig 21: AdamW loss spikes vs eps; Adam-mini stays stable",
     true),
    ("table1", "Table 1 + Fig 1a: optimizer memory, GPT-2/Llama family",
     false),
    ("table2", "Table 2: simulated 2xA800 throughput + GPU-hours", false),
    ("fig14", "Fig 14: blockwise GD beats AdamW on a 1-layer Transformer",
     true),
    ("nonllm", "Table 6: non-LLM tasks (MLP classifier, GCN)", false),
    ("fig22", "Fig 22: SFT with LoRA, Adam steps replaced by Adam-mini",
     true),
];

/// Run one experiment by name.
pub fn run(name: &str, engine: Option<&Engine>, quick: bool) -> Result<()> {
    let need = |()| -> Result<&Engine> {
        engine.ok_or_else(|| anyhow::anyhow!(
            "experiment {name} needs artifacts — run `make artifacts`"))
    };
    match name {
        "fig3" => hessian_exp::fig3(quick),
        "fig4" => quad::fig4(quick),
        "fig5" => quad::fig5(quick),
        "fig6" => leaveout::fig6(need(())?, quick),
        "fig7" => hessian_exp::fig7(need(())?, quick),
        "table3" => hessian_exp::table3(need(())?, quick),
        "fig8" => pretrain::fig8(need(())?, quick),
        "fig9" => pretrain::fig9(need(())?, quick),
        "fig10" => pretrain::fig10(need(())?, quick),
        "scaling" => scaling::run(need(())?, quick),
        "sft" => align::sft(need(())?, quick),
        "rlhf" => align::rlhf(need(())?, quick),
        "sensitivity" => align::sensitivity(need(())?, quick),
        "fig13" => pretrain::fig13(need(())?, quick),
        "fig15" => pretrain::fig15(need(())?, quick),
        "fig19" => pretrain::fig19(need(())?, quick),
        "fig20" => pretrain::fig20(need(())?, quick),
        "fig21" => pretrain::fig21(need(())?, quick),
        "table1" => throughput::table1(),
        "table2" => throughput::table2(),
        "fig14" => leaveout::fig14(need(())?, quick),
        "nonllm" => nonllm::table6(quick),
        "fig22" => align::fig22(need(())?, quick),
        other => bail!("unknown experiment {other:?} — see `repro list`"),
    }
}
