//! Host tensor type shared by the optimizer roster, the runtime literal
//! bridge, and checkpointing. Row-major `f32` storage, shape-checked
//! helpers — deliberately minimal (the heavy math runs inside the AOT
//! XLA executables; host tensors exist for optimizer state and analysis).

use std::fmt;

use anyhow::{bail, Result};

use crate::util::prng::Rng;

/// A named, row-major f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor({} {:?}, {} elems)", self.name, self.shape,
               self.data.len())
    }
}

impl Tensor {
    pub fn new(name: impl Into<String>, shape: &[usize], data: Vec<f32>)
        -> Tensor {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape/data mismatch");
        Tensor { name: name.into(), shape: shape.to_vec(), data }
    }

    pub fn zeros(name: impl Into<String>, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { name: name.into(), shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn ones(name: impl Into<String>, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { name: name.into(), shape: shape.to_vec(), data: vec![1.0; n] }
    }

    /// N(0, std) initialized tensor.
    pub fn randn(name: impl Into<String>, shape: &[usize], std: f32,
                 rng: &mut Rng) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor {
            name: name.into(),
            shape: shape.to_vec(),
            data: rng.normal_vec(n, std),
        }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Squared L2 norm.
    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    pub fn norm(&self) -> f64 {
        self.sq_norm().sqrt()
    }

    /// Elementwise a += s * b.
    pub fn axpy(&mut self, s: f32, b: &Tensor) {
        assert_eq!(self.shape, b.shape);
        for (x, y) in self.data.iter_mut().zip(&b.data) {
            *x += s * y;
        }
    }

    /// Mean-squared distance to another tensor (trajectory comparison).
    pub fn sq_dist(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum()
    }

    pub fn assert_shape(&self, shape: &[usize]) -> Result<()> {
        if self.shape != shape {
            bail!("{}: shape {:?} != expected {:?}", self.name, self.shape,
                  shape);
        }
        Ok(())
    }

    /// Max |a - b| over all elements.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// ℓ2 distance between two parameter lists (paper Fig 9b trajectory
/// comparison).
pub fn params_l2_dist(a: &[Tensor], b: &[Tensor]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| x.sq_dist(y))
        .sum::<f64>()
        .sqrt()
}

/// Total element count of a parameter list.
pub fn params_numel(ts: &[Tensor]) -> usize {
    ts.iter().map(Tensor::numel).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_numel() {
        let t = Tensor::zeros("a", &[2, 3]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.shape, vec![2, 3]);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn new_checks_shape() {
        Tensor::new("a", &[2, 2], vec![0.0; 3]);
    }

    #[test]
    fn axpy_and_dist() {
        let mut a = Tensor::ones("a", &[4]);
        let b = Tensor::ones("b", &[4]);
        a.axpy(2.0, &b);
        assert_eq!(a.data, vec![3.0; 4]);
        assert!((a.sq_dist(&b) - 16.0).abs() < 1e-9);
        assert!((params_l2_dist(&[a.clone()], &[b.clone()]) - 4.0).abs()
            < 1e-9);
    }

    #[test]
    fn randn_statistics() {
        let mut rng = Rng::new(0);
        let t = Tensor::randn("w", &[100, 100], 0.02, &mut rng);
        let mean: f64 =
            t.data.iter().map(|&x| x as f64).sum::<f64>() / 1e4;
        assert!(mean.abs() < 1e-3);
        let rms = (t.sq_norm() / 1e4).sqrt();
        assert!((rms - 0.02).abs() < 1e-3, "rms {rms}");
    }
}
