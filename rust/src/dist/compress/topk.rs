//! Sparse top-|g| gradient-drop codec (grad_drop style).
//!
//! Only the `frac` largest-magnitude entries of a summation segment
//! cross the wire, as (index, value) pairs with full-f32 values —
//! `1 + 2·ceil(frac·n)` wire slots for n dense elements, ~2·frac× the
//! dense bytes. Selection is a deterministic total order (|value|
//! descending, index ascending as the tie-break), so every rank and
//! every transport produce identical wire bits for identical inputs.
//!
//! The dropped mass is NOT lost: the coded collectives pair this
//! codec with a per-rank error-feedback residual (see
//! [`CodedRing`](super::codec::CodedRing)) that re-injects it into
//! the same segment on the next step. Broadcast payloads (param
//! all-gather) are never top-k compressed — dropping a parameter
//! would corrupt the replica, not approximate it — so
//! [`Codec::compresses_broadcast`] is false and those phases stay
//! dense f32.

use crate::dist::comm::TrafficClass;

use super::codec::Codec;

/// Top-|g| sparsification with kept fraction `frac` in (0, 1].
pub struct TopKCodec {
    pub frac: f32,
}

impl TopKCodec {
    /// Entries kept for a dense segment of `len` elements: at least
    /// one, at most all of them.
    pub fn kept(&self, len: usize) -> usize {
        if len == 0 {
            return 0;
        }
        ((len as f64 * self.frac as f64).ceil() as usize).clamp(1, len)
    }
}

impl Codec for TopKCodec {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn class(&self) -> TrafficClass {
        TrafficClass::CodecTopK
    }

    fn encode(&self, data: &[f32]) -> Vec<f32> {
        debug_assert!(data.len() < (1 << 23), "header slot overflow");
        let k = self.kept(data.len());
        let mut wire = Vec::with_capacity(1 + 2 * k);
        wire.push(f32::from_bits(k as u32));
        if k == 0 {
            return wire;
        }
        let mut idx: Vec<u32> = (0..data.len() as u32).collect();
        // Deterministic total order: |v| descending, index ascending.
        // total_cmp keeps this well-defined even for NaN gradients.
        let by_mag = |&a: &u32, &b: &u32| {
            data[b as usize]
                .abs()
                .total_cmp(&data[a as usize].abs())
                .then(a.cmp(&b))
        };
        if k < idx.len() {
            idx.select_nth_unstable_by(k - 1, by_mag);
        }
        let mut top = idx[..k].to_vec();
        // Wire order is index-ascending: deterministic and decode-
        // friendly.
        top.sort_unstable();
        for i in top {
            wire.push(f32::from_bits(i));
            wire.push(data[i as usize]);
        }
        wire
    }

    fn decode(&self, wire: &[f32], len: usize) -> Vec<f32> {
        let k = wire[0].to_bits() as usize;
        let mut out = vec![0.0f32; len];
        for pair in wire[1..1 + 2 * k].chunks_exact(2) {
            out[pair[0].to_bits() as usize] = pair[1];
        }
        out
    }

    fn compresses_broadcast(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_the_largest_magnitudes_exactly() {
        let codec = TopKCodec { frac: 0.25 };
        let data = vec![0.1f32, -5.0, 0.2, 3.0, -0.3, 0.05, 1.0, 0.4];
        // k = ceil(8 * 0.25) = 2: keeps -5.0 and 3.0, full precision.
        let wire = codec.encode(&data);
        assert_eq!(wire.len(), 1 + 2 * 2);
        let back = codec.decode(&wire, data.len());
        assert_eq!(back,
                   vec![0.0, -5.0, 0.0, 3.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn selection_is_deterministic_under_ties() {
        // Equal magnitudes: the lower index wins, every time.
        let codec = TopKCodec { frac: 0.5 };
        let data = vec![1.0f32, -1.0, 1.0, -1.0];
        let a = codec.encode(&data);
        let b = codec.encode(&data);
        let bits = |w: &[f32]| -> Vec<u32> {
            w.iter().map(|x| x.to_bits()).collect()
        };
        assert_eq!(bits(&a), bits(&b));
        assert_eq!(codec.decode(&a, 4), vec![1.0, -1.0, 0.0, 0.0]);
    }

    #[test]
    fn kept_counts_clamp() {
        let c = TopKCodec { frac: 0.25 };
        assert_eq!(c.kept(0), 0);
        assert_eq!(c.kept(1), 1);
        assert_eq!(c.kept(2), 1);
        assert_eq!(c.kept(8), 2);
        assert_eq!(c.kept(100), 25);
        let all = TopKCodec { frac: 1.0 };
        assert_eq!(all.kept(7), 7);
    }

    #[test]
    fn frac_one_is_dense_in_values() {
        let codec = TopKCodec { frac: 1.0 };
        let data = vec![0.5f32, -0.25, 0.0, 7.0];
        let back = codec.decode(&codec.encode(&data), data.len());
        assert_eq!(back, data);
    }

    #[test]
    fn empty_segment_is_a_header_only_message() {
        let codec = TopKCodec { frac: 0.5 };
        let wire = codec.encode(&[]);
        assert_eq!(wire.len(), 1);
        assert!(codec.decode(&wire, 0).is_empty());
    }

    #[test]
    fn wire_size_matches_the_closed_form() {
        let codec = TopKCodec { frac: 0.1 };
        for n in [1usize, 10, 100, 1000] {
            let data: Vec<f32> = (0..n)
                .map(|i| ((i * 37) % 101) as f32 - 50.0)
                .collect();
            assert_eq!(codec.encode(&data).len(),
                       1 + 2 * codec.kept(n), "n={n}");
        }
    }
}
