//! The [`Codec`] trait, the `compress=` config spec, and the
//! per-collective [`CodedRing`] context threaded through the coded
//! ring collectives.

use anyhow::{bail, Context, Result};

use crate::dist::comm::TrafficClass;

use super::f16::F16Codec;
use super::topk::TopKCodec;

/// Parsed `compress=none|f16|topk:<frac>` config value. Lives on
/// `DistOptions` and round-trips through `TrainConfig::to_json` for
/// the multi-process socket path.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum CodecSpec {
    /// True bypass: the pre-codec pipeline, bit-exact.
    #[default]
    None,
    /// Half-precision quantization of scatter AND gather payloads.
    F16,
    /// Sparse top-|g| with error feedback; `frac` is the kept
    /// fraction of each summation segment, in (0, 1].
    TopK { frac: f32 },
}

impl CodecSpec {
    /// Parse the `compress=` config key.
    pub fn parse(s: &str) -> Result<CodecSpec> {
        let s = s.trim();
        Ok(match s {
            "" | "none" => CodecSpec::None,
            "f16" => CodecSpec::F16,
            "topk" => CodecSpec::TopK { frac: 0.25 },
            other => match other.strip_prefix("topk:") {
                Some(arg) => {
                    let frac: f32 = arg.parse().with_context(|| {
                        format!("bad topk fraction {arg:?}")
                    })?;
                    if !(frac > 0.0 && frac <= 1.0) {
                        bail!("topk fraction must be in (0, 1], \
                               got {frac}");
                    }
                    CodecSpec::TopK { frac }
                }
                None => bail!("unknown compress codec {other:?} \
                               (none | f16 | topk:<frac>)"),
            },
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            CodecSpec::None => "none",
            CodecSpec::F16 => "f16",
            CodecSpec::TopK { .. } => "topk",
        }
    }

    /// The config-string form (`CodecSpec::parse` round-trips it).
    pub fn config_key(&self) -> String {
        match self {
            CodecSpec::None => "none".to_string(),
            CodecSpec::F16 => "f16".to_string(),
            CodecSpec::TopK { frac } => format!("topk:{frac}"),
        }
    }

    pub fn is_none(&self) -> bool {
        *self == CodecSpec::None
    }

    /// The traffic class compressed payloads are accounted under.
    pub fn class(&self) -> Option<TrafficClass> {
        match self {
            CodecSpec::None => None,
            CodecSpec::F16 => Some(TrafficClass::CodecF16),
            CodecSpec::TopK { .. } => Some(TrafficClass::CodecTopK),
        }
    }

    /// Whether this codec carries a per-rank error-feedback residual.
    pub fn error_feedback(&self) -> bool {
        matches!(self, CodecSpec::TopK { .. })
    }

    /// Instantiate the codec (`None` for the bypass).
    pub fn build(&self) -> Option<Box<dyn Codec>> {
        match self {
            CodecSpec::None => None,
            CodecSpec::F16 => Some(Box::new(F16Codec)),
            CodecSpec::TopK { frac } => {
                Some(Box::new(TopKCodec { frac: *frac }))
            }
        }
    }
}

/// One gradient/parameter compression scheme. Implementations must be
/// deterministic pure functions of the input segment: every rank must
/// produce identical wire bits for identical inputs, or the
/// cross-transport bit-exactness matrix breaks.
pub trait Codec: Send + Sync {
    fn name(&self) -> &'static str;

    /// The traffic class this codec's wire payloads are recorded
    /// under (in place of the base class of the collective).
    fn class(&self) -> TrafficClass;

    /// Encode a dense f32 segment into wire slots (self-describing;
    /// fewer slots than `data.len()` for a payload worth sending).
    fn encode(&self, data: &[f32]) -> Vec<f32>;

    /// Decode wire slots back into a dense segment of length `len`.
    fn decode(&self, wire: &[f32], len: usize) -> Vec<f32>;

    /// True if broadcast (copy-semantics) payloads — the param
    /// all-gather phases — are compressed too. Summation payloads are
    /// always compressed.
    fn compresses_broadcast(&self) -> bool;
}

/// Per-collective codec context: the codec, the (optional)
/// error-feedback residual for the active flat window, and the
/// raw-vs-wire slot accounting the worker layer publishes as
/// `Event::BucketCompressed`.
pub struct CodedRing<'a> {
    pub codec: &'a dyn Codec,
    /// Window-relative residual slice (`None` for codecs without
    /// error feedback). Indexed by the same offsets as the window
    /// buffer the collective runs over.
    pub residual: Option<&'a mut [f32]>,
    /// Dense f32 elements that would have crossed the wire.
    pub raw_elems: u64,
    /// Wire f32 slots actually sent.
    pub wire_elems: u64,
}

impl<'a> CodedRing<'a> {
    pub fn new(codec: &'a dyn Codec,
               residual: Option<&'a mut [f32]>) -> CodedRing<'a> {
        CodedRing { codec, residual, raw_elems: 0, wire_elems: 0 }
    }

    /// Encode one outgoing SUMMATION segment whose window-relative
    /// range starts at `lo`: fold the residual into the payload,
    /// encode, then store the new residual (what this hop dropped).
    pub fn encode_sum(&mut self, data: &[f32], lo: usize) -> Vec<f32> {
        let mut out = data.to_vec();
        if let Some(res) = &mut self.residual {
            let res = &mut res[lo..lo + data.len()];
            for (o, r) in out.iter_mut().zip(res.iter()) {
                *o += *r;
            }
        }
        let wire = self.codec.encode(&out);
        if let Some(res) = &mut self.residual {
            let res = &mut res[lo..lo + data.len()];
            let back = self.codec.decode(&wire, out.len());
            for ((r, o), b) in res.iter_mut().zip(&out).zip(&back) {
                *r = o - b;
            }
        }
        self.raw_elems += data.len() as u64;
        self.wire_elems += wire.len() as u64;
        wire
    }

    /// Encode one outgoing BROADCAST (copy-semantics) segment: no
    /// residual — a broadcast hop forwards, it does not accumulate.
    pub fn encode_copy(&mut self, data: &[f32]) -> Vec<f32> {
        let wire = self.codec.encode(data);
        self.raw_elems += data.len() as u64;
        self.wire_elems += wire.len() as u64;
        wire
    }

    /// Decode an incoming wire payload into a dense segment.
    pub fn decode(&self, wire: &[f32], len: usize) -> Vec<f32> {
        self.codec.decode(wire, len)
    }

    /// Round one segment through the codec in place — the owning
    /// rank's own chunk in a coded all-gather, so every rank ends the
    /// collective holding identical (quantized) bits.
    pub fn quantize_in_place(&self, data: &mut [f32]) {
        let wire = self.codec.encode(data);
        data.copy_from_slice(&self.codec.decode(&wire, data.len()));
    }

    /// (raw, wire) BYTES moved through this context so far.
    pub fn bytes(&self) -> (u64, u64) {
        (self.raw_elems * 4, self.wire_elems * 4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parses_and_round_trips() {
        assert_eq!(CodecSpec::parse("none").unwrap(), CodecSpec::None);
        assert_eq!(CodecSpec::parse("").unwrap(), CodecSpec::None);
        assert_eq!(CodecSpec::parse("f16").unwrap(), CodecSpec::F16);
        assert_eq!(CodecSpec::parse("topk:0.25").unwrap(),
                   CodecSpec::TopK { frac: 0.25 });
        assert_eq!(CodecSpec::parse("topk").unwrap(),
                   CodecSpec::TopK { frac: 0.25 });
        for s in ["none", "f16", "topk:0.25", "topk:0.5"] {
            let spec = CodecSpec::parse(s).unwrap();
            assert_eq!(CodecSpec::parse(&spec.config_key()).unwrap(),
                       spec, "{s}");
        }
        assert!(CodecSpec::parse("topk:0").is_err());
        assert!(CodecSpec::parse("topk:1.5").is_err());
        assert!(CodecSpec::parse("gzip").is_err());
    }

    #[test]
    fn spec_capabilities() {
        assert!(CodecSpec::None.build().is_none());
        assert!(CodecSpec::None.class().is_none());
        assert!(!CodecSpec::None.error_feedback());
        let f = CodecSpec::F16;
        assert_eq!(f.class(), Some(TrafficClass::CodecF16));
        assert!(!f.error_feedback());
        assert!(f.build().unwrap().compresses_broadcast());
        let t = CodecSpec::TopK { frac: 0.5 };
        assert_eq!(t.class(), Some(TrafficClass::CodecTopK));
        assert!(t.error_feedback());
        assert!(!t.build().unwrap().compresses_broadcast());
    }

    #[test]
    fn error_feedback_conserves_dropped_mass() {
        // The EF invariant: payload-as-sent + residual-after ==
        // payload-as-meant (input + residual-before), exactly, every
        // hop. Whatever top-k drops this step is re-injected next.
        let codec = TopKCodec { frac: 0.25 };
        let mut residual = vec![0.0f32; 8];
        let input = vec![4.0, -0.5, 0.25, 8.0, -0.125, 0.0625, 1.0,
                         -2.0];
        let mut ctx = CodedRing::new(&codec, Some(&mut residual));
        let wire = ctx.encode_sum(&input, 0);
        let sent = ctx.decode(&wire, input.len());
        for i in 0..input.len() {
            assert_eq!(sent[i] + residual[i], input[i], "elem {i}");
        }
        // Second step over a zero gradient: the residual drains.
        let mut ctx = CodedRing::new(&codec, Some(&mut residual));
        let wire = ctx.encode_sum(&[0.0; 8], 0);
        let sent = ctx.decode(&wire, 8);
        let drained: f32 = sent.iter().map(|v| v.abs()).sum();
        assert!(drained > 0.0, "residual mass must re-inject");
    }

    #[test]
    fn accounting_counts_raw_and_wire() {
        let codec = F16Codec;
        let mut ctx = CodedRing::new(&codec, None);
        let data = vec![1.0f32; 100];
        let wire = ctx.encode_copy(&data);
        assert_eq!(ctx.raw_elems, 100);
        assert_eq!(ctx.wire_elems, wire.len() as u64);
        assert_eq!(ctx.bytes(), (400, wire.len() as u64 * 4));
        // Two f16 per slot + one header slot.
        assert_eq!(wire.len(), 51);
    }

    #[test]
    fn quantize_in_place_is_idempotent() {
        let codec = F16Codec;
        let ctx = CodedRing::new(&codec, None);
        let mut a = vec![0.1f32, -3.7, 1e-5, 42.0];
        ctx.quantize_in_place(&mut a);
        let once = a.clone();
        ctx.quantize_in_place(&mut a);
        assert_eq!(a, once, "re-quantizing quantized data is a no-op");
    }
}
