//! Gradient compression: a codec layer under the bucket pipeline.
//!
//! Adam-mini's thesis is "move fewer bytes"; this subsystem pushes it
//! from the optimizer state onto the wire. A [`Codec`] re-encodes
//! every ring-collective hop: the sender turns a dense f32 segment
//! into fewer wire slots, the receiver decodes before accumulating
//! (summation hops) or copying (broadcast hops). The wire stays
//! `Vec<f32>`, so compression composes UNDER both transports and the
//! socket ARQ/fault middleware by construction — a corrupted or
//! dropped frame is retransmitted bit-exactly whether or not its
//! payload is compressed.
//!
//! Two codecs ship behind the `compress=` config key:
//!
//! - `f16` ([`F16Codec`]) — half-precision quantization of both
//!   reduce-scatter and all-gather payloads, two f16 per wire slot
//!   (~0.5× bytes). Lossy but unbiased enough per step that no error
//!   feedback is carried.
//! - `topk:<frac>` ([`TopKCodec`]) — sparse top-|g| gradient drop:
//!   only the largest-magnitude `frac` of each summation segment
//!   crosses the wire as (index, value) pairs (~2·frac× bytes), and
//!   the dropped mass lands in a per-rank error-feedback residual
//!   that is re-injected into the same segment next step. Broadcast
//!   payloads (param all-gather) stay dense: dropping a parameter is
//!   not an approximation, it is corruption.
//!
//! Accounting: compressed payloads are recorded under the codec's own
//! [`TrafficClass`] at the `record_from` choke point, so the base
//! ledgers keep meaning "dense f32 bytes" and the `cluster.rs` closed
//! forms for compressed step bytes can be cross-checked per class.
//!
//! [`TrafficClass`]: crate::dist::comm::TrafficClass

pub mod codec;
pub mod f16;
pub mod topk;

pub use codec::{Codec, CodecSpec, CodedRing};
pub use f16::F16Codec;
pub use topk::TopKCodec;
