//! Half-precision (IEEE 754 binary16) quantization codec.
//!
//! The conversions are hand-rolled (no `half` crate): round-to-
//! nearest-even f32→f16, exact f16→f32. Wire format: one header slot
//! carrying the dense length, then two f16 values packed per f32 slot
//! — so a segment of n elements costs `1 + ceil(n/2)` slots, ~0.5×
//! the dense bytes.
//!
//! The packed slots are arbitrary bit patterns reinterpreted as f32
//! (including patterns in the NaN space). That is safe here because
//! nothing between `encode` and `decode` does floating-point
//! arithmetic on payloads: the channel transport moves the `Vec<f32>`
//! verbatim, and the socket framer serializes each slot with
//! `to_le_bytes`/`from_le_bytes` — both bit-preserving.

use crate::dist::comm::TrafficClass;

use super::codec::Codec;

/// f32 → binary16 bits, round-to-nearest-even. Out-of-range values
/// overflow to ±inf; NaNs stay NaN (quietened, payload truncated).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        return if man == 0 {
            sign | 0x7c00
        } else {
            sign | 0x7e00 | ((man >> 13) as u16 & 0x01ff)
        };
    }
    let e = exp - 127 + 15;
    if e >= 0x1f {
        return sign | 0x7c00;
    }
    if e <= 0 {
        if e < -10 {
            return sign;
        }
        // Subnormal: implicit-1 mantissa shifted into place, then
        // round to nearest, ties to even.
        let man = man | 0x0080_0000;
        let shift = (1 - e) as u32 + 13;
        let half = (man >> shift) as u16;
        let rem = man & ((1u32 << shift) - 1);
        let tie = 1u32 << (shift - 1);
        return sign
            | (half
               + u16::from(rem > tie || (rem == tie && half & 1 == 1)));
    }
    let half = sign | ((e as u16) << 10) | ((man >> 13) as u16);
    let rem = man & 0x1fff;
    // Mantissa carry propagates into the exponent by construction
    // (0x...3ff + 1 rolls the exponent field, 30→31 yields inf).
    half + u16::from(rem > 0x1000 || (rem == 0x1000 && half & 1 == 1))
}

/// binary16 bits → f32, exact (every f16 value is representable).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x03ff) as u32;
    let bits = match exp {
        0 => {
            if man == 0 {
                sign
            } else {
                // Subnormal: value = man × 2⁻²⁴; normalize.
                let k = 31 - man.leading_zeros();
                sign | ((k + 103) << 23)
                    | ((man & !(1 << k)) << (23 - k))
            }
        }
        0x1f => sign | 0x7f80_0000 | (man << 13),
        e => sign | ((e + 112) << 23) | (man << 13),
    };
    f32::from_bits(bits)
}

/// Half-precision codec: quantizes both summation and broadcast
/// payloads (re-encoding already-quantized data is lossless, so
/// forwarded all-gather hops stay bit-stable).
pub struct F16Codec;

impl Codec for F16Codec {
    fn name(&self) -> &'static str {
        "f16"
    }

    fn class(&self) -> TrafficClass {
        TrafficClass::CodecF16
    }

    fn encode(&self, data: &[f32]) -> Vec<f32> {
        debug_assert!(data.len() < (1 << 23), "header slot overflow");
        let mut wire = Vec::with_capacity(1 + data.len().div_ceil(2));
        wire.push(f32::from_bits(data.len() as u32));
        for pair in data.chunks(2) {
            let lo = f32_to_f16_bits(pair[0]) as u32;
            let hi = if pair.len() > 1 {
                f32_to_f16_bits(pair[1]) as u32
            } else {
                0
            };
            wire.push(f32::from_bits(lo | (hi << 16)));
        }
        wire
    }

    fn decode(&self, wire: &[f32], len: usize) -> Vec<f32> {
        debug_assert_eq!(wire[0].to_bits() as usize, len,
                         "f16 wire header disagrees with dense len");
        let mut out = Vec::with_capacity(len);
        for slot in &wire[1..] {
            let bits = slot.to_bits();
            out.push(f16_bits_to_f32(bits as u16));
            if out.len() < len {
                out.push(f16_bits_to_f32((bits >> 16) as u16));
            }
        }
        out.truncate(len);
        debug_assert_eq!(out.len(), len);
        out
    }

    fn compresses_broadcast(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_round_trip_exactly() {
        // Values exactly representable in f16 must survive bitwise.
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0,
                  -65504.0, 0.25, 1.5, 6.1035156e-5, 5.9604645e-8] {
            let back = f16_bits_to_f32(f32_to_f16_bits(v));
            assert_eq!(back.to_bits(), v.to_bits(), "{v}");
        }
    }

    #[test]
    fn rounding_is_nearest_even_and_bounded() {
        // Relative error of one round-trip is bounded by 2⁻¹¹ for
        // normal-range values.
        let mut x = 1.0001f32;
        for _ in 0..2000 {
            let back = f16_bits_to_f32(f32_to_f16_bits(x));
            assert!(((back - x) / x).abs() <= 1.0 / 2048.0, "{x}");
            x *= 1.01;
            if x > 60000.0 {
                x = 1e-4;
            }
        }
        // Ties round to even mantissa: 1 + 2⁻¹¹ is exactly halfway
        // between 1.0 and the next f16; even mantissa wins.
        let tie = f32::from_bits(0x3f80_1000);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(tie)), 1.0);
        let tie_up = f32::from_bits(0x3f80_3000);
        assert_eq!(f32_to_f16_bits(tie_up), 0x3c02);
    }

    #[test]
    fn specials_survive() {
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xfc00);
        assert_eq!(f32_to_f16_bits(1e30), 0x7c00, "overflow to inf");
        assert_eq!(f32_to_f16_bits(1e-30), 0x0000, "underflow to 0");
        assert_eq!(f32_to_f16_bits(-1e-30), 0x8000);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        assert_eq!(f16_bits_to_f32(0x7c00), f32::INFINITY);
    }

    #[test]
    fn codec_packs_two_per_slot() {
        let codec = F16Codec;
        for n in [0usize, 1, 2, 3, 7, 8, 100] {
            let data: Vec<f32> =
                (0..n).map(|i| i as f32 * 0.25 - 3.0).collect();
            let wire = codec.encode(&data);
            assert_eq!(wire.len(), 1 + n.div_ceil(2), "n={n}");
            let back = codec.decode(&wire, n);
            // Quarter-steps near zero are exact in f16.
            assert_eq!(back, data, "n={n}");
        }
    }

    #[test]
    fn decode_of_encode_is_a_projection() {
        let codec = F16Codec;
        let data = vec![0.1f32, -2.7, 3.14159, 1e-6, 123.456];
        let once = codec.decode(&codec.encode(&data), data.len());
        let twice = codec.decode(&codec.encode(&once), once.len());
        assert_eq!(once, twice, "second pass must be lossless");
    }
}
