//! Multi-process orchestration: spawn one OS process per rank and
//! broker the localhost port exchange over stdio.
//!
//! The parent re-execs its own binary with the hidden `dist-worker`
//! subcommand, passing the run config through [`ENV_CFG`] and the
//! rank through [`ENV_RANK`]. Each child binds an ephemeral listener,
//! announces `port <p>` as its first stdout line, then blocks reading
//! one `peers <p0> <p1> ...` line on stdin. Once every child has
//! reported, the parent broadcasts the full port list and each child
//! runs [`connect_node`] concurrently — outbound TCP connects succeed
//! through the listen backlog, so the mesh wires up without any
//! accept-order coordination.
//!
//! Rank 0's remaining stdout (the loss lines) is streamed through to
//! the parent's stdout so `repro train ... transport=socket` reads
//! like the single-process run. A child that exits nonzero surfaces
//! as [`DistError::WorkerExited`] naming the rank.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener};
use std::process::{Child, Command, Stdio};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::super::comm::{CommStats, LinkModel, RingNode};
use super::super::error::DistError;
use super::{connect_node, SocketOptions};

/// Env var carrying the run-config JSON into worker processes.
pub const ENV_CFG: &str = "REPRO_DIST_CFG";
/// Env var carrying the worker's rank.
pub const ENV_RANK: &str = "REPRO_DIST_RANK";
/// Hidden subcommand the parent re-execs workers with.
pub const WORKER_SUBCOMMAND: &str = "dist-worker";

/// Child side of the handshake: bind, announce the port, read the
/// peer list, connect this rank's links. Returns the rank's ring node
/// plus its (process-local) byte ledger.
pub fn child_world(rank: usize, world: usize, link: LinkModel,
                   opts: &SocketOptions)
    -> Result<(RingNode, Arc<CommStats>)> {
    let listener = TcpListener::bind("127.0.0.1:0")
        .context("bind worker listener")?;
    let port = listener.local_addr().context("listener addr")?.port();
    {
        let mut out = std::io::stdout().lock();
        writeln!(out, "port {port}").context("announce port")?;
        out.flush().context("flush port line")?;
    }
    let mut line = String::new();
    std::io::stdin()
        .lock()
        .read_line(&mut line)
        .context("read peers line")?;
    let mut it = line.split_whitespace();
    if it.next() != Some("peers") {
        bail!("rank {rank}: malformed peers line {line:?}");
    }
    let addrs: Vec<SocketAddr> = it
        .map(|p| {
            let port: u16 = p.parse()
                .with_context(|| format!("bad peer port {p:?}"))?;
            Ok(SocketAddr::from(([127, 0, 0, 1], port)))
        })
        .collect::<Result<_>>()?;
    if addrs.len() != world {
        bail!("rank {rank}: got {} peers for world {world}",
              addrs.len());
    }
    let sl = connect_node(rank, world, &listener, &addrs, opts)?;
    let stats = Arc::new(CommStats::new(link));
    Ok((RingNode::from_socket(rank, world, sl, Arc::clone(&stats)),
        stats))
}

/// Parent side: spawn `world` children, broker the port exchange,
/// stream rank 0's stdout through, and wait for every child. The
/// first nonzero exit is a typed [`DistError::WorkerExited`].
pub fn run_parent(world: usize, cfg_json: &str) -> Result<()> {
    assert!(world >= 1, "world size must be >= 1");
    let exe = std::env::current_exe().context("locate own binary")?;
    let mut children: Vec<Child> = Vec::with_capacity(world);
    for rank in 0..world {
        let child = Command::new(&exe)
            .arg(WORKER_SUBCOMMAND)
            .env(ENV_CFG, cfg_json)
            .env(ENV_RANK, rank.to_string())
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .spawn()
            .with_context(|| format!("spawn worker rank {rank}"))?;
        children.push(child);
    }
    // Phase 1: every child announces its listener port.
    let mut ports = Vec::with_capacity(world);
    let mut stdouts = Vec::with_capacity(world);
    for (rank, child) in children.iter_mut().enumerate() {
        let mut out = BufReader::new(
            child.stdout.take().expect("piped stdout"));
        let mut line = String::new();
        out.read_line(&mut line)
            .with_context(|| format!("read port from rank {rank}"))?;
        let port = line
            .strip_prefix("port ")
            .and_then(|p| p.trim().parse::<u16>().ok())
            .ok_or_else(|| {
                DistError::Io {
                    rank,
                    msg: format!("bad port line {line:?}"),
                }
            })?;
        ports.push(port.to_string());
        stdouts.push(out);
    }
    // Phase 2: broadcast the full peer list; dropping each stdin
    // handle closes it (children read exactly one line).
    let peers = format!("peers {}\n", ports.join(" "));
    for (rank, child) in children.iter_mut().enumerate() {
        child
            .stdin
            .take()
            .expect("piped stdin")
            .write_all(peers.as_bytes())
            .with_context(|| format!("send peers to rank {rank}"))?;
    }
    // Phase 3: rank 0 owns the console; forward its output live.
    let mut out0 = stdouts.remove(0);
    std::io::copy(&mut out0, &mut std::io::stdout())
        .context("stream rank 0 output")?;
    drop(out0);
    for (rank, mut child) in children.into_iter().enumerate() {
        let status = child
            .wait()
            .with_context(|| format!("wait for rank {rank}"))?;
        if !status.success() {
            return Err(DistError::WorkerExited {
                rank,
                code: status.code().unwrap_or(-1),
            }
            .into());
        }
    }
    Ok(())
}
