//! Receiver side of the socket transport: the acceptor loop and the
//! per-connection reader threads.
//!
//! Each rank binds one listener. Outbound links identify themselves
//! with a hello frame (link kind + sender rank) right after
//! connecting, so the acceptor can accept connections in any order
//! and still wire each one to the right queue. Every inbound data
//! connection then gets a detached reader thread that:
//!
//! 1. reads frames forever (no timeout on the receive side),
//! 2. drops corrupt frames *without acking* (the sender's timeout
//!    turns that into a retransmission),
//! 3. dedupes by sequence number — exactly-once, in-order delivery:
//!    the expected seq is delivered then acked; an already-seen seq is
//!    re-acked and discarded (late duplicates from `dup`/`reorder`
//!    faults or premature retransmits),
//! 4. delivers payloads into an in-process mpsc queue drained by
//!    `RingNode::recv_left` / the root gather.
//!
//! Delivery happens *before* the ack: a consumer that died never acks,
//! so the failure propagates to the sender as a timeout/EOF instead of
//! being silently swallowed. A reader thread exits on EOF, read error,
//! or a closed delivery queue — dropping its queue sender, which the
//! application sees as [`DistError::PeerDisconnected`] naming the
//! peer.
//!
//! [`DistError::PeerDisconnected`]: crate::dist::DistError::PeerDisconnected

use std::io::{self, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};

use super::framer::{
    read_frame, write_frame, Frame, Inbound, KIND_DATA, KIND_HELLO,
};

/// Link kinds carried in hello frames.
pub(crate) const LINK_RING: u8 = 0;
pub(crate) const LINK_GATHER: u8 = 1;

/// Identify an outbound connection to the accepting rank.
pub(crate) fn send_hello(stream: &mut TcpStream, link_kind: u8,
                         from_rank: usize) -> io::Result<()> {
    write_frame(stream, &Frame::hello(link_kind, from_rank))?;
    stream.flush()
}

/// Read the identifying hello off a fresh inbound connection.
pub(crate) fn read_hello(stream: &mut TcpStream)
    -> io::Result<(u8, usize)> {
    match read_frame(stream)? {
        Inbound::Frame(f) if f.kind == KIND_HELLO => {
            Ok((f.class, f.seq as usize))
        }
        other => Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("expected hello frame, got {other:?}"),
        )),
    }
}

/// The verify → dedupe → deliver → ack loop shared by both link
/// kinds. `deliver` returns false when the consumer is gone.
fn reader_loop(mut stream: TcpStream,
               mut deliver: impl FnMut(Vec<f32>) -> bool) {
    if stream.set_read_timeout(None).is_err()
        || stream.set_nodelay(true).is_err()
    {
        return;
    }
    let mut expected: u64 = 0;
    loop {
        match read_frame(&mut stream) {
            Ok(Inbound::Frame(f)) if f.kind == KIND_DATA => {
                if f.seq == expected {
                    let ack = Frame::ack(f.class, f.seq);
                    if !deliver(f.payload)
                        || write_frame(&mut stream, &ack).is_err()
                    {
                        return;
                    }
                    expected += 1;
                } else if f.seq < expected {
                    // Duplicate of a delivered frame: re-ack only.
                    let ack = Frame::ack(f.class, f.seq);
                    if write_frame(&mut stream, &ack).is_err() {
                        return;
                    }
                }
                // f.seq > expected cannot happen under stop-and-wait;
                // drop it and let the sender retransmit in order.
            }
            // Stray acks/hellos are noise on a receive link.
            Ok(Inbound::Frame(_)) => {}
            // Corrupt: consume, do NOT ack — sender will retransmit.
            Ok(Inbound::Corrupt { .. }) => {}
            Ok(Inbound::Eof) | Err(_) => return,
        }
    }
}

/// Spawn the detached reader for one inbound data connection.
fn spawn_reader(stream: TcpStream, tx: Sender<Vec<f32>>) {
    std::thread::spawn(move || {
        reader_loop(stream, move |payload| tx.send(payload).is_ok());
    });
}

/// Inbound queues for one rank, produced by the acceptor loop.
pub(crate) struct InboundLinks {
    /// Payloads from the left ring neighbour.
    pub left_rx: Option<Receiver<Vec<f32>>>,
    /// Per-sender gather queues at rank 0 (index r-1 ↔ rank r). One
    /// queue per rank, not one shared queue: a dead worker closes its
    /// own queue, so the root can name exactly which rank is gone.
    pub gather_rx: Vec<Receiver<Vec<f32>>>,
}

/// Accept this rank's expected inbound connections (one ring link,
/// plus `world - 1` gather links at rank 0), classify each by its
/// hello, and spawn its reader thread.
pub(crate) fn accept_inbound(listener: &TcpListener, rank: usize,
                             world: usize) -> io::Result<InboundLinks> {
    let ring_expected = usize::from(world > 1);
    let gather_expected = if rank == 0 { world - 1 } else { 0 };
    let (ring_tx, ring_rx) = channel();
    let mut gather_txs: Vec<Option<Sender<Vec<f32>>>> =
        Vec::with_capacity(gather_expected);
    let mut gather_rxs: Vec<Receiver<Vec<f32>>> =
        Vec::with_capacity(gather_expected);
    for _ in 0..gather_expected {
        let (tx, rx) = channel();
        gather_txs.push(Some(tx));
        gather_rxs.push(rx);
    }
    let mut ring_seen = 0usize;
    let mut gather_seen = 0usize;
    while ring_seen < ring_expected || gather_seen < gather_expected {
        let (mut stream, _) = listener.accept()?;
        let (kind, from) = read_hello(&mut stream)?;
        match kind {
            LINK_RING if ring_seen < ring_expected
                && from == (rank + world - 1) % world => {
                ring_seen += 1;
                spawn_reader(stream, ring_tx.clone());
            }
            LINK_GATHER if from >= 1
                && from < world
                && gather_txs
                    .get(from - 1)
                    .is_some_and(Option::is_some) => {
                gather_seen += 1;
                let tx = gather_txs[from - 1].take().unwrap();
                spawn_reader(stream, tx);
            }
            _ => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("rank {rank}: unexpected link kind {kind} \
                             from rank {from}"),
                ))
            }
        }
    }
    // The acceptor's own ring clone must die here, or a dead peer's
    // queue would never close.
    drop(ring_tx);
    Ok(InboundLinks {
        left_rx: (ring_expected > 0).then_some(ring_rx),
        gather_rx: gather_rxs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    fn pair() -> (TcpStream, TcpStream) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let w = TcpStream::connect(addr).unwrap();
        let (r, _) = l.accept().unwrap();
        (w, r)
    }

    #[test]
    fn hello_identifies_the_link() {
        let (mut w, mut r) = pair();
        send_hello(&mut w, LINK_GATHER, 3).unwrap();
        assert_eq!(read_hello(&mut r).unwrap(), (LINK_GATHER, 3));
    }

    #[test]
    fn reader_delivers_in_order_acks_and_dedupes() {
        let (mut w, r) = pair();
        let (tx, rx) = channel();
        spawn_reader(r, tx);
        // In-order frames deliver and ack.
        write_frame(&mut w, &Frame::data(0, 0, &[1.0])).unwrap();
        write_frame(&mut w, &Frame::data(0, 1, &[2.0])).unwrap();
        // Duplicate of seq 0: re-acked, not redelivered.
        write_frame(&mut w, &Frame::data(0, 0, &[1.0])).unwrap();
        write_frame(&mut w, &Frame::data(0, 2, &[3.0])).unwrap();
        assert_eq!(rx.recv().unwrap(), vec![1.0]);
        assert_eq!(rx.recv().unwrap(), vec![2.0]);
        assert_eq!(rx.recv().unwrap(), vec![3.0]);
        // Four acks came back: seqs 0, 1, 0 (dup), 2.
        let mut acks = Vec::new();
        for _ in 0..4 {
            match read_frame(&mut w).unwrap() {
                Inbound::Frame(f) => {
                    assert_eq!(f.kind, super::super::framer::KIND_ACK);
                    acks.push(f.seq);
                }
                other => panic!("expected ack, got {other:?}"),
            }
        }
        assert_eq!(acks, vec![0, 1, 0, 2]);
    }

    #[test]
    fn corrupt_frame_is_not_acked_or_delivered() {
        let (mut w, r) = pair();
        let (tx, rx) = channel();
        spawn_reader(r, tx);
        let mut bytes = Frame::data(0, 0, &[5.0, 6.0]).encode();
        let n = bytes.len();
        bytes[n - 1] ^= 0xff;
        w.write_all(&bytes).unwrap();
        // Resend clean: delivered once, acked once.
        write_frame(&mut w, &Frame::data(0, 0, &[5.0, 6.0])).unwrap();
        assert_eq!(rx.recv().unwrap(), vec![5.0, 6.0]);
        match read_frame(&mut w).unwrap() {
            Inbound::Frame(f) => assert_eq!(f.seq, 0),
            other => panic!("expected ack, got {other:?}"),
        }
        assert!(rx.try_recv().is_err());
    }

    #[test]
    fn dead_sender_closes_the_queue() {
        let (w, r) = pair();
        let (tx, rx) = channel();
        spawn_reader(r, tx);
        drop(w);
        assert!(rx.recv().is_err());
    }
}
