//! Ack-timeout policy for the retry middleware: exponential backoff
//! with a cap and a bounded attempt budget.

use std::time::Duration;

/// How long to wait for an ack on each attempt, and how many attempts
/// a send gets before it becomes a [`DistError::Timeout`].
///
/// [`DistError::Timeout`]: crate::dist::DistError::Timeout
#[derive(Debug, Clone, PartialEq)]
pub struct TimeoutPolicy {
    /// First attempt's ack wait, in milliseconds.
    pub base_ms: u64,
    /// Backoff multiplier between attempts.
    pub factor: f64,
    /// Ceiling on any single wait, in milliseconds.
    pub cap_ms: u64,
    /// Total send attempts (first try + retries).
    pub max_attempts: usize,
}

impl Default for TimeoutPolicy {
    fn default() -> Self {
        TimeoutPolicy {
            base_ms: 50,
            factor: 2.0,
            cap_ms: 1_000,
            max_attempts: 10,
        }
    }
}

impl TimeoutPolicy {
    /// A patient policy for fault-free links where any retry would be
    /// a bug (tests assert zero retries under it).
    pub fn patient() -> Self {
        TimeoutPolicy { base_ms: 2_000, ..TimeoutPolicy::default() }
    }

    /// A twitchy policy for fault-injection tests: short waits keep
    /// retransmission cheap while the dedupe keeps it correct.
    pub fn twitchy() -> Self {
        TimeoutPolicy {
            base_ms: 4,
            factor: 1.5,
            cap_ms: 200,
            max_attempts: 12,
        }
    }

    /// Ack wait for `attempt` (0-based): `base * factor^attempt`,
    /// capped.
    pub fn wait_for(&self, attempt: usize) -> Duration {
        let scaled = self.base_ms as f64
            * self.factor.powi(attempt.min(30) as i32);
        Duration::from_millis(
            (scaled as u64).clamp(1, self.cap_ms.max(1)),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_then_caps() {
        let p = TimeoutPolicy {
            base_ms: 10,
            factor: 2.0,
            cap_ms: 65,
            max_attempts: 8,
        };
        assert_eq!(p.wait_for(0), Duration::from_millis(10));
        assert_eq!(p.wait_for(1), Duration::from_millis(20));
        assert_eq!(p.wait_for(2), Duration::from_millis(40));
        assert_eq!(p.wait_for(3), Duration::from_millis(65));
        assert_eq!(p.wait_for(20), Duration::from_millis(65));
    }

    #[test]
    fn waits_are_never_zero() {
        let p = TimeoutPolicy {
            base_ms: 0,
            factor: 2.0,
            cap_ms: 100,
            max_attempts: 2,
        };
        assert!(p.wait_for(0) >= Duration::from_millis(1));
    }
}
