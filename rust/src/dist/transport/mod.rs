//! Socket transport for the dist engine: a real multi-process-capable
//! wire behind the same [`RingNode`] interface as the in-process
//! channel transport.
//!
//! Layering, bottom to top:
//!
//! - [`framer`] — length-framed binary codec (kind, class, seq, len,
//!   FNV-1a checksum, f32 LE payload).
//! - [`fault`] — deterministic seeded fault shim on the sender side
//!   (drop / duplicate / reorder / corrupt, per traffic class).
//! - [`timeouter`] — ack-timeout policy: exponential backoff, capped,
//!   bounded attempts.
//! - [`retryer`] — stop-and-wait ARQ sender ([`ReliableTx`]): write
//!   through the fault shim, await ack, retransmit on timeout.
//! - [`acceptor`] — listener + hello handshake + per-connection
//!   reader threads (verify, dedupe by seq, ack, deliver).
//! - [`proc`] — the OS-process driver behind
//!   `repro train transport=socket`.
//!
//! The transport guarantees exactly-once in-order delivery of the
//! exact payload bits: a frame is delivered only when its checksum
//! verifies and its seq is next expected, so injected faults can cost
//! retransmissions (accounted under [`TrafficClass::Retry`]) but can
//! never change what the collectives compute. That is the mechanism
//! behind the fault-matrix tests asserting bit-exact loss
//! trajectories against the channel transport.
//!
//! [`ReliableTx`]: retryer::ReliableTx

pub mod acceptor;
pub mod fault;
pub mod framer;
pub mod proc;
pub mod retryer;
pub mod timeouter;

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::Receiver;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

pub use fault::{FaultInjector, FaultSpec};
pub use timeouter::TimeoutPolicy;

use super::comm::{CommStats, LinkModel, RingNode, TrafficClass};
use super::error::DistError;
use acceptor::{accept_inbound, send_hello, LINK_GATHER, LINK_RING};
use retryer::ReliableTx;

/// Which wire a dist world runs over.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum TransportKind {
    /// In-process mpsc channels (the seed transport).
    #[default]
    Channel,
    /// Framed TCP over localhost with retry/timeout middleware.
    Socket(SocketOptions),
}

/// Socket transport knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct SocketOptions {
    pub faults: FaultSpec,
    /// Seed for the per-link fault injectors.
    pub seed: u64,
    pub policy: TimeoutPolicy,
}

impl Default for SocketOptions {
    fn default() -> Self {
        SocketOptions {
            faults: FaultSpec::default(),
            seed: 0,
            // Patient by default: on a fault-free localhost link any
            // retry would be a bug, not recovery.
            policy: TimeoutPolicy::patient(),
        }
    }
}

/// Resolve the `transport=` / `fault=` / `fault_seed=` config keys
/// into a [`TransportKind`] for the in-process trainer. The
/// multi-process `transport=socket` path is dispatched earlier, in
/// `main.rs`; reaching here with it means the model needs artifacts
/// and cannot span processes.
pub fn parse_transport(transport: &str, fault: &str, fault_seed: u64)
    -> Result<TransportKind> {
    match transport {
        "channel" => {
            if !fault.trim().is_empty() {
                bail!("fault injection needs a socket transport \
                       (transport=tcp or transport=socket)");
            }
            Ok(TransportKind::Channel)
        }
        "tcp" => Ok(TransportKind::Socket(socket_options(
            fault, fault_seed)?)),
        "socket" => bail!(
            "transport=socket spans OS processes and requires \
             model=bigram (artifact models cannot re-exec); use \
             transport=tcp for in-process workers over localhost TCP"
        ),
        other => bail!(
            "unknown transport {other:?} (channel | tcp | socket)"
        ),
    }
}

/// Resolve `fault=` / `fault_seed=` into socket knobs: a noop spec
/// keeps the patient policy (a retry on a clean localhost link is a
/// bug); injected faults switch to the twitchy policy so recovery is
/// fast enough to test.
pub fn socket_options(fault: &str, fault_seed: u64)
    -> Result<SocketOptions> {
    let faults = FaultSpec::parse(fault)?;
    let policy = if faults.is_noop() {
        TimeoutPolicy::patient()
    } else {
        TimeoutPolicy::twitchy()
    };
    Ok(SocketOptions { faults, seed: fault_seed, policy })
}

/// Independent fault-injector stream per directed link.
fn link_seed(base: u64, from: usize, to: usize, kind: u8) -> u64 {
    base ^ ((from as u64) << 32)
        ^ ((to as u64) << 16)
        ^ ((kind as u64) << 8)
        ^ 0x5eed
}

/// One rank's socket endpoints (lives inside [`RingNode`]).
pub struct SocketLink {
    rank: usize,
    world: usize,
    right: Option<ReliableTx>,
    left_rx: Option<Receiver<Vec<f32>>>,
    to_root: Option<ReliableTx>,
    /// Rank 0 only: per-sender gather queues (index r-1 ↔ rank r).
    gather_rx: Vec<Receiver<Vec<f32>>>,
}

impl SocketLink {
    pub(crate) fn send_right(&mut self, class: TrafficClass,
                             data: &[f32], stats: &CommStats)
        -> Result<(), DistError> {
        let rank = self.rank;
        match &mut self.right {
            Some(tx) => tx.send(class, data, stats),
            None => Err(DistError::CommHangup { rank }),
        }
    }

    pub(crate) fn recv_left(&mut self) -> Result<Vec<f32>, DistError> {
        let (rank, peer) =
            (self.rank, (self.rank + self.world - 1) % self.world);
        match &self.left_rx {
            Some(rx) => rx
                .recv()
                .map_err(|_| DistError::PeerDisconnected { rank, peer }),
            None => Err(DistError::CommHangup { rank }),
        }
    }

    pub(crate) fn gather_to_root(&mut self, class: TrafficClass,
                                 payload: Vec<f32>, stats: &CommStats)
        -> Result<Option<Vec<Vec<f32>>>, DistError> {
        let rank = self.rank;
        if rank != 0 {
            let tx = self
                .to_root
                .as_mut()
                .ok_or(DistError::CommHangup { rank })?;
            tx.send(class, &payload, stats)?;
            return Ok(None);
        }
        let mut out: Vec<Vec<f32>> = vec![Vec::new(); self.world];
        out[0] = payload;
        for peer in 1..self.world {
            out[peer] =
                self.gather_rx[peer - 1].recv().map_err(|_| {
                    DistError::PeerDisconnected { rank, peer }
                })?;
        }
        Ok(Some(out))
    }
}

fn io_dist(rank: usize, e: std::io::Error) -> DistError {
    DistError::Io { rank, msg: e.to_string() }
}

/// Build one rank's [`SocketLink`]: connect outbound links (right
/// ring neighbour, plus the rank-0 gather link), then accept and wire
/// this rank's inbound connections. Outbound connects never block on
/// the peer's accept loop (TCP backlog), so all ranks can run this
/// concurrently — in threads or in separate processes — without a
/// handshake deadlock.
pub(crate) fn connect_node(rank: usize, world: usize,
                           listener: &TcpListener, addrs: &[SocketAddr],
                           opts: &SocketOptions)
    -> Result<SocketLink, DistError> {
    let err = |e| io_dist(rank, e);
    let mut right = None;
    let mut to_root = None;
    if world > 1 {
        let peer = (rank + 1) % world;
        let mut stream =
            TcpStream::connect(addrs[peer]).map_err(err)?;
        send_hello(&mut stream, LINK_RING, rank).map_err(err)?;
        right = Some(
            ReliableTx::new(
                stream,
                rank,
                peer,
                FaultInjector::new(
                    opts.faults.clone(),
                    link_seed(opts.seed, rank, peer, LINK_RING),
                ),
                opts.policy.clone(),
            )
            .map_err(err)?,
        );
        if rank != 0 {
            let mut stream =
                TcpStream::connect(addrs[0]).map_err(err)?;
            send_hello(&mut stream, LINK_GATHER, rank).map_err(err)?;
            to_root = Some(
                ReliableTx::new(
                    stream,
                    rank,
                    0,
                    FaultInjector::new(
                        opts.faults.clone(),
                        link_seed(opts.seed, rank, 0, LINK_GATHER),
                    ),
                    opts.policy.clone(),
                )
                .map_err(err)?,
            );
        }
    }
    let inbound =
        accept_inbound(listener, rank, world).map_err(err)?;
    Ok(SocketLink {
        rank,
        world,
        right,
        left_rx: inbound.left_rx,
        to_root,
        gather_rx: inbound.gather_rx,
    })
}

/// Build an N-worker world over localhost TCP — same shape as
/// `comm::ring_world`, workers still in-process, but every payload
/// crosses the full framed/retried socket stack.
pub fn socket_ring_world(world: usize, link: LinkModel,
                         opts: &SocketOptions)
    -> Result<(Vec<RingNode>, Arc<CommStats>)> {
    assert!(world >= 1, "world size must be >= 1");
    let stats = Arc::new(CommStats::new(link));
    let mut listeners = Vec::with_capacity(world);
    let mut addrs = Vec::with_capacity(world);
    for _ in 0..world {
        let l = TcpListener::bind("127.0.0.1:0")
            .context("bind transport listener")?;
        addrs.push(l.local_addr().context("listener addr")?);
        listeners.push(l);
    }
    let links: Vec<Result<SocketLink, DistError>> =
        std::thread::scope(|s| {
            let handles: Vec<_> = listeners
                .iter()
                .enumerate()
                .map(|(rank, listener)| {
                    let addrs = &addrs;
                    s.spawn(move || {
                        connect_node(rank, world, listener, addrs, opts)
                    })
                })
                .collect();
            handles
                .into_iter()
                .enumerate()
                .map(|(rank, h)| {
                    h.join().unwrap_or(Err(
                        DistError::WorkerPanicked { rank },
                    ))
                })
                .collect()
        });
    let mut nodes = Vec::with_capacity(world);
    for (rank, link) in links.into_iter().enumerate() {
        let link = link
            .with_context(|| format!("connect rank {rank}"))?;
        nodes.push(RingNode::from_socket(
            rank,
            world,
            link,
            Arc::clone(&stats),
        ));
    }
    Ok((nodes, stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spin_ring(nodes: Vec<RingNode>, payload_len: usize)
        -> Vec<Result<Vec<f32>, DistError>> {
        std::thread::scope(|s| {
            let handles: Vec<_> = nodes
                .into_iter()
                .map(|mut node| {
                    s.spawn(move || {
                        let data: Vec<f32> = (0..payload_len)
                            .map(|i| {
                                (node.rank * 1000 + i) as f32 * 1.5
                            })
                            .collect();
                        node.send_right(
                            TrafficClass::GradReduce,
                            data,
                        )?;
                        node.recv_left()
                    })
                })
                .collect();
            handles
                .into_iter()
                .enumerate()
                .map(|(rank, h)| {
                    h.join().unwrap_or(Err(
                        DistError::WorkerPanicked { rank },
                    ))
                })
                .collect()
        })
    }

    #[test]
    fn socket_ring_matches_channel_ledger_with_zero_retries() {
        let world = 3;
        let (sock_nodes, sock_stats) = socket_ring_world(
            world,
            LinkModel::default(),
            &SocketOptions::default(),
        )
        .unwrap();
        let got = spin_ring(sock_nodes, 8);
        for (rank, r) in got.iter().enumerate() {
            let left = (rank + world - 1) % world;
            let want: Vec<f32> = (0..8)
                .map(|i| (left * 1000 + i) as f32 * 1.5)
                .collect();
            assert_eq!(r.as_ref().unwrap(), &want, "rank {rank}");
        }
        let (chan_nodes, chan_stats) =
            super::super::comm::ring_world(world, LinkModel::default());
        for r in spin_ring(chan_nodes, 8) {
            r.unwrap();
        }
        for class in TrafficClass::ALL {
            assert_eq!(
                sock_stats.bytes(class),
                chan_stats.bytes(class),
                "{} ledger must match the channel transport",
                class.name()
            );
        }
        assert_eq!(sock_stats.bytes(TrafficClass::Retry), 0);
    }

    #[test]
    fn faulty_ring_still_delivers_exact_bits_and_accounts_retries() {
        let world = 3;
        let opts = SocketOptions {
            faults: FaultSpec::parse(
                "drop:0.2,dup:0.1,corrupt:0.15,reorder:0.1",
            )
            .unwrap(),
            seed: 42,
            policy: TimeoutPolicy::twitchy(),
        };
        let (nodes, stats) =
            socket_ring_world(world, LinkModel::default(), &opts)
                .unwrap();
        let results: Vec<_> = std::thread::scope(|s| {
            let handles: Vec<_> = nodes
                .into_iter()
                .map(|mut node| {
                    s.spawn(move || -> Result<(), DistError> {
                        for round in 0..20u32 {
                            let data: Vec<f32> = (0..16)
                                .map(|i| {
                                    f32::from_bits(
                                        0x3f80_0000
                                            + node.rank as u32 * 977
                                            + round * 31
                                            + i,
                                    )
                                })
                                .collect();
                            node.send_right(
                                TrafficClass::GradScatter,
                                data,
                            )?;
                            let got = node.recv_left()?;
                            let left = (node.rank + node.world - 1)
                                % node.world;
                            let want: Vec<u32> = (0..16)
                                .map(|i| {
                                    0x3f80_0000
                                        + left as u32 * 977
                                        + round * 31
                                        + i
                                })
                                .collect();
                            let bits: Vec<u32> = got
                                .iter()
                                .map(|x| x.to_bits())
                                .collect();
                            assert_eq!(bits, want);
                        }
                        Ok(())
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for r in results {
            r.unwrap();
        }
        // Base ledger is fault-independent; retries are visible and
        // bounded by the attempt budget.
        let data_msgs = stats.messages(TrafficClass::GradScatter);
        assert_eq!(stats.bytes(TrafficClass::GradScatter),
                   world as u64 * 20 * 16 * 4);
        let retries = stats.messages(TrafficClass::Retry);
        assert!(retries > 0, "fault rates this high must retry");
        assert!(
            retries
                < data_msgs
                    * TimeoutPolicy::twitchy().max_attempts as u64,
            "retries must stay within the attempt budget"
        );
    }

    #[test]
    fn killed_peer_yields_typed_errors_naming_it() {
        let world = 3;
        let (mut nodes, _stats) = socket_ring_world(
            world,
            LinkModel::default(),
            &SocketOptions {
                policy: TimeoutPolicy {
                    base_ms: 20,
                    factor: 2.0,
                    cap_ms: 100,
                    max_attempts: 4,
                },
                ..SocketOptions::default()
            },
        )
        .unwrap();
        // Rank 1 dies before the step.
        let dead = nodes.remove(1);
        drop(dead);
        let results: Vec<_> = std::thread::scope(|s| {
            let handles: Vec<_> = nodes
                .into_iter()
                .map(|mut node| {
                    s.spawn(move || -> Result<(), DistError> {
                        node.send_right(
                            TrafficClass::GradReduce,
                            vec![1.0; 4],
                        )?;
                        node.recv_left()?;
                        Ok(())
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let errs: Vec<DistError> =
            results.into_iter().filter_map(Result::err).collect();
        assert!(!errs.is_empty(), "a dead rank must surface an error");
        assert!(
            errs.iter().any(|e| matches!(
                e,
                DistError::PeerDisconnected { peer: 1, .. }
                    | DistError::Timeout { peer: 1, .. }
            )),
            "some error must name the dead rank 1: {errs:?}"
        );
    }
}
