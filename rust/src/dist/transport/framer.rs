//! Length-framed binary codec for the socket transport.
//!
//! Every message on the wire is one frame:
//!
//! ```text
//! offset  size  field
//!      0     1  kind        (0 = data, 1 = ack, 2 = hello)
//!      1     1  class       traffic-class index (hello: link kind)
//!      2     8  seq         u64 LE (hello: sender rank)
//!     10     4  len         u32 LE, payload length in f32 elements
//!     14     4  checksum    u32 LE, FNV-1a over the payload bytes
//!     18   4*len payload    f32 LE elements
//! ```
//!
//! The header is never fault-injected (the injector flips payload
//! bytes only — see `fault.rs`), so a reader can always consume a
//! whole frame and the stream never desynchronizes; a payload flip
//! shows up as a checksum mismatch and the frame is dropped without
//! an ack, which the retry middleware turns into a retransmission.

use std::io::{self, Read, Write};

pub const KIND_DATA: u8 = 0;
pub const KIND_ACK: u8 = 1;
pub const KIND_HELLO: u8 = 2;

/// Fixed frame-header length in bytes.
pub const HEADER_LEN: usize = 18;

/// Upper bound on a frame's payload (elements); a longer length field
/// means the stream is corrupt beyond recovery.
const MAX_PAYLOAD_ELEMS: usize = 1 << 28;

/// FNV-1a over a byte slice (32-bit).
pub fn fnv1a(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// One decoded frame.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub kind: u8,
    pub class: u8,
    pub seq: u64,
    pub payload: Vec<f32>,
}

impl Frame {
    pub fn data(class: u8, seq: u64, payload: &[f32]) -> Frame {
        Frame { kind: KIND_DATA, class, seq, payload: payload.to_vec() }
    }

    pub fn ack(class: u8, seq: u64) -> Frame {
        Frame { kind: KIND_ACK, class, seq, payload: Vec::new() }
    }

    /// `class` carries the link kind, `seq` the sender's rank.
    pub fn hello(link_kind: u8, rank: usize) -> Frame {
        Frame {
            kind: KIND_HELLO,
            class: link_kind,
            seq: rank as u64,
            payload: Vec::new(),
        }
    }

    /// Serialize to wire bytes (header + payload).
    pub fn encode(&self) -> Vec<u8> {
        let mut out =
            Vec::with_capacity(HEADER_LEN + 4 * self.payload.len());
        out.push(self.kind);
        out.push(self.class);
        out.extend_from_slice(&self.seq.to_le_bytes());
        out.extend_from_slice(
            &(self.payload.len() as u32).to_le_bytes(),
        );
        let mut body = Vec::with_capacity(4 * self.payload.len());
        for x in &self.payload {
            body.extend_from_slice(&x.to_le_bytes());
        }
        out.extend_from_slice(&fnv1a(&body).to_le_bytes());
        out.extend_from_slice(&body);
        out
    }
}

/// What a read produced: a verified frame, a checksum failure (frame
/// consumed but payload untrusted), or a cleanly closed stream.
#[derive(Debug, PartialEq)]
pub enum Inbound {
    Frame(Frame),
    Corrupt { seq: u64 },
    Eof,
}

/// Read one frame. Timeouts and hard I/O failures propagate as
/// `io::Error`; an EOF at a frame boundary is `Inbound::Eof`.
pub fn read_frame(r: &mut impl Read) -> io::Result<Inbound> {
    let mut header = [0u8; HEADER_LEN];
    if let Err(e) = r.read_exact(&mut header) {
        if e.kind() == io::ErrorKind::UnexpectedEof {
            return Ok(Inbound::Eof);
        }
        return Err(e);
    }
    let kind = header[0];
    let class = header[1];
    let seq = u64::from_le_bytes(header[2..10].try_into().unwrap());
    let len =
        u32::from_le_bytes(header[10..14].try_into().unwrap()) as usize;
    let checksum =
        u32::from_le_bytes(header[14..18].try_into().unwrap());
    if len > MAX_PAYLOAD_ELEMS {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds cap"),
        ));
    }
    let mut body = vec![0u8; 4 * len];
    if let Err(e) = r.read_exact(&mut body) {
        if e.kind() == io::ErrorKind::UnexpectedEof {
            return Ok(Inbound::Eof);
        }
        return Err(e);
    }
    if fnv1a(&body) != checksum {
        return Ok(Inbound::Corrupt { seq });
    }
    let payload = body
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok(Inbound::Frame(Frame { kind, class, seq, payload }))
}

/// Write one encoded frame.
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> io::Result<()> {
    w.write_all(&frame.encode())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn data_frame_roundtrips_bit_exactly() {
        let payload =
            vec![1.5f32, -0.0, f32::MIN_POSITIVE, 3.0e8, -7.25];
        let frame = Frame::data(2, 41, &payload);
        let mut cur = Cursor::new(frame.encode());
        match read_frame(&mut cur).unwrap() {
            Inbound::Frame(f) => {
                assert_eq!(f, frame);
                let bits: Vec<u32> =
                    f.payload.iter().map(|x| x.to_bits()).collect();
                let want: Vec<u32> =
                    payload.iter().map(|x| x.to_bits()).collect();
                assert_eq!(bits, want);
            }
            other => panic!("expected frame, got {other:?}"),
        }
    }

    #[test]
    fn ack_and_hello_roundtrip() {
        for frame in [Frame::ack(1, 9), Frame::hello(0, 3)] {
            let mut cur = Cursor::new(frame.encode());
            assert_eq!(
                read_frame(&mut cur).unwrap(),
                Inbound::Frame(frame)
            );
        }
    }

    #[test]
    fn corrupted_payload_is_detected_not_delivered() {
        let frame = Frame::data(0, 7, &[1.0, 2.0, 3.0]);
        let mut bytes = frame.encode();
        bytes[HEADER_LEN + 5] ^= 0x40;
        let mut cur = Cursor::new(bytes);
        assert_eq!(
            read_frame(&mut cur).unwrap(),
            Inbound::Corrupt { seq: 7 }
        );
    }

    #[test]
    fn eof_at_frame_boundary_is_clean() {
        let mut cur = Cursor::new(Vec::<u8>::new());
        assert_eq!(read_frame(&mut cur).unwrap(), Inbound::Eof);
    }

    #[test]
    fn back_to_back_frames_stay_in_sync() {
        let a = Frame::data(0, 0, &[1.0]);
        let b = Frame::ack(0, 0);
        let c = Frame::data(3, 1, &[2.0, 4.0]);
        let mut bytes = a.encode();
        bytes.extend(b.encode());
        bytes.extend(c.encode());
        let mut cur = Cursor::new(bytes);
        assert_eq!(read_frame(&mut cur).unwrap(), Inbound::Frame(a));
        assert_eq!(read_frame(&mut cur).unwrap(), Inbound::Frame(b));
        assert_eq!(read_frame(&mut cur).unwrap(), Inbound::Frame(c));
        assert_eq!(read_frame(&mut cur).unwrap(), Inbound::Eof);
    }
}
