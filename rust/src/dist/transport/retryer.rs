//! Reliable sender middleware: stop-and-wait ARQ over one framed TCP
//! stream.
//!
//! Each [`ReliableTx`] owns the sending end of one directed link. A
//! send writes a data frame (through the fault shim), then blocks
//! reading acks with a per-attempt timeout from the
//! [`TimeoutPolicy`]; no ack in time means retransmit with backoff.
//! The receiver (`acceptor.rs`) acks every verified in-order frame
//! immediately on a dedicated reader thread, so ring schedules where
//! every rank is inside `send_right` at once cannot deadlock — acks
//! never wait on the application calling `recv_left`.
//!
//! Accounting contract: the caller (`RingNode::send_right`) records
//! the base payload once under its traffic class, identically to the
//! channel transport, so the base ledgers stay byte-exact across
//! transports. Every attempt after the first records the payload
//! again under [`TrafficClass::Retry`] and publishes an
//! [`Event::RetrySent`]; exhausting the budget publishes
//! [`Event::CommTimeout`] and returns [`DistError::Timeout`].

use std::io::{self, ErrorKind};
use std::net::TcpStream;

use super::fault::FaultInjector;
use super::framer::{read_frame, Frame, Inbound, KIND_ACK};
use super::timeouter::TimeoutPolicy;
use crate::dist::comm::{CommStats, TrafficClass};
use crate::dist::error::DistError;
use crate::telemetry::Event;

enum AckWait {
    Acked,
    Timeout,
    Disconnected,
}

/// The sending half of one directed link, with retry middleware.
pub(crate) struct ReliableTx {
    stream: TcpStream,
    rank: usize,
    peer: usize,
    seq: u64,
    fault: FaultInjector,
    policy: TimeoutPolicy,
}

impl ReliableTx {
    pub fn new(stream: TcpStream, rank: usize, peer: usize,
               fault: FaultInjector, policy: TimeoutPolicy)
        -> io::Result<ReliableTx> {
        stream.set_nodelay(true)?;
        Ok(ReliableTx { stream, rank, peer, seq: 0, fault, policy })
    }

    fn io_err(&self, e: io::Error) -> DistError {
        match e.kind() {
            ErrorKind::BrokenPipe
            | ErrorKind::ConnectionReset
            | ErrorKind::ConnectionAborted
            | ErrorKind::UnexpectedEof => DistError::PeerDisconnected {
                rank: self.rank,
                peer: self.peer,
            },
            _ => DistError::Io { rank: self.rank, msg: e.to_string() },
        }
    }

    /// Reliably deliver one payload. Retransmitted payload bytes are
    /// accounted under [`TrafficClass::Retry`] on `stats`.
    pub fn send(&mut self, class: TrafficClass, data: &[f32],
                stats: &CommStats) -> Result<(), DistError> {
        let seq = self.seq;
        self.seq += 1;
        let wire = Frame::data(class_idx(class), seq, data).encode();
        let payload_bytes = (data.len() * 4) as u64;
        for attempt in 0..self.policy.max_attempts {
            if attempt > 0 {
                stats.record_from(self.rank, TrafficClass::Retry,
                                  payload_bytes);
                stats.publish(Event::RetrySent {
                    rank: self.rank,
                    peer: self.peer,
                    class: class.name(),
                    seq,
                    attempt: attempt as u64,
                    bytes: payload_bytes,
                });
            }
            self.fault
                .write_data(&mut self.stream, &wire, class.name())
                .map_err(|e| self.io_err(e))?;
            self.stream
                .set_read_timeout(Some(self.policy.wait_for(attempt)))
                .map_err(|e| self.io_err(e))?;
            match self.wait_ack(seq) {
                AckWait::Acked => return Ok(()),
                AckWait::Timeout => continue,
                AckWait::Disconnected => {
                    return Err(DistError::PeerDisconnected {
                        rank: self.rank,
                        peer: self.peer,
                    })
                }
            }
        }
        stats.publish(Event::CommTimeout {
            rank: self.rank,
            peer: self.peer,
            class: class.name(),
            seq,
            attempts: self.policy.max_attempts as u64,
        });
        Err(DistError::Timeout {
            rank: self.rank,
            peer: self.peer,
            class: class.name(),
            attempts: self.policy.max_attempts,
        })
    }

    /// Read acks until one covers `seq`. Stale acks (late duplicates
    /// of earlier seqs) are skipped without consuming the timeout
    /// budget conceptually — each read re-arms the same deadline.
    fn wait_ack(&mut self, seq: u64) -> AckWait {
        loop {
            match read_frame(&mut self.stream) {
                Ok(Inbound::Frame(f)) if f.kind == KIND_ACK => {
                    if f.seq >= seq {
                        return AckWait::Acked;
                    }
                }
                // Anything else inbound on a send link is noise.
                Ok(Inbound::Frame(_)) | Ok(Inbound::Corrupt { .. }) => {}
                Ok(Inbound::Eof) => return AckWait::Disconnected,
                Err(e) => {
                    return match e.kind() {
                        ErrorKind::WouldBlock | ErrorKind::TimedOut => {
                            AckWait::Timeout
                        }
                        _ => AckWait::Disconnected,
                    }
                }
            }
        }
    }
}

/// Wire index of a traffic class (frame `class` byte).
pub(crate) fn class_idx(class: TrafficClass) -> u8 {
    TrafficClass::ALL
        .iter()
        .position(|c| *c == class)
        .expect("class in ALL") as u8
}

/// Inverse of [`class_idx`]; unknown bytes read as `GradReduce` (the
/// receiver only echoes the byte into acks, so this is cosmetic).
pub(crate) fn class_of(idx: u8) -> TrafficClass {
    TrafficClass::ALL
        .get(idx as usize)
        .copied()
        .unwrap_or(TrafficClass::GradReduce)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_bytes_roundtrip() {
        for class in TrafficClass::ALL {
            assert_eq!(class_of(class_idx(class)), class);
        }
        assert_eq!(class_of(200), TrafficClass::GradReduce);
    }
}
