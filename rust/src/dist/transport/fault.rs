//! Deterministic fault injection between the codec and the retry
//! middleware.
//!
//! The injector sits on the *sender* side of a link and mangles
//! outgoing DATA frames only — acks and hellos always pass clean, and
//! it never touches the 18-byte header, so the receiver can always
//! consume whole frames (a corrupt payload is caught by checksum, not
//! by a desynchronized stream). Decisions are drawn from a seeded
//! [`Rng`] per link, so a faulty run is bit-reproducible.
//!
//! Semantics per outgoing frame (one roll, cumulative thresholds, so
//! at most one fault fires per write):
//!
//! - **drop**: nothing hits the wire; the retryer's ack timeout fires.
//! - **corrupt**: one payload byte is flipped; the receiver drops the
//!   frame on checksum and withholds the ack.
//! - **dup**: the frame is written twice; the receiver's seq dedupe
//!   delivers once and re-acks the copy.
//! - **reorder**: the frame is held back and flushed *after* the next
//!   write on the link. Under stop-and-wait the next write is the
//!   retransmission of the same seq, so reordering manifests as a
//!   timeout plus a late duplicate — which the dedupe absorbs.

use std::io::{self, Write};

use anyhow::{bail, Result};

use super::framer::HEADER_LEN;
use crate::util::prng::Rng;

/// Per-link fault rates (each in `[0, 1)`), optionally restricted to
/// one traffic class by name.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultSpec {
    pub drop: f64,
    pub dup: f64,
    pub reorder: f64,
    pub corrupt: f64,
    /// `Some("grad_reduce")` injects on that class only; `None` on all.
    pub class: Option<String>,
}

impl FaultSpec {
    /// Parse `"drop:0.05,dup:0.02,reorder:0.01,corrupt:0.03"` (any
    /// subset; `class:NAME` restricts to one traffic class). Empty
    /// string means no faults.
    pub fn parse(s: &str) -> Result<FaultSpec> {
        let mut spec = FaultSpec::default();
        if s.trim().is_empty() {
            return Ok(spec);
        }
        for part in s.split(',') {
            let Some((key, value)) = part.split_once(':') else {
                bail!("fault spec {part:?}: expected key:value");
            };
            let (key, value) = (key.trim(), value.trim());
            if key == "class" {
                spec.class = Some(value.to_string());
                continue;
            }
            let rate: f64 = value
                .parse()
                .map_err(|_| {
                    anyhow::anyhow!("fault rate {value:?} is not a \
                                     number")
                })?;
            if !(0.0..1.0).contains(&rate) {
                bail!("fault rate {key}:{rate} outside [0, 1)");
            }
            match key {
                "drop" => spec.drop = rate,
                "dup" => spec.dup = rate,
                "reorder" => spec.reorder = rate,
                "corrupt" => spec.corrupt = rate,
                other => bail!("unknown fault kind {other:?}"),
            }
        }
        if spec.drop + spec.corrupt + spec.dup + spec.reorder >= 1.0 {
            bail!("fault rates sum to >= 1: every frame would fault");
        }
        Ok(spec)
    }

    pub fn is_noop(&self) -> bool {
        self.drop == 0.0
            && self.dup == 0.0
            && self.reorder == 0.0
            && self.corrupt == 0.0
    }
}

/// What the injector did to one write (exposed for tests/telemetry).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    Pass,
    Drop,
    Corrupt,
    Duplicate,
    Reorder,
}

/// Seeded fault shim over one link's outgoing data frames.
#[derive(Debug)]
pub struct FaultInjector {
    spec: FaultSpec,
    rng: Rng,
    held: Option<Vec<u8>>,
    /// Total faults injected on this link so far.
    pub injected: u64,
}

impl FaultInjector {
    pub fn new(spec: FaultSpec, seed: u64) -> FaultInjector {
        FaultInjector {
            spec,
            rng: Rng::new(seed),
            held: None,
            injected: 0,
        }
    }

    /// Decide this write's fate (and consume one roll when rates are
    /// live for `class_name`).
    fn decide(&mut self, class_name: &str, payload_len: usize)
        -> FaultAction {
        if self.spec.is_noop() {
            return FaultAction::Pass;
        }
        if let Some(only) = &self.spec.class {
            if only != class_name {
                return FaultAction::Pass;
            }
        }
        let r = self.rng.f64();
        let mut edge = self.spec.drop;
        if r < edge {
            return FaultAction::Drop;
        }
        edge += self.spec.corrupt;
        if r < edge && payload_len > 0 {
            return FaultAction::Corrupt;
        }
        edge += self.spec.dup;
        if r < edge {
            return FaultAction::Duplicate;
        }
        edge += self.spec.reorder;
        if r < edge {
            return FaultAction::Reorder;
        }
        FaultAction::Pass
    }

    /// Write one encoded data frame through the shim. Returns the
    /// action taken so the caller can count injections.
    pub fn write_data(&mut self, w: &mut impl Write, frame: &[u8],
                      class_name: &str) -> io::Result<FaultAction> {
        // A held (reordered) frame flushes behind the next write,
        // whatever that write's own roll would have been.
        if let Some(held) = self.held.take() {
            w.write_all(frame)?;
            w.write_all(&held)?;
            return Ok(FaultAction::Pass);
        }
        let payload_len = frame.len().saturating_sub(HEADER_LEN);
        let action = self.decide(class_name, payload_len);
        if action != FaultAction::Pass {
            self.injected += 1;
        }
        match action {
            FaultAction::Pass => w.write_all(frame)?,
            FaultAction::Drop => {}
            FaultAction::Corrupt => {
                let mut bytes = frame.to_vec();
                let at = HEADER_LEN + self.rng.below(payload_len);
                bytes[at] ^= 0x20;
                w.write_all(&bytes)?;
            }
            FaultAction::Duplicate => {
                w.write_all(frame)?;
                w.write_all(frame)?;
            }
            FaultAction::Reorder => self.held = Some(frame.to_vec()),
        }
        Ok(action)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::transport::framer::{read_frame, Frame, Inbound};
    use std::io::Cursor;

    #[test]
    fn spec_parses_and_rejects() {
        let s = FaultSpec::parse(
            "drop:0.05,dup:0.02,reorder:0.01,corrupt:0.03",
        )
        .unwrap();
        assert_eq!(s.drop, 0.05);
        assert_eq!(s.dup, 0.02);
        assert_eq!(s.reorder, 0.01);
        assert_eq!(s.corrupt, 0.03);
        assert!(s.class.is_none());
        let s = FaultSpec::parse("drop:0.1,class:grad_scatter").unwrap();
        assert_eq!(s.class.as_deref(), Some("grad_scatter"));
        assert!(FaultSpec::parse("").unwrap().is_noop());
        assert!(FaultSpec::parse("drop:1.5").is_err());
        assert!(FaultSpec::parse("explode:0.5").is_err());
        assert!(FaultSpec::parse("drop=0.5").is_err());
        assert!(FaultSpec::parse("drop:0.6,dup:0.5").is_err());
    }

    #[test]
    fn injector_is_deterministic_per_seed() {
        let spec =
            FaultSpec::parse("drop:0.2,dup:0.2,corrupt:0.2").unwrap();
        let frame = Frame::data(0, 0, &[1.0, 2.0]).encode();
        let run = |seed: u64| {
            let mut inj = FaultInjector::new(spec.clone(), seed);
            let mut out = Vec::new();
            let actions: Vec<FaultAction> = (0..64)
                .map(|_| {
                    inj.write_data(&mut out, &frame, "grad_reduce")
                        .unwrap()
                })
                .collect();
            (actions, out)
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7).0, run(8).0);
    }

    #[test]
    fn class_filter_passes_other_classes_clean() {
        let spec =
            FaultSpec::parse("drop:0.9,class:state_sync").unwrap();
        let mut inj = FaultInjector::new(spec, 1);
        let frame = Frame::data(0, 0, &[1.0]).encode();
        let mut out = Vec::new();
        for _ in 0..32 {
            assert_eq!(
                inj.write_data(&mut out, &frame, "grad_reduce")
                    .unwrap(),
                FaultAction::Pass
            );
        }
        assert_eq!(inj.injected, 0);
    }

    #[test]
    fn corrupt_keeps_framing_but_fails_checksum() {
        let spec = FaultSpec::parse("corrupt:0.99").unwrap();
        let mut inj = FaultInjector::new(spec, 3);
        let frame = Frame::data(1, 5, &[1.0, 2.0, 3.0]);
        let mut out = Vec::new();
        let action = inj
            .write_data(&mut out, &frame.encode(), "grad_reduce")
            .unwrap();
        assert_eq!(action, FaultAction::Corrupt);
        let mut cur = Cursor::new(out);
        assert_eq!(
            read_frame(&mut cur).unwrap(),
            Inbound::Corrupt { seq: 5 }
        );
        assert_eq!(read_frame(&mut cur).unwrap(), Inbound::Eof);
    }

    #[test]
    fn reorder_holds_then_flushes_behind_the_next_write() {
        let spec = FaultSpec::parse("reorder:0.99").unwrap();
        let mut inj = FaultInjector::new(spec, 4);
        let first = Frame::data(0, 0, &[1.0]);
        let second = Frame::data(0, 1, &[2.0]);
        let mut out = Vec::new();
        assert_eq!(
            inj.write_data(&mut out, &first.encode(), "grad_reduce")
                .unwrap(),
            FaultAction::Reorder
        );
        assert!(out.is_empty());
        // Next write flushes: second frame lands first, held one after.
        inj.write_data(&mut out, &second.encode(), "grad_reduce")
            .unwrap();
        let mut cur = Cursor::new(out);
        assert_eq!(
            read_frame(&mut cur).unwrap(),
            Inbound::Frame(second)
        );
        assert_eq!(read_frame(&mut cur).unwrap(), Inbound::Frame(first));
    }
}
