//! Transports for the data-parallel engine.
//!
//! Every worker owns a [`RingNode`]: ring neighbours plus a direct
//! gather link to rank 0 for checkpoint-style state collection. Two
//! transports implement the same interface behind an internal link
//! enum:
//!
//! - **channel** — workers are threads; links are `mpsc` channels.
//!   This is the seed behavior, bit-identical to what it always was.
//! - **socket** — links are localhost TCP streams speaking the
//!   length-framed codec of `transport::framer`, wrapped in the
//!   retry/timeout middleware of `transport::retryer`. Workers can be
//!   threads (`transport=tcp`) or OS processes (`transport=socket`).
//!
//! Every message is accounted — bytes and message count per
//! [`TrafficClass`], plus a simulated link-time integral under an
//! `alpha + bytes/beta` cost model — so a run's measured traffic can be
//! cross-checked against the analytical `cluster.rs` predictions.
//! Retransmissions are accounted under [`TrafficClass::Retry`]: the
//! four base classes stay byte-exact across transports (and across
//! fault injection), and the retry ledger isolates the overhead.
//!
//! Link failures no longer panic: sends and receives return a typed
//! [`DistError`] naming the rank and the peer, which the worker layer
//! propagates instead of crashing the trainer.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, OnceLock};

use super::error::DistError;
use super::transport::SocketLink;
use crate::telemetry::{Event, EventBus};
use crate::util::json::Json;

/// What a message carries — the ledger the traffic report groups by.
///
/// The gradient phases are attributed separately on purpose: a ZeRO-2
/// step's reduce-scatter must never be lumped under the all-reduce
/// class, or the measured-vs-modeled cross-check would double-count
/// one schedule's bytes against the other's closed form. The same
/// discipline puts retransmitted bytes in their own class: a lossy
/// link must not inflate the base ledgers the closed forms predict.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrafficClass {
    /// Gradient ring all-reduce (ZeRO-1 / replicated schedules).
    GradReduce,
    /// Gradient ring reduce-scatter (the ZeRO-2 schedule).
    GradScatter,
    /// Parameter all-gather after the sharded update (ZeRO-1/2).
    ParamGather,
    /// Optimizer-state collection (checkpoint / state round-trip).
    StateSync,
    /// Retransmitted payload bytes (socket transport only): every
    /// attempt after the first, whatever base class it carries.
    Retry,
    /// Half-precision-compressed collective payloads (`compress=f16`):
    /// wire bytes actually moved, recorded in place of the base
    /// gradient/parameter class the payload would have used dense.
    CodecF16,
    /// Sparse top-|g| compressed payloads (`compress=topk:<frac>`),
    /// same in-place-of-base-class discipline as [`Self::CodecF16`].
    CodecTopK,
}

impl TrafficClass {
    pub const ALL: [TrafficClass; 7] = [
        TrafficClass::GradReduce,
        TrafficClass::GradScatter,
        TrafficClass::ParamGather,
        TrafficClass::StateSync,
        TrafficClass::Retry,
        TrafficClass::CodecF16,
        TrafficClass::CodecTopK,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            TrafficClass::GradReduce => "grad_reduce",
            TrafficClass::GradScatter => "grad_scatter",
            TrafficClass::ParamGather => "param_gather",
            TrafficClass::StateSync => "state_sync",
            TrafficClass::Retry => "retry",
            TrafficClass::CodecF16 => "codec_f16",
            TrafficClass::CodecTopK => "codec_topk",
        }
    }

    fn idx(&self) -> usize {
        match self {
            TrafficClass::GradReduce => 0,
            TrafficClass::GradScatter => 1,
            TrafficClass::ParamGather => 2,
            TrafficClass::StateSync => 3,
            TrafficClass::Retry => 4,
            TrafficClass::CodecF16 => 5,
            TrafficClass::CodecTopK => 6,
        }
    }
}

/// Per-message cost model for the simulated link-time integral.
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    /// Fixed per-message latency (nanoseconds) — the alpha term.
    pub latency_ns: f64,
    /// Link bandwidth (bytes/second) — the beta term.
    pub bytes_per_sec: f64,
}

impl Default for LinkModel {
    fn default() -> Self {
        // PCIe/NVLink-ish defaults; only ratios matter for the report.
        LinkModel { latency_ns: 5_000.0, bytes_per_sec: 25e9 }
    }
}

impl LinkModel {
    /// Modeled time (ns) for one `bytes`-sized message on this link.
    pub fn msg_ns(&self, bytes: f64) -> f64 {
        self.latency_ns + bytes / self.bytes_per_sec * 1e9
    }

    /// Modeled wall time (ns) of `rounds` lockstep ring rounds, each
    /// moving `bytes_per_round` per rank. Ranks transmit in parallel,
    /// rounds serialize — the alpha–beta wall clock of a ring
    /// collective, as opposed to the cluster-total byte integral.
    pub fn ring_ns(&self, rounds: usize, bytes_per_round: f64) -> f64 {
        rounds as f64 * self.msg_ns(bytes_per_round)
    }
}

#[derive(Default)]
struct ClassCounters {
    bytes: AtomicU64,
    messages: AtomicU64,
}

/// Cluster-wide traffic ledger, shared by every endpoint.
pub struct CommStats {
    classes: [ClassCounters; 7],
    /// Sum of per-message modeled times (ns). An aggregate link-time
    /// integral, NOT wall-clock: messages on different links overlap.
    sim_link_ns: AtomicU64,
    link: LinkModel,
    /// Optional telemetry tap: every recorded message is mirrored as
    /// an [`Event::Message`], so an event consumer can rebuild this
    /// ledger byte-for-byte.
    bus: OnceLock<Arc<EventBus>>,
}

impl CommStats {
    pub fn new(link: LinkModel) -> CommStats {
        CommStats {
            classes: Default::default(),
            sim_link_ns: AtomicU64::new(0),
            link,
            bus: OnceLock::new(),
        }
    }

    /// Mirror every future message into `bus` (idempotent; first
    /// attach wins).
    pub fn attach_bus(&self, bus: Arc<EventBus>) {
        let _ = self.bus.set(bus);
    }

    fn record(&self, class: TrafficClass, bytes: u64) {
        let c = &self.classes[class.idx()];
        c.bytes.fetch_add(bytes, Ordering::Relaxed);
        c.messages.fetch_add(1, Ordering::Relaxed);
        let t = self.link.latency_ns
            + bytes as f64 / self.link.bytes_per_sec * 1e9;
        self.sim_link_ns.fetch_add(t as u64, Ordering::Relaxed);
    }

    /// Record one message from `rank`, publishing it to the attached
    /// bus (if any) with sender attribution.
    pub(crate) fn record_from(&self, rank: usize, class: TrafficClass,
                              bytes: u64) {
        self.record(class, bytes);
        if let Some(bus) = self.bus.get() {
            bus.publish(Event::Message { rank, class: class.name(), bytes });
        }
    }

    /// Publish a non-ledger event (retries, timeouts) to the attached
    /// bus, if any.
    pub(crate) fn publish(&self, event: Event) {
        if let Some(bus) = self.bus.get() {
            bus.publish(event);
        }
    }

    /// Total bytes moved so far in one traffic class.
    pub fn bytes(&self, class: TrafficClass) -> u64 {
        self.classes[class.idx()].bytes.load(Ordering::Relaxed)
    }

    pub fn messages(&self, class: TrafficClass) -> u64 {
        self.classes[class.idx()].messages.load(Ordering::Relaxed)
    }

    pub fn total_bytes(&self) -> u64 {
        TrafficClass::ALL.iter().map(|c| self.bytes(*c)).sum()
    }

    /// Aggregate modeled link-seconds (see [`CommStats::sim_link_ns`]).
    pub fn sim_link_secs(&self) -> f64 {
        self.sim_link_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Point-in-time copy of the byte counters (for per-phase deltas).
    pub fn snapshot(&self) -> CommSnapshot {
        let mut bytes = [0u64; 7];
        for c in TrafficClass::ALL {
            bytes[c.idx()] = self.bytes(c);
        }
        CommSnapshot { bytes }
    }

    /// Machine-readable ledger: per-class bytes/messages plus the
    /// modeled link-time integral.
    pub fn to_json(&self) -> Json {
        let classes = TrafficClass::ALL
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("class", Json::str(c.name())),
                    ("bytes", Json::num(self.bytes(*c) as f64)),
                    ("messages", Json::num(self.messages(*c) as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("classes", Json::Arr(classes)),
            ("total_bytes", Json::num(self.total_bytes() as f64)),
            ("sim_link_secs", Json::num(self.sim_link_secs())),
        ])
    }
}

/// Byte counters frozen at one instant.
#[derive(Debug, Clone, Copy)]
pub struct CommSnapshot {
    bytes: [u64; 7],
}

impl CommSnapshot {
    /// Bytes moved in `class` between `self` (earlier) and `later`.
    pub fn delta(&self, later: &CommSnapshot, class: TrafficClass) -> u64 {
        later.bytes[class.idx()] - self.bytes[class.idx()]
    }

    /// Frozen per-class byte counters as a JSON object.
    pub fn to_json(&self) -> Json {
        Json::Obj(
            TrafficClass::ALL
                .iter()
                .map(|c| {
                    (c.name().to_string(),
                     Json::num(self.bytes[c.idx()] as f64))
                })
                .collect(),
        )
    }
}

/// Completion side of a nonblocking collective — held by the comm
/// thread executing it; [`CollectiveDone::complete`] resolves the
/// paired [`CollectiveHandle`].
pub struct CollectiveDone<T> {
    tx: Sender<T>,
}

impl<T> CollectiveDone<T> {
    pub fn complete(self, value: T) {
        // A dropped handle just means nobody is waiting.
        let _ = self.tx.send(value);
    }
}

/// Caller side of a nonblocking collective: launched work continues on
/// the comm thread; the handle resolves when it completes. `wait`
/// blocks, `try_ready` polls.
pub struct CollectiveHandle<T> {
    rx: Receiver<T>,
}

impl<T> CollectiveHandle<T> {
    pub fn wait(self) -> T {
        self.rx.recv().expect("collective dropped before completing")
    }

    /// Like `wait`, but a completion side dropped without resolving
    /// (a comm thread that died mid-collective) yields `None` instead
    /// of panicking.
    pub fn wait_opt(self) -> Option<T> {
        self.rx.recv().ok()
    }

    pub fn try_ready(&self) -> Option<T> {
        self.rx.try_recv().ok()
    }
}

/// A fresh (completion, handle) pair for one in-flight collective.
pub fn collective_handle<T>() -> (CollectiveDone<T>, CollectiveHandle<T>) {
    let (tx, rx) = channel();
    (CollectiveDone { tx }, CollectiveHandle { rx })
}

/// The wire under a [`RingNode`]: in-process mpsc channels (the seed
/// transport) or framed TCP streams with retry middleware.
enum LinkImpl {
    Channel {
        right: Sender<Vec<f32>>,
        left: Receiver<Vec<f32>>,
        /// Absent at rank 0 — the root must not hold a sender clone,
        /// or a dead worker would deadlock the gather instead of
        /// closing the channel.
        to_root: Option<Sender<(usize, Vec<f32>)>>,
        /// Present only at rank 0.
        root_rx: Option<Receiver<(usize, Vec<f32>)>>,
    },
    Socket(Box<SocketLink>),
}

/// One worker's endpoints: ring neighbours + the rank-0 gather link.
pub struct RingNode {
    pub rank: usize,
    pub world: usize,
    link: LinkImpl,
    stats: Arc<CommStats>,
}

impl RingNode {
    /// Wrap a connected socket link (see `transport::socket_ring_world`
    /// and `transport::proc`).
    pub(crate) fn from_socket(rank: usize, world: usize,
                              link: SocketLink, stats: Arc<CommStats>)
        -> RingNode {
        RingNode { rank, world, link: LinkImpl::Socket(Box::new(link)), stats }
    }

    fn right_peer(&self) -> usize {
        (self.rank + 1) % self.world
    }

    fn left_peer(&self) -> usize {
        (self.rank + self.world - 1) % self.world
    }

    /// Send to the right ring neighbour (accounted).
    pub fn send_right(&mut self, class: TrafficClass, data: Vec<f32>)
        -> Result<(), DistError> {
        self.stats.record_from(self.rank, class, (data.len() * 4) as u64);
        let (rank, peer) = (self.rank, self.right_peer());
        match &mut self.link {
            LinkImpl::Channel { right, .. } => right
                .send(data)
                .map_err(|_| DistError::PeerDisconnected { rank, peer }),
            LinkImpl::Socket(sock) => {
                sock.send_right(class, &data, &self.stats)
            }
        }
    }

    /// Receive from the left ring neighbour (blocking).
    pub fn recv_left(&mut self) -> Result<Vec<f32>, DistError> {
        let (rank, peer) = (self.rank, self.left_peer());
        match &mut self.link {
            LinkImpl::Channel { left, .. } => left
                .recv()
                .map_err(|_| DistError::PeerDisconnected { rank, peer }),
            LinkImpl::Socket(sock) => sock.recv_left(),
        }
    }

    /// Gather one payload per rank at rank 0. Non-root ranks send and
    /// get `Ok(None)`; rank 0 collects (its own payload moves no
    /// bytes).
    pub fn gather_to_root(&mut self, class: TrafficClass,
                          payload: Vec<f32>)
        -> Result<Option<Vec<Vec<f32>>>, DistError> {
        if self.world == 1 {
            return Ok(Some(vec![payload]));
        }
        let rank = self.rank;
        if rank != 0 {
            self.stats
                .record_from(rank, class, (payload.len() * 4) as u64);
        }
        match &mut self.link {
            LinkImpl::Channel { to_root, root_rx, .. } => match root_rx {
                None => {
                    let tx = to_root
                        .as_ref()
                        .ok_or(DistError::CommHangup { rank })?;
                    tx.send((rank, payload)).map_err(|_| {
                        DistError::PeerDisconnected { rank, peer: 0 }
                    })?;
                    Ok(None)
                }
                Some(rx) => {
                    let mut out: Vec<Vec<f32>> =
                        vec![Vec::new(); self.world];
                    let mut got = vec![false; self.world];
                    out[rank] = payload;
                    got[rank] = true;
                    for _ in 0..self.world - 1 {
                        let (from, data) = rx.recv().map_err(|_| {
                            DistError::PeerDisconnected {
                                rank,
                                peer: first_missing(&got),
                            }
                        })?;
                        out[from] = data;
                        got[from] = true;
                    }
                    Ok(Some(out))
                }
            },
            LinkImpl::Socket(sock) => {
                sock.gather_to_root(class, payload, &self.stats)
            }
        }
    }

    pub fn stats(&self) -> &Arc<CommStats> {
        &self.stats
    }
}

/// Lowest rank whose payload never arrived (for error attribution).
pub(crate) fn first_missing(got: &[bool]) -> usize {
    got.iter().position(|g| !g).unwrap_or(0)
}

/// Build an N-worker ring world; returns one node per rank plus the
/// shared traffic ledger.
pub fn ring_world(world: usize, link: LinkModel)
    -> (Vec<RingNode>, Arc<CommStats>) {
    assert!(world >= 1, "world size must be >= 1");
    let stats = Arc::new(CommStats::new(link));
    // links[i]: channel from rank i to rank (i+1) % world.
    let mut txs: Vec<Sender<Vec<f32>>> = Vec::with_capacity(world);
    let mut rxs: Vec<Option<Receiver<Vec<f32>>>> =
        Vec::with_capacity(world);
    for _ in 0..world {
        let (tx, rx) = channel();
        txs.push(tx);
        rxs.push(Some(rx));
    }
    let (root_tx, root_rx) = channel();
    let mut root_rx = Some(root_rx);
    let mut nodes = Vec::with_capacity(world);
    for rank in 0..world {
        // Rank receives from its LEFT neighbour's outgoing link.
        let left_link = (rank + world - 1) % world;
        nodes.push(RingNode {
            rank,
            world,
            link: LinkImpl::Channel {
                right: txs[rank].clone(),
                left: rxs[left_link].take().expect("link already claimed"),
                to_root: if rank == 0 {
                    None
                } else {
                    Some(root_tx.clone())
                },
                root_rx: if rank == 0 { root_rx.take() } else { None },
            },
            stats: stats.clone(),
        });
    }
    (nodes, stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_passes_messages_and_counts_bytes() {
        let (nodes, stats) = ring_world(3, LinkModel::default());
        std::thread::scope(|s| {
            // Threads take ownership: &RingNode is !Send (mpsc
            // Receiver is !Sync).
            for mut node in nodes {
                s.spawn(move || {
                    node.send_right(TrafficClass::GradReduce,
                                    vec![node.rank as f32; 4])
                        .unwrap();
                    let got = node.recv_left().unwrap();
                    let left = (node.rank + 2) % 3;
                    assert_eq!(got, vec![left as f32; 4]);
                });
            }
        });
        assert_eq!(stats.bytes(TrafficClass::GradReduce), 3 * 16);
        assert_eq!(stats.messages(TrafficClass::GradReduce), 3);
        assert_eq!(stats.bytes(TrafficClass::ParamGather), 0);
        assert_eq!(stats.bytes(TrafficClass::Retry), 0);
        assert!(stats.sim_link_secs() > 0.0);
    }

    #[test]
    fn gather_to_root_collects_by_rank() {
        let (nodes, stats) = ring_world(4, LinkModel::default());
        let before = stats.snapshot();
        let results: Vec<_> = std::thread::scope(|s| {
            let handles: Vec<_> = nodes
                .into_iter()
                .map(|mut node| {
                    s.spawn(move || {
                        node.gather_to_root(TrafficClass::StateSync,
                                            vec![node.rank as f32])
                    })
                })
                .collect();
            handles
                .into_iter()
                .enumerate()
                .map(|(rank, h)| {
                    h.join()
                        .map_err(|_| DistError::WorkerPanicked { rank })
                        .and_then(|r| r)
                        .unwrap()
                })
                .collect()
        });
        let gathered = results[0].clone().expect("rank 0 gathers");
        for (r, payload) in gathered.iter().enumerate() {
            assert_eq!(payload, &vec![r as f32]);
        }
        assert!(results[1..].iter().all(Option::is_none));
        // 3 non-root ranks × 1 f32 each.
        let after = stats.snapshot();
        assert_eq!(before.delta(&after, TrafficClass::StateSync), 12);
    }

    #[test]
    fn collective_handle_resolves_on_complete() {
        let (done, handle) = collective_handle::<u32>();
        assert!(handle.try_ready().is_none());
        done.complete(7);
        assert_eq!(handle.wait(), 7);
    }

    #[test]
    fn dropped_completion_resolves_wait_opt_to_none() {
        let (done, handle) = collective_handle::<u32>();
        drop(done);
        assert!(handle.wait_opt().is_none());
    }

    #[test]
    fn link_model_times_are_additive() {
        let link = LinkModel { latency_ns: 100.0, bytes_per_sec: 1e9 };
        // 1000 B at 1 GB/s = 1000 ns + 100 ns latency.
        assert!((link.msg_ns(1000.0) - 1100.0).abs() < 1e-9);
        assert!((link.ring_ns(3, 1000.0) - 3300.0).abs() < 1e-9);
    }

    #[test]
    fn grad_phases_are_separate_classes() {
        // The ZeRO-2 fix: reduce-scatter bytes must never land in the
        // all-reduce ledger.
        let (nodes, stats) = ring_world(2, LinkModel::default());
        std::thread::scope(|s| {
            for mut node in nodes {
                s.spawn(move || {
                    node.send_right(TrafficClass::GradScatter,
                                    vec![0.0; 8])
                        .unwrap();
                    node.recv_left().unwrap();
                });
            }
        });
        assert_eq!(stats.bytes(TrafficClass::GradScatter), 2 * 32);
        assert_eq!(stats.bytes(TrafficClass::GradReduce), 0);
        assert_eq!(stats.total_bytes(), 2 * 32);
    }

    #[test]
    fn attached_bus_mirrors_ledger() {
        let (nodes, stats) = ring_world(2, LinkModel::default());
        let bus = EventBus::new(64);
        stats.attach_bus(Arc::clone(&bus));
        std::thread::scope(|s| {
            for mut node in nodes {
                s.spawn(move || {
                    node.send_right(TrafficClass::GradReduce,
                                    vec![1.0; 8])
                        .unwrap();
                    node.recv_left().unwrap();
                });
            }
        });
        let mut event_bytes = 0u64;
        for st in bus.drain() {
            if let Event::Message { class, bytes, .. } = st.event {
                assert_eq!(class, "grad_reduce");
                event_bytes += bytes;
            }
        }
        assert_eq!(event_bytes, stats.bytes(TrafficClass::GradReduce));
        assert_eq!(bus.dropped(), 0);
    }

    #[test]
    fn single_worker_world_is_valid() {
        let (mut nodes, stats) = ring_world(1, LinkModel::default());
        assert_eq!(nodes.len(), 1);
        let got = nodes[0]
            .gather_to_root(TrafficClass::StateSync, vec![7.0])
            .unwrap()
            .unwrap();
        assert_eq!(got, vec![vec![7.0]]);
        assert_eq!(stats.total_bytes(), 0);
    }

    #[test]
    fn dead_peer_is_a_typed_error_naming_the_rank() {
        let (mut nodes, _stats) = ring_world(2, LinkModel::default());
        // Rank 1 dies: its inbound link (rank 0's right) is gone.
        let dead = nodes.remove(1);
        drop(dead);
        let err = nodes[0]
            .send_right(TrafficClass::GradReduce, vec![1.0; 4])
            .unwrap_err();
        assert_eq!(err,
                   DistError::PeerDisconnected { rank: 0, peer: 1 });
        let err = nodes[0].recv_left().unwrap_err();
        assert_eq!(err,
                   DistError::PeerDisconnected { rank: 0, peer: 1 });
    }

    #[test]
    fn dead_worker_fails_the_root_gather_with_its_rank() {
        let (mut nodes, _stats) = ring_world(3, LinkModel::default());
        let dead = nodes.remove(2);
        drop(dead);
        let results: Vec<_> = std::thread::scope(|s| {
            let handles: Vec<_> = nodes
                .into_iter()
                .map(|mut node| {
                    s.spawn(move || {
                        node.gather_to_root(TrafficClass::StateSync,
                                            vec![node.rank as f32])
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // Rank 1 delivered; rank 0 then waited on rank 2, whose
        // channel sender is gone once every live sender finished.
        assert_eq!(
            results[0],
            Err(DistError::PeerDisconnected { rank: 0, peer: 2 })
        );
        assert_eq!(results[1], Ok(None));
    }
}
