//! The data-parallel engine: N in-process workers (threads) that
//! reduce gradients and step the optimizer in sharded (ZeRO-1/2) or
//! replicated mode, batch-synchronously or as a streaming bucket
//! pipeline.
//!
//! Step contract (driver side):
//!
//! 1. The driver assigns each global micro-batch `i` of a step to
//!    worker `i % N` and accumulates per-worker UNNORMALIZED gradient
//!    sums into flat buffers (the batch stream is identical for every
//!    world size — the core N-vs-1 equivalence invariant).
//! 2. Either [`DistTrainer::step`] (batch-synchronous: all gradients
//!    land, then the collectives run) or [`DistTrainer::begin_step`]
//!    (streaming: gradients land tensor by tensor and each readiness
//!    bucket's collective launches the moment its last tensor arrives)
//!    executes one of three schedules:
//!    - **ZeRO-1**: bucketed ring all-reduce, step this worker's shard
//!      over its contiguous range (`Optimizer::step_segment_scaled` on
//!      the flat buffers — no tensor-list clone round-trips, and the
//!      1/n_micro average folds into the fused update sweep instead of
//!      a separate scale pass), ring all-gather the updated parameters;
//!    - **ZeRO-2**: bucketed ring **reduce-scatter** (each worker only
//!      ever holds its gradient shard reduced — `(N−1)·P` bytes
//!      instead of the all-reduce's `2(N−1)·P`), step the shard,
//!      all-gather the updated parameters. In the streaming pipeline
//!      this is **bucket-granular**: the moment a bucket's
//!      reduce-scatter lands, the worker steps its shard∩bucket
//!      segment and immediately launches that bucket's parameter
//!      all-gather — optimizer compute and the gather overlap
//!      in-flight collectives instead of serializing after the last
//!      reduce-scatter ([`StepTiming::granular_gain`] measures the
//!      modeled win; `bucket_step=false` restores the deferred tail);
//!    - **replicated**: all-reduce and return the reduced gradient —
//!      the identical per-replica update is executed once by the
//!      caller (non-shardable optimizers).
//!
//! With `n_micro <= 1` micro-batch every schedule is bit-identical to
//! the single-worker run (idle workers contribute exact zeros, and
//! x + 0 is exact in any summation order); with several micro-batches
//! they match to float tolerance (ring summation order differs from
//! sequential accumulation). Bucket-granular stepping preserves this:
//! segment boundaries are drawn from the optimizer's cut grid, so the
//! per-element / per-block update math is unchanged.

use anyhow::{bail, Result};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use super::allreduce::{clip_ranges, ring_all_gather,
                       ring_all_gather_coded, ring_all_reduce,
                       ring_all_reduce_coded, ring_reduce_scatter,
                       ring_reduce_scatter_bucketed,
                       ring_reduce_scatter_bucketed_coded,
                       ring_reduce_scatter_coded};
use super::bucket::{gather_comm_ns, grad_comm_ns, BucketPlan,
                    ComputeModel, OverlapTimeline, StepTiming};
use super::comm::{collective_handle, ring_world, CollectiveDone,
                  CollectiveHandle, CommStats, LinkModel, RingNode,
                  TrafficClass};
use super::compress::{Codec, CodecSpec, CodedRing};
use super::error::DistError;
use super::shard::{block_cuts, build_shard_optimizer, pieces_for,
                   shard_spec, shardable, slice_shard, FlatLayout,
                   Partition, SendOptimizer};
use super::transport::{socket_ring_world, TransportKind};
use crate::optim::{GradView, Hyper, ParamView, ReduceOp, StateDict};
use crate::partition::BlockView;
use crate::telemetry::{Event, EventBus};
use crate::tensor::Tensor;

/// Publish to an optional bus (the no-telemetry path stays a branch).
fn pub_ev(bus: &Option<Arc<EventBus>>, event: Event) {
    if let Some(b) = bus {
        b.publish(event);
    }
}

/// Which step schedule the engine runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepMode {
    /// All-reduce; the caller executes the (identical) update once.
    Replicated,
    /// All-reduce + sharded optimizer state + param all-gather.
    Zero1,
    /// Reduce-scatter + sharded state AND gradients + param all-gather.
    Zero2,
}

impl StepMode {
    /// True when optimizer state (and for ZeRO-2, gradients) shard.
    pub fn sharded(&self) -> bool {
        !matches!(self, StepMode::Replicated)
    }

    pub fn name(&self) -> &'static str {
        match self {
            StepMode::Replicated => "replicated",
            StepMode::Zero1 => "zero1",
            StepMode::Zero2 => "zero2",
        }
    }
}

/// Engine configuration (mirrors the `workers`/`bucket_kb`/`zero1`/
/// `zero2`/`bucket_step` config keys plus what optimizer construction
/// needs).
pub struct DistOptions {
    pub workers: usize,
    pub bucket_kb: usize,
    /// Shard optimizer state (ZeRO-1). Requires a shardable optimizer;
    /// callers should fall back to replicated mode otherwise.
    pub zero1: bool,
    /// Also shard gradients (ZeRO-2): reduce-scatter → step →
    /// all-gather. Implies (and requires) a shardable optimizer;
    /// takes precedence over `zero1`.
    pub zero2: bool,
    /// ZeRO-2 streaming only: step each bucket's shard segment the
    /// moment its reduce-scatter lands and launch that bucket's
    /// all-gather immediately (on by default). `false` restores the
    /// PR-2 deferred tail (step + whole gather after the last
    /// reduce-scatter) — the A/B lever the bench sweeps.
    pub bucket_step: bool,
    pub optimizer: String,
    pub reduce: ReduceOp,
    pub hp: Hyper,
    /// Full-space Adam-mini block views (required for `adam_mini*`).
    pub spec: Option<Vec<BlockView>>,
    pub link: LinkModel,
    /// Simulated backward- and optimizer-compute costs for the overlap
    /// timeline.
    pub compute: ComputeModel,
    /// The wire under the worker ring: in-process channels (default,
    /// the seed behavior, bit-identical) or framed localhost TCP with
    /// retry/timeout middleware (`transport=tcp`).
    pub transport: TransportKind,
    /// Wire compression for the ring collectives
    /// (`compress=none|f16|topk:<frac>`). `None` is a true bypass:
    /// the coded paths are never entered and the pipeline stays
    /// bit-exact with the pre-codec engine.
    pub compress: CodecSpec,
}

impl Default for DistOptions {
    fn default() -> Self {
        DistOptions {
            workers: 1,
            bucket_kb: 64,
            zero1: true,
            zero2: false,
            bucket_step: true,
            optimizer: "adamw".into(),
            reduce: ReduceOp::Mean,
            hp: Hyper::default(),
            spec: None,
            link: LinkModel::default(),
            compute: ComputeModel::default(),
            transport: TransportKind::default(),
            compress: CodecSpec::None,
        }
    }
}

pub(crate) struct WorkerSlot {
    pub(crate) node: RingNode,
    /// Sharded modes only: this worker's shard optimizer, whose arena
    /// is the shard itself (shard-local coordinates).
    pub(crate) opt: Option<SendOptimizer>,
    /// This worker's contiguous flat range (global coordinates).
    pub(crate) shard_range: (usize, usize),
    /// Full parameter replica (sharded modes only; kept in flat form).
    pub(crate) flat_params: Vec<f32>,
    /// Telemetry publisher handle (None when no bus is attached).
    pub(crate) bus: Option<Arc<EventBus>>,
    /// Active wire codec (`None` ⇒ the bit-exact dense pipeline).
    pub(crate) codec: Option<Box<dyn Codec>>,
    /// Per-rank error-feedback residual over the full flat space:
    /// gradient mass a lossy codec dropped on this rank's summation
    /// hops, re-injected into the same positions next step. It is
    /// optimizer-adjacent state — it rides checkpoints as the
    /// `rank<r>/ef/residual` entry.
    pub(crate) residual: Option<Vec<f32>>,
}

/// Build one rank's slot: slice its shard out of the flat replica and
/// stand up the shard-local optimizer. Shared between the in-process
/// trainer and the multi-process per-rank driver so both worlds run
/// the identical construction path.
pub(crate) fn shard_slot(node: RingNode, layout: &FlatLayout,
                         range: (usize, usize), flat: &[f32],
                         opts: &DistOptions, sharded: bool)
    -> Result<WorkerSlot> {
    let is_mini = opts.optimizer.starts_with("adam_mini");
    let opt = if sharded {
        let pieces = pieces_for(layout, range);
        let shard = slice_shard(layout, &pieces, flat);
        let spec = if is_mini {
            let full = opts.spec.as_ref().ok_or_else(|| {
                anyhow::anyhow!("adam_mini dist run needs a block spec")
            })?;
            Some(shard_spec(layout, &pieces, full)?)
        } else {
            None
        };
        Some(build_shard_optimizer(&opts.optimizer, opts.hp, &shard,
                                   spec, opts.reduce)?)
    } else {
        None
    };
    Ok(WorkerSlot {
        node,
        opt,
        shard_range: range,
        flat_params: if sharded { flat.to_vec() } else { Vec::new() },
        bus: None,
        codec: opts.compress.build(),
        residual: if opts.compress.error_feedback() {
            Some(vec![0.0f32; layout.total])
        } else {
            None
        },
    })
}

/// Publish one collective's compression accounting (skipped when the
/// coded path moved nothing — e.g. a top-k all-gather stays dense).
fn pub_compressed(bus: &Option<Arc<EventBus>>, step: u64, rank: usize,
                  bucket: i64, ctx: &CodedRing) {
    if ctx.raw_elems == 0 {
        return;
    }
    let (raw_bytes, wire_bytes) = ctx.bytes();
    pub_ev(bus, Event::BucketCompressed {
        step, rank, bucket, codec: ctx.codec.name(), raw_bytes,
        wire_bytes,
    });
}

/// Publish the post-step error-feedback residual norm, when one
/// exists — the observable that EF mass is bounded, not diverging.
fn pub_residual_norm(bus: &Option<Arc<EventBus>>, step: u64,
                     rank: usize, residual: &Option<Vec<f32>>) {
    if let Some(res) = residual {
        let norm = res
            .iter()
            .map(|&v| v as f64 * v as f64)
            .sum::<f64>()
            .sqrt();
        pub_ev(bus, Event::ResidualNorm { step, rank, norm });
    }
}

/// Step this worker's whole shard against `reduced` (only the shard's
/// own range is read) through the segment API — no shard-clone
/// round-trip — then all-gather the updated parameters. `reduced`
/// holds the UNNORMALIZED gradient sum; the `gscale` factor (the
/// 1/n_micro average) folds into the fused update sweep instead of a
/// separate scale pass over the buffer.
fn step_shard_and_gather(slot: &mut WorkerSlot,
                         ranges: &[(usize, usize)], reduced: &[f32],
                         lr: f32, gscale: f32, step: u64)
    -> std::result::Result<(), DistError> {
    let (a, b) = slot.shard_range;
    if let Some(opt) = &mut slot.opt {
        opt.begin_step();
        if b > a {
            opt.step_segment_scaled(
                ParamView::new(0, &mut slot.flat_params[a..b]),
                GradView::new(0, &reduced[a..b]), lr, gscale);
        }
    }
    // bucket == -1: the whole-shard (deferred) optimizer step.
    pub_ev(&slot.bus, Event::ShardStepped {
        step, rank: slot.node.rank, bucket: -1, lo: a, hi: b,
    });
    if let Some(codec) = &slot.codec {
        let mut ctx = CodedRing::new(codec.as_ref(), None);
        ring_all_gather_coded(&mut slot.node, ranges,
                              &mut slot.flat_params,
                              TrafficClass::ParamGather,
                              Some(&mut ctx))?;
        pub_compressed(&slot.bus, step, slot.node.rank, -1, &ctx);
        Ok(())
    } else {
        ring_all_gather(&mut slot.node, ranges, &mut slot.flat_params,
                        TrafficClass::ParamGather)
    }
}

/// One rank's batch-synchronous step body: reduce (or scatter) the
/// gradient, step the local shard, gather parameters back. Shared by
/// the in-process worker threads and the multi-process per-rank
/// driver, so both execute byte-identical arithmetic in the identical
/// order — N-process vs N-thread bit-exactness holds by construction.
pub(crate) fn rank_step(slot: &mut WorkerSlot,
                        ranges: &[(usize, usize)], grad: &mut [f32],
                        bucket: usize, mode: StepMode, gscale: f32,
                        lr: f32, step: u64)
    -> std::result::Result<(), DistError> {
    let rank = slot.node.rank;
    match mode {
        StepMode::Replicated | StepMode::Zero1 => {
            if let Some(codec) = &slot.codec {
                let mut ctx = CodedRing::new(
                    codec.as_ref(), slot.residual.as_deref_mut());
                ring_all_reduce_coded(&mut slot.node, grad, bucket,
                                      TrafficClass::GradReduce,
                                      Some(&mut ctx))?;
                pub_compressed(&slot.bus, step, rank, -1, &ctx);
            } else {
                ring_all_reduce(&mut slot.node, grad, bucket,
                                TrafficClass::GradReduce)?;
            }
            if mode == StepMode::Replicated {
                for x in grad.iter_mut() {
                    *x *= gscale;
                }
            } else {
                step_shard_and_gather(slot, ranges, grad, lr, gscale,
                                      step)?;
            }
        }
        StepMode::Zero2 => {
            if let Some(codec) = &slot.codec {
                let mut ctx = CodedRing::new(
                    codec.as_ref(), slot.residual.as_deref_mut());
                ring_reduce_scatter_bucketed_coded(
                    &mut slot.node, ranges, grad, bucket,
                    TrafficClass::GradScatter, Some(&mut ctx))?;
                pub_compressed(&slot.bus, step, rank, -1, &ctx);
            } else {
                ring_reduce_scatter_bucketed(
                    &mut slot.node, ranges, grad, bucket,
                    TrafficClass::GradScatter)?;
            }
            step_shard_and_gather(slot, ranges, grad, lr, gscale,
                                  step)?;
        }
    }
    pub_residual_norm(&slot.bus, step, rank, &slot.residual);
    Ok(())
}

/// The multi-worker data-parallel trainer.
pub struct DistTrainer {
    layout: Arc<FlatLayout>,
    partition: Partition,
    plan: BucketPlan,
    slots: Vec<WorkerSlot>,
    stats: Arc<CommStats>,
    bucket_elems: usize,
    mode: StepMode,
    /// Bucket-granular ZeRO-2 stepping is live for streamed steps.
    granular: bool,
    link: LinkModel,
    compute: ComputeModel,
    last_timing: Option<StepTiming>,
    steps: u64,
    /// Telemetry publisher handle (see [`DistTrainer::attach_bus`]).
    bus: Option<Arc<EventBus>>,
}

impl DistTrainer {
    pub fn new(params: &[Tensor], opts: DistOptions)
        -> Result<DistTrainer> {
        let n = opts.workers;
        if n == 0 {
            bail!("workers must be >= 1");
        }
        let mode = if opts.zero2 {
            StepMode::Zero2
        } else if opts.zero1 {
            StepMode::Zero1
        } else {
            StepMode::Replicated
        };
        if mode.sharded() && !shardable(&opts.optimizer) {
            bail!("{}: not {} shardable; use replicated mode",
                  opts.optimizer, mode.name());
        }
        let layout = Arc::new(FlatLayout::of(params));
        let is_mini = opts.optimizer.starts_with("adam_mini");
        let cuts = if is_mini {
            let spec = opts.spec.as_ref().ok_or_else(|| {
                anyhow::anyhow!("adam_mini dist run needs a block spec")
            })?;
            Some(block_cuts(spec))
        } else {
            None
        };
        let partition = if !mode.sharded() {
            // Replicated mode still defines ranges (unused for comm).
            Partition::even(layout.total, n)
        } else if let Some(cuts) = &cuts {
            Partition::aligned(cuts, n)
        } else {
            Partition::even(layout.total, n)
        };
        let bucket_elems = (opts.bucket_kb.max(1) * 1024) / 4;
        let plan =
            BucketPlan::carve(&layout, cuts.as_deref(), bucket_elems);
        // Bucket-granular stepping needs every shard∩bucket boundary
        // on the optimizer's cut grid. The carve guarantees it when a
        // grid exists; elementwise optimizers align anywhere.
        let granular = opts.bucket_step
            && mode == StepMode::Zero2
            && match &cuts {
                None => true,
                Some(c) => plan.aligned_to(c),
            };
        let (nodes, stats) = match &opts.transport {
            TransportKind::Channel => ring_world(n, opts.link),
            TransportKind::Socket(sopts) => {
                socket_ring_world(n, opts.link, sopts)?
            }
        };
        let flat = layout.flatten(params);
        let mut slots = Vec::with_capacity(n);
        for (w, node) in nodes.into_iter().enumerate() {
            let range = partition.ranges[w];
            slots.push(shard_slot(node, &layout, range, &flat, &opts,
                                  mode.sharded())?);
        }
        Ok(DistTrainer {
            layout,
            partition,
            plan,
            slots,
            stats,
            bucket_elems,
            mode,
            granular,
            link: opts.link,
            compute: opts.compute,
            last_timing: None,
            steps: 0,
            bus: None,
        })
    }

    /// Attach a telemetry bus: step lifecycle, bucket readiness,
    /// collective launch/land, shard steps, and every transport
    /// message are published from here on. Telemetry never alters the
    /// training math — publishers fire strictly after (or around) the
    /// numeric work they describe.
    pub fn attach_bus(&mut self, bus: Arc<EventBus>) {
        self.stats.attach_bus(Arc::clone(&bus));
        for slot in &mut self.slots {
            slot.bus = Some(Arc::clone(&bus));
        }
        self.bus = Some(bus);
    }

    pub fn workers(&self) -> usize {
        self.slots.len()
    }

    pub fn layout(&self) -> &FlatLayout {
        &self.layout
    }

    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    /// The readiness-bucket plan the streaming pipeline launches by.
    pub fn plan(&self) -> &BucketPlan {
        &self.plan
    }

    pub fn mode(&self) -> StepMode {
        self.mode
    }

    pub fn is_sharded(&self) -> bool {
        self.mode.sharded()
    }

    /// True when streamed ZeRO-2 steps run bucket-granular (shard
    /// segment stepped per landed bucket + per-bucket all-gather).
    pub fn granular(&self) -> bool {
        self.granular
    }

    pub fn stats(&self) -> &Arc<CommStats> {
        &self.stats
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Timeline of the most recent streamed step (None until
    /// [`DistTrainer::begin_step`] has completed once).
    pub fn last_step_timing(&self) -> Option<StepTiming> {
        self.last_timing
    }

    /// Fresh per-worker gradient buffers for one step.
    pub fn grad_buffers(&self) -> Vec<Vec<f32>> {
        vec![vec![0.0f32; self.layout.total]; self.slots.len()]
    }

    /// Optimizer-state bytes held across all shards (sharded modes) —
    /// the cluster total, i.e. comparable to a replicated optimizer's
    /// `state_bytes`.
    pub fn state_bytes(&self) -> usize {
        self.slots
            .iter()
            .filter_map(|s| s.opt.as_ref().map(|o| o.state_bytes()))
            .sum()
    }

    /// One batch-synchronous data-parallel step. `local_grads[w]` is
    /// worker `w`'s unnormalized gradient sum over its assigned
    /// micro-batches (zeros if it got none); `n_micro` is the GLOBAL
    /// micro-batch count the average divides by.
    ///
    /// Sharded modes: `params` is updated in place and `None` is
    /// returned. Replicated: `params` is untouched and the reduced
    /// (averaged) gradient is returned for the caller's replicated
    /// update.
    pub fn step(&mut self, params: &mut [Tensor],
                mut local_grads: Vec<Vec<f32>>, n_micro: usize, lr: f32)
        -> Result<Option<Vec<Tensor>>> {
        let n = self.slots.len();
        if local_grads.len() != n {
            bail!("got {} grad buffers for {} workers",
                  local_grads.len(), n);
        }
        for (w, g) in local_grads.iter().enumerate() {
            if g.len() != self.layout.total {
                bail!("worker {w}: grad buffer {} != flat size {}",
                      g.len(), self.layout.total);
            }
        }
        self.steps += 1;
        let step = self.steps;
        pub_ev(&self.bus, Event::StepBegin {
            step, n_micro, workers: n,
        });
        let t0 = Instant::now();
        let inv = 1.0 / n_micro.max(1) as f32;
        let bucket = self.bucket_elems;
        let mode = self.mode;
        let ranges = &self.partition.ranges;
        let slots = &mut self.slots;
        std::thread::scope(|s| -> Result<()> {
            let handles: Vec<_> = slots
                .iter_mut()
                .zip(local_grads.iter_mut())
                .map(|(slot, grad)| {
                    s.spawn(move || {
                        rank_step(slot, ranges, grad, bucket, mode,
                                  inv, lr, step)
                    })
                })
                .collect();
            for (rank, h) in handles.into_iter().enumerate() {
                h.join()
                    .map_err(|_| DistError::WorkerPanicked { rank })??;
            }
            Ok(())
        })?;
        pub_ev(&self.bus, Event::StepEnd {
            step, wall_ns: t0.elapsed().as_secs_f64() * 1e9,
        });
        if self.mode.sharded() {
            self.layout.unflatten(&self.slots[0].flat_params, params);
            Ok(None)
        } else {
            // All ranks hold the identical reduced gradient; return
            // rank 0's as tensors for the replicated update.
            let mut grads: Vec<Tensor> = self
                .layout
                .spans
                .iter()
                .map(|sp| Tensor::zeros(&*sp.name, &sp.shape))
                .collect();
            self.layout.unflatten(&local_grads[0], &mut grads);
            Ok(Some(grads))
        }
    }

    /// Open a streaming step: per-worker comm threads spin up and the
    /// driver feeds gradients tensor by tensor via
    /// [`StepStream::push_grad`]; each readiness bucket's collective
    /// launches the moment its last gradient lands. Close with
    /// [`StepStream::finish`].
    pub fn begin_step(&mut self, n_micro: usize, lr: f32)
        -> StepStream<'_> {
        let n = self.slots.len();
        let total = self.layout.total;
        let inv = 1.0 / n_micro.max(1) as f32;
        let mode = self.mode;
        let granular = self.granular;
        // finish() increments the counter; this stream IS that step.
        let step = self.steps + 1;
        pub_ev(&self.bus, Event::StepBegin {
            step, n_micro, workers: n,
        });
        let ranges = self.partition.ranges.clone();
        let mut to_workers = Vec::with_capacity(n);
        let mut joins = Vec::with_capacity(n);
        for slot in self.slots.drain(..) {
            let (tx, rx) = channel::<BucketJob>();
            let layout = self.layout.clone();
            let ranges = ranges.clone();
            joins.push(std::thread::spawn(move || {
                worker_stream_loop(slot, rx, layout, ranges, mode,
                                   granular, inv, lr, step)
            }));
            to_workers.push(tx);
        }
        let pending: Vec<usize> =
            self.plan.buckets.iter().map(|b| b.n_spans()).collect();
        let landed = vec![false; self.layout.spans.len()];
        let timeline = OverlapTimeline::new(self.compute);
        StepStream {
            trainer: self,
            to_workers,
            joins,
            handles: Vec::new(),
            acc: vec![vec![0.0f32; total]; n],
            pending,
            landed,
            launched: 0,
            timeline,
            n_micro: n_micro.max(1),
            step,
            t0: Instant::now(),
        }
    }

    /// Collect the full (sharded) optimizer state at rank 0 through the
    /// transport — the checkpoint path, accounted as `StateSync`
    /// traffic. Returns one [`StateDict`] whose entries carry
    /// `rank<r>/` key prefixes (the ZeRO state routing convention).
    /// Replicated mode moves no bytes and returns an empty dict (the
    /// caller owns the replicated optimizer and exports it directly).
    pub fn sync_state(&mut self) -> Result<StateDict> {
        if !self.mode.sharded() {
            return Ok(StateDict::new());
        }
        // Per-rank export (keys/shapes) — driver side; the data itself
        // travels through the gather link below. The error-feedback
        // residual rides along as an `ef/`-prefixed entry: it is
        // optimizer-adjacent state, and a topk resume without it would
        // silently drop the un-sent gradient mass.
        let dicts: Vec<StateDict> = self
            .slots
            .iter()
            .map(|s| {
                let mut d = s.opt.as_ref().map(|o| o.state_dict())
                    .unwrap_or_default();
                if let Some(res) = &s.residual {
                    d.insert("ef/residual", &[res.len()], res.clone());
                }
                d
            })
            .collect();
        let slots = &mut self.slots;
        type GatherOut =
            std::result::Result<Option<Vec<Vec<f32>>>, DistError>;
        let payloads: Vec<GatherOut> = std::thread::scope(|s| {
            // iter_mut: a shared &WorkerSlot is !Send (the node
            // holds an mpsc Receiver); an exclusive borrow is Send.
            let handles: Vec<_> = slots
                .iter_mut()
                .zip(&dicts)
                .map(|(slot, dict)| {
                    s.spawn(move || {
                        let mut flat = Vec::new();
                        for t in dict.entries() {
                            flat.extend_from_slice(&t.data);
                        }
                        slot.node.gather_to_root(
                            TrafficClass::StateSync, flat)
                    })
                })
                .collect();
            handles
                .into_iter()
                .enumerate()
                .map(|(rank, h)| {
                    h.join().unwrap_or(Err(DistError::WorkerPanicked {
                        rank,
                    }))
                })
                .collect()
        });
        let mut gathered = None;
        for p in payloads {
            if let Some(g) = p? {
                gathered = Some(g);
            }
        }
        let gathered = gathered
            .ok_or_else(|| anyhow::anyhow!("rank 0 gathered nothing"))?;
        let mut out = StateDict::new();
        for (r, (dict, payload)) in
            dicts.iter().zip(gathered).enumerate()
        {
            let mut off = 0;
            for t in dict.entries() {
                let n = t.numel();
                out.insert(format!("rank{r}/{}", t.name), &t.shape,
                           payload[off..off + n].to_vec());
                off += n;
            }
            debug_assert_eq!(off, payload.len());
        }
        Ok(out)
    }

    /// Inverse of [`DistTrainer::sync_state`]: route a rank-prefixed
    /// state dict back into the shard optimizers (same world size and
    /// partition as the exporting run). Unroutable entries are an
    /// error, never a silent drop.
    pub fn import_state(&mut self, state: &StateDict) -> Result<()> {
        if !self.mode.sharded() {
            if state.is_empty() {
                return Ok(());
            }
            bail!("replicated mode holds no sharded state to import");
        }
        let mut routed = 0;
        for (r, slot) in self.slots.iter_mut().enumerate() {
            let Some(opt) = &mut slot.opt else { continue };
            let mut sub = state.sub_dict(&format!("rank{r}/"));
            if let Some(res) = sub.remove("ef/residual") {
                routed += 1;
                let Some(dst) = &mut slot.residual else {
                    bail!("rank {r}: checkpoint carries an \
                           error-feedback residual but the current \
                           compress codec keeps none");
                };
                if res.data.len() != dst.len() {
                    bail!("rank {r}: residual has {} elems, \
                           expected {}", res.data.len(), dst.len());
                }
                dst.copy_from_slice(&res.data);
            }
            routed += sub.len();
            opt.load_state_dict(&sub)?;
        }
        if routed != state.len() {
            bail!("state dict has {} entries outside any rank prefix",
                  state.len() - routed);
        }
        Ok(())
    }
}

/// One bucket's worth of a worker's gradient, in flight to its comm
/// thread.
struct BucketJob {
    lo: usize,
    hi: usize,
    data: Vec<f32>,
    done: CollectiveDone<usize>,
    idx: usize,
}

/// A worker's streamed step: drain bucket collectives in launch order,
/// then finalize. ZeRO-2 bucket-granular mode steps the shard∩bucket
/// segment and all-gathers the bucket's parameters inline, per job —
/// the finalize phase has nothing left to do. Other sharded modes
/// defer (optimizer step + whole param all-gather at the end);
/// replicated hands the reduced gradient back.
fn worker_stream_loop(mut slot: WorkerSlot, rx: Receiver<BucketJob>,
                      layout: Arc<FlatLayout>,
                      ranges: Vec<(usize, usize)>, mode: StepMode,
                      granular: bool, inv: f32, lr: f32, step: u64)
    -> (WorkerSlot, std::result::Result<Option<Vec<f32>>, DistError>) {
    let res = stream_rank_loop(&mut slot, rx, &layout, &ranges, mode,
                               granular, inv, lr, step);
    // The slot rides back either way so the trainer survives a failed
    // step with its workers intact.
    (slot, res)
}

/// The body of [`worker_stream_loop`], with `?`-style error exits. An
/// early return drops the job queue, which the driver observes as a
/// hangup on its next launch.
fn stream_rank_loop(slot: &mut WorkerSlot, rx: Receiver<BucketJob>,
                    layout: &FlatLayout, ranges: &[(usize, usize)],
                    mode: StepMode, granular: bool, inv: f32, lr: f32,
                    step: u64)
    -> std::result::Result<Option<Vec<f32>>, DistError> {
    let rank = slot.node.rank;
    let bus = slot.bus.clone();
    let bucket_step = granular && mode == StepMode::Zero2;
    if bucket_step {
        // One model step: open it once; segments follow per bucket.
        if let Some(opt) = &mut slot.opt {
            opt.begin_step();
        }
    }
    // Bucket-granular mode steps and gathers inline — it never
    // touches the accumulation buffer, so don't pay its allocation.
    let mut reduced = if bucket_step {
        Vec::new()
    } else {
        vec![0.0f32; layout.total]
    };
    while let Ok(mut job) = rx.recv() {
        let bucket_bytes = (job.data.len() * 4) as u64;
        match mode {
            StepMode::Replicated | StepMode::Zero1 => {
                let len = job.data.len().max(1);
                pub_ev(&bus, Event::CollectiveLaunched {
                    step, rank, bucket: job.idx,
                    class: TrafficClass::GradReduce.name(),
                    bytes: bucket_bytes,
                });
                let t = Instant::now();
                if let Some(codec) = &slot.codec {
                    let mut ctx = CodedRing::new(
                        codec.as_ref(),
                        slot.residual
                            .as_mut()
                            .map(|r| &mut r[job.lo..job.hi]));
                    ring_all_reduce_coded(&mut slot.node,
                                          &mut job.data, len,
                                          TrafficClass::GradReduce,
                                          Some(&mut ctx))?;
                    pub_compressed(&bus, step, rank, job.idx as i64,
                                   &ctx);
                } else {
                    ring_all_reduce(&mut slot.node, &mut job.data,
                                    len, TrafficClass::GradReduce)?;
                }
                pub_ev(&bus, Event::CollectiveLanded {
                    step, rank, bucket: job.idx,
                    class: TrafficClass::GradReduce.name(),
                    bytes: bucket_bytes,
                    ns: t.elapsed().as_secs_f64() * 1e9,
                });
                if mode == StepMode::Replicated {
                    // The caller receives the reduced gradient and
                    // runs the replicated update itself — hand back
                    // the AVERAGED form. ZeRO-1 instead keeps the raw
                    // sum and folds 1/n_micro into the deferred fused
                    // shard step.
                    for x in job.data.iter_mut() {
                        *x *= inv;
                    }
                }
                reduced[job.lo..job.hi].copy_from_slice(&job.data);
            }
            StepMode::Zero2 => {
                let clipped = clip_ranges(&ranges, job.lo, job.hi);
                pub_ev(&bus, Event::CollectiveLaunched {
                    step, rank, bucket: job.idx,
                    class: TrafficClass::GradScatter.name(),
                    bytes: bucket_bytes,
                });
                let t = Instant::now();
                if let Some(codec) = &slot.codec {
                    let mut ctx = CodedRing::new(
                        codec.as_ref(),
                        slot.residual
                            .as_mut()
                            .map(|r| &mut r[job.lo..job.hi]));
                    ring_reduce_scatter_coded(
                        &mut slot.node, &clipped, &mut job.data,
                        TrafficClass::GradScatter, Some(&mut ctx))?;
                    pub_compressed(&bus, step, rank, job.idx as i64,
                                   &ctx);
                } else {
                    ring_reduce_scatter(&mut slot.node, &clipped,
                                        &mut job.data,
                                        TrafficClass::GradScatter)?;
                }
                pub_ev(&bus, Event::CollectiveLanded {
                    step, rank, bucket: job.idx,
                    class: TrafficClass::GradScatter.name(),
                    bytes: bucket_bytes,
                    ns: t.elapsed().as_secs_f64() * 1e9,
                });
                let (a, b) = clipped[rank];
                if bucket_step {
                    // Step the shard∩bucket segment NOW (shard-local
                    // coordinates) with the 1/n_micro average folded
                    // into the fused sweep, then gather this bucket's
                    // params.
                    let shard_lo = slot.shard_range.0;
                    if b > a {
                        let (glo, ghi) = (job.lo + a, job.lo + b);
                        if let Some(opt) = &mut slot.opt {
                            opt.step_segment_scaled(
                                ParamView::new(
                                    glo - shard_lo,
                                    &mut slot.flat_params[glo..ghi]),
                                GradView::new(glo - shard_lo,
                                              &job.data[a..b]),
                                lr, inv);
                        }
                        pub_ev(&bus, Event::ShardStepped {
                            step, rank, bucket: job.idx as i64,
                            lo: glo, hi: ghi,
                        });
                    }
                    pub_ev(&bus, Event::CollectiveLaunched {
                        step, rank, bucket: job.idx,
                        class: TrafficClass::ParamGather.name(),
                        bytes: bucket_bytes,
                    });
                    let t = Instant::now();
                    if let Some(codec) = &slot.codec {
                        let mut ctx =
                            CodedRing::new(codec.as_ref(), None);
                        ring_all_gather_coded(
                            &mut slot.node, &clipped,
                            &mut slot.flat_params[job.lo..job.hi],
                            TrafficClass::ParamGather,
                            Some(&mut ctx))?;
                        pub_compressed(&bus, step, rank,
                                       job.idx as i64, &ctx);
                    } else {
                        ring_all_gather(
                            &mut slot.node, &clipped,
                            &mut slot.flat_params[job.lo..job.hi],
                            TrafficClass::ParamGather)?;
                    }
                    pub_ev(&bus, Event::CollectiveLanded {
                        step, rank, bucket: job.idx,
                        class: TrafficClass::ParamGather.name(),
                        bytes: bucket_bytes,
                        ns: t.elapsed().as_secs_f64() * 1e9,
                    });
                } else {
                    reduced[job.lo + a..job.lo + b]
                        .copy_from_slice(&job.data[a..b]);
                }
            }
        }
        job.done.complete(job.idx);
    }
    // Residual mutations all happen on the reduce hops above; the
    // trailing phases only step and gather.
    pub_residual_norm(&bus, step, rank, &slot.residual);
    match mode {
        StepMode::Replicated => {
            Ok(if rank == 0 { Some(reduced) } else { None })
        }
        StepMode::Zero2 if bucket_step => {
            // Every bucket already stepped + gathered inline.
            Ok(None)
        }
        StepMode::Zero1 | StepMode::Zero2 => {
            step_shard_and_gather(slot, ranges, &reduced, lr, inv,
                                  step)?;
            Ok(None)
        }
    }
}

/// A streaming step in flight (created by [`DistTrainer::begin_step`]).
///
/// Contract: push micro-batches in ascending order (`micro` `0..n`,
/// worker assignment `micro % N` as in the batch-synchronous path);
/// the FINAL micro-batch's landings trigger bucket launches. Every
/// span must land for every micro-batch before [`StepStream::finish`].
/// Dropping the stream without finishing shuts the comm threads down
/// cleanly but loses the step (and the trainer's workers).
pub struct StepStream<'a> {
    trainer: &'a mut DistTrainer,
    to_workers: Vec<Sender<BucketJob>>,
    joins: Vec<JoinHandle<(WorkerSlot,
                           std::result::Result<Option<Vec<f32>>,
                                               DistError>)>>,
    /// One nonblocking handle per (bucket, worker) collective.
    handles: Vec<CollectiveHandle<usize>>,
    /// Per-worker unnormalized gradient accumulation buffers.
    acc: Vec<Vec<f32>>,
    /// Per-bucket count of spans still awaiting their final gradient.
    pending: Vec<usize>,
    /// Spans whose FINAL micro-batch gradient has landed (duplicate
    /// guard — a repeat would underflow the pending counts).
    landed: Vec<bool>,
    launched: usize,
    timeline: OverlapTimeline,
    n_micro: usize,
    /// The step number this stream executes (assigned at begin_step).
    step: u64,
    t0: Instant,
}

impl StepStream<'_> {
    /// Accumulate micro-batch `micro`'s gradient for tensor `span`.
    /// On the final micro-batch this may launch one or more bucket
    /// collectives (the moment a bucket's last gradient lands).
    pub fn push_grad(&mut self, micro: usize, span: usize,
                     grad: &Tensor) -> Result<()> {
        if micro >= self.n_micro {
            bail!("micro-batch {micro} out of range (n_micro {})",
                  self.n_micro);
        }
        if span >= self.trainer.layout.spans.len() {
            bail!("span {span} out of range ({} tensors)",
                  self.trainer.layout.spans.len());
        }
        let sp = &self.trainer.layout.spans[span];
        if grad.numel() != sp.len {
            bail!("span {span} ({}): gradient has {} elems, expected {}",
                  sp.name, grad.numel(), sp.len);
        }
        let w = micro % self.acc.len();
        let dst = &mut self.acc[w][sp.offset..sp.offset + sp.len];
        for (x, y) in dst.iter_mut().zip(&grad.data) {
            *x += y;
        }
        self.timeline.record_compute(sp.len);
        if micro + 1 == self.n_micro {
            // Final micro-batch: this tensor's gradient is complete on
            // every worker; launch any bucket it was the last gate of.
            if self.landed[span] {
                bail!("span {span} ({}): duplicate gradient for the \
                       final micro-batch", sp.name);
            }
            self.landed[span] = true;
            let gated = self.trainer.plan.span_buckets[span].clone();
            for b in gated {
                self.pending[b] -= 1;
                if self.pending[b] == 0 {
                    let bk = self.trainer.plan.buckets[b];
                    pub_ev(&self.trainer.bus, Event::BucketReady {
                        step: self.step,
                        bucket: b,
                        spans: bk.n_spans(),
                        elems: bk.elems(),
                    });
                    self.launch(b)?;
                }
            }
        }
        Ok(())
    }

    /// Launch bucket `b`'s collective on every worker's comm thread.
    /// A worker whose comm thread hung up mid-step (transport failure
    /// or panic) surfaces as a typed error naming the rank — the step
    /// is abandoned, not the process.
    fn launch(&mut self, b: usize) -> Result<()> {
        let bk = self.trainer.plan.buckets[b];
        for (w, tx) in self.to_workers.iter().enumerate() {
            let (done, handle) = collective_handle();
            let data = self.acc[w][bk.lo..bk.hi].to_vec();
            let job = BucketJob { lo: bk.lo, hi: bk.hi, data, done,
                                  idx: b };
            if tx.send(job).is_err() {
                pub_ev(&self.trainer.bus, Event::CommHangup {
                    step: self.step, rank: w,
                });
                return Err(DistError::CommHangup { rank: w }.into());
            }
            self.handles.push(handle);
        }
        self.launched += 1;
        let world = self.to_workers.len();
        if self.trainer.granular {
            // Bucket-granular ZeRO-2: scatter, then the shard-segment
            // step, then the bucket param all-gather — all modeled per
            // bucket. Workers step their shard∩bucket in parallel and
            // the gather waits for the slowest, so the chain is
            // charged the LARGEST intersection — usually the whole
            // bucket, since buckets are much smaller than shards and
            // land inside one.
            let scatter = grad_comm_ns(&self.trainer.link, world,
                                       bk.elems(), true);
            let max_chunk = self
                .trainer
                .partition
                .ranges
                .iter()
                .map(|&(a, b)| {
                    b.min(bk.hi).saturating_sub(a.max(bk.lo))
                })
                .max()
                .unwrap_or(0);
            let step = max_chunk as f64
                * self.timeline.compute_model().step_ns_per_elem;
            let gather =
                gather_comm_ns(&self.trainer.link, world, bk.elems());
            self.timeline.launch_granular(scatter, step, gather);
        } else {
            let scatter_only = self.trainer.mode == StepMode::Zero2;
            let comm_ns = grad_comm_ns(&self.trainer.link, world,
                                       bk.elems(), scatter_only);
            self.timeline.launch(comm_ns);
        }
        Ok(())
    }

    /// Close the step: wait for every launched collective, run any
    /// trailing phase (deferred shard step + whole parameter
    /// all-gather — a no-op in bucket-granular ZeRO-2, where every
    /// bucket stepped and gathered inline) and restore the trainer.
    /// Returns like [`DistTrainer::step`]: `None` for sharded modes
    /// (params updated in place), the reduced gradient for replicated
    /// mode.
    pub fn finish(mut self, params: &mut [Tensor])
        -> Result<Option<Vec<Tensor>>> {
        let planned = self.trainer.plan.len();
        if self.launched != planned {
            bail!("streamed step incomplete: {}/{planned} buckets \
                   launched (missing gradients?)", self.launched);
        }
        // Closing the queues tells the comm threads to finalize.
        self.to_workers.clear();
        let world = self.joins.len();
        let mut replicated_out: Option<Vec<f32>> = None;
        let mut first_err: Option<DistError> = None;
        for (rank, j) in self.joins.drain(..).enumerate() {
            match j.join() {
                Err(_) => {
                    first_err.get_or_insert(
                        DistError::WorkerPanicked { rank });
                }
                Ok((slot, res)) => {
                    self.trainer.slots.push(slot);
                    match res {
                        Ok(Some(g)) => replicated_out = Some(g),
                        Ok(None) => {}
                        Err(e) => {
                            first_err.get_or_insert(e);
                        }
                    }
                }
            }
        }
        // Drain handles with wait_opt: on a clean step every launched
        // collective resolved before its comm thread exited; on a
        // failed step completions were dropped mid-flight and the
        // typed error below is the loud signal, not a handle panic.
        for h in self.handles.drain(..) {
            let _ = h.wait_opt();
        }
        if let Some(e) = first_err {
            return Err(e.into());
        }
        let sharded = self.trainer.mode.sharded();
        if sharded {
            let total = self.trainer.layout.total;
            // Workers step whole shards in parallel; the trailing
            // gather waits for the largest one.
            let max_shard = self
                .trainer
                .partition
                .ranges
                .iter()
                .map(|&(a, b)| b - a)
                .max()
                .unwrap_or(0);
            let step_total = max_shard as f64
                * self.timeline.compute_model().step_ns_per_elem;
            let gather_whole =
                gather_comm_ns(&self.trainer.link, world, total);
            if self.trainer.granular {
                // Live schedule has no tail; record what the deferred
                // comparator would pay.
                self.timeline.set_deferred_tail(step_total,
                                                gather_whole);
            } else {
                self.timeline.set_tail(step_total, gather_whole);
            }
        }
        self.trainer.steps += 1;
        self.trainer.last_timing = Some(self.timeline.timing());
        pub_ev(&self.trainer.bus, Event::StepEnd {
            step: self.step,
            wall_ns: self.t0.elapsed().as_secs_f64() * 1e9,
        });
        if sharded {
            let flat = std::mem::take(
                &mut self.trainer.slots[0].flat_params);
            self.trainer.layout.unflatten(&flat, params);
            self.trainer.slots[0].flat_params = flat;
            Ok(None)
        } else {
            let reduced = replicated_out.ok_or_else(|| {
                anyhow::anyhow!("rank 0 returned no reduced gradient")
            })?;
            let mut grads: Vec<Tensor> = self
                .trainer
                .layout
                .spans
                .iter()
                .map(|sp| Tensor::zeros(&*sp.name, &sp.shape))
                .collect();
            self.trainer.layout.unflatten(&reduced, &mut grads);
            Ok(Some(grads))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{by_name, ModelMeta, Optimizer};
    use crate::partition::Strategy;
    use crate::util::prng::Rng;

    fn toy() -> (Vec<Tensor>, ModelMeta) {
        let mut rng = Rng::new(20);
        let params = vec![
            Tensor::randn("embed", &[16, 8], 0.5, &mut rng),
            Tensor::randn("wq", &[2, 8, 8], 0.5, &mut rng),
            Tensor::randn("attn_norm", &[2, 8], 0.5, &mut rng),
        ];
        let meta = ModelMeta {
            n_heads: 2,
            stacked: vec!["wq".into(), "attn_norm".into()],
        };
        (params, meta)
    }

    fn rand_grads(params: &[Tensor], rng: &mut Rng) -> Vec<Tensor> {
        params
            .iter()
            .map(|p| Tensor::randn(&*p.name, &p.shape, 0.3, rng))
            .collect()
    }

    fn mini_spec(params: &[Tensor], meta: &ModelMeta)
        -> Vec<BlockView> {
        meta.spec_for(params, Strategy::Hessian).unwrap()
    }

    fn toy_options(optimizer: &str, workers: usize, zero1: bool,
                   zero2: bool, spec: Option<Vec<BlockView>>)
        -> DistOptions {
        DistOptions {
            workers,
            bucket_kb: 1,
            zero1,
            zero2,
            optimizer: optimizer.into(),
            spec,
            ..Default::default()
        }
    }

    /// Drive `steps` dist steps with `micro` micro-grads per step,
    /// mirroring the coordinator's i % N assignment; return params.
    /// `overlap` routes through the streaming pipeline instead of the
    /// batch-synchronous `step`.
    fn run_dist(optimizer: &str, workers: usize, zero1: bool,
                zero2: bool, overlap: bool, steps: usize, micro: usize)
        -> Vec<Tensor> {
        run_dist_opt(optimizer, workers, zero1, zero2, true, overlap,
                     steps, micro)
    }

    fn run_dist_opt(optimizer: &str, workers: usize, zero1: bool,
                    zero2: bool, bucket_step: bool, overlap: bool,
                    steps: usize, micro: usize) -> Vec<Tensor> {
        let (mut params, meta) = toy();
        let spec = if optimizer.starts_with("adam_mini") {
            Some(mini_spec(&params, &meta))
        } else {
            None
        };
        let mut opts = toy_options(optimizer, workers, zero1, zero2,
                                   spec);
        opts.bucket_step = bucket_step;
        let mut dist = DistTrainer::new(&params, opts).unwrap();
        let mut replicated = if zero1 || zero2 {
            None
        } else {
            Some(by_name(optimizer, Hyper::default(), &params, &meta)
                .unwrap())
        };
        let mut grng = Rng::new(77);
        for _ in 0..steps {
            let out = if overlap {
                let grads: Vec<Vec<Tensor>> = (0..micro)
                    .map(|_| rand_grads(&params, &mut grng))
                    .collect();
                let mut stream = dist.begin_step(micro, 1e-2);
                for (i, g) in grads.iter().enumerate() {
                    // Reverse span order — backward-pass readiness.
                    for j in (0..g.len()).rev() {
                        stream.push_grad(i, j, &g[j]).unwrap();
                    }
                }
                stream.finish(&mut params).unwrap()
            } else {
                let mut local = dist.grad_buffers();
                for i in 0..micro {
                    let g = rand_grads(&params, &mut grng);
                    dist.layout().accumulate(&mut local[i % workers],
                                             &g);
                }
                dist.step(&mut params, local, micro, 1e-2).unwrap()
            };
            if let (Some(opt), Some(g)) = (&mut replicated, out) {
                opt.step(&mut params, &g, 1e-2);
            }
        }
        params
    }

    /// Reference: single-replica host optimizer over the same
    /// micro-gradient stream (sum then average, coordinator-style).
    fn run_host(optimizer: &str, steps: usize, micro: usize)
        -> Vec<Tensor> {
        let (mut params, meta) = toy();
        let mut opt =
            by_name(optimizer, Hyper::default(), &params, &meta)
                .unwrap();
        let mut grng = Rng::new(77);
        for _ in 0..steps {
            let mut acc: Option<Vec<Tensor>> = None;
            for _ in 0..micro {
                let g = rand_grads(&params, &mut grng);
                acc = Some(match acc {
                    None => g,
                    Some(mut a) => {
                        for (x, y) in a.iter_mut().zip(&g) {
                            x.axpy(1.0, y);
                        }
                        a
                    }
                });
            }
            let mut g = acc.unwrap();
            let inv = 1.0 / micro as f32;
            for t in g.iter_mut() {
                for x in t.data.iter_mut() {
                    *x *= inv;
                }
            }
            opt.step(&mut params, &g, 1e-2);
        }
        params
    }

    #[test]
    fn zero1_matches_host_for_adamw_and_adam_mini() {
        for optimizer in ["adamw", "adam_mini"] {
            let reference = run_host(optimizer, 8, 6);
            for workers in [1usize, 2, 3, 5] {
                let got = run_dist(optimizer, workers, true, false,
                                   false, 8, 6);
                for (a, b) in reference.iter().zip(&got) {
                    let d = a.max_abs_diff(b);
                    assert!(d < 1e-4,
                            "{optimizer} x{workers} {}: drift {d}",
                            a.name);
                }
            }
        }
    }

    #[test]
    fn zero2_matches_host_in_both_pipelines() {
        for optimizer in ["adamw", "adam_mini"] {
            let reference = run_host(optimizer, 8, 6);
            for overlap in [false, true] {
                for workers in [1usize, 2, 4] {
                    let got = run_dist(optimizer, workers, true, true,
                                       overlap, 8, 6);
                    for (a, b) in reference.iter().zip(&got) {
                        let d = a.max_abs_diff(b);
                        assert!(d < 1e-4,
                                "{optimizer} x{workers} overlap \
                                 {overlap} {}: drift {d}", a.name);
                    }
                }
            }
        }
    }

    #[test]
    fn granular_and_deferred_zero2_agree_bitwise() {
        // Bucket-granular stepping changes WHEN segments step, never
        // the math: the streamed ZeRO-2 run with bucket_step on equals
        // the bucket_step=false run bit-for-bit.
        for optimizer in ["adamw", "adam_mini"] {
            for workers in [2usize, 4] {
                let on = run_dist_opt(optimizer, workers, true, true,
                                      true, true, 6, 4);
                let off = run_dist_opt(optimizer, workers, true, true,
                                       false, true, 6, 4);
                assert_eq!(on, off, "{optimizer} x{workers}");
            }
        }
    }

    #[test]
    fn streamed_zero1_matches_host() {
        for optimizer in ["adamw", "adam_mini"] {
            let reference = run_host(optimizer, 8, 6);
            for workers in [2usize, 3] {
                let got = run_dist(optimizer, workers, true, false,
                                   true, 8, 6);
                for (a, b) in reference.iter().zip(&got) {
                    let d = a.max_abs_diff(b);
                    assert!(d < 1e-4,
                            "{optimizer} x{workers} streamed {}: \
                             drift {d}", a.name);
                }
            }
        }
    }

    #[test]
    fn single_micro_batch_is_bit_exact_in_all_modes() {
        // With one micro-batch, idle workers contribute exact zeros:
        // every (pipeline × sharding) combination equals the host run
        // bitwise.
        for optimizer in ["adamw", "adam_mini"] {
            let reference = run_host(optimizer, 6, 1);
            for zero2 in [false, true] {
                for overlap in [false, true] {
                    let got = run_dist(optimizer, 4, true, zero2,
                                       overlap, 6, 1);
                    assert_eq!(reference, got,
                               "{optimizer} zero2={zero2} \
                                overlap={overlap}");
                }
            }
        }
    }

    #[test]
    fn replicated_mode_matches_host_for_non_shardable() {
        // LAMB is not elementwise → replicated fallback path, both
        // pipelines.
        let reference = run_host("lamb", 6, 4);
        for overlap in [false, true] {
            let got = run_dist("lamb", 3, false, false, overlap, 6, 4);
            for (a, b) in reference.iter().zip(&got) {
                let d = a.max_abs_diff(b);
                assert!(d < 1e-4,
                        "lamb overlap {overlap} {}: drift {d}", a.name);
            }
        }
    }

    #[test]
    fn sharded_modes_reject_non_shardable_optimizers() {
        let (params, _) = toy();
        for (zero1, zero2) in [(true, false), (false, true)] {
            let err = DistTrainer::new(&params, DistOptions {
                workers: 2,
                optimizer: "adafactor".into(),
                zero1,
                zero2,
                ..Default::default()
            });
            assert!(err.is_err(), "zero1={zero1} zero2={zero2}");
        }
    }

    #[test]
    fn zero2_moves_fewer_grad_bytes_than_zero1() {
        let run = |zero2: bool| {
            let (mut params, _) = toy();
            let mut dist = DistTrainer::new(
                &params,
                toy_options("adamw", 4, true, zero2, None)).unwrap();
            let mut local = dist.grad_buffers();
            let mut rng = Rng::new(5);
            let g = rand_grads(&params, &mut rng);
            dist.layout().accumulate(&mut local[0], &g);
            dist.step(&mut params, local, 1, 1e-2).unwrap();
            let s = dist.stats();
            (s.bytes(TrafficClass::GradReduce),
             s.bytes(TrafficClass::GradScatter),
             s.bytes(TrafficClass::ParamGather))
        };
        let total = 272 * 4; // toy flat bytes
        let (ar1, rs1, ag1) = run(false);
        assert_eq!(ar1, (2 * 3 * total) as u64);
        assert_eq!(rs1, 0);
        assert_eq!(ag1, (3 * total) as u64);
        let (ar2, rs2, ag2) = run(true);
        assert_eq!(ar2, 0, "ZeRO-2 must not log all-reduce bytes");
        assert_eq!(rs2, (3 * total) as u64);
        assert_eq!(ag2, (3 * total) as u64);
        // The schedule's headline: 2(N−1)P vs 3(N−1)P per step.
        assert!(rs2 + ag2 < ar1 + ag1);
    }

    #[test]
    fn granular_gather_bytes_match_deferred() {
        // Per-bucket all-gathers must sum to exactly the whole-gather
        // bytes: (N−1)·P either way.
        let run = |bucket_step: bool| {
            let (mut params, _) = toy();
            let mut opts = toy_options("adamw", 4, true, true, None);
            opts.bucket_step = bucket_step;
            let mut dist = DistTrainer::new(&params, opts).unwrap();
            assert_eq!(dist.granular(), bucket_step);
            let mut rng = Rng::new(5);
            let g = rand_grads(&params, &mut rng);
            let mut stream = dist.begin_step(1, 1e-2);
            for j in (0..g.len()).rev() {
                stream.push_grad(0, j, &g[j]).unwrap();
            }
            stream.finish(&mut params).unwrap();
            (dist.stats().bytes(TrafficClass::GradScatter),
             dist.stats().bytes(TrafficClass::ParamGather))
        };
        let (rs_on, ag_on) = run(true);
        let (rs_off, ag_off) = run(false);
        assert_eq!(rs_on, rs_off);
        assert_eq!(ag_on, ag_off);
        let total = 272 * 4;
        assert_eq!(ag_on, (3 * total) as u64);
    }

    #[test]
    fn streamed_step_reports_overlap_win() {
        let (mut params, _) = toy();
        // bucket_kb=1 → two readiness buckets for the toy layout.
        let mut dist = DistTrainer::new(
            &params, toy_options("adamw", 4, true, false, None))
            .unwrap();
        assert!(dist.plan().len() >= 2, "toy plan should bucket");
        assert!(dist.last_step_timing().is_none());
        let mut rng = Rng::new(9);
        let g = rand_grads(&params, &mut rng);
        let mut stream = dist.begin_step(1, 1e-2);
        for j in (0..g.len()).rev() {
            stream.push_grad(0, j, &g[j]).unwrap();
        }
        stream.finish(&mut params).unwrap();
        let t = dist.last_step_timing().unwrap();
        assert!(t.overlapped_ns < t.sequential_ns,
                "overlap {:.0} !< sequential {:.0}", t.overlapped_ns,
                t.sequential_ns);
        assert!(t.speedup() > 1.0);
        // ZeRO-1 defers the step: live == deferred comparator.
        assert!((t.overlapped_ns - t.deferred_ns).abs() < 1e-9);
    }

    #[test]
    fn streamed_step_rejects_missing_and_duplicate_gradients() {
        let (mut params, _) = toy();
        let mut dist = DistTrainer::new(
            &params, toy_options("adamw", 2, true, false, None))
            .unwrap();
        let mut rng = Rng::new(9);
        let g = rand_grads(&params, &mut rng);
        let mut stream = dist.begin_step(1, 1e-2);
        stream.push_grad(0, 2, &g[2]).unwrap();
        // A repeat of a final-micro gradient is an error, not a
        // silent pending-count underflow.
        assert!(stream.push_grad(0, 2, &g[2]).is_err());
        // Out-of-range indices error rather than panic.
        assert!(stream.push_grad(1, 0, &g[0]).is_err());
        assert!(stream.push_grad(0, 9, &g[0]).is_err());
        let err = stream.finish(&mut params);
        assert!(err.is_err(), "finish must flag unlaunched buckets");
    }

    #[test]
    fn sharded_state_roundtrips_through_transport() {
        let (mut params, meta) = toy();
        let spec = Some(mini_spec(&params, &meta));
        let make = |params: &[Tensor]| {
            DistTrainer::new(params, DistOptions {
                workers: 3,
                optimizer: "adam_mini".into(),
                spec: spec.clone(),
                ..Default::default()
            }).unwrap()
        };
        let mut a = make(&params);
        let mut grng = Rng::new(3);
        let mut step =
            |d: &mut DistTrainer, p: &mut Vec<Tensor>, r: &mut Rng| {
                let mut local = d.grad_buffers();
                let g = rand_grads(p, r);
                d.layout().accumulate(&mut local[0], &g);
                d.step(p, local, 1, 1e-2).unwrap();
            };
        for _ in 0..3 {
            step(&mut a, &mut params, &mut grng);
        }
        let state = a.sync_state().unwrap();
        assert!(!state.is_empty());
        // Every entry carries a rank prefix.
        assert!(state.keys().all(|k| k.starts_with("rank")));
        assert!(a.stats().bytes(TrafficClass::StateSync) > 0);
        // Import into a fresh engine; both continue identically.
        let mut params_b = params.clone();
        let mut b = make(&params_b);
        b.import_state(&state).unwrap();
        let mut grng_b = grng.clone();
        step(&mut a, &mut params, &mut grng);
        step(&mut b, &mut params_b, &mut grng_b);
        assert_eq!(params, params_b);
        // An unroutable entry is a loud error.
        let mut bogus = StateDict::new();
        for t in state.entries() {
            bogus.insert_tensor(t.clone());
        }
        bogus.insert("rank9/m", &[1], vec![0.0]);
        assert!(b.import_state(&bogus).is_err());
    }

    #[test]
    fn state_bytes_sum_to_the_replicated_total() {
        let (params, meta) = toy();
        let n: usize = params.iter().map(Tensor::numel).sum();
        let spec = mini_spec(&params, &meta);
        let blocks: usize =
            spec.iter().map(|b| b.num_blocks).sum();
        let dist = DistTrainer::new(&params, DistOptions {
            workers: 3,
            optimizer: "adam_mini".into(),
            spec: Some(spec),
            ..Default::default()
        }).unwrap();
        // m (n floats) + one v_b per block, regardless of sharding.
        assert_eq!(dist.state_bytes(), 4 * (n + blocks));
    }

    #[test]
    fn tcp_transport_matches_channel_bitwise() {
        use crate::dist::transport::SocketOptions;
        let run = |transport: TransportKind, overlap: bool| {
            let (mut params, meta) = toy();
            let spec = Some(mini_spec(&params, &meta));
            let mut opts =
                toy_options("adam_mini", 3, false, true, spec);
            opts.transport = transport;
            let mut dist = DistTrainer::new(&params, opts).unwrap();
            let mut grng = Rng::new(77);
            for _ in 0..3 {
                if overlap {
                    let grads: Vec<Vec<Tensor>> = (0..2)
                        .map(|_| rand_grads(&params, &mut grng))
                        .collect();
                    let mut stream = dist.begin_step(2, 1e-2);
                    for (i, g) in grads.iter().enumerate() {
                        for j in (0..g.len()).rev() {
                            stream.push_grad(i, j, &g[j]).unwrap();
                        }
                    }
                    stream.finish(&mut params).unwrap();
                } else {
                    let mut local = dist.grad_buffers();
                    for i in 0..2 {
                        let g = rand_grads(&params, &mut grng);
                        dist.layout()
                            .accumulate(&mut local[i % 3], &g);
                    }
                    dist.step(&mut params, local, 2, 1e-2).unwrap();
                }
            }
            params
        };
        for overlap in [false, true] {
            let chan = run(TransportKind::Channel, overlap);
            let sock = run(
                TransportKind::Socket(SocketOptions::default()),
                overlap);
            assert_eq!(chan, sock, "overlap={overlap}");
        }
    }

    /// run_dist with a wire codec active (always zero1 fallback on).
    fn run_dist_codec(optimizer: &str, workers: usize, zero2: bool,
                      overlap: bool, compress: &str, steps: usize,
                      micro: usize) -> Vec<Tensor> {
        let (mut params, meta) = toy();
        let spec = if optimizer.starts_with("adam_mini") {
            Some(mini_spec(&params, &meta))
        } else {
            None
        };
        let mut opts =
            toy_options(optimizer, workers, true, zero2, spec);
        opts.compress = CodecSpec::parse(compress).unwrap();
        let mut dist = DistTrainer::new(&params, opts).unwrap();
        let mut grng = Rng::new(77);
        for _ in 0..steps {
            if overlap {
                let grads: Vec<Vec<Tensor>> = (0..micro)
                    .map(|_| rand_grads(&params, &mut grng))
                    .collect();
                let mut stream = dist.begin_step(micro, 1e-2);
                for (i, g) in grads.iter().enumerate() {
                    for j in (0..g.len()).rev() {
                        stream.push_grad(i, j, &g[j]).unwrap();
                    }
                }
                stream.finish(&mut params).unwrap();
            } else {
                let mut local = dist.grad_buffers();
                for i in 0..micro {
                    let g = rand_grads(&params, &mut grng);
                    dist.layout()
                        .accumulate(&mut local[i % workers], &g);
                }
                dist.step(&mut params, local, micro, 1e-2).unwrap();
            }
        }
        params
    }

    #[test]
    fn f16_compression_tracks_the_host_run() {
        let reference = run_host("adamw", 6, 4);
        for zero2 in [false, true] {
            for overlap in [false, true] {
                let got = run_dist_codec("adamw", 4, zero2, overlap,
                                         "f16", 6, 4);
                for (a, b) in reference.iter().zip(&got) {
                    let d = a.max_abs_diff(b);
                    assert!(d < 2e-2,
                            "zero2={zero2} overlap={overlap} {}: \
                             drift {d}", a.name);
                }
            }
        }
    }

    #[test]
    fn topk_compression_learns_and_replicas_stay_identical() {
        let (mut params, _) = toy();
        let before = params.clone();
        let mut opts = toy_options("adamw", 4, true, true, None);
        opts.compress = CodecSpec::TopK { frac: 0.25 };
        let mut dist = DistTrainer::new(&params, opts).unwrap();
        let mut grng = Rng::new(7);
        for _ in 0..4 {
            let mut local = dist.grad_buffers();
            for i in 0..3 {
                let g = rand_grads(&params, &mut grng);
                dist.layout().accumulate(&mut local[i % 4], &g);
            }
            dist.step(&mut params, local, 3, 1e-2).unwrap();
        }
        // Params moved and stayed finite.
        for (a, b) in before.iter().zip(&params) {
            assert!(a.max_abs_diff(b) > 0.0, "{}: frozen", a.name);
        }
        for p in &params {
            assert!(p.data.iter().all(|v| v.is_finite()));
        }
        // Every replica holds identical bits (the dense all-gather
        // under topk), and every rank carries dropped mass.
        let flat0 = dist.slots[0].flat_params.clone();
        for (r, slot) in dist.slots.iter().enumerate().skip(1) {
            assert_eq!(slot.flat_params, flat0, "rank {r} diverged");
        }
        for (r, slot) in dist.slots.iter().enumerate() {
            let res = slot.residual.as_ref().unwrap();
            assert!(res.iter().any(|v| *v != 0.0),
                    "rank {r}: empty residual after lossy steps");
        }
        // Wire bytes land on the codec class; the all-gather stays
        // dense on its own class.
        assert!(dist.stats().bytes(TrafficClass::CodecTopK) > 0);
        assert!(dist.stats().bytes(TrafficClass::ParamGather) > 0);
        assert_eq!(dist.stats().bytes(TrafficClass::GradScatter), 0);
    }

    #[test]
    fn ef_residual_rides_the_checkpoint_roundtrip() {
        let (mut params, meta) = toy();
        let spec = Some(mini_spec(&params, &meta));
        let make = |params: &[Tensor]| {
            DistTrainer::new(params, DistOptions {
                workers: 3,
                optimizer: "adam_mini".into(),
                spec: spec.clone(),
                zero2: true,
                compress: CodecSpec::TopK { frac: 0.25 },
                ..Default::default()
            }).unwrap()
        };
        let mut a = make(&params);
        let mut grng = Rng::new(3);
        let mut step =
            |d: &mut DistTrainer, p: &mut Vec<Tensor>, r: &mut Rng| {
                let mut local = d.grad_buffers();
                let g = rand_grads(p, r);
                d.layout().accumulate(&mut local[0], &g);
                d.step(p, local, 1, 1e-2).unwrap();
            };
        for _ in 0..3 {
            step(&mut a, &mut params, &mut grng);
        }
        let state = a.sync_state().unwrap();
        for r in 0..3 {
            let key = format!("rank{r}/ef/residual");
            let t = state.get(&key).unwrap_or_else(|| {
                panic!("missing {key}")
            });
            assert_eq!(t.numel(), a.layout().total);
        }
        // Import restores the residual: both engines continue
        // bit-identically (the EF mass re-injects the same way).
        let mut params_b = params.clone();
        let mut b = make(&params_b);
        b.import_state(&state).unwrap();
        let mut grng_b = grng.clone();
        step(&mut a, &mut params, &mut grng);
        step(&mut b, &mut params_b, &mut grng_b);
        assert_eq!(params, params_b);
        // A residual entry with no residual slot to land in is loud.
        let mut plain = DistTrainer::new(&params, DistOptions {
            workers: 3,
            optimizer: "adam_mini".into(),
            spec: spec.clone(),
            zero2: true,
            ..Default::default()
        }).unwrap();
        assert!(plain.import_state(&state).is_err());
    }
}
