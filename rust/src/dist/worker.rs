//! The data-parallel engine: N in-process workers (threads) that
//! all-reduce gradients and step the optimizer in ZeRO-1 sharded or
//! replicated mode.
//!
//! Step contract (driver side):
//!
//! 1. The driver assigns each global micro-batch `i` of a step to
//!    worker `i % N` and accumulates per-worker UNNORMALIZED gradient
//!    sums into flat buffers (the batch stream is identical for every
//!    world size — the core N-vs-1 equivalence invariant).
//! 2. [`DistTrainer::step`] spawns one thread per worker: bucketed ring
//!    all-reduce of the gradient, scale by `1/n_micro`, then
//!    - **ZeRO-1**: step this worker's shard optimizer over its
//!      contiguous shard only, and ring-all-gather the updated
//!      parameters (every worker ends with the full updated replica);
//!    - **replicated**: return the reduced gradient — the identical
//!      per-replica update is executed once by the caller.
//!
//! With `n_micro <= 1` micro-batch the N-worker run is bit-identical
//! to the single-worker run (idle workers contribute exact zeros); with
//! several micro-batches it matches to float tolerance (ring summation
//! order differs from sequential accumulation).

use anyhow::{bail, Result};
use std::sync::Arc;

use super::allreduce::{ring_all_gather, ring_all_reduce};
use super::comm::{ring_world, CommStats, LinkModel, RingNode,
                  TrafficClass};
use super::shard::{block_cuts, build_shard_optimizer, pieces_for,
                   shard_spec, shardable, slice_shard, write_shard,
                   FlatLayout, Partition, SendOptimizer, ShardPiece};
use crate::optim::{Hyper, Optimizer, ReduceOp};
use crate::partition::BlockView;
use crate::tensor::Tensor;

/// Engine configuration (mirrors the `workers`/`bucket_kb`/`zero1`
/// config keys plus what optimizer construction needs).
pub struct DistOptions {
    pub workers: usize,
    pub bucket_kb: usize,
    /// Shard optimizer state (ZeRO-1). Requires a shardable optimizer;
    /// callers should fall back to replicated mode otherwise.
    pub zero1: bool,
    pub optimizer: String,
    pub reduce: ReduceOp,
    pub hp: Hyper,
    /// Full-space Adam-mini block views (required for `adam_mini*`).
    pub spec: Option<Vec<BlockView>>,
    pub link: LinkModel,
}

impl Default for DistOptions {
    fn default() -> Self {
        DistOptions {
            workers: 1,
            bucket_kb: 64,
            zero1: true,
            optimizer: "adamw".into(),
            reduce: ReduceOp::Mean,
            hp: Hyper::default(),
            spec: None,
            link: LinkModel::default(),
        }
    }
}

struct WorkerSlot {
    node: RingNode,
    /// ZeRO-1 only: this worker's shard optimizer.
    opt: Option<SendOptimizer>,
    pieces: Vec<ShardPiece>,
    /// Full parameter replica (ZeRO-1 only; kept in flat form).
    flat_params: Vec<f32>,
}

/// The multi-worker data-parallel trainer.
pub struct DistTrainer {
    layout: FlatLayout,
    partition: Partition,
    slots: Vec<WorkerSlot>,
    stats: Arc<CommStats>,
    bucket_elems: usize,
    zero1: bool,
    steps: u64,
}

impl DistTrainer {
    pub fn new(params: &[Tensor], opts: DistOptions)
        -> Result<DistTrainer> {
        let n = opts.workers;
        if n == 0 {
            bail!("workers must be >= 1");
        }
        if opts.zero1 && !shardable(&opts.optimizer) {
            bail!("{}: not ZeRO-1 shardable; use replicated mode",
                  opts.optimizer);
        }
        let layout = FlatLayout::of(params);
        let is_mini = opts.optimizer.starts_with("adam_mini");
        let partition = if !opts.zero1 {
            // Replicated mode still defines ranges (unused for comm).
            Partition::even(layout.total, n)
        } else if is_mini {
            let spec = opts.spec.as_ref().ok_or_else(|| {
                anyhow::anyhow!("adam_mini dist run needs a block spec")
            })?;
            Partition::aligned(&block_cuts(spec), n)
        } else {
            Partition::even(layout.total, n)
        };
        let (nodes, stats) = ring_world(n, opts.link);
        let flat = layout.flatten(params);
        let mut slots = Vec::with_capacity(n);
        for (w, node) in nodes.into_iter().enumerate() {
            let pieces = pieces_for(&layout, partition.ranges[w]);
            let opt = if opts.zero1 {
                let shard = slice_shard(&layout, &pieces, &flat);
                let spec = if is_mini {
                    Some(shard_spec(&layout, &pieces,
                                    opts.spec.as_ref().unwrap())?)
                } else {
                    None
                };
                Some(build_shard_optimizer(&opts.optimizer, opts.hp,
                                           &shard, spec, opts.reduce)?)
            } else {
                None
            };
            slots.push(WorkerSlot {
                node,
                opt,
                pieces,
                flat_params: if opts.zero1 { flat.clone() }
                             else { Vec::new() },
            });
        }
        Ok(DistTrainer {
            layout,
            partition,
            slots,
            stats,
            bucket_elems: (opts.bucket_kb.max(1) * 1024) / 4,
            zero1: opts.zero1,
            steps: 0,
        })
    }

    pub fn workers(&self) -> usize {
        self.slots.len()
    }

    pub fn layout(&self) -> &FlatLayout {
        &self.layout
    }

    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    pub fn is_zero1(&self) -> bool {
        self.zero1
    }

    pub fn stats(&self) -> &Arc<CommStats> {
        &self.stats
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Fresh per-worker gradient buffers for one step.
    pub fn grad_buffers(&self) -> Vec<Vec<f32>> {
        vec![vec![0.0f32; self.layout.total]; self.slots.len()]
    }

    /// Optimizer-state bytes held across all shards (ZeRO-1) — the
    /// cluster total, i.e. comparable to a replicated optimizer's
    /// `state_bytes`.
    pub fn state_bytes(&self) -> usize {
        self.slots
            .iter()
            .filter_map(|s| s.opt.as_ref().map(|o| o.state_bytes()))
            .sum()
    }

    /// One data-parallel step. `local_grads[w]` is worker `w`'s
    /// unnormalized gradient sum over its assigned micro-batches (zeros
    /// if it got none); `n_micro` is the GLOBAL micro-batch count the
    /// average divides by.
    ///
    /// ZeRO-1: `params` is updated in place and `None` is returned.
    /// Replicated: `params` is untouched and the reduced (averaged)
    /// gradient is returned for the caller's replicated update.
    pub fn step(&mut self, params: &mut [Tensor],
                mut local_grads: Vec<Vec<f32>>, n_micro: usize, lr: f32)
        -> Result<Option<Vec<Tensor>>> {
        let n = self.slots.len();
        if local_grads.len() != n {
            bail!("got {} grad buffers for {} workers",
                  local_grads.len(), n);
        }
        for (w, g) in local_grads.iter().enumerate() {
            if g.len() != self.layout.total {
                bail!("worker {w}: grad buffer {} != flat size {}",
                      g.len(), self.layout.total);
            }
        }
        self.steps += 1;
        let inv = 1.0 / n_micro.max(1) as f32;
        let bucket = self.bucket_elems;
        let zero1 = self.zero1;
        let layout = &self.layout;
        let ranges = &self.partition.ranges;
        let slots = &mut self.slots;
        std::thread::scope(|s| -> Result<()> {
            let handles: Vec<_> = slots
                .iter_mut()
                .zip(local_grads.iter_mut())
                .map(|(slot, grad)| {
                    s.spawn(move || {
                        ring_all_reduce(&slot.node, grad, bucket,
                                        TrafficClass::GradReduce);
                        for x in grad.iter_mut() {
                            *x *= inv;
                        }
                        if !zero1 {
                            return;
                        }
                        if let Some(opt) = &mut slot.opt {
                            let mut sp = slice_shard(
                                layout, &slot.pieces, &slot.flat_params);
                            let sg = slice_shard(
                                layout, &slot.pieces, grad);
                            opt.step(&mut sp, &sg, lr);
                            write_shard(layout, &slot.pieces, &sp,
                                        &mut slot.flat_params);
                        }
                        ring_all_gather(&slot.node, ranges,
                                        &mut slot.flat_params,
                                        TrafficClass::ParamGather);
                    })
                })
                .collect();
            for h in handles {
                h.join().map_err(|_| {
                    anyhow::anyhow!("dist worker thread panicked")
                })?;
            }
            Ok(())
        })?;
        if self.zero1 {
            self.layout.unflatten(&self.slots[0].flat_params, params);
            Ok(None)
        } else {
            // All ranks hold the identical reduced gradient; return
            // rank 0's as tensors for the replicated update.
            let mut grads: Vec<Tensor> = self
                .layout
                .spans
                .iter()
                .map(|sp| Tensor::zeros(&*sp.name, &sp.shape))
                .collect();
            self.layout.unflatten(&local_grads[0], &mut grads);
            Ok(Some(grads))
        }
    }

    /// Collect the full (sharded) optimizer state at rank 0 through the
    /// transport — the checkpoint path, accounted as `StateSync`
    /// traffic. Returns the assembled state tensor list (rank-major).
    /// Replicated mode moves no bytes and returns an empty list (the
    /// caller owns the replicated optimizer and exports it directly).
    pub fn sync_state(&mut self) -> Result<Vec<Tensor>> {
        if !self.zero1 {
            return Ok(Vec::new());
        }
        // Per-rank export metadata (names/shapes) — driver side; the
        // data itself travels through the gather link below.
        let metas: Vec<Vec<Tensor>> = self
            .slots
            .iter()
            .map(|s| {
                s.opt.as_ref().map(|o| o.state_export())
                    .unwrap_or_default()
            })
            .collect();
        let slots = &mut self.slots;
        let payloads: Vec<Option<Vec<Vec<f32>>>> =
            std::thread::scope(|s| {
                // iter_mut: a shared &WorkerSlot is !Send (the node
                // holds an mpsc Receiver); an exclusive borrow is Send.
                let handles: Vec<_> = slots
                    .iter_mut()
                    .zip(&metas)
                    .map(|(slot, meta)| {
                        s.spawn(move || {
                            let mut flat = Vec::new();
                            for t in meta {
                                flat.extend_from_slice(&t.data);
                            }
                            slot.node.gather_to_root(
                                TrafficClass::StateSync, flat)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("state-sync thread"))
                    .collect()
            });
        let gathered = payloads
            .into_iter()
            .flatten()
            .next()
            .ok_or_else(|| anyhow::anyhow!("rank 0 gathered nothing"))?;
        let mut out = Vec::new();
        for (meta, payload) in metas.iter().zip(gathered) {
            let mut off = 0;
            for t in meta {
                let n = t.numel();
                out.push(Tensor::new(&*t.name, &t.shape,
                                     payload[off..off + n].to_vec()));
                off += n;
            }
            debug_assert_eq!(off, payload.len());
        }
        Ok(out)
    }

    /// Inverse of [`DistTrainer::sync_state`]: route a gathered state
    /// list back into the shard optimizers (same world size and
    /// partition as the exporting run).
    pub fn import_state(&mut self, state: &[Tensor]) -> Result<()> {
        if !self.zero1 {
            if state.is_empty() {
                return Ok(());
            }
            bail!("replicated mode holds no sharded state to import");
        }
        let mut cursor = 0;
        for slot in self.slots.iter_mut() {
            let Some(opt) = &mut slot.opt else { continue };
            let count = opt.state_len();
            if cursor + count > state.len() {
                bail!("state list too short: need {} more tensors",
                      cursor + count - state.len());
            }
            opt.state_import(&state[cursor..cursor + count])?;
            cursor += count;
        }
        if cursor != state.len() {
            bail!("state list has {} extra tensors", state.len() - cursor);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{by_name, ModelMeta, Optimizer};
    use crate::partition::Strategy;
    use crate::util::prng::Rng;

    fn toy() -> (Vec<Tensor>, ModelMeta) {
        let mut rng = Rng::new(20);
        let params = vec![
            Tensor::randn("embed", &[16, 8], 0.5, &mut rng),
            Tensor::randn("wq", &[2, 8, 8], 0.5, &mut rng),
            Tensor::randn("attn_norm", &[2, 8], 0.5, &mut rng),
        ];
        let meta = ModelMeta {
            n_heads: 2,
            stacked: vec!["wq".into(), "attn_norm".into()],
        };
        (params, meta)
    }

    fn rand_grads(params: &[Tensor], rng: &mut Rng) -> Vec<Tensor> {
        params
            .iter()
            .map(|p| Tensor::randn(&*p.name, &p.shape, 0.3, rng))
            .collect()
    }

    fn mini_spec(params: &[Tensor], meta: &ModelMeta)
        -> Vec<BlockView> {
        meta.spec_for(params, Strategy::Hessian).unwrap()
    }

    /// Drive `steps` dist steps with `micro` micro-grads per step,
    /// mirroring the coordinator's i % N assignment; return params.
    fn run_dist(optimizer: &str, workers: usize, zero1: bool,
                steps: usize, micro: usize) -> Vec<Tensor> {
        let (mut params, meta) = toy();
        let spec = if optimizer.starts_with("adam_mini") {
            Some(mini_spec(&params, &meta))
        } else {
            None
        };
        let mut dist = DistTrainer::new(&params, DistOptions {
            workers,
            bucket_kb: 1,
            zero1,
            optimizer: optimizer.into(),
            spec,
            ..Default::default()
        }).unwrap();
        let mut replicated = if zero1 {
            None
        } else {
            Some(by_name(optimizer, Hyper::default(), &params, &meta)
                .unwrap())
        };
        let mut grng = Rng::new(77);
        for _ in 0..steps {
            let mut local = dist.grad_buffers();
            for i in 0..micro {
                let g = rand_grads(&params, &mut grng);
                dist.layout().accumulate(&mut local[i % workers], &g);
            }
            let out =
                dist.step(&mut params, local, micro, 1e-2).unwrap();
            if let (Some(opt), Some(g)) = (&mut replicated, out) {
                opt.step(&mut params, &g, 1e-2);
            }
        }
        params
    }

    /// Reference: single-replica host optimizer over the same
    /// micro-gradient stream (sum then average, coordinator-style).
    fn run_host(optimizer: &str, steps: usize, micro: usize)
        -> Vec<Tensor> {
        let (mut params, meta) = toy();
        let mut opt =
            by_name(optimizer, Hyper::default(), &params, &meta)
                .unwrap();
        let mut grng = Rng::new(77);
        for _ in 0..steps {
            let mut acc: Option<Vec<Tensor>> = None;
            for _ in 0..micro {
                let g = rand_grads(&params, &mut grng);
                acc = Some(match acc {
                    None => g,
                    Some(mut a) => {
                        for (x, y) in a.iter_mut().zip(&g) {
                            x.axpy(1.0, y);
                        }
                        a
                    }
                });
            }
            let mut g = acc.unwrap();
            let inv = 1.0 / micro as f32;
            for t in g.iter_mut() {
                for x in t.data.iter_mut() {
                    *x *= inv;
                }
            }
            opt.step(&mut params, &g, 1e-2);
        }
        params
    }

    #[test]
    fn zero1_matches_host_for_adamw_and_adam_mini() {
        for optimizer in ["adamw", "adam_mini"] {
            let reference = run_host(optimizer, 8, 6);
            for workers in [1usize, 2, 3, 5] {
                let got = run_dist(optimizer, workers, true, 8, 6);
                for (a, b) in reference.iter().zip(&got) {
                    let d = a.max_abs_diff(b);
                    assert!(d < 1e-4,
                            "{optimizer} x{workers} {}: drift {d}",
                            a.name);
                }
            }
        }
    }

    #[test]
    fn single_micro_batch_is_bit_exact_across_world_sizes() {
        // With one micro-batch, idle workers contribute exact zeros:
        // the N-worker ZeRO-1 run equals the host run bitwise.
        for optimizer in ["adamw", "adam_mini"] {
            let reference = run_host(optimizer, 6, 1);
            let got = run_dist(optimizer, 4, true, 6, 1);
            assert_eq!(reference, got, "{optimizer}");
        }
    }

    #[test]
    fn replicated_mode_matches_host_for_non_shardable() {
        // LAMB is not elementwise → replicated fallback path.
        let reference = run_host("lamb", 6, 4);
        let got = run_dist("lamb", 3, false, 6, 4);
        for (a, b) in reference.iter().zip(&got) {
            let d = a.max_abs_diff(b);
            assert!(d < 1e-4, "lamb {}: drift {d}", a.name);
        }
    }

    #[test]
    fn zero1_rejects_non_shardable_optimizers() {
        let (params, _) = toy();
        let err = DistTrainer::new(&params, DistOptions {
            workers: 2,
            optimizer: "adafactor".into(),
            zero1: true,
            ..Default::default()
        });
        assert!(err.is_err());
    }

    #[test]
    fn sharded_state_roundtrips_through_transport() {
        let (mut params, meta) = toy();
        let spec = Some(mini_spec(&params, &meta));
        let make = |params: &[Tensor]| {
            DistTrainer::new(params, DistOptions {
                workers: 3,
                optimizer: "adam_mini".into(),
                spec: spec.clone(),
                ..Default::default()
            }).unwrap()
        };
        let mut a = make(&params);
        let mut grng = Rng::new(3);
        let mut step =
            |d: &mut DistTrainer, p: &mut Vec<Tensor>, r: &mut Rng| {
                let mut local = d.grad_buffers();
                let g = rand_grads(p, r);
                d.layout().accumulate(&mut local[0], &g);
                d.step(p, local, 1, 1e-2).unwrap();
            };
        for _ in 0..3 {
            step(&mut a, &mut params, &mut grng);
        }
        let state = a.sync_state().unwrap();
        assert!(!state.is_empty());
        assert!(a.stats().bytes(TrafficClass::StateSync) > 0);
        // Import into a fresh engine; both continue identically.
        let mut params_b = params.clone();
        let mut b = make(&params_b);
        b.import_state(&state).unwrap();
        let mut grng_b = grng.clone();
        step(&mut a, &mut params, &mut grng);
        step(&mut b, &mut params_b, &mut grng_b);
        assert_eq!(params, params_b);
    }

    #[test]
    fn state_bytes_sum_to_the_replicated_total() {
        let (params, meta) = toy();
        let n: usize = params.iter().map(Tensor::numel).sum();
        let spec = mini_spec(&params, &meta);
        let blocks: usize =
            spec.iter().map(|b| b.num_blocks).sum();
        let dist = DistTrainer::new(&params, DistOptions {
            workers: 3,
            optimizer: "adam_mini".into(),
            spec: Some(spec),
            ..Default::default()
        }).unwrap();
        // m (n floats) + one v_b per block, regardless of sharding.
        assert_eq!(dist.state_bytes(), 4 * (n + blocks));
    }
}

