//! Typed failures of the distributed engine.
//!
//! Every comm-layer and worker-thread failure surfaces as a
//! [`DistError`] naming the rank where it was observed (and the peer
//! that caused it, when there is one), instead of the join-panics the
//! engine used to die with. `DistError` implements `std::error::Error`,
//! so `?` lifts it into the `anyhow::Result` plumbing everywhere else.

use std::fmt;

/// A failure in the distributed engine, attributed to a rank.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DistError {
    /// A peer's end of a link closed (process death, dropped node).
    PeerDisconnected { rank: usize, peer: usize },
    /// A send exhausted its retry budget without an acknowledgement.
    Timeout {
        rank: usize,
        peer: usize,
        class: &'static str,
        attempts: usize,
    },
    /// A worker thread panicked; the panic payload is lost but the
    /// rank is not.
    WorkerPanicked { rank: usize },
    /// A worker process exited with a non-zero status.
    WorkerExited { rank: usize, code: i32 },
    /// A worker's comm thread hung up mid-step (its job queue closed
    /// before the step finished streaming).
    CommHangup { rank: usize },
    /// Transport-level I/O failure not covered above.
    Io { rank: usize, msg: String },
}

impl DistError {
    /// The rank that observed the failure.
    pub fn rank(&self) -> usize {
        match self {
            DistError::PeerDisconnected { rank, .. }
            | DistError::Timeout { rank, .. }
            | DistError::WorkerPanicked { rank }
            | DistError::WorkerExited { rank, .. }
            | DistError::CommHangup { rank }
            | DistError::Io { rank, .. } => *rank,
        }
    }
}

impl fmt::Display for DistError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DistError::PeerDisconnected { rank, peer } => write!(
                f,
                "rank {rank}: peer rank {peer} disconnected"
            ),
            DistError::Timeout { rank, peer, class, attempts } => {
                write!(
                    f,
                    "rank {rank}: {class} send to rank {peer} timed \
                     out after {attempts} attempts"
                )
            }
            DistError::WorkerPanicked { rank } => {
                write!(f, "dist worker thread for rank {rank} panicked")
            }
            DistError::WorkerExited { rank, code } => write!(
                f,
                "dist worker process for rank {rank} exited with \
                 status {code}"
            ),
            DistError::CommHangup { rank } => write!(
                f,
                "rank {rank}: comm thread hung up mid-step"
            ),
            DistError::Io { rank, msg } => {
                write!(f, "rank {rank}: transport i/o error: {msg}")
            }
        }
    }
}

impl std::error::Error for DistError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn errors_name_the_rank() {
        let cases: Vec<(DistError, usize)> = vec![
            (DistError::PeerDisconnected { rank: 1, peer: 2 }, 1),
            (
                DistError::Timeout {
                    rank: 3,
                    peer: 0,
                    class: "grad_reduce",
                    attempts: 10,
                },
                3,
            ),
            (DistError::WorkerPanicked { rank: 2 }, 2),
            (DistError::WorkerExited { rank: 4, code: 1 }, 4),
            (DistError::CommHangup { rank: 0 }, 0),
            (DistError::Io { rank: 5, msg: "broken pipe".into() }, 5),
        ];
        for (e, rank) in cases {
            assert_eq!(e.rank(), rank);
            let msg = e.to_string();
            assert!(
                msg.contains(&format!("rank {rank}")),
                "{msg:?} should name rank {rank}"
            );
        }
    }

    #[test]
    fn lifts_into_anyhow() {
        fn fails() -> anyhow::Result<()> {
            Err(DistError::PeerDisconnected { rank: 0, peer: 3 })?;
            Ok(())
        }
        let err = fails().unwrap_err();
        assert!(err.downcast_ref::<DistError>().is_some());
        assert!(err.to_string().contains("peer rank 3"));
    }
}
