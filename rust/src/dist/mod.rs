//! Data-parallel training engine (multi-threaded, in-process workers)
//! with ZeRO-1/2 sharding, bucketed ring collectives, and a streaming
//! overlap pipeline.
//!
//! The paper's headline systems claim (§3.4, Fig 1a, Table 2) is that
//! halving optimizer state admits larger per-GPU batches and cuts the
//! bytes moved when optimizer state is sharded/synchronized. The
//! analytical `cluster.rs` simulator *models* that; this subsystem
//! *executes* it: real worker threads, real byte-accounted messages,
//! real sharded state — so measured traffic can be cross-checked
//! against the model (`repro report`, [`traffic_report`]).
//!
//! Layers:
//!
//! - [`comm`] — channel transport: ring + gather links, per-class
//!   byte/message/latency accounting ([`comm::CommStats`]), and
//!   nonblocking collective handles ([`comm::CollectiveHandle`]).
//! - [`allreduce`] — bucketed ring all-reduce, reduce-scatter and
//!   all-gather over flat `f32` segments (cluster traffic:
//!   `2(N−1)·P`, `(N−1)·P` and `(N−1)·P` bytes).
//! - [`bucket`] — the readiness-bucket scheduler: carves the flat
//!   gradient into per-tensor buckets (reverse parameter order — the
//!   backward pass's production order) and models the overlapped vs
//!   sequential step timelines.
//! - [`shard`] — ZeRO partitioner: contiguous shards of the flattened
//!   parameter space, aligned to Hessian-block boundaries for
//!   Adam-mini, plus per-shard optimizer construction.
//! - [`worker`] — [`DistTrainer`]: splits the global batch across
//!   workers and executes one of three schedules (replicated
//!   all-reduce; ZeRO-1 all-reduce + shard step + all-gather; ZeRO-2
//!   reduce-scatter + shard step + all-gather), either
//!   batch-synchronously ([`DistTrainer::step`]) or as a streaming
//!   bucket pipeline ([`DistTrainer::begin_step`]) that launches each
//!   bucket's collective the moment its last gradient lands.
//!
//! Adam-mini's sharding-aware fast path falls out of the state layout:
//! its shard state is `m` plus ONE `v_b` scalar per Hessian block, so
//! state-sync traffic is ~half of AdamW's `m`+`v` — the measurable
//! form of the paper's communication-reduction argument. ZeRO-2 adds
//! the gradient-side saving: `2(N−1)·P` step bytes vs ZeRO-1's
//! `3(N−1)·P`.
//!
//! Core invariant (tested in `tests/dist.rs`): an N-worker run with
//! the same global batch and seed matches the 1-worker run's loss
//! curve to float tolerance — in every (schedule × pipeline)
//! combination, bit-exactly for single-micro-batch steps.

pub mod allreduce;
pub mod bucket;
pub mod comm;
pub mod compress;
pub mod error;
pub mod shard;
pub mod transport;
pub mod worker;

pub use bucket::{BucketPlan, ComputeModel, OverlapTimeline, StepTiming};
pub use comm::{CollectiveDone, CollectiveHandle, CommStats, LinkModel,
               TrafficClass};
pub use compress::{Codec, CodecSpec, CodedRing};
pub use error::DistError;
pub use shard::{shardable, FlatLayout, Partition};
pub use transport::{parse_transport, FaultSpec, SocketOptions,
                    TimeoutPolicy, TransportKind};
pub use worker::{DistOptions, DistTrainer, StepMode, StepStream};

use anyhow::Result;

use crate::cluster::{ring_allgather_bytes, ring_allreduce_bytes,
                     ring_reducescatter_bytes, ADAMW_PROFILE,
                     ADAM_MINI_PROFILE};
use crate::optim::{self, Hyper, ModelMeta, ReduceOp};
use crate::partition::{partition_spec, Strategy};
use crate::telemetry::{Event, Telemetry, DEFAULT_BUS_CAPACITY};
use crate::tensor::Tensor;
use crate::util::csv::ascii_table;
use crate::util::json::Json;
use crate::util::prng::Rng;

/// The probe inventory used by the traffic report and the all-reduce
/// bench: a ~1.6M-param transformer shape set (t1m6-like).
pub fn probe_params(seed: u64) -> (Vec<Tensor>, usize) {
    let mut rng = Rng::new(seed);
    let (l, d, ff, v) = (6usize, 128usize, 512usize, 256usize);
    let params = vec![
        Tensor::randn("embed", &[v, d], 0.02, &mut rng),
        Tensor::randn("wq", &[l, d, d], 0.02, &mut rng),
        Tensor::randn("wk", &[l, d, d], 0.02, &mut rng),
        Tensor::randn("wv", &[l, d, d], 0.02, &mut rng),
        Tensor::randn("wo", &[l, d, d], 0.02, &mut rng),
        Tensor::randn("w1", &[l, ff, d], 0.02, &mut rng),
        Tensor::randn("w3", &[l, ff, d], 0.02, &mut rng),
        Tensor::randn("w2", &[l, d, ff], 0.02, &mut rng),
        Tensor::ones("attn_norm", &[l, d]),
        Tensor::ones("mlp_norm", &[l, d]),
        Tensor::ones("final_norm", &[d]),
        Tensor::randn("output", &[v, d], 0.02, &mut rng),
    ];
    let n = params.iter().map(Tensor::numel).sum();
    (params, n)
}

/// Model metadata matching [`probe_params`].
pub fn probe_meta() -> ModelMeta {
    ModelMeta {
        n_heads: 8,
        stacked: ["wq", "wk", "wv", "wo", "w1", "w3", "w2", "attn_norm",
                  "mlp_norm"].iter().map(|s| s.to_string()).collect(),
    }
}

fn probe_spec(params: &[Tensor]) -> Result<Vec<crate::partition::BlockView>> {
    let shapes: Vec<(String, Vec<usize>)> = params
        .iter()
        .map(|p| (p.name.clone(), p.shape.clone()))
        .collect();
    let meta = probe_meta();
    partition_spec(&shapes, meta.n_heads, &meta.stacked,
                   Strategy::Hessian)
}

/// Probe-inventory [`DistTrainer`]: adam_mini with ZeRO-1 state
/// sharding, bucket-granular stepping, and the `zero2` gradient
/// schedule lever — the configuration every telemetry probe drives.
fn probe_trainer(workers: usize, zero2: bool)
    -> Result<(DistTrainer, Vec<Tensor>)> {
    let (params, _) = probe_params(0xD157);
    let spec = Some(probe_spec(&params)?);
    let dist = DistTrainer::new(&params, DistOptions {
        workers,
        bucket_kb: 64,
        zero1: true,
        zero2,
        bucket_step: true,
        optimizer: "adam_mini".into(),
        reduce: ReduceOp::Mean,
        hp: Hyper::default(),
        spec,
        ..Default::default()
    })?;
    Ok((dist, params))
}

/// One streamed probe step: synthetic gradients pushed in reverse
/// parameter order (the backward pass's production order), through
/// the overlapped bucket pipeline.
fn stream_probe_step(dist: &mut DistTrainer, params: &mut Vec<Tensor>,
                     rng: &mut Rng, lr: f32) -> Result<()> {
    let grads: Vec<Tensor> = params
        .iter()
        .map(|p| Tensor::randn(&*p.name, &p.shape, 0.01, rng))
        .collect();
    let mut stream = dist.begin_step(1, lr);
    for j in (0..grads.len()).rev() {
        stream.push_grad(0, j, &grads[j])?;
    }
    stream.finish(params)?;
    Ok(())
}

/// Record a real telemetry trace without needing model artifacts:
/// drive the probe inventory through the streamed ZeRO pipeline with
/// a bus attached and every event written to a JSONL trace at `path`
/// (the `repro top --record` backend). Returns (published, dropped)
/// bus counts.
pub fn record_probe_trace(path: impl AsRef<std::path::Path>,
                          workers: usize, steps: usize, zero2: bool)
    -> Result<(u64, u64)> {
    let (mut dist, mut params) = probe_trainer(workers, zero2)?;
    let mut tel = Telemetry::with_trace(DEFAULT_BUS_CAPACITY, &path)?;
    let bus = tel.bus();
    dist.attach_bus(tel.bus());
    let mut rng = Rng::new(7);
    for s in 0..steps {
        let lr = 1e-4;
        stream_probe_step(&mut dist, &mut params, &mut rng, lr)?;
        // Synthetic cluster loss so the console sparkline has a
        // curve to draw (deterministic decay, no wall clock).
        bus.publish(Event::LossReported {
            step: (s + 1) as u64,
            rank: -1,
            loss: 1.0 + 4.5 * (-0.15 * s as f64).exp(),
            lr: lr as f64,
        });
        tel.pump()?;
    }
    tel.finish_mut()?;
    Ok((bus.published(), bus.dropped()))
}

/// Live `repro top` backend (no artifacts needed): drive the probe
/// inventory through the streamed pipeline on this thread while a
/// spawned console thread pumps and renders the shared telemetry.
pub fn probe_top_live(workers: usize, steps: usize, zero2: bool,
                      interval_ms: u64) -> Result<()> {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Arc, Mutex};

    let (mut dist, mut params) = probe_trainer(workers, zero2)?;
    let tel = Arc::new(Mutex::new(Telemetry::new(DEFAULT_BUS_CAPACITY)));
    let bus = tel.lock().unwrap_or_else(|e| e.into_inner()).bus();
    dist.attach_bus(Arc::clone(&bus));
    let done = Arc::new(AtomicBool::new(false));
    let console = {
        let tel = Arc::clone(&tel);
        let done = Arc::clone(&done);
        std::thread::spawn(move || {
            crate::telemetry::top::live_loop(&tel, &done, interval_ms);
        })
    };
    let mut rng = Rng::new(7);
    for s in 0..steps {
        let lr = 1e-4;
        stream_probe_step(&mut dist, &mut params, &mut rng, lr)?;
        bus.publish(Event::LossReported {
            step: (s + 1) as u64,
            rank: -1,
            loss: 1.0 + 4.5 * (-0.15 * s as f64).exp(),
            lr: lr as f64,
        });
        // Pace the probe so the console has time to draw each step.
        std::thread::sleep(std::time::Duration::from_millis(
            interval_ms.clamp(20, 150)));
    }
    done.store(true, Ordering::Relaxed);
    console.join().ok();
    println!("live probe done: {} steps, {} events published, {} \
              dropped", steps, bus.published(), bus.dropped());
    Ok(())
}

/// Measured vs `cluster.rs`-modeled traffic for one optimizer on the
/// probe inventory.
#[derive(Debug, Clone)]
pub struct TrafficRow {
    pub optimizer: String,
    pub class: &'static str,
    pub measured_bytes: f64,
    pub modeled_bytes: f64,
}

impl TrafficRow {
    pub fn delta_pct(&self) -> f64 {
        if self.modeled_bytes == 0.0 {
            return 0.0;
        }
        100.0 * (self.measured_bytes - self.modeled_bytes)
            / self.modeled_bytes
    }
}

/// Run a few sharded steps of the probe model through the real engine
/// and report measured bytes/step per traffic class next to the
/// closed-form `cluster.rs` prediction. `zero2` picks the gradient
/// schedule: reduce-scatter (ZeRO-2) or all-reduce (ZeRO-1). Needs no
/// artifacts. Each phase is attributed to its own class — the
/// measured grad_reduce and grad_scatter columns are mutually
/// exclusive by construction, never double-counted.
pub fn measure_traffic(optimizer: &str, workers: usize, bucket_kb: usize,
                       steps: usize, zero2: bool)
    -> Result<Vec<TrafficRow>> {
    let (mut params, n_params) = probe_params(0xD157);
    let is_mini = optimizer.starts_with("adam_mini");
    let spec = if is_mini { Some(probe_spec(&params)?) } else { None };
    let opts = DistOptions {
        workers,
        bucket_kb,
        zero1: true,
        zero2,
        optimizer: optimizer.into(),
        reduce: ReduceOp::Mean,
        hp: Hyper::default(),
        spec,
        ..Default::default()
    };
    let mut dist = DistTrainer::new(&params, opts)?;
    let before = dist.stats().snapshot();
    let mut rng = Rng::new(1);
    for _ in 0..steps {
        let mut bufs = dist.grad_buffers();
        for b in bufs.iter_mut() {
            for x in b.iter_mut() {
                *x = rng.normal_f32(0.01);
            }
        }
        dist.step(&mut params, bufs, workers, 1e-4)?;
    }
    let after_steps = dist.stats().snapshot();
    dist.sync_state()?;
    let after_sync = dist.stats().snapshot();

    let payload = (n_params * 4) as f64;
    let profile = if is_mini { ADAM_MINI_PROFILE } else { ADAMW_PROFILE };
    // State-sync gathers every non-root shard: (N−1)/N of the state.
    let sync_frac = (workers - 1) as f64 / workers as f64;
    let per_step = |class: TrafficClass| {
        before.delta(&after_steps, class) as f64 / steps as f64
    };
    let rows = vec![
        TrafficRow {
            optimizer: optimizer.into(),
            class: TrafficClass::GradReduce.name(),
            measured_bytes: per_step(TrafficClass::GradReduce),
            modeled_bytes: if zero2 {
                0.0
            } else {
                ring_allreduce_bytes(payload, workers)
            },
        },
        TrafficRow {
            optimizer: optimizer.into(),
            class: TrafficClass::GradScatter.name(),
            measured_bytes: per_step(TrafficClass::GradScatter),
            modeled_bytes: if zero2 {
                ring_reducescatter_bytes(payload, workers)
            } else {
                0.0
            },
        },
        TrafficRow {
            optimizer: optimizer.into(),
            class: TrafficClass::ParamGather.name(),
            measured_bytes: per_step(TrafficClass::ParamGather),
            modeled_bytes: ring_allgather_bytes(payload, workers),
        },
        TrafficRow {
            optimizer: optimizer.into(),
            class: TrafficClass::StateSync.name(),
            measured_bytes: after_steps.delta(
                &after_sync, TrafficClass::StateSync) as f64,
            modeled_bytes: profile.state_sync_payload(n_params as f64)
                * sync_frac,
        },
    ];
    Ok(rows)
}

/// The `repro report` section: measured vs modeled bytes for AdamW and
/// Adam-mini on the probe inventory, 4 sharded workers, both gradient
/// schedules (ZeRO-1 all-reduce vs ZeRO-2 reduce-scatter). Also writes
/// the machine-readable mirror `results/report.json` (traffic rows,
/// summaries, the modeled [`StepTiming`] of a streamed probe step,
/// and the per-class ledger snapshot).
pub fn traffic_report() -> Result<()> {
    let (workers, bucket_kb, steps) = (4, 64, 3);
    let (_, n_params) = probe_params(0xD157);
    println!("\nDist traffic: measured (in-process engine, {workers} \
              sharded workers, {n_params} params) vs cluster.rs model");
    let mut table = Vec::new();
    let mut json_rows = Vec::new();
    let mut state_sync = Vec::new();
    // AdamW step bytes per schedule [zero1, zero2] — the headline
    // reduce-scatter saving printed under the table.
    let mut step_bytes = [0.0f64; 2];
    for (si, zero2) in [(0usize, false), (1usize, true)] {
        for optimizer in ["adamw", "adam_mini"] {
            let schedule = if zero2 { "zero2" } else { "zero1" };
            for row in measure_traffic(optimizer, workers, bucket_kb,
                                       steps, zero2)? {
                // Skip the structurally-zero grad phase of the other
                // schedule to keep the table readable.
                let zero_phase = (zero2
                    && row.class == TrafficClass::GradReduce.name())
                    || (!zero2
                        && row.class == TrafficClass::GradScatter.name());
                if zero_phase && row.measured_bytes == 0.0 {
                    continue;
                }
                if row.class == TrafficClass::StateSync.name() {
                    if !zero2 {
                        state_sync.push(row.measured_bytes);
                    }
                } else if optimizer == "adamw" {
                    step_bytes[si] += row.measured_bytes;
                }
                table.push(vec![
                    row.optimizer.clone(),
                    schedule.to_string(),
                    row.class.to_string(),
                    format!("{:.0}", row.measured_bytes),
                    format!("{:.0}", row.modeled_bytes),
                    format!("{:+.2}%", row.delta_pct()),
                ]);
                json_rows.push(Json::obj(vec![
                    ("optimizer", Json::str(&row.optimizer)),
                    ("schedule", Json::str(schedule)),
                    ("class", Json::str(row.class)),
                    ("measured_bytes", Json::num(row.measured_bytes)),
                    ("modeled_bytes", Json::num(row.modeled_bytes)),
                    ("delta_pct", Json::num(row.delta_pct())),
                ]));
            }
        }
    }
    println!("{}", ascii_table(
        &["Optimizer", "Schedule", "Traffic class", "Measured B/step",
          "Modeled B/step", "Delta"], &table));
    println!("(state_sync rows are bytes per sync event — the sharded \
              checkpoint gather; others are per training step)");
    let (aw, am) = (state_sync[0], state_sync[1]);
    println!("state-sync bytes: adam_mini {am:.0} vs adamw {aw:.0} \
              ({:.1}% less)  {}",
             100.0 * (1.0 - am / aw),
             if am < aw { "[OK: Adam-mini moves strictly fewer \
                           state-sync bytes]" }
             else { "[FAIL]" });
    let (z1, z2) = (step_bytes[0], step_bytes[1]);
    println!("step bytes (adamw): zero2 {z2:.0} vs zero1 {z1:.0} \
              ({:.1}% less)  {}",
             100.0 * (1.0 - z2 / z1),
             if z2 < z1 { "[OK: reduce-scatter schedule moves \
                           strictly fewer bytes]" }
             else { "[FAIL]" });
    state_dict_schema_report()?;

    // One streamed ZeRO-2 probe step for the timing/ledger sections.
    let (timing, ledger) = {
        let (mut dist, mut params) = probe_trainer(workers, true)?;
        let mut rng = Rng::new(11);
        stream_probe_step(&mut dist, &mut params, &mut rng, 1e-4)?;
        (dist.last_step_timing(), dist.stats().to_json())
    };
    std::fs::create_dir_all(crate::experiments::RESULTS_DIR)?;
    let report = Json::obj(vec![
        ("schema", Json::num(1)),
        ("workers", Json::num(workers as f64)),
        ("probe_params", Json::num(n_params as f64)),
        ("traffic", Json::Arr(json_rows)),
        ("state_sync_bytes", Json::obj(vec![
            ("adamw", Json::num(aw)),
            ("adam_mini", Json::num(am)),
        ])),
        ("step_bytes_adamw", Json::obj(vec![
            ("zero1", Json::num(z1)),
            ("zero2", Json::num(z2)),
        ])),
        ("step_timing",
         timing.map(|t| t.to_json()).unwrap_or(Json::Null)),
        ("ledger", ledger),
    ]);
    let out = format!("{}/report.json", crate::experiments::RESULTS_DIR);
    std::fs::write(&out, report.to_string())?;
    println!("wrote {out}");
    Ok(())
}

/// Measured vs modeled step bytes for one codec on the probe
/// inventory (summed over every per-step traffic class, so coded and
/// dense phases both count).
#[derive(Debug, Clone)]
pub struct CompressionRow {
    pub codec: String,
    pub schedule: &'static str,
    pub measured_bytes: f64,
    pub modeled_bytes: f64,
    /// Measured step bytes over the dense closed form — the realized
    /// compression ratio against the f32 baseline.
    pub ratio_vs_f32: f64,
}

impl CompressionRow {
    pub fn delta_pct(&self) -> f64 {
        if self.modeled_bytes == 0.0 {
            return 0.0;
        }
        100.0 * (self.measured_bytes - self.modeled_bytes)
            / self.modeled_bytes
    }
}

/// Run sharded probe steps under a codec and report measured step
/// bytes next to the `cluster.rs` compressed closed form. The codec's
/// own traffic class carries the coded hops; phases a codec leaves
/// dense (top-k broadcasts) stay on their base class — the sum over
/// all five per-step classes is the comparable total.
pub fn measure_compressed_traffic(compress: CodecSpec, workers: usize,
                                  bucket_kb: usize, steps: usize,
                                  zero2: bool) -> Result<CompressionRow> {
    let (mut params, n_params) = probe_params(0xD157);
    let spec = Some(probe_spec(&params)?);
    let opts = DistOptions {
        workers,
        bucket_kb,
        zero1: true,
        zero2,
        optimizer: "adam_mini".into(),
        reduce: ReduceOp::Mean,
        hp: Hyper::default(),
        spec,
        compress,
        ..Default::default()
    };
    let mut dist = DistTrainer::new(&params, opts)?;
    let before = dist.stats().snapshot();
    let mut rng = Rng::new(2);
    for _ in 0..steps {
        let mut bufs = dist.grad_buffers();
        for b in bufs.iter_mut() {
            for x in b.iter_mut() {
                *x = rng.normal_f32(0.01);
            }
        }
        dist.step(&mut params, bufs, workers, 1e-4)?;
    }
    let after = dist.stats().snapshot();
    let measured = [
        TrafficClass::GradReduce,
        TrafficClass::GradScatter,
        TrafficClass::ParamGather,
        TrafficClass::CodecF16,
        TrafficClass::CodecTopK,
    ]
    .iter()
    .map(|&c| before.delta(&after, c) as f64)
    .sum::<f64>()
        / steps as f64;
    let payload = (n_params * 4) as f64;
    let frac = match compress {
        CodecSpec::TopK { frac } => frac as f64,
        _ => 0.0,
    };
    let modeled = crate::cluster::compressed_step_bytes(
        payload, workers, zero2, compress.name(), frac);
    let dense = crate::cluster::compressed_step_bytes(
        payload, workers, zero2, "none", 0.0);
    Ok(CompressionRow {
        codec: compress.config_key(),
        schedule: if zero2 { "zero2" } else { "zero1" },
        measured_bytes: measured,
        modeled_bytes: modeled,
        ratio_vs_f32: if dense > 0.0 { measured / dense } else { 0.0 },
    })
}

/// The `repro report` compression section: measured vs modeled step
/// bytes for every codec on the probe inventory, both gradient
/// schedules, plus the realized ratio against the f32 baseline.
/// Writes the machine-readable mirror
/// `results/compress_report.json`.
pub fn compression_report() -> Result<()> {
    let (workers, bucket_kb, steps) = (4, 64, 2);
    println!("\nCompressed collectives: measured (in-process engine, \
              {workers} sharded workers) vs cluster.rs model");
    let mut table = Vec::new();
    let mut json_rows = Vec::new();
    for zero2 in [false, true] {
        for spec in [CodecSpec::None, CodecSpec::F16,
                     CodecSpec::TopK { frac: 0.25 }] {
            let row = measure_compressed_traffic(
                spec, workers, bucket_kb, steps, zero2)?;
            table.push(vec![
                row.codec.clone(),
                row.schedule.to_string(),
                format!("{:.0}", row.measured_bytes),
                format!("{:.0}", row.modeled_bytes),
                format!("{:+.2}%", row.delta_pct()),
                format!("{:.3}x", row.ratio_vs_f32),
            ]);
            json_rows.push(Json::obj(vec![
                ("codec", Json::str(&row.codec)),
                ("schedule", Json::str(row.schedule)),
                ("measured_bytes", Json::num(row.measured_bytes)),
                ("modeled_bytes", Json::num(row.modeled_bytes)),
                ("delta_pct", Json::num(row.delta_pct())),
                ("ratio_vs_f32", Json::num(row.ratio_vs_f32)),
            ]));
        }
    }
    println!("{}", ascii_table(
        &["Codec", "Schedule", "Measured B/step", "Modeled B/step",
          "Delta", "vs f32"], &table));
    println!("(top-k ships 8-byte index/value pairs on the sum hops \
              and leaves broadcasts dense; f16 halves every phase)");
    std::fs::create_dir_all(crate::experiments::RESULTS_DIR)?;
    let out = format!("{}/compress_report.json",
                      crate::experiments::RESULTS_DIR);
    std::fs::write(&out, Json::obj(vec![
        ("schema", Json::num(1)),
        ("workers", Json::num(workers as f64)),
        ("compression", Json::Arr(json_rows)),
    ]).to_string())?;
    println!("wrote {out}");
    Ok(())
}

/// Print each probe optimizer's named state-dict schema — the wire
/// format checkpointing and the ZeRO state router move (replaces the
/// old fragile positional `m…, vb…, __step` convention).
fn state_dict_schema_report() -> Result<()> {
    let (params, _) = probe_params(0xD157);
    let meta = probe_meta();
    println!("\nstate-dict schema (host optimizers, probe inventory):");
    let mut rows = Vec::new();
    for name in ["adamw", "adam_mini", "sgd", "lion"] {
        let opt = optim::by_name(name, Hyper::default(), &params,
                                 &meta)?;
        let sd = opt.state_dict();
        let mut keys: Vec<&str> = sd.keys().take(4).collect();
        if sd.len() > 4 {
            keys.push("...");
        }
        rows.push(vec![
            name.to_string(),
            sd.len().to_string(),
            format!("{:.1} KB", sd.total_elems() as f64 * 4.0 / 1e3),
            keys.join(", "),
        ]);
    }
    println!("{}", ascii_table(
        &["Optimizer", "Entries", "State bytes", "Keys"], &rows));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measured_traffic_matches_closed_forms() {
        for zero2 in [false, true] {
            let rows =
                measure_traffic("adamw", 3, 16, 2, zero2).unwrap();
            for row in &rows {
                if row.class == "state_sync" {
                    // Model omits the per-shard step counters; allow
                    // slack.
                    assert!(row.delta_pct().abs() < 1.0,
                            "{}: {row:?}", row.class);
                } else {
                    assert_eq!(row.measured_bytes, row.modeled_bytes,
                               "zero2={zero2} {}: {row:?}", row.class);
                }
            }
        }
    }

    #[test]
    fn compressed_traffic_matches_closed_forms_within_10pct() {
        for zero2 in [false, true] {
            for spec in [CodecSpec::F16,
                         CodecSpec::TopK { frac: 0.25 }] {
                let row = measure_compressed_traffic(
                    spec, 3, 16, 1, zero2).unwrap();
                assert!(row.delta_pct().abs() < 10.0,
                        "zero2={zero2} {row:?}");
                assert!(row.ratio_vs_f32 < 1.0, "{row:?}");
            }
            // compress=none keeps the dense pipeline exact.
            let none = measure_compressed_traffic(
                CodecSpec::None, 3, 16, 1, zero2).unwrap();
            assert_eq!(none.measured_bytes, none.modeled_bytes);
            assert_eq!(none.ratio_vs_f32, 1.0);
        }
    }

    #[test]
    fn zero2_grad_traffic_is_attributed_not_lumped() {
        let pick = |rows: &[TrafficRow], class: &str| {
            rows.iter()
                .find(|r| r.class == class)
                .unwrap()
                .measured_bytes
        };
        let z1 = measure_traffic("adamw", 4, 64, 1, false).unwrap();
        let z2 = measure_traffic("adamw", 4, 64, 1, true).unwrap();
        // ZeRO-1 uses only the all-reduce class, ZeRO-2 only the
        // reduce-scatter class — and the latter moves half the bytes.
        assert!(pick(&z1, "grad_reduce") > 0.0);
        assert_eq!(pick(&z1, "grad_scatter"), 0.0);
        assert_eq!(pick(&z2, "grad_reduce"), 0.0);
        assert!(pick(&z2, "grad_scatter") > 0.0);
        assert_eq!(pick(&z2, "grad_scatter"),
                   0.5 * pick(&z1, "grad_reduce"));
        // Param-gather traffic is identical across schedules.
        assert_eq!(pick(&z1, "param_gather"), pick(&z2, "param_gather"));
    }

    #[test]
    fn adam_mini_state_sync_strictly_smaller() {
        let aw = measure_traffic("adamw", 2, 64, 1, false).unwrap();
        let am = measure_traffic("adam_mini", 2, 64, 1, false).unwrap();
        let pick = |rows: &[TrafficRow]| {
            rows.iter()
                .find(|r| r.class == "state_sync")
                .unwrap()
                .measured_bytes
        };
        assert!(pick(&am) < 0.6 * pick(&aw),
                "mini {} vs adamw {}", pick(&am), pick(&aw));
    }
}
