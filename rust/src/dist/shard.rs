//! ZeRO sharding: the flattened parameter space, its contiguous
//! per-worker partition, and construction of per-shard optimizers.
//!
//! The flat space itself IS the optimizer layer's [`Arena`]
//! (`optim::core`) — re-exported here as [`FlatLayout`] — so shard
//! optimizers step their ranges directly through
//! `Optimizer::step_segment` views with no tensor-list clone
//! round-trips. Each worker owns one contiguous range of the flat
//! space, holds optimizer state ONLY for that range, steps only its
//! range, and all-gathers updated parameters afterwards. Correctness
//! requires the sharded update to equal the replicated one, which
//! holds when
//!
//! - the update is elementwise (AdamW, SGD, Lion, AdaGrad), with any
//!   shard boundary, or
//! - the update is blockwise on gradients every worker already has
//!   post-reduction (Adam-mini), with shard boundaries aligned to
//!   Hessian-block boundaries — [`block_cuts`] + [`Partition::aligned`].
//!
//! Optimizers whose update couples a whole tensor (LAMB's trust ratio,
//! Adafactor's row/column factors) are not shardable this way; the
//! engine falls back to replicated mode for them (see `worker.rs`).

use anyhow::{bail, Result};

use crate::optim::extra::AdaGrad;
use crate::optim::{AdamMini, AdamW, Hyper, Lion, Optimizer, ReduceOp,
                   Sgd};
use crate::partition::BlockView;
use crate::tensor::Tensor;

pub use crate::optim::core::{Arena as FlatLayout, Span};

/// A `Send` host optimizer (worker threads own their shard optimizer).
pub type SendOptimizer = Box<dyn Optimizer + Send>;

/// Contiguous per-worker ranges covering `[0, total)`.
#[derive(Debug, Clone)]
pub struct Partition {
    pub ranges: Vec<(usize, usize)>,
}

impl Partition {
    /// Exact even split (elementwise-safe optimizers).
    pub fn even(total: usize, workers: usize) -> Partition {
        assert!(workers >= 1);
        let ranges = (0..workers)
            .map(|w| (w * total / workers, (w + 1) * total / workers))
            .collect();
        Partition { ranges }
    }

    /// Balanced split whose boundaries are drawn from `cuts` (sorted,
    /// starting at 0 and ending at `total`). Workers may get an empty
    /// range when there are fewer atoms than workers.
    pub fn aligned(cuts: &[usize], workers: usize) -> Partition {
        assert!(workers >= 1);
        assert!(!cuts.is_empty() && cuts[0] == 0);
        let total = *cuts.last().unwrap();
        let mut bounds = Vec::with_capacity(workers + 1);
        bounds.push(0);
        for w in 1..workers {
            let target = w * total / workers;
            // Nearest cut to the ideal boundary, kept monotone.
            let idx = cuts.partition_point(|&c| c < target);
            let cand_hi = cuts.get(idx).copied().unwrap_or(total);
            let cand_lo = if idx > 0 { cuts[idx - 1] } else { 0 };
            let pick = if target - cand_lo <= cand_hi - target {
                cand_lo
            } else {
                cand_hi
            };
            bounds.push(pick.max(*bounds.last().unwrap()));
        }
        bounds.push(total);
        let ranges =
            bounds.windows(2).map(|w| (w[0], w[1])).collect();
        Partition { ranges }
    }

    pub fn total(&self) -> usize {
        self.ranges.last().map(|r| r.1).unwrap_or(0)
    }
}

/// Flat-space cut points at every Hessian-block boundary of a spec
/// (includes 0 and total — the valid ZeRO boundaries for Adam-mini).
pub fn block_cuts(spec: &[BlockView]) -> Vec<usize> {
    let mut cuts = vec![0];
    let mut offset = 0;
    for bv in spec {
        for b in 1..=bv.num_blocks {
            cuts.push(offset + b * bv.block_size);
        }
        offset += bv.num_blocks * bv.block_size;
    }
    cuts
}

/// One contiguous piece of a worker's shard, within a single tensor.
#[derive(Debug, Clone)]
pub struct ShardPiece {
    /// Index into `FlatLayout::spans`.
    pub span: usize,
    /// Element range within that tensor.
    pub lo: usize,
    pub hi: usize,
}

impl ShardPiece {
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    pub fn is_empty(&self) -> bool {
        self.hi == self.lo
    }
}

/// Intersect a worker's flat range with the tensor spans.
pub fn pieces_for(layout: &FlatLayout, range: (usize, usize))
    -> Vec<ShardPiece> {
    let (a, b) = range;
    let mut pieces = Vec::new();
    for (i, s) in layout.spans.iter().enumerate() {
        let lo = a.max(s.offset);
        let hi = b.min(s.offset + s.len);
        if lo < hi {
            pieces.push(ShardPiece {
                span: i,
                lo: lo - s.offset,
                hi: hi - s.offset,
            });
        }
    }
    pieces
}

/// Materialize a worker's shard of `flat` as 1-D named tensors (the
/// shard optimizer's constructor inventory — its sub-arena; the step
/// path itself works on flat views, not on these).
pub fn slice_shard(layout: &FlatLayout, pieces: &[ShardPiece],
                   flat: &[f32]) -> Vec<Tensor> {
    pieces
        .iter()
        .map(|p| {
            let s = &layout.spans[p.span];
            Tensor::new(
                format!("{}[{}..{}]", s.name, p.lo, p.hi),
                &[p.len()],
                flat[s.offset + p.lo..s.offset + p.hi].to_vec(),
            )
        })
        .collect()
}

/// Per-piece Adam-mini block views. Piece boundaries must be aligned to
/// the parent tensor's block grid (guaranteed by [`Partition::aligned`]
/// over [`block_cuts`]).
pub fn shard_spec(layout: &FlatLayout, pieces: &[ShardPiece],
                  full_spec: &[BlockView]) -> Result<Vec<BlockView>> {
    assert_eq!(layout.spans.len(), full_spec.len());
    pieces
        .iter()
        .map(|p| {
            let bv = &full_spec[p.span];
            let bs = bv.block_size;
            if p.lo % bs != 0 || p.hi % bs != 0 {
                bail!("{}: shard [{}, {}) not aligned to block size {bs}",
                      bv.name, p.lo, p.hi);
            }
            Ok(BlockView {
                name: format!("{}[{}..{}]", bv.name, p.lo, p.hi),
                shape: vec![p.len()],
                num_blocks: p.len() / bs,
                block_size: bs,
                category: bv.category,
            })
        })
        .collect()
}

/// True if `optimizer` admits an exact ZeRO sharded update.
pub fn shardable(optimizer: &str) -> bool {
    optimizer.starts_with("adam_mini")
        || matches!(optimizer, "adamw" | "sgd" | "lion" | "adagrad")
}

/// Build the optimizer instance for one worker's shard. The shard
/// tensors become the optimizer's (shard-local) arena.
///
/// `spec` is required for (and only for) `adam_mini*` — the per-piece
/// block views from [`shard_spec`].
pub fn build_shard_optimizer(optimizer: &str, hp: Hyper,
                             shard_params: &[Tensor],
                             spec: Option<Vec<BlockView>>,
                             reduce: ReduceOp) -> Result<SendOptimizer> {
    Ok(if optimizer.starts_with("adam_mini") {
        let spec = spec.ok_or_else(|| {
            anyhow::anyhow!("adam_mini shard needs a block spec")
        })?;
        Box::new(AdamMini::new(hp, spec, reduce))
    } else {
        match optimizer {
            "adamw" => Box::new(AdamW::new(hp, shard_params)),
            "sgd" => Box::new(Sgd::new(0.9, shard_params)),
            "lion" => Box::new(Lion::new(hp, shard_params)),
            "adagrad" => {
                Box::new(AdaGrad::new(shard_params, 0.9, hp.eps))
            }
            other => bail!(
                "{other:?} is not ZeRO shardable (non-elementwise \
                 update); run with zero1=false"),
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn toy_params(rng: &mut Rng) -> Vec<Tensor> {
        vec![
            Tensor::randn("embed", &[8, 4], 0.5, rng),
            Tensor::randn("wq", &[2, 4, 4], 0.5, rng),
            Tensor::randn("final_norm", &[4], 0.5, rng),
        ]
    }

    #[test]
    fn flatten_unflatten_roundtrip() {
        let mut rng = Rng::new(0);
        let params = toy_params(&mut rng);
        let layout = FlatLayout::of(&params);
        assert_eq!(layout.total, 32 + 32 + 4);
        let flat = layout.flatten(&params);
        let mut back = params
            .iter()
            .map(|p| Tensor::zeros(&*p.name, &p.shape))
            .collect::<Vec<_>>();
        layout.unflatten(&flat, &mut back);
        assert_eq!(back, params);
    }

    #[test]
    fn accumulate_adds_in_place() {
        let mut rng = Rng::new(1);
        let params = toy_params(&mut rng);
        let layout = FlatLayout::of(&params);
        let mut flat = vec![0.0; layout.total];
        layout.accumulate(&mut flat, &params);
        layout.accumulate(&mut flat, &params);
        let twice = layout.flatten(&params)
            .iter().map(|x| 2.0 * x).collect::<Vec<_>>();
        assert_eq!(flat, twice);
    }

    #[test]
    fn even_partition_covers_and_balances() {
        for workers in 1..6 {
            let p = Partition::even(103, workers);
            assert_eq!(p.ranges.len(), workers);
            assert_eq!(p.ranges[0].0, 0);
            assert_eq!(p.total(), 103);
            for w in p.ranges.windows(2) {
                assert_eq!(w[0].1, w[1].0);
            }
            for &(a, b) in &p.ranges {
                let len = b - a;
                assert!(len >= 103 / workers && len <= 103 / workers + 1);
            }
        }
    }

    #[test]
    fn aligned_partition_only_cuts_at_atoms() {
        let cuts = vec![0, 10, 20, 30, 64, 100];
        for workers in 1..8 {
            let p = Partition::aligned(&cuts, workers);
            assert_eq!(p.ranges.len(), workers);
            assert_eq!(p.ranges[0].0, 0);
            assert_eq!(p.total(), 100);
            for w in p.ranges.windows(2) {
                assert_eq!(w[0].1, w[1].0);
            }
            for &(a, b) in &p.ranges {
                assert!(cuts.contains(&a) && cuts.contains(&b),
                        "workers {workers}: boundary ({a}, {b})");
            }
        }
    }

    #[test]
    fn more_workers_than_atoms_yields_empty_shards() {
        let p = Partition::aligned(&[0, 50, 100], 5);
        assert_eq!(p.ranges.len(), 5);
        assert_eq!(p.total(), 100);
        let nonempty =
            p.ranges.iter().filter(|(a, b)| b > a).count();
        assert!(nonempty <= 2);
    }

    #[test]
    fn block_cuts_enumerate_every_block_boundary() {
        let spec = vec![
            BlockView { name: "a".into(), shape: vec![4, 3],
                        num_blocks: 4, block_size: 3,
                        category: crate::partition::Category::TokenRow },
            BlockView { name: "b".into(), shape: vec![6],
                        num_blocks: 1, block_size: 6,
                        category: crate::partition::Category::Whole },
        ];
        assert_eq!(block_cuts(&spec), vec![0, 3, 6, 9, 12, 18]);
    }

    #[test]
    fn pieces_slice_shard_views() {
        let mut rng = Rng::new(2);
        let params = toy_params(&mut rng);
        let layout = FlatLayout::of(&params);
        let flat = layout.flatten(&params);
        // A range straddling embed's tail and wq's head.
        let pieces = pieces_for(&layout, (24, 40));
        assert_eq!(pieces.len(), 2);
        assert_eq!((pieces[0].lo, pieces[0].hi), (24, 32));
        assert_eq!((pieces[1].lo, pieces[1].hi), (0, 8));
        assert!(pieces.iter().all(|p| !p.is_empty()));
        let shard = slice_shard(&layout, &pieces, &flat);
        assert_eq!(shard[0].data, flat[24..32].to_vec());
        assert_eq!(shard[1].data, flat[32..40].to_vec());
        assert_eq!(shard[0].name, "embed[24..32]");
        assert_eq!(shard[1].name, "wq[0..8]");
    }

    #[test]
    fn shard_spec_requires_block_alignment() {
        let mut rng = Rng::new(3);
        let params = toy_params(&mut rng);
        let layout = FlatLayout::of(&params);
        let full_spec: Vec<BlockView> = params
            .iter()
            .map(|p| {
                let n = p.numel();
                BlockView { name: p.name.clone(), shape: p.shape.clone(),
                            num_blocks: n / 4, block_size: 4,
                            category: crate::partition::Category::Whole }
            })
            .collect();
        let ok = pieces_for(&layout, (8, 32));
        let spec = shard_spec(&layout, &ok, &full_spec).unwrap();
        assert_eq!(spec.len(), 1);
        assert_eq!(spec[0].num_blocks, 6);
        let bad = pieces_for(&layout, (6, 32));
        assert!(shard_spec(&layout, &bad, &full_spec).is_err());
    }

    #[test]
    fn shardable_whitelist() {
        for name in ["adamw", "adam_mini", "adam_mini_default", "sgd",
                     "lion", "adagrad"] {
            assert!(shardable(name), "{name}");
        }
        for name in ["lamb", "adafactor", "came", "galore"] {
            assert!(!shardable(name), "{name}");
        }
    }
}
