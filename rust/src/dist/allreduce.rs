//! Ring collectives over flat `f32` segments.
//!
//! The all-reduce is the classic two-phase ring (reduce-scatter then
//! all-gather), processed in fixed-size buckets so peak message size —
//! and therefore per-worker staging memory — is bounded by `bucket_kb`
//! regardless of model size. Cluster-total traffic is exactly
//! `2·(N−1)·payload` bytes for all-reduce and `(N−1)·payload` for
//! all-gather, independent of bucket size — the closed forms mirrored
//! by `cluster.rs` and cross-checked in the traffic report.
//!
//! Determinism: each chunk is accumulated in a fixed ring order, so a
//! run is bit-reproducible for a given world size. The order differs
//! from a naive left-to-right sum, which is why cross-world-size
//! comparisons are to float tolerance, not bit-exact.
//!
//! Every collective returns `Result`: a dead or hung peer surfaces as
//! a typed [`DistError`] from the underlying link instead of a panic,
//! and the worker layer decides how to unwind the step.

use super::comm::{RingNode, TrafficClass};
use super::compress::CodedRing;
use super::error::DistError;

/// Balanced split of `len` elements into `n` chunks: chunk `c` is
/// `[c*len/n, (c+1)*len/n)` (sizes differ by at most one).
pub fn chunk_range(len: usize, n: usize, c: usize) -> (usize, usize) {
    (c * len / n, (c + 1) * len / n)
}

/// In-place ring all-reduce (sum) of `data` across the world, processed
/// in buckets of at most `bucket_elems` elements. Every rank ends with
/// the identical (bitwise) elementwise sum.
pub fn ring_all_reduce(node: &mut RingNode, data: &mut [f32],
                       bucket_elems: usize, class: TrafficClass)
    -> Result<(), DistError> {
    ring_all_reduce_coded(node, data, bucket_elems, class, None)
}

/// All-reduce with an optional compression context. Summation hops go
/// through [`CodedRing::encode_sum`] (error-feedback residuals indexed
/// by each chunk's offset into `data`); gather-phase hops are
/// compressed only when the codec compresses broadcast payloads, in
/// which case the owning rank first quantizes its own completed chunk
/// in place so every replica ends the collective holding identical
/// bits. With `ctx == None` the statements executed are exactly the
/// pre-codec pipeline — `compress=none` stays bit-exact.
pub fn ring_all_reduce_coded(node: &mut RingNode, data: &mut [f32],
                             bucket_elems: usize, class: TrafficClass,
                             mut ctx: Option<&mut CodedRing>)
    -> Result<(), DistError> {
    if node.world <= 1 || data.is_empty() {
        return Ok(());
    }
    let bucket = bucket_elems.max(1);
    let mut off = 0;
    while off < data.len() {
        let hi = (off + bucket).min(data.len());
        bucket_all_reduce(node, &mut data[off..hi], off, class,
                          ctx.as_deref_mut())?;
        off = hi;
    }
    Ok(())
}

/// One bucket: reduce-scatter (N−1 steps) + all-gather (N−1 steps).
/// `base` is the bucket's offset into the full buffer — the index the
/// error-feedback residual (which spans the full buffer) is keyed by.
fn bucket_all_reduce(node: &mut RingNode, buf: &mut [f32], base: usize,
                     class: TrafficClass,
                     mut ctx: Option<&mut CodedRing>)
    -> Result<(), DistError> {
    let (n, r) = (node.world, node.rank);
    // Reduce-scatter: after step s, the partial for chunk (r−s−1) has
    // accumulated s+2 ranks' contributions at rank r. After N−1 steps
    // rank r holds the complete sum for chunk (r+1) mod n.
    for s in 0..n - 1 {
        let send_c = (r + n - s) % n;
        let (lo, hi) = chunk_range(buf.len(), n, send_c);
        match &mut ctx {
            Some(c) => {
                let wire = c.encode_sum(&buf[lo..hi], base + lo);
                node.send_right(c.codec.class(), wire)?;
            }
            None => node.send_right(class, buf[lo..hi].to_vec())?,
        }
        let recv_c = (r + n - s - 1) % n;
        let (lo, hi) = chunk_range(buf.len(), n, recv_c);
        let incoming = node.recv_left()?;
        let incoming = match &ctx {
            Some(c) => c.decode(&incoming, hi - lo),
            None => incoming,
        };
        debug_assert_eq!(incoming.len(), hi - lo);
        for (x, y) in buf[lo..hi].iter_mut().zip(&incoming) {
            *x += y;
        }
    }
    // All-gather: circulate completed chunks. Forwarded hops re-encode
    // already-quantized data (lossless projection), so the owner-side
    // quantize keeps all replicas bit-identical.
    let coded_bcast =
        matches!(&ctx, Some(c) if c.codec.compresses_broadcast());
    if coded_bcast {
        if let Some(c) = &mut ctx {
            let (lo, hi) = chunk_range(buf.len(), n, (r + 1) % n);
            c.quantize_in_place(&mut buf[lo..hi]);
        }
    }
    for s in 0..n - 1 {
        let send_c = (r + 1 + n - s) % n;
        let (lo, hi) = chunk_range(buf.len(), n, send_c);
        match &mut ctx {
            Some(c) if coded_bcast => {
                let wire = c.encode_copy(&buf[lo..hi]);
                node.send_right(c.codec.class(), wire)?;
            }
            _ => node.send_right(class, buf[lo..hi].to_vec())?,
        }
        let recv_c = (r + n - s) % n;
        let (lo, hi) = chunk_range(buf.len(), n, recv_c);
        let incoming = node.recv_left()?;
        let incoming = match &ctx {
            Some(c) if coded_bcast => c.decode(&incoming, hi - lo),
            _ => incoming,
        };
        debug_assert_eq!(incoming.len(), hi - lo);
        buf[lo..hi].copy_from_slice(&incoming);
    }
    Ok(())
}

/// Ring reduce-scatter over a flat buffer partitioned into per-rank
/// chunks: `chunks[w]` is the contiguous range rank `w` ends up owning
/// the complete elementwise sum of. Chunks must be sorted, contiguous
/// and cover the buffer; they may be ragged or empty (the ZeRO-2 shard
/// map clipped to a bucket). Regions outside rank r's own chunk hold
/// partial sums on return — garbage to the caller.
///
/// Cluster-total traffic: `(N−1)·payload` bytes — half an all-reduce,
/// the byte saving the ZeRO-2 schedule banks every step.
pub fn ring_reduce_scatter(node: &mut RingNode,
                           chunks: &[(usize, usize)], buf: &mut [f32],
                           class: TrafficClass) -> Result<(), DistError> {
    ring_reduce_scatter_coded(node, chunks, buf, class, None)
}

/// Reduce-scatter with an optional compression context. Every hop is
/// a summation payload, so each send goes through
/// [`CodedRing::encode_sum`]; the residual is indexed by the chunk's
/// offset into `buf`. With `ctx == None` this executes exactly the
/// pre-codec statements.
pub fn ring_reduce_scatter_coded(node: &mut RingNode,
                                 chunks: &[(usize, usize)],
                                 buf: &mut [f32], class: TrafficClass,
                                 ctx: Option<&mut CodedRing>)
    -> Result<(), DistError> {
    reduce_scatter_window(node, chunks, buf, 0, class, ctx)
}

/// The reduce-scatter kernel. `base` is the window's offset into the
/// flat space the error-feedback residual is keyed by (0 for a
/// whole-buffer call; the window start for the bucketed variant).
fn reduce_scatter_window(node: &mut RingNode,
                         chunks: &[(usize, usize)], buf: &mut [f32],
                         base: usize, class: TrafficClass,
                         mut ctx: Option<&mut CodedRing>)
    -> Result<(), DistError> {
    let (n, r) = (node.world, node.rank);
    assert_eq!(chunks.len(), n, "one chunk per rank");
    if n <= 1 {
        return Ok(());
    }
    debug_assert_eq!(chunks[0].0, 0, "chunks must start at 0");
    debug_assert_eq!(chunks[n - 1].1, buf.len(),
                     "chunks must cover the buffer");
    // Step s: send chunk (r+n−1−s), receive + accumulate chunk
    // (r+n−2−s). After N−1 steps rank r holds the complete sum of
    // chunk r, accumulated in ring order v(r+1), v(r+2), …, v(r) —
    // fixed by ring position, so runs are bit-reproducible for a
    // given world size.
    for s in 0..n - 1 {
        let send_c = (r + n - 1 - s) % n;
        let (lo, hi) = chunks[send_c];
        match &mut ctx {
            Some(c) => {
                let wire = c.encode_sum(&buf[lo..hi], base + lo);
                node.send_right(c.codec.class(), wire)?;
            }
            None => node.send_right(class, buf[lo..hi].to_vec())?,
        }
        let recv_c = (r + n - 2 - s) % n;
        let (lo, hi) = chunks[recv_c];
        let incoming = node.recv_left()?;
        let incoming = match &ctx {
            Some(c) => c.decode(&incoming, hi - lo),
            None => incoming,
        };
        debug_assert_eq!(incoming.len(), hi - lo);
        for (x, y) in buf[lo..hi].iter_mut().zip(&incoming) {
            *x += y;
        }
    }
    Ok(())
}

/// Clip sorted contiguous per-rank `ranges` to the window `[lo, hi)`,
/// re-based to window-relative offsets. Ranges outside the window
/// degenerate to empty chunks at the window edge, so the result still
/// covers the window contiguously — the chunk map a windowed
/// reduce-scatter needs.
pub fn clip_ranges(ranges: &[(usize, usize)], lo: usize, hi: usize)
    -> Vec<(usize, usize)> {
    ranges
        .iter()
        .map(|&(a, b)| (a.clamp(lo, hi) - lo, b.clamp(lo, hi) - lo))
        .collect()
}

/// Bucketed whole-buffer reduce-scatter: the flat space is processed
/// in windows of at most `bucket_elems` elements; inside each window
/// the chunk boundaries are the global per-rank `ranges` clipped to
/// the window. Peak message size is bounded like the bucketed
/// all-reduce; cluster-total traffic stays `(N−1)·payload` regardless
/// of bucket size.
pub fn ring_reduce_scatter_bucketed(node: &mut RingNode,
                                    ranges: &[(usize, usize)],
                                    buf: &mut [f32], bucket_elems: usize,
                                    class: TrafficClass)
    -> Result<(), DistError> {
    ring_reduce_scatter_bucketed_coded(node, ranges, buf, bucket_elems,
                                       class, None)
}

/// Bucketed reduce-scatter with an optional compression context. The
/// residual is keyed by offsets into the full `buf`, so each window
/// passes its start offset down as the residual base.
pub fn ring_reduce_scatter_bucketed_coded(node: &mut RingNode,
                                          ranges: &[(usize, usize)],
                                          buf: &mut [f32],
                                          bucket_elems: usize,
                                          class: TrafficClass,
                                          mut ctx: Option<&mut CodedRing>)
    -> Result<(), DistError> {
    if node.world <= 1 || buf.is_empty() {
        return Ok(());
    }
    let bucket = bucket_elems.max(1);
    let mut off = 0;
    while off < buf.len() {
        let hi = (off + bucket).min(buf.len());
        let clipped = clip_ranges(ranges, off, hi);
        reduce_scatter_window(node, &clipped, &mut buf[off..hi], off,
                              class, ctx.as_deref_mut())?;
        off = hi;
    }
    Ok(())
}

/// Ring all-gather over a shared flat buffer partitioned into per-rank
/// ranges (`ranges[w]` = the slice rank `w` is authoritative for; the
/// ZeRO-1 shard map). On return every rank's `buf` holds every range's
/// up-to-date contents. Ranges may be empty.
pub fn ring_all_gather(node: &mut RingNode, ranges: &[(usize, usize)],
                       buf: &mut [f32], class: TrafficClass)
    -> Result<(), DistError> {
    ring_all_gather_coded(node, ranges, buf, class, None)
}

/// All-gather with an optional compression context. Every hop is a
/// broadcast (copy-semantics) payload: it is compressed only when the
/// codec opts in via [`Codec::compresses_broadcast`] — top-k never
/// does, because dropping a parameter corrupts the replica. When
/// compression is active the owning rank first quantizes its own
/// range in place, so after the collective every rank (owner
/// included) holds identical bits; forwarded hops re-encode
/// already-quantized data, which is lossless.
///
/// [`Codec::compresses_broadcast`]:
///     super::compress::Codec::compresses_broadcast
pub fn ring_all_gather_coded(node: &mut RingNode,
                             ranges: &[(usize, usize)],
                             buf: &mut [f32], class: TrafficClass,
                             mut ctx: Option<&mut CodedRing>)
    -> Result<(), DistError> {
    let (n, r) = (node.world, node.rank);
    assert_eq!(ranges.len(), n, "one range per rank");
    if n <= 1 {
        return Ok(());
    }
    let coded_bcast =
        matches!(&ctx, Some(c) if c.codec.compresses_broadcast());
    if coded_bcast {
        if let Some(c) = &mut ctx {
            let (lo, hi) = ranges[r];
            c.quantize_in_place(&mut buf[lo..hi]);
        }
    }
    let mut send_c = r;
    for s in 0..n - 1 {
        let (lo, hi) = ranges[send_c];
        match &mut ctx {
            Some(c) if coded_bcast => {
                let wire = c.encode_copy(&buf[lo..hi]);
                node.send_right(c.codec.class(), wire)?;
            }
            _ => node.send_right(class, buf[lo..hi].to_vec())?,
        }
        let recv_c = (r + n - 1 - s) % n;
        let (lo, hi) = ranges[recv_c];
        let incoming = node.recv_left()?;
        let incoming = match &ctx {
            Some(c) if coded_bcast => c.decode(&incoming, hi - lo),
            _ => incoming,
        };
        debug_assert_eq!(incoming.len(), hi - lo);
        buf[lo..hi].copy_from_slice(&incoming);
        send_c = recv_c;
    }
    Ok(())
}

/// Reference sum for tests: elementwise sum of every rank's vector.
#[cfg(test)]
pub fn naive_sum(inputs: &[Vec<f32>]) -> Vec<f32> {
    let mut out = vec![0.0f32; inputs[0].len()];
    for v in inputs {
        for (o, x) in out.iter_mut().zip(v) {
            *o += x;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::comm::{ring_world, LinkModel};
    use crate::util::prng::Rng;

    fn run_all_reduce(inputs: Vec<Vec<f32>>, bucket: usize)
        -> (Vec<Vec<f32>>, u64) {
        let n = inputs.len();
        let (nodes, stats) = ring_world(n, LinkModel::default());
        let outs: Vec<Vec<f32>> = std::thread::scope(|s| {
            // Threads own their node: &RingNode is !Send.
            let handles: Vec<_> = nodes
                .into_iter()
                .zip(inputs)
                .map(|(mut node, mut data)| {
                    s.spawn(move || {
                        ring_all_reduce(&mut node, &mut data, bucket,
                                        TrafficClass::GradReduce)
                            .unwrap();
                        data
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        (outs, stats.bytes(TrafficClass::GradReduce))
    }

    #[test]
    fn matches_naive_sum_for_odd_sizes_and_world_sizes() {
        let mut rng = Rng::new(7);
        for &world in &[1usize, 2, 3, 5] {
            for &len in &[1usize, 7, 33, 257, 1025] {
                for &bucket in &[3usize, 64, 100_000] {
                    let inputs: Vec<Vec<f32>> = (0..world)
                        .map(|_| rng.normal_vec(len, 1.0))
                        .collect();
                    let expect = naive_sum(&inputs);
                    let (outs, _) = run_all_reduce(inputs, bucket);
                    for (r, out) in outs.iter().enumerate() {
                        assert_eq!(out.len(), len);
                        for (i, (a, b)) in
                            out.iter().zip(&expect).enumerate()
                        {
                            assert!((a - b).abs() <= 1e-4 * b.abs().max(1.0),
                                    "world {world} len {len} bucket \
                                     {bucket} rank {r} elem {i}: {a} vs {b}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn all_ranks_agree_bitwise() {
        let mut rng = Rng::new(11);
        let inputs: Vec<Vec<f32>> =
            (0..4).map(|_| rng.normal_vec(101, 1.0)).collect();
        let (outs, _) = run_all_reduce(inputs, 17);
        for out in &outs[1..] {
            assert_eq!(out, &outs[0]);
        }
    }

    #[test]
    fn traffic_matches_closed_form_regardless_of_bucket() {
        // Cluster total = 2·(N−1)·payload bytes, any bucket size.
        for &world in &[2usize, 3, 5] {
            for &bucket in &[5usize, 128, 1 << 20] {
                let len = 999;
                let inputs =
                    vec![vec![1.0f32; len]; world];
                let (_, bytes) = run_all_reduce(inputs, bucket);
                assert_eq!(bytes,
                           (2 * (world - 1) * len * 4) as u64,
                           "world {world} bucket {bucket}");
            }
        }
    }

    /// Drive a bucketed reduce-scatter on every rank; return each
    /// rank's buffer plus the grad_scatter byte counter.
    fn run_reduce_scatter(inputs: Vec<Vec<f32>>,
                          ranges: Vec<(usize, usize)>, bucket: usize)
        -> (Vec<Vec<f32>>, u64) {
        let n = inputs.len();
        let (nodes, stats) = ring_world(n, LinkModel::default());
        let outs: Vec<Vec<f32>> = std::thread::scope(|s| {
            let handles: Vec<_> = nodes
                .into_iter()
                .zip(inputs)
                .map(|(mut node, mut data)| {
                    let ranges = &ranges;
                    s.spawn(move || {
                        ring_reduce_scatter_bucketed(
                            &mut node, ranges, &mut data, bucket,
                            TrafficClass::GradScatter)
                            .unwrap();
                        data
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        (outs, stats.bytes(TrafficClass::GradScatter))
    }

    #[test]
    fn reduce_scatter_matches_all_reduce_then_slice() {
        // Each rank's own range must hold exactly what an all-reduce
        // would put there — including ragged buckets and ragged ranges.
        let mut rng = Rng::new(23);
        for &world in &[2usize, 3, 5] {
            for &len in &[7usize, 33, 257] {
                for &bucket in &[5usize, 64, 100_000] {
                    let inputs: Vec<Vec<f32>> = (0..world)
                        .map(|_| rng.normal_vec(len, 1.0))
                        .collect();
                    let expect = naive_sum(&inputs);
                    let ranges: Vec<(usize, usize)> = (0..world)
                        .map(|w| chunk_range(len, world, w))
                        .collect();
                    let (outs, _) = run_reduce_scatter(
                        inputs, ranges.clone(), bucket);
                    for (w, out) in outs.iter().enumerate() {
                        let (lo, hi) = ranges[w];
                        for i in lo..hi {
                            let (a, b) = (out[i], expect[i]);
                            assert!((a - b).abs()
                                        <= 1e-4 * b.abs().max(1.0),
                                    "world {world} len {len} bucket \
                                     {bucket} rank {w} elem {i}: \
                                     {a} vs {b}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn reduce_scatter_traffic_is_half_an_all_reduce() {
        // (N−1)·payload bytes cluster-total, any bucket size.
        for &world in &[2usize, 4] {
            for &bucket in &[3usize, 1 << 20] {
                let len = 333;
                let inputs = vec![vec![1.0f32; len]; world];
                let ranges: Vec<(usize, usize)> = (0..world)
                    .map(|w| chunk_range(len, world, w))
                    .collect();
                let (outs, bytes) =
                    run_reduce_scatter(inputs, ranges.clone(), bucket);
                assert_eq!(bytes, ((world - 1) * len * 4) as u64,
                           "world {world} bucket {bucket}");
                for (w, out) in outs.iter().enumerate() {
                    let (lo, hi) = ranges[w];
                    for i in lo..hi {
                        assert_eq!(out[i], world as f32);
                    }
                }
            }
        }
    }

    #[test]
    fn reduce_scatter_handles_empty_and_ragged_ranges() {
        // Uneven shard map with one empty range (more workers than
        // atoms) — every nonempty owner still gets the exact sum.
        let len = 23;
        let ranges = vec![(0, 9), (9, 9), (9, 16), (16, 23)];
        let mut rng = Rng::new(31);
        let inputs: Vec<Vec<f32>> =
            (0..4).map(|_| rng.normal_vec(len, 1.0)).collect();
        let expect = naive_sum(&inputs);
        // Bucket of 10 splits range (9,16) across two windows.
        let (outs, bytes) =
            run_reduce_scatter(inputs, ranges.clone(), 10);
        for (w, out) in outs.iter().enumerate() {
            let (lo, hi) = ranges[w];
            for i in lo..hi {
                assert!((out[i] - expect[i]).abs() <= 1e-4,
                        "rank {w} elem {i}");
            }
        }
        assert_eq!(bytes, (3 * len * 4) as u64);
    }

    #[test]
    fn reduce_scatter_single_worker_is_a_no_op() {
        let inputs = vec![vec![2.0f32; 5]];
        let (outs, bytes) =
            run_reduce_scatter(inputs, vec![(0, 5)], 2);
        assert_eq!(outs[0], vec![2.0f32; 5]);
        assert_eq!(bytes, 0);
    }

    #[test]
    fn all_gather_fills_every_range_including_empty() {
        let total = 23;
        // Uneven ranges, one empty: [0,9) [9,9) [9,16) [16,23).
        let ranges = vec![(0, 9), (9, 9), (9, 16), (16, 23)];
        let (nodes, stats) = ring_world(4, LinkModel::default());
        let outs: Vec<Vec<f32>> = std::thread::scope(|s| {
            let handles: Vec<_> = nodes
                .into_iter()
                .enumerate()
                .map(|(w, mut node)| {
                    let ranges = &ranges;
                    s.spawn(move || {
                        // Rank knows only its own range's true values.
                        let (lo, hi) = ranges[w];
                        let mut buf = vec![f32::NAN; total];
                        for i in lo..hi {
                            buf[i] = i as f32;
                        }
                        ring_all_gather(&mut node, ranges, &mut buf,
                                        TrafficClass::ParamGather)
                            .unwrap();
                        buf
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for out in &outs {
            for (i, &x) in out.iter().enumerate() {
                assert_eq!(x, i as f32);
            }
        }
        // (N−1)·payload bytes cluster-total.
        assert_eq!(stats.bytes(TrafficClass::ParamGather),
                   (3 * total * 4) as u64);
    }

    #[test]
    fn coded_f16_all_reduce_keeps_ranks_bit_identical() {
        use crate::dist::compress::{CodedRing, F16Codec};
        let mut rng = Rng::new(41);
        let inputs: Vec<Vec<f32>> =
            (0..4).map(|_| rng.normal_vec(101, 1.0)).collect();
        let expect = naive_sum(&inputs);
        let (nodes, stats) = ring_world(4, LinkModel::default());
        let outs: Vec<Vec<f32>> = std::thread::scope(|s| {
            let handles: Vec<_> = nodes
                .into_iter()
                .zip(inputs)
                .map(|(mut node, mut data)| {
                    s.spawn(move || {
                        let codec = F16Codec;
                        let mut ctx = CodedRing::new(&codec, None);
                        ring_all_reduce_coded(
                            &mut node, &mut data, 17,
                            TrafficClass::GradReduce,
                            Some(&mut ctx))
                            .unwrap();
                        data
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // The owner-side quantize before the gather phase is what
        // keeps replicas identical despite lossy wire payloads.
        for out in &outs[1..] {
            assert_eq!(out, &outs[0], "ranks must agree bitwise");
        }
        for (i, (a, b)) in outs[0].iter().zip(&expect).enumerate() {
            assert!((a - b).abs() <= 3e-2 * b.abs().max(1.0),
                    "elem {i}: {a} vs {b}");
        }
        // Compressed payloads land on the codec class, not the base
        // class, and cost fewer bytes than the dense closed form.
        assert_eq!(stats.bytes(TrafficClass::GradReduce), 0);
        let wire = stats.bytes(TrafficClass::CodecF16);
        assert!(wire > 0 && wire < (2 * 3 * 101 * 4) as u64,
                "wire bytes {wire}");
    }

    #[test]
    fn coded_f16_all_gather_quantizes_every_replica_identically() {
        use crate::dist::compress::{CodedRing, F16Codec};
        let total = 23;
        let ranges = vec![(0, 9), (9, 9), (9, 16), (16, 23)];
        let (nodes, stats) = ring_world(4, LinkModel::default());
        let outs: Vec<Vec<f32>> = std::thread::scope(|s| {
            let handles: Vec<_> = nodes
                .into_iter()
                .enumerate()
                .map(|(w, mut node)| {
                    let ranges = &ranges;
                    s.spawn(move || {
                        let (lo, hi) = ranges[w];
                        let mut buf = vec![0.0f32; total];
                        for i in lo..hi {
                            // Not f16-exact: the owner must project
                            // its own range too.
                            buf[i] = i as f32 + 0.123;
                        }
                        let codec = F16Codec;
                        let mut ctx = CodedRing::new(&codec, None);
                        ring_all_gather_coded(
                            &mut node, ranges, &mut buf,
                            TrafficClass::ParamGather,
                            Some(&mut ctx))
                            .unwrap();
                        buf
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for out in &outs[1..] {
            assert_eq!(out, &outs[0], "replicas must agree bitwise");
        }
        for (i, &x) in outs[0].iter().enumerate() {
            let want = i as f32 + 0.123;
            assert!((x - want).abs() <= want.abs().max(1.0) / 2048.0,
                    "elem {i}: {x} vs {want}");
        }
        assert_eq!(stats.bytes(TrafficClass::ParamGather), 0);
        assert!(stats.bytes(TrafficClass::CodecF16) > 0);
    }

    #[test]
    fn coded_topk_frac_one_reduce_scatter_matches_dense_bitwise() {
        // frac=1 keeps every entry at full precision, so the coded
        // path must reproduce the dense accumulation bit-for-bit.
        use crate::dist::compress::{CodedRing, TopKCodec};
        let mut rng = Rng::new(43);
        let len = 33;
        let inputs: Vec<Vec<f32>> =
            (0..3).map(|_| rng.normal_vec(len, 1.0)).collect();
        let ranges: Vec<(usize, usize)> =
            (0..3).map(|w| chunk_range(len, 3, w)).collect();
        let (dense, _) =
            run_reduce_scatter(inputs.clone(), ranges.clone(), 10);
        let (nodes, _) = ring_world(3, LinkModel::default());
        let coded: Vec<Vec<f32>> = std::thread::scope(|s| {
            let handles: Vec<_> = nodes
                .into_iter()
                .zip(inputs)
                .map(|(mut node, mut data)| {
                    let ranges = &ranges;
                    s.spawn(move || {
                        let codec = TopKCodec { frac: 1.0 };
                        let mut res = vec![0.0f32; len];
                        let mut ctx =
                            CodedRing::new(&codec, Some(&mut res));
                        ring_reduce_scatter_bucketed_coded(
                            &mut node, ranges, &mut data, 10,
                            TrafficClass::GradScatter,
                            Some(&mut ctx))
                            .unwrap();
                        data
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (w, out) in coded.iter().enumerate() {
            let (lo, hi) = ranges[w];
            assert_eq!(&out[lo..hi], &dense[w][lo..hi], "rank {w}");
        }
    }

    #[test]
    fn coded_topk_leaves_dropped_mass_in_the_residual() {
        use crate::dist::compress::{CodedRing, TopKCodec};
        let mut rng = Rng::new(47);
        let len = 32;
        let inputs: Vec<Vec<f32>> =
            (0..2).map(|_| rng.normal_vec(len, 1.0)).collect();
        let ranges = vec![(0, 16), (16, 32)];
        let (nodes, _) = ring_world(2, LinkModel::default());
        let residuals: Vec<Vec<f32>> = std::thread::scope(|s| {
            let handles: Vec<_> = nodes
                .into_iter()
                .zip(inputs)
                .map(|(mut node, mut data)| {
                    let ranges = &ranges;
                    s.spawn(move || {
                        let codec = TopKCodec { frac: 0.25 };
                        let mut res = vec![0.0f32; len];
                        let mut ctx =
                            CodedRing::new(&codec, Some(&mut res));
                        ring_reduce_scatter_bucketed_coded(
                            &mut node, ranges, &mut data, 100,
                            TrafficClass::GradScatter,
                            Some(&mut ctx))
                            .unwrap();
                        let (raw, wire) = ctx.bytes();
                        assert!(wire < raw,
                                "topk must shrink the wire");
                        res
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // Each rank sent one 16-element chunk keeping 4 entries: the
        // other 12 must survive in that rank's residual.
        for (w, res) in residuals.iter().enumerate() {
            let nonzero = res.iter().filter(|v| **v != 0.0).count();
            assert!(nonzero >= 8, "rank {w}: residual too empty");
        }
    }
}
