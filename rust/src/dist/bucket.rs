//! The bucket scheduler: carves the flat gradient space into
//! readiness buckets and models the overlapped step timeline.
//!
//! A bucket is a contiguous flat range covering one or more whole
//! tensors (or an aligned slice of one oversized tensor). During a
//! streamed step the driver launches a bucket's ring collective the
//! moment the LAST gradient the bucket covers lands — while earlier
//! tensors' gradients are still being produced — so communication
//! hides behind compute instead of waiting for the full gradient.
//!
//! Buckets are carved in REVERSE parameter order because that is the
//! order a backward pass emits gradients: the output layers' grads are
//! ready first, so the tail of the flat space fills first. When a cut
//! grid is present (the Adam-mini Hessian-block grid), EVERY bucket
//! boundary is drawn from it — a window with no interior cut extends
//! to the next cut rather than splitting a block — so bucket-granular
//! segment stepping (`Optimizer::step_segment`) never splits a block.
//!
//! [`OverlapTimeline`] records the clocks of a streamed step — the
//! simulated backward-compute clock (gradient production), the modeled
//! link clock (per-bucket collective durations under the alpha–beta
//! [`LinkModel`]), and the modeled optimizer-step clock
//! ([`ComputeModel::step_ns_per_elem`], its own resource: shard
//! stepping runs on the worker while the link moves the next bucket) —
//! and derives three schedules from one run:
//!
//! - **sequential**: all compute, then every gradient collective
//!   back-to-back, then the trailing step + whole-parameter gather
//!   (the PR-1 batch-synchronous pipeline);
//! - **deferred**: gradient collectives stream per bucket, but the
//!   optimizer steps once after the LAST one lands, followed by one
//!   whole-parameter all-gather (the PR-2 pipeline);
//! - **overlapped**: the live schedule. With bucket-granular stepping
//!   (ZeRO-2), each bucket chains reduce-scatter → shard-segment step
//!   → bucket all-gather, so optimizer compute and the trailing
//!   gather hide behind in-flight collectives instead of serializing
//!   after the last reduce-scatter.
//!
//! `overlapped < deferred < sequential` is the tentpole win,
//! asserted at `workers = 4` in `tests/dist.rs`.

use super::comm::LinkModel;
use super::shard::FlatLayout;

/// One readiness bucket: flat range `[lo, hi)` covering spans
/// `[span_lo, span_hi]` of the layout. Ready when every covered
/// span's gradient has landed for the final micro-batch.
#[derive(Debug, Clone, Copy)]
pub struct Bucket {
    pub lo: usize,
    pub hi: usize,
    pub span_lo: usize,
    pub span_hi: usize,
}

impl Bucket {
    pub fn elems(&self) -> usize {
        self.hi - self.lo
    }

    /// Number of distinct tensors whose gradients gate this bucket.
    pub fn n_spans(&self) -> usize {
        self.span_hi - self.span_lo + 1
    }
}

/// The carved bucket list, in launch order (reverse flat order —
/// backward-pass readiness order), plus the span → buckets map the
/// driver uses to trigger launches.
#[derive(Debug, Clone)]
pub struct BucketPlan {
    pub buckets: Vec<Bucket>,
    /// `span_buckets[s]` = indices of every bucket gated by span `s`.
    pub span_buckets: Vec<Vec<usize>>,
}

impl BucketPlan {
    /// Carve `layout` into buckets of at most `bucket_elems` elements.
    /// Whole tensors are grouped greedily from the tail; a tensor
    /// larger than the budget gets its own buckets, split ONLY at
    /// `cuts` boundaries when a grid is present (growing past the
    /// budget rather than splitting a block).
    pub fn carve(layout: &FlatLayout, cuts: Option<&[usize]>,
                 bucket_elems: usize) -> BucketPlan {
        let bucket_elems = bucket_elems.max(1);
        let spans = &layout.spans;
        let mut buckets = Vec::new();
        let mut j = spans.len();
        while j > 0 {
            let last = j - 1;
            if spans[last].len > bucket_elems {
                // Oversized tensor: its own buckets, tail first.
                let s = &spans[last];
                let pieces = split_ranges(s.offset, s.offset + s.len,
                                          bucket_elems, cuts);
                for &(lo, hi) in pieces.iter().rev() {
                    buckets.push(Bucket {
                        lo,
                        hi,
                        span_lo: last,
                        span_hi: last,
                    });
                }
                j = last;
            } else {
                // Group consecutive spans ending at `last` while the
                // total stays within budget.
                let mut i = last;
                let mut total = spans[last].len;
                while i > 0 && spans[i - 1].len <= bucket_elems
                    && total + spans[i - 1].len <= bucket_elems
                {
                    i -= 1;
                    total += spans[i].len;
                }
                buckets.push(Bucket {
                    lo: spans[i].offset,
                    hi: spans[last].offset + spans[last].len,
                    span_lo: i,
                    span_hi: last,
                });
                j = i;
            }
        }
        let mut span_buckets = vec![Vec::new(); spans.len()];
        for (bi, b) in buckets.iter().enumerate() {
            for s in b.span_lo..=b.span_hi {
                span_buckets[s].push(bi);
            }
        }
        BucketPlan { buckets, span_buckets }
    }

    pub fn len(&self) -> usize {
        self.buckets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buckets.is_empty()
    }

    /// True when every bucket boundary is drawn from `cuts` — the
    /// precondition for stepping shard∩bucket segments of a blockwise
    /// optimizer without splitting a block.
    pub fn aligned_to(&self, cuts: &[usize]) -> bool {
        self.buckets.iter().all(|b| {
            cuts.binary_search(&b.lo).is_ok()
                && cuts.binary_search(&b.hi).is_ok()
        })
    }
}

/// Split `[lo, hi)` into windows of at most `bucket` elements. With a
/// cut grid, every boundary is drawn from it: prefer the largest cut
/// in `(a, a+bucket]`; if a window holds no interior cut, extend to
/// the NEXT cut (oversize beats splitting a block).
fn split_ranges(lo: usize, hi: usize, bucket: usize,
                cuts: Option<&[usize]>) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut a = lo;
    while a < hi {
        let mut b = (a + bucket).min(hi);
        if b < hi {
            if let Some(cs) = cuts {
                let idx = cs.partition_point(|&c| c <= b);
                if idx > 0 && cs[idx - 1] > a {
                    // Largest cut inside the window.
                    b = cs[idx - 1];
                } else {
                    // No interior cut: grow to the next one (or hi).
                    b = cs.get(idx).copied().unwrap_or(hi).min(hi);
                }
            }
        }
        out.push((a, b));
        a = b;
    }
    out
}

/// Simulated compute costs the overlap timeline runs on. Only ratios
/// to the [`LinkModel`] matter; the defaults put a ~1.6M-param probe
/// step's backward compute within a small factor of its communication
/// so all three schedules are exercised.
#[derive(Debug, Clone, Copy)]
pub struct ComputeModel {
    /// Nanoseconds of backward compute per gradient element produced.
    pub ns_per_elem: f64,
    /// Nanoseconds of optimizer compute per parameter element stepped
    /// (the shard step runs on the worker — modeled as its own
    /// resource that overlaps the link).
    pub step_ns_per_elem: f64,
}

impl Default for ComputeModel {
    fn default() -> Self {
        ComputeModel { ns_per_elem: 2.0, step_ns_per_elem: 1.0 }
    }
}

/// Modeled wall time of one bucket's gradient collective:
/// `2(N−1)` rounds for the all-reduce schedules, `(N−1)` for the
/// ZeRO-2 reduce-scatter, each round moving `elems/N` f32s per rank.
pub fn grad_comm_ns(link: &LinkModel, world: usize, elems: usize,
                    scatter_only: bool) -> f64 {
    if world <= 1 || elems == 0 {
        return 0.0;
    }
    let rounds = if scatter_only { world - 1 } else { 2 * (world - 1) };
    link.ring_ns(rounds, elems as f64 * 4.0 / world as f64)
}

/// Modeled wall time of a parameter all-gather over `elems`:
/// `(N−1)` rounds of `elems/N` f32s per rank.
pub fn gather_comm_ns(link: &LinkModel, world: usize, elems: usize)
    -> f64 {
    if world <= 1 || elems == 0 {
        return 0.0;
    }
    link.ring_ns(world - 1, elems as f64 * 4.0 / world as f64)
}

/// One launched bucket's modeled costs.
#[derive(Debug, Clone, Copy)]
struct BucketEvent {
    /// Compute clock when the bucket's last gradient landed.
    ready: f64,
    /// Gradient collective (all-reduce or reduce-scatter).
    scatter_ns: f64,
    /// Shard-segment optimizer step (bucket-granular mode only).
    step_ns: f64,
    /// Bucket parameter all-gather (bucket-granular mode only).
    gather_ns: f64,
}

/// Event recorder for one streamed step: compute advances as gradients
/// land, bucket launches pin their modeled costs, and the trailing
/// phase (if any) is appended once. [`OverlapTimeline::timing`] folds
/// the events into all three schedules' wall clocks.
#[derive(Debug, Clone)]
pub struct OverlapTimeline {
    compute: ComputeModel,
    compute_ns: f64,
    events: Vec<BucketEvent>,
    /// Trailing phase actually run by this schedule (deferred modes):
    /// (optimizer step ns, whole-gather comm ns).
    tail_step_ns: f64,
    tail_comm_ns: f64,
    /// Trailing phase the DEFERRED comparator would run (set when the
    /// live schedule is bucket-granular and has no trailing phase).
    deferred_tail: Option<(f64, f64)>,
}

impl OverlapTimeline {
    pub fn new(compute: ComputeModel) -> OverlapTimeline {
        OverlapTimeline {
            compute,
            compute_ns: 0.0,
            events: Vec::new(),
            tail_step_ns: 0.0,
            tail_comm_ns: 0.0,
            deferred_tail: None,
        }
    }

    /// The configured cost model (drivers size per-bucket step costs
    /// with `step_ns_per_elem`).
    pub fn compute_model(&self) -> ComputeModel {
        self.compute
    }

    /// Advance the compute clock by one produced gradient tensor.
    pub fn record_compute(&mut self, elems: usize) {
        self.compute_ns += elems as f64 * self.compute.ns_per_elem;
    }

    /// A bucket's gradient collective launched now (grads ready at the
    /// current compute clock); the optimizer steps later, in a
    /// trailing phase.
    pub fn launch(&mut self, comm_ns: f64) {
        self.events.push(BucketEvent {
            ready: self.compute_ns,
            scatter_ns: comm_ns,
            step_ns: 0.0,
            gather_ns: 0.0,
        });
    }

    /// A bucket-granular launch (ZeRO-2 streaming): reduce-scatter,
    /// then the shard∩bucket segment step, then the bucket
    /// all-gather, all chained per bucket.
    pub fn launch_granular(&mut self, scatter_ns: f64, step_ns: f64,
                           gather_ns: f64) {
        self.events.push(BucketEvent {
            ready: self.compute_ns,
            scatter_ns,
            step_ns,
            gather_ns,
        });
    }

    /// Trailing serialized phase this schedule actually runs
    /// (whole-shard optimizer step + whole-parameter all-gather).
    pub fn set_tail(&mut self, step_ns: f64, comm_ns: f64) {
        self.tail_step_ns = step_ns;
        self.tail_comm_ns = comm_ns;
    }

    /// Trailing phase of the deferred-step comparator, for runs whose
    /// live schedule is bucket-granular (their own tail is empty).
    pub fn set_deferred_tail(&mut self, step_ns: f64, comm_ns: f64) {
        self.deferred_tail = Some((step_ns, comm_ns));
    }

    pub fn timing(&self) -> StepTiming {
        let (def_step, def_comm) = self
            .deferred_tail
            .unwrap_or((self.tail_step_ns, self.tail_comm_ns));
        // Live schedule: the link serializes collectives; the
        // optimizer stream serializes segment steps; a bucket's step
        // starts when its scatter lands, its gather when its step and
        // the link are both free.
        let mut link = 0.0f64;
        let mut opt_stream = 0.0f64;
        let mut scatter_total = 0.0;
        let mut gather_total = 0.0;
        let mut step_total = 0.0;
        // Deferred comparator: same per-bucket gradient collectives,
        // no interleaved steps/gathers.
        let mut link_deferred = 0.0f64;
        for ev in &self.events {
            let s_end = link.max(ev.ready) + ev.scatter_ns;
            link = s_end;
            if ev.step_ns > 0.0 || ev.gather_ns > 0.0 {
                let st_end = opt_stream.max(s_end) + ev.step_ns;
                opt_stream = st_end;
                link = link.max(st_end) + ev.gather_ns;
            }
            link_deferred = link_deferred.max(ev.ready) + ev.scatter_ns;
            scatter_total += ev.scatter_ns;
            gather_total += ev.gather_ns;
            step_total += ev.step_ns;
        }
        let overlapped_ns = link.max(opt_stream).max(self.compute_ns)
            + self.tail_step_ns
            + self.tail_comm_ns;
        let deferred_ns = link_deferred.max(self.compute_ns) + def_step
            + def_comm;
        let sequential_ns =
            self.compute_ns + scatter_total + def_step + def_comm;
        StepTiming {
            overlapped_ns,
            deferred_ns,
            sequential_ns,
            compute_ns: self.compute_ns,
            comm_ns: scatter_total + gather_total + self.tail_comm_ns,
            step_ns: step_total + self.tail_step_ns,
        }
    }
}

/// The three schedules' modeled wall clocks for one step, derived from
/// the same recorded events — the apples-to-apples overlap comparison.
#[derive(Debug, Clone, Copy)]
pub struct StepTiming {
    /// The live streaming pipeline (bucket-granular stepping when
    /// active): collectives AND optimizer compute hide behind compute.
    pub overlapped_ns: f64,
    /// Streamed collectives but the optimizer steps after the LAST
    /// gradient collective, then one whole all-gather (PR-2 pipeline).
    pub deferred_ns: f64,
    /// PR-1 batch-synchronous pipeline: compute, then all comm, then
    /// step + gather.
    pub sequential_ns: f64,
    pub compute_ns: f64,
    pub comm_ns: f64,
    /// Modeled optimizer compute in this step.
    pub step_ns: f64,
}

impl StepTiming {
    /// Sequential / overlapped — > 1 whenever overlap hides anything.
    pub fn speedup(&self) -> f64 {
        self.sequential_ns / self.overlapped_ns.max(1e-9)
    }

    /// Deferred / overlapped — > 1 when bucket-granular stepping
    /// shortens the critical path vs stepping after the last
    /// reduce-scatter.
    pub fn granular_gain(&self) -> f64 {
        self.deferred_ns / self.overlapped_ns.max(1e-9)
    }

    /// Machine-readable form (for `results/report.json` and traces).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("overlapped_ns", Json::num(self.overlapped_ns)),
            ("deferred_ns", Json::num(self.deferred_ns)),
            ("sequential_ns", Json::num(self.sequential_ns)),
            ("compute_ns", Json::num(self.compute_ns)),
            ("comm_ns", Json::num(self.comm_ns)),
            ("step_ns", Json::num(self.step_ns)),
            ("speedup", Json::num(self.speedup())),
            ("granular_gain", Json::num(self.granular_gain())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::shard::FlatLayout;
    use crate::tensor::Tensor;

    fn layout(sizes: &[usize]) -> FlatLayout {
        let params: Vec<Tensor> = sizes
            .iter()
            .enumerate()
            .map(|(i, &n)| Tensor::zeros(format!("t{i}"), &[n]))
            .collect();
        FlatLayout::of(&params)
    }

    fn covers_exactly(plan: &BucketPlan, total: usize) {
        // Buckets are in reverse flat order and tile [0, total).
        let mut hi = total;
        for b in &plan.buckets {
            assert_eq!(b.hi, hi, "gap or overlap at {hi}");
            assert!(b.lo < b.hi || total == 0);
            hi = b.lo;
        }
        assert_eq!(hi, 0);
    }

    #[test]
    fn carve_groups_small_tensors_tail_first() {
        let l = layout(&[10, 20, 30, 5]);
        let plan = BucketPlan::carve(&l, None, 40);
        covers_exactly(&plan, 65);
        // Tail first: {30, 5} fit one bucket, then {20, 10}... 20+10=30
        // <= 40 so they group.
        assert_eq!(plan.buckets.len(), 2);
        assert_eq!((plan.buckets[0].lo, plan.buckets[0].hi), (30, 65));
        assert_eq!((plan.buckets[0].span_lo, plan.buckets[0].span_hi),
                   (2, 3));
        assert_eq!((plan.buckets[1].lo, plan.buckets[1].hi), (0, 30));
    }

    #[test]
    fn carve_splits_oversized_tensors() {
        let l = layout(&[100, 8]);
        let plan = BucketPlan::carve(&l, None, 30);
        covers_exactly(&plan, 108);
        // t1 (8) fits; t0 (100) splits into 30/30/30/10, tail first.
        assert_eq!(plan.buckets.len(), 5);
        assert_eq!((plan.buckets[0].lo, plan.buckets[0].hi), (100, 108));
        assert_eq!((plan.buckets[1].lo, plan.buckets[1].hi), (90, 100));
        assert_eq!((plan.buckets[4].lo, plan.buckets[4].hi), (0, 30));
        // Every t0 bucket is gated by span 0 alone.
        for b in &plan.buckets[1..] {
            assert_eq!((b.span_lo, b.span_hi), (0, 0));
        }
        assert_eq!(plan.span_buckets[0], vec![1, 2, 3, 4]);
        assert_eq!(plan.span_buckets[1], vec![0]);
    }

    #[test]
    fn carve_prefers_block_cuts_for_oversized_splits() {
        let l = layout(&[100]);
        // Block grid of 24: cuts 0,24,48,72,96,100.
        let cuts = vec![0, 24, 48, 72, 96, 100];
        let plan = BucketPlan::carve(&l, Some(&cuts), 30);
        covers_exactly(&plan, 100);
        // Forward boundaries snap to the largest cut <= a+30 (24, 48,
        // 72); the last window (72, 100) already fits the budget.
        // Reversed for launch order.
        let got: Vec<(usize, usize)> = plan
            .buckets
            .iter()
            .map(|b| (b.lo, b.hi))
            .collect();
        assert_eq!(got, vec![(72, 100), (48, 72), (24, 48), (0, 24)]);
        assert!(plan.aligned_to(&cuts));
    }

    #[test]
    fn carve_never_splits_a_block_even_when_oversized() {
        // Blocks of 40 > budget 16: every boundary still lands on the
        // grid — a window without an interior cut extends to the next
        // one instead of splitting a block.
        let l = layout(&[120]);
        let cuts = vec![0, 40, 80, 120];
        let plan = BucketPlan::carve(&l, Some(&cuts), 16);
        covers_exactly(&plan, 120);
        assert!(plan.aligned_to(&cuts));
        let got: Vec<(usize, usize)> = plan
            .buckets
            .iter()
            .map(|b| (b.lo, b.hi))
            .collect();
        assert_eq!(got, vec![(80, 120), (40, 80), (0, 40)]);
    }

    #[test]
    fn carve_single_bucket_when_budget_is_huge() {
        let l = layout(&[10, 20, 30]);
        let plan = BucketPlan::carve(&l, None, 1 << 20);
        assert_eq!(plan.buckets.len(), 1);
        assert_eq!((plan.buckets[0].lo, plan.buckets[0].hi), (0, 60));
        assert_eq!(plan.buckets[0].n_spans(), 3);
    }

    #[test]
    fn timeline_overlap_is_bounded_by_both_clocks() {
        let cm = ComputeModel { ns_per_elem: 1.0, step_ns_per_elem: 0.0 };
        let mut tl = OverlapTimeline::new(cm);
        // Three tensors of 100 elems; a bucket launches after each.
        for _ in 0..3 {
            tl.record_compute(100);
            tl.launch(50.0);
        }
        tl.set_tail(0.0, 25.0);
        let t = tl.timing();
        assert!((t.compute_ns - 300.0).abs() < 1e-9);
        assert!((t.comm_ns - 175.0).abs() < 1e-9);
        assert!((t.sequential_ns - 475.0).abs() < 1e-9);
        // Overlapped: bucket 1 at 100→150, bucket 2 at max(200,150)=200
        // →250, bucket 3 at max(300,250)=300→350, +tail = 375.
        assert!((t.overlapped_ns - 375.0).abs() < 1e-9);
        // No bucket-granular events → deferred is the live schedule.
        assert!((t.deferred_ns - t.overlapped_ns).abs() < 1e-9);
        assert!(t.overlapped_ns < t.sequential_ns);
        assert!(t.speedup() > 1.0);
    }

    #[test]
    fn timeline_comm_bound_step_still_overlaps_early_buckets() {
        let cm = ComputeModel { ns_per_elem: 0.01,
                                step_ns_per_elem: 0.0 };
        let mut tl = OverlapTimeline::new(cm);
        tl.record_compute(100);
        tl.launch(1000.0);
        tl.record_compute(100);
        tl.launch(1000.0);
        let t = tl.timing();
        // Link is the bottleneck, but the first bucket started at 1.0
        // instead of 2.0 — still strictly better than sequential.
        assert!(t.overlapped_ns < t.sequential_ns);
    }

    #[test]
    fn granular_stepping_beats_deferred_when_compute_bound() {
        // Compute-bound step: gradients land slowly, so per-bucket
        // step+gather hides entirely behind gradient production, while
        // the deferred schedule serializes the whole step + whole
        // gather after the last scatter.
        let cm = ComputeModel { ns_per_elem: 10.0, step_ns_per_elem: 1.0 };
        let mut tl = OverlapTimeline::new(cm);
        for _ in 0..10 {
            tl.record_compute(100);
            // scatter 80, step 25, gather 80 per bucket.
            tl.launch_granular(80.0, 25.0, 80.0);
        }
        // Deferred comparator: one 250 step + one 700 whole-gather.
        tl.set_deferred_tail(250.0, 700.0);
        let t = tl.timing();
        // compute = 10_000; live: last bucket chain ends ~10_185;
        // deferred: 10_000 + 950.
        assert!(t.overlapped_ns < t.deferred_ns,
                "overlapped {:.0} !< deferred {:.0}", t.overlapped_ns,
                t.deferred_ns);
        assert!(t.deferred_ns < t.sequential_ns);
        assert!(t.granular_gain() > 1.0);
    }

    #[test]
    fn step_timing_serializes() {
        let t = StepTiming {
            overlapped_ns: 100.0,
            deferred_ns: 150.0,
            sequential_ns: 200.0,
            compute_ns: 80.0,
            comm_ns: 90.0,
            step_ns: 30.0,
        };
        let j = t.to_json();
        assert_eq!(j.get("overlapped_ns").unwrap().as_f64().unwrap(),
                   100.0);
        assert_eq!(j.get("speedup").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(j.get("granular_gain").unwrap().as_f64().unwrap(),
                   1.5);
    }

    #[test]
    fn modeled_comm_times_scale_with_rounds() {
        let link = LinkModel { latency_ns: 10.0, bytes_per_sec: 1e9 };
        let ar = grad_comm_ns(&link, 4, 1000, false);
        let rs = grad_comm_ns(&link, 4, 1000, true);
        let ag = gather_comm_ns(&link, 4, 1000);
        assert!((ar - 2.0 * rs).abs() < 1e-9);
        assert!((rs - ag).abs() < 1e-9);
        assert_eq!(grad_comm_ns(&link, 1, 1000, false), 0.0);
        assert_eq!(gather_comm_ns(&link, 1, 1000), 0.0);
    }
}
