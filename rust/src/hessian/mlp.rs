//! 1-hidden-layer MLP with analytic gradients and exact-to-O(ε²)
//! Hessians — the Fig 3 substrate (Collobert 2004 §7 reproduction).
//!
//! Architecture: logits = V·tanh(W·x), softmax cross-entropy. The
//! hidden-layer Hessian ∂²L/∂W² is near-block-diagonal with one dense
//! block per hidden neuron (paper Eq. 3's p(1−p) argument); we verify
//! the structure *appears after 1 step* and persists through training.

use crate::linalg::Mat;
use crate::tensor::Tensor;
use crate::util::prng::Rng;

/// Synthetic classification dataset: Gaussian mixture, one component
/// per class (substitutes CIFAR-100; DESIGN.md §4).
#[derive(Debug, Clone)]
pub struct GaussianMixture {
    pub x: Vec<Vec<f32>>,
    pub y: Vec<usize>,
    pub d: usize,
    pub classes: usize,
}

impl GaussianMixture {
    /// Split into (first `n_train`, rest) keeping shared class centers.
    pub fn split(self, n_train: usize) -> (GaussianMixture, GaussianMixture) {
        let (d, classes) = (self.d, self.classes);
        let train = GaussianMixture {
            x: self.x[..n_train].to_vec(),
            y: self.y[..n_train].to_vec(),
            d, classes,
        };
        let val = GaussianMixture {
            x: self.x[n_train..].to_vec(),
            y: self.y[n_train..].to_vec(),
            d, classes,
        };
        (train, val)
    }

    pub fn generate(n: usize, d: usize, classes: usize, spread: f32,
                    seed: u64) -> GaussianMixture {
        let mut rng = Rng::new(seed ^ 0x6A55);
        let centers: Vec<Vec<f32>> = (0..classes)
            .map(|_| rng.normal_vec(d, 1.0))
            .collect();
        let mut x = Vec::with_capacity(n);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let c = i % classes;
            let mut xi = centers[c].clone();
            for v in xi.iter_mut() {
                *v += rng.normal_f32(spread);
            }
            x.push(xi);
            y.push(c);
        }
        GaussianMixture { x, y, d, classes }
    }
}

/// The MLP. Parameters exposed as tensors so the optimizer roster can
/// train it directly (Table 6's non-LLM path).
pub struct Mlp {
    pub d: usize,
    pub hidden: usize,
    pub classes: usize,
    /// W: (hidden, d) — the layer whose Hessian we study.
    pub w: Tensor,
    /// V: (classes, hidden).
    pub v: Tensor,
}

impl Mlp {
    pub fn init(d: usize, hidden: usize, classes: usize, seed: u64)
        -> Mlp {
        let mut rng = Rng::new(seed ^ 0x31337);
        let sw = (1.0 / d as f32).sqrt();
        let sv = (1.0 / hidden as f32).sqrt();
        Mlp {
            d,
            hidden,
            classes,
            w: Tensor::randn("w", &[hidden, d], sw, &mut rng),
            v: Tensor::randn("v", &[classes, hidden], sv, &mut rng),
        }
    }

    fn forward_one(&self, x: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let h = self.hidden;
        let mut a = vec![0.0f32; h];
        for i in 0..h {
            let mut z = 0.0;
            for j in 0..self.d {
                z += self.w.data[i * self.d + j] * x[j];
            }
            a[i] = z.tanh();
        }
        let mut logits = vec![0.0f32; self.classes];
        for c in 0..self.classes {
            let mut acc = 0.0;
            for i in 0..h {
                acc += self.v.data[c * h + i] * a[i];
            }
            logits[c] = acc;
        }
        (a, logits)
    }

    /// Mean CE loss over the dataset.
    pub fn loss(&self, data: &GaussianMixture) -> f64 {
        let mut total = 0.0;
        for (x, &y) in data.x.iter().zip(&data.y) {
            let (_, logits) = self.forward_one(x);
            total += ce(&logits, y);
        }
        total / data.x.len() as f64
    }

    /// Mean loss + analytic gradients (gW, gV).
    pub fn loss_grad(&self, data: &GaussianMixture)
        -> (f64, Tensor, Tensor) {
        let (h, d, c) = (self.hidden, self.d, self.classes);
        let mut gw = Tensor::zeros("w", &[h, d]);
        let mut gv = Tensor::zeros("v", &[c, h]);
        let mut total = 0.0;
        let inv_n = 1.0 / data.x.len() as f32;
        for (x, &y) in data.x.iter().zip(&data.y) {
            let (a, logits) = self.forward_one(x);
            total += ce(&logits, y);
            let p = softmax(&logits);
            // dlogits = p − onehot(y)
            for ci in 0..c {
                let dl = (p[ci] - if ci == y { 1.0 } else { 0.0 }) * inv_n;
                for i in 0..h {
                    gv.data[ci * h + i] += dl * a[i];
                }
            }
            // da = Vᵀ dlogits; dz = da ⊙ (1 − a²); gW += dz xᵀ
            for i in 0..h {
                let mut da = 0.0;
                for ci in 0..c {
                    da += self.v.data[ci * h + i]
                        * (p[ci] - if ci == y { 1.0 } else { 0.0 });
                }
                let dz = da * (1.0 - a[i] * a[i]) * inv_n;
                for j in 0..d {
                    gw.data[i * d + j] += dz * x[j];
                }
            }
        }
        (total / data.x.len() as f64, gw, gv)
    }

    /// Exact (to O(ε²)) Hessian of the mean loss w.r.t. W, by central
    /// finite differences of the analytic gradient. Size (h·d)².
    pub fn hessian_w(&mut self, data: &GaussianMixture, eps: f32) -> Mat {
        let n = self.hidden * self.d;
        let mut hmat = Mat::zeros(n, n);
        for j in 0..n {
            let orig = self.w.data[j];
            self.w.data[j] = orig + eps;
            let (_, gp, _) = self.loss_grad(data);
            self.w.data[j] = orig - eps;
            let (_, gm, _) = self.loss_grad(data);
            self.w.data[j] = orig;
            for i in 0..n {
                hmat.set(i, j,
                         ((gp.data[i] - gm.data[i]) / (2.0 * eps)) as f64);
            }
        }
        hmat.symmetrize();
        hmat
    }

    /// Hidden-neuron block ranges in the flattened-W index space.
    pub fn neuron_blocks(&self) -> Vec<(usize, usize)> {
        (0..self.hidden).map(|i| (i * self.d, self.d)).collect()
    }

    /// Train with the given host optimizer; returns the loss history.
    pub fn train(&mut self, data: &GaussianMixture,
                 opt: &mut dyn crate::optim::Optimizer, lr: f32,
                 steps: usize) -> Vec<f64> {
        let mut losses = Vec::with_capacity(steps);
        for _ in 0..steps {
            let (loss, gw, gv) = self.loss_grad(data);
            losses.push(loss);
            let mut params = vec![self.w.clone(), self.v.clone()];
            opt.step(&mut params, &[gw, gv], lr);
            self.w = params.remove(0);
            self.v = params.remove(0);
        }
        losses
    }
}

fn softmax(logits: &[f32]) -> Vec<f32> {
    let mx = logits.iter().cloned().fold(f32::MIN, f32::max);
    let exps: Vec<f32> = logits.iter().map(|l| (l - mx).exp()).collect();
    let s: f32 = exps.iter().sum();
    exps.iter().map(|e| e / s).collect()
}

fn ce(logits: &[f32], y: usize) -> f64 {
    let mx = logits.iter().cloned().fold(f32::MIN, f32::max);
    let lse: f32 = logits.iter().map(|l| (l - mx).exp()).sum::<f32>().ln()
        + mx;
    (lse - logits[y]) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Mlp, GaussianMixture) {
        (Mlp::init(6, 4, 3, 0),
         GaussianMixture::generate(60, 6, 3, 0.4, 0))
    }

    #[test]
    fn analytic_grad_matches_finite_difference() {
        let (mut mlp, data) = setup();
        let (_, gw, gv) = mlp.loss_grad(&data);
        let eps = 1e-3f32;
        for idx in [0, 5, 11, 17] {
            let orig = mlp.w.data[idx];
            mlp.w.data[idx] = orig + eps;
            let lp = mlp.loss(&data);
            mlp.w.data[idx] = orig - eps;
            let lm = mlp.loss(&data);
            mlp.w.data[idx] = orig;
            let fd = (lp - lm) / (2.0 * eps as f64);
            assert!((fd - gw.data[idx] as f64).abs() < 2e-4,
                    "W[{idx}]: fd {fd} vs {}", gw.data[idx]);
        }
        for idx in [0, 3, 7] {
            let orig = mlp.v.data[idx];
            mlp.v.data[idx] = orig + eps;
            let lp = mlp.loss(&data);
            mlp.v.data[idx] = orig - eps;
            let lm = mlp.loss(&data);
            mlp.v.data[idx] = orig;
            let fd = (lp - lm) / (2.0 * eps as f64);
            assert!((fd - gv.data[idx] as f64).abs() < 2e-4,
                    "V[{idx}]: fd {fd} vs {}", gv.data[idx]);
        }
    }

    #[test]
    fn hessian_is_symmetric_and_nontrivial() {
        let (mut mlp, data) = setup();
        let h = mlp.hessian_w(&data, 1e-2);
        assert_eq!(h.rows, 24);
        assert!(h.max_abs() > 1e-4);
        for i in 0..24 {
            for j in 0..24 {
                assert!((h.get(i, j) - h.get(j, i)).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn training_reduces_loss() {
        let (mut mlp, data) = setup();
        let hp = crate::optim::Hyper { weight_decay: 0.0,
                                       ..Default::default() };
        let params = vec![mlp.w.clone(), mlp.v.clone()];
        let mut opt = crate::optim::AdamW::new(hp, &params);
        let losses = mlp.train(&data, &mut opt, 5e-3, 150);
        assert!(losses[149] < 0.6 * losses[0],
                "loss {} -> {}", losses[0], losses[149]);
    }
}
