//! Transformer Hessian sub-blocks via the AOT `grad` artifact
//! (paper Fig 7 and Table 3 / Appendix D.1 Exp 1).
//!
//! The `h1t` model mirrors the paper's Appendix F.2 probe: 1 layer,
//! n_emb = 16, 4 heads, MLP width 32, vocab 8. Hessian columns come
//! from central finite differences of the *analytic* gradients the
//! artifact computes — each column costs two executable runs.

use anyhow::Result;

use crate::data::Batch;
use crate::linalg::{cond_general, Mat};
use crate::runtime::ModelRuntime;
use crate::tensor::Tensor;

/// Selection of a parameter sub-vector: tensor index + flat range.
#[derive(Debug, Clone)]
pub struct BlockSel {
    pub label: String,
    pub tensor: usize,
    pub lo: usize,
    pub len: usize,
}

impl BlockSel {
    pub fn new(label: impl Into<String>, tensor: usize, lo: usize,
               len: usize) -> BlockSel {
        BlockSel { label: label.into(), tensor, lo, len }
    }
}

/// Exact (O(ε²)) Hessian of the loss restricted to one parameter block:
/// H[a][b] = ∂²L/∂θ_a∂θ_b for a, b in the selection.
pub fn block_hessian(rt: &ModelRuntime, params: &[Tensor], batch: &Batch,
                     sel: &BlockSel, eps: f32) -> Result<Mat> {
    let n = sel.len;
    let mut h = Mat::zeros(n, n);
    let mut work = params.to_vec();
    for col in 0..n {
        let idx = sel.lo + col;
        let orig = work[sel.tensor].data[idx];
        work[sel.tensor].data[idx] = orig + eps;
        let (_, gp) = rt.grad(&work, batch)?;
        work[sel.tensor].data[idx] = orig - eps;
        let (_, gm) = rt.grad(&work, batch)?;
        work[sel.tensor].data[idx] = orig;
        let gp = &gp[sel.tensor].data[sel.lo..sel.lo + n];
        let gm = &gm[sel.tensor].data[sel.lo..sel.lo + n];
        for row in 0..n {
            h.set(row, col,
                  ((gp[row] - gm[row]) / (2.0 * eps)) as f64);
        }
    }
    h.symmetrize();
    Ok(h)
}

/// Table 3 row: κ(H) and κ(D_Adam·H) for one block.
///
/// κ is the singular-value condition number (the transformer Hessian is
/// indefinite at early training, so eigenvalue ratios are ill-posed).
/// D_Adam = Diag(1/√v) with v the mean of g⊙g over `batches` — the
/// bias-corrected early-training value of Adam's v.
pub fn kappa_report(rt: &ModelRuntime, params: &[Tensor],
                    batches: &[Batch], sel: &BlockSel, eps: f32)
    -> Result<(f64, f64)> {
    let h = block_hessian(rt, params, &batches[0], sel, eps)?;
    let mut v = vec![0.0f64; sel.len];
    for b in batches {
        let (_, grads) = rt.grad(params, b)?;
        let g = &grads[sel.tensor].data[sel.lo..sel.lo + sel.len];
        for (vi, gi) in v.iter_mut().zip(g) {
            *vi += (*gi as f64) * (*gi as f64);
        }
    }
    let n = batches.len() as f64;
    let dinv: Vec<f64> =
        v.iter().map(|vi| 1.0 / (vi / n).sqrt().max(1e-12)).collect();
    let kh = cond_general(&h);
    let kdh = cond_general(&h.scale_rows(&dinv));
    Ok((kh, kdh))
}

/// Off-block leakage metric for a full-tensor Hessian (Fig 7): fraction
/// of squared mass inside the given diagonal blocks.
pub fn block_structure(h: &Mat, blocks: &[(usize, usize)]) -> f64 {
    crate::linalg::block_energy_ratio(h, blocks)
}
