//! Exact Hessian analysis — the paper's structural evidence.
//!
//! - [`mlp`]: 1-hidden-layer MLP with analytic gradients; Hessian via
//!   central finite differences of the analytic gradient (Fig 3:
//!   near-block-diagonal structure, one block per hidden neuron,
//!   maintained throughout training).
//! - [`transformer`]: Hessian sub-blocks of the 1-layer `h1t`
//!   transformer, differentiating the AOT `grad` artifact numerically
//!   (Fig 7 block classes; Table 3 κ(H) vs κ(D_Adam·H)).

pub mod mlp;
pub mod transformer;

pub use mlp::{GaussianMixture, Mlp};
pub use transformer::{block_hessian, kappa_report, BlockSel};
