//! Parameter partitioning — the Rust mirror of `compile/partition.py`
//! (paper Algorithm 3 + Principle 1).
//!
//! Every parameter tensor maps to a 2-D block view
//! `(num_blocks, block_size)` whose rows are the smallest dense Hessian
//! sub-blocks:
//!
//! - `embed` / `output` / `pos_emb` → one block per token row;
//! - `wq` / `wk`                    → one block per head (per layer);
//! - `wv` / `wo` / MLP matrices     → one block per output neuron;
//! - norms / everything else       → one block per tensor (per layer).
//!
//! The Python exporter writes the same spec into `manifest.json`; an
//! integration test golden-checks both sides agree for every model.

use anyhow::{bail, Result};

/// Partition strategies from the paper's experiments.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Algorithm 3 (the Adam-mini default).
    Hessian,
    /// PyTorch-default: one block per parameter tensor (per layer).
    /// Destabilizes ≥1B-scale training (paper Fig 7i / Fig 8a).
    Default,
    /// Algorithm 3 with `value` treated as a whole per layer
    /// (Appendix D.6 strategy II — `optimizer.wv_names = {}`).
    ValueWhole,
}

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Hessian => "hessian",
            Strategy::Default => "default",
            Strategy::ValueWhole => "value_whole",
        }
    }

    pub fn from_name(s: &str) -> Result<Strategy> {
        Ok(match s {
            "hessian" => Strategy::Hessian,
            "default" => Strategy::Default,
            "value_whole" => Strategy::ValueWhole,
            other => bail!("unknown partition strategy {other:?}"),
        })
    }
}

/// Hessian-block category of a tensor (which Algorithm-3 branch fired).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Category {
    TokenRow,
    Head,
    OutNeuron,
    Whole,
}

impl Category {
    pub fn name(&self) -> &'static str {
        match self {
            Category::TokenRow => "token_row",
            Category::Head => "head",
            Category::OutNeuron => "out_neuron",
            Category::Whole => "whole",
        }
    }
}

/// 2-D block view of one tensor: `view = tensor.reshape(num_blocks,
/// block_size)`, row i = Hessian block i.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockView {
    pub name: String,
    pub shape: Vec<usize>,
    pub num_blocks: usize,
    pub block_size: usize,
    pub category: Category,
}

const TOKEN_ROW: &[&str] = &["embed", "output", "pos_emb"];
const HEAD: &[&str] = &["wq", "wk"];
const OUT_NEURON: &[&str] = &["wv", "wo", "w1", "w2", "w3", "w_in", "w_out"];

fn category(name: &str) -> Category {
    let base = name.rsplit('.').next().unwrap_or(name);
    if TOKEN_ROW.iter().any(|k| base.contains(k)) {
        Category::TokenRow
    } else if HEAD.contains(&base) {
        Category::Head
    } else if OUT_NEURON.contains(&base) {
        Category::OutNeuron
    } else {
        Category::Whole
    }
}

/// Compute the block view for one tensor. `stacked` marks layer-stacked
/// tensors whose axis 0 is `n_layers` (the scan-model layout).
pub fn block_view(name: &str, shape: &[usize], n_heads: usize,
                  stacked: bool, strategy: Strategy) -> Result<BlockView> {
    let n: usize = shape.iter().product();
    if n == 0 {
        bail!("{name}: empty tensor");
    }
    let layers = if stacked { shape[0] } else { 1 };
    let mut cat = category(name);
    let base = name.rsplit('.').next().unwrap_or(name);

    let blocks = match strategy {
        Strategy::Default => layers,
        Strategy::ValueWhole if base == "wv" => {
            cat = Category::Whole;
            layers
        }
        _ => match cat {
            Category::TokenRow => shape[0],
            Category::Head => layers * n_heads,
            Category::OutNeuron => {
                let out_dim = if stacked { shape[1] } else { shape[0] };
                layers * out_dim
            }
            Category::Whole => layers,
        },
    };

    if n % blocks != 0 {
        bail!("{name}: {n} elements not divisible into {blocks} blocks");
    }
    Ok(BlockView {
        name: name.to_string(),
        shape: shape.to_vec(),
        num_blocks: blocks,
        block_size: n / blocks,
        category: cat,
    })
}

/// Partition a whole parameter inventory, preserving order.
pub fn partition_spec(shapes: &[(String, Vec<usize>)], n_heads: usize,
                      stacked: &[String], strategy: Strategy)
                      -> Result<Vec<BlockView>> {
    shapes
        .iter()
        .map(|(name, shape)| {
            block_view(name, shape, n_heads,
                       stacked.iter().any(|s| s == name), strategy)
        })
        .collect()
}

/// Total learning-rate count (#blocks) for a spec.
pub fn total_blocks(spec: &[BlockView]) -> usize {
    spec.iter().map(|b| b.num_blocks).sum()
}

/// Total parameter count for a spec.
pub fn total_params(spec: &[BlockView]) -> usize {
    spec.iter().map(|b| b.num_blocks * b.block_size).sum()
}

/// Fraction of Adam's v removed (paper: ≥ 99.9 % for mainstream LLMs).
pub fn v_reduction_ratio(spec: &[BlockView]) -> f64 {
    1.0 - total_blocks(spec) as f64 / total_params(spec) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bv(name: &str, shape: &[usize], heads: usize, stacked: bool,
          s: Strategy) -> BlockView {
        block_view(name, shape, heads, stacked, s).unwrap()
    }

    #[test]
    fn embed_partitions_by_token() {
        let b = bv("embed", &[256, 64], 4, false, Strategy::Hessian);
        assert_eq!((b.num_blocks, b.block_size), (256, 64));
        assert_eq!(b.category, Category::TokenRow);
    }

    #[test]
    fn qk_partition_by_head_per_layer() {
        let b = bv("wq", &[4, 64, 64], 4, true, Strategy::Hessian);
        assert_eq!((b.num_blocks, b.block_size), (16, 1024));
        assert_eq!(b.category, Category::Head);
    }

    #[test]
    fn value_and_mlp_by_output_neuron() {
        let b = bv("wv", &[4, 64, 64], 4, true, Strategy::Hessian);
        assert_eq!((b.num_blocks, b.block_size), (256, 64));
        let b = bv("w1", &[4, 256, 64], 4, true, Strategy::Hessian);
        assert_eq!((b.num_blocks, b.block_size), (1024, 64));
    }

    #[test]
    fn norms_are_whole_per_layer() {
        let b = bv("attn_norm", &[4, 64], 4, true, Strategy::Hessian);
        assert_eq!((b.num_blocks, b.block_size), (4, 64));
        assert_eq!(b.category, Category::Whole);
        let b = bv("final_norm", &[64], 4, false, Strategy::Hessian);
        assert_eq!((b.num_blocks, b.block_size), (1, 64));
    }

    #[test]
    fn default_strategy_is_per_tensor_per_layer() {
        let b = bv("wq", &[4, 64, 64], 4, true, Strategy::Default);
        assert_eq!((b.num_blocks, b.block_size), (4, 4096));
        let b = bv("embed", &[256, 64], 4, false, Strategy::Default);
        assert_eq!((b.num_blocks, b.block_size), (1, 256 * 64));
    }

    #[test]
    fn value_whole_only_changes_wv() {
        let b = bv("wv", &[4, 64, 64], 4, true, Strategy::ValueWhole);
        assert_eq!((b.num_blocks, b.block_size), (4, 4096));
        assert_eq!(b.category, Category::Whole);
        let b = bv("wk", &[4, 64, 64], 4, true, Strategy::ValueWhole);
        assert_eq!(b.num_blocks, 16);
    }

    #[test]
    fn reduction_ratio_is_high_for_llm_shapes() {
        // Llama-7B-like inventory.
        let shapes: Vec<(String, Vec<usize>)> = vec![
            ("embed".into(), vec![32000, 4096]),
            ("wq".into(), vec![32, 4096, 4096]),
            ("wk".into(), vec![32, 4096, 4096]),
            ("wv".into(), vec![32, 4096, 4096]),
            ("wo".into(), vec![32, 4096, 4096]),
            ("w1".into(), vec![32, 11008, 4096]),
            ("w3".into(), vec![32, 11008, 4096]),
            ("w2".into(), vec![32, 4096, 11008]),
            ("attn_norm".into(), vec![32, 4096]),
            ("mlp_norm".into(), vec![32, 4096]),
            ("final_norm".into(), vec![4096]),
            ("output".into(), vec![32000, 4096]),
        ];
        let stacked: Vec<String> =
            ["wq", "wk", "wv", "wo", "w1", "w3", "w2", "attn_norm",
             "mlp_norm"].iter().map(|s| s.to_string()).collect();
        let spec = partition_spec(&shapes, 32, &stacked,
                                  Strategy::Hessian).unwrap();
        let r = v_reduction_ratio(&spec);
        assert!(r > 0.999, "v reduction {r}");
    }

    #[test]
    fn partition_covers_all_params_property() {
        use crate::util::prop::{check, prop_assert};
        check(64, |rng| {
            let heads = 1 + rng.below(8);
            let layers = 1 + rng.below(6);
            let d = heads * (1 + rng.below(16));
            let name = *rng.choose(&["wq", "wk", "wv", "wo", "w1",
                                     "attn_norm", "embed"]);
            let shape: Vec<usize> = match name {
                "embed" => vec![2 + rng.below(500), d],
                "attn_norm" => vec![layers, d],
                "w1" => vec![layers, 4 * d, d],
                _ => vec![layers, d, d],
            };
            let stacked = name != "embed";
            for s in [Strategy::Hessian, Strategy::Default,
                      Strategy::ValueWhole] {
                let b = block_view(name, &shape, heads, stacked, s)
                    .map_err(|e| e.to_string())?;
                let n: usize = shape.iter().product();
                prop_assert(b.num_blocks * b.block_size == n,
                            "blocks × size == numel")?;
                prop_assert(b.num_blocks >= 1, "at least one block")?;
            }
            Ok(())
        });
    }
}
