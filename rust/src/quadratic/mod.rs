//! Random-quadratic case studies (paper §2.1, Fig 4 and Fig 5).
//!
//! These are the experiments that motivate Adam-mini: on a
//! block-diagonal quadratic, Adam's coordinate-wise learning rates lose
//! to a single well-chosen rate *per dense block*.

pub mod fig4;
pub mod precond;

pub use fig4::{adam_quadratic, blockwise_gd_quadratic, gd_quadratic,
               make_fig4_hessian, QuadCurves};
pub use precond::{adam_precond_ratio, precond_sweep, PrecondPoint};
