//! Fig 4: min ½ wᵀHw with a 3-block random PD Hessian.
//!
//! Paper Appendix F.2 setup: block eigenvalues sampled 30× from
//! {1,2,3}, {99,100,101}, {4998,4999,5000}; GD uses the optimal constant
//! rate 2/(L+μ); Adam uses β1 = 0, β2 = 1 (the bias-corrected β2→1
//! limit = running mean of g², the variant that converges on quadratics
//! per Da Silva & Gazeau 2020); blockwise GD uses the per-block optimal
//! rates.

use crate::linalg::{block_diag, eigh, random_pd_from_eigs, Mat};
use crate::util::prng::Rng;

/// Loss curves for one method.
#[derive(Debug, Clone)]
pub struct QuadCurves {
    pub method: String,
    pub losses: Vec<f64>,
}

/// The paper's three-block Hessian; returns (H, block ranges).
pub fn make_fig4_hessian(rng: &mut Rng) -> (Mat, Vec<(usize, usize)>) {
    let sets: [&[f64]; 3] = [
        &[1.0, 2.0, 3.0],
        &[99.0, 100.0, 101.0],
        &[4998.0, 4999.0, 5000.0],
    ];
    let blocks: Vec<Mat> = sets
        .iter()
        .map(|set| {
            let eigs: Vec<f64> =
                (0..30).map(|_| *rng.choose(set)).collect();
            random_pd_from_eigs(&eigs, rng)
        })
        .collect();
    let ranges = vec![(0, 30), (30, 30), (60, 30)];
    (block_diag(&blocks), ranges)
}

fn loss(h: &Mat, w: &[f64]) -> f64 {
    0.5 * h
        .matvec(w)
        .iter()
        .zip(w)
        .map(|(hw, wi)| hw * wi)
        .sum::<f64>()
}

/// Extremal eigenvalues of a symmetric PD matrix.
fn l_mu(h: &Mat) -> (f64, f64) {
    let e = eigh(h);
    let l = e.values.iter().cloned().fold(f64::MIN, f64::max);
    let mu = e.values.iter().cloned().fold(f64::MAX, f64::min);
    (l, mu)
}

/// GD with the optimal constant learning rate 2/(L+μ).
pub fn gd_quadratic(h: &Mat, w0: &[f64], steps: usize) -> QuadCurves {
    let (l, mu) = l_mu(h);
    let lr = 2.0 / (l + mu);
    let mut w = w0.to_vec();
    let mut losses = Vec::with_capacity(steps + 1);
    losses.push(loss(h, &w));
    for _ in 0..steps {
        let g = h.matvec(&w);
        for (wi, gi) in w.iter_mut().zip(&g) {
            *wi -= lr * gi;
        }
        losses.push(loss(h, &w));
    }
    QuadCurves { method: "gd_optimal".into(), losses }
}

/// Blockwise GD: per-block optimal rates 2/(L_b+μ_b) (the paper's green
/// line — "collect these optimal learning rates … faster than Adam").
pub fn blockwise_gd_quadratic(h: &Mat, ranges: &[(usize, usize)],
                              w0: &[f64], steps: usize) -> QuadCurves {
    // Per-block optimal lr from each diagonal block.
    let lrs: Vec<f64> = ranges
        .iter()
        .map(|&(s, len)| {
            let hb = Mat::from_fn(len, len, |i, j| h.get(s + i, s + j));
            let (l, mu) = l_mu(&hb);
            2.0 / (l + mu)
        })
        .collect();
    let mut w = w0.to_vec();
    let mut losses = Vec::with_capacity(steps + 1);
    losses.push(loss(h, &w));
    for _ in 0..steps {
        let g = h.matvec(&w);
        for (b, &(s, len)) in ranges.iter().enumerate() {
            for i in s..s + len {
                w[i] -= lrs[b] * g[i];
            }
        }
        losses.push(loss(h, &w));
    }
    QuadCurves { method: "blockwise_gd".into(), losses }
}

/// Adam with β1 = 0, β2 = 1 (running-mean v) and a grid-tuned constant
/// lr: the strongest coordinate-wise baseline on quadratics.
pub fn adam_quadratic(h: &Mat, w0: &[f64], steps: usize, lr: f64)
    -> QuadCurves {
    let n = w0.len();
    let mut w = w0.to_vec();
    let mut v = vec![0.0f64; n];
    let mut losses = Vec::with_capacity(steps + 1);
    losses.push(loss(h, &w));
    for t in 1..=steps {
        let g = h.matvec(&w);
        for i in 0..n {
            // β2→1 limit: v_t = ((t−1)·v + g²)/t (running mean).
            v[i] = ((t - 1) as f64 * v[i] + g[i] * g[i]) / t as f64;
            w[i] -= lr * g[i] / (v[i].sqrt() + 1e-12);
        }
        losses.push(loss(h, &w));
    }
    QuadCurves { method: format!("adam_lr{lr}"), losses }
}

/// Grid-search Adam's lr on the problem, return the best curve.
pub fn adam_quadratic_tuned(h: &Mat, w0: &[f64], steps: usize)
    -> QuadCurves {
    let grid = [1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1];
    let mut best: Option<QuadCurves> = None;
    for &lr in &grid {
        let c = adam_quadratic(h, w0, steps, lr);
        let score = *c.losses.last().unwrap();
        if score.is_finite()
            && best
                .as_ref()
                .map(|b| score < *b.losses.last().unwrap())
                .unwrap_or(true)
        {
            best = Some(c);
        }
    }
    let mut b = best.unwrap();
    b.method = "adam_tuned".into();
    b
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Mat, Vec<(usize, usize)>, Vec<f64>) {
        let mut rng = Rng::new(4);
        let (h, ranges) = make_fig4_hessian(&mut rng);
        let w0: Vec<f64> = (0..h.rows).map(|_| rng.normal()).collect();
        (h, ranges, w0)
    }

    #[test]
    fn hessian_has_paper_structure() {
        let (h, ranges, _) = setup();
        assert_eq!(h.rows, 90);
        // Off-block entries are exactly zero.
        assert_eq!(h.get(0, 45), 0.0);
        assert_eq!(h.get(85, 10), 0.0);
        // Block condition numbers ≈ 3, ~1.02, ~1.0004.
        let hb0 = Mat::from_fn(30, 30, |i, j| h.get(i, j));
        let k0 = crate::linalg::cond_sym(&hb0);
        assert!(k0 <= 3.0 + 1e-6 && k0 >= 1.0);
        assert_eq!(ranges.len(), 3);
    }

    #[test]
    fn all_methods_descend() {
        let (h, ranges, w0) = setup();
        for c in [
            gd_quadratic(&h, &w0, 100),
            blockwise_gd_quadratic(&h, &ranges, &w0, 100),
            adam_quadratic_tuned(&h, &w0, 100),
        ] {
            assert!(c.losses[100] < c.losses[0] * 0.9, "{}", c.method);
        }
    }

    #[test]
    fn paper_ordering_blockwise_beats_adam_beats_gd() {
        // The paper's Fig 4b finding at a fixed moderate budget.
        let (h, ranges, w0) = setup();
        let steps = 300;
        let gd = gd_quadratic(&h, &w0, steps);
        let adam = adam_quadratic_tuned(&h, &w0, steps);
        let bw = blockwise_gd_quadratic(&h, &ranges, &w0, steps);
        let f = |c: &QuadCurves| *c.losses.last().unwrap();
        assert!(f(&bw) < f(&adam), "blockwise {} vs adam {}", f(&bw),
                f(&adam));
        assert!(f(&adam) < f(&gd), "adam {} vs gd {}", f(&adam), f(&gd));
    }

    #[test]
    fn single_block_gd_beats_adam() {
        // Fig 4(c,d): on ONE dense block, optimal single-lr GD wins.
        let mut rng = Rng::new(11);
        let eigs: Vec<f64> =
            (0..30).map(|_| *rng.choose(&[99.0, 100.0, 101.0])).collect();
        let hb = random_pd_from_eigs(&eigs, &mut rng);
        let w0: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let gd = gd_quadratic(&hb, &w0, 60);
        let adam = adam_quadratic_tuned(&hb, &w0, 60);
        assert!(gd.losses.last().unwrap() < adam.losses.last().unwrap());
    }
}
