//! Fig 5: effectiveness of Adam's diagonal preconditioner on dense
//! blocks — r = κ(D_Adam·H_b)/κ(H_b) as a function of the
//! diagonal-ratio τ, dimension d, and κ(H_b).
//!
//! Paper Appendix F.2 generator, reproduced exactly: H_b = QΛQᵀ with Λ =
//! diag(κ, 1, …, 1), Q from d(d−1)/2 Givens rotations; θ scaled by
//! R ∈ [0, 1] sweeps τ at fixed spectrum. D_Adam = Diag(1/√v), v = g⊙g,
//! g = H_b·x, x ~ N(0, 1/√d) (Xavier).

use crate::linalg::{cond_general, diag_ratio, Mat};
use crate::linalg::random::{pd_from_rotations, sample_angles};
use crate::util::prng::Rng;

/// One sweep point.
#[derive(Debug, Clone)]
pub struct PrecondPoint {
    pub d: usize,
    pub kappa: f64,
    pub scale_r: f64,
    pub tau: f64,
    /// r = κ(D_Adam H)/κ(H), averaged over inits.
    pub ratio: f64,
}

/// κ(D_Adam·H)/κ(H) for one H and one Xavier init.
pub fn adam_precond_ratio(h: &Mat, rng: &mut Rng) -> f64 {
    let d = h.rows;
    let std = (1.0 / (d as f64).sqrt()).sqrt();
    // x_i ~ N(0, 1/√d) (variance 1/√d, per the paper's code).
    let x: Vec<f64> = (0..d).map(|_| rng.normal() * std).collect();
    let g = h.matvec(&x);
    let dinv: Vec<f64> = g
        .iter()
        .map(|gi| 1.0 / (gi * gi).sqrt().max(1e-12))
        .collect();
    let dh = h.scale_rows(&dinv);
    cond_general(&dh) / cond_general(h)
}

/// Full sweep for one (d, κ): `n_theta` rotation draws × `n_init`
/// Xavier inits at each of `scales` R values.
pub fn precond_sweep(d: usize, kappa: f64, scales: &[f64],
                     n_theta: usize, n_init: usize, rng: &mut Rng)
                     -> Vec<PrecondPoint> {
    let mut eigs = vec![1.0; d];
    eigs[0] = kappa;
    let mut out = Vec::new();
    for &r in scales {
        let mut taus = Vec::new();
        let mut ratios = Vec::new();
        for _ in 0..n_theta {
            let base = sample_angles(d, rng);
            let scaled: Vec<f64> = base.iter().map(|a| a * r).collect();
            let h = pd_from_rotations(&eigs, &scaled);
            taus.push(diag_ratio(&h));
            let mut acc = 0.0;
            for _ in 0..n_init {
                acc += adam_precond_ratio(&h, rng);
            }
            ratios.push(acc / n_init as f64);
        }
        out.push(PrecondPoint {
            d,
            kappa,
            scale_r: r,
            tau: crate::util::stats::mean(&taus),
            ratio: crate::util::stats::mean(&ratios),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_small_when_diagonal() {
        // At R = 0, H is diagonal: Adam's preconditioner is near-optimal
        // (r ≤ ~1); at R = 1 (dense), r should be larger.
        let mut rng = Rng::new(2);
        let pts = precond_sweep(20, 500.0, &[0.0, 1.0], 6, 16, &mut rng);
        let diag = &pts[0];
        let dense = &pts[1];
        assert!(diag.tau > 0.99, "tau at R=0: {}", diag.tau);
        assert!(dense.tau < 0.6, "tau at R=1: {}", dense.tau);
        assert!(dense.ratio > 2.0 * diag.ratio,
                "dense r {} vs diag r {}", dense.ratio, diag.ratio);
    }

    #[test]
    fn tau_decreases_with_rotation_scale() {
        let mut rng = Rng::new(3);
        let pts = precond_sweep(16, 100.0, &[0.0, 0.3, 0.6, 1.0], 4, 4,
                                &mut rng);
        for w in pts.windows(2) {
            assert!(w[1].tau <= w[0].tau + 0.05,
                    "tau not decreasing: {} -> {}", w[0].tau, w[1].tau);
        }
    }
}
