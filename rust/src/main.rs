//! `repro` — the leader CLI of the Adam-mini reproduction framework.
//!
//! Subcommands:
//!   train [--config FILE] [key=value ...]   run one training job
//!   exp <name|all> [--quick]                regenerate a paper artifact
//!   list                                    models + experiments
//!   report                                  memory/throughput summary
//!   selfcheck                               load+run every artifact once
//!
//! (Argument parsing is hand-rolled: clap is not in the vendored crate
//! set — see DESIGN.md.)

use anyhow::{bail, Result};

use adam_mini::config::TrainConfig;
use adam_mini::coordinator::Trainer;
use adam_mini::experiments;
use adam_mini::runtime::{manifest, Engine};

fn usage() -> ! {
    eprintln!(
        "usage:\n  repro train [--config FILE] [key=value ...]\n  \
         repro exp <name|all> [--quick]\n  repro list\n  repro report\n  \
         repro selfcheck\n\ntrain keys include workers=N (data-parallel \
         engine), bucket_kb=K,\nzero1=BOOL (ZeRO-1 optimizer-state \
         sharding), zero2=BOOL (also shard\ngradients: reduce-scatter \
         schedule), overlap=BOOL (streaming bucket\npipeline), \
         bucket_step=BOOL (ZeRO-2 overlap: step each bucket's\nshard \
         segment as its reduce-scatter lands; default true)\n\n\
         artifacts dir: $ADAM_MINI_ARTIFACTS (default ./artifacts)"
    );
    std::process::exit(2);
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("train") => cmd_train(&args[1..]),
        Some("exp") => cmd_exp(&args[1..]),
        Some("list") => cmd_list(),
        Some("report") => {
            experiments::throughput::table1()?;
            experiments::throughput::table2()?;
            adam_mini::dist::traffic_report()
        }
        Some("selfcheck") => cmd_selfcheck(),
        _ => usage(),
    }
}

fn cmd_train(args: &[String]) -> Result<()> {
    let mut cfg = TrainConfig::default();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--config" => {
                i += 1;
                let path = args.get(i).unwrap_or_else(|| usage());
                cfg = TrainConfig::from_file(path)?;
            }
            kv if kv.contains('=') => cfg.apply_override(kv)?,
            _ => usage(),
        }
        i += 1;
    }
    println!("config: {}", cfg.to_json());
    let engine = Engine::new(manifest::default_dir())?;
    let mut trainer = Trainer::from_config(&engine, &cfg)?;
    let hist = trainer.train(false)?;
    let path = hist.write_csv("results/train")?;
    println!(
        "done: {} steps in {:.1}s ({:.0} tok/s), final loss {:.4}, \
         val {:.4}, optimizer state {:.1} KB\ncurve: {}",
        cfg.steps, hist.wall_secs, hist.tokens_per_sec,
        hist.final_train_loss(), hist.final_val_loss(),
        hist.opt_state_bytes as f64 / 1e3, path.display()
    );
    if let Some(stats) = trainer.comm_stats() {
        use adam_mini::dist::TrafficClass;
        let per_step = |c: TrafficClass| {
            stats.bytes(c) as f64 / cfg.steps.max(1) as f64 / 1e3
        };
        println!(
            "dist comm ({} workers): grad_reduce {:.1} KB/step, \
             grad_scatter {:.1} KB/step, param_gather {:.1} KB/step, \
             state_sync {:.1} KB total, modeled link time {:.1} ms",
            cfg.workers,
            per_step(TrafficClass::GradReduce),
            per_step(TrafficClass::GradScatter),
            per_step(TrafficClass::ParamGather),
            stats.bytes(TrafficClass::StateSync) as f64 / 1e3,
            stats.sim_link_secs() * 1e3
        );
    }
    if let Some(t) = trainer.step_timing() {
        println!(
            "overlap timeline (simulated link model): overlapped \
             {:.2} ms/step vs deferred-step {:.2} ms/step vs \
             sequential {:.2} ms/step ({:.2}x vs sequential, {:.2}x \
             vs deferred)",
            t.overlapped_ns / 1e6, t.deferred_ns / 1e6,
            t.sequential_ns / 1e6, t.speedup(), t.granular_gain()
        );
    }
    Ok(())
}

fn cmd_exp(args: &[String]) -> Result<()> {
    let Some(name) = args.first() else { usage() };
    let quick = args.iter().any(|a| a == "--quick");
    // Engine is lazy: only experiments that need artifacts get one.
    let needs_engine = |n: &str| {
        experiments::EXPERIMENTS
            .iter()
            .find(|(en, _, _)| *en == n)
            .map(|(_, _, ne)| *ne)
            .unwrap_or(true)
    };
    let run_names: Vec<&str> = if name == "all" {
        experiments::EXPERIMENTS.iter().map(|(n, _, _)| *n).collect()
    } else {
        vec![name.as_str()]
    };
    let engine = if run_names.iter().any(|n| needs_engine(n)) {
        Some(Engine::new(manifest::default_dir())?)
    } else {
        None
    };
    for n in run_names {
        println!("\n=== experiment {n} ===");
        let t = std::time::Instant::now();
        experiments::run(n, engine.as_ref(), quick)?;
        println!("=== {n} done in {:.1}s ===", t.elapsed().as_secs_f64());
    }
    Ok(())
}

fn cmd_list() -> Result<()> {
    println!("experiments (repro exp <name> [--quick]):");
    for (name, what, needs) in experiments::EXPERIMENTS {
        println!("  {name:<12} {what}{}",
                 if *needs { "" } else { "  [no artifacts needed]" });
    }
    match Engine::new(manifest::default_dir()) {
        Ok(engine) => {
            println!("\nmodels (artifacts loaded):");
            for (name, mm) in &engine.manifest.models {
                println!(
                    "  {name:<8} {:>9} params  {} L{} d{} h{} \
                     seq{} bs{}  v-cut {:.2}%  artifacts: {}",
                    mm.n_params, mm.family, mm.n_layers, mm.d_model,
                    mm.n_heads, mm.seq_len, mm.batch_size,
                    mm.v_reduction * 100.0, mm.artifacts.len());
            }
        }
        Err(e) => println!("\n(no artifacts: {e})"),
    }
    Ok(())
}

fn cmd_selfcheck() -> Result<()> {
    use adam_mini::data::{Batcher, Corpus, SyntheticSpec};
    let engine = Engine::new(manifest::default_dir())?;
    let names: Vec<String> =
        engine.manifest.models.keys().cloned().collect();
    let mut failures = 0;
    for name in &names {
        let rt = adam_mini::runtime::ModelRuntime::new(&engine, name)?;
        let params = rt.init_params(0);
        let corpus = Corpus::synthetic(&SyntheticSpec {
            vocab: rt.mm.vocab,
            n_tokens: 8 * rt.mm.batch_size * rt.mm.seq_len + 64,
            ..Default::default()
        });
        let mut b = Batcher::new(corpus, rt.mm.batch_size, rt.mm.seq_len,
                                 0);
        let batch = b.next_batch();
        match rt.grad(&params, &batch) {
            Ok((loss, grads)) => {
                let expect = (rt.mm.vocab as f32).ln();
                let gn: f64 =
                    grads.iter().map(|g| g.sq_norm()).sum::<f64>().sqrt();
                let ok = loss.is_finite()
                    && (loss - expect).abs() < 0.5 * expect
                    && gn.is_finite()
                    && gn > 0.0;
                println!(
                    "  {name:<8} loss {loss:.4} (ln V = {expect:.3}) \
                     |grad| {gn:.3e}  {}",
                    if ok { "OK" } else { "SUSPECT" });
                if !ok {
                    failures += 1;
                }
            }
            Err(e) => {
                println!("  {name:<8} FAILED: {e}");
                failures += 1;
            }
        }
    }
    if failures > 0 {
        bail!("{failures} model(s) failed selfcheck");
    }
    println!("selfcheck OK ({} models)", names.len());
    Ok(())
}
