//! `repro` — the leader CLI of the Adam-mini reproduction framework.
//!
//! Subcommands:
//!   train [--config FILE] [key=value ...]   run one training job
//!   exp <name|all> [--quick]                regenerate a paper artifact
//!   list                                    models + experiments
//!   report [--bench-history [--gate]]       memory/throughput summary
//!   serve [key=value ...]                   multi-tenant job service
//!   top [...]                               live telemetry console
//!   selfcheck                               load+run every artifact once
//!
//! (Argument parsing is hand-rolled: clap is not in the vendored crate
//! set — see DESIGN.md.)

use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use adam_mini::config::TrainConfig;
use adam_mini::coordinator::Trainer;
use adam_mini::experiments;
use adam_mini::runtime::{manifest, Engine};
use adam_mini::telemetry::{self, Telemetry, DEFAULT_BUS_CAPACITY};

fn usage() -> ! {
    eprintln!(
        "usage:\n  repro train [--config FILE] [key=value ...]\n  \
         repro exp <name|all> [--quick]\n  repro list\n  \
         repro report [--bench-history [--gate]]\n  \
         repro serve [tenants=N pool=N sched=fair|fifo|priority \
         storm_seed=N\n              quantum=K jobs=N rank=R \
         optimizer=NAME fail_rate=X trace=FILE]\n  \
         repro top [workers=N steps=K zero2=BOOL interval=MS]\n  \
         repro top --replay FILE.jsonl [--once] [interval=MS]\n  \
         repro top --record FILE.jsonl [workers=N steps=K zero2=BOOL]\n  \
         repro top --check FILE.jsonl\n  \
         repro selfcheck\n\ntrain keys include workers=N (data-parallel \
         engine), bucket_kb=K,\nzero1=BOOL (ZeRO-1 optimizer-state \
         sharding), zero2=BOOL (also shard\ngradients: reduce-scatter \
         schedule), overlap=BOOL (streaming bucket\npipeline), \
         bucket_step=BOOL (ZeRO-2 overlap: step each bucket's\nshard \
         segment as its reduce-scatter lands; default true),\n\
         simd=auto|on|off (optimizer kernel dispatch; off = scalar\n\
         parity oracle), clip=X (global-norm gradient clip, folded\n\
         into the fused update sweep; host path only, 0 = off),\n\
         transport=channel|tcp|socket (dist wire: in-process \
         channels,\nframed localhost TCP, or one OS process per rank \
         — socket\nrequires model=bigram), fault=SPEC \
         (deterministic fault\ninjection on socket transports, e.g. \
         \"drop:0.2,dup:0.1\"),\nfault_seed=N, \
         compress=none|f16|topk[:FRAC] (gradient codec\nunder the \
         collectives: f16 quantization or sparse top-|g| with\nerror \
         feedback; needs workers>1),\n\
         trace=FILE.jsonl (record every telemetry event; a \
         Chrome-trace\nsibling FILE.chrome.json is exported at the \
         end — load it in\nabout://tracing)\n\ntop: live dashboard \
         over an artifact-free dist probe. --replay\nre-renders a \
         recorded trace (--once prints one plain frame, no\nTTY \
         needed — the CI mode); --record writes a probe trace; \
         --check\nvalidates one (every line parses, seq gaps <= \
         reported drops)\n\n\
         artifacts dir: $ADAM_MINI_ARTIFACTS (default ./artifacts)"
    );
    std::process::exit(2);
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("train") => cmd_train(&args[1..]),
        // Hidden: re-exec target for multi-process `transport=socket`
        // runs (config + rank arrive via env vars, see dist::transport::proc).
        Some("dist-worker") => {
            adam_mini::coordinator::bigram::worker_main()
        }
        Some("exp") => cmd_exp(&args[1..]),
        Some("list") => cmd_list(),
        Some("report") => cmd_report(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("top") => cmd_top(&args[1..]),
        Some("selfcheck") => cmd_selfcheck(),
        _ => usage(),
    }
}

fn cmd_report(args: &[String]) -> Result<()> {
    if args.iter().any(|a| a == "--bench-history") {
        let gate = args.iter().any(|a| a == "--gate");
        return experiments::bench_history::report(gate);
    }
    experiments::throughput::table1()?;
    experiments::throughput::table2()?;
    adam_mini::dist::traffic_report()?;
    adam_mini::dist::compression_report()?;
    adam_mini::serve::memory_report()
}

/// `repro serve`: run the seeded storm to all-terminal, print the
/// report, and exit non-zero if any job is stuck or a tenant starved
/// (the CI smoke contract).
fn cmd_serve(args: &[String]) -> Result<()> {
    let cfg = adam_mini::serve::ServeConfig::parse_args(args)?;
    let report = adam_mini::serve::run(&cfg)?;
    adam_mini::serve::print_report(&report);
    report.check()
}

fn cmd_top(args: &[String]) -> Result<()> {
    let (mut workers, mut steps, mut zero2) = (4usize, 40usize, true);
    let mut interval: u64 = 120;
    let (mut replay, mut record, mut check) = (None, None, None);
    let mut once = false;
    let mut i = 0;
    while i < args.len() {
        let path_arg = |args: &[String], i: usize| {
            args.get(i).cloned().unwrap_or_else(|| usage())
        };
        match args[i].as_str() {
            "--replay" => {
                i += 1;
                replay = Some(path_arg(args, i));
            }
            "--record" => {
                i += 1;
                record = Some(path_arg(args, i));
            }
            "--check" => {
                i += 1;
                check = Some(path_arg(args, i));
            }
            "--once" => once = true,
            kv if kv.contains('=') => {
                let (k, v) = kv.split_once('=').unwrap();
                match k {
                    "workers" => workers = v.parse()?,
                    "steps" => steps = v.parse()?,
                    "zero2" => zero2 = v.parse()?,
                    "interval" => interval = v.parse()?,
                    _ => usage(),
                }
            }
            _ => usage(),
        }
        i += 1;
    }
    if let Some(path) = check {
        println!("{}", telemetry::check_report(&path)?);
        return Ok(());
    }
    if let Some(path) = record {
        let (published, dropped) = adam_mini::dist::record_probe_trace(
            &path, workers, steps, zero2)?;
        println!("recorded {path}: {published} events published, \
                  {dropped} dropped");
        return Ok(());
    }
    if let Some(path) = replay {
        return telemetry::top::replay(&path, once, interval);
    }
    adam_mini::dist::probe_top_live(workers, steps, zero2, interval)
}

fn cmd_train(args: &[String]) -> Result<()> {
    let mut cfg = TrainConfig::default();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--config" => {
                i += 1;
                let path = args.get(i).unwrap_or_else(|| usage());
                cfg = TrainConfig::from_file(path)?;
            }
            kv if kv.contains('=') => cfg.apply_override(kv)?,
            _ => usage(),
        }
        i += 1;
    }
    println!("config: {}", cfg.to_json());
    if cfg.model == "bigram" {
        // Artifact-free path — the only model that can span OS
        // processes (transport=socket); also runs channel/tcp.
        return adam_mini::coordinator::bigram::train(&cfg);
    }
    let engine = Engine::new(manifest::default_dir())?;
    let mut trainer = Trainer::from_config(&engine, &cfg)?;
    let tel = if cfg.trace.is_empty() {
        None
    } else {
        let t = Arc::new(Mutex::new(Telemetry::with_trace(
            DEFAULT_BUS_CAPACITY, &cfg.trace)?));
        trainer.attach_telemetry(Arc::clone(&t));
        Some(t)
    };
    let hist = trainer.train(false)?;
    let path = hist.write_csv("results/train")?;
    println!(
        "done: {} steps in {:.1}s ({:.0} tok/s), final loss {:.4}, \
         val {:.4}, optimizer state {:.1} KB\ncurve: {}",
        cfg.steps, hist.wall_secs, hist.tokens_per_sec,
        hist.final_train_loss(), hist.final_val_loss(),
        hist.opt_state_bytes as f64 / 1e3, path.display()
    );
    if let Some(stats) = trainer.comm_stats() {
        use adam_mini::dist::TrafficClass;
        let per_step = |c: TrafficClass| {
            stats.bytes(c) as f64 / cfg.steps.max(1) as f64 / 1e3
        };
        println!(
            "dist comm ({} workers): grad_reduce {:.1} KB/step, \
             grad_scatter {:.1} KB/step, param_gather {:.1} KB/step, \
             state_sync {:.1} KB total, modeled link time {:.1} ms",
            cfg.workers,
            per_step(TrafficClass::GradReduce),
            per_step(TrafficClass::GradScatter),
            per_step(TrafficClass::ParamGather),
            stats.bytes(TrafficClass::StateSync) as f64 / 1e3,
            stats.sim_link_secs() * 1e3
        );
        let coded = per_step(TrafficClass::CodecF16)
            + per_step(TrafficClass::CodecTopK);
        if coded > 0.0 {
            println!("codec ({}): {coded:.1} KB/step coded traffic",
                     cfg.compress);
        }
    }
    if let Some(t) = trainer.step_timing() {
        println!(
            "overlap timeline (simulated link model): overlapped \
             {:.2} ms/step vs deferred-step {:.2} ms/step vs \
             sequential {:.2} ms/step ({:.2}x vs sequential, {:.2}x \
             vs deferred)",
            t.overlapped_ns / 1e6, t.deferred_ns / 1e6,
            t.sequential_ns / 1e6, t.speedup(), t.granular_gain()
        );
    }
    if let Some(t) = tel {
        let mut t = t.lock().unwrap_or_else(|e| e.into_inner());
        let bus = t.bus();
        if let Some(path) = t.finish_mut()? {
            let chrome = telemetry::export_chrome(&path)?;
            println!(
                "trace: {} ({} events, {} dropped)  chrome: {} \
                 (open in about://tracing)",
                path.display(), bus.published(), bus.dropped(),
                chrome.display()
            );
        }
    }
    Ok(())
}

fn cmd_exp(args: &[String]) -> Result<()> {
    let Some(name) = args.first() else { usage() };
    let quick = args.iter().any(|a| a == "--quick");
    // Engine is lazy: only experiments that need artifacts get one.
    let needs_engine = |n: &str| {
        experiments::EXPERIMENTS
            .iter()
            .find(|(en, _, _)| *en == n)
            .map(|(_, _, ne)| *ne)
            .unwrap_or(true)
    };
    let run_names: Vec<&str> = if name == "all" {
        experiments::EXPERIMENTS.iter().map(|(n, _, _)| *n).collect()
    } else {
        vec![name.as_str()]
    };
    let engine = if run_names.iter().any(|n| needs_engine(n)) {
        Some(Engine::new(manifest::default_dir())?)
    } else {
        None
    };
    for n in run_names {
        println!("\n=== experiment {n} ===");
        let t = std::time::Instant::now();
        experiments::run(n, engine.as_ref(), quick)?;
        println!("=== {n} done in {:.1}s ===", t.elapsed().as_secs_f64());
    }
    Ok(())
}

fn cmd_list() -> Result<()> {
    println!("experiments (repro exp <name> [--quick]):");
    for (name, what, needs) in experiments::EXPERIMENTS {
        println!("  {name:<12} {what}{}",
                 if *needs { "" } else { "  [no artifacts needed]" });
    }
    match Engine::new(manifest::default_dir()) {
        Ok(engine) => {
            println!("\nmodels (artifacts loaded):");
            for (name, mm) in &engine.manifest.models {
                println!(
                    "  {name:<8} {:>9} params  {} L{} d{} h{} \
                     seq{} bs{}  v-cut {:.2}%  artifacts: {}",
                    mm.n_params, mm.family, mm.n_layers, mm.d_model,
                    mm.n_heads, mm.seq_len, mm.batch_size,
                    mm.v_reduction * 100.0, mm.artifacts.len());
            }
        }
        Err(e) => println!("\n(no artifacts: {e})"),
    }
    Ok(())
}

fn cmd_selfcheck() -> Result<()> {
    use adam_mini::data::{Batcher, Corpus, SyntheticSpec};
    let engine = Engine::new(manifest::default_dir())?;
    let names: Vec<String> =
        engine.manifest.models.keys().cloned().collect();
    let mut failures = 0;
    for name in &names {
        let rt = adam_mini::runtime::ModelRuntime::new(&engine, name)?;
        let params = rt.init_params(0);
        let corpus = Corpus::synthetic(&SyntheticSpec {
            vocab: rt.mm.vocab,
            n_tokens: 8 * rt.mm.batch_size * rt.mm.seq_len + 64,
            ..Default::default()
        });
        let mut b = Batcher::new(corpus, rt.mm.batch_size, rt.mm.seq_len,
                                 0);
        let batch = b.next_batch();
        match rt.grad(&params, &batch) {
            Ok((loss, grads)) => {
                let expect = (rt.mm.vocab as f32).ln();
                let gn: f64 =
                    grads.iter().map(|g| g.sq_norm()).sum::<f64>().sqrt();
                let ok = loss.is_finite()
                    && (loss - expect).abs() < 0.5 * expect
                    && gn.is_finite()
                    && gn > 0.0;
                println!(
                    "  {name:<8} loss {loss:.4} (ln V = {expect:.3}) \
                     |grad| {gn:.3e}  {}",
                    if ok { "OK" } else { "SUSPECT" });
                if !ok {
                    failures += 1;
                }
            }
            Err(e) => {
                println!("  {name:<8} FAILED: {e}");
                failures += 1;
            }
        }
    }
    if failures > 0 {
        bail!("{failures} model(s) failed selfcheck");
    }
    println!("selfcheck OK ({} models)", names.len());
    Ok(())
}
