//! Optimizer-state memory accounting (paper Table 1).
//!
//! Exact arithmetic over real model shape inventories — GPT-2-1.5B and
//! the Llama family at their published dimensions — in float32 (the
//! paper's Table 1 convention). AdamW state = 2 floats/param (m and v);
//! Adam-mini state = 1 float/param (m) + 1 float/Hessian-block (v_b),
//! with blocks from the Algorithm-3 partition.

use crate::partition::{partition_spec, total_blocks, BlockView, Strategy};

/// Architecture descriptor sufficient to enumerate parameter shapes.
#[derive(Debug, Clone)]
pub struct ArchSpec {
    pub name: &'static str,
    pub family: &'static str, // "gpt2" | "llama"
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    /// Tied embedding/output matrix (GPT-2 convention).
    pub tied_embeddings: bool,
    /// KV heads for grouped-query attention (== n_heads when MHA).
    pub kv_heads: usize,
}

/// The published models of paper Table 1.
pub fn table1_models() -> Vec<ArchSpec> {
    vec![
        // GPT-2 XL ("GPT-2-1.5B"): d=1600, 48 layers, 25 heads, ff=6400.
        ArchSpec { name: "GPT-2-1.5B", family: "gpt2", vocab: 50257,
                   d_model: 1600, n_layers: 48, n_heads: 25, d_ff: 6400,
                   seq_len: 1024, tied_embeddings: true, kv_heads: 25 },
        // Paper's Llama 2-1B (Table 8 geometry at pre-7B scale):
        // d=2048, 18 layers; ff = 8/3·d rounded to 5504.
        ArchSpec { name: "Llama 2-1B", family: "llama", vocab: 32000,
                   d_model: 2048, n_layers: 18, n_heads: 16, d_ff: 5504,
                   seq_len: 2048, tied_embeddings: false, kv_heads: 16 },
        ArchSpec { name: "Llama 2-7B", family: "llama", vocab: 32000,
                   d_model: 4096, n_layers: 32, n_heads: 32, d_ff: 11008,
                   seq_len: 4096, tied_embeddings: false, kv_heads: 32 },
        ArchSpec { name: "Llama 3-8B", family: "llama", vocab: 128256,
                   d_model: 4096, n_layers: 32, n_heads: 32, d_ff: 14336,
                   seq_len: 8192, tied_embeddings: false, kv_heads: 8 },
        ArchSpec { name: "Llama 2-13B", family: "llama", vocab: 32000,
                   d_model: 5120, n_layers: 40, n_heads: 40, d_ff: 13824,
                   seq_len: 4096, tied_embeddings: false, kv_heads: 40 },
    ]
}

impl ArchSpec {
    /// Full parameter shape inventory in the framework's naming scheme
    /// (stacked per-layer tensors).
    pub fn param_shapes(&self) -> Vec<(String, Vec<usize>)> {
        let (l, d, ff, v) = (self.n_layers, self.d_model, self.d_ff,
                             self.vocab);
        let mut shapes: Vec<(String, Vec<usize>)> =
            vec![("embed".into(), vec![v, d])];
        if self.family == "gpt2" {
            shapes.push(("pos_emb".into(), vec![self.seq_len, d]));
        }
        // GQA: K/V projections use kv_heads · head_dim output rows.
        let d_kv = d / self.n_heads * self.kv_heads;
        shapes.push(("wq".into(), vec![l, d, d]));
        shapes.push(("wk".into(), vec![l, d_kv, d]));
        shapes.push(("wv".into(), vec![l, d_kv, d]));
        shapes.push(("wo".into(), vec![l, d, d]));
        if self.family == "llama" {
            shapes.push(("w1".into(), vec![l, ff, d]));
            shapes.push(("w3".into(), vec![l, ff, d]));
            shapes.push(("w2".into(), vec![l, d, ff]));
        } else {
            shapes.push(("w_in".into(), vec![l, ff, d]));
            shapes.push(("w_out".into(), vec![l, d, ff]));
        }
        shapes.push(("attn_norm".into(), vec![l, d]));
        shapes.push(("mlp_norm".into(), vec![l, d]));
        shapes.push(("final_norm".into(), vec![d]));
        if !self.tied_embeddings {
            shapes.push(("output".into(), vec![v, d]));
        }
        shapes
    }

    pub fn stacked_names(&self) -> Vec<String> {
        self.param_shapes()
            .iter()
            .filter(|(n, s)| {
                s.first() == Some(&self.n_layers)
                    && !matches!(n.as_str(), "embed" | "output" | "pos_emb")
            })
            .map(|(n, _)| n.clone())
            .collect()
    }

    pub fn n_params(&self) -> usize {
        self.param_shapes()
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum()
    }

    pub fn spec(&self, strategy: Strategy) -> Vec<BlockView> {
        partition_spec(&self.param_shapes(), self.n_heads,
                       &self.stacked_names(), strategy)
            .expect("partition")
    }
}

/// Optimizer-state memory report for one architecture.
#[derive(Debug, Clone)]
pub struct MemoryReport {
    pub model: String,
    pub n_params: usize,
    pub n_blocks: usize,
    pub adamw_bytes: u64,
    pub adam_mini_bytes: u64,
}

impl MemoryReport {
    pub fn saving_pct(&self) -> f64 {
        100.0 * (1.0 - self.adam_mini_bytes as f64
                 / self.adamw_bytes as f64)
    }
}

/// Compute the Table 1 row for an architecture (float32 states).
pub fn memory_report(arch: &ArchSpec) -> MemoryReport {
    let n = arch.n_params() as u64;
    let spec = arch.spec(Strategy::Hessian);
    let blocks = total_blocks(&spec) as u64;
    MemoryReport {
        model: arch.name.to_string(),
        n_params: n as usize,
        n_blocks: blocks as usize,
        // AdamW: m + v, 4 bytes each.
        adamw_bytes: 2 * 4 * n,
        // Adam-mini: m + one scalar per block.
        adam_mini_bytes: 4 * (n + blocks),
    }
}

pub fn gib(bytes: u64) -> f64 {
    bytes as f64 / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::v_reduction_ratio;

    #[test]
    fn param_counts_match_published_sizes() {
        // Paper Table 1 derives memory as 2·4·N; invert their GB figures
        // to the implied N and check we are within 6 % (the paper's own
        // Table-8 1B geometry yields 1.04e9 vs their rounded 8.80 GB).
        let expect = [
            ("GPT-2-1.5B", 12.48f64),
            ("Llama 2-1B", 8.80),
            ("Llama 2-7B", 53.92),
            ("Llama 3-8B", 64.24),
            ("Llama 2-13B", 104.16),
        ];
        for (arch, (name, gb)) in table1_models().iter().zip(expect) {
            assert_eq!(arch.name, name);
            let implied = gb * 1e9 / 8.0;
            let ours = arch.n_params() as f64;
            let rel = (ours - implied).abs() / implied;
            assert!(rel < 0.06, "{name}: ours {ours:.3e} vs implied \
                     {implied:.3e} ({:.1}%)", rel * 100.0);
        }
    }

    #[test]
    fn adam_mini_saves_about_half() {
        for arch in table1_models() {
            let r = memory_report(&arch);
            let s = r.saving_pct();
            assert!(s > 49.9 && s <= 50.0, "{}: saving {s}%", r.model);
        }
    }

    #[test]
    fn v_reduction_exceeds_999_permille() {
        for arch in table1_models() {
            let spec = arch.spec(Strategy::Hessian);
            let r = v_reduction_ratio(&spec);
            assert!(r >= 0.999, "{}: v reduction {r}", arch.name);
        }
    }

    #[test]
    fn seven_b_is_about_6_7b_params() {
        let seven = &table1_models()[2];
        let n = seven.n_params();
        assert!((6.5e9..7.0e9).contains(&(n as f64)), "n = {n}");
    }
}
