//! Minimal JSON parser/writer (RFC 8259 subset sufficient for the
//! artifact manifest, run configs, and metrics logs).
//!
//! Hand-rolled because serde/serde_json are not in the vendored crate
//! set. Supports objects, arrays, strings (with escapes, incl. \uXXXX
//! BMP), numbers (f64), booleans and null. Not supported: surrogate
//! pairs, duplicate-key detection (last wins).

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value. Objects use `BTreeMap` for deterministic output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // ----- typed accessors -------------------------------------------------

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => Err(anyhow!("expected object, got {other:?}")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => Err(anyhow!("expected array, got {other:?}")),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(anyhow!("expected string, got {other:?}")),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(anyhow!("expected number, got {other:?}")),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(anyhow!("expected bool, got {other:?}")),
        }
    }

    /// `obj["key"]` with a useful error.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// Optional key lookup.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    // ----- builders ---------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num<T: Into<f64>>(n: T) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                _ => {
                    // Re-scan as UTF-8: back up and take the full char.
                    self.i -= 1;
                    let rest = std::str::from_utf8(&self.b[self.i..])?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow!("bad number {text:?} at byte {start}: {e}")
        })?))
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -1.5e3 ").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":false}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_usize().unwrap(), 1);
        assert!(!arr[2].get("b").unwrap().as_bool().unwrap());
    }

    #[test]
    fn parse_unicode_escape() {
        let v = Json::parse("\"\\u00e9\\u4e2d\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "é中");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,null,true],"s":"q\"uote\n","o":{}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn errors_carry_context() {
        let v = Json::parse(r#"{"a":1}"#).unwrap();
        let e = v.get("missing").unwrap_err().to_string();
        assert!(e.contains("missing"));
    }
}
