//! Deterministic PRNG (xoshiro256++ seeded by SplitMix64) plus the
//! samplers the framework needs: uniform, normal (Box–Muller), integer
//! ranges, shuffles, and the Zipfian sampler behind the synthetic corpus.
//!
//! Self-contained because the `rand` crate is not in the vendored set.
//! Every experiment takes an explicit seed, so all results in
//! EXPERIMENTS.md are bit-reproducible.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller sample.
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (for per-worker/per-tensor seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Uses rejection to avoid modulo bias.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(x) = self.spare.take() {
            return x;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    pub fn normal_f32(&mut self, std: f32) -> f32 {
        (self.normal() as f32) * std
    }

    /// Vector of N(0, std) f32 samples.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32(std)).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Pick one element by reference.
    pub fn choose<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.below(v.len())]
    }
}

/// Zipfian distribution over {0..n-1} with exponent `s` (token unigram
/// model for the synthetic corpus). Sampled by inverse CDF over a
/// precomputed table — O(log n) per draw.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut rng = Rng::new(1);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(2);
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut rng = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(4);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut rng = Rng::new(5);
        let z = Zipf::new(100, 1.1);
        let mut counts = [0usize; 100];
        for _ in 0..10_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > counts[50]);
    }

    #[test]
    fn fork_streams_differ() {
        let mut base = Rng::new(6);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
