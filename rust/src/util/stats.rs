//! Small statistics helpers: summary stats, percentiles, least-squares
//! fits (used by the scaling-law experiment and the bench harness).

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// Percentile by linear interpolation, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Ordinary least squares fit y = a + b*x. Returns (a, b, r2).
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let mx = mean(xs);
    let my = mean(ys);
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let b = sxy / sxx;
    let a = my - b * mx;
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (a + b * x);
            e * e
        })
        .sum();
    let ss_tot: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
    (a, b, r2)
}

/// Power-law fit y = c * x^k via log-log OLS. Returns (c, k, r2).
/// Used for the Chinchilla-style scaling-law fit (paper Fig 11b).
pub fn powerfit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    let lx: Vec<f64> = xs.iter().map(|x| x.ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|y| y.ln()).collect();
    let (a, b, r2) = linfit(&lx, &ly);
    (a.exp(), b, r2)
}

/// Exponential moving average smoothing of a series.
pub fn ema(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = None;
    for &x in xs {
        let v = match acc {
            None => x,
            Some(prev) => alpha * x + (1.0 - alpha) * prev,
        };
        acc = Some(v);
        out.push(v);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std(&xs) - 1.118).abs() < 1e-3);
    }

    #[test]
    fn percentiles() {
        let xs = [3.0, 1.0, 2.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn linfit_exact_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let (a, b, r2) = linfit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn powerfit_recovers_exponent() {
        let xs: Vec<f64> = (1..=16).map(|i| i as f64 * 1e6).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 12.0 * x.powf(-0.31)).collect();
        let (c, k, r2) = powerfit(&xs, &ys);
        assert!((k + 0.31).abs() < 1e-9, "k={k}");
        assert!((c - 12.0).abs() < 1e-6);
        assert!(r2 > 0.999999);
    }

    #[test]
    fn ema_smooths() {
        let out = ema(&[0.0, 10.0], 0.5);
        assert_eq!(out, vec![0.0, 5.0]);
    }
}
