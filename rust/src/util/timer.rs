//! Wall-clock timing + the bench harness used by `cargo bench`.
//!
//! Criterion is not in the vendored crate set, so every `[[bench]]`
//! target is `harness = false` and uses [`Bench`] here: warmup, then
//! timed iterations with mean/std/percentiles, printed in a stable
//! machine-grepable format (`BENCH <name> mean_ns=... p50_ns=...`).

use std::time::{Duration, Instant};

use super::stats;

/// Simple scope timer.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Timer {
        Timer(Instant::now())
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Minimal criterion replacement.
pub struct Bench {
    /// Target measurement wall-time per benchmark.
    pub measure_time: Duration,
    /// Warmup wall-time before measuring.
    pub warmup_time: Duration,
    /// Cap on measured iterations (useful for slow end-to-end steps).
    pub max_iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            measure_time: Duration::from_secs(2),
            warmup_time: Duration::from_millis(300),
            max_iters: 10_000,
        }
    }
}

impl Bench {
    pub fn quick() -> Bench {
        Bench {
            measure_time: Duration::from_millis(500),
            warmup_time: Duration::from_millis(100),
            max_iters: 1_000,
        }
    }

    /// Run `f` repeatedly, report stats. `f` should include no setup.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup.
        let w = Instant::now();
        let mut warm_iters = 0usize;
        while w.elapsed() < self.warmup_time && warm_iters < self.max_iters {
            f();
            warm_iters += 1;
        }
        // Measure.
        let mut samples = Vec::new();
        let m = Instant::now();
        while m.elapsed() < self.measure_time && samples.len() < self.max_iters
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        if samples.is_empty() {
            // f() slower than measure_time: take one mandatory sample.
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        let r = BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            mean_ns: stats::mean(&samples),
            std_ns: stats::std(&samples),
            p50_ns: stats::percentile(&samples, 50.0),
            p95_ns: stats::percentile(&samples, 95.0),
        };
        println!(
            "BENCH {} iters={} mean_ns={:.0} std_ns={:.0} p50_ns={:.0} \
             p95_ns={:.0} ({:.3} ms/iter)",
            r.name, r.iters, r.mean_ns, r.std_ns, r.p50_ns, r.p95_ns,
            r.mean_ms()
        );
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let b = Bench {
            measure_time: Duration::from_millis(30),
            warmup_time: Duration::from_millis(5),
            max_iters: 100_000,
        };
        let mut acc = 0u64;
        let r = b.run("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(r.iters > 0);
        assert!(r.mean_ns >= 0.0);
    }

    #[test]
    fn timer_advances() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.millis() >= 1.0);
    }
}
