//! CSV writer + run-metrics logger. Every experiment writes its raw
//! series under `results/` so figures/tables are regenerable and
//! diffable (EXPERIMENTS.md references these files).

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::Result;

/// Buffered CSV file writer.
pub struct Csv {
    w: BufWriter<File>,
    cols: usize,
    pub path: PathBuf,
}

impl Csv {
    /// Create (truncating) a CSV with a header row; parent dirs created.
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> Result<Csv> {
        let path = path.as_ref().to_path_buf();
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        let mut w = BufWriter::new(File::create(&path)?);
        writeln!(w, "{}", header.join(","))?;
        Ok(Csv { w, cols: header.len(), path })
    }

    /// Write one row of f64 cells.
    pub fn row(&mut self, cells: &[f64]) -> Result<()> {
        assert_eq!(cells.len(), self.cols, "column count mismatch");
        let line: Vec<String> = cells.iter().map(|c| format!("{c}")).collect();
        writeln!(self.w, "{}", line.join(","))?;
        Ok(())
    }

    /// Write one row of pre-formatted string cells.
    pub fn row_str(&mut self, cells: &[String]) -> Result<()> {
        assert_eq!(cells.len(), self.cols, "column count mismatch");
        writeln!(self.w, "{}", cells.join(","))?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

/// Render an aligned ASCII table (for terminal reports that mirror the
/// paper's tables).
pub fn ascii_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, c) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(c.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let hdr: Vec<String> = header.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&hdr, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_csv() {
        let dir = std::env::temp_dir().join("adam_mini_csv_test");
        let path = dir.join("x.csv");
        let mut c = Csv::create(&path, &["a", "b"]).unwrap();
        c.row(&[1.0, 2.5]).unwrap();
        c.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2.5\n");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn row_width_checked() {
        let dir = std::env::temp_dir().join("adam_mini_csv_test2");
        let mut c = Csv::create(dir.join("y.csv"), &["a", "b"]).unwrap();
        c.row(&[1.0]).unwrap();
    }

    #[test]
    fn table_aligns() {
        let t = ascii_table(&["name", "v"],
                            &[vec!["adamw".into(), "1".into()],
                              vec!["adam-mini".into(), "22".into()]]);
        assert!(t.contains("adam-mini"));
        assert!(t.lines().count() == 4);
    }
}
