//! Mini property-testing harness (proptest is not in the vendored crate
//! set; DESIGN.md records the substitution).
//!
//! Usage:
//! ```ignore
//! check(256, |rng| {
//!     let n = 1 + rng.below(64);
//!     let v = rng.normal_vec(n, 1.0);
//!     prop_assert(v.len() == n, "length preserved")
//! });
//! ```
//!
//! Each case gets an independent seeded [`Rng`]; on failure the harness
//! reports the failing seed so the case is replayable with
//! [`check_seed`]. No shrinking — failing inputs are regenerated from
//! the seed instead.

use super::prng::Rng;

/// A property over one randomized case. Return `Err(msg)` to fail.
pub type Property = fn(&mut Rng) -> Result<(), String>;

/// Assert helper for property bodies.
pub fn prop_assert(cond: bool, msg: &str) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

/// Assert |a-b| <= atol + rtol*|b| for property bodies.
pub fn prop_close(a: f64, b: f64, atol: f64, rtol: f64, what: &str)
    -> Result<(), String> {
    if (a - b).abs() <= atol + rtol * b.abs() {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (atol={atol}, rtol={rtol})"))
    }
}

/// Run `cases` randomized cases of a property; panics with the failing
/// seed + message on the first failure.
pub fn check<F>(cases: u64, f: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    check_base_seed(0xADA0_0001, cases, f)
}

/// Like [`check`] but with an explicit base seed (keeps independent
/// properties on independent streams).
pub fn check_base_seed<F>(base: u64, cases: u64, f: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property failed (case {case}, seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single failing case by seed.
pub fn check_seed<F>(seed: u64, f: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("property failed (seed {seed:#x}): {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(64, |rng| {
            let x = rng.f64();
            prop_assert((0.0..1.0).contains(&x), "uniform in [0,1)")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        check(64, |rng| {
            prop_assert(rng.f64() < 0.5, "always below half (false)")
        });
    }

    #[test]
    fn prop_close_tolerances() {
        assert!(prop_close(1.0, 1.0 + 1e-9, 1e-8, 0.0, "x").is_ok());
        assert!(prop_close(1.0, 2.0, 1e-8, 0.0, "x").is_err());
        assert!(prop_close(100.0, 101.0, 0.0, 0.02, "x").is_ok());
    }
}
