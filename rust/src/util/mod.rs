//! Shared utilities: JSON, PRNG, stats, CSV logging, a mini
//! property-testing harness, and wall-clock timers.
//!
//! These exist because the offline build environment vendors only the
//! `xla` crate's dependency set — no serde / rand / proptest / criterion —
//! so the framework carries its own minimal, well-tested implementations
//! (documented as a substitution in DESIGN.md).

pub mod csv;
pub mod json;
pub mod prng;
pub mod prop;
pub mod stats;
pub mod timer;
