//! Dense linear algebra substrate (f64): matrices, a cyclic-Jacobi
//! symmetric eigensolver, condition numbers, and the random
//! positive-definite generators of the paper's §2 case studies
//! (Fig 4 block Hessians, Fig 5 rotation-controlled H_b).
//!
//! Built from scratch — no LAPACK in the environment. The Jacobi solver
//! is O(n³) per sweep, plenty for the paper's matrix sizes (d ≤ a few
//! hundred).

pub mod jacobi;
pub mod mat;
pub mod random;

pub use jacobi::{eigh, Eigh};
pub use mat::Mat;
pub use random::{block_diag, random_pd_from_eigs, rotation_matrix};

/// Condition number κ = λ_max/λ_min from a symmetric PD matrix.
pub fn cond_sym(h: &Mat) -> f64 {
    let e = eigh(h);
    let max = e.values.iter().cloned().fold(f64::MIN, f64::max);
    let min = e.values.iter().cloned().fold(f64::MAX, f64::min);
    max / min
}

/// Condition number of a general (possibly non-symmetric) matrix A via
/// singular values: κ(A) = σ_max/σ_min = sqrt(κ(AᵀA) eigenvalues).
/// Needed for κ(D·H) where D·H is not symmetric (paper Eq. 2).
pub fn cond_general(a: &Mat) -> f64 {
    let ata = a.transpose().matmul(a);
    let e = eigh(&ata);
    let max = e.values.iter().cloned().fold(f64::MIN, f64::max).max(0.0);
    let min = e.values.iter().cloned().fold(f64::MAX, f64::min).max(0.0);
    (max / min).sqrt()
}

/// Diagonal-over-off-diagonal ratio τ = Σ|H_ii| / Σ|H_ij| (paper Eq. 2):
/// 1 for diagonal matrices, → 0 as mass moves off the diagonal.
pub fn diag_ratio(h: &Mat) -> f64 {
    let mut diag = 0.0;
    let mut all = 0.0;
    for i in 0..h.rows {
        for j in 0..h.cols {
            let v = h.get(i, j).abs();
            all += v;
            if i == j {
                diag += v;
            }
        }
    }
    diag / all
}

/// Fraction of |H| "energy" (squared Frobenius mass) inside the given
/// diagonal blocks — the block-diagonal-structure metric for Fig 3/7.
/// `blocks` are (start, len) row/col ranges covering [0, n).
pub fn block_energy_ratio(h: &Mat, blocks: &[(usize, usize)]) -> f64 {
    let mut inside = 0.0;
    let mut total = 0.0;
    for i in 0..h.rows {
        for j in 0..h.cols {
            let v = h.get(i, j);
            let e = v * v;
            total += e;
            if blocks
                .iter()
                .any(|&(s, l)| i >= s && i < s + l && j >= s && j < s + l)
            {
                inside += e;
            }
        }
    }
    if total == 0.0 {
        0.0
    } else {
        inside / total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cond_of_diagonal() {
        let mut h = Mat::zeros(3, 3);
        h.set(0, 0, 1.0);
        h.set(1, 1, 4.0);
        h.set(2, 2, 2.0);
        assert!((cond_sym(&h) - 4.0).abs() < 1e-9);
        assert!((cond_general(&h) - 4.0).abs() < 1e-7);
    }

    #[test]
    fn diag_ratio_extremes() {
        let h = Mat::identity(4);
        assert!((diag_ratio(&h) - 1.0).abs() < 1e-12);
        let mut dense = Mat::from_fn(4, 4, |_, _| 1.0);
        assert!((diag_ratio(&dense) - 0.25).abs() < 1e-12);
        dense.set(0, 0, 0.0);
        assert!(diag_ratio(&dense) < 0.25);
    }

    #[test]
    fn block_energy_of_block_diag() {
        let a = Mat::from_fn(2, 2, |_, _| 1.0);
        let h = block_diag(&[a.clone(), a]);
        let r = block_energy_ratio(&h, &[(0, 2), (2, 2)]);
        assert!((r - 1.0).abs() < 1e-12);
        let r_half = block_energy_ratio(&h, &[(0, 2)]);
        assert!((r_half - 0.5).abs() < 1e-12);
    }
}
