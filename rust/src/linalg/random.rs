//! Random matrix generators used by the paper's §2 case studies.
//!
//! `rotation_matrix` reimplements the paper's Appendix F.2 generator for
//! Fig 5 exactly: Q is a product of d(d−1)/2 Givens rotations with
//! angles θ_ij; H_b = Q Λ Qᵀ with Λ = diag(κ, 1, …, 1). Scaling the θ
//! sample by R ∈ [0, 1] sweeps the diagonal-ratio τ without changing
//! the spectrum.

use super::mat::Mat;
use crate::util::prng::Rng;

/// Orthogonal matrix from a full set of Givens rotations; `angles[k]`
/// indexes the (i, j) pairs in row-major upper-triangular order.
pub fn rotation_matrix(n: usize, angles: &[f64]) -> Mat {
    assert_eq!(angles.len(), n * (n - 1) / 2, "need d(d-1)/2 angles");
    let mut q = Mat::identity(n);
    let mut k = 0;
    for i in 0..n {
        for j in (i + 1)..n {
            let (c, s) = (angles[k].cos(), angles[k].sin());
            k += 1;
            // q <- P · q, where P rotates rows i and j.
            for col in 0..n {
                let qi = q.get(i, col);
                let qj = q.get(j, col);
                q.set(i, col, c * qi + s * qj);
                q.set(j, col, -s * qi + c * qj);
            }
        }
    }
    q
}

/// Sample d(d−1)/2 angles uniform in [−π/2, π/2] (paper Appendix F.2).
pub fn sample_angles(n: usize, rng: &mut Rng) -> Vec<f64> {
    (0..n * (n - 1) / 2)
        .map(|_| rng.range(-std::f64::consts::FRAC_PI_2,
                           std::f64::consts::FRAC_PI_2))
        .collect()
}

/// H = Q diag(eigs) Qᵀ with Q from the given rotation angles.
pub fn pd_from_rotations(eigs: &[f64], angles: &[f64]) -> Mat {
    let q = rotation_matrix(eigs.len(), angles);
    q.matmul(&Mat::diag(eigs)).matmul(&q.transpose())
}

/// Random PD matrix with the given eigenvalues and a random rotation.
pub fn random_pd_from_eigs(eigs: &[f64], rng: &mut Rng) -> Mat {
    let angles = sample_angles(eigs.len(), rng);
    pd_from_rotations(eigs, &angles)
}

/// Block-diagonal composition (paper Fig 4's three-block Hessian).
pub fn block_diag(blocks: &[Mat]) -> Mat {
    let n: usize = blocks.iter().map(|b| b.rows).sum();
    let mut out = Mat::zeros(n, n);
    let mut off = 0;
    for b in blocks {
        assert_eq!(b.rows, b.cols);
        for i in 0..b.rows {
            for j in 0..b.cols {
                out.set(off + i, off + j, b.get(i, j));
            }
        }
        off += b.rows;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{cond_sym, eigh};
    use crate::util::prop::{check, prop_close};

    #[test]
    fn rotation_is_orthogonal() {
        check(16, |rng| {
            let n = 2 + rng.below(6);
            let q = rotation_matrix(n, &sample_angles(n, rng));
            let qtq = q.transpose().matmul(&q);
            let eye = Mat::identity(n);
            let mut err: f64 = 0.0;
            for (a, b) in qtq.data.iter().zip(&eye.data) {
                err = err.max((a - b).abs());
            }
            prop_close(err, 0.0, 1e-10, 0.0, "QᵀQ − I")
        });
    }

    #[test]
    fn pd_preserves_spectrum() {
        check(12, |rng| {
            let n = 2 + rng.below(5);
            let eigs: Vec<f64> =
                (0..n).map(|i| 1.0 + i as f64 + rng.f64()).collect();
            let h = random_pd_from_eigs(&eigs, rng);
            let mut got = eigh(&h).values;
            got.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mut want = eigs.clone();
            want.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for (g, w) in got.iter().zip(&want) {
                prop_close(*g, *w, 1e-7, 1e-9, "eigenvalue")?;
            }
            Ok(())
        });
    }

    #[test]
    fn zero_angles_give_diagonal() {
        let eigs = [5.0, 1.0, 1.0];
        let h = pd_from_rotations(&eigs, &vec![0.0; 3]);
        assert_eq!(h, Mat::diag(&eigs));
        assert!((cond_sym(&h) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn block_diag_layout() {
        let a = Mat::from_fn(2, 2, |_, _| 1.0);
        let b = Mat::from_fn(1, 1, |_, _| 9.0);
        let h = block_diag(&[a, b]);
        assert_eq!(h.rows, 3);
        assert_eq!(h.get(2, 2), 9.0);
        assert_eq!(h.get(0, 2), 0.0);
        assert_eq!(h.get(2, 0), 0.0);
    }
}
