//! Cyclic Jacobi eigensolver for real symmetric matrices.
//!
//! Classic Givens-rotation sweeps until all off-diagonal mass is below
//! tolerance. Accurate and simple; O(n³) per sweep with typically < 15
//! sweeps for the ≤ few-hundred-dim matrices in the paper's case studies.

use super::mat::Mat;

/// Eigendecomposition result: `h ≈ vectors · diag(values) · vectorsᵀ`,
/// eigenvectors in the *columns* of `vectors`.
#[derive(Debug, Clone)]
pub struct Eigh {
    pub values: Vec<f64>,
    pub vectors: Mat,
}

/// Eigendecomposition of a symmetric matrix by cyclic Jacobi rotation.
pub fn eigh(h: &Mat) -> Eigh {
    assert_eq!(h.rows, h.cols, "eigh needs a square matrix");
    let n = h.rows;
    let mut a = h.clone();
    let mut v = Mat::identity(n);
    let tol = 1e-12 * a.max_abs().max(1e-300);

    for _sweep in 0..64 {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += a.get(i, j).abs();
            }
        }
        if off < tol * (n * n) as f64 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a.get(p, q);
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = a.get(p, p);
                let aqq = a.get(q, q);
                let theta = (aqq - app) / (2.0 * apq);
                // Stable tangent of rotation angle.
                let t = theta.signum()
                    / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;

                // A <- Jᵀ A J, applied to rows/cols p and q.
                for k in 0..n {
                    let akp = a.get(k, p);
                    let akq = a.get(k, q);
                    a.set(k, p, c * akp - s * akq);
                    a.set(k, q, s * akp + c * akq);
                }
                for k in 0..n {
                    let apk = a.get(p, k);
                    let aqk = a.get(q, k);
                    a.set(p, k, c * apk - s * aqk);
                    a.set(q, k, s * apk + c * aqk);
                }
                // Accumulate eigenvectors: V <- V J.
                for k in 0..n {
                    let vkp = v.get(k, p);
                    let vkq = v.get(k, q);
                    v.set(k, p, c * vkp - s * vkq);
                    v.set(k, q, s * vkp + c * vkq);
                }
            }
        }
    }

    let values: Vec<f64> = (0..n).map(|i| a.get(i, i)).collect();
    Eigh { values, vectors: v }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop::{check, prop_close};

    #[test]
    fn eigen_of_diagonal() {
        let h = Mat::diag(&[3.0, -1.0, 7.0]);
        let mut vals = eigh(&h).values;
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((vals[0] + 1.0).abs() < 1e-10);
        assert!((vals[1] - 3.0).abs() < 1e-10);
        assert!((vals[2] - 7.0).abs() < 1e-10);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3.
        let h = Mat { rows: 2, cols: 2, data: vec![2.0, 1.0, 1.0, 2.0] };
        let mut vals = eigh(&h).values;
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((vals[0] - 1.0).abs() < 1e-10);
        assert!((vals[1] - 3.0).abs() < 1e-10);
    }

    #[test]
    fn reconstructs_matrix_property() {
        check(24, |rng: &mut Rng| {
            let n = 2 + rng.below(8);
            // Random symmetric matrix.
            let mut h = Mat::zeros(n, n);
            for i in 0..n {
                for j in i..n {
                    let v = rng.normal();
                    h.set(i, j, v);
                    h.set(j, i, v);
                }
            }
            let e = eigh(&h);
            // V diag(w) Vᵀ == H
            let rec = e
                .vectors
                .matmul(&Mat::diag(&e.values))
                .matmul(&e.vectors.transpose());
            let mut max_err: f64 = 0.0;
            for (a, b) in rec.data.iter().zip(&h.data) {
                max_err = max_err.max((a - b).abs());
            }
            prop_close(max_err, 0.0, 1e-8, 0.0, "reconstruction error")
        });
    }

    #[test]
    fn vectors_orthonormal_property() {
        check(24, |rng: &mut Rng| {
            let n = 2 + rng.below(6);
            let mut h = Mat::zeros(n, n);
            for i in 0..n {
                for j in i..n {
                    let v = rng.range(-2.0, 2.0);
                    h.set(i, j, v);
                    h.set(j, i, v);
                }
            }
            let e = eigh(&h);
            let vtv = e.vectors.transpose().matmul(&e.vectors);
            let eye = Mat::identity(n);
            let mut max_err: f64 = 0.0;
            for (a, b) in vtv.data.iter().zip(&eye.data) {
                max_err = max_err.max((a - b).abs());
            }
            prop_close(max_err, 0.0, 1e-9, 0.0, "VᵀV − I")
        });
    }
}
