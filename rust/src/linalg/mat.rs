//! Dense row-major f64 matrix with the operations the case studies need.

use std::fmt;

/// Dense row-major matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    pub fn from_fn(rows: usize, cols: usize,
                   f: impl Fn(usize, usize) -> f64) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.set(i, j, f(i, j));
            }
        }
        m
    }

    pub fn diag(values: &[f64]) -> Mat {
        let n = values.len();
        let mut m = Mat::zeros(n, n);
        for (i, &v) in values.iter().enumerate() {
            m.set(i, i, v);
        }
        m
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |i, j| self.get(j, i))
    }

    /// Naive O(n³) matmul with transposed-B inner loop for locality.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let bt = other.transpose();
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            let arow = self.row(i);
            for j in 0..other.cols {
                let brow = bt.row(j);
                let mut acc = 0.0;
                for k in 0..self.cols {
                    acc += arow[k] * brow[k];
                }
                out.set(i, j, acc);
            }
        }
        out
    }

    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|i| {
                self.row(i)
                    .iter()
                    .zip(x)
                    .map(|(a, b)| a * b)
                    .sum()
            })
            .collect()
    }

    /// Left-multiply by a diagonal matrix: returns Diag(d) · self.
    pub fn scale_rows(&self, d: &[f64]) -> Mat {
        assert_eq!(d.len(), self.rows);
        Mat::from_fn(self.rows, self.cols, |i, j| d[i] * self.get(i, j))
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&other.data) {
            *a += b;
        }
        out
    }

    pub fn scaled(&self, s: f64) -> Mat {
        let mut out = self.clone();
        for a in out.data.iter_mut() {
            *a *= s;
        }
        out
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, &x| m.max(x.abs()))
    }

    /// Symmetrize in place: H <- (H + Hᵀ)/2 (tames finite-difference
    /// asymmetry before eigensolving).
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self.get(i, j) + self.get(j, i));
                self.set(i, j, v);
                self.set(j, i, v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(3, 3, |i, j| (i * 3 + j) as f64);
        let i = Mat::identity(3);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat { rows: 2, cols: 2, data: vec![1.0, 2.0, 3.0, 4.0] };
        let b = Mat { rows: 2, cols: 2, data: vec![5.0, 6.0, 7.0, 8.0] };
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matvec_known() {
        let a = Mat { rows: 2, cols: 3,
                      data: vec![1.0, 0.0, 2.0, 0.0, 1.0, -1.0] };
        assert_eq!(a.matvec(&[1.0, 2.0, 3.0]), vec![7.0, -1.0]);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_fn(2, 4, |i, j| (i + 10 * j) as f64);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn scale_rows_is_diag_mul() {
        let a = Mat::from_fn(2, 2, |_, _| 1.0);
        let s = a.scale_rows(&[2.0, 3.0]);
        assert_eq!(s.data, vec![2.0, 2.0, 3.0, 3.0]);
    }

    #[test]
    fn symmetrize_works() {
        let mut a = Mat { rows: 2, cols: 2, data: vec![1.0, 2.0, 4.0, 1.0] };
        a.symmetrize();
        assert_eq!(a.get(0, 1), 3.0);
        assert_eq!(a.get(1, 0), 3.0);
    }
}
