//! Typed model-level runtime: parameter init + grad/eval/fused-train
//! step functions over one model's artifacts.

use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use super::engine::{lit_i32, lit_scalar, lit_to_scalar, lit_to_tensor,
                    tensor_to_lit, Engine, Executable};
use super::manifest::ModelManifest;
use crate::data::Batch;
use crate::optim::StateDict;
use crate::partition::Strategy;
use crate::tensor::Tensor;
use crate::util::prng::Rng;

/// One model's runtime surface.
pub struct ModelRuntime<'e> {
    pub engine: &'e Engine,
    pub mm: ModelManifest,
    grad_exe: Rc<Executable>,
    eval_exe: Rc<Executable>,
}

impl<'e> ModelRuntime<'e> {
    pub fn new(engine: &'e Engine, model: &str) -> Result<ModelRuntime<'e>> {
        let mm = engine.manifest.model(model)?.clone();
        Ok(ModelRuntime {
            grad_exe: engine.load(model, "grad")?,
            eval_exe: engine.load(model, "eval")?,
            engine,
            mm,
        })
    }

    /// GPT-2-style init matching `compile/model.py`: N(0, 0.02) with
    /// residual-output matrices scaled by 1/sqrt(2L); norms at 1.
    /// (Distribution-level match; streams differ from jax PRNG, which is
    /// fine — all optimizer comparisons share this init.)
    pub fn init_params(&self, seed: u64) -> Vec<Tensor> {
        let mut rng = Rng::new(seed ^ 0x1217);
        let resid = ["wo", "w2", "w_out"];
        self.mm
            .params
            .iter()
            .map(|p| {
                if p.name.contains("norm") {
                    Tensor::ones(&*p.name, &p.shape)
                } else {
                    let mut std = 0.02f32;
                    if resid.contains(&p.name.as_str()) {
                        std /= (2.0 * self.mm.n_layers as f32).sqrt();
                    }
                    Tensor::randn(&*p.name, &p.shape, std, &mut rng)
                }
            })
            .collect()
    }

    fn batch_lits(&self, batch: &Batch) -> Result<[xla::Literal; 2]> {
        if batch.batch_size != self.mm.batch_size
            || batch.seq_len != self.mm.seq_len
        {
            bail!("batch ({}, {}) does not match model ({}, {})",
                  batch.batch_size, batch.seq_len, self.mm.batch_size,
                  self.mm.seq_len);
        }
        let shape = [self.mm.batch_size, self.mm.seq_len];
        Ok([lit_i32(&shape, &batch.tokens)?,
            lit_i32(&shape, &batch.targets)?])
    }

    /// loss + per-parameter gradients, streamed: `sink(param_index,
    /// gradient)` fires once per parameter in REVERSE parameter order
    /// — the order a backward pass produces gradients (output layers
    /// first), which is the readiness order overlapped communication
    /// schedules key on. Each gradient is materialized from the
    /// executable's output buffer only when its turn comes, so a
    /// consumer can launch collectives on early gradients while later
    /// ones are still being converted.
    pub fn grad_streamed<F>(&self, params: &[Tensor], batch: &Batch,
                            mut sink: F) -> Result<f32>
    where
        F: FnMut(usize, Tensor) -> Result<()>,
    {
        let [tok, tgt] = self.batch_lits(batch)?;
        let mut args = vec![tok, tgt];
        for p in params {
            args.push(tensor_to_lit(p)?);
        }
        let outs = self.grad_exe.run(&args)?;
        let loss = lit_to_scalar(&outs[0])?;
        for j in (0..outs.len() - 1).rev() {
            let g = lit_to_tensor(&outs[1 + j],
                                  &self.grad_exe.outputs[1 + j])?;
            sink(j, g)?;
        }
        Ok(loss)
    }

    /// loss + gradients (the universal substrate for host optimizers);
    /// a collecting wrapper over [`ModelRuntime::grad_streamed`].
    pub fn grad(&self, params: &[Tensor], batch: &Batch)
        -> Result<(f32, Vec<Tensor>)> {
        let n = self.mm.params.len();
        let mut grads: Vec<Option<Tensor>> =
            (0..n).map(|_| None).collect();
        let loss = self.grad_streamed(params, batch, |j, g| {
            grads[j] = Some(g);
            Ok(())
        })?;
        let grads = grads
            .into_iter()
            .enumerate()
            .map(|(j, g)| {
                g.ok_or_else(|| {
                    anyhow!("grad artifact produced no output for \
                             parameter {j}")
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok((loss, grads))
    }

    /// Evaluation loss on one batch.
    pub fn eval_loss(&self, params: &[Tensor], batch: &Batch)
        -> Result<f32> {
        let [tok, tgt] = self.batch_lits(batch)?;
        let mut args = vec![tok, tgt];
        for p in params {
            args.push(tensor_to_lit(p)?);
        }
        let outs = self.eval_exe.run(&args)?;
        lit_to_scalar(&outs[0])
    }

    /// A fused train-step handle (`train_adamw`, `train_adam_mini`,
    /// `train_adam_mini_default`, `*_ref`, ...).
    pub fn fused(&self, key: &str) -> Result<FusedTrainer> {
        let exe = self.engine.load(&self.mm.name, key)?;
        let info = &self.mm.artifacts[key];
        let optimizer = info
            .optimizer
            .clone()
            .ok_or_else(|| anyhow!("{key} is not a train artifact"))?;
        let strategy = Strategy::from_name(
            info.strategy.as_deref().unwrap_or("hessian"))?;

        // v-state shapes follow the ABI: full mirrors for adamw, one
        // (num_blocks,) vector per tensor for adam-mini.
        let v_shapes: Vec<Vec<usize>> = if optimizer == "adamw" {
            self.mm.params.iter().map(|p| p.shape.clone()).collect()
        } else {
            self.mm
                .params
                .iter()
                .map(|p| {
                    let bv = p.block_view(strategy)?;
                    Ok(vec![bv.num_blocks])
                })
                .collect::<Result<_>>()?
        };
        let init_m: Vec<Tensor> = self
            .mm
            .params
            .iter()
            .map(|p| Tensor::zeros(&*p.name, &p.shape))
            .collect();
        let init_v: Vec<Tensor> = v_shapes
            .iter()
            .zip(&self.mm.params)
            .map(|(s, p)| Tensor::zeros(&*p.name, s))
            .collect();
        let state_elems = init_m.iter().map(Tensor::numel).sum::<usize>()
            + init_v.iter().map(Tensor::numel).sum::<usize>();
        Ok(FusedTrainer {
            exe,
            n_tensors: self.mm.params.len(),
            state: None,
            init_m,
            init_v,
            state_elems,
            t: 0,
        })
    }
}

/// Fused AOT train step: owns the optimizer state, steps params in place.
/// The whole update — grad + Pallas optimizer kernel — is one XLA
/// executable.
///
/// Perf note (EXPERIMENTS.md §Perf): after the first step, the
/// (params, m, v) state lives as **XLA literals** — the executable's
/// own outputs are fed straight back as the next step's inputs, so the
/// hot loop performs no host `Vec<f32>` ⇄ literal conversions.
/// [`FusedTrainer::step_device`] is that fast path; [`FusedTrainer::step`]
/// additionally refreshes the caller's host tensors every step (the
/// equivalence-testing path).
pub struct FusedTrainer {
    exe: Rc<Executable>,
    n_tensors: usize,
    /// Literal-resident state: params ++ m ++ v (None until first step).
    state: Option<Vec<xla::Literal>>,
    /// Host m/v used only to seed the first step (zeros).
    init_m: Vec<Tensor>,
    init_v: Vec<Tensor>,
    state_elems: usize,
    pub t: u64,
}

impl FusedTrainer {
    /// Fast path: state stays as literals; `params` is NOT updated
    /// (call [`Self::sync_params`] before reading it).
    pub fn step_device(&mut self, params: &[Tensor], batch: &Batch,
                       lr: f32) -> Result<f32> {
        self.t += 1;
        let n = self.n_tensors;
        assert_eq!(params.len(), n);
        let spec0 = &self.exe.inputs[0];
        // Per-step inputs (batch + scalars) are tiny.
        let head = [
            lit_i32(&spec0.shape, &batch.tokens)?,
            lit_i32(&spec0.shape, &batch.targets)?,
            lit_scalar(lr),
            lit_scalar(self.t as f32),
        ];
        if self.state.is_none() {
            // First step: upload host params + zero state once.
            let mut st = Vec::with_capacity(3 * n);
            for p in params.iter().chain(&self.init_m).chain(&self.init_v)
            {
                st.push(tensor_to_lit(p)?);
            }
            self.state = Some(st);
        }
        let state = self.state.as_ref().unwrap();
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(4 + 3 * n);
        args.extend(head.iter());
        args.extend(state.iter());
        let mut outs = self.exe.run(&args)?;
        let loss = lit_to_scalar(&outs[0])?;
        // Outputs: loss, params, m, v — feed straight back next step.
        self.state = Some(outs.split_off(1));
        Ok(loss)
    }

    /// Compatible path: fast step + host-tensor refresh.
    pub fn step(&mut self, params: &mut [Tensor], batch: &Batch, lr: f32)
        -> Result<f32> {
        let loss = self.step_device(params, batch, lr)?;
        self.sync_params(params)?;
        Ok(loss)
    }

    /// Copy the literal-resident parameters back into host tensors.
    pub fn sync_params(&self, params: &mut [Tensor]) -> Result<()> {
        if let Some(state) = &self.state {
            for (i, p) in params.iter_mut().enumerate() {
                *p = lit_to_tensor(&state[i], &self.exe.outputs[1 + i])?;
            }
        }
        Ok(())
    }

    /// Current optimizer state (m, v) as host tensors.
    pub fn state_tensors(&self) -> Result<(Vec<Tensor>, Vec<Tensor>)> {
        let n = self.n_tensors;
        match &self.state {
            None => Ok((self.init_m.clone(), self.init_v.clone())),
            Some(state) => {
                let m = (0..n)
                    .map(|i| lit_to_tensor(&state[n + i],
                                           &self.exe.outputs[1 + n + i]))
                    .collect::<Result<_>>()?;
                let v = (0..n)
                    .map(|i| lit_to_tensor(&state[2 * n + i],
                                           &self.exe.outputs[1 + 2 * n
                                                             + i]))
                    .collect::<Result<_>>()?;
                Ok((m, v))
            }
        }
    }

    /// Current optimizer state as a named [`StateDict`] — the same
    /// key convention the host optimizers export (`m/<tensor>`,
    /// `v/<tensor>`, `__step`), so fused-path state is inspectable
    /// next to host-path checkpoints even though the fused trainer
    /// has no import ABI (its state is device-resident).
    pub fn state_dict(&self) -> Result<StateDict> {
        let (m, v) = self.state_tensors()?;
        let mut sd = StateDict::new();
        for t in &m {
            sd.insert(format!("m/{}", t.name), &t.shape,
                      t.data.clone());
        }
        for t in &v {
            sd.insert(format!("v/{}", t.name), &t.shape,
                      t.data.clone());
        }
        sd.set_step(self.t);
        Ok(sd)
    }

    /// Optimizer-state bytes held by this fused trainer.
    pub fn state_bytes(&self) -> usize {
        self.state_elems * 4
    }
}
