//! L3 ⇄ L2 runtime: loads the AOT HLO-text artifacts through the `xla`
//! crate's PJRT CPU client and exposes typed step functions.
//!
//! Interchange contract (see `python/compile/aot.py` and DESIGN.md §6):
//! HLO *text* + `manifest.json` describing positional I/O. The Rust
//! binary is self-contained once `make artifacts` has run — Python is
//! never on the step path.

pub mod engine;
pub mod manifest;
pub mod model;

pub use engine::{Engine, Executable};
pub use manifest::{ArtifactInfo, IoSpec, Manifest, ModelManifest,
                   ParamInfo};
pub use model::ModelRuntime;
