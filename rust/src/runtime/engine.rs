//! PJRT engine: HLO-text loading, executable caching, literal bridging.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{IoSpec, Manifest};
use crate::telemetry::{Event, EventBus};
use crate::tensor::Tensor;

/// A compiled executable + its I/O contract.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub name: String,
}

impl Executable {
    /// Execute with host literals (owned or borrowed); returns
    /// decomposed output literals (the module root is a tuple —
    /// `return_tuple=True` at lowering).
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self, args: &[L]) -> Result<Vec<xla::Literal>> {
        if args.len() != self.inputs.len() {
            bail!("{}: got {} args, artifact wants {}", self.name,
                  args.len(), self.inputs.len());
        }
        let result = self
            .exe
            .execute::<L>(args)
            .with_context(|| format!("executing {}", self.name))?;
        let mut lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching {} outputs", self.name))?;
        let outs = lit.decompose_tuple()?;
        if outs.len() != self.outputs.len() {
            bail!("{}: got {} outputs, manifest says {}", self.name,
                  outs.len(), self.outputs.len());
        }
        Ok(outs)
    }

    /// Execute taking device buffers (kept for state that stays on
    /// device between steps) — outputs still come back as literals.
    pub fn run_b(&self, args: &[xla::PjRtBuffer])
        -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute_b::<xla::PjRtBuffer>(args)
            .with_context(|| format!("executing {}", self.name))?;
        let mut lit = result[0][0].to_literal_sync()?;
        Ok(lit.decompose_tuple()?)
    }
}

/// PJRT CPU client + executable cache keyed by artifact file name.
pub struct Engine {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
    /// Telemetry tap: artifact compile (cache-miss) events.
    bus: RefCell<Option<Arc<EventBus>>>,
}

impl Engine {
    /// Create a CPU engine over an artifacts directory.
    pub fn new(dir: impl Into<PathBuf>) -> Result<Engine> {
        let dir = dir.into();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
        Ok(Engine { client, manifest, dir, cache: RefCell::new(
            HashMap::new()), bus: RefCell::new(None) })
    }

    /// Publish [`Event::ArtifactLoaded`] for every future cache-miss
    /// compile.
    pub fn attach_bus(&self, bus: Arc<EventBus>) {
        *self.bus.borrow_mut() = Some(bus);
    }

    /// Engine over the default artifacts dir ($ADAM_MINI_ARTIFACTS).
    pub fn default_engine() -> Result<Engine> {
        Engine::new(super::manifest::default_dir())
    }

    /// Load (or fetch cached) an artifact of `model` by key
    /// (`grad`, `eval`, `train_adamw`, `train_adam_mini`, ...).
    pub fn load(&self, model: &str, key: &str) -> Result<Rc<Executable>> {
        let mm = self.manifest.model(model)?;
        let info = mm
            .artifacts
            .get(key)
            .ok_or_else(|| anyhow!(
                "model {model} has no artifact {key:?} (have {:?})",
                mm.artifacts.keys().collect::<Vec<_>>()))?;
        if let Some(exe) = self.cache.borrow().get(&info.file) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(&info.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().unwrap())
            .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", info.file))?;
        if let Some(bus) = self.bus.borrow().as_ref() {
            bus.publish(Event::ArtifactLoaded {
                name: format!("{model}/{key}"),
                ms: t0.elapsed().as_secs_f64() * 1e3,
            });
        }
        let exe = Rc::new(Executable {
            exe,
            inputs: info.inputs.clone(),
            outputs: info.outputs.clone(),
            name: format!("{model}/{key}"),
        });
        self.cache
            .borrow_mut()
            .insert(info.file.clone(), exe.clone());
        Ok(exe)
    }
}

// ---------------------------------------------------------------------------
// Literal bridging
// ---------------------------------------------------------------------------

/// f32 literal with shape.
pub fn lit_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    if shape.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape {shape:?}: {e:?}"))
}

/// i32 literal with shape.
pub fn lit_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    if shape.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape {shape:?}: {e:?}"))
}

pub fn lit_scalar(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Tensor -> literal.
pub fn tensor_to_lit(t: &Tensor) -> Result<xla::Literal> {
    lit_f32(&t.shape, &t.data)
}

/// literal -> Tensor (shape from the manifest spec).
pub fn lit_to_tensor(l: &xla::Literal, spec: &IoSpec) -> Result<Tensor> {
    let data = l
        .to_vec::<f32>()
        .map_err(|e| anyhow!("literal {} to_vec: {e:?}", spec.name))?;
    if data.len() != spec.numel() {
        bail!("{}: literal has {} elements, expected {}", spec.name,
              data.len(), spec.numel());
    }
    Ok(Tensor::new(&*spec.name, &spec.shape, data))
}

/// Scalar f32 from a rank-0 literal.
pub fn lit_to_scalar(l: &xla::Literal) -> Result<f32> {
    l.to_vec::<f32>()
        .map_err(|e| anyhow!("scalar literal: {e:?}"))
        .map(|v| v[0])
}
