//! `artifacts/manifest.json` schema — the L2→L3 ABI.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::partition::{BlockView, Category, Strategy};
use crate::util::json::Json;

/// One tensor in an artifact's positional input/output list.
#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
    pub role: String,  // "batch" | "scalar" | "param" | "m" | "v" | ...
}

impl IoSpec {
    fn parse(j: &Json) -> Result<IoSpec> {
        Ok(IoSpec {
            name: j.get("name")?.as_str()?.to_string(),
            shape: j
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?,
            dtype: j.get("dtype")?.as_str()?.to_string(),
            role: j.get("role")?.as_str()?.to_string(),
        })
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One exported HLO graph.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub optimizer: Option<String>,
    pub strategy: Option<String>,
    pub kernels: Option<String>,
}

impl ArtifactInfo {
    fn parse(j: &Json) -> Result<ArtifactInfo> {
        let io = |key: &str| -> Result<Vec<IoSpec>> {
            j.get(key)?.as_arr()?.iter().map(IoSpec::parse).collect()
        };
        let opt_str = |key: &str| {
            j.opt(key).and_then(|v| v.as_str().ok()).map(str::to_string)
        };
        Ok(ArtifactInfo {
            file: j.get("file")?.as_str()?.to_string(),
            inputs: io("inputs")?,
            outputs: io("outputs")?,
            optimizer: opt_str("optimizer"),
            strategy: opt_str("strategy"),
            kernels: opt_str("kernels"),
        })
    }
}

/// One parameter tensor + its partition under each strategy.
#[derive(Debug, Clone)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub category: String,
    /// strategy name -> (num_blocks, block_size)
    pub blocks: BTreeMap<String, (usize, usize)>,
}

impl ParamInfo {
    fn parse(j: &Json) -> Result<ParamInfo> {
        let mut blocks = BTreeMap::new();
        for strat in ["hessian", "default", "value_whole"] {
            let arr = j.get(strat)?.as_arr()?;
            blocks.insert(strat.to_string(),
                          (arr[0].as_usize()?, arr[1].as_usize()?));
        }
        Ok(ParamInfo {
            name: j.get("name")?.as_str()?.to_string(),
            shape: j
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?,
            category: j.get("category")?.as_str()?.to_string(),
            blocks,
        })
    }

    /// As a [`BlockView`] for the given strategy.
    pub fn block_view(&self, strategy: Strategy) -> Result<BlockView> {
        let (nb, bs) = *self
            .blocks
            .get(strategy.name())
            .ok_or_else(|| anyhow!("no partition for {}", strategy.name()))?;
        let cat = match self.category.as_str() {
            "token_row" => Category::TokenRow,
            "head" => Category::Head,
            "out_neuron" => Category::OutNeuron,
            _ => Category::Whole,
        };
        Ok(BlockView {
            name: self.name.clone(),
            shape: self.shape.clone(),
            num_blocks: nb,
            block_size: bs,
            category: cat,
        })
    }
}

/// One model's exported configuration + artifacts.
#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    pub family: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch_size: usize,
    pub n_params: usize,
    pub v_reduction: f64,
    pub params: Vec<ParamInfo>,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
}

impl ModelManifest {
    fn parse(name: &str, j: &Json) -> Result<ModelManifest> {
        let params = j
            .get("params")?
            .as_arr()?
            .iter()
            .map(ParamInfo::parse)
            .collect::<Result<Vec<_>>>()?;
        let mut artifacts = BTreeMap::new();
        for (k, v) in j.get("artifacts")?.as_obj()? {
            artifacts.insert(k.clone(), ArtifactInfo::parse(v)
                .with_context(|| format!("artifact {k}"))?);
        }
        Ok(ModelManifest {
            name: name.to_string(),
            family: j.get("family")?.as_str()?.to_string(),
            vocab: j.get("vocab")?.as_usize()?,
            d_model: j.get("d_model")?.as_usize()?,
            n_layers: j.get("n_layers")?.as_usize()?,
            n_heads: j.get("n_heads")?.as_usize()?,
            d_ff: j.get("d_ff")?.as_usize()?,
            seq_len: j.get("seq_len")?.as_usize()?,
            batch_size: j.get("batch_size")?.as_usize()?,
            n_params: j.get("n_params")?.as_usize()?,
            v_reduction: j.get("v_reduction")?.as_f64()?,
            params,
            artifacts,
        })
    }

    /// Names of layer-stacked tensors (axis 0 == n_layers).
    pub fn stacked_names(&self) -> Vec<String> {
        self.params
            .iter()
            .filter(|p| {
                p.shape.first() == Some(&self.n_layers)
                    && !matches!(p.name.as_str(),
                                 "embed" | "output" | "pos_emb")
            })
            .map(|p| p.name.clone())
            .collect()
    }

    pub fn meta(&self) -> crate::optim::ModelMeta {
        crate::optim::ModelMeta {
            n_heads: self.n_heads,
            stacked: self.stacked_names(),
        }
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
    pub models: BTreeMap<String, ModelManifest>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run \
                                      `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let hyper = j.get("hyper")?;
        let mut models = BTreeMap::new();
        for (name, mj) in j.get("models")?.as_obj()? {
            models.insert(name.clone(), ModelManifest::parse(name, mj)
                .with_context(|| format!("model {name}"))?);
        }
        Ok(Manifest {
            dir,
            beta1: hyper.get("beta1")?.as_f64()?,
            beta2: hyper.get("beta2")?.as_f64()?,
            eps: hyper.get("eps")?.as_f64()?,
            weight_decay: hyper.get("weight_decay")?.as_f64()?,
            models,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model {name:?} not in manifest \
                                    (have: {:?})",
                                   self.models.keys().collect::<Vec<_>>()))
    }

    pub fn hyper(&self) -> crate::optim::Hyper {
        crate::optim::Hyper {
            beta1: self.beta1 as f32,
            beta2: self.beta2 as f32,
            eps: self.eps as f32,
            weight_decay: self.weight_decay as f32,
        }
    }
}

/// Default artifacts directory: $ADAM_MINI_ARTIFACTS or ./artifacts.
pub fn default_dir() -> PathBuf {
    std::env::var("ADAM_MINI_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<Manifest> {
        Manifest::load(default_dir()).ok()
    }

    #[test]
    fn loads_and_has_models() {
        let Some(m) = manifest() else { return };
        assert!(m.models.contains_key("t295k"));
        assert!((m.beta2 - 0.95).abs() < 1e-12);
    }

    #[test]
    fn partition_agrees_with_rust_partitioner() {
        // GOLDEN: the Python exporter's partition must equal ours for
        // every tensor of every model under every strategy.
        let Some(m) = manifest() else { return };
        for (_, mm) in &m.models {
            let stacked = mm.stacked_names();
            for p in &mm.params {
                for strat in [Strategy::Hessian, Strategy::Default,
                              Strategy::ValueWhole] {
                    let ours = crate::partition::block_view(
                        &p.name, &p.shape, mm.n_heads,
                        stacked.iter().any(|s| s == &p.name), strat)
                        .unwrap();
                    let theirs = p.blocks[strat.name()];
                    assert_eq!(
                        (ours.num_blocks, ours.block_size), theirs,
                        "{}/{} under {}", mm.name, p.name, strat.name());
                }
            }
        }
    }

    #[test]
    fn grad_io_is_consistent() {
        let Some(m) = manifest() else { return };
        let mm = m.model("t295k").unwrap();
        let grad = &mm.artifacts["grad"];
        // inputs: tokens, targets, then params in order.
        assert_eq!(grad.inputs[0].name, "tokens");
        assert_eq!(grad.inputs[1].role, "batch");
        assert_eq!(grad.inputs.len(), 2 + mm.params.len());
        assert_eq!(grad.outputs.len(), 1 + mm.params.len());
        assert_eq!(grad.outputs[0].role, "loss");
        for (io, p) in grad.inputs[2..].iter().zip(&mm.params) {
            assert_eq!(io.name, p.name);
            assert_eq!(io.shape, p.shape);
        }
    }
}
