//! Training coordinator (L3): the step loop that drives AOT executables,
//! host or fused optimizers, schedules, metrics and checkpoints.

pub mod bigram;
pub mod checkpoint;
pub mod trainer;

pub use checkpoint::{load_checkpoint, save_checkpoint};
pub use trainer::{RunHistory, StepLog, Trainer, TrainerMode};
