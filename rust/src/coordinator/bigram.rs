//! `model=bigram`: the artifact-free training path that can span OS
//! processes.
//!
//! The bigram LM (mean CE over a `(vocab, vocab)` logit table,
//! analytic gradient) is the smallest model with a real Adam-mini
//! Hessian partition, and it needs no compiled artifacts — so it is
//! the one model the multi-process `transport=socket` path can run:
//! worker processes re-exec this binary and rebuild the model from
//! the config alone.
//!
//! Every transport drives the SAME per-rank routine ([`run_rank`]):
//! each rank replays the full deterministic batch stream, sums the
//! loss over every micro-batch (f64, micro order — identical on all
//! ranks), accumulates gradients only for its own micro-batches
//! (`i % world == rank`), then runs the shared `rank_step` schedule.
//! Channel threads, TCP threads, and OS processes therefore produce
//! bit-identical loss trajectories by construction; the CI smoke
//! diffs the printed loss bits across transports to prove it.

use anyhow::{bail, Context, Result};

use crate::config::TrainConfig;
use crate::data::{Batch, Batcher, Corpus, SyntheticSpec};
use crate::dist::comm::{ring_world, CommStats, LinkModel,
                        TrafficClass};
use crate::dist::compress::CodecSpec;
use crate::dist::error::DistError;
use crate::dist::shard::{block_cuts, shardable, FlatLayout, Partition};
use crate::dist::transport::proc::{run_parent, ENV_CFG, ENV_RANK};
use crate::dist::transport::{parse_transport, socket_options,
                             socket_ring_world, TransportKind};
use crate::dist::worker::{rank_step, shard_slot, DistOptions,
                          StepMode, WorkerSlot};
use crate::optim::{ModelMeta, ReduceOp, Schedule};
use crate::partition::{BlockView, Strategy};
use crate::tensor::Tensor;
use crate::util::prng::Rng;

pub const VOCAB: usize = 32;

/// Build the bigram parameter list (one `(VOCAB, VOCAB)` table).
pub fn init_params(seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed);
    vec![Tensor::randn("embed", &[VOCAB, VOCAB], 0.1, &mut rng)]
}

pub fn meta() -> ModelMeta {
    ModelMeta { n_heads: 1, stacked: vec![] }
}

/// (mean loss, analytic gradient) over one batch.
pub fn loss_grad(params: &[Tensor], batch: &Batch)
    -> (f32, Vec<Tensor>) {
    let w = &params[0];
    let mut grad = Tensor::zeros("embed", &[VOCAB, VOCAB]);
    let n = batch.tokens.len();
    let inv = 1.0 / n as f32;
    let mut total = 0.0f64;
    for (&tok, &tgt) in batch.tokens.iter().zip(&batch.targets) {
        let (tok, tgt) = (tok as usize, tgt as usize);
        let row = &w.data[tok * VOCAB..(tok + 1) * VOCAB];
        let mx = row.iter().cloned().fold(f32::MIN, f32::max);
        let exps: Vec<f32> =
            row.iter().map(|x| (x - mx).exp()).collect();
        let z: f32 = exps.iter().sum();
        total += (z.ln() + mx - row[tgt]) as f64;
        let grow = &mut grad.data[tok * VOCAB..(tok + 1) * VOCAB];
        for (c, e) in grow.iter_mut().zip(&exps) {
            *c += e / z * inv;
        }
        grow[tgt] -= inv;
    }
    ((total * inv as f64) as f32, vec![grad])
}

fn batcher_for(cfg: &TrainConfig) -> Batcher {
    let corpus = Corpus::synthetic(&SyntheticSpec {
        vocab: VOCAB,
        n_tokens: 20_000,
        seed: cfg.seed ^ 0xDA7A,
        ..Default::default()
    });
    Batcher::new(corpus, 4, 16, cfg.seed)
}

/// Everything a rank needs besides its node: derived once, identically,
/// in every process.
struct BigramPlan {
    params0: Vec<Tensor>,
    layout: FlatLayout,
    partition: Partition,
    mode: StepMode,
    bucket: usize,
    opts: DistOptions,
    schedule: Schedule,
    steps: usize,
    micro: usize,
}

fn plan_for(cfg: &TrainConfig) -> Result<BigramPlan> {
    if cfg.workers == 0 {
        bail!("workers must be >= 1");
    }
    let mode = if cfg.zero2 {
        StepMode::Zero2
    } else {
        StepMode::Zero1
    };
    if !shardable(&cfg.optimizer) {
        bail!("{}: not shardable; the bigram path runs sharded modes \
               only", cfg.optimizer);
    }
    let params0 = init_params(cfg.seed);
    let layout = FlatLayout::of(&params0);
    let is_mini = cfg.optimizer.starts_with("adam_mini");
    let spec: Option<Vec<BlockView>> = if is_mini {
        Some(meta().spec_for(&params0, Strategy::Hessian)?)
    } else {
        None
    };
    let partition = match &spec {
        Some(s) => Partition::aligned(&block_cuts(s), cfg.workers),
        None => Partition::even(layout.total, cfg.workers),
    };
    let bucket = (cfg.bucket_kb.max(1) * 1024) / 4;
    let opts = DistOptions {
        workers: cfg.workers,
        bucket_kb: cfg.bucket_kb,
        zero1: mode == StepMode::Zero1,
        zero2: mode == StepMode::Zero2,
        optimizer: cfg.optimizer.clone(),
        reduce: ReduceOp::Mean,
        spec,
        compress: CodecSpec::parse(&cfg.compress)?,
        ..Default::default()
    };
    Ok(BigramPlan {
        params0,
        layout,
        partition,
        mode,
        bucket,
        opts,
        schedule: cfg.schedule_for(cfg.steps)?,
        steps: cfg.steps,
        micro: cfg.grad_accum.max(1),
    })
}

/// One rank's whole training run. Returns the per-step mean losses
/// (identical on every rank — each replays the full batch stream).
fn run_rank(mut slot: WorkerSlot, plan: &BigramPlan,
            cfg: &TrainConfig)
    -> std::result::Result<Vec<f32>, DistError> {
    let world = slot.node.world;
    let rank = slot.node.rank;
    let mut batcher = batcher_for(cfg);
    let mut params = plan.params0.clone();
    let mut losses = Vec::with_capacity(plan.steps);
    let inv = 1.0 / plan.micro as f32;
    for step in 0..plan.steps {
        let lr = plan.schedule.lr(step);
        let mut total = 0.0f64;
        let mut grad = vec![0.0f32; plan.layout.total];
        for i in 0..plan.micro {
            let batch = batcher.next_batch();
            let (loss, g) = loss_grad(&params, &batch);
            total += loss as f64;
            if i % world == rank {
                plan.layout.accumulate(&mut grad, &g);
            }
        }
        rank_step(&mut slot, &plan.partition.ranges, &mut grad,
                  plan.bucket, plan.mode, inv, lr, step as u64 + 1)?;
        plan.layout.unflatten(&slot.flat_params, &mut params);
        losses.push((total / plan.micro as f64) as f32);
    }
    Ok(losses)
}

/// Print the loss trajectory in a shell-diffable form: the hex f32
/// bits are the cross-transport bit-exactness witness.
fn print_losses(losses: &[f32], stats: &CommStats) {
    for (s, l) in losses.iter().enumerate() {
        println!("step {s} loss_bits 0x{:08x} loss {l}", l.to_bits());
    }
    println!("retry_bytes {}", stats.bytes(TrafficClass::Retry));
}

/// In-process world (channel threads or TCP threads): every rank runs
/// [`run_rank`] on its own thread; rank 0's losses are printed.
fn run_in_process(cfg: &TrainConfig, kind: TransportKind)
    -> Result<()> {
    let plan = plan_for(cfg)?;
    let n = cfg.workers;
    let (nodes, stats) = match &kind {
        TransportKind::Channel => ring_world(n, LinkModel::default()),
        TransportKind::Socket(sopts) => {
            socket_ring_world(n, LinkModel::default(), sopts)?
        }
    };
    let flat = plan.layout.flatten(&plan.params0);
    let mut slots = Vec::with_capacity(n);
    for (w, node) in nodes.into_iter().enumerate() {
        slots.push(shard_slot(node, &plan.layout,
                              plan.partition.ranges[w], &flat,
                              &plan.opts, true)?);
    }
    let plan = &plan;
    let losses: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = slots
            .into_iter()
            .map(|slot| s.spawn(move || run_rank(slot, plan, cfg)))
            .collect();
        handles
            .into_iter()
            .enumerate()
            .map(|(rank, h)| {
                h.join()
                    .unwrap_or(Err(DistError::WorkerPanicked { rank }))
            })
            .collect()
    });
    let mut rank0 = None;
    for (rank, l) in losses.into_iter().enumerate() {
        let l = l.with_context(|| format!("worker rank {rank}"))?;
        if rank == 0 {
            rank0 = Some(l);
        }
    }
    print_losses(&rank0.expect("rank 0 result"), &stats);
    Ok(())
}

/// Entry point for `repro train model=bigram ...` — dispatches on the
/// transport: in-process threads for `channel`/`tcp`, one OS process
/// per rank for `socket`.
pub fn train(cfg: &TrainConfig) -> Result<()> {
    if cfg.model != "bigram" {
        bail!("bigram driver got model {:?}", cfg.model);
    }
    eprintln!(
        "bigram: workers={} transport={} optimizer={} steps={} \
         micro={} mode={}",
        cfg.workers, cfg.transport, cfg.optimizer, cfg.steps,
        cfg.grad_accum.max(1),
        if cfg.zero2 { "zero2" } else { "zero1" });
    if cfg.transport == "socket" {
        // Validate the plan (and the fault spec) before paying for
        // process spawns; children re-derive both from the config.
        plan_for(cfg)?;
        socket_options(&cfg.fault, cfg.fault_seed)?;
        return run_parent(cfg.workers, &cfg.to_json().to_string());
    }
    let kind =
        parse_transport(&cfg.transport, &cfg.fault, cfg.fault_seed)?;
    run_in_process(cfg, kind)
}

/// Child-process entry point (the hidden `dist-worker` subcommand):
/// reconstruct the config from [`ENV_CFG`], the rank from
/// [`ENV_RANK`], wire this rank into the socket world, run, and let
/// rank 0 own the console.
pub fn worker_main() -> Result<()> {
    let cfg_json = std::env::var(ENV_CFG)
        .with_context(|| format!("{ENV_CFG} not set"))?;
    let rank: usize = std::env::var(ENV_RANK)
        .with_context(|| format!("{ENV_RANK} not set"))?
        .parse()
        .context("bad rank")?;
    let cfg = TrainConfig::from_json_str(&cfg_json)?;
    let plan = plan_for(&cfg)?;
    let sopts = socket_options(&cfg.fault, cfg.fault_seed)?;
    let (node, stats) = crate::dist::transport::proc::child_world(
        rank, cfg.workers, LinkModel::default(), &sopts)?;
    let flat = plan.layout.flatten(&plan.params0);
    let slot = shard_slot(node, &plan.layout,
                          plan.partition.ranges[rank], &flat,
                          &plan.opts, true)?;
    let losses = run_rank(slot, &plan, &cfg)
        .with_context(|| format!("worker rank {rank}"))?;
    if rank == 0 {
        print_losses(&losses, &stats);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn smoke_cfg() -> TrainConfig {
        let mut cfg = TrainConfig::default();
        cfg.model = "bigram".into();
        cfg.optimizer = "adam_mini".into();
        cfg.steps = 4;
        cfg.grad_accum = 2;
        cfg.workers = 3;
        cfg.bucket_kb = 1;
        cfg.schedule = "const".into();
        cfg.peak_lr = 2e-2;
        cfg
    }

    fn losses_for(cfg: &TrainConfig, kind: TransportKind)
        -> Vec<f32> {
        let plan = plan_for(cfg).unwrap();
        let n = cfg.workers;
        let (nodes, _stats) = match &kind {
            TransportKind::Channel => {
                ring_world(n, LinkModel::default())
            }
            TransportKind::Socket(sopts) => {
                socket_ring_world(n, LinkModel::default(), sopts)
                    .unwrap()
            }
        };
        let flat = plan.layout.flatten(&plan.params0);
        let mut slots = Vec::new();
        for (w, node) in nodes.into_iter().enumerate() {
            slots.push(shard_slot(node, &plan.layout,
                                  plan.partition.ranges[w], &flat,
                                  &plan.opts, true).unwrap());
        }
        let plan = &plan;
        std::thread::scope(|s| {
            let handles: Vec<_> = slots
                .into_iter()
                .map(|slot| {
                    s.spawn(move || run_rank(slot, plan, cfg))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap().unwrap())
                .next()
                .unwrap()
        })
    }

    #[test]
    fn channel_and_tcp_losses_are_bit_identical() {
        let cfg = smoke_cfg();
        let chan = losses_for(&cfg, TransportKind::Channel);
        let tcp = losses_for(
            &cfg,
            TransportKind::Socket(
                crate::dist::transport::SocketOptions::default()));
        assert_eq!(chan.len(), 4);
        let cb: Vec<u32> =
            chan.iter().map(|l| l.to_bits()).collect();
        let tb: Vec<u32> = tcp.iter().map(|l| l.to_bits()).collect();
        assert_eq!(cb, tb);
        // And the model actually trains.
        assert!(chan[3] < chan[0]);
    }

    #[test]
    fn world_size_is_invisible_in_the_loss_bits() {
        let mut solo = smoke_cfg();
        solo.workers = 1;
        solo.grad_accum = 1;
        let mut wide = smoke_cfg();
        wide.workers = 4;
        wide.grad_accum = 1;
        // One micro-batch: idle ranks contribute exact zeros, so the
        // 4-worker trajectory is bit-identical to the solo run.
        let a = losses_for(&solo, TransportKind::Channel);
        let b = losses_for(&wide, TransportKind::Channel);
        assert_eq!(
            a.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|l| l.to_bits()).collect::<Vec<_>>());
    }

    #[test]
    fn topk_losses_are_transport_invariant() {
        // The codec runs above the wire, so compressed runs keep the
        // cross-transport bit-exactness witness.
        let mut cfg = smoke_cfg();
        cfg.compress = "topk:0.5".into();
        let chan = losses_for(&cfg, TransportKind::Channel);
        let tcp = losses_for(
            &cfg,
            TransportKind::Socket(
                crate::dist::transport::SocketOptions::default()));
        assert_eq!(
            chan.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
            tcp.iter().map(|l| l.to_bits()).collect::<Vec<_>>());
        assert!(chan[3] < chan[0]);
    }

    #[test]
    fn non_shardable_optimizer_is_rejected() {
        let mut cfg = smoke_cfg();
        cfg.optimizer = "adafactor".into();
        assert!(plan_for(&cfg).is_err());
    }
}
